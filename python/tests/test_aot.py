"""AOT path: lowered HLO text is runnable-by-construction for the Rust side.

These tests lower the smallest bucket of each program and validate the
contract the Rust runtime depends on: text parses back, no custom-calls,
parameter/result shapes as documented in the manifest.
"""

import json
import os
import subprocess
import sys

import pytest

from compile import aot

HERE = os.path.dirname(os.path.abspath(__file__))


@pytest.fixture(scope="module")
def embed_text():
    return aot.lower_embed(256, 8)


@pytest.fixture(scope="module")
def kstep_text():
    return aot.lower_kstep(256, aot.KSTEP_K, aot.KSTEP_D)


def test_embed_no_custom_calls(embed_text):
    aot.check_no_custom_calls(embed_text, "embed")  # raises on violation


def test_kstep_no_custom_calls(kstep_text):
    aot.check_no_custom_calls(kstep_text, "kstep")


def test_embed_has_while_loop(embed_text):
    # the fori_loop must survive lowering (otherwise 150 sweeps got unrolled
    # and artifact size/compile time would explode at n=2048)
    assert "while" in embed_text


def test_embed_signature(embed_text):
    head = embed_text[:4000]
    assert "f32[256,8]" in head  # cw param and evecs out
    assert "f32[256]" in head  # w / deg


def test_text_roundtrip_via_parser(embed_text, tmp_path):
    """jax-emitted text must be accepted by XLA's HLO parser (the exact code
    path the Rust runtime uses). We round-trip through xla_client."""
    from jax._src.lib import xla_client as xc

    # The hlo_module_from_text API name moved around across jaxlib versions;
    # parsing via XlaComputation from the text's proto is enough of a check
    # that the text is well-formed HLO the parser accepts.
    if not hasattr(xc._xla, "hlo_module_from_text"):
        pytest.skip("xla_client lacks hlo_module_from_text in this jaxlib")
    mod = xc._xla.hlo_module_from_text(embed_text)
    assert mod is not None


def test_quick_aot_writes_manifest(tmp_path):
    out = tmp_path / "artifacts"
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--quick", "--out-dir", str(out)],
        check=True,
        cwd=os.path.dirname(HERE),
        env=env,
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["format"] == "hlo-text/return-tuple"
    names = {p["name"] for p in manifest["programs"]}
    assert "embed_n256_d8" in names
    for p in manifest["programs"]:
        assert (out / p["file"]).exists()
        # parameter order is the ABI the Rust runtime relies on
        pnames = [q["name"] for q in p["params"]]
        if p["kind"] == "embed":
            assert pnames == ["cw", "w", "sigma"]
        else:
            assert pnames == ["p", "c", "pmask", "cmask"]
