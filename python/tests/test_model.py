"""Layer-2 correctness: spectral embedding + kmeans_step semantics.

Checks against dense numpy linear algebra (eigh) on small problems and
verifies the masking/padding contract the Rust runtime relies on.
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.model import EMBED_K, kmeans_step, spectral_embedding

F32 = np.float32


def two_blobs(n, d, real, sep=4.0, seed=0, scale=0.3):
    """Two well-separated Gaussian blobs + (n - real) padding rows."""
    rng = np.random.default_rng(seed)
    x = np.zeros((n, d), F32)
    half = real // 2
    x[:half] = (rng.standard_normal((half, d)) * scale + sep / 2).astype(F32)
    x[half:real] = (rng.standard_normal((real - half, d)) * scale - sep / 2).astype(F32)
    w = np.zeros(n, F32)
    w[:real] = 1.0
    return x, w


def dense_m(x, w, sigma):
    a = np.asarray(ref.affinity_ref(jnp.array(x), jnp.array(w), jnp.float32(sigma)))
    deg = a.sum(1)
    sd = np.where(deg <= 1e-12, 1.0, deg)
    return a / np.sqrt(sd)[:, None] / np.sqrt(sd)[None, :], deg


def test_embedding_matches_dense_eigh():
    x, w = two_blobs(256, 8, 200)
    v, ritz, deg = spectral_embedding(jnp.array(x), jnp.array(w), jnp.float32(1.0))
    v, ritz, deg = map(np.asarray, (v, ritz, deg))

    m, deg_ref = dense_m(x, w, 1.0)
    evals = np.linalg.eigvalsh(m)[::-1]
    np.testing.assert_allclose(np.sort(ritz)[::-1][:4], evals[:4], atol=2e-3)
    np.testing.assert_allclose(deg, deg_ref, rtol=1e-4, atol=1e-4)


def test_embedding_orthonormal_and_sorted():
    x, w = two_blobs(256, 16, 256, seed=2)
    v, ritz, _ = spectral_embedding(jnp.array(x), jnp.array(w), jnp.float32(1.5))
    v, ritz = np.asarray(v), np.asarray(ritz)
    gram = v.T @ v
    np.testing.assert_allclose(gram, np.eye(EMBED_K), atol=1e-4)
    assert np.all(np.diff(ritz) <= 1e-6), "Ritz values must be sorted descending"
    # eigenvalues of M lie in [-1, 1]
    assert np.all(ritz <= 1.0 + 1e-4) and np.all(ritz >= -1.0 - 1e-4)


def test_embedding_separates_two_blobs():
    """Sign pattern of the 2nd eigenvector must split the two blobs."""
    x, w = two_blobs(256, 8, 200, seed=5)
    v, _, _ = spectral_embedding(jnp.array(x), jnp.array(w), jnp.float32(1.0))
    v = np.asarray(v)
    v2 = v[:200, 1]
    s1, s2 = np.sign(v2[:100]), np.sign(v2[100:200])
    # each blob has a coherent sign and the two differ
    assert np.abs(s1.sum()) == 100
    assert np.abs(s2.sum()) == 100
    assert s1[0] != s2[0]


def test_embedding_pad_value_invariance():
    x1, w = two_blobs(256, 8, 180, seed=6)
    x2 = x1.copy()
    rng = np.random.default_rng(7)
    x2[180:] = rng.standard_normal((76, 8)).astype(F32) * 50
    v1, r1, _ = spectral_embedding(jnp.array(x1), jnp.array(w), jnp.float32(1.0))
    v2, r2, _ = spectral_embedding(jnp.array(x2), jnp.array(w), jnp.float32(1.0))
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), atol=1e-5)
    # eigenvectors defined up to sign per column on real rows
    a, b = np.asarray(v1)[:180], np.asarray(v2)[:180]
    for j in range(EMBED_K):
        s = np.sign(np.dot(a[:, j], b[:, j])) or 1.0
        np.testing.assert_allclose(a[:, j], s * b[:, j], atol=1e-3)


def test_embedding_weighted_mode_runs():
    x, w = two_blobs(256, 8, 200, seed=8)
    w[:200] = np.random.default_rng(0).integers(1, 100, 200).astype(F32)
    v, ritz, deg = spectral_embedding(jnp.array(x), jnp.array(w), jnp.float32(1.0))
    assert np.all(np.isfinite(np.asarray(v)))
    assert np.all(np.isfinite(np.asarray(ritz)))
    assert np.all(np.asarray(deg)[200:] == 0.0)


# -------------------------------------------------------------- kmeans_step


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), k=st.sampled_from([2, 3, 8]))
def test_kmeans_step_decreases_inertia(seed, k):
    rng = np.random.default_rng(seed)
    n, d = 256, 8
    p = rng.standard_normal((n, d)).astype(F32)
    pmask = np.ones(n, F32)
    pmask[240:] = 0.0
    c = p[rng.choice(240, k, replace=False)]
    cmask = np.zeros(8, F32)
    cmask[:k] = 1.0
    cpad = np.zeros((8, d), F32)
    cpad[:k] = c

    prev = np.inf
    cc = cpad
    for _ in range(8):
        cc, idx, shift, inertia = kmeans_step(
            jnp.array(p), jnp.array(cc), jnp.array(pmask), jnp.array(cmask)
        )
        cc = np.asarray(cc)
        inertia = float(inertia)
        assert inertia <= prev + 1e-3, "Lloyd iterations must not increase inertia"
        prev = inertia
    assert float(shift) < 1.0  # should be (near) converged on n=240


def test_kmeans_step_fixed_point():
    """Perfectly centered centroids are a fixed point with shift 0."""
    p = np.array([[0.0, 0], [0, 0], [10, 10], [10, 10]], F32)
    p = np.tile(p, (64, 1))
    c = np.zeros((8, 2), F32)
    c[0] = [0, 0]
    c[1] = [10, 10]
    cmask = np.zeros(8, F32)
    cmask[:2] = 1.0
    pmask = np.ones(256, F32)
    new_c, idx, shift, inertia = kmeans_step(
        jnp.array(p), jnp.array(c), jnp.array(pmask), jnp.array(cmask)
    )
    assert float(shift) == 0.0
    assert float(inertia) == 0.0
    np.testing.assert_array_equal(np.asarray(new_c), c)


def test_kmeans_step_empty_cluster_keeps_centroid():
    rng = np.random.default_rng(1)
    p = (rng.standard_normal((256, 4)) * 0.1).astype(F32)  # all near origin
    c = np.zeros((8, 4), F32)
    c[1] = [100, 100, 100, 100]  # will be empty
    cmask = np.zeros(8, F32)
    cmask[:2] = 1.0
    new_c, idx, _, _ = kmeans_step(
        jnp.array(p), jnp.array(c), jnp.ones(256, dtype=jnp.float32), jnp.array(cmask)
    )
    np.testing.assert_array_equal(np.asarray(new_c)[1], c[1])
    assert np.all(np.asarray(idx) == 0)


def test_kmeans_step_pmask_excludes_padding():
    """Padding rows must not drag centroids."""
    p = np.zeros((256, 2), F32)
    p[:128] = [1.0, 1.0]
    p[128:] = [1000.0, 1000.0]  # padding junk
    pmask = np.zeros(256, F32)
    pmask[:128] = 1.0
    c = np.zeros((8, 2), F32)
    c[0] = [0.5, 0.5]
    cmask = np.zeros(8, F32)
    cmask[0] = 1.0
    new_c, _, _, inertia = kmeans_step(
        jnp.array(p), jnp.array(c), jnp.array(pmask), jnp.array(cmask)
    )
    np.testing.assert_allclose(np.asarray(new_c)[0], [1.0, 1.0], atol=1e-5)
