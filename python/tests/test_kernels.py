"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes, bandwidths, weight patterns and data scales; every
case asserts allclose against ref.py. These are the core correctness signal
for the compute the Rust coordinator executes via the AOT artifacts.
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.affinity import affinity
from compile.kernels.kmeans import kmeans_assign

F32 = np.float32


def rand(rng, *shape, scale=1.0):
    return (rng.standard_normal(shape) * scale).astype(F32)


# ---------------------------------------------------------------- affinity


@settings(max_examples=25, deadline=None)
@given(
    n_tiles=st.integers(1, 3),
    d=st.sampled_from([1, 2, 4, 8, 16, 33, 64]),
    sigma=st.floats(0.05, 50.0),
    scale=st.floats(0.1, 10.0),
    pad=st.integers(0, 100),
    weighted=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_affinity_matches_ref(n_tiles, d, sigma, scale, pad, weighted, seed):
    tile = 128
    n = n_tiles * tile
    rng = np.random.default_rng(seed)
    x = rand(rng, n, d, scale=scale)
    w = np.ones(n, F32)
    if weighted:
        w = rng.integers(1, 500, n).astype(F32)
    pad = min(pad, n - 1)
    if pad:
        w[n - pad :] = 0.0

    got = np.asarray(affinity(jnp.array(x), jnp.array(w), jnp.float32(sigma), tile=tile))
    want = np.asarray(ref.affinity_ref(jnp.array(x), jnp.array(w), jnp.float32(sigma)))
    # Error model: the expanded-form d² carries ~eps·max|x|² absolute error,
    # which propagates through exp(−d²/2σ²) as ≤ Δd²/(2σ²) in both relative
    # and (×w²) absolute terms. Capped so the test still bites: a genuine
    # kernel bug produces O(1) mismatches, far above 1%.
    m = scale * scale * (d + 6.0 * np.sqrt(d))
    dx = 2e-6 * m / (2.0 * sigma * sigma)
    tol = float(np.clip(dx, 1e-5, 1e-2))
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * float(w.max()) ** 2)


def test_affinity_diagonal_zero_and_symmetric():
    rng = np.random.default_rng(7)
    x = rand(rng, 256, 8)
    w = rng.integers(1, 40, 256).astype(F32)
    a = np.asarray(affinity(jnp.array(x), jnp.array(w), jnp.float32(1.5)))
    assert np.all(np.diag(a) == 0.0)
    np.testing.assert_allclose(a, a.T, rtol=1e-6, atol=1e-6)
    assert np.all(a >= 0.0)


def test_affinity_padding_rows_zero():
    rng = np.random.default_rng(8)
    x = rand(rng, 128, 4)
    w = np.ones(128, F32)
    w[100:] = 0.0
    a = np.asarray(affinity(jnp.array(x), jnp.array(w), jnp.float32(1.0)))
    assert np.all(a[100:, :] == 0.0)
    assert np.all(a[:, 100:] == 0.0)


def test_affinity_pad_invariance():
    """Real block of A must not depend on the *values* in padding rows."""
    rng = np.random.default_rng(9)
    x1 = rand(rng, 128, 8)
    x2 = x1.copy()
    x2[100:] = rng.standard_normal((28, 8)).astype(F32) * 100
    w = np.ones(128, F32)
    w[100:] = 0.0
    a1 = np.asarray(affinity(jnp.array(x1), jnp.array(w), jnp.float32(2.0)))
    a2 = np.asarray(affinity(jnp.array(x2), jnp.array(w), jnp.float32(2.0)))
    np.testing.assert_array_equal(a1[:100, :100], a2[:100, :100])


def test_affinity_rejects_bad_tile():
    import pytest

    x = jnp.zeros((100, 4), jnp.float32)
    w = jnp.ones((100,), jnp.float32)
    with pytest.raises(ValueError):
        affinity(x, w, jnp.float32(1.0), tile=128)


# ------------------------------------------------------------- kmeans_assign


@settings(max_examples=25, deadline=None)
@given(
    n_tiles=st.integers(1, 3),
    k=st.sampled_from([1, 2, 3, 8, 17, 64]),
    d=st.sampled_from([1, 2, 5, 8, 32]),
    scale=st.floats(0.1, 10.0),
    inactive=st.integers(0, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_assign_matches_ref(n_tiles, k, d, scale, inactive, seed):
    tile = 256
    n = n_tiles * tile
    rng = np.random.default_rng(seed)
    p = rand(rng, n, d, scale=scale)
    c = rand(rng, k, d, scale=scale)
    cmask = np.ones(k, F32)
    inactive = min(inactive, k - 1)
    if inactive:
        off = rng.choice(k, size=inactive, replace=False)
        cmask[off] = 0.0

    gi, gm = kmeans_assign(jnp.array(p), jnp.array(c), jnp.array(cmask), tile=tile)
    wi, wm = ref.kmeans_assign_ref(jnp.array(p), jnp.array(c), jnp.array(cmask))
    gi, gm, wi, wm = map(np.asarray, (gi, gm, wi, wm))
    # Index can differ only on (near-)distance ties; distances must agree.
    # The expanded |p|^2+|c|^2-2pc form loses ~eps * |coords|^2 absolute
    # precision, so atol scales with the squared data magnitude.
    atol = 1e-4 * max(1.0, scale * scale)
    np.testing.assert_allclose(gm, wm, rtol=1e-5, atol=atol)
    same = gi == wi
    if not same.all():
        # tolerate only genuine (near-)ties
        d2 = np.asarray(ref.pairwise_sqdist_ref(jnp.array(p), jnp.array(c)))
        for i in np.where(~same)[0]:
            assert np.isclose(d2[i, gi[i]], d2[i, wi[i]], rtol=1e-5, atol=atol)


def test_assign_never_picks_inactive():
    rng = np.random.default_rng(3)
    p = rand(rng, 256, 4)
    c = rand(rng, 8, 4)
    cmask = np.array([1, 0, 1, 0, 1, 0, 1, 0], F32)
    gi, _ = kmeans_assign(jnp.array(p), jnp.array(c), jnp.array(cmask))
    assert set(np.asarray(gi).tolist()) <= {0, 2, 4, 6}


def test_assign_known_case():
    p = np.array([[0.0, 0], [10, 0], [0, 10], [10, 10]], F32)
    p = np.tile(p, (64, 1))  # n=256
    c = np.array([[0.0, 0], [10, 0], [0, 10], [10, 10]], F32)
    gi, gm = kmeans_assign(jnp.array(p), jnp.array(c), jnp.ones(4, dtype=jnp.float32))
    assert np.array_equal(np.asarray(gi), np.tile(np.arange(4, dtype=np.int32), 64))
    assert np.allclose(np.asarray(gm), 0.0, atol=1e-5)


def test_assign_shape_mismatch_raises():
    import pytest

    with pytest.raises(ValueError):
        kmeans_assign(
            jnp.zeros((256, 4), jnp.float32),
            jnp.zeros((8, 5), jnp.float32),
            jnp.ones((8,), jnp.float32),
        )
