"""AOT compiler: lower the Layer-2 programs to HLO *text* artifacts.

Run once by ``make artifacts`` (build time only — python is never on the
request path).  For every shape bucket it writes

    artifacts/embed_n{n}_d{d}.hlo.txt       spectral_embedding
    artifacts/kstep_n{n}_k{K}_d{d}.hlo.txt  kmeans_step
    artifacts/manifest.json                 parameter/output schemas

The Rust runtime (rust/src/runtime/) reads the manifest, pads its inputs to
the nearest bucket, compiles the text with ``HloModuleProto::from_text_file``
on a PJRT CPU client, and caches the executable.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
Lowered with ``return_tuple=True``; the Rust side unwraps the tuple.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import EMBED_ITERS, EMBED_K, kmeans_step, spectral_embedding

# Shape buckets. n must be a multiple of the Pallas tiles (128 / 256).
EMBED_NS = (256, 512, 1024, 2048)
EMBED_DS = (4, 8, 16, 32, 64)
KSTEP_NS = (256, 512, 1024, 2048)
KSTEP_K = EMBED_K  # k-means over the embedding: centroid count bucket
KSTEP_D = EMBED_K  # embedding width


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_embed(n: int, d: int) -> str:
    spec_x = jax.ShapeDtypeStruct((n, d), jnp.float32)
    spec_w = jax.ShapeDtypeStruct((n,), jnp.float32)
    spec_s = jax.ShapeDtypeStruct((), jnp.float32)

    def fn(cw, w, sigma):
        return spectral_embedding(cw, w, sigma, k_eig=EMBED_K, iters=EMBED_ITERS)

    return to_hlo_text(jax.jit(fn).lower(spec_x, spec_w, spec_s))


def lower_kstep(n: int, k: int, d: int) -> str:
    spec_p = jax.ShapeDtypeStruct((n, d), jnp.float32)
    spec_c = jax.ShapeDtypeStruct((k, d), jnp.float32)
    spec_pm = jax.ShapeDtypeStruct((n,), jnp.float32)
    spec_cm = jax.ShapeDtypeStruct((k,), jnp.float32)
    return to_hlo_text(jax.jit(kmeans_step).lower(spec_p, spec_c, spec_pm, spec_cm))


def check_no_custom_calls(text: str, name: str) -> None:
    """The PJRT CPU client cannot execute Mosaic/LAPACK custom-calls."""
    if "custom-call" in text:
        raise RuntimeError(
            f"{name}: lowered HLO contains a custom-call — it would not run "
            "on the PJRT CPU client. Check interpret=True on all pallas_call "
            "sites and avoid jnp.linalg.* factorizations."
        )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--quick",
        action="store_true",
        help="emit only the smallest bucket of each program (CI smoke)",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    embed_ns = EMBED_NS[:1] if args.quick else EMBED_NS
    embed_ds = EMBED_DS[1:2] if args.quick else EMBED_DS
    kstep_ns = KSTEP_NS[:1] if args.quick else KSTEP_NS

    manifest = {
        "format": "hlo-text/return-tuple",
        "embed_k": EMBED_K,
        "embed_iters": EMBED_ITERS,
        "programs": [],
    }

    for n in embed_ns:
        for d in embed_ds:
            name = f"embed_n{n}_d{d}"
            text = lower_embed(n, d)
            check_no_custom_calls(text, name)
            path = os.path.join(args.out_dir, f"{name}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            manifest["programs"].append(
                {
                    "name": name,
                    "kind": "embed",
                    "file": f"{name}.hlo.txt",
                    "n": n,
                    "d": d,
                    "params": [
                        {"name": "cw", "shape": [n, d], "dtype": "f32"},
                        {"name": "w", "shape": [n], "dtype": "f32"},
                        {"name": "sigma", "shape": [], "dtype": "f32"},
                    ],
                    "outputs": [
                        {"name": "evecs", "shape": [n, EMBED_K], "dtype": "f32"},
                        {"name": "evals", "shape": [EMBED_K], "dtype": "f32"},
                        {"name": "deg", "shape": [n], "dtype": "f32"},
                    ],
                }
            )
            print(f"wrote {path} ({len(text)} chars)", file=sys.stderr)

    for n in kstep_ns:
        name = f"kstep_n{n}_k{KSTEP_K}_d{KSTEP_D}"
        text = lower_kstep(n, KSTEP_K, KSTEP_D)
        check_no_custom_calls(text, name)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["programs"].append(
            {
                "name": name,
                "kind": "kstep",
                "file": f"{name}.hlo.txt",
                "n": n,
                "k": KSTEP_K,
                "d": KSTEP_D,
                "params": [
                    {"name": "p", "shape": [n, KSTEP_D], "dtype": "f32"},
                    {"name": "c", "shape": [KSTEP_K, KSTEP_D], "dtype": "f32"},
                    {"name": "pmask", "shape": [n], "dtype": "f32"},
                    {"name": "cmask", "shape": [KSTEP_K], "dtype": "f32"},
                ],
                "outputs": [
                    {"name": "new_c", "shape": [KSTEP_K, KSTEP_D], "dtype": "f32"},
                    {"name": "idx", "shape": [n], "dtype": "s32"},
                    {"name": "shift", "shape": [], "dtype": "f32"},
                    {"name": "inertia", "shape": [], "dtype": "f32"},
                ],
            }
        )
        print(f"wrote {path} ({len(text)} chars)", file=sys.stderr)

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath} ({len(manifest['programs'])} programs)", file=sys.stderr)


if __name__ == "__main__":
    main()
