"""Layer-2 JAX model: the central-node compute graph of the paper.

Two programs are lowered to HLO text by ``aot.py`` and executed from the
Rust coordinator (Layer 3) via PJRT:

``spectral_embedding``
    codewords (n,d) + weights (n,) + bandwidth  ->  top-K eigenvectors of the
    normalized affinity  M = D^{-1/2} A D^{-1/2}, its Ritz eigenvalues, and
    the degree vector.  A is produced by the Layer-1 Pallas affinity kernel,
    so the kernel lowers into the same HLO module.  Eigenvectors are computed
    by orthogonal (subspace) iteration with Gram–Schmidt re-orthonormalization
    inside ``lax.fori_loop`` — deliberately *not* ``jnp.linalg.eigh``, which
    lowers to a LAPACK custom-call the PJRT CPU client of xla_extension 0.5.1
    cannot execute.  Smallest eigenvectors of the normalized Laplacian
    L = I - M are the largest of M, so top-of-M is exactly what normalized
    cuts / NJW need.

``kmeans_step``
    one Lloyd iteration over masked points/centroids, with the Layer-1
    assignment kernel for the distance/argmin part and one-hot matmuls for
    the centroid update (plain HLO, no scatter).

Padding convention (shared with ref.py and the Rust runtime): rows beyond
the real problem size carry weight 0. Their affinity rows/cols are zero; the
degree of such rows is clamped to 1 before the inverse square root so the
iteration stays finite, and the Rust side drops their embedding rows.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels.affinity import affinity
from .kernels.kmeans import kmeans_assign
from .kernels import ref

__all__ = ["spectral_embedding", "kmeans_step", "EMBED_K", "EMBED_ITERS"]

# Embedding width baked into the artifacts. The paper's experiments use
# 2..5 clusters; 8 eigenvectors cover all of them with headroom.
EMBED_K = 8
# Orthogonal-iteration sweeps baked into the artifacts. Convergence is
# geometric in (lambda_{K+1}/lambda_K)^iters; 150 sweeps is conservative for
# the eigengaps of clusterable affinity graphs (validated in tests against
# numpy.linalg.eigh).
EMBED_ITERS = 150


def _init_subspace(n: int, k: int) -> jnp.ndarray:
    """Deterministic full-rank start for subspace iteration.

    Baked into the HLO as a constant. A fixed PRNG draw (key 0) is almost
    surely non-orthogonal to every eigenvector we care about; determinism
    keeps artifacts reproducible bit-for-bit.
    """
    return jax.random.normal(jax.random.PRNGKey(0), (n, k), dtype=jnp.float32)


def _gram_schmidt(v: jnp.ndarray) -> jnp.ndarray:
    """Modified Gram–Schmidt orthonormalization of the columns of ``v`` (n,k).

    k is small (EMBED_K) and static, so the python loop unrolls into a short
    chain of matvecs in the HLO. Degenerate columns are replaced by a safe
    normalization guard (norm clamped away from 0) rather than re-drawn —
    subspace iteration recovers rank on the next multiply.
    """
    n, k = v.shape
    cols = []
    for j in range(k):
        c = v[:, j]
        for q in cols:
            c = c - jnp.dot(q, c) * q
        norm = jnp.sqrt(jnp.maximum(jnp.dot(c, c), 1e-30))
        cols.append(c / norm)
    return jnp.stack(cols, axis=1)


@functools.partial(
    jax.jit, static_argnames=("k_eig", "iters", "use_pallas", "interpret")
)
def spectral_embedding(
    cw: jnp.ndarray,
    w: jnp.ndarray,
    sigma: jnp.ndarray,
    *,
    k_eig: int = EMBED_K,
    iters: int = EMBED_ITERS,
    use_pallas: bool = True,
    interpret: bool = True,
):
    """Spectral embedding of the codeword set.

    Args:
      cw:    (n, d) codewords collected from all sites (padded).
      w:     (n,)   weights; group sizes or 1.0, 0.0 for padding rows.
      sigma: scalar Gaussian bandwidth.

    Returns:
      evecs: (n, k_eig) orthonormal Ritz vectors of M = D^-1/2 A D^-1/2,
             ordered by decreasing Ritz value (column 0 ~ trivial vector).
      evals: (k_eig,) Ritz values (eigenvalues of M; lap eigs are 1 - these).
      deg:   (n,) degrees of the affinity graph (0 for padding rows).
    """
    if use_pallas:
        a = affinity(cw, w, sigma, interpret=interpret)
    else:
        a = ref.affinity_ref(cw, w, sigma)

    deg = jnp.sum(a, axis=1)
    # Padding rows (and fully isolated codewords) get degree 1 so D^-1/2 is
    # finite; their affinity rows are zero so they do not couple back.
    safe_deg = jnp.where(deg <= 1e-12, 1.0, deg)
    dinv = jax.lax.rsqrt(safe_deg)
    m = a * dinv[:, None] * dinv[None, :]

    v0 = _gram_schmidt(_init_subspace(cw.shape[0], k_eig))

    def sweep(_, v):
        return _gram_schmidt(m @ v)

    v = jax.lax.fori_loop(0, iters, sweep, v0)

    # Ritz values + a final rotation to sort columns by decreasing value.
    mv = m @ v
    ritz = jnp.sum(v * mv, axis=0)
    order = jnp.argsort(-ritz)
    v = jnp.take(v, order, axis=1)
    ritz = jnp.take(ritz, order)
    return v, ritz, deg


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def kmeans_step(
    p: jnp.ndarray,
    c: jnp.ndarray,
    pmask: jnp.ndarray,
    cmask: jnp.ndarray,
    *,
    use_pallas: bool = True,
    interpret: bool = True,
):
    """One Lloyd iteration over masked points.

    Args:
      p:     (n, d) points (padded rows arbitrary).
      c:     (K, d) current centroids.
      pmask: (n,)  1.0 for real points, 0.0 for padding.
      cmask: (K,)  1.0 for active centroids.

    Returns:
      new_c:  (K, d) updated centroids (inactive/empty keep their old value).
      idx:    (n,)  int32 assignment of every row (padding rows assign to the
              nearest active centroid too, but carry zero weight in updates).
      shift:  scalar, squared movement of active centroids — the Rust driver
              uses it as the convergence signal.
      inertia: scalar, weighted within-cluster sum of squares of real points.
    """
    if use_pallas:
        idx, mind = kmeans_assign(p, c, cmask, interpret=interpret)
    else:
        idx, mind = ref.kmeans_assign_ref(p, c, cmask)

    k = c.shape[0]
    onehot = (idx[:, None] == jnp.arange(k, dtype=jnp.int32)[None, :]).astype(
        jnp.float32
    )
    onehot = onehot * pmask[:, None]

    counts = jnp.sum(onehot, axis=0)
    sums = onehot.T @ p
    new_c = sums / jnp.maximum(counts, 1.0)[:, None]
    # Empty or inactive clusters keep their previous centroid.
    keep_old = (counts < 0.5) | (cmask < 0.5)
    new_c = jnp.where(keep_old[:, None], c, new_c)

    shift = jnp.sum((new_c - c) ** 2 * cmask[:, None])
    inertia = jnp.sum(mind * pmask)
    return new_c, idx, shift, inertia
