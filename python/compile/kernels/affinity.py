"""Layer-1 Pallas kernel: tiled weighted Gaussian affinity matrix.

This is the O(n^2 d) hot spot of the central spectral-clustering step the
paper runs over the union of codewords collected from all distributed sites.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the pairwise squared
distance decomposes as  |x_i|^2 + |x_j|^2 - 2 x_i . x_j , so the dominant
cost of each (TILE x TILE) output block is a single (TILE,d)x(d,TILE)
matmul — exactly the MXU's job — followed by a VPU elementwise
exp/mask/scale pass over the same block.  The BlockSpec grid walks the
(row-tile, col-tile) plane; each program pulls one row-block and one
col-block of the codeword matrix from HBM into VMEM.

VMEM budget per program at TILE=128, d<=64:
  2 * 128*64*4 B (inputs) + 128*128*4 B (output) + 2*128*4 B (weights)
  ~= 131 KB  — far under the ~16 MB VMEM ceiling, leaving room for the
compiler to double-buffer the HBM->VMEM streams.

The kernel MUST be lowered with ``interpret=True`` in this environment:
the CPU PJRT plugin cannot execute Mosaic custom-calls (see
/opt/xla-example/README.md).  Numerics are validated against
``ref.affinity_ref`` by python/tests/test_kernels.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["affinity", "DEFAULT_TILE"]

DEFAULT_TILE = 128


def _affinity_kernel(x_row_ref, x_col_ref, w_row_ref, w_col_ref, sigma_ref, o_ref):
    """One (TILE x TILE) block of the affinity matrix.

    Refs:
      x_row_ref : (TILE, d) row-block of codewords
      x_col_ref : (TILE, d) col-block of codewords
      w_row_ref : (TILE,)   row weights (0.0 marks padding)
      w_col_ref : (TILE,)   col weights
      sigma_ref : (1, 1)    Gaussian bandwidth
      o_ref     : (TILE, TILE) output block
    """
    i = pl.program_id(0)
    j = pl.program_id(1)
    tile = o_ref.shape[0]

    x = x_row_ref[...]
    y = x_col_ref[...]

    # |x|^2 + |y|^2 - 2 x.y^T : one MXU matmul per block + rank-1 updates.
    sx = jnp.sum(x * x, axis=1)
    sy = jnp.sum(y * y, axis=1)
    d2 = sx[:, None] + sy[None, :] - 2.0 * jnp.dot(
        x, y.T, preferred_element_type=jnp.float32
    )
    d2 = jnp.maximum(d2, 0.0)  # cancellation guard

    sigma = sigma_ref[0, 0]
    a = jnp.exp(-d2 / (2.0 * sigma * sigma))

    # Weight / padding mask (w == 0 rows and cols vanish).
    wr = w_row_ref[...]
    wc = w_col_ref[...]
    a = a * (wr[:, None] * wc[None, :])

    # Zero the global diagonal. Row/col global indices from the grid position.
    row_ids = i * tile + jax.lax.iota(jnp.int32, tile)
    col_ids = j * tile + jax.lax.iota(jnp.int32, tile)
    on_diag = row_ids[:, None] == col_ids[None, :]
    o_ref[...] = jnp.where(on_diag, 0.0, a)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def affinity(
    x: jnp.ndarray,
    w: jnp.ndarray,
    sigma: jnp.ndarray,
    *,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
) -> jnp.ndarray:
    """Weighted Gaussian affinity A (n,n) over codewords ``x`` (n,d).

    Semantics identical to ``ref.affinity_ref``: A[i,j] = w_i w_j
    exp(-|x_i-x_j|^2 / 2 sigma^2) with zero diagonal; ``n`` must be a
    multiple of ``tile`` (the AOT shape buckets guarantee this).
    """
    n, _d = x.shape
    if n % tile != 0:
        raise ValueError(f"n={n} not a multiple of tile={tile}")
    grid = (n // tile, n // tile)
    sigma2d = jnp.asarray(sigma, jnp.float32).reshape(1, 1)

    return pl.pallas_call(
        _affinity_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, x.shape[1]), lambda i, j: (i, 0)),
            pl.BlockSpec((tile, x.shape[1]), lambda i, j: (j, 0)),
            pl.BlockSpec((tile,), lambda i, j: (i,)),
            pl.BlockSpec((tile,), lambda i, j: (j,)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, tile), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=interpret,
    )(x, x, w, w, sigma2d)
