"""Layer-1 Pallas kernel: tiled nearest-centroid assignment.

The assignment step is the hot loop of Lloyd's algorithm — the DML transform
every distributed site runs locally in the paper (§2.2.1).  Per point-tile it
is the same MXU-friendly pattern as the affinity kernel: one
(TILE,d)x(d,K) matmul gives the cross terms of the squared distances, the
VPU finishes with the rank-1 corrections and an argmin reduction over the
centroid axis.

The full centroid matrix (K <= 2048, d <= 64 -> <= 512 KB) is small enough
to pin in VMEM for every program, so the grid is 1-D over point tiles and
the centroid block index map is constant — the compiler keeps it resident
instead of re-streaming it per tile.

Validated against ``ref.kmeans_assign_ref`` (python/tests/test_kernels.py);
ties break toward the lower centroid index in both implementations (argmin
semantics).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import BIG

__all__ = ["kmeans_assign", "DEFAULT_TILE"]

DEFAULT_TILE = 256


def _assign_kernel(p_ref, c_ref, cmask_ref, idx_ref, mind_ref):
    """Assign one tile of points to their nearest active centroid.

    Refs:
      p_ref     : (TILE, d) point tile
      c_ref     : (K, d)    full centroid matrix (VMEM-resident)
      cmask_ref : (K,)      1.0 = active centroid, 0.0 = disabled
      idx_ref   : (TILE,)   out: int32 nearest-centroid index
      mind_ref  : (TILE,)   out: squared distance to it
    """
    p = p_ref[...]
    c = c_ref[...]

    sp = jnp.sum(p * p, axis=1)
    sc = jnp.sum(c * c, axis=1)
    d2 = sp[:, None] + sc[None, :] - 2.0 * jnp.dot(
        p, c.T, preferred_element_type=jnp.float32
    )
    d2 = jnp.maximum(d2, 0.0)

    # Disabled centroids are pushed out of argmin range.
    d2 = d2 + (1.0 - cmask_ref[...])[None, :] * BIG

    idx_ref[...] = jnp.argmin(d2, axis=1).astype(jnp.int32)
    mind_ref[...] = jnp.min(d2, axis=1)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def kmeans_assign(
    p: jnp.ndarray,
    c: jnp.ndarray,
    cmask: jnp.ndarray,
    *,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
):
    """Nearest-centroid assignment for points ``p`` (n,d), centroids ``c`` (K,d).

    Returns ``(idx, mind)`` as in ``ref.kmeans_assign_ref``. ``n`` must be a
    multiple of ``tile``.
    """
    n, d = p.shape
    k, dc = c.shape
    if d != dc:
        raise ValueError(f"point dim {d} != centroid dim {dc}")
    if n % tile != 0:
        raise ValueError(f"n={n} not a multiple of tile={tile}")
    grid = (n // tile,)

    return pl.pallas_call(
        _assign_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=interpret,
    )(p, c, cmask)
