"""Pallas kernels (Layer 1) and their pure-jnp oracles.

``affinity``       — tiled weighted Gaussian affinity (the central hot spot)
``kmeans_assign``  — tiled nearest-centroid assignment (the site hot loop)
``ref``            — correctness oracles for both
"""

from .affinity import affinity
from .kmeans import kmeans_assign
from . import ref

__all__ = ["affinity", "kmeans_assign", "ref"]
