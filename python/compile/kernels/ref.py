"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
must match its oracle to float32 tolerance under pytest/hypothesis sweeps
(see python/tests/test_kernels.py).  They are also what the L2 model would
use if the Pallas path were disabled, so they define the exact semantics:

- ``affinity_ref``  : masked, weighted Gaussian affinity with zero diagonal.
- ``kmeans_assign_ref`` : nearest-centroid assignment with centroid masking.

Conventions shared with the kernels and the Rust runtime:

* ``w`` is the per-row weight vector. Real rows carry the codeword group
  size (weighted mode) or 1.0 (unweighted mode); **padding rows carry 0.0**
  so that the same vector doubles as the validity mask. Pad rows/cols of the
  affinity matrix are exactly zero.
* The affinity diagonal is zero (normalized-cuts convention; also keeps the
  trivial self-similarity from dominating small codebooks).
* ``cmask`` marks active centroids with 1.0; inactive centroids are pushed
  to +inf distance so no point selects them.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["affinity_ref", "kmeans_assign_ref", "pairwise_sqdist_ref", "BIG"]

# Distance offset used to disable masked-out centroids in argmin races.
BIG = 1e30


def pairwise_sqdist_ref(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Squared Euclidean distances between rows of ``x`` (n,d) and ``y`` (m,d).

    Uses the expanded form ``|x|^2 + |y|^2 - 2 x.y`` — the same algebra the
    Pallas kernels use so that rounding behaviour is comparable — and clamps
    tiny negatives produced by cancellation back to zero.
    """
    sx = jnp.sum(x * x, axis=-1)
    sy = jnp.sum(y * y, axis=-1)
    d2 = sx[:, None] + sy[None, :] - 2.0 * (x @ y.T)
    return jnp.maximum(d2, 0.0)


def affinity_ref(x: jnp.ndarray, w: jnp.ndarray, sigma: jnp.ndarray) -> jnp.ndarray:
    """Weighted Gaussian affinity matrix over codewords.

    A[i,j] = w_i * w_j * exp(-|x_i - x_j|^2 / (2 sigma^2)),  A[i,i] = 0.

    ``sigma`` is a scalar (or shape-(1,1)) bandwidth. Rows with w == 0 are
    padding and produce all-zero rows/columns.
    """
    sigma = jnp.asarray(sigma, jnp.float32).reshape(())
    d2 = pairwise_sqdist_ref(x, x)
    a = jnp.exp(-d2 / (2.0 * sigma * sigma))
    a = a * (w[:, None] * w[None, :])
    n = x.shape[0]
    eye = jnp.eye(n, dtype=a.dtype)
    return a * (1.0 - eye)


def kmeans_assign_ref(p, c, cmask):
    """Nearest-centroid assignment.

    Returns ``(idx, mind)`` where ``idx[i]`` is the int32 index of the
    nearest *active* centroid to point ``p[i]`` and ``mind[i]`` the squared
    distance to it. Inactive centroids (cmask == 0) never win.
    """
    d2 = pairwise_sqdist_ref(p, c)
    d2 = d2 + (1.0 - cmask)[None, :] * BIG
    idx = jnp.argmin(d2, axis=1).astype(jnp.int32)
    mind = jnp.min(d2, axis=1)
    return idx, mind
