//! Minimal TOML-subset parser (offline stand-in for the `toml` crate).
//!
//! Supports what experiment configs need: `[section]` / `[a.b]` tables,
//! `key = value` with strings, integers, floats, booleans and flat arrays,
//! `#` comments and blank lines. Keys are flattened to `section.key` paths
//! in a single map — the typed config layer does its own lookups.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// A parsed scalar (or flat array) value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(v) => Some(*v),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(v) => Some(*v),
            TomlValue::Int(v) => Some(*v as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(v) => Some(*v),
            _ => None,
        }
    }
}

/// Parse a TOML-subset document into a flat `section.key → value` map.
pub fn parse(text: &str) -> Result<BTreeMap<String, TomlValue>> {
    let mut map = BTreeMap::new();
    let mut section = String::new();

    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let Some(name) = rest.strip_suffix(']') else {
                bail!("line {}: malformed section header", lineno + 1);
            };
            let name = name.trim();
            if name.is_empty() {
                bail!("line {}: empty section name", lineno + 1);
            }
            section = name.to_string();
            continue;
        }
        let Some(eq) = line.find('=') else {
            bail!("line {}: expected `key = value`", lineno + 1);
        };
        let key = line[..eq].trim();
        if key.is_empty() {
            bail!("line {}: empty key", lineno + 1);
        }
        let value = parse_value(line[eq + 1..].trim())
            .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
        let full = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
        map.insert(full, value);
    }
    Ok(map)
}

fn strip_comment(line: &str) -> &str {
    // respects '#' inside quoted strings
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(rest) = s.strip_prefix('"') {
        let Some(inner) = rest.strip_suffix('"') else {
            bail!("unterminated string");
        };
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let Some(inner) = rest.strip_suffix(']') else {
            bail!("unterminated array");
        };
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(vec![]));
        }
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            items.push(parse_value(part.trim())?);
        }
        return Ok(TomlValue::Array(items));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(v) = s.replace('_', "").parse::<i64>() {
            return Ok(TomlValue::Int(v));
        }
    }
    if let Ok(v) = s.parse::<f64>() {
        return Ok(TomlValue::Float(v));
    }
    bail!("cannot parse value {s:?}");
}

/// Split on commas that are not inside quotes (arrays are flat — no nesting).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_document() {
        let doc = r#"
            # experiment config
            seed = 42
            name = "hepmass"   # dataset

            [pipeline]
            sites = 3
            weighted = false
            tol = 1e-6
            scales = [0.5, 1.0, 2.0]
        "#;
        let m = parse(doc).unwrap();
        assert_eq!(m["seed"], TomlValue::Int(42));
        assert_eq!(m["name"].as_str(), Some("hepmass"));
        assert_eq!(m["pipeline.sites"], TomlValue::Int(3));
        assert_eq!(m["pipeline.weighted"], TomlValue::Bool(false));
        assert_eq!(m["pipeline.tol"].as_f64(), Some(1e-6));
        let arr = match &m["pipeline.scales"] {
            TomlValue::Array(a) => a,
            _ => panic!(),
        };
        assert_eq!(arr.len(), 3);
    }

    #[test]
    fn hash_inside_string_is_kept() {
        let m = parse(r##"tag = "a#b" # comment"##).unwrap();
        assert_eq!(m["tag"].as_str(), Some("a#b"));
    }

    #[test]
    fn underscored_ints() {
        let m = parse("n = 1_000_000").unwrap();
        assert_eq!(m["n"].as_i64(), Some(1_000_000));
    }

    #[test]
    fn errors() {
        assert!(parse("[unclosed").is_err());
        assert!(parse("novalue =").is_err());
        assert!(parse("= 3").is_err());
        assert!(parse("bad").is_err());
        assert!(parse("s = \"unterminated").is_err());
    }

    #[test]
    fn int_as_f64_coerces() {
        let m = parse("x = 3").unwrap();
        assert_eq!(m["x"].as_f64(), Some(3.0));
    }
}
