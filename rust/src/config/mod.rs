//! Typed experiment/pipeline configuration with a TOML file format.
//!
//! [`PipelineConfig`] carries every knob of Algorithm 1 plus the execution
//! environment (backend, link model, seeds). It can be built in code
//! (examples/benches), loaded from a TOML file (`dsc run --config`), or
//! tweaked via CLI overrides — the launcher merges all three.

pub mod toml;

use std::path::Path;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::dml::DmlKind;
use crate::net::LinkSpec;
use crate::spectral::{Algo, Bandwidth, GraphKind};

pub use crate::data::scenario::Scenario;

/// How leader and sites talk (`[net] transport`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process star over `mpsc` channels — sites are threads of the
    /// coordinator process (`dsc run`, tests, benches).
    Channel,
    /// Real sockets between separate processes (`dsc leader` / `dsc site`).
    Tcp,
}

impl TransportKind {
    pub fn parse(s: &str) -> Option<TransportKind> {
        match s.to_ascii_lowercase().as_str() {
            "channel" | "inproc" | "in-process" => Some(TransportKind::Channel),
            "tcp" => Some(TransportKind::Tcp),
            _ => None,
        }
    }
}

/// Network deployment knobs (`[net]`): which transport, where the daemons
/// listen/dial, and the TCP socket deadlines. Ignored by the channel
/// backend except as documentation of intent.
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Intended transport for this config. `dsc run` always executes
    /// in-process; `dsc leader`/`dsc site` always speak TCP — a config with
    /// `transport = "tcp"` handed to `dsc run` is a loud error rather than
    /// a silent simulation.
    pub transport: TransportKind,
    /// Site daemon listen address (`dsc site --listen` overrides).
    pub listen: String,
    /// Site addresses the leader dials, in site-id order (`dsc leader
    /// --sites` overrides).
    pub sites: Vec<String>,
    /// TCP dial + handshake deadline.
    pub connect_timeout: Duration,
    /// TCP mid-frame read/write stall deadline; zero disables.
    pub io_timeout: Duration,
    /// Site-side dead-leader deadline on accepted connections: a link with
    /// no frame at all for this long is dropped and the daemon re-listens
    /// (a leader that died *silently* — power loss, partition — never
    /// closes the socket, and idle is otherwise legal forever). Zero
    /// disables. Size it above the longest legitimate central phase.
    pub max_idle: Duration,
}

impl Default for NetConfig {
    fn default() -> Self {
        let t = crate::net::tcp::TcpTimeouts::default();
        NetConfig {
            transport: TransportKind::Channel,
            listen: "127.0.0.1:7010".to_string(),
            sites: Vec::new(),
            connect_timeout: t.connect,
            io_timeout: t.io,
            max_idle: t.max_idle,
        }
    }
}

impl NetConfig {
    /// The socket deadlines in the shape the TCP backend wants.
    pub fn tcp_timeouts(&self) -> crate::net::tcp::TcpTimeouts {
        crate::net::tcp::TcpTimeouts {
            connect: self.connect_timeout,
            io: self.io_timeout,
            max_idle: self.max_idle,
        }
    }
}

/// Job-serving knobs (`[leader]`): how `dsc leader --serve` queues and
/// pipelines client-submitted runs. Irrelevant to the one-shot modes.
#[derive(Clone, Debug)]
pub struct LeaderConfig {
    /// Runs in flight at once; further accepted jobs wait in the queue.
    pub max_jobs: usize,
    /// Pending-job cap; submissions beyond it are rejected with a reason.
    pub queue_depth: usize,
    /// Allow clients to pull populated per-point labels through the leader
    /// (`LABELSPULL`). Off by default — the paper's privacy posture keeps
    /// per-point labels at the sites.
    pub allow_label_pull: bool,
    /// Central-step worker threads for the job server: the reactor hands a
    /// run's central spectral step to this pool and keeps dispatching
    /// frames for every other run while it computes (`CentralDone` comes
    /// back through the mailbox). `0` runs centrals inline on the reactor
    /// thread — the pre-offload behavior, which blocks every other run for
    /// the duration. XLA backends always run inline (the PJRT runtime is
    /// thread-local). Default: `min(2, cores)`.
    pub central_workers: usize,
    /// Serve the job queue with per-client weighted fair queueing (deficit
    /// round-robin keyed by client id, job priorities as weights) instead
    /// of the legacy global FIFO. Off by default: with `false` the server
    /// is byte-identical to the pre-fair-queue dialect.
    pub fair_queue: bool,
    /// Token-bucket admission: sustained submits/second allowed *per
    /// client*; a client above it gets `rate limited` rejects until its
    /// bucket refills. `0.0` (the default) disables admission control.
    pub admit_rate: f64,
    /// Token-bucket burst: submits a client may fire back-to-back above
    /// `admit_rate` before throttling kicks in (≥ 1).
    pub admit_burst: usize,
    /// Event-journal path for crash recovery (`dsc leader --journal`
    /// overrides). When set, every state-changing reactor event is
    /// appended to this file before it is applied, and a restarted leader
    /// replays it to rebuild the queue and every incomplete run. `None`
    /// (the default) disables journaling — the pre-journal server,
    /// byte for byte.
    pub journal_path: Option<std::path::PathBuf>,
    /// `fsync` the journal at every group commit (once per mailbox
    /// drain). Off by default: the OS page cache still survives a process
    /// crash; only power loss can drop acknowledged events (see
    /// docs/DEPLOY.md for the exact durability window).
    pub journal_fsync: bool,
    /// The serving primary's job-socket address a `dsc leader --standby`
    /// process dials for journal replication (`dsc leader --primary`
    /// overrides). Standby mode requires it — and a journal path to
    /// replicate into. `None` (the default) on a serving primary, which
    /// *accepts* standbys on its job socket whenever journaling is on.
    pub standby_of: Option<String>,
    /// Standby promotion deadline: how long the replication link may go
    /// with no frame at all (records or heartbeats) before the standby
    /// presumes the primary dead and promotes itself. The primary
    /// heartbeats the link at a quarter of this, so a healthy-but-idle
    /// primary never trips it. Also used as the re-dial cap while the
    /// standby has never reached the primary.
    pub standby_timeout: Duration,
}

/// `min(2, cores)` — enough to overlap one long central with another run's
/// central without oversubscribing the machine the `par` pool also uses.
pub fn default_central_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(2)
}

impl Default for LeaderConfig {
    fn default() -> Self {
        LeaderConfig {
            max_jobs: 4,
            queue_depth: 32,
            allow_label_pull: false,
            central_workers: default_central_workers(),
            fair_queue: false,
            admit_rate: 0.0,
            admit_burst: 4,
            journal_path: None,
            journal_fsync: false,
            standby_of: None,
            standby_timeout: Duration::from_secs(10),
        }
    }
}

/// Where the central spectral step executes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Pure-Rust Lanczos/ncut path.
    Native,
    /// AOT XLA artifact for the embedding (PJRT), native K-means finish.
    Xla,
    /// XLA artifacts for both the embedding and the Lloyd steps.
    XlaFull,
}

impl Backend {
    pub fn parse(s: &str) -> Option<Backend> {
        match s.to_ascii_lowercase().as_str() {
            "native" => Some(Backend::Native),
            "xla" => Some(Backend::Xla),
            "xla-full" | "xlafull" => Some(Backend::XlaFull),
            _ => None,
        }
    }
}

/// Full pipeline configuration (Algorithm 1 + environment).
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// DML transform run at every site.
    pub dml: DmlKind,
    /// Total codeword budget across all sites, split proportionally to
    /// site sizes (the paper fixes the data-compression ratio; budget =
    /// N / ratio).
    pub total_codes: usize,
    /// Lloyd sweep cap for K-means DML.
    pub kmeans_max_iters: usize,
    /// Relative centroid-shift tolerance for K-means DML.
    pub kmeans_tol: f64,
    /// Number of output clusters.
    pub k_clusters: usize,
    /// Affinity bandwidth policy for the central step.
    pub bandwidth: Bandwidth,
    /// Central spectral algorithm.
    pub algo: Algo,
    /// Affinity-graph storage for the central step: the paper's dense
    /// `m × m` matrix, or the sparse k-NN graph that unlocks large
    /// codebooks (8k+ codewords). Native backend only.
    pub graph: GraphKind,
    /// Weight the affinity by codeword group sizes (ablation A2).
    pub weighted_affinity: bool,
    /// Execution backend for the central step.
    pub backend: Backend,
    /// Site↔leader link model.
    pub link: LinkSpec,
    /// Master seed; per-site seeds fork from it.
    pub seed: u64,
    /// Artifact directory for XLA backends.
    pub artifact_dir: std::path::PathBuf,
    /// Network deployment: transport kind, daemon addresses, TCP deadlines.
    pub net: NetConfig,
    /// Job-serving knobs for `dsc leader --serve`.
    pub leader: LeaderConfig,
    /// Site-session limits (`[site]`): label cache depth and the
    /// hostile-leader open-run backstop for `dsc site` multi-run sessions.
    pub site: crate::site::SessionLimits,
    /// How long the leader waits out each collect phase (site registration,
    /// then codebooks) before declaring the missing sites failed
    /// (straggler/crash protection).
    pub collect_timeout: Duration,
    /// Chaos hook: make this site crash before reporting (tests/drills).
    pub inject_site_failure: Option<usize>,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            dml: DmlKind::KMeans,
            total_codes: 1000,
            kmeans_max_iters: 30,
            kmeans_tol: 1e-6,
            k_clusters: 2,
            bandwidth: Bandwidth::default(),
            algo: Algo::RecursiveNcut,
            graph: GraphKind::Dense,
            weighted_affinity: false,
            backend: Backend::Native,
            link: LinkSpec::default(),
            net: NetConfig::default(),
            leader: LeaderConfig::default(),
            site: crate::site::SessionLimits::default(),
            seed: 0,
            artifact_dir: crate::runtime::default_artifact_dir(),
            collect_timeout: Duration::from_secs(300),
            inject_site_failure: None,
        }
    }
}

impl PipelineConfig {
    /// Load from a TOML file; missing keys keep their defaults.
    pub fn from_file(path: &Path) -> Result<PipelineConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("read {}: {e}", path.display()))?;
        Self::from_toml(&text)
    }

    /// Parse from TOML text. Recognized keys (all optional):
    ///
    /// ```toml
    /// [pipeline]
    /// dml = "kmeans"            # or "rptrees"
    /// total_codes = 1000
    /// kmeans_max_iters = 30
    /// kmeans_tol = 1e-6
    /// k_clusters = 4
    /// algo = "ncut"             # or "njw"
    /// weighted_affinity = false
    /// backend = "native"        # or "xla", "xla-full"
    /// seed = 42
    /// artifact_dir = "artifacts"
    ///
    /// [spectral]
    /// graph = "dense"           # or "knn" (sparse k-NN affinity, large codebooks)
    /// knn_k = 32                # neighbors per codeword (graph = "knn" only)
    ///
    /// [bandwidth]
    /// policy = "median"         # "fixed" | "median" | "eigengap"
    /// value = 1.0               # σ for fixed, scale for median, k for eigengap
    ///
    /// [link]
    /// bandwidth_mbps = 100.0
    /// latency_ms = 20.0
    ///
    /// [net]
    /// transport = "channel"     # or "tcp" (leader/site daemon deployment)
    /// listen = "127.0.0.1:7010" # site daemon bind address
    /// sites = ["10.0.0.2:7010", "10.0.0.3:7010"]   # leader dial list,
    ///                           # site-id order (or one comma-separated string)
    /// connect_timeout_s = 10.0  # dial + handshake deadline
    /// io_timeout_s = 30.0       # mid-frame stall deadline; 0 disables
    /// max_idle_secs = 0         # site-side dead-leader deadline; 0 disables
    ///
    /// [leader]
    /// max_jobs = 4              # concurrent runs (dsc leader --serve)
    /// queue_depth = 32          # pending-job cap
    /// allow_label_pull = false  # let clients pull labels through the leader
    /// central_workers = 2       # central-step worker pool (0 = inline;
    ///                           # default min(2, cores))
    /// fair_queue = false        # per-client weighted fair queueing (DRR);
    ///                           # false = legacy global FIFO
    /// admit_rate = 0.0          # per-client submits/sec admitted (0 = off)
    /// admit_burst = 4           # token-bucket burst above admit_rate
    /// journal_path = "leader.journal"  # crash-recovery event log (unset = off)
    /// journal_fsync = false     # fsync each group commit (power-loss durability)
    /// standby_of = "10.0.0.1:7100"  # primary address a --standby replicates from
    /// standby_timeout_s = 10.0  # silent replication link ⇒ standby promotes
    ///
    /// [site]
    /// label_cache_runs = 8      # completed runs kept for LABELSPULL
    /// max_open_runs = 64        # hostile-leader open-run backstop
    /// cache_dml = true          # replay cached DML results while the shard
    ///                           # version is unchanged
    /// dml_cache_runs = 8        # cached DML results kept (oldest evicted)
    /// digest_chunk = 1024       # points per shard-digest leaf chunk
    /// report_digest = false     # volunteer SITEINFO2 at session start
    ///                           # (needs a leader that knows the tag)
    /// ```
    pub fn from_toml(text: &str) -> Result<PipelineConfig> {
        let map = toml::parse(text)?;
        let mut cfg = PipelineConfig::default();

        let get = |k: &str| map.get(k);
        if let Some(v) = get("pipeline.dml") {
            let s = v.as_str().ok_or_else(|| anyhow!("pipeline.dml must be a string"))?;
            cfg.dml = DmlKind::parse(s).ok_or_else(|| anyhow!("unknown dml {s:?}"))?;
        }
        if let Some(v) = get("pipeline.total_codes") {
            cfg.total_codes =
                v.as_i64().ok_or_else(|| anyhow!("total_codes must be int"))? as usize;
        }
        if let Some(v) = get("pipeline.kmeans_max_iters") {
            cfg.kmeans_max_iters =
                v.as_i64().ok_or_else(|| anyhow!("kmeans_max_iters must be int"))? as usize;
        }
        if let Some(v) = get("pipeline.kmeans_tol") {
            cfg.kmeans_tol = v.as_f64().ok_or_else(|| anyhow!("kmeans_tol must be float"))?;
        }
        if let Some(v) = get("pipeline.k_clusters") {
            cfg.k_clusters =
                v.as_i64().ok_or_else(|| anyhow!("k_clusters must be int"))? as usize;
        }
        if let Some(v) = get("pipeline.algo") {
            let s = v.as_str().ok_or_else(|| anyhow!("pipeline.algo must be a string"))?;
            cfg.algo = Algo::parse(s).ok_or_else(|| anyhow!("unknown algo {s:?}"))?;
        }
        if let Some(v) = get("pipeline.weighted_affinity") {
            cfg.weighted_affinity =
                v.as_bool().ok_or_else(|| anyhow!("weighted_affinity must be bool"))?;
        }
        if let Some(v) = get("pipeline.backend") {
            let s = v.as_str().ok_or_else(|| anyhow!("pipeline.backend must be a string"))?;
            cfg.backend = Backend::parse(s).ok_or_else(|| anyhow!("unknown backend {s:?}"))?;
        }
        if let Some(v) = get("pipeline.seed") {
            cfg.seed = v.as_i64().ok_or_else(|| anyhow!("seed must be int"))? as u64;
        }
        if let Some(v) = get("pipeline.artifact_dir") {
            cfg.artifact_dir =
                v.as_str().ok_or_else(|| anyhow!("artifact_dir must be a string"))?.into();
        }

        let knn_k = match get("spectral.knn_k") {
            None => None,
            Some(v) => {
                let k = v.as_i64().ok_or_else(|| anyhow!("spectral.knn_k must be an int"))?;
                if k < 1 {
                    bail!("spectral.knn_k must be ≥ 1");
                }
                Some(k as usize)
            }
        };
        match get("spectral.graph") {
            None => {
                if knn_k.is_some() {
                    bail!("spectral.knn_k requires spectral.graph = \"knn\"");
                }
            }
            Some(v) => {
                let s =
                    v.as_str().ok_or_else(|| anyhow!("spectral.graph must be a string"))?;
                // same vocabulary (and aliases) as the CLI --graph flag
                cfg.graph = match GraphKind::parse(s) {
                    None => {
                        bail!("unknown spectral.graph {s:?} (expected \"dense\" or \"knn\")")
                    }
                    Some(GraphKind::Dense) => {
                        if knn_k.is_some() {
                            bail!("spectral.knn_k requires spectral.graph = \"knn\"");
                        }
                        GraphKind::Dense
                    }
                    Some(GraphKind::Knn { .. }) => {
                        GraphKind::Knn { k: knn_k.unwrap_or(GraphKind::DEFAULT_KNN_K) }
                    }
                };
            }
        }

        match get("bandwidth.policy").and_then(|v| v.as_str()) {
            None => {}
            Some("fixed") => {
                let s = get("bandwidth.value")
                    .and_then(|v| v.as_f64())
                    .ok_or_else(|| anyhow!("fixed bandwidth needs bandwidth.value"))?;
                cfg.bandwidth = Bandwidth::Fixed(s);
            }
            Some("median") => {
                let s = get("bandwidth.value").and_then(|v| v.as_f64()).unwrap_or(1.0);
                cfg.bandwidth = Bandwidth::MedianScale(s);
            }
            Some("eigengap") => {
                let k = get("bandwidth.value").and_then(|v| v.as_f64()).unwrap_or(2.0) as usize;
                cfg.bandwidth = Bandwidth::EigengapSearch { k };
            }
            Some(other) => bail!("unknown bandwidth policy {other:?}"),
        }

        if let Some(v) = get("pipeline.collect_timeout_s") {
            let secs = v.as_f64().ok_or_else(|| anyhow!("collect_timeout_s must be a number"))?;
            cfg.collect_timeout = Duration::from_secs_f64(secs);
        }
        if let Some(v) = get("link.bandwidth_mbps") {
            let mbps = v.as_f64().ok_or_else(|| anyhow!("bandwidth_mbps must be float"))?;
            cfg.link.bandwidth_bps = mbps * 1e6 / 8.0;
        }
        if let Some(v) = get("link.latency_ms") {
            let ms = v.as_f64().ok_or_else(|| anyhow!("latency_ms must be float"))?;
            cfg.link.latency = Duration::from_secs_f64(ms / 1000.0);
        }

        if let Some(v) = get("net.transport") {
            let s = v.as_str().ok_or_else(|| anyhow!("net.transport must be a string"))?;
            cfg.net.transport = TransportKind::parse(s)
                .ok_or_else(|| anyhow!("unknown net.transport {s:?} (channel | tcp)"))?;
        }
        if let Some(v) = get("net.listen") {
            cfg.net.listen =
                v.as_str().ok_or_else(|| anyhow!("net.listen must be a string"))?.to_string();
        }
        if let Some(v) = get("net.sites") {
            cfg.net.sites = match v {
                // canonical form: an array of "host:port" strings
                toml::TomlValue::Array(items) => items
                    .iter()
                    .map(|it| {
                        it.as_str().map(str::to_string).ok_or_else(|| {
                            anyhow!("net.sites entries must be strings")
                        })
                    })
                    .collect::<Result<Vec<_>>>()?,
                // convenience form: one comma-separated string
                toml::TomlValue::Str(s) => s
                    .split(',')
                    .map(|a| a.trim().to_string())
                    .filter(|a| !a.is_empty())
                    .collect(),
                _ => bail!("net.sites must be an array of strings"),
            };
            if cfg.net.sites.is_empty() {
                bail!("net.sites must name at least one site address");
            }
        }
        if let Some(v) = get("net.connect_timeout_s") {
            let secs =
                v.as_f64().ok_or_else(|| anyhow!("net.connect_timeout_s must be a number"))?;
            if !(secs >= 0.0) {
                bail!("net.connect_timeout_s must be ≥ 0");
            }
            cfg.net.connect_timeout = Duration::from_secs_f64(secs);
        }
        if let Some(v) = get("net.io_timeout_s") {
            let secs = v.as_f64().ok_or_else(|| anyhow!("net.io_timeout_s must be a number"))?;
            if !(secs >= 0.0) {
                bail!("net.io_timeout_s must be ≥ 0");
            }
            cfg.net.io_timeout = Duration::from_secs_f64(secs);
        }
        if let Some(v) = get("net.max_idle_secs") {
            let secs = v.as_f64().ok_or_else(|| anyhow!("net.max_idle_secs must be a number"))?;
            if !(secs >= 0.0) {
                bail!("net.max_idle_secs must be ≥ 0");
            }
            cfg.net.max_idle = Duration::from_secs_f64(secs);
        }

        if let Some(v) = get("leader.max_jobs") {
            let n = v.as_i64().ok_or_else(|| anyhow!("leader.max_jobs must be an int"))?;
            if n < 1 {
                bail!("leader.max_jobs must be ≥ 1");
            }
            cfg.leader.max_jobs = n as usize;
        }
        if let Some(v) = get("leader.queue_depth") {
            let n = v.as_i64().ok_or_else(|| anyhow!("leader.queue_depth must be an int"))?;
            if n < 1 {
                bail!("leader.queue_depth must be ≥ 1");
            }
            cfg.leader.queue_depth = n as usize;
        }
        if let Some(v) = get("leader.allow_label_pull") {
            cfg.leader.allow_label_pull =
                v.as_bool().ok_or_else(|| anyhow!("leader.allow_label_pull must be bool"))?;
        }
        if let Some(v) = get("leader.central_workers") {
            let n =
                v.as_i64().ok_or_else(|| anyhow!("leader.central_workers must be an int"))?;
            if n < 0 {
                bail!("leader.central_workers must be ≥ 0 (0 = run centrals inline)");
            }
            cfg.leader.central_workers = n as usize;
        }
        if let Some(v) = get("leader.fair_queue") {
            cfg.leader.fair_queue =
                v.as_bool().ok_or_else(|| anyhow!("leader.fair_queue must be bool"))?;
        }
        if let Some(v) = get("leader.admit_rate") {
            let rate =
                v.as_f64().ok_or_else(|| anyhow!("leader.admit_rate must be a number"))?;
            if !rate.is_finite() || rate < 0.0 {
                bail!("leader.admit_rate must be finite and ≥ 0 (0 disables admission)");
            }
            cfg.leader.admit_rate = rate;
        }
        if let Some(v) = get("leader.admit_burst") {
            let n = v.as_i64().ok_or_else(|| anyhow!("leader.admit_burst must be an int"))?;
            if n < 1 {
                bail!("leader.admit_burst must be ≥ 1");
            }
            cfg.leader.admit_burst = n as usize;
        }
        if let Some(v) = get("leader.journal_path") {
            let s =
                v.as_str().ok_or_else(|| anyhow!("leader.journal_path must be a string"))?;
            if s.is_empty() {
                bail!("leader.journal_path must not be empty (omit the key to disable)");
            }
            cfg.leader.journal_path = Some(s.into());
        }
        if let Some(v) = get("leader.journal_fsync") {
            cfg.leader.journal_fsync =
                v.as_bool().ok_or_else(|| anyhow!("leader.journal_fsync must be bool"))?;
        }
        if let Some(v) = get("leader.standby_of") {
            let s = v.as_str().ok_or_else(|| anyhow!("leader.standby_of must be a string"))?;
            if s.is_empty() {
                bail!("leader.standby_of must not be empty (omit the key on a primary)");
            }
            cfg.leader.standby_of = Some(s.to_string());
        }
        if let Some(v) = get("leader.standby_timeout_s") {
            let secs =
                v.as_f64().ok_or_else(|| anyhow!("leader.standby_timeout_s must be a number"))?;
            if !(secs > 0.0) || !secs.is_finite() {
                bail!("leader.standby_timeout_s must be finite and > 0");
            }
            cfg.leader.standby_timeout = Duration::from_secs_f64(secs);
        }

        if let Some(v) = get("site.label_cache_runs") {
            let n =
                v.as_i64().ok_or_else(|| anyhow!("site.label_cache_runs must be an int"))?;
            if n < 1 {
                bail!("site.label_cache_runs must be ≥ 1 (a pull needs at least one cached run)");
            }
            cfg.site.label_cache_runs = n as usize;
        }
        if let Some(v) = get("site.max_open_runs") {
            let n = v.as_i64().ok_or_else(|| anyhow!("site.max_open_runs must be an int"))?;
            if n < 1 {
                bail!("site.max_open_runs must be ≥ 1 (a session must admit at least one run)");
            }
            cfg.site.max_open_runs = n as usize;
        }
        if let Some(v) = get("site.cache_dml") {
            cfg.site.cache_dml =
                v.as_bool().ok_or_else(|| anyhow!("site.cache_dml must be bool"))?;
        }
        if let Some(v) = get("site.dml_cache_runs") {
            let n = v.as_i64().ok_or_else(|| anyhow!("site.dml_cache_runs must be an int"))?;
            if n < 1 {
                bail!("site.dml_cache_runs must be ≥ 1 (a cache needs at least one slot)");
            }
            cfg.site.dml_cache_runs = n as usize;
        }
        if let Some(v) = get("site.digest_chunk") {
            let n = v.as_i64().ok_or_else(|| anyhow!("site.digest_chunk must be an int"))?;
            if n < 1 {
                bail!("site.digest_chunk must be ≥ 1 (points per digest leaf)");
            }
            cfg.site.digest_chunk = n as usize;
        }
        if let Some(v) = get("site.report_digest") {
            cfg.site.report_digest =
                v.as_bool().ok_or_else(|| anyhow!("site.report_digest must be bool"))?;
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_without_keys() {
        let cfg = PipelineConfig::from_toml("").unwrap();
        assert_eq!(cfg.k_clusters, 2);
        assert_eq!(cfg.backend, Backend::Native);
        assert_eq!(cfg.dml, DmlKind::KMeans);
        assert_eq!(cfg.graph, GraphKind::Dense);
    }

    #[test]
    fn spectral_graph_keys() {
        let cfg = PipelineConfig::from_toml("[spectral]\ngraph = \"knn\"\nknn_k = 48").unwrap();
        assert_eq!(cfg.graph, GraphKind::Knn { k: 48 });
        // knn without an explicit k falls back to the default
        let cfg = PipelineConfig::from_toml("[spectral]\ngraph = \"knn\"").unwrap();
        assert_eq!(cfg.graph, GraphKind::Knn { k: GraphKind::DEFAULT_KNN_K });
        // the CLI aliases work in TOML too
        let cfg = PipelineConfig::from_toml("[spectral]\ngraph = \"sparse\"\nknn_k = 9").unwrap();
        assert_eq!(cfg.graph, GraphKind::Knn { k: 9 });
        let cfg = PipelineConfig::from_toml("[spectral]\ngraph = \"dense\"").unwrap();
        assert_eq!(cfg.graph, GraphKind::Dense);
    }

    #[test]
    fn spectral_graph_rejects_bad_combinations() {
        // knn_k without the knn graph is a loud error, not silently inert
        assert!(PipelineConfig::from_toml("[spectral]\nknn_k = 16").is_err());
        assert!(
            PipelineConfig::from_toml("[spectral]\ngraph = \"dense\"\nknn_k = 16").is_err()
        );
        assert!(PipelineConfig::from_toml("[spectral]\ngraph = \"adjacency\"").is_err());
        assert!(PipelineConfig::from_toml("[spectral]\ngraph = \"knn\"\nknn_k = 0").is_err());
    }

    #[test]
    fn full_roundtrip() {
        let cfg = PipelineConfig::from_toml(
            r#"
            [pipeline]
            dml = "rptrees"
            total_codes = 500
            k_clusters = 4
            algo = "njw"
            weighted_affinity = true
            backend = "xla"
            seed = 9

            [bandwidth]
            policy = "fixed"
            value = 2.5

            [link]
            bandwidth_mbps = 1000.0
            latency_ms = 1.0
            "#,
        )
        .unwrap();
        assert_eq!(cfg.dml, DmlKind::RpTree);
        assert_eq!(cfg.total_codes, 500);
        assert_eq!(cfg.k_clusters, 4);
        assert_eq!(cfg.algo, Algo::Njw);
        assert!(cfg.weighted_affinity);
        assert_eq!(cfg.backend, Backend::Xla);
        assert_eq!(cfg.seed, 9);
        match cfg.bandwidth {
            Bandwidth::Fixed(s) => assert_eq!(s, 2.5),
            other => panic!("{other:?}"),
        }
        assert!((cfg.link.bandwidth_bps - 1.25e8).abs() < 1.0);
        assert_eq!(cfg.link.latency, Duration::from_millis(1));
    }

    #[test]
    fn net_table_roundtrip() {
        let cfg = PipelineConfig::from_toml(
            r#"
            [net]
            transport = "tcp"
            listen = "0.0.0.0:9001"
            sites = ["10.0.0.2:7010", "10.0.0.3:7010"]
            connect_timeout_s = 2.5
            io_timeout_s = 0
            "#,
        )
        .unwrap();
        assert_eq!(cfg.net.transport, TransportKind::Tcp);
        assert_eq!(cfg.net.listen, "0.0.0.0:9001");
        assert_eq!(cfg.net.sites, vec!["10.0.0.2:7010", "10.0.0.3:7010"]);
        assert_eq!(cfg.net.connect_timeout, Duration::from_millis(2500));
        assert_eq!(cfg.net.io_timeout, Duration::ZERO); // 0 = disabled
        let t = cfg.net.tcp_timeouts();
        assert_eq!(t.connect, Duration::from_millis(2500));
        assert_eq!(t.io, Duration::ZERO);
    }

    #[test]
    fn net_sites_accepts_comma_separated_string() {
        let cfg = PipelineConfig::from_toml(
            "[net]\nsites = \"127.0.0.1:7010, 127.0.0.1:7011\"",
        )
        .unwrap();
        assert_eq!(cfg.net.sites, vec!["127.0.0.1:7010", "127.0.0.1:7011"]);
    }

    #[test]
    fn net_defaults_are_channel_and_empty() {
        let cfg = PipelineConfig::from_toml("").unwrap();
        assert_eq!(cfg.net.transport, TransportKind::Channel);
        assert!(cfg.net.sites.is_empty());
        assert!(!cfg.net.connect_timeout.is_zero());
        assert!(!cfg.net.io_timeout.is_zero());
    }

    #[test]
    fn net_table_rejects_bad_values() {
        assert!(PipelineConfig::from_toml("[net]\ntransport = \"carrier-pigeon\"").is_err());
        assert!(PipelineConfig::from_toml("[net]\nsites = [1, 2]").is_err());
        assert!(PipelineConfig::from_toml("[net]\nsites = []").is_err());
        assert!(PipelineConfig::from_toml("[net]\nsites = \"  ,  \"").is_err());
        assert!(PipelineConfig::from_toml("[net]\nio_timeout_s = -1").is_err());
        assert!(PipelineConfig::from_toml("[net]\nconnect_timeout_s = \"fast\"").is_err());
        assert!(PipelineConfig::from_toml("[net]\nmax_idle_secs = -5").is_err());
        assert!(PipelineConfig::from_toml("[net]\nmax_idle_secs = \"long\"").is_err());
    }

    #[test]
    fn max_idle_key_reaches_the_tcp_timeouts() {
        // disabled by default: idle links are legal forever
        let cfg = PipelineConfig::from_toml("").unwrap();
        assert_eq!(cfg.net.max_idle, Duration::ZERO);
        assert_eq!(cfg.net.tcp_timeouts().max_idle, Duration::ZERO);

        let cfg = PipelineConfig::from_toml("[net]\nmax_idle_secs = 90").unwrap();
        assert_eq!(cfg.net.max_idle, Duration::from_secs(90));
        assert_eq!(cfg.net.tcp_timeouts().max_idle, Duration::from_secs(90));
    }

    #[test]
    fn leader_table_roundtrip_and_defaults() {
        let cfg = PipelineConfig::from_toml("").unwrap();
        assert_eq!(cfg.leader.max_jobs, 4);
        assert_eq!(cfg.leader.queue_depth, 32);
        assert!(!cfg.leader.allow_label_pull);
        assert_eq!(cfg.leader.central_workers, default_central_workers());
        assert!(default_central_workers() >= 1 && default_central_workers() <= 2);
        // scheduling/admission defaults: legacy FIFO, admission off
        assert!(!cfg.leader.fair_queue);
        assert_eq!(cfg.leader.admit_rate, 0.0);
        assert_eq!(cfg.leader.admit_burst, 4);
        // journaling off by default: the pre-journal server, byte for byte
        assert_eq!(cfg.leader.journal_path, None);
        assert!(!cfg.leader.journal_fsync);
        // failover off by default: no primary to replicate from, 10 s
        // promotion deadline once one is configured
        assert_eq!(cfg.leader.standby_of, None);
        assert_eq!(cfg.leader.standby_timeout, Duration::from_secs(10));

        let cfg = PipelineConfig::from_toml(
            "[leader]\nmax_jobs = 2\nqueue_depth = 8\nallow_label_pull = true\n\
             central_workers = 3\nfair_queue = true\nadmit_rate = 2.5\nadmit_burst = 7\n\
             journal_path = \"leader.journal\"\njournal_fsync = true\n\
             standby_of = \"10.0.0.1:7100\"\nstandby_timeout_s = 2.5",
        )
        .unwrap();
        assert_eq!(cfg.leader.standby_of.as_deref(), Some("10.0.0.1:7100"));
        assert_eq!(cfg.leader.standby_timeout, Duration::from_millis(2500));
        assert_eq!(cfg.leader.max_jobs, 2);
        assert_eq!(cfg.leader.queue_depth, 8);
        assert!(cfg.leader.allow_label_pull);
        assert_eq!(cfg.leader.central_workers, 3);
        assert!(cfg.leader.fair_queue);
        assert_eq!(cfg.leader.admit_rate, 2.5);
        assert_eq!(cfg.leader.admit_burst, 7);
        assert_eq!(
            cfg.leader.journal_path.as_deref(),
            Some(std::path::Path::new("leader.journal"))
        );
        assert!(cfg.leader.journal_fsync);
        // 0 is legal and means "inline centrals" (the pre-offload behavior)
        let cfg = PipelineConfig::from_toml("[leader]\ncentral_workers = 0").unwrap();
        assert_eq!(cfg.leader.central_workers, 0);
    }

    #[test]
    fn leader_table_rejects_bad_values() {
        assert!(PipelineConfig::from_toml("[leader]\nmax_jobs = 0").is_err());
        assert!(PipelineConfig::from_toml("[leader]\nqueue_depth = 0").is_err());
        assert!(PipelineConfig::from_toml("[leader]\nmax_jobs = \"many\"").is_err());
        assert!(PipelineConfig::from_toml("[leader]\nallow_label_pull = 1").is_err());
        assert!(PipelineConfig::from_toml("[leader]\ncentral_workers = -1").is_err());
        assert!(PipelineConfig::from_toml("[leader]\ncentral_workers = \"all\"").is_err());
        assert!(PipelineConfig::from_toml("[leader]\nfair_queue = 1").is_err());
        assert!(PipelineConfig::from_toml("[leader]\nadmit_rate = -1.0").is_err());
        assert!(PipelineConfig::from_toml("[leader]\nadmit_rate = \"fast\"").is_err());
        assert!(PipelineConfig::from_toml("[leader]\nadmit_burst = 0").is_err());
        assert!(PipelineConfig::from_toml("[leader]\nadmit_burst = -2").is_err());
        assert!(PipelineConfig::from_toml("[leader]\njournal_path = \"\"").is_err());
        assert!(PipelineConfig::from_toml("[leader]\njournal_path = 7").is_err());
        assert!(PipelineConfig::from_toml("[leader]\njournal_fsync = \"yes\"").is_err());
        assert!(PipelineConfig::from_toml("[leader]\nstandby_of = \"\"").is_err());
        assert!(PipelineConfig::from_toml("[leader]\nstandby_of = 7").is_err());
        assert!(PipelineConfig::from_toml("[leader]\nstandby_timeout_s = 0").is_err());
        assert!(PipelineConfig::from_toml("[leader]\nstandby_timeout_s = -2").is_err());
        assert!(PipelineConfig::from_toml("[leader]\nstandby_timeout_s = \"soon\"").is_err());
    }

    #[test]
    fn site_table_roundtrip_and_defaults() {
        let cfg = PipelineConfig::from_toml("").unwrap();
        assert_eq!(cfg.site.label_cache_runs, 8);
        assert_eq!(cfg.site.max_open_runs, 64);
        assert!(cfg.site.cache_dml);
        assert_eq!(cfg.site.dml_cache_runs, 8);
        assert_eq!(cfg.site.digest_chunk, crate::site::digest::DEFAULT_DIGEST_CHUNK);
        assert!(!cfg.site.report_digest);

        let cfg = PipelineConfig::from_toml(
            "[site]\nlabel_cache_runs = 2\nmax_open_runs = 5\ncache_dml = false\n\
             dml_cache_runs = 3\ndigest_chunk = 256\nreport_digest = true",
        )
        .unwrap();
        assert_eq!(cfg.site.label_cache_runs, 2);
        assert_eq!(cfg.site.max_open_runs, 5);
        assert!(!cfg.site.cache_dml);
        assert_eq!(cfg.site.dml_cache_runs, 3);
        assert_eq!(cfg.site.digest_chunk, 256);
        assert!(cfg.site.report_digest);
    }

    #[test]
    fn site_table_rejects_bad_values() {
        // zero would silently disable pulls / refuse every run — loud errors
        assert!(PipelineConfig::from_toml("[site]\nlabel_cache_runs = 0").is_err());
        assert!(PipelineConfig::from_toml("[site]\nmax_open_runs = 0").is_err());
        assert!(PipelineConfig::from_toml("[site]\nlabel_cache_runs = -3").is_err());
        assert!(PipelineConfig::from_toml("[site]\nmax_open_runs = \"lots\"").is_err());
        assert!(PipelineConfig::from_toml("[site]\ndml_cache_runs = 0").is_err());
        assert!(PipelineConfig::from_toml("[site]\ndigest_chunk = 0").is_err());
        assert!(PipelineConfig::from_toml("[site]\ncache_dml = 1").is_err());
        assert!(PipelineConfig::from_toml("[site]\nreport_digest = \"yes\"").is_err());
    }

    #[test]
    fn rejects_unknown_enum_values() {
        assert!(PipelineConfig::from_toml("[pipeline]\ndml = \"dbscan\"").is_err());
        assert!(PipelineConfig::from_toml("[pipeline]\nbackend = \"gpu\"").is_err());
        assert!(PipelineConfig::from_toml("[bandwidth]\npolicy = \"magic\"").is_err());
    }

    #[test]
    fn fixed_bandwidth_requires_value() {
        assert!(PipelineConfig::from_toml("[bandwidth]\npolicy = \"fixed\"").is_err());
    }
}
