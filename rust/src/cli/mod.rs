//! Command-line launcher (offline stand-in for `clap`).
//!
//! Subcommands:
//!
//! * `dsc run`       — one in-process distributed run; prints a report table.
//! * `dsc site`      — site daemon: serve local data to a leader over TCP.
//! * `dsc leader`    — leader over TCP: one-shot run, or (`--serve`) a
//!   long-lived job server accepting client submissions.
//! * `dsc submit`    — client: enqueue a job on a serving leader and
//!   stream back the result (optionally pulling populated labels).
//! * `dsc datasets`  — the Table-1 proxy inventory.
//! * `dsc artifacts` — verify the AOT artifact set is loadable.
//!
//! `parse_flags` is a tiny `--key value` / `--flag` parser with typed
//! accessors; unknown flags are an error so typos fail loudly. The daemon
//! modes print machine-readable line families — `LISTENING <addr>` and
//! `SERVED …` (site), `SERVING <addr>` (job-serving leader), and
//! `NETREPORT …` / `SUBMITTED run=…` (leader/submit) — that
//! `examples/tcp_cluster.rs` and deployment scripts parse; their field
//! order is a CLI contract (`docs/DEPLOY.md`).

use std::collections::BTreeMap;
use std::io::Write as _;
use std::path::Path;

use anyhow::{anyhow, bail, Context as _, Result};

use crate::config::{Backend, PipelineConfig, TransportKind};
use crate::coordinator::server::{
    replicate_standby, serve_jobs, JobClient, ServerOpts, ETA_UNKNOWN_NS,
};
use crate::coordinator::{run_leader_tcp, run_pipeline, spec_from_config};
use crate::data::scenario::{self, Scenario};
use crate::data::{csvio, gmm, iris, uci_proxy, Dataset};
use crate::dml::DmlKind;
use crate::net::tcp::{Backoff, SiteListener};
use crate::net::{JobSpec, SiteNet};
use crate::spectral::{Algo, Bandwidth, GraphKind};

/// Parsed `--key value` flags (flags without values map to "true").
#[derive(Debug, Default)]
pub struct Flags {
    map: BTreeMap<String, String>,
}

/// Flags that take no value.
const BOOL_FLAGS: &[&str] =
    &["weighted", "full-scale", "once", "fair-queue", "journal-fsync", "standby", "help"];

pub fn parse_flags(args: &[String]) -> Result<Flags> {
    let mut map = BTreeMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        let Some(key) = a.strip_prefix("--") else {
            bail!("unexpected positional argument {a:?}");
        };
        if BOOL_FLAGS.contains(&key) {
            map.insert(key.to_string(), "true".to_string());
            i += 1;
            continue;
        }
        let Some(val) = args.get(i + 1) else {
            bail!("flag --{key} needs a value");
        };
        map.insert(key.to_string(), val.clone());
        i += 2;
    }
    Ok(Flags { map })
}

impl Flags {
    pub fn str(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(|s| s.as_str())
    }
    pub fn usize(&self, key: &str) -> Result<Option<usize>> {
        self.map
            .get(key)
            .map(|s| s.parse::<usize>().map_err(|_| anyhow!("--{key} expects an integer")))
            .transpose()
    }
    pub fn f64(&self, key: &str) -> Result<Option<f64>> {
        self.map
            .get(key)
            .map(|s| s.parse::<f64>().map_err(|_| anyhow!("--{key} expects a number")))
            .transpose()
    }
    pub fn u64(&self, key: &str) -> Result<Option<u64>> {
        self.map
            .get(key)
            .map(|s| s.parse::<u64>().map_err(|_| anyhow!("--{key} expects an integer")))
            .transpose()
    }
    pub fn bool(&self, key: &str) -> bool {
        self.map.get(key).map(|s| s == "true").unwrap_or(false)
    }
    /// Error on flags this command does not understand.
    pub fn reject_unknown(&self, known: &[&str]) -> Result<()> {
        for k in self.map.keys() {
            if !known.contains(&k.as_str()) {
                bail!("unknown flag --{k} (see `dsc help`)");
            }
        }
        Ok(())
    }
}

pub const USAGE: &str = "\
dsc — distributed spectral clustering (Yan et al., TBDATA 2019)

USAGE:
  dsc run [FLAGS]       run one distributed clustering pipeline in-process
  dsc site [FLAGS]      site daemon: serve local data to a leader over TCP
  dsc leader [FLAGS]    leader: drive running site daemons over TCP
                        (one-shot, or --serve for a multi-run job server)
  dsc submit [FLAGS]    client: enqueue a job on a serving leader
  dsc datasets          list the UCI dataset proxies (paper Table 1)
  dsc artifacts         check the AOT artifact set loads
  dsc help              this text

SITE FLAGS (see docs/DEPLOY.md):
  --listen ADDR     bind address (default from [net] listen; port 0 = any
                    free port — the chosen one is printed as LISTENING addr)
  --data FILE       local shard CSV: dim float columns + integer label
  --out FILE        write populated labels here after each run (one per line)
  --once            serve exactly one leader connection, then exit
  --ingest FILE     fold FILE's points into the shard at startup (same
                    column layout as --data) before serving: the shard
                    digest moves and cached DML results are invalidated
  --config FILE     TOML config ([net] timeouts/listen/max_idle_secs and
                    [site] caching/digest knobs)

LEADER FLAGS (see docs/DEPLOY.md):
  --sites A,B,...   site addresses in site-id order (or [net] sites)
  --config FILE     TOML pipeline config (flags override it)
  --serve ADDR      job-server mode: accept `dsc submit` jobs on ADDR
                    (port 0 = any; printed as SERVING addr), pipeline up to
                    [leader] max_jobs runs over persistent site sessions
  --max-jobs N      override [leader] max_jobs     (serve mode)
  --queue-depth N   override [leader] queue_depth  (serve mode)
  --central-workers N  override [leader] central_workers (serve mode;
                    0 = run central steps inline on the reactor thread)
  --serve-limit N   exit after N clients have come and gone (serve mode;
                    drills/CI — a clean shutdown once every client is done)
  --fair-queue      per-client weighted fair queueing, DRR by job priority
                    (serve mode; default [leader] fair_queue = false keeps
                    the legacy global FIFO)
  --admit-rate R    token-bucket admission: submits/sec admitted per client
                    (serve mode; 0 disables — [leader] admit_rate)
  --admit-burst N   burst above --admit-rate ([leader] admit_burst)
  --journal PATH    event-source every reactor event to an append-only log
                    at PATH (serve mode; [leader] journal_path). On restart
                    against the same journal the leader replays it, rebuilds
                    the queue and every incomplete run, and resumes serving
  --journal-fsync   fsync the journal at every group commit ([leader]
                    journal_fsync; durable across power loss, slower)
  --standby         warm standby: replicate the primary's journal over the
                    job socket instead of serving, and promote — replay,
                    re-dial the sites, bind --serve — once the primary
                    has been silent past the standby timeout. Needs
                    --serve, --journal, and --primary ([leader] standby_of)
  --primary ADDR    the serving primary's job address to replicate from
                    (--standby only; overrides [leader] standby_of)
  --standby-timeout SECS  silence on the replication link that triggers
                    promotion ([leader] standby_timeout_s, default 10)
  plus the central-step RUN FLAGS: --dml --codes --k --algo --graph
  --knn-k --backend --bandwidth --weighted --seed

SUBMIT FLAGS (see docs/DEPLOY.md):
  --leader A[,B,…]  leader job addresses, tried in order (primary first,
                    then standbys) with capped-backoff retry sweeps until
                    one accepts the dial — submit-time failover
  --config FILE     TOML pipeline config for the job (flags override it)
  --pull DIR        after the run, pull populated labels through the leader
                    into DIR/labels_site<id>.txt (needs [leader]
                    allow_label_pull = true on the leader)
  --priority P      job priority 1..16 — the DRR weight under a
                    --fair-queue leader; also prints the accept's queue
                    position and ETA estimate
  plus the central-step RUN FLAGS except --backend (the central step runs
  on the leader, under the leader's backend)

RUN FLAGS:
  --dataset NAME    gmm2d | gmm10d | iris | connect4 | skinseg | usci |
                    covertype | htsensor | pokerhand | gassensor | hepmass
  --n N             points to generate (default: dataset-specific)
  --rho R           gmm10d covariance decay (0.1/0.3/0.6; default 0.3)
  --sites S         number of distributed sites (default 2)
  --scenario D      d1 | d2 | d3 | d4 (default d3)
  --dml KIND        kmeans | rptrees (default kmeans)
  --codes N         total codeword budget (default: paper's ratio)
  --k K             clusters (default: dataset classes)
  --algo A          ncut | njw (default ncut)
  --graph G         dense | knn — affinity storage for the central step
                    (default dense; knn is the sparse large-codebook path)
  --knn-k N         neighbors per codeword; implies --graph knn (default 32)
  --backend B       native | xla | xla-full (default native)
  --bandwidth SPEC  fixed:σ | median:scale | eigengap:k (default median:1)
  --weighted        weight affinity by codeword group sizes
  --seed N          master seed (default 7)
  --config FILE     TOML config (flags override it)
  --full-scale      use the paper's full dataset sizes
";

/// Materialize the dataset a `run` invocation asks for.
pub fn load_dataset(flags: &Flags) -> Result<(Dataset, usize)> {
    let name = flags.str("dataset").unwrap_or("gmm10d");
    let seed = flags.u64("seed")?.unwrap_or(7);
    match name {
        "gmm2d" => {
            let n = flags.usize("n")?.unwrap_or(10_000);
            Ok((gmm::paper_mixture_2d(n, seed), 4))
        }
        "gmm10d" => {
            let n = flags.usize("n")?.unwrap_or(40_000);
            let rho = flags.f64("rho")?.unwrap_or(0.3);
            Ok((gmm::paper_mixture_10d(n, rho, seed), 4))
        }
        "iris" => Ok((iris::load(), 3)),
        other => {
            let spec = uci_proxy::by_name(other)
                .ok_or_else(|| anyhow!("unknown dataset {other:?} (see `dsc datasets`)"))?;
            let n = if flags.bool("full-scale") {
                spec.paper_n
            } else {
                flags.usize("n")?.unwrap_or_else(|| spec.default_n())
            };
            Ok((spec.generate(n, seed), spec.n_classes))
        }
    }
}

/// Apply the dataset-independent central-step flag overrides to a config
/// (shared by `dsc run` and `dsc leader`; flags beat the file).
pub fn apply_overrides(cfg: &mut PipelineConfig, flags: &Flags) -> Result<()> {
    if let Some(v) = flags.str("dml") {
        cfg.dml = DmlKind::parse(v).ok_or_else(|| anyhow!("bad --dml {v:?}"))?;
    }
    if let Some(v) = flags.usize("codes")? {
        cfg.total_codes = v;
    }
    if let Some(v) = flags.usize("k")? {
        cfg.k_clusters = v;
    }
    if let Some(v) = flags.str("algo") {
        cfg.algo = Algo::parse(v).ok_or_else(|| anyhow!("bad --algo {v:?}"))?;
    }
    if let Some(v) = flags.str("graph") {
        cfg.graph = GraphKind::parse(v).ok_or_else(|| anyhow!("bad --graph {v:?}"))?;
    }
    if let Some(kk) = flags.usize("knn-k")? {
        if kk == 0 {
            bail!("--knn-k must be ≥ 1");
        }
        // An explicit neighbor count implies the sparse graph. Two flags
        // contradicting each other is a loud error (same contract as the
        // TOML `spectral.knn_k` key); a `graph = "dense"` from --config is
        // instead overridden, per the documented flags-beat-file precedence.
        if flags.str("graph").is_some() && cfg.graph == GraphKind::Dense {
            bail!("--knn-k conflicts with --graph dense (drop one)");
        }
        cfg.graph = GraphKind::Knn { k: kk };
    }
    if let Some(v) = flags.str("backend") {
        cfg.backend = Backend::parse(v).ok_or_else(|| anyhow!("bad --backend {v:?}"))?;
    }
    if let Some(v) = flags.str("bandwidth") {
        cfg.bandwidth = parse_bandwidth(v)?;
    }
    if flags.bool("weighted") {
        cfg.weighted_affinity = true;
    }
    if let Some(v) = flags.u64("seed")? {
        cfg.seed = v;
    }
    Ok(())
}

/// Build a [`PipelineConfig`] from `--config` + flag overrides, with the
/// dataset-aware defaults `dsc run` wants when a flag is absent.
pub fn build_config(flags: &Flags, default_k: usize, n_points: usize) -> Result<PipelineConfig> {
    let mut cfg = match flags.str("config") {
        Some(path) => PipelineConfig::from_file(Path::new(path))?,
        None => PipelineConfig::default(),
    };
    apply_overrides(&mut cfg, flags)?;
    if flags.usize("codes")?.is_none() {
        if let Some(spec) = flags.str("dataset").and_then(uci_proxy::by_name) {
            // default to the paper's compression ratio target for UCI proxies
            cfg.total_codes = spec.target_codewords().min(n_points);
        } else {
            cfg.total_codes = cfg.total_codes.min(n_points / 4).max(16.min(n_points));
        }
    }
    if flags.usize("k")?.is_none() && flags.str("config").is_none() {
        // no flag and no config file: fall back to the dataset's class
        // count (a file-provided k_clusters must not be clobbered)
        cfg.k_clusters = default_k;
    }
    Ok(cfg)
}

/// `fixed:2.5 | median:0.5 | eigengap:4`
pub fn parse_bandwidth(s: &str) -> Result<Bandwidth> {
    let (kind, val) = s.split_once(':').unwrap_or((s, ""));
    match kind {
        "fixed" => Ok(Bandwidth::Fixed(
            val.parse().map_err(|_| anyhow!("fixed:<σ> needs a number"))?,
        )),
        "median" => Ok(Bandwidth::MedianScale(if val.is_empty() {
            1.0
        } else {
            val.parse().map_err(|_| anyhow!("median:<scale> needs a number"))?
        })),
        "eigengap" => Ok(Bandwidth::EigengapSearch {
            k: if val.is_empty() {
                2
            } else {
                val.parse().map_err(|_| anyhow!("eigengap:<k> needs an integer"))?
            },
        }),
        other => bail!("unknown bandwidth policy {other:?}"),
    }
}

/// The `dsc run` subcommand.
pub fn cmd_run(args: &[String]) -> Result<()> {
    let flags = parse_flags(args)?;
    flags.reject_unknown(&[
        "dataset", "n", "rho", "sites", "scenario", "dml", "codes", "k", "algo", "graph",
        "knn-k", "backend", "bandwidth", "weighted", "seed", "config", "full-scale", "help",
    ])?;
    if flags.bool("help") {
        println!("{USAGE}");
        return Ok(());
    }

    let (ds, default_k) = load_dataset(&flags)?;
    let cfg = build_config(&flags, default_k, ds.len())?;
    if cfg.net.transport == TransportKind::Tcp {
        bail!(
            "this config sets [net] transport = \"tcp\" — `dsc run` executes \
             in-process; use `dsc site` + `dsc leader` for a multi-process run \
             (docs/DEPLOY.md)"
        );
    }
    let sites = flags.usize("sites")?.unwrap_or(2);
    let sc = match flags.str("scenario") {
        None => Scenario::D3,
        Some(s) => Scenario::parse(s).ok_or_else(|| anyhow!("bad --scenario {s:?}"))?,
    };
    let seed = cfg.seed;

    println!(
        "dataset={} n={} dim={} classes={} | sites={sites} scenario={sc} dml={} codes={} k={} backend={:?}",
        ds.name,
        ds.len(),
        ds.dim,
        ds.n_classes,
        cfg.dml,
        cfg.total_codes,
        cfg.k_clusters,
        cfg.backend,
    );

    let parts = if sites == 1 {
        vec![scenario::SitePart {
            site_id: 0,
            data: ds.clone(),
            global_idx: (0..ds.len() as u32).collect(),
        }]
    } else {
        scenario::split(&ds, sc, sites, seed ^ 0x5C)
    };
    let report = run_pipeline(&parts, &cfg)?;

    println!("── result ─────────────────────────────");
    println!("accuracy        {:.4}", report.accuracy);
    println!("ARI / NMI       {:.4} / {:.4}", report.ari, report.nmi);
    println!("codewords       {}", report.n_codes);
    println!("sigma           {:.4}", report.sigma);
    println!(
        "elapsed (model) {:.3}s  (max DML {:.3}s + central {:.3}s + populate {:.3}s)",
        report.elapsed_model.as_secs_f64(),
        report.site_dml.iter().copied().max().unwrap_or_default().as_secs_f64(),
        report.central.as_secs_f64(),
        report.populate.as_secs_f64(),
    );
    println!("wall clock      {:.3}s", report.wall.as_secs_f64());
    println!(
        "comm            {} B on the wire vs {} B full-data ({}x less), modeled transfer {:.1} ms",
        report.net.total_bytes(),
        report.full_data_bytes,
        report.full_data_bytes / report.net.total_bytes().max(1),
        report.net.max_link_time().as_secs_f64() * 1e3,
    );
    Ok(())
}

/// The `dsc site` subcommand: serve a local CSV shard to a leader over TCP.
///
/// Prints `LISTENING <addr>` (the actual bound address — meaningful with
/// `--listen host:0`) once the socket is up, then `SERVED …` after each
/// completed run. Without `--once` it keeps accepting leader connections,
/// one pipeline run per connection, and survives failed runs.
pub fn cmd_site(args: &[String]) -> Result<()> {
    let flags = parse_flags(args)?;
    flags.reject_unknown(&["listen", "data", "out", "once", "config", "ingest", "help"])?;
    if flags.bool("help") {
        println!("{USAGE}");
        return Ok(());
    }

    let cfg = match flags.str("config") {
        Some(path) => PipelineConfig::from_file(Path::new(path))?,
        None => PipelineConfig::default(),
    };
    let data_path = flags
        .str("data")
        .ok_or_else(|| anyhow!("dsc site needs --data <csv> (float features…, integer label per row)"))?;
    let name = Path::new(data_path)
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("site")
        .to_string();
    let data = csvio::load_dataset(Path::new(data_path), &name, None)?;
    if data.is_empty() {
        bail!("{data_path}: empty shard");
    }

    // The session owns the shard across connections: its DML result cache,
    // shard digest, and live codebook survive leader reconnects.
    let mut session = crate::site::Session::new(data, cfg.site);
    if let Some(ingest_path) = flags.str("ingest") {
        let extra = csvio::load_dataset(Path::new(ingest_path), "ingest", None)?;
        let added = session.ingest(&extra)?;
        println!(
            "INGESTED n_points={added} total={} version={:016x}",
            session.data().len(),
            session.shard_version()
        );
        std::io::stdout().flush().ok();
    }

    let listen = flags.str("listen").unwrap_or(&cfg.net.listen);
    let timeouts = cfg.net.tcp_timeouts();
    let listener = SiteListener::bind(listen)?;
    let addr = listener.local_addr()?;
    println!("LISTENING {addr}");
    std::io::stdout().flush().ok();
    eprintln!(
        "site daemon: {} points × {} dims from {data_path} (shard version {:016x}); \
         waiting for a leader",
        session.data().len(),
        session.data().dim,
        session.shard_version()
    );

    let once = flags.bool("once");
    // Backoff for the error path: capped exponential with deterministic
    // jitter, salted by the listen address so a *fleet* of sites sharing a
    // config seed does not retry in lockstep after a common fault.
    let mut backoff = Backoff::new(cfg.seed ^ addr_salt(listen));
    loop {
        let served = (|| -> Result<()> {
            let transport = listener.accept(&timeouts)?;
            if transport.session_mode() {
                // A job-serving leader: persistent multi-run session over
                // this one connection, served from the long-lived session —
                // its DML result cache spans connections, so a leader that
                // reconnects and resubmits an identical job gets a cached
                // (bit-identical) codebook without a single DML pass.
                let net = SiteNet::over(Box::new(transport));
                let out = session.serve(
                    &net,
                    flags.str("out").map(Path::new),
                    |r| {
                        println!(
                            "SERVED run={} n_points={} n_codes={} dml_s={:.3} distortion={:.6} cache={}",
                            r.run,
                            r.n_points,
                            r.n_codes,
                            r.dml_time.as_secs_f64(),
                            r.distortion,
                            if r.cache_hit { "hit" } else { "miss" },
                        );
                        std::io::stdout().flush().ok();
                    },
                )?;
                println!(
                    "SESSION runs={} aborted={} dml_passes={} cache_hits={}",
                    out.runs_served, out.aborted_runs, out.dml_passes, out.cache_hits
                );
                std::io::stdout().flush().ok();
            } else {
                let net = SiteNet::over(Box::new(transport));
                let site_id = net.site_id();
                let out = crate::site::serve(&net, session.data())?;
                if let Some(out_path) = flags.str("out") {
                    crate::site::write_labels(Path::new(out_path), &out.labels)?;
                }
                println!(
                    "SERVED site={site_id} n_points={} n_codes={} dml_s={:.3} distortion={:.6}",
                    out.n_points,
                    out.n_codes,
                    out.dml_time.as_secs_f64(),
                    out.distortion,
                );
                std::io::stdout().flush().ok();
            }
            Ok(())
        })();
        match served {
            Ok(()) if once => return Ok(()),
            Ok(()) => backoff.reset(),
            Err(e) if once => return Err(e),
            // Daemon mode: one bad leader (crash, version mismatch, port
            // scanner, silent death past [net] max_idle_secs) must not take
            // the site down. The backoff keeps a persistently-failing
            // accept (fd exhaustion, dead listener) from hot-spinning the
            // daemon or letting a flapping fleet sync-storm the leader.
            Err(e) => {
                eprintln!("site: run failed: {e:#} (daemon continues)");
                std::thread::sleep(backoff.next_delay());
            }
        }
    }
}

/// FNV-1a of an address string: a per-site salt for the backoff jitter
/// stream, so sites sharing a config seed still decorrelate.
fn addr_salt(addr: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in addr.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The `dsc leader` subcommand: drive running `dsc site` daemons over TCP.
///
/// One-shot mode (default): a single run from this config; prints one
/// `NETREPORT site=<id> …` line per link with the per-direction
/// frame/byte/modeled-time counters — byte-for-byte what the in-process
/// backend reports for the same config and data — plus a
/// `NETREPORT total_bytes=…` summary line.
///
/// Job-server mode (`--serve ADDR`): bind ADDR for `dsc submit` clients
/// (printing `SERVING <addr>` first, a CLI contract like the site's
/// `LISTENING`), open persistent multi-run sessions to every site, and
/// pipeline up to `[leader] max_jobs` client runs over them until killed
/// (or `--serve-limit` runs finish).
pub fn cmd_leader(args: &[String]) -> Result<()> {
    let flags = parse_flags(args)?;
    flags.reject_unknown(&[
        "sites", "config", "serve", "max-jobs", "queue-depth", "central-workers",
        "serve-limit", "fair-queue", "admit-rate", "admit-burst", "journal", "journal-fsync",
        "standby", "primary", "standby-timeout", "dml", "codes", "k", "algo", "graph",
        "knn-k", "backend", "bandwidth", "weighted", "seed", "help",
    ])?;
    if flags.bool("help") {
        println!("{USAGE}");
        return Ok(());
    }

    let mut cfg = match flags.str("config") {
        Some(path) => PipelineConfig::from_file(Path::new(path))?,
        None => PipelineConfig::default(),
    };
    apply_overrides(&mut cfg, &flags)?;
    if let Some(s) = flags.str("sites") {
        cfg.net.sites =
            s.split(',').map(|a| a.trim().to_string()).filter(|a| !a.is_empty()).collect();
    }
    cfg.net.transport = TransportKind::Tcp; // leader mode is TCP by definition
    if cfg.net.sites.is_empty() {
        bail!("dsc leader needs --sites a,b,… or [net] sites in the config");
    }

    if let Some(serve_addr) = flags.str("serve") {
        // Scheduling knobs live on [leader] (the reactor reads the config,
        // not ServerOpts), so the flag overrides mutate cfg.leader.
        if flags.bool("fair-queue") {
            cfg.leader.fair_queue = true;
        }
        if let Some(rate) = flags.f64("admit-rate")? {
            if !rate.is_finite() || rate < 0.0 {
                bail!("--admit-rate must be finite and ≥ 0 (0 disables admission)");
            }
            cfg.leader.admit_rate = rate;
        }
        if let Some(n) = flags.usize("admit-burst")? {
            if n == 0 {
                bail!("--admit-burst must be ≥ 1");
            }
            cfg.leader.admit_burst = n;
        }
        if let Some(path) = flags.str("journal") {
            if path.is_empty() {
                bail!("--journal needs a non-empty path (omit the flag to disable)");
            }
            cfg.leader.journal_path = Some(std::path::PathBuf::from(path));
        }
        if flags.bool("journal-fsync") {
            if cfg.leader.journal_path.is_none() {
                bail!("--journal-fsync needs --journal PATH (or [leader] journal_path)");
            }
            cfg.leader.journal_fsync = true;
        }
        let standby = flags.bool("standby");
        if let Some(p) = flags.str("primary") {
            if !standby {
                bail!("--primary only makes sense with --standby");
            }
            if p.is_empty() {
                bail!("--primary needs a non-empty address");
            }
            cfg.leader.standby_of = Some(p.to_string());
        }
        if let Some(secs) = flags.f64("standby-timeout")? {
            if !standby {
                bail!("--standby-timeout only makes sense with --standby");
            }
            if !secs.is_finite() || secs <= 0.0 {
                bail!("--standby-timeout must be finite and > 0 seconds");
            }
            cfg.leader.standby_timeout = std::time::Duration::from_secs_f64(secs);
        }
        if standby {
            if cfg.leader.standby_of.is_none() {
                bail!("--standby needs --primary ADDR (or [leader] standby_of)");
            }
            if cfg.leader.journal_path.is_none() {
                bail!(
                    "--standby needs --journal PATH (or [leader] journal_path) — \
                     the replicated copy it promotes from"
                );
            }
        }
        let mut opts = ServerOpts::from_config(&cfg);
        if let Some(n) = flags.usize("max-jobs")? {
            if n == 0 {
                bail!("--max-jobs must be ≥ 1");
            }
            opts.max_jobs = n;
        }
        if let Some(n) = flags.usize("queue-depth")? {
            if n == 0 {
                bail!("--queue-depth must be ≥ 1");
            }
            opts.queue_depth = n;
        }
        if let Some(n) = flags.usize("central-workers")? {
            // 0 is legal: run central steps inline (the pre-offload mode)
            opts.central_workers = n;
        }
        opts.client_limit = flags.u64("serve-limit")?;

        if standby {
            // Warm standby: no listener yet — a standby that accepted
            // clients before promotion would be a split brain. Replicate
            // until the primary goes silent, then fall through to the
            // normal serve path: `serve_jobs` finds the replicated journal
            // on disk and performs exactly the crash-restart recovery
            // (replay, re-dial the sites, resume incomplete runs).
            let primary = cfg.leader.standby_of.as_deref().unwrap_or("?").to_string();
            println!(
                "STANDBY primary={primary} journal={}",
                cfg.leader.journal_path.as_deref().map(|p| p.display().to_string()).unwrap(),
            );
            std::io::stdout().flush().ok();
            let records = replicate_standby(&cfg)?;
            println!("PROMOTED records={records}");
            std::io::stdout().flush().ok();
        }

        let listener = std::net::TcpListener::bind(serve_addr)
            .with_context(|| format!("bind job socket {serve_addr}"))?;
        let addr = listener.local_addr().context("job socket local addr")?;
        println!("SERVING {addr}");
        std::io::stdout().flush().ok();
        eprintln!(
            "leader: job server at {addr}; {} site(s): {} (max_jobs={}, queue_depth={}, \
             central_workers={}, label_pull={}, fair_queue={}, admit_rate={}, journal={})",
            cfg.net.sites.len(),
            cfg.net.sites.join(", "),
            opts.max_jobs,
            opts.queue_depth,
            opts.central_workers,
            opts.allow_label_pull,
            cfg.leader.fair_queue,
            cfg.leader.admit_rate,
            cfg.leader
                .journal_path
                .as_deref()
                .map(|p| p.display().to_string())
                .unwrap_or_else(|| "off".to_string()),
        );
        let stats = serve_jobs(&cfg, &opts, listener)?;
        println!(
            "SERVED_JOBS completed={} failed={} rejected={}",
            stats.completed, stats.failed, stats.rejected
        );
        return Ok(());
    }

    if flags.bool("standby") || flags.str("primary").is_some() || flags.str("standby-timeout").is_some()
    {
        bail!("--standby needs --serve ADDR (the address the promoted leader serves on)");
    }

    println!(
        "leader: dialing {} site(s): {}",
        cfg.net.sites.len(),
        cfg.net.sites.join(", ")
    );
    let report = run_leader_tcp(&cfg)?;

    println!("── leader result ──────────────────────");
    println!("sites           {}", report.outcome.site_points.len());
    println!("points          {}", report.outcome.site_points.iter().sum::<u64>());
    println!(
        "codewords       {}  (per site: {:?})",
        report.outcome.n_codes, report.outcome.site_codes
    );
    println!("sigma           {:.4}", report.outcome.sigma);
    println!(
        "central         {:.3}s | wall {:.3}s",
        report.outcome.central.as_secs_f64(),
        report.wall.as_secs_f64()
    );
    for (sid, l) in report.net.per_site.iter().enumerate() {
        println!(
            "NETREPORT site={sid} up_frames={} up_bytes={} down_frames={} down_bytes={} \
             up_sim_ns={} down_sim_ns={}",
            l.to_leader.frames,
            l.to_leader.bytes,
            l.to_site.frames,
            l.to_site.bytes,
            l.to_leader.sim_time.as_nanos(),
            l.to_site.sim_time.as_nanos(),
        );
    }
    println!("NETREPORT total_bytes={}", report.net.total_bytes());
    Ok(())
}

/// Dial sweeps a submit makes over its `--leader` failover chain before
/// giving up. With the capped-exponential `Backoff` between sweeps this
/// spans comfortably more than a default `standby_timeout` (10 s), so a
/// client that arrives mid-failover outlives the standby's promotion.
const SUBMIT_DIAL_SWEEPS: usize = 8;

/// Try each leader in order; on a full sweep of refusals, back off and
/// sweep again. The first address is the primary — a connect that lands
/// anywhere else is a failover and says so on stderr.
fn dial_leaders(leaders: &[String], cfg: &PipelineConfig) -> Result<JobClient> {
    let timeouts = cfg.net.tcp_timeouts();
    let mut backoff = Backoff::new(cfg.seed ^ addr_salt(&leaders.join(",")));
    let mut last_err = anyhow!("no leader addresses");
    for sweep in 0..SUBMIT_DIAL_SWEEPS {
        if sweep > 0 {
            std::thread::sleep(backoff.next_delay());
        }
        for (i, addr) in leaders.iter().enumerate() {
            match JobClient::connect(addr, &timeouts) {
                Ok(client) => {
                    if i > 0 || sweep > 0 {
                        eprintln!("submit: connected to {addr} (failover, sweep {sweep})");
                    }
                    return Ok(client);
                }
                Err(e) => last_err = e.context(format!("dial leader {addr}")),
            }
        }
    }
    Err(last_err.context(format!(
        "no leader reachable after {SUBMIT_DIAL_SWEEPS} sweeps of {leaders:?}"
    )))
}

/// The `dsc submit` subcommand: enqueue one clustering job on a serving
/// leader (`dsc leader --serve`) and wait for the result.
///
/// Prints `SUBMITTED run=<id>` once the leader accepts, then — when the
/// run completes — a `RUN …` summary plus the same `NETREPORT` line family
/// as one-shot `dsc leader`, scoped to exactly this run's frames. With
/// `--pull DIR`, the populated per-point labels are pulled through the
/// leader afterwards (one file per site, local shard row order), which
/// needs `[leader] allow_label_pull = true` on the serving side.
pub fn cmd_submit(args: &[String]) -> Result<()> {
    let flags = parse_flags(args)?;
    flags.reject_unknown(&[
        "leader", "config", "pull", "priority", "dml", "codes", "k", "algo", "graph", "knn-k",
        "bandwidth", "weighted", "seed", "help",
    ])?;
    if flags.bool("help") {
        println!("{USAGE}");
        return Ok(());
    }

    let mut cfg = match flags.str("config") {
        Some(path) => PipelineConfig::from_file(Path::new(path))?,
        None => PipelineConfig::default(),
    };
    apply_overrides(&mut cfg, &flags)?;
    let addr = flags
        .str("leader")
        .ok_or_else(|| anyhow!("dsc submit needs --leader <addr> (the leader's --serve address)"))?;
    // A comma-separated list is a failover chain: primary first, then the
    // standby(s) that will promote if it dies.
    let leaders: Vec<String> =
        addr.split(',').map(|a| a.trim().to_string()).filter(|a| !a.is_empty()).collect();
    if leaders.is_empty() {
        bail!("--leader needs at least one address");
    }

    let mut spec = spec_from_config(&cfg);
    // Validate before dialing so a bad flag fails fast and offline.
    let tracked = match flags.usize("priority")? {
        Some(p) => {
            if p < 1 || p > JobSpec::MAX_PRIORITY as usize {
                bail!("--priority must be in 1..={}", JobSpec::MAX_PRIORITY);
            }
            spec.priority = p as u32;
            true
        }
        None => false,
    };
    let client = dial_leaders(&leaders, &cfg)?;
    let run = if tracked {
        // The priority dialect: the accept carries queue position and an
        // ETA estimate, so surface them. The plain `SUBMITTED run=<id>`
        // line stays untouched for legacy scripts. A cold server has no
        // completed run to extrapolate from; the wire says so with the
        // u64::MAX sentinel, and inventing `0.000` here would read as
        // "immediate" — print the honest answer instead.
        let acc = client.submit_tracked(&spec)?;
        if acc.eta_ns == ETA_UNKNOWN_NS {
            println!("SUBMITTED run={} position={} eta_s=unknown", acc.run, acc.position);
        } else {
            println!(
                "SUBMITTED run={} position={} eta_s={:.3}",
                acc.run,
                acc.position,
                acc.eta_ns as f64 / 1e9
            );
        }
        acc.run
    } else {
        let run = client.submit(&spec)?;
        println!("SUBMITTED run={run}");
        run
    };
    std::io::stdout().flush().ok();

    let report = client.await_done(run)?;
    println!(
        "RUN run={run} n_codes={} sigma={:.4} central_s={:.3} wall_s={:.3}",
        report.n_codes,
        report.sigma,
        report.central_ns as f64 / 1e9,
        report.wall_ns as f64 / 1e9,
    );
    for (sid, l) in report.per_site.iter().enumerate() {
        println!(
            "NETREPORT site={sid} up_frames={} up_bytes={} down_frames={} down_bytes={} \
             up_sim_ns={} down_sim_ns={}",
            l.up_frames, l.up_bytes, l.down_frames, l.down_bytes, l.up_sim_ns, l.down_sim_ns,
        );
    }
    let total: u64 = report.per_site.iter().map(|l| l.up_bytes + l.down_bytes).sum();
    println!("NETREPORT total_bytes={total}");

    if let Some(dir) = flags.str("pull") {
        let pulled = client.pull_labels(run, report.per_site.len())?;
        for (site, labels) in &pulled {
            let path = Path::new(dir).join(format!("labels_site{site}.txt"));
            crate::site::write_labels(&path, labels)?;
            println!("PULLED site={site} n={} out={}", labels.len(), path.display());
        }
    }
    Ok(())
}

/// The `dsc datasets` subcommand (Table 1).
pub fn cmd_datasets() {
    println!(
        "{:<11} {:>4} {:>8} {:>8} {:>7} {:>8} {:>9}",
        "dataset", "dim", "paper_n", "classes", "ratio", "codes", "default_n"
    );
    for s in uci_proxy::specs() {
        println!(
            "{:<11} {:>4} {:>8} {:>8} {:>7} {:>8} {:>9}",
            s.name,
            s.dim,
            s.paper_n,
            s.n_classes,
            s.paper_ratio,
            s.target_codewords(),
            s.default_n()
        );
    }
}

/// The `dsc artifacts` subcommand.
pub fn cmd_artifacts() -> Result<()> {
    let dir = crate::runtime::default_artifact_dir();
    let arts = crate::runtime::Artifacts::load(&dir)?;
    println!("artifact dir: {} ({} programs, embed_k={})", dir.display(), arts.programs.len(), arts.embed_k);
    for p in &arts.programs {
        println!("  {:<22} {:?} n={} d={} k={}", p.name, p.kind, p.n, p.d, p.k);
    }
    Ok(())
}

/// Top-level dispatch (called by `main`).
pub fn dispatch(argv: Vec<String>) -> Result<()> {
    match argv.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(&argv[1..]),
        Some("site") => cmd_site(&argv[1..]),
        Some("leader") => cmd_leader(&argv[1..]),
        Some("submit") => cmd_submit(&argv[1..]),
        Some("datasets") => {
            cmd_datasets();
            Ok(())
        }
        Some("artifacts") => cmd_artifacts(),
        Some("help") | None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(other) => bail!("unknown subcommand {other:?} (see `dsc help`)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flags(args: &[&str]) -> Flags {
        parse_flags(&args.iter().map(|s| s.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn parse_typed_flags() {
        let f = flags(&["--sites", "3", "--weighted", "--rho", "0.6", "--dataset", "hepmass"]);
        assert_eq!(f.usize("sites").unwrap(), Some(3));
        assert!(f.bool("weighted"));
        assert_eq!(f.f64("rho").unwrap(), Some(0.6));
        assert_eq!(f.str("dataset"), Some("hepmass"));
        assert_eq!(f.usize("missing").unwrap(), None);
    }

    #[test]
    fn missing_value_is_error() {
        let args = vec!["--sites".to_string()];
        assert!(parse_flags(&args).is_err());
    }

    #[test]
    fn positional_rejected() {
        let args = vec!["oops".to_string()];
        assert!(parse_flags(&args).is_err());
    }

    #[test]
    fn unknown_flag_rejected() {
        let f = flags(&["--bogus", "1"]);
        assert!(f.reject_unknown(&["sites"]).is_err());
        assert!(f.reject_unknown(&["bogus"]).is_ok());
    }

    #[test]
    fn bandwidth_specs() {
        assert!(matches!(parse_bandwidth("fixed:2.5").unwrap(), Bandwidth::Fixed(s) if s == 2.5));
        assert!(
            matches!(parse_bandwidth("median:0.3").unwrap(), Bandwidth::MedianScale(s) if s == 0.3)
        );
        assert!(matches!(
            parse_bandwidth("eigengap:4").unwrap(),
            Bandwidth::EigengapSearch { k: 4 }
        ));
        assert!(parse_bandwidth("magic").is_err());
        assert!(parse_bandwidth("fixed:abc").is_err());
    }

    #[test]
    fn dataset_loading_iris_and_proxies() {
        let f = flags(&["--dataset", "iris"]);
        let (ds, k) = load_dataset(&f).unwrap();
        assert_eq!(ds.len(), 150);
        assert_eq!(k, 3);

        let f = flags(&["--dataset", "skinseg", "--n", "2000"]);
        let (ds, k) = load_dataset(&f).unwrap();
        assert_eq!(ds.len(), 2000);
        assert_eq!(k, 2);

        let f = flags(&["--dataset", "nope"]);
        assert!(load_dataset(&f).is_err());
    }

    #[test]
    fn config_overrides() {
        let f = flags(&["--dml", "rptrees", "--k", "5", "--backend", "xla", "--codes", "99"]);
        let cfg = build_config(&f, 2, 10_000).unwrap();
        assert_eq!(cfg.dml, DmlKind::RpTree);
        assert_eq!(cfg.k_clusters, 5);
        assert_eq!(cfg.backend, Backend::Xla);
        assert_eq!(cfg.total_codes, 99);
        assert_eq!(cfg.graph, GraphKind::Dense);
    }

    #[test]
    fn graph_flags() {
        let f = flags(&["--graph", "knn"]);
        let cfg = build_config(&f, 2, 1_000).unwrap();
        assert_eq!(cfg.graph, GraphKind::Knn { k: GraphKind::DEFAULT_KNN_K });

        // --knn-k implies the sparse graph and overrides the default k
        let f = flags(&["--knn-k", "12"]);
        let cfg = build_config(&f, 2, 1_000).unwrap();
        assert_eq!(cfg.graph, GraphKind::Knn { k: 12 });

        let f = flags(&["--graph", "knn", "--knn-k", "64"]);
        let cfg = build_config(&f, 2, 1_000).unwrap();
        assert_eq!(cfg.graph, GraphKind::Knn { k: 64 });

        // explicit dense + knn-k is contradictory: loud error, not override
        let f = flags(&["--graph", "dense", "--knn-k", "12"]);
        assert!(build_config(&f, 2, 1_000).is_err());

        let f = flags(&["--graph", "hypercube"]);
        assert!(build_config(&f, 2, 1_000).is_err());
        let f = flags(&["--knn-k", "0"]);
        assert!(build_config(&f, 2, 1_000).is_err());
    }

    #[test]
    fn config_file_k_clusters_not_clobbered() {
        let path = std::env::temp_dir().join("dsc_cli_k_test.toml");
        std::fs::write(&path, "[pipeline]\nk_clusters = 8\n").unwrap();
        let f = flags(&["--config", path.to_str().unwrap()]);
        let cfg = build_config(&f, 4, 10_000).unwrap();
        assert_eq!(cfg.k_clusters, 8, "file value must survive absent --k");
        // an explicit --k still wins over the file
        let f = flags(&["--config", path.to_str().unwrap(), "--k", "3"]);
        let cfg = build_config(&f, 4, 10_000).unwrap();
        assert_eq!(cfg.k_clusters, 3);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn uci_default_codes_follow_paper_ratio() {
        let f = flags(&["--dataset", "hepmass"]);
        let cfg = build_config(&f, 2, 100_000).unwrap();
        assert_eq!(cfg.total_codes, 1500); // 10.5M / 7000
    }

    #[test]
    fn apply_overrides_leaves_untouched_fields_alone() {
        let mut cfg = PipelineConfig::from_toml(
            "[pipeline]\nk_clusters = 9\ntotal_codes = 77\n[net]\nsites = \"a:1,b:2\"",
        )
        .unwrap();
        let f = flags(&["--seed", "42", "--algo", "njw"]);
        apply_overrides(&mut cfg, &f).unwrap();
        assert_eq!(cfg.seed, 42);
        assert_eq!(cfg.algo, Algo::Njw);
        assert_eq!(cfg.k_clusters, 9, "file value must survive");
        assert_eq!(cfg.total_codes, 77);
        assert_eq!(cfg.net.sites, vec!["a:1", "b:2"]);
    }

    #[test]
    fn site_subcommand_requires_data() {
        let err = cmd_site(&[]).unwrap_err();
        assert!(err.to_string().contains("--data"), "{err}");
    }

    #[test]
    fn leader_subcommand_requires_sites() {
        let err = cmd_leader(&[]).unwrap_err();
        assert!(err.to_string().contains("--sites"), "{err}");
    }

    #[test]
    fn submit_subcommand_requires_leader() {
        let err = cmd_submit(&[]).unwrap_err();
        assert!(err.to_string().contains("--leader"), "{err}");
    }

    #[test]
    fn serve_flags_validated() {
        let args: Vec<String> = ["--sites", "127.0.0.1:1", "--serve", "127.0.0.1:0", "--max-jobs", "0"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let err = cmd_leader(&args).unwrap_err();
        assert!(err.to_string().contains("--max-jobs"), "{err}");

        let args: Vec<String> =
            ["--sites", "127.0.0.1:1", "--serve", "127.0.0.1:0", "--queue-depth", "0"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let err = cmd_leader(&args).unwrap_err();
        assert!(err.to_string().contains("--queue-depth"), "{err}");

        let args: Vec<String> =
            ["--sites", "127.0.0.1:1", "--serve", "127.0.0.1:0", "--admit-rate", "-1"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let err = cmd_leader(&args).unwrap_err();
        assert!(err.to_string().contains("--admit-rate"), "{err}");

        let args: Vec<String> =
            ["--sites", "127.0.0.1:1", "--serve", "127.0.0.1:0", "--admit-burst", "0"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let err = cmd_leader(&args).unwrap_err();
        assert!(err.to_string().contains("--admit-burst"), "{err}");

        // journal flags validate offline too: empty path, fsync without a log
        let args: Vec<String> =
            ["--sites", "127.0.0.1:1", "--serve", "127.0.0.1:0", "--journal", ""]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let err = cmd_leader(&args).unwrap_err();
        assert!(err.to_string().contains("--journal"), "{err}");

        let args: Vec<String> =
            ["--sites", "127.0.0.1:1", "--serve", "127.0.0.1:0", "--journal-fsync"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let err = cmd_leader(&args).unwrap_err();
        assert!(err.to_string().contains("--journal-fsync needs --journal"), "{err}");
    }

    fn leader_args(extra: &[&str]) -> Vec<String> {
        ["--sites", "127.0.0.1:1", "--serve", "127.0.0.1:0"]
            .iter()
            .chain(extra)
            .map(|s| s.to_string())
            .collect()
    }

    /// Every standby misconfiguration fails offline, before any socket
    /// is touched — the validation order is part of the CLI contract.
    #[test]
    fn standby_flags_validated() {
        // standby is a warm *server* mode: it needs a --serve address to
        // promote onto
        let args: Vec<String> =
            ["--sites", "127.0.0.1:1", "--standby"].iter().map(|s| s.to_string()).collect();
        let err = cmd_leader(&args).unwrap_err();
        assert!(err.to_string().contains("--standby needs --serve"), "{err}");

        // no primary to replicate from
        let err = cmd_leader(&leader_args(&["--standby", "--journal", "/tmp/dsc-s.j"]))
            .unwrap_err();
        assert!(err.to_string().contains("--primary"), "{err}");

        // no journal to replicate into
        let err = cmd_leader(&leader_args(&["--standby", "--primary", "127.0.0.1:9"]))
            .unwrap_err();
        assert!(err.to_string().contains("--journal"), "{err}");

        // replication knobs without --standby are a loud error, not a no-op
        let err = cmd_leader(&leader_args(&["--primary", "127.0.0.1:9"])).unwrap_err();
        assert!(err.to_string().contains("--primary only makes sense"), "{err}");
        let err = cmd_leader(&leader_args(&["--standby-timeout", "5"])).unwrap_err();
        assert!(err.to_string().contains("--standby-timeout only makes sense"), "{err}");

        // the promotion deadline must be a positive duration
        for bad in ["0", "-3", "inf"] {
            let err = cmd_leader(&leader_args(&[
                "--standby", "--primary", "127.0.0.1:9", "--journal", "/tmp/dsc-s.j",
                "--standby-timeout", bad,
            ]))
            .unwrap_err();
            assert!(err.to_string().contains("--standby-timeout"), "{bad}: {err}");
        }
    }

    #[test]
    fn submit_rejects_an_empty_leader_list() {
        let args: Vec<String> =
            ["--leader", ",,"].iter().map(|s| s.to_string()).collect();
        let err = cmd_submit(&args).unwrap_err();
        assert!(err.to_string().contains("at least one address"), "{err}");
    }

    #[test]
    fn fair_queue_is_a_bool_flag() {
        let f = flags(&["--fair-queue"]);
        assert!(f.bool("fair-queue"));
        assert!(!flags(&[]).bool("fair-queue"));
    }

    /// --priority is validated before the client dials the leader, so a
    /// bad value fails fast and offline.
    #[test]
    fn submit_priority_validated_offline() {
        for bad in ["0", "17"] {
            let args: Vec<String> = ["--leader", "127.0.0.1:1", "--priority", bad]
                .iter()
                .map(|s| s.to_string())
                .collect();
            let err = cmd_submit(&args).unwrap_err();
            assert!(err.to_string().contains("--priority"), "{err}");
        }
    }

    #[test]
    fn addr_salt_is_deterministic_and_distinct() {
        assert_eq!(addr_salt("10.0.0.2:7010"), addr_salt("10.0.0.2:7010"));
        assert_ne!(addr_salt("10.0.0.2:7010"), addr_salt("10.0.0.3:7010"));
    }

    #[test]
    fn run_rejects_tcp_transport_configs() {
        let path = std::env::temp_dir().join(format!("dsc_cli_tcp_{}.toml", std::process::id()));
        std::fs::write(&path, "[net]\ntransport = \"tcp\"\nsites = \"127.0.0.1:1\"\n").unwrap();
        let err = cmd_run(&[
            "--dataset".to_string(),
            "iris".to_string(),
            "--config".to_string(),
            path.to_str().unwrap().to_string(),
        ])
        .unwrap_err();
        assert!(err.to_string().contains("dsc site"), "{err}");
        let _ = std::fs::remove_file(&path);
    }
}
