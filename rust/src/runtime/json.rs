//! Minimal JSON parser (offline stand-in for `serde_json`).
//!
//! Parses the artifact manifest emitted by `python/compile/aot.py`. Full
//! JSON value model (objects, arrays, strings with escapes, numbers,
//! booleans, null); no serialization beyond what the crate needs. Errors
//! carry byte offsets for debuggability.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 && x <= usize::MAX as f64 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// Parse a complete JSON document.
pub fn parse(text: &str) -> Result<Value> {
    let bytes = text.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != bytes.len() {
        bail!("trailing characters at offset {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected {:?} at offset {}", c as char, self.i);
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.keyword("true", Value::Bool(true)),
            Some(b'f') => self.keyword("false", Value::Bool(false)),
            Some(b'n') => self.keyword("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at offset {}", other.map(|c| c as char), self.i),
        }
    }

    fn keyword(&mut self, kw: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(kw.as_bytes()) {
            self.i += kw.len();
            Ok(v)
        } else {
            bail!("bad keyword at offset {}", self.i);
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Object(map));
                }
                _ => bail!("expected ',' or '}}' at offset {}", self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Array(items));
                }
                _ => bail!("expected ',' or ']' at offset {}", self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            // surrogate pairs unsupported (manifest is ASCII)
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => bail!("bad escape {:?}", other.map(|c| c as char)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        let num: f64 = txt.parse()?;
        Ok(Value::Num(num))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let doc = r#"{
          "format": "hlo-text/return-tuple",
          "embed_k": 8,
          "programs": [
            {"name": "embed_n256_d8", "kind": "embed", "n": 256, "d": 8,
             "params": [{"name": "cw", "shape": [256, 8], "dtype": "f32"}]}
          ]
        }"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("format").unwrap().as_str().unwrap(), "hlo-text/return-tuple");
        assert_eq!(v.get("embed_k").unwrap().as_usize().unwrap(), 8);
        let progs = v.get("programs").unwrap().as_array().unwrap();
        assert_eq!(progs.len(), 1);
        let shape = progs[0].get("params").unwrap().as_array().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_array()
            .unwrap();
        assert_eq!(shape[0].as_usize(), Some(256));
    }

    #[test]
    fn scalars_and_literals() {
        assert_eq!(parse("42").unwrap().as_f64(), Some(42.0));
        assert_eq!(parse("-1.5e2").unwrap().as_f64(), Some(-150.0));
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse(r#""a\nb""#).unwrap().as_str(), Some("a\nb"));
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap().as_str(), Some("A"));
    }

    #[test]
    fn error_cases() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("{}extra").is_err());
        assert!(parse("{'single': 1}").is_err());
    }

    #[test]
    fn nested_arrays() {
        let v = parse("[[1,2],[3]]").unwrap();
        let a = v.as_array().unwrap();
        assert_eq!(a[0].as_array().unwrap().len(), 2);
        assert_eq!(a[1].as_array().unwrap()[0].as_usize(), Some(3));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Value::Array(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::Object(BTreeMap::new()));
    }
}
