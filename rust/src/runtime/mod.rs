//! Runtime for the AOT-compiled XLA artifacts, behind the `xla` cargo
//! feature.
//!
//! This is the bridge to Layers 1+2 of the stack. `make artifacts` (python,
//! build time) lowers the spectral-embedding and Lloyd-step compute graphs —
//! with the Pallas kernels inlined — to HLO *text* under `artifacts/`, one
//! file per shape bucket, plus `manifest.json` describing the
//! parameter/output ABI. At run time this module:
//!
//! 1. parses the manifest ([`json`] — no serde offline);
//! 2. picks the smallest bucket that fits a request (`n` and `d` round up;
//!    extra rows carry weight 0, extra feature columns are zero — both are
//!    exact no-ops for the math, see `python/compile/model.py`);
//! 3. compiles the HLO with the PJRT CPU client on first use and caches
//!    the executable (compilation is milliseconds-to-seconds; steady-state
//!    calls are pure execution);
//! 4. pads inputs, executes, unpads outputs.
//!
//! ## Feature gating
//!
//! Manifest parsing and bucket selection ([`Artifacts`]) are pure Rust and
//! always compiled. The PJRT executor ([`XlaRuntime`]) has two builds:
//!
//! * **default (no `xla` feature)** — a fallback with the same API whose
//!   constructor returns an error, so `Backend::Xla`/`Backend::XlaFull`
//!   fail fast with a clear message while the pure-Rust eigensolver path
//!   (`linalg::eigen`, `Backend::Native`) serves every pipeline.
//! * **`--features xla`** — the real executor, compiled against the `xla`
//!   bindings (the workspace ships a compile-time stub; vendor the real
//!   bindings via `[patch]` to execute HLO — see README.md).
//!
//! HLO **text** is the interchange format because jax ≥ 0.5 serialized
//! protos carry 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids.

pub mod json;

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

/// One AOT program described by the manifest.
#[derive(Clone, Debug)]
pub struct ProgramSpec {
    pub name: String,
    pub kind: ProgramKind,
    pub file: PathBuf,
    /// Row bucket (codewords / points).
    pub n: usize,
    /// Feature bucket (embed) or embedding width (kstep).
    pub d: usize,
    /// Centroid bucket (kstep only).
    pub k: usize,
}

/// Which compute graph a program implements.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProgramKind {
    /// Spectral embedding of the codeword affinity.
    Embed,
    /// One Lloyd step over embedding rows.
    KStep,
}

/// Parsed manifest + artifact directory.
#[derive(Debug)]
pub struct Artifacts {
    pub dir: PathBuf,
    pub embed_k: usize,
    pub programs: Vec<ProgramSpec>,
}

impl Artifacts {
    /// Load `manifest.json` from `dir`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Artifacts> {
        let dir = dir.as_ref().to_path_buf();
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("read {} (run `make artifacts` first?)", mpath.display()))?;
        let doc = json::parse(&text).context("parse manifest.json")?;

        let format = doc.get("format").and_then(|v| v.as_str()).unwrap_or("");
        if format != "hlo-text/return-tuple" {
            bail!("unsupported artifact format {format:?}");
        }
        let embed_k = doc
            .get("embed_k")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow!("manifest missing embed_k"))?;

        let mut programs = Vec::new();
        for p in doc
            .get("programs")
            .and_then(|v| v.as_array())
            .ok_or_else(|| anyhow!("manifest missing programs"))?
        {
            let name = p
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("program missing name"))?
                .to_string();
            let kind = match p.get("kind").and_then(|v| v.as_str()) {
                Some("embed") => ProgramKind::Embed,
                Some("kstep") => ProgramKind::KStep,
                other => bail!("program {name}: unknown kind {other:?}"),
            };
            let file = dir.join(
                p.get("file")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| anyhow!("program {name}: missing file"))?,
            );
            if !file.exists() {
                bail!("artifact file missing: {}", file.display());
            }
            let n = p.get("n").and_then(|v| v.as_usize()).unwrap_or(0);
            let d = p.get("d").and_then(|v| v.as_usize()).unwrap_or(0);
            let k = p.get("k").and_then(|v| v.as_usize()).unwrap_or(0);
            programs.push(ProgramSpec { name, kind, file, n, d, k });
        }
        if programs.is_empty() {
            bail!("manifest lists no programs");
        }
        Ok(Artifacts { dir, embed_k, programs })
    }

    /// Smallest embed bucket with `n_bucket ≥ n` and `d_bucket ≥ d`.
    pub fn embed_bucket(&self, n: usize, d: usize) -> Option<&ProgramSpec> {
        self.programs
            .iter()
            .filter(|p| p.kind == ProgramKind::Embed && p.n >= n && p.d >= d)
            .min_by_key(|p| (p.n, p.d))
    }

    /// Smallest kstep bucket with `n_bucket ≥ n` (embedding width is fixed).
    pub fn kstep_bucket(&self, n: usize) -> Option<&ProgramSpec> {
        self.programs
            .iter()
            .filter(|p| p.kind == ProgramKind::KStep && p.n >= n)
            .min_by_key(|p| p.n)
    }
}

/// Output of the embed artifact (unpadded).
#[derive(Clone, Debug)]
pub struct EmbedOut {
    /// `n × embed_k` row-major eigenvectors of M (decreasing eigenvalue).
    pub evecs: Vec<f32>,
    pub evals: Vec<f32>,
    pub deg: Vec<f32>,
    pub k_cols: usize,
    /// Which bucket ran (for logging/benches).
    pub bucket: String,
}

/// Default artifact directory: `$DSC_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("DSC_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

// ─── PJRT executor (feature `xla`) ────────────────────────────────────────

#[cfg(feature = "xla")]
mod pjrt {
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::sync::Mutex;

    use anyhow::{anyhow, bail, Result};

    use super::{Artifacts, EmbedOut, ProgramSpec};

    /// PJRT executor with an executable cache.
    pub struct XlaRuntime {
        artifacts: Artifacts,
        client: xla::PjRtClient,
        cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    }

    impl XlaRuntime {
        /// Create a CPU PJRT client over the artifact directory.
        pub fn new(artifact_dir: impl AsRef<Path>) -> Result<XlaRuntime> {
            let artifacts = Artifacts::load(artifact_dir)?;
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e}"))?;
            Ok(XlaRuntime { artifacts, client, cache: Mutex::new(HashMap::new()) })
        }

        pub fn artifacts(&self) -> &Artifacts {
            &self.artifacts
        }

        fn executable(
            &self,
            spec: &ProgramSpec,
        ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
            {
                let cache = self.cache.lock().unwrap();
                if let Some(exe) = cache.get(&spec.name) {
                    return Ok(exe.clone());
                }
            }
            let path = spec
                .file
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 artifact path"))?;
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(|e| anyhow!("parse {}: {e}", spec.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e}", spec.name))?;
            let exe = std::sync::Arc::new(exe);
            self.cache.lock().unwrap().insert(spec.name.clone(), exe.clone());
            Ok(exe)
        }

        /// Number of compiled executables currently cached.
        pub fn cached_executables(&self) -> usize {
            self.cache.lock().unwrap().len()
        }

        /// Run the spectral-embedding artifact on `n = points.len()/dim`
        /// codewords. `weights` follow the padding convention (0 ⇒ pad row);
        /// real rows must have positive weight.
        pub fn embed(
            &self,
            points: &[f32],
            dim: usize,
            weights: &[f32],
            sigma: f32,
        ) -> Result<EmbedOut> {
            let n = weights.len();
            if points.len() != n * dim {
                bail!("points buffer {} != n {} × dim {}", points.len(), n, dim);
            }
            if n == 0 {
                bail!("embed of empty codeword set");
            }
            let spec = self
                .artifacts
                .embed_bucket(n, dim)
                .ok_or_else(|| anyhow!("no embed bucket fits n={n}, d={dim}"))?
                .clone();
            let exe = self.executable(&spec)?;

            // pad points (nb × db) and weights (nb)
            let (nb, db) = (spec.n, spec.d);
            let mut cw = vec![0.0f32; nb * db];
            for i in 0..n {
                cw[i * db..i * db + dim].copy_from_slice(&points[i * dim..(i + 1) * dim]);
            }
            let mut w = vec![0.0f32; nb];
            w[..n].copy_from_slice(weights);

            let cw_lit = xla::Literal::vec1(&cw)
                .reshape(&[nb as i64, db as i64])
                .map_err(|e| anyhow!("reshape cw: {e}"))?;
            let w_lit = xla::Literal::vec1(&w);
            let sigma_lit = xla::Literal::from(sigma);

            let result = exe
                .execute::<xla::Literal>(&[cw_lit, w_lit, sigma_lit])
                .map_err(|e| anyhow!("execute {}: {e}", spec.name))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch result: {e}"))?;
            let (evecs_l, evals_l, deg_l) =
                result.to_tuple3().map_err(|e| anyhow!("untuple: {e}"))?;

            let k_cols = self.artifacts.embed_k;
            let evecs_pad: Vec<f32> = evecs_l.to_vec().map_err(|e| anyhow!("evecs: {e}"))?;
            let evals: Vec<f32> = evals_l.to_vec().map_err(|e| anyhow!("evals: {e}"))?;
            let deg_pad: Vec<f32> = deg_l.to_vec().map_err(|e| anyhow!("deg: {e}"))?;

            // unpad rows
            let mut evecs = vec![0.0f32; n * k_cols];
            evecs.copy_from_slice(&evecs_pad[..n * k_cols]);
            let deg = deg_pad[..n].to_vec();
            Ok(EmbedOut { evecs, evals, deg, k_cols, bucket: spec.name.clone() })
        }

        /// Run one Lloyd step of the kstep artifact over `n` embedding rows
        /// (`d` must equal the artifact's embedding width). Returns
        /// `(new_centroids, assignment, shift, inertia)` unpadded.
        #[allow(clippy::type_complexity)]
        pub fn kmeans_step(
            &self,
            points: &[f32],
            d: usize,
            centroids: &[f32],
            k_active: usize,
        ) -> Result<(Vec<f32>, Vec<i32>, f32, f32)> {
            let n = points.len() / d;
            let spec = self
                .artifacts
                .kstep_bucket(n)
                .ok_or_else(|| anyhow!("no kstep bucket fits n={n}"))?
                .clone();
            if d != spec.d {
                bail!("kstep expects d={}, got {d}", spec.d);
            }
            if k_active > spec.k {
                bail!("kstep supports ≤ {} centroids, got {k_active}", spec.k);
            }
            if centroids.len() != k_active * d {
                bail!("centroid buffer size mismatch");
            }
            let exe = self.executable(&spec)?;

            let (nb, kb) = (spec.n, spec.k);
            let mut p = vec![0.0f32; nb * d];
            p[..n * d].copy_from_slice(points);
            let mut c = vec![0.0f32; kb * d];
            c[..k_active * d].copy_from_slice(centroids);
            // park inactive centroids far away so padding rows (pmask 0)
            // assign harmlessly and active points never pick them (cmask
            // also guards)
            for slot in c[k_active * d..].iter_mut() {
                *slot = 1e6;
            }
            let mut pmask = vec![0.0f32; nb];
            pmask[..n].fill(1.0);
            let mut cmask = vec![0.0f32; kb];
            cmask[..k_active].fill(1.0);

            let p_lit = xla::Literal::vec1(&p)
                .reshape(&[nb as i64, d as i64])
                .map_err(|e| anyhow!("reshape p: {e}"))?;
            let c_lit = xla::Literal::vec1(&c)
                .reshape(&[kb as i64, d as i64])
                .map_err(|e| anyhow!("reshape c: {e}"))?;
            let pm_lit = xla::Literal::vec1(&pmask);
            let cm_lit = xla::Literal::vec1(&cmask);

            let result = exe
                .execute::<xla::Literal>(&[p_lit, c_lit, pm_lit, cm_lit])
                .map_err(|e| anyhow!("execute {}: {e}", spec.name))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch result: {e}"))?;
            let (newc_l, idx_l, shift_l, inertia_l) =
                result.to_tuple4().map_err(|e| anyhow!("untuple: {e}"))?;

            let newc_pad: Vec<f32> = newc_l.to_vec().map_err(|e| anyhow!("new_c: {e}"))?;
            let idx_pad: Vec<i32> = idx_l.to_vec().map_err(|e| anyhow!("idx: {e}"))?;
            let shift: f32 = shift_l.get_first_element().map_err(|e| anyhow!("shift: {e}"))?;
            let inertia: f32 =
                inertia_l.get_first_element().map_err(|e| anyhow!("inertia: {e}"))?;

            Ok((newc_pad[..k_active * d].to_vec(), idx_pad[..n].to_vec(), shift, inertia))
        }
    }

    thread_local! {
        static RUNTIME_CACHE: std::cell::RefCell<HashMap<PathBuf, std::rc::Rc<XlaRuntime>>> =
            std::cell::RefCell::new(HashMap::new());
    }

    /// Thread-local shared runtime for `artifact_dir`.
    ///
    /// PJRT executables are not `Send`, so the cache is per-thread — which
    /// matches how the coordinator uses it (the leader thread owns the
    /// central step). Compiling an embed bucket costs ~1 s; with this cache
    /// a process running many pipelines (benches, sweeps, long-lived
    /// servers) pays it once per bucket instead of once per run.
    pub fn shared(artifact_dir: impl AsRef<Path>) -> Result<std::rc::Rc<XlaRuntime>> {
        let key = artifact_dir.as_ref().to_path_buf();
        RUNTIME_CACHE.with(|cache| {
            if let Some(rt) = cache.borrow().get(&key) {
                return Ok(rt.clone());
            }
            let rt = std::rc::Rc::new(XlaRuntime::new(&key)?);
            cache.borrow_mut().insert(key, rt.clone());
            Ok(rt)
        })
    }
}

#[cfg(feature = "xla")]
pub use pjrt::{shared, XlaRuntime};

// ─── fallback executor (default build, no `xla` feature) ──────────────────

/// Fallback `XlaRuntime` for builds without the `xla` feature: the API
/// matches the PJRT executor so callers compile unchanged, but construction
/// always fails — `Backend::Native` (the pure-Rust `linalg::eigen` path) is
/// the only central-step backend in this configuration.
#[cfg(not(feature = "xla"))]
pub struct XlaRuntime {
    _private: (),
}

#[cfg(not(feature = "xla"))]
impl XlaRuntime {
    /// Always errors: this build has no PJRT runtime. The artifact manifest
    /// is still validated first so a missing/corrupt artifact set is
    /// reported ahead of the feature problem.
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<XlaRuntime> {
        let _ = Artifacts::load(artifact_dir)?;
        bail!(
            "built without the `xla` feature: the PJRT runtime is unavailable \
             (use Backend::Native, or rebuild with `cargo build --features xla`)"
        );
    }

    /// Unreachable: no fallback runtime can be constructed.
    pub fn artifacts(&self) -> &Artifacts {
        unreachable!("fallback XlaRuntime cannot be constructed")
    }

    /// Always zero in the fallback build.
    pub fn cached_executables(&self) -> usize {
        0
    }

    /// Unreachable at runtime (construction fails); compiles so
    /// `Backend::Xla` call sites need no feature gates.
    pub fn embed(
        &self,
        _points: &[f32],
        _dim: usize,
        _weights: &[f32],
        _sigma: f32,
    ) -> Result<EmbedOut> {
        bail!("built without the `xla` feature")
    }

    /// Unreachable at runtime (construction fails); compiles so
    /// `Backend::XlaFull` call sites need no feature gates.
    #[allow(clippy::type_complexity)]
    pub fn kmeans_step(
        &self,
        _points: &[f32],
        _d: usize,
        _centroids: &[f32],
        _k_active: usize,
    ) -> Result<(Vec<f32>, Vec<i32>, f32, f32)> {
        bail!("built without the `xla` feature")
    }
}

/// Fallback `shared`: same signature as the PJRT variant, always errors.
#[cfg(not(feature = "xla"))]
pub fn shared(artifact_dir: impl AsRef<Path>) -> Result<std::rc::Rc<XlaRuntime>> {
    XlaRuntime::new(artifact_dir).map(std::rc::Rc::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Pure-manifest tests (no PJRT). Execution tests live in
    // rust/tests/runtime_exec.rs because they need artifacts on disk.

    fn fake_manifest(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        for f in ["embed_n256_d8.hlo.txt", "embed_n512_d16.hlo.txt", "kstep_n256_k8_d8.hlo.txt"] {
            std::fs::write(dir.join(f), "HloModule fake").unwrap();
        }
        std::fs::write(
            dir.join("manifest.json"),
            r#"{
              "format": "hlo-text/return-tuple",
              "embed_k": 8,
              "embed_iters": 150,
              "programs": [
                {"name":"embed_n256_d8","kind":"embed","file":"embed_n256_d8.hlo.txt","n":256,"d":8,"params":[],"outputs":[]},
                {"name":"embed_n512_d16","kind":"embed","file":"embed_n512_d16.hlo.txt","n":512,"d":16,"params":[],"outputs":[]},
                {"name":"kstep_n256_k8_d8","kind":"kstep","file":"kstep_n256_k8_d8.hlo.txt","n":256,"k":8,"d":8,"params":[],"outputs":[]}
              ]
            }"#,
        )
        .unwrap();
    }

    #[test]
    fn manifest_load_and_bucket_selection() {
        let dir = std::env::temp_dir().join(format!("dsc_rt_{}", std::process::id()));
        fake_manifest(&dir);
        let arts = Artifacts::load(&dir).unwrap();
        assert_eq!(arts.embed_k, 8);
        assert_eq!(arts.programs.len(), 3);

        let b = arts.embed_bucket(200, 5).unwrap();
        assert_eq!(b.name, "embed_n256_d8");
        let b = arts.embed_bucket(257, 8).unwrap();
        assert_eq!(b.name, "embed_n512_d16");
        let b = arts.embed_bucket(300, 12).unwrap();
        assert_eq!(b.name, "embed_n512_d16");
        assert!(arts.embed_bucket(1000, 8).is_none());
        assert!(arts.embed_bucket(256, 64).is_none());

        let k = arts.kstep_bucket(100).unwrap();
        assert_eq!(k.name, "kstep_n256_k8_d8");
        assert!(arts.kstep_bucket(1000).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_missing_file_rejected() {
        let dir = std::env::temp_dir().join(format!("dsc_rt2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"format":"hlo-text/return-tuple","embed_k":8,
                "programs":[{"name":"x","kind":"embed","file":"missing.hlo.txt","n":256,"d":8}]}"#,
        )
        .unwrap();
        assert!(Artifacts::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_bad_format_rejected() {
        let dir = std::env::temp_dir().join(format!("dsc_rt3_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"format":"protobuf","programs":[]}"#)
            .unwrap();
        assert!(Artifacts::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn fallback_runtime_reports_missing_feature() {
        let dir = std::env::temp_dir().join(format!("dsc_rt4_{}", std::process::id()));
        fake_manifest(&dir);
        let err = XlaRuntime::new(&dir).unwrap_err();
        assert!(format!("{err:#}").contains("xla"), "{err:#}");
        // a bad artifact dir is reported ahead of the feature problem
        let err = XlaRuntime::new(dir.join("nope")).unwrap_err();
        assert!(format!("{err:#}").contains("manifest.json"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
