//! Gaussian-mixture samplers, including the paper's synthetic benchmarks.
//!
//! §5.1 of the paper uses two mixtures:
//!
//! * **Fig. 5** — 2-D, 4 components at μ = (±2, ±2), Σ = `[[3,1],[1,3]]`;
//! * **Figs. 6–7** — 10-D, 4 components at μᵢ = 2.5·eᵢ (i = 1..4),
//!   Σᵢⱼ = ρ^{|i−j|} for ρ ∈ {0.1, 0.3, 0.6}; 40 000 points, compression
//!   40:1 (1000 codewords).
//!
//! Sampling with a general covariance goes through its Cholesky factor:
//! x = μ + L z with z ~ N(0, I).

use crate::linalg::{cholesky, Mat};
use crate::rng::Rng;

use super::Dataset;

/// Specification of one mixture component.
#[derive(Clone, Debug)]
pub struct Component {
    pub mean: Vec<f64>,
    /// Lower Cholesky factor of the covariance.
    pub chol: Mat,
    /// Mixing proportion (will be normalized across components).
    pub weight: f64,
}

impl Component {
    /// Component with an arbitrary SPD covariance matrix.
    pub fn new(mean: Vec<f64>, cov: &Mat, weight: f64) -> Self {
        assert_eq!(cov.rows, mean.len());
        Component { mean, chol: cholesky(cov), weight }
    }

    /// Component with isotropic covariance σ²·I.
    pub fn isotropic(mean: Vec<f64>, sigma: f64, weight: f64) -> Self {
        let d = mean.len();
        let mut l = Mat::zeros(d, d);
        for i in 0..d {
            l[(i, i)] = sigma;
        }
        Component { mean, chol: l, weight }
    }

    fn sample_into(&self, rng: &mut Rng, out: &mut [f32]) {
        let d = self.mean.len();
        debug_assert_eq!(out.len(), d);
        // z ~ N(0, I), x = mean + L z
        let z: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        for i in 0..d {
            let mut acc = self.mean[i];
            for j in 0..=i {
                acc += self.chol[(i, j)] * z[j];
            }
            out[i] = acc as f32;
        }
    }
}

/// Draw `n` labeled points from the mixture; the label of a point is its
/// component index (the paper's ground truth for the synthetic runs).
pub fn sample(name: &str, components: &[Component], n: usize, seed: u64) -> Dataset {
    assert!(!components.is_empty());
    let dim = components[0].mean.len();
    for c in components {
        assert_eq!(c.mean.len(), dim, "gmm: mixed dimensions");
    }
    let mut cum = Vec::with_capacity(components.len());
    let mut acc = 0.0;
    for c in components {
        assert!(c.weight >= 0.0);
        acc += c.weight;
        cum.push(acc);
    }
    assert!(acc > 0.0, "gmm: zero total weight");

    let mut rng = Rng::new(seed);
    let mut ds = Dataset::new(name, dim, components.len());
    ds.points.resize(n * dim, 0.0);
    ds.labels.resize(n, 0);
    let mut buf = vec![0.0f32; dim];
    for i in 0..n {
        let k = rng.discrete_cum(&cum);
        components[k].sample_into(&mut rng, &mut buf);
        ds.points[i * dim..(i + 1) * dim].copy_from_slice(&buf);
        ds.labels[i] = k as u16;
    }
    ds
}

/// The paper's Fig. 5 toy mixture: 2-D, means (±2, ±2), Σ = `[[3,1],[1,3]]`.
/// Component order: (2,2), (−2,−2), (−2,2), (2,−2) — matching the text.
pub fn paper_mixture_2d(n: usize, seed: u64) -> Dataset {
    let cov = Mat::from_rows(2, 2, &[3.0, 1.0, 1.0, 3.0]);
    let comps = vec![
        Component::new(vec![2.0, 2.0], &cov, 1.0),
        Component::new(vec![-2.0, -2.0], &cov, 1.0),
        Component::new(vec![-2.0, 2.0], &cov, 1.0),
        Component::new(vec![2.0, -2.0], &cov, 1.0),
    ];
    sample("gmm2d", &comps, n, seed)
}

/// The paper's Figs. 6–7 mixture: 10-D, 4 equally-weighted components with
/// μᵢ = 2.5·eᵢ and Σᵢⱼ = ρ^{|i−j|}.
pub fn paper_mixture_10d(n: usize, rho: f64, seed: u64) -> Dataset {
    let d = 10;
    let cov = Mat::from_fn(d, d, |i, j| rho.powi((i as i32 - j as i32).abs()));
    let comps: Vec<Component> = (0..4)
        .map(|k| {
            let mut mean = vec![0.0; d];
            mean[k] = 2.5;
            Component::new(mean, &cov, 1.0)
        })
        .collect();
    sample(&format!("gmm10d_rho{rho}"), &comps, n, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_has_right_shape_and_labels() {
        let ds = paper_mixture_2d(1000, 3);
        assert_eq!(ds.dim, 2);
        assert_eq!(ds.len(), 1000);
        assert_eq!(ds.n_classes, 4);
        assert!(ds.labels.iter().all(|&l| l < 4));
        // all four components show up with roughly equal mass
        let counts = ds.class_counts();
        for c in counts {
            assert!(c > 150, "component mass too low: {c}");
        }
    }

    #[test]
    fn component_means_recovered() {
        let ds = paper_mixture_2d(40_000, 5);
        let counts = ds.class_counts();
        let mut sums = [[0.0f64; 2]; 4];
        for i in 0..ds.len() {
            let l = ds.labels[i] as usize;
            sums[l][0] += ds.point(i)[0] as f64;
            sums[l][1] += ds.point(i)[1] as f64;
        }
        let want = [[2.0, 2.0], [-2.0, -2.0], [-2.0, 2.0], [2.0, -2.0]];
        for k in 0..4 {
            let mx = sums[k][0] / counts[k] as f64;
            let my = sums[k][1] / counts[k] as f64;
            assert!((mx - want[k][0]).abs() < 0.1, "mean x of comp {k}: {mx}");
            assert!((my - want[k][1]).abs() < 0.1, "mean y of comp {k}: {my}");
        }
    }

    #[test]
    fn covariance_structure_10d() {
        let rho = 0.6;
        let ds = paper_mixture_10d(60_000, rho, 9);
        // pool component 0 and estimate cov of adjacent coords 5,6 (mean 0
        // for both in that component)
        let idx = ds.class_indices(0);
        let mut c55 = 0.0f64;
        let mut c56 = 0.0f64;
        for &i in &idx {
            let p = ds.point(i);
            c55 += (p[5] as f64) * (p[5] as f64);
            c56 += (p[5] as f64) * (p[6] as f64);
        }
        c55 /= idx.len() as f64;
        c56 /= idx.len() as f64;
        assert!((c55 - 1.0).abs() < 0.06, "var {c55}");
        assert!((c56 - rho).abs() < 0.06, "cov {c56}");
    }

    #[test]
    fn deterministic_in_seed() {
        let a = paper_mixture_10d(100, 0.3, 42);
        let b = paper_mixture_10d(100, 0.3, 42);
        assert_eq!(a.points, b.points);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn weights_respected() {
        let comps = vec![
            Component::isotropic(vec![0.0], 1.0, 9.0),
            Component::isotropic(vec![10.0], 1.0, 1.0),
        ];
        let ds = sample("w", &comps, 20_000, 1);
        let counts = ds.class_counts();
        let frac = counts[1] as f64 / 20_000.0;
        assert!((frac - 0.1).abs() < 0.02, "frac {frac}");
    }
}
