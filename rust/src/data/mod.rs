//! Dataset substrate: in-memory labeled point sets, generators, splitters.
//!
//! * [`Dataset`] — flat row-major `f32` points with ground-truth labels
//!   (labels are used only for *evaluation*, exactly as in the paper's
//!   clustering-accuracy metric).
//! * [`gmm`] — Gaussian-mixture samplers, including the paper's two
//!   synthetic benchmarks (§5.1): the 2-D 4-component mixture of Fig. 5 and
//!   the 10-D mixture with Σᵢⱼ = ρ^{|i−j|} of Figs. 6–7.
//! * [`uci_proxy`] — synthetic stand-ins for the eight UC Irvine datasets
//!   of Table 1 (the real files are not available offline; see DESIGN.md §5
//!   for the substitution argument).
//! * [`scenario`] — the D1/D2/D3 distributed-site splits of Tables 2 and 5.
//! * [`csvio`] — tiny CSV reader/writer for external data and bench dumps.
//! * [`iris`] — the classic Fisher Iris table embedded for the end-to-end
//!   example (a real, labeled, small dataset).

pub mod csvio;
pub mod gmm;
pub mod iris;
pub mod scenario;
pub mod uci_proxy;

/// A labeled point set. Points are row-major `n × dim` `f32` (the pipeline
/// storage type — matches the AOT artifacts' dtype).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub dim: usize,
    /// `n * dim` row-major coordinates.
    pub points: Vec<f32>,
    /// Ground-truth class per point, `0..n_classes`. Evaluation only.
    pub labels: Vec<u16>,
    pub n_classes: usize,
}

impl Dataset {
    pub fn new(name: impl Into<String>, dim: usize, n_classes: usize) -> Self {
        Dataset { name: name.into(), dim, points: Vec::new(), labels: Vec::new(), n_classes }
    }

    /// Number of points.
    #[inline]
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Borrow point `i` as a `dim`-length slice.
    #[inline]
    pub fn point(&self, i: usize) -> &[f32] {
        &self.points[i * self.dim..(i + 1) * self.dim]
    }

    /// Append one labeled point.
    pub fn push(&mut self, coords: &[f32], label: u16) {
        debug_assert_eq!(coords.len(), self.dim);
        self.points.extend_from_slice(coords);
        self.labels.push(label);
    }

    /// Bytes a full-data transmission would cost (f32 coords + u16 label):
    /// the paper's communication baseline.
    pub fn wire_bytes(&self) -> u64 {
        (self.points.len() * 4 + self.labels.len() * 2) as u64
    }

    /// Per-class point counts.
    pub fn class_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.n_classes];
        for &l in &self.labels {
            counts[l as usize] += 1;
        }
        counts
    }

    /// Indices of every point of class `c`.
    pub fn class_indices(&self, c: u16) -> Vec<usize> {
        (0..self.len()).filter(|&i| self.labels[i] == c).collect()
    }

    /// New dataset from a subset of indices (order preserved).
    pub fn select(&self, idx: &[usize]) -> Dataset {
        let mut out = Dataset::new(self.name.clone(), self.dim, self.n_classes);
        out.points.reserve(idx.len() * self.dim);
        out.labels.reserve(idx.len());
        for &i in idx {
            out.points.extend_from_slice(self.point(i));
            out.labels.push(self.labels[i]);
        }
        out
    }

    /// Standardize every feature to mean 0 / sd 1 in place (the paper does
    /// this to Connect-4, USCI, Gas Sensor and the first 10 Cover Type
    /// features). Constant features are left centered.
    pub fn standardize(&mut self) {
        let n = self.len();
        if n == 0 {
            return;
        }
        for j in 0..self.dim {
            let mut mean = 0.0f64;
            for i in 0..n {
                mean += self.points[i * self.dim + j] as f64;
            }
            mean /= n as f64;
            let mut var = 0.0f64;
            for i in 0..n {
                let d = self.points[i * self.dim + j] as f64 - mean;
                var += d * d;
            }
            var /= n as f64;
            let sd = var.sqrt();
            let inv = if sd > 1e-12 { 1.0 / sd } else { 1.0 };
            for i in 0..n {
                let v = &mut self.points[i * self.dim + j];
                *v = ((*v as f64 - mean) * inv) as f32;
            }
        }
    }

    /// Deterministic subsample of `k` points (for scaled-down runs).
    pub fn subsample(&self, k: usize, seed: u64) -> Dataset {
        if k >= self.len() {
            return self.clone();
        }
        let mut rng = crate::rng::Rng::new(seed);
        let mut idx: Vec<usize> = (0..self.len()).collect();
        rng.shuffle(&mut idx);
        idx.truncate(k);
        idx.sort_unstable();
        self.select(&idx)
    }

    /// Concatenate datasets with identical schema.
    pub fn concat(parts: &[&Dataset]) -> Dataset {
        assert!(!parts.is_empty());
        let d0 = parts[0];
        let mut out = Dataset::new(d0.name.clone(), d0.dim, d0.n_classes);
        for p in parts {
            assert_eq!(p.dim, d0.dim, "concat: dim mismatch");
            assert_eq!(p.n_classes, d0.n_classes, "concat: class-count mismatch");
            out.points.extend_from_slice(&p.points);
            out.labels.extend_from_slice(&p.labels);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        let mut d = Dataset::new("toy", 2, 2);
        d.push(&[0.0, 1.0], 0);
        d.push(&[2.0, 3.0], 1);
        d.push(&[4.0, 5.0], 0);
        d
    }

    #[test]
    fn push_and_index() {
        let d = toy();
        assert_eq!(d.len(), 3);
        assert_eq!(d.point(1), &[2.0, 3.0]);
        assert_eq!(d.labels, vec![0, 1, 0]);
    }

    #[test]
    fn class_counts_and_indices() {
        let d = toy();
        assert_eq!(d.class_counts(), vec![2, 1]);
        assert_eq!(d.class_indices(0), vec![0, 2]);
    }

    #[test]
    fn select_preserves_order() {
        let d = toy();
        let s = d.select(&[2, 0]);
        assert_eq!(s.point(0), &[4.0, 5.0]);
        assert_eq!(s.labels, vec![0, 0]);
    }

    #[test]
    fn standardize_zero_mean_unit_var() {
        let mut d = Dataset::new("s", 1, 1);
        for v in [1.0f32, 2.0, 3.0, 4.0, 5.0] {
            d.push(&[v], 0);
        }
        d.standardize();
        let mean: f32 = d.points.iter().sum::<f32>() / 5.0;
        let var: f32 = d.points.iter().map(|x| x * x).sum::<f32>() / 5.0;
        assert!(mean.abs() < 1e-6);
        assert!((var - 1.0).abs() < 1e-5);
    }

    #[test]
    fn subsample_size_and_determinism() {
        let mut d = Dataset::new("s", 1, 1);
        for i in 0..100 {
            d.push(&[i as f32], 0);
        }
        let a = d.subsample(10, 7);
        let b = d.subsample(10, 7);
        assert_eq!(a.points, b.points);
        assert_eq!(a.len(), 10);
        assert_ne!(a.points, d.subsample(10, 8).points);
    }

    #[test]
    fn concat_roundtrip() {
        let d = toy();
        let c = Dataset::concat(&[&d, &d]);
        assert_eq!(c.len(), 6);
        assert_eq!(c.point(4), d.point(1));
    }

    #[test]
    fn wire_bytes_counts_floats_and_labels() {
        let d = toy();
        assert_eq!(d.wire_bytes(), (3 * 2 * 4 + 3 * 2) as u64);
    }
}
