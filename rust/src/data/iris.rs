//! Fisher's Iris dataset, embedded (150 × 4, 3 classes).
//!
//! The one *real* labeled dataset shipped with the repo, used by the
//! end-to-end example to prove the full distributed pipeline on non-
//! synthetic data. Values are the canonical UCI `iris.data` table
//! (public domain); label 0 = setosa, 1 = versicolor, 2 = virginica.

use super::Dataset;

const IRIS_CSV: &str = include_str!("iris.csv");

/// Load the embedded Iris table.
pub fn load() -> Dataset {
    let mut ds = Dataset::new("iris", 4, 3);
    for (lineno, line) in IRIS_CSV.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let mut coords = [0.0f32; 4];
        let mut label = 0u16;
        for (k, tok) in line.split(',').enumerate() {
            if k < 4 {
                coords[k] = tok.parse().unwrap_or_else(|_| {
                    panic!("iris.csv line {}: bad float {tok:?}", lineno + 1)
                });
            } else {
                label = tok.parse().unwrap_or_else(|_| {
                    panic!("iris.csv line {}: bad label {tok:?}", lineno + 1)
                });
            }
        }
        ds.push(&coords, label);
    }
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_classes() {
        let ds = load();
        assert_eq!(ds.len(), 150);
        assert_eq!(ds.dim, 4);
        assert_eq!(ds.class_counts(), vec![50, 50, 50]);
    }

    #[test]
    fn known_rows() {
        let ds = load();
        assert_eq!(ds.point(0), &[5.1, 3.5, 1.4, 0.2]);
        assert_eq!(ds.labels[0], 0);
        assert_eq!(ds.point(50), &[7.0, 3.2, 4.7, 1.4]);
        assert_eq!(ds.labels[50], 1);
        assert_eq!(ds.point(149), &[5.9, 3.0, 5.1, 1.8]);
        assert_eq!(ds.labels[149], 2);
    }

    #[test]
    fn setosa_is_linearly_separated() {
        // petal length < 2.5 iff setosa — a structural property of the real
        // table that a typo would likely break.
        let ds = load();
        for i in 0..150 {
            let petal_len = ds.point(i)[2];
            assert_eq!(ds.labels[i] == 0, petal_len < 2.5, "row {i}");
        }
    }
}
