//! Distributed-site splits: the paper's D1/D2/D3 scenarios (Tables 2 & 5)
//! plus a size-skewed D4.
//!
//! These are *not* load-balancing splits — each models a way data ends up
//! distributed in the wild. The full taxonomy, ordered by how adversarial
//! the partition is for a per-site compressor:
//!
//! * **D1 — disjoint class supports** (paper §5.1): every class lives
//!   (almost) entirely at one site, e.g. hospitals that each see only a
//!   regional disease mix. The hardest case for any *local* method — no
//!   site can see the global cluster structure — and the paper's headline
//!   result is that codeword union + central spectral step recovers it.
//! * **D2 — overlapping class supports** (paper §5.1): classes are spread
//!   unevenly across sites (e.g. 70%/30%), the common "related but
//!   non-identical branches" regime.
//! * **D3 — i.i.d. split** (paper §5.1): every site is a uniform random
//!   sample of the full distribution, the shard-for-throughput regime; the
//!   easiest case and the baseline the others are compared against.
//! * **D4 — size-skewed i.i.d. split** (beyond the paper): like D3 each
//!   site draws from the full distribution, but site sizes decay
//!   geometrically — site `s` holds a share ∝ 2^{-(s+1)}, normalized so
//!   the shares sum to 1 (2 sites: 2/3 and 1/3; 3 sites: 4/7, 2/7, 1/7).
//!   This models hub-and-spoke deployments — one big datacenter plus
//!   small edge sites — and stresses the proportional codeword-budget
//!   split and the max-over-sites elapsed model rather than the
//!   clustering itself.
//!
//! A split is expressed as a *site-fraction matrix* `frac[s][c]` — the
//! fraction of class `c`'s points that go to site `s` (columns sum to 1) —
//! and realized by [`split_by_fractions`], which shuffles each class once
//! and deals out contiguous runs. [`split`] builds the paper's exact
//! configurations for 2 sites (Table 2) and the HEPMASS 3/4-site variants
//! (Table 5); [`fractions`] exposes the matrices themselves.

use crate::rng::Rng;

use super::Dataset;

/// Distributed-data scenario (see the module docs for the full taxonomy).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Scenario {
    /// Disjoint class supports per site (paper, Table 2).
    D1,
    /// Overlapping class supports (paper, Table 2).
    D2,
    /// Random uniform split (paper, Table 2).
    D3,
    /// Size-skewed random split: geometric site sizes, same class mix
    /// everywhere (beyond the paper; hub-and-spoke deployments).
    D4,
}

impl Scenario {
    pub fn parse(s: &str) -> Option<Scenario> {
        match s.to_ascii_lowercase().as_str() {
            "d1" => Some(Scenario::D1),
            "d2" => Some(Scenario::D2),
            "d3" => Some(Scenario::D3),
            "d4" => Some(Scenario::D4),
            _ => None,
        }
    }
}

impl std::fmt::Display for Scenario {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Scenario::D1 => write!(f, "D1"),
            Scenario::D2 => write!(f, "D2"),
            Scenario::D3 => write!(f, "D3"),
            Scenario::D4 => write!(f, "D4"),
        }
    }
}

/// One site's share of the data, with the provenance needed to evaluate the
/// recovered labels globally.
#[derive(Clone, Debug)]
pub struct SitePart {
    pub site_id: usize,
    pub data: Dataset,
    /// For every local point, its index in the original full dataset.
    pub global_idx: Vec<u32>,
}

/// Split `ds` according to an explicit site-fraction matrix
/// (`frac[s][c]` = share of class `c` at site `s`; columns must sum to ≤ 1,
/// any remainder goes to the last site).
pub fn split_by_fractions(ds: &Dataset, frac: &[Vec<f64>], seed: u64) -> Vec<SitePart> {
    let n_sites = frac.len();
    assert!(n_sites >= 1);
    for row in frac {
        assert_eq!(row.len(), ds.n_classes, "fraction row arity != n_classes");
    }
    for c in 0..ds.n_classes {
        let col: f64 = frac.iter().map(|r| r[c]).sum();
        assert!(col <= 1.0 + 1e-9, "class {c} oversubscribed: {col}");
    }

    let mut rng = Rng::new(seed);
    let mut site_indices: Vec<Vec<usize>> = vec![Vec::new(); n_sites];

    for c in 0..ds.n_classes {
        let mut idx = ds.class_indices(c as u16);
        rng.shuffle(&mut idx);
        let total = idx.len();
        let mut cursor = 0usize;
        for (s, row) in frac.iter().enumerate() {
            let want = if s + 1 == n_sites {
                total - cursor // absorb rounding remainder
            } else {
                ((row[c] * total as f64).round() as usize).min(total - cursor)
            };
            site_indices[s].extend_from_slice(&idx[cursor..cursor + want]);
            cursor += want;
        }
    }

    site_indices
        .into_iter()
        .enumerate()
        .map(|(s, mut idx)| {
            idx.sort_unstable(); // stable point order within a site
            let data = ds.select(&idx);
            SitePart {
                site_id: s,
                data,
                global_idx: idx.into_iter().map(|i| i as u32).collect(),
            }
        })
        .collect()
}

/// The paper's site-fraction matrix for `scenario` with `n_sites` sites over
/// a dataset with `n_classes` classes (Tables 2 and 5).
pub fn fractions(scenario: Scenario, n_sites: usize, n_classes: usize) -> Vec<Vec<f64>> {
    assert!(n_sites >= 2, "need at least two sites");
    match scenario {
        // Every site a random 1/S sample, any class structure.
        Scenario::D3 => vec![vec![1.0 / n_sites as f64; n_classes]; n_sites],

        // Size-skewed i.i.d. split: site s holds a share ∝ 2^{-(s+1)} of
        // every class (normalized so the shares sum to 1), so site 0 is the
        // "datacenter" and later sites are progressively smaller "edges".
        Scenario::D4 => {
            let raw: Vec<f64> = (0..n_sites).map(|s| 0.5f64.powi(s as i32 + 1)).collect();
            let total: f64 = raw.iter().sum();
            raw.into_iter().map(|w| vec![w / total; n_classes]).collect()
        }

        Scenario::D1 => match (n_sites, n_classes) {
            // Site1: C1, Site2: C2 (2 classes)
            (2, 2) => vec![vec![1.0, 0.0], vec![0.0, 1.0]],
            // Site1: C1, Site2: C2+C3 (3 classes — Connect-4 / HT / Poker)
            (2, 3) => vec![vec![1.0, 0.0, 0.0], vec![0.0, 1.0, 1.0]],
            // Cover Type row of Table 2: Site1: C2, Site2: C1 + C3–C5
            (2, 5) => vec![
                vec![0.0, 1.0, 0.0, 0.0, 0.0],
                vec![1.0, 0.0, 1.0, 1.0, 1.0],
            ],
            // Table 5, 3 sites, 2 classes: C1/2 | C1/2 | C2
            (3, 2) => vec![vec![0.5, 0.0], vec![0.5, 0.0], vec![0.0, 1.0]],
            // Table 5, 4 sites, 2 classes: C1/2 | C1/2 | C2/2 | C2/2
            (4, 2) => vec![
                vec![0.5, 0.0],
                vec![0.5, 0.0],
                vec![0.0, 0.5],
                vec![0.0, 0.5],
            ],
            // General fallback: classes dealt round-robin to sites whole.
            _ => {
                let mut f = vec![vec![0.0; n_classes]; n_sites];
                for c in 0..n_classes {
                    f[c % n_sites][c] = 1.0;
                }
                f
            }
        },

        Scenario::D2 => match (n_sites, n_classes) {
            // Site1: 0.7C1+0.3C2, Site2: 0.3C1+0.7C2
            (2, 2) => vec![vec![0.7, 0.3], vec![0.3, 0.7]],
            // Site1: 0.5C1 + C2, Site2: 0.5C1 + C3
            (2, 3) => vec![vec![0.5, 1.0, 0.0], vec![0.5, 0.0, 1.0]],
            // Cover Type: Site1: 0.7C1+0.3C2+C3–5, Site2: 0.3C1+0.7C2
            (2, 5) => vec![
                vec![0.7, 0.3, 1.0, 1.0, 1.0],
                vec![0.3, 0.7, 0.0, 0.0, 0.0],
            ],
            // Table 5, 3 sites: C1/2+C2/4 | C1/4+C2/4 | C1/4+C2/2
            (3, 2) => vec![vec![0.5, 0.25], vec![0.25, 0.25], vec![0.25, 0.5]],
            // Table 5, 4 sites: 3/8C1+1/8C2 ×2 | 1/8C1+3/8C2 ×2
            (4, 2) => vec![
                vec![0.375, 0.125],
                vec![0.375, 0.125],
                vec![0.125, 0.375],
                vec![0.125, 0.375],
            ],
            // General fallback: 70% of a "home" class + the rest spread.
            _ => {
                let mut f = vec![vec![0.0; n_classes]; n_sites];
                for c in 0..n_classes {
                    let home = c % n_sites;
                    for (s, row) in f.iter_mut().enumerate() {
                        row[c] = if s == home {
                            0.7
                        } else {
                            0.3 / (n_sites - 1) as f64
                        };
                    }
                }
                f
            }
        },
    }
}

/// Split `ds` across `n_sites` per the paper's `scenario` configuration.
pub fn split(ds: &Dataset, scenario: Scenario, n_sites: usize, seed: u64) -> Vec<SitePart> {
    let frac = fractions(scenario, n_sites, ds.n_classes);
    split_by_fractions(ds, &frac, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gmm;

    fn toy(n_classes: usize, per_class: usize) -> Dataset {
        let mut d = Dataset::new("toy", 1, n_classes);
        for c in 0..n_classes {
            for i in 0..per_class {
                d.push(&[(c * 1000 + i) as f32], c as u16);
            }
        }
        d
    }

    fn total_points(parts: &[SitePart]) -> usize {
        parts.iter().map(|p| p.data.len()).sum()
    }

    #[test]
    fn split_conserves_points_exactly() {
        let ds = toy(3, 997); // awkward size to stress rounding
        for sc in [Scenario::D1, Scenario::D2, Scenario::D3] {
            let parts = split(&ds, sc, 2, 7);
            assert_eq!(total_points(&parts), ds.len(), "{sc}");
            // global indices form a partition of 0..n
            let mut seen = vec![false; ds.len()];
            for p in &parts {
                for &g in &p.global_idx {
                    assert!(!seen[g as usize], "duplicate global index {g}");
                    seen[g as usize] = true;
                }
            }
            assert!(seen.iter().all(|&b| b));
        }
    }

    #[test]
    fn d1_two_class_is_disjoint() {
        let ds = toy(2, 500);
        let parts = split(&ds, Scenario::D1, 2, 3);
        assert!(parts[0].data.labels.iter().all(|&l| l == 0));
        assert!(parts[1].data.labels.iter().all(|&l| l == 1));
    }

    #[test]
    fn d2_two_class_has_paper_mix() {
        let ds = toy(2, 1000);
        let parts = split(&ds, Scenario::D2, 2, 3);
        let c = parts[0].data.class_counts();
        assert_eq!(c, vec![700, 300]);
        let c = parts[1].data.class_counts();
        assert_eq!(c, vec![300, 700]);
    }

    #[test]
    fn d1_three_class_follows_table2() {
        let ds = toy(3, 400);
        let parts = split(&ds, Scenario::D1, 2, 3);
        assert_eq!(parts[0].data.class_counts(), vec![400, 0, 0]);
        assert_eq!(parts[1].data.class_counts(), vec![0, 400, 400]);
    }

    #[test]
    fn d3_roughly_even() {
        let ds = gmm::paper_mixture_2d(10_000, 5);
        let parts = split(&ds, Scenario::D3, 2, 9);
        let n0 = parts[0].data.len() as f64;
        assert!((n0 / 10_000.0 - 0.5).abs() < 0.02, "{n0}");
    }

    #[test]
    fn hepmass_three_site_d2_matches_table5() {
        let ds = toy(2, 4000);
        let parts = split(&ds, Scenario::D2, 3, 1);
        assert_eq!(parts[0].data.class_counts(), vec![2000, 1000]);
        assert_eq!(parts[1].data.class_counts(), vec![1000, 1000]);
        assert_eq!(parts[2].data.class_counts(), vec![1000, 2000]);
    }

    #[test]
    fn four_site_d1_matches_table5() {
        let ds = toy(2, 1000);
        let parts = split(&ds, Scenario::D1, 4, 1);
        assert_eq!(parts[0].data.class_counts(), vec![500, 0]);
        assert_eq!(parts[1].data.class_counts(), vec![500, 0]);
        assert_eq!(parts[2].data.class_counts(), vec![0, 500]);
        assert_eq!(parts[3].data.class_counts(), vec![0, 500]);
    }

    #[test]
    fn global_idx_maps_back_to_same_coords() {
        let ds = gmm::paper_mixture_2d(2_000, 11);
        let parts = split(&ds, Scenario::D2, 2, 13);
        for p in &parts {
            for (local, &g) in p.global_idx.iter().enumerate() {
                assert_eq!(p.data.point(local), ds.point(g as usize));
                assert_eq!(p.data.labels[local], ds.labels[g as usize]);
            }
        }
    }

    #[test]
    fn d4_sizes_decay_geometrically_and_partition_exactly() {
        let ds = toy(3, 1000);
        let parts = split(&ds, Scenario::D4, 3, 7);
        assert_eq!(total_points(&parts), ds.len());
        // shares 4/7, 2/7, 1/7 of 3000 points (± rounding)
        let sizes: Vec<usize> = parts.iter().map(|p| p.data.len()).collect();
        assert!(sizes[0] > sizes[1] && sizes[1] > sizes[2], "{sizes:?}");
        assert!((sizes[0] as f64 - 3000.0 * 4.0 / 7.0).abs() < 30.0, "{sizes:?}");
        // class mix at every site follows the global (uniform) mix
        for p in &parts {
            let counts = p.data.class_counts();
            let n = p.data.len() as f64;
            for c in counts {
                assert!((c as f64 / n - 1.0 / 3.0).abs() < 0.05, "{sizes:?}");
            }
        }
    }

    #[test]
    fn seed_changes_assignment_but_not_counts() {
        let ds = toy(2, 1000);
        let a = split(&ds, Scenario::D2, 2, 1);
        let b = split(&ds, Scenario::D2, 2, 2);
        assert_eq!(a[0].data.class_counts(), b[0].data.class_counts());
        assert_ne!(a[0].global_idx, b[0].global_idx);
    }
}
