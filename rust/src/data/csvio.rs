//! Minimal CSV I/O for datasets and benchmark dumps.
//!
//! Format: one row per point, `dim` float columns followed by an integer
//! label column. No quoting/escaping — the data this pipeline touches is
//! purely numeric. Lines starting with `#` and blank lines are skipped on
//! read (benchmark dumps use `#` headers for provenance).

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Dataset;

/// Read a labeled dataset from `path`. `n_classes` is inferred as
/// `max(label) + 1` unless `n_classes_hint` is given.
pub fn load_dataset(path: &Path, name: &str, n_classes_hint: Option<usize>) -> Result<Dataset> {
    let file = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let reader = std::io::BufReader::new(file);

    let mut dim: Option<usize> = None;
    let mut points: Vec<f32> = Vec::new();
    let mut labels: Vec<u16> = Vec::new();

    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let toks: Vec<&str> = line.split(',').map(str::trim).collect();
        if toks.len() < 2 {
            bail!("{}:{}: need at least one feature + label", path.display(), lineno + 1);
        }
        let d = toks.len() - 1;
        match dim {
            None => dim = Some(d),
            Some(d0) if d0 != d => {
                bail!("{}:{}: ragged row ({} cols, expected {})", path.display(), lineno + 1, d, d0)
            }
            _ => {}
        }
        for tok in &toks[..d] {
            let v: f32 = tok
                .parse()
                .with_context(|| format!("{}:{}: bad float {tok:?}", path.display(), lineno + 1))?;
            points.push(v);
        }
        let label: u16 = toks[d]
            .parse()
            .with_context(|| format!("{}:{}: bad label {:?}", path.display(), lineno + 1, toks[d]))?;
        labels.push(label);
    }

    let dim = dim.context("empty csv")?;
    let n_classes =
        n_classes_hint.unwrap_or_else(|| labels.iter().map(|&l| l as usize + 1).max().unwrap_or(1));
    if let Some(&bad) = labels.iter().find(|&&l| (l as usize) >= n_classes) {
        bail!("label {bad} out of range for n_classes={n_classes}");
    }
    Ok(Dataset { name: name.to_string(), dim, points, labels, n_classes })
}

/// Create the parent directory of `path` if it does not exist yet (bench
/// dumps land under `bench_out/` before anything else creates it).
fn ensure_parent(path: &Path) {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent).ok();
        }
    }
}

/// Write a dataset as CSV (features…, label). `header` lines are emitted as
/// `# `-prefixed comments.
pub fn save_dataset(path: &Path, ds: &Dataset, header: &[&str]) -> Result<()> {
    ensure_parent(path);
    let file = std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(file);
    for h in header {
        writeln!(w, "# {h}")?;
    }
    for i in 0..ds.len() {
        let mut first = true;
        for v in ds.point(i) {
            if !first {
                write!(w, ",")?;
            }
            write!(w, "{v}")?;
            first = false;
        }
        writeln!(w, ",{}", ds.labels[i])?;
    }
    w.flush()?;
    Ok(())
}

/// Write an arbitrary numeric table (bench series dumps for plotting).
pub fn save_table(path: &Path, header: &[&str], columns: &[&str], rows: &[Vec<f64>]) -> Result<()> {
    ensure_parent(path);
    let file = std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(file);
    for h in header {
        writeln!(w, "# {h}")?;
    }
    writeln!(w, "{}", columns.join(","))?;
    for row in rows {
        let cells: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
        writeln!(w, "{}", cells.join(","))?;
    }
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("dsc_csv_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.csv");

        let mut ds = Dataset::new("rt", 3, 2);
        ds.push(&[1.5, -2.0, 0.25], 0);
        ds.push(&[0.0, 7.0, -1.0], 1);
        save_dataset(&path, &ds, &["roundtrip test"]).unwrap();

        let back = load_dataset(&path, "rt", None).unwrap();
        assert_eq!(back.dim, 3);
        assert_eq!(back.points, ds.points);
        assert_eq!(back.labels, ds.labels);
        assert_eq!(back.n_classes, 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn skips_comments_and_blank_lines() {
        let dir = std::env::temp_dir().join(format!("dsc_csv_test2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.csv");
        std::fs::write(&path, "# header\n\n1.0,2.0,0\n# mid comment\n3.0,4.0,1\n").unwrap();
        let ds = load_dataset(&path, "c", None).unwrap();
        assert_eq!(ds.len(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_ragged_rows() {
        let dir = std::env::temp_dir().join(format!("dsc_csv_test3_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("r.csv");
        std::fs::write(&path, "1.0,2.0,0\n1.0,0\n").unwrap();
        assert!(load_dataset(&path, "r", None).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_out_of_range_label() {
        let dir = std::env::temp_dir().join(format!("dsc_csv_test4_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("l.csv");
        std::fs::write(&path, "1.0,5\n").unwrap();
        assert!(load_dataset(&path, "l", Some(2)).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
