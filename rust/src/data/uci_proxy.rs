//! Synthetic proxies for the eight UC Irvine datasets of Table 1.
//!
//! The real files are not available in this offline environment, so each
//! dataset is replaced by a Gaussian-mixture generator matched on what the
//! paper's claims actually depend on (DESIGN.md §5):
//!
//! * dimension and number of classes (Table 1, after the paper's
//!   preprocessing — e.g. Cover Type drops classes 4–5, Poker merges the
//!   small hands into 3 classes);
//! * class proportions (they set the accuracy ceiling on the unbalanced
//!   sets — USCI's 0.94 is essentially its majority share);
//! * cluster separability, tuned via `sep` so the *non-distributed*
//!   spectral accuracy lands near the paper's Table 3 column 1 — the
//!   distributed-vs-local comparison (the actual claim) is then measured on
//!   the same geometry the paper had;
//! * the codeword budget: the paper's compression ratios imply a target
//!   number of representative points per dataset (`target_codewords`),
//!   which we keep fixed while the default point counts are scaled down
//!   (`default_n`) to laptop-bench size; `paper_n` restores full scale.
//!
//! Class `c`'s component is centred at `sep · e_c` with unit isotropic
//! covariance — the same geometry as the paper's own synthetic §5.1 model,
//! so Theorem 3's analysis applies verbatim.

use super::{gmm, Dataset};

/// Static description of one UCI dataset proxy.
#[derive(Clone, Debug)]
pub struct UciSpec {
    pub name: &'static str,
    pub dim: usize,
    pub n_classes: usize,
    /// Class proportions (sum 1), matching the paper's preprocessing notes.
    pub proportions: &'static [f64],
    /// Instance count in the paper (Table 1, after preprocessing).
    pub paper_n: usize,
    /// Paper's data-compression ratio for K-means DML (Table 3 text).
    pub paper_ratio: usize,
    /// Cluster separation of the proxy (see module docs).
    pub sep: f64,
    /// Paper's non-distributed accuracy, K-means DML (Table 3) — recorded
    /// for EXPERIMENTS.md comparison, not used by the generator.
    pub paper_acc_kmeans: f64,
    /// Same for rpTrees DML (Table 4).
    pub paper_acc_rptrees: f64,
}

impl UciSpec {
    /// Codeword budget the paper's compression ratio implies.
    pub fn target_codewords(&self) -> usize {
        self.paper_n.div_ceil(self.paper_ratio)
    }

    /// Default scaled-down instance count for laptop benches: keeps every
    /// dataset ≥ 40 points per codeword but caps the biggest runs.
    pub fn default_n(&self) -> usize {
        self.paper_n.min(40_000).max(self.target_codewords() * 20)
    }

    /// Generate the proxy at `n` points.
    pub fn generate(&self, n: usize, seed: u64) -> Dataset {
        let comps: Vec<gmm::Component> = (0..self.n_classes)
            .map(|c| {
                let mut mean = vec![0.0; self.dim];
                mean[c % self.dim] = self.sep;
                gmm::Component::isotropic(mean, 1.0, self.proportions[c])
            })
            .collect();
        let mut ds = gmm::sample(self.name, &comps, n, seed);
        ds.name = self.name.to_string();
        ds
    }
}

/// The eight datasets of Table 1, in paper order.
pub fn specs() -> &'static [UciSpec] {
    // Class proportions follow the paper's notes: Poker is merged to
    // 50.12/42.25/7.63; Cover Type keeps classes {2,1,3,7,6} of the original
    // (relabelled 0..4); USCI is the >50k/<=50k split; SkinSeg is the
    // skin/non-skin pixel ratio; Gas Sensor's two gas mixtures are roughly
    // even, as are HEPMASS signal/background and HT Sensor's stimuli.
    const SPECS: &[UciSpec] = &[
        UciSpec {
            name: "connect4",
            dim: 42,
            n_classes: 3,
            proportions: &[0.6565, 0.2460, 0.0975],
            paper_n: 67_557,
            paper_ratio: 200,
            sep: 1.35,
            paper_acc_kmeans: 0.6569,
            paper_acc_rptrees: 0.6577,
        },
        UciSpec {
            name: "skinseg",
            dim: 3,
            n_classes: 2,
            proportions: &[0.2075, 0.7925],
            paper_n: 245_057,
            paper_ratio: 800,
            sep: 2.4,
            paper_acc_kmeans: 0.9482,
            paper_acc_rptrees: 0.9492,
        },
        UciSpec {
            name: "usci",
            dim: 37,
            n_classes: 2,
            proportions: &[0.9380, 0.0620],
            paper_n: 285_779,
            paper_ratio: 500,
            sep: 2.0,
            paper_acc_kmeans: 0.9356,
            paper_acc_rptrees: 0.9394,
        },
        UciSpec {
            name: "covertype",
            dim: 54,
            n_classes: 5,
            proportions: &[0.4976, 0.3725, 0.0629, 0.0360, 0.0310],
            paper_n: 568_772,
            paper_ratio: 500,
            sep: 1.1,
            paper_acc_kmeans: 0.4984,
            paper_acc_rptrees: 0.4978,
        },
        UciSpec {
            name: "htsensor",
            dim: 11,
            n_classes: 3,
            proportions: &[0.3720, 0.3320, 0.2960],
            paper_n: 928_991,
            paper_ratio: 3000,
            sep: 0.8,
            paper_acc_kmeans: 0.4960,
            paper_acc_rptrees: 0.4957,
        },
        UciSpec {
            name: "pokerhand",
            dim: 10,
            n_classes: 3,
            proportions: &[0.5012, 0.4225, 0.0763],
            paper_n: 1_000_000,
            paper_ratio: 3000,
            sep: 0.65,
            paper_acc_kmeans: 0.4977,
            paper_acc_rptrees: 0.4990,
        },
        UciSpec {
            name: "gassensor",
            dim: 18,
            n_classes: 2,
            proportions: &[0.5320, 0.4680],
            paper_n: 8_386_765,
            paper_ratio: 16_000,
            sep: 3.6,
            paper_acc_kmeans: 0.9865,
            paper_acc_rptrees: 0.9828,
        },
        UciSpec {
            name: "hepmass",
            dim: 28,
            n_classes: 2,
            proportions: &[0.5, 0.5],
            paper_n: 10_500_000,
            paper_ratio: 7000,
            sep: 1.5,
            paper_acc_kmeans: 0.7929,
            paper_acc_rptrees: 0.7906,
        },
    ];
    SPECS
}

/// Look a spec up by (case-insensitive) name.
pub fn by_name(name: &str) -> Option<&'static UciSpec> {
    let lower = name.to_ascii_lowercase();
    specs().iter().find(|s| s.name == lower)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eight_specs_in_paper_order() {
        let names: Vec<&str> = specs().iter().map(|s| s.name).collect();
        assert_eq!(
            names,
            vec![
                "connect4", "skinseg", "usci", "covertype", "htsensor", "pokerhand",
                "gassensor", "hepmass"
            ]
        );
    }

    #[test]
    fn proportions_sum_to_one() {
        for s in specs() {
            let sum: f64 = s.proportions.iter().sum();
            assert!((sum - 1.0).abs() < 1e-3, "{}: {sum}", s.name);
            assert_eq!(s.proportions.len(), s.n_classes, "{}", s.name);
        }
    }

    #[test]
    fn target_codewords_match_paper_arithmetic() {
        // e.g. HEPMASS 10.5M / 7000 = 1500 representatives
        assert_eq!(by_name("hepmass").unwrap().target_codewords(), 1500);
        assert_eq!(by_name("connect4").unwrap().target_codewords(), 338);
        assert_eq!(by_name("skinseg").unwrap().target_codewords(), 307);
    }

    #[test]
    fn generate_matches_spec() {
        let s = by_name("htsensor").unwrap();
        let ds = s.generate(5_000, 3);
        assert_eq!(ds.dim, 11);
        assert_eq!(ds.n_classes, 3);
        assert_eq!(ds.len(), 5_000);
        let counts = ds.class_counts();
        for (c, &p) in counts.iter().zip(s.proportions) {
            let frac = *c as f64 / 5_000.0;
            assert!((frac - p).abs() < 0.05, "class fraction {frac} vs {p}");
        }
    }

    #[test]
    fn default_n_bounded() {
        for s in specs() {
            let n = s.default_n();
            assert!(n <= s.paper_n);
            assert!(n >= s.target_codewords() * 20, "{}: n={n} too small", s.name);
        }
    }

    #[test]
    fn unknown_name_is_none() {
        assert!(by_name("mnist").is_none());
        assert!(by_name("HEPMASS").is_some()); // case-insensitive
    }
}
