//! Structured-parallelism substrate (the offline stand-in for `rayon`).
//!
//! Everything is built on `std::thread::scope`, so closures may borrow from
//! the caller's stack — no `'static` bounds, no unsafe. Three primitives
//! cover the crate's needs:
//!
//! * [`par_map`] — run one closure per item on its own thread (bounded by
//!   [`threads`]); used for *sites*, which is exactly the parallelism the
//!   paper exploits ("local computation at individual nodes in parallel").
//! * [`par_chunks_mut`] — split an output slice into per-thread chunks and
//!   fill them concurrently; used by the K-means assignment hot loop and
//!   the native affinity builder.
//! * [`par_reduce_chunks`] — chunked map-reduce over an input slice.
//!
//! Thread count defaults to the machine's available parallelism and can be
//! pinned with `DSC_THREADS` (benchmarks use this for scaling curves).

use std::sync::OnceLock;

/// Number of worker threads to use for data-parallel loops.
pub fn threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        if let Ok(v) = std::env::var("DSC_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                if n >= 1 {
                    return n;
                }
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    })
}

/// Apply `f` to every item, each on its own scoped thread (at most
/// [`threads`] in flight), preserving input order in the output.
///
/// Intended for coarse tasks — e.g. one per distributed site. Panics in a
/// worker propagate to the caller.
pub fn par_map<I, O, F>(items: Vec<I>, f: F) -> Vec<O>
where
    I: Send,
    O: Send,
    F: Fn(usize, I) -> O + Sync,
{
    let max = threads().max(1);
    let mut out: Vec<Option<O>> = Vec::with_capacity(items.len());
    out.resize_with(items.len(), || None);

    // Process in waves of `max` to bound concurrency.
    let mut idx = 0usize;
    let mut items = items.into_iter();
    while idx < out.len() {
        let wave: Vec<(usize, I)> = (&mut items)
            .take(max)
            .enumerate()
            .map(|(k, it)| (idx + k, it))
            .collect();
        let wave_len = wave.len();
        std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(wave_len);
            for (i, item) in wave {
                let f = &f;
                handles.push(s.spawn(move || (i, f(i, item))));
            }
            for h in handles {
                let (i, v) = h.join().expect("par_map worker panicked");
                out[i] = Some(v);
            }
        });
        idx += wave_len;
    }
    out.into_iter().map(|o| o.expect("par_map slot unfilled")).collect()
}

/// Fill `out` in parallel: the slice is split into ~[`threads`] contiguous
/// chunks (each at least `min_chunk` long) and `f(start_index, chunk)` runs
/// on its own scoped thread.
pub fn par_chunks_mut<T, F>(out: &mut [T], min_chunk: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = out.len();
    if n == 0 {
        return;
    }
    let nthreads = threads().max(1);
    let chunk = (n.div_ceil(nthreads)).max(min_chunk.max(1));
    if chunk >= n {
        f(0, out);
        return;
    }
    std::thread::scope(|s| {
        let mut start = 0usize;
        let mut rest = out;
        let mut handles = Vec::new();
        while !rest.is_empty() {
            let take = chunk.min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            let f = &f;
            let begin = start;
            handles.push(s.spawn(move || f(begin, head)));
            start += take;
            rest = tail;
        }
        for h in handles {
            h.join().expect("par_chunks_mut worker panicked");
        }
    });
}

/// Row-aligned variant of [`par_chunks_mut`]: `out` is an `R × row_len`
/// row-major matrix; chunks always cover whole rows, and `f(first_row,
/// rows_slice)` receives a slice whose length is a multiple of `row_len`.
pub fn par_rows_mut<T, F>(out: &mut [T], row_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(row_len > 0);
    assert_eq!(out.len() % row_len, 0, "buffer not a whole number of rows");
    let n_rows = out.len() / row_len;
    if n_rows == 0 {
        return;
    }
    let nthreads = threads().max(1);
    let rows_per_chunk = n_rows.div_ceil(nthreads).max(1);
    if rows_per_chunk >= n_rows {
        f(0, out);
        return;
    }
    std::thread::scope(|s| {
        let mut first_row = 0usize;
        let mut rest = out;
        let mut handles = Vec::new();
        while !rest.is_empty() {
            let take_rows = rows_per_chunk.min(rest.len() / row_len);
            let (head, tail) = rest.split_at_mut(take_rows * row_len);
            let f = &f;
            let begin = first_row;
            handles.push(s.spawn(move || f(begin, head)));
            first_row += take_rows;
            rest = tail;
        }
        for h in handles {
            h.join().expect("par_rows_mut worker panicked");
        }
    });
}

/// Chunked map-reduce: `map(start, chunk) -> A`, combined left-to-right with
/// `reduce`. Chunk boundaries are deterministic for a fixed thread count, so
/// use an order-insensitive `reduce` (or pin `DSC_THREADS`) when exact
/// reproducibility across machines matters.
pub fn par_reduce_chunks<T, A, M, R>(xs: &[T], min_chunk: usize, map: M, reduce: R) -> Option<A>
where
    T: Sync,
    A: Send,
    M: Fn(usize, &[T]) -> A + Sync,
    R: Fn(A, A) -> A,
{
    let n = xs.len();
    if n == 0 {
        return None;
    }
    let nthreads = threads().max(1);
    let chunk = (n.div_ceil(nthreads)).max(min_chunk.max(1));
    if chunk >= n {
        return Some(map(0, xs));
    }
    let mut partials: Vec<A> = Vec::new();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        let mut start = 0usize;
        while start < n {
            let end = (start + chunk).min(n);
            let slice = &xs[start..end];
            let map = &map;
            let begin = start;
            handles.push(s.spawn(move || map(begin, slice)));
            start = end;
        }
        for h in handles {
            partials.push(h.join().expect("par_reduce worker panicked"));
        }
    });
    partials.into_iter().reduce(reduce)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<usize> = (0..37).collect();
        let out = par_map(items, |i, x| {
            assert_eq!(i, x);
            x * 2
        });
        assert_eq!(out, (0..37).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn par_chunks_mut_fills_everything() {
        let mut v = vec![0usize; 10_000];
        par_chunks_mut(&mut v, 16, |start, chunk| {
            for (k, slot) in chunk.iter_mut().enumerate() {
                *slot = start + k;
            }
        });
        assert!(v.iter().enumerate().all(|(i, &x)| i == x));
    }

    #[test]
    fn par_chunks_mut_small_input_single_thread() {
        let mut v = vec![1u8; 3];
        par_chunks_mut(&mut v, 64, |start, chunk| {
            assert_eq!(start, 0);
            assert_eq!(chunk.len(), 3);
            chunk.fill(9);
        });
        assert_eq!(v, vec![9, 9, 9]);
    }

    #[test]
    fn par_rows_mut_whole_rows_only() {
        let row_len = 7;
        let n_rows = 53;
        let mut m = vec![0usize; row_len * n_rows];
        par_rows_mut(&mut m, row_len, |first_row, rows| {
            assert_eq!(rows.len() % row_len, 0);
            for (r, row) in rows.chunks_exact_mut(row_len).enumerate() {
                row.fill(first_row + r);
            }
        });
        for (i, &v) in m.iter().enumerate() {
            assert_eq!(v, i / row_len);
        }
    }

    #[test]
    #[should_panic(expected = "whole number of rows")]
    fn par_rows_mut_rejects_ragged() {
        let mut m = vec![0u8; 10];
        par_rows_mut(&mut m, 3, |_, _| {});
    }

    #[test]
    fn par_reduce_sums() {
        let xs: Vec<u64> = (0..100_000).collect();
        let got = par_reduce_chunks(&xs, 1, |_, c| c.iter().sum::<u64>(), |a, b| a + b);
        assert_eq!(got, Some(4999950000));
    }

    #[test]
    fn par_reduce_empty_is_none() {
        let xs: Vec<u64> = vec![];
        assert_eq!(par_reduce_chunks(&xs, 1, |_, c| c.len(), |a, b| a + b), None);
    }

    #[test]
    fn threads_at_least_one() {
        assert!(threads() >= 1);
    }
}
