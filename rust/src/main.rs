//! `dsc` — leader entrypoint for distributed spectral clustering.
//!
//! See `dsc help` (or [`dsc::cli::USAGE`]) for the launcher surface. The
//! heavy lifting lives in the library crate; this binary is the thin
//! process shell around [`dsc::cli::dispatch`].

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dsc::cli::dispatch(argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
