//! Event-sourced run journal: the job server's crash-recovery log.
//!
//! Every state-changing reactor event — submits, site frames, central
//! results, client hangups, deadline ticks — is appended to this log
//! *before* it is applied (write-ahead order), so a leader that dies can
//! rebuild the exact reactor state by replaying the journal from the top:
//! the `JobQueue` (FIFO order or DRR lanes and deficits), every incomplete
//! [`super::machine::RunMachine`], token-bucket levels, the run-id counter
//! and the per-run byte counters. Budgets and forked seeds are pure
//! functions of `(JobSpec, site sizes)`, so a replayed run reproduces its
//! labels and `LinkStats` bit for bit (`rust/tests/journal_replay.rs`
//! sweeps a crash through every record index and asserts exactly that).
//!
//! ## On-disk format
//!
//! Little-endian, following the `net/wire.rs` framing discipline (bounded
//! allocation, explicit truncation errors — the journal is parsed with the
//! same [`Reader`] the wire codec uses):
//!
//! ```text
//! file    := magic:[u8; 8] record*          magic = "DSCJL001"
//! record  := len:u32 crc:u32 payload:[u8; len]
//! payload := t_ns:u64 kind:u8 body
//! ```
//!
//! `crc` is CRC-32 (IEEE) over `payload`; `t_ns` is the reactor clock at
//! append time, as nanoseconds since the journal's epoch (virtual time in
//! the channel harness, real time under TCP) — replay re-seeds clocks,
//! deadlines and token buckets from it. Kinds 1–9 are replayable reactor
//! events (8 marks a process restart, so link generations and run
//! restarts carry across crashes; 9 records a *local* send failure by its
//! send ordinal, so replay re-fails the identical send — see
//! `server.rs`); kinds ≥ 16 are **annotations** (queue
//! admissions/rejections,
//! run starts/completions) that replay skips but tests and operators use
//! as a durable record of scheduling decisions.
//!
//! ## Recovery rules
//!
//! [`recover`] distinguishes the two corruption shapes a crash can leave:
//!
//! * **Torn final record** — the file ends mid-record (the write that was
//!   in flight when the process died). Recovery is *clean*: every complete
//!   record before it is returned and [`Journal::open`] truncates the tail,
//!   exactly like a database WAL.
//! * **Corruption before the tail** — a complete record whose CRC does not
//!   match, an undecodable payload, or bad magic. That is not a torn write
//!   (torn writes are only ever at the end), so recovery fails *loudly*,
//!   naming the byte offset — silently dropping interior history would
//!   resurrect a wrong queue.
//!
//! Durability is batched: [`Journal::append`] writes into a buffer and
//! [`Journal::sync`] flushes (plus `fsync` when `[leader] journal_fsync`
//! is on) — frontends sync once per mailbox drain, not once per event, so
//! the hot path stays off the disk's critical path. The window this opens
//! (events acknowledged but not yet synced) is documented in
//! `docs/DEPLOY.md`.
//!
//! ## Poisoning
//!
//! When an append or sync fails mid-flight the leader keeps serving but
//! stops journaling — and the log on disk, a perfectly valid-looking
//! prefix of the history, must never be mistaken for the whole record on
//! a later restart. [`Journal::poison`] moves the file aside
//! (`<path>.poisoned`) and leaves a poison marker at the journal path, so
//! [`recover`] fails loudly ("journal was poisoned…") instead of silently
//! resurrecting a stale queue. Every step is best-effort (the disk is
//! already failing) and logged.

use std::fs::{self, File, OpenOptions};
use std::io::{BufWriter, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::net::wire::{self, Message, Reader, Writer};
use crate::net::JobSpec;

/// First 8 bytes of every journal file.
pub const MAGIC: [u8; 8] = *b"DSCJL001";

/// A complete record may not claim more than this many payload bytes —
/// far above any real frame (the wire codec's own element caps bound the
/// embedded frames), so a larger length is corruption, not data.
const MAX_RECORD: u32 = 1 << 30;

/// Smallest legal payload: `t_ns:u64 kind:u8` with an empty body.
const MIN_RECORD: u32 = 9;

/// The poison marker is a record header that can never be real: a length
/// no record may claim, paired with a fixed sentinel where the CRC goes.
/// [`Journal::poison`] writes it when journaling is disabled after a
/// write failure; [`recover`] refuses the file loudly on sight of it.
const POISON_LEN: u32 = u32::MAX;
const POISON_CRC: u32 = 0x504F_4953; // "POIS"

// Replayable reactor events.
const K_CLIENT_SUBMIT: u8 = 1;
const K_CLIENT_PULL: u8 = 2;
const K_CLIENT_DOWN: u8 = 3;
const K_SITE_FRAME: u8 = 4;
const K_SITE_DOWN: u8 = 5;
const K_CENTRAL_DONE: u8 = 6;
const K_TICK: u8 = 7;
const K_RESTART: u8 = 8;
const K_SEND_FAIL: u8 = 9;
// Annotations (skipped by state replay).
const K_ADMITTED: u8 = 16;
const K_REJECTED: u8 = 17;
const K_STARTED: u8 = 18;
const K_COMPLETED: u8 = 19;
const K_FAILED: u8 = 20;

/// One journaled happening. The first eight variants mirror the reactor's
/// mailbox events and are replayed; the rest are annotations — durable
/// breadcrumbs of scheduling decisions (what was admitted, in which order
/// the queue popped) that replay derives for itself and tests assert on.
#[derive(Clone, Debug, PartialEq)]
pub enum JournalEvent {
    /// A client submitted a job (the spec is embedded as its wire frame).
    ClientSubmit { client: u64, spec: JobSpec, modern: bool },
    /// A client asked for a completed run's labels.
    ClientPull { client: u64, run: u32 },
    /// A client connection ended.
    ClientDown { client: u64 },
    /// One frame arrived from a site link (stored verbatim).
    SiteFrame { site: usize, gen: u64, frame: Vec<u8> },
    /// A site link died.
    SiteDown { site: usize, gen: u64, err: String },
    /// A central worker delivered a run's spectral result.
    CentralDone { run: u32, result: std::result::Result<(Vec<u16>, f64), String>, elapsed_ns: u64 },
    /// A deadline tick reached the reactor.
    Tick,
    /// The leader process restarted here: every site link was freshly
    /// re-dialed (one incarnation past whatever the dead session left)
    /// and every incomplete run was restarted from scratch. Replay acts
    /// this out so records appended *after* a restart land on the same
    /// link generations and fresh run machines the restarted leader had —
    /// which is what keeps a twice-crashed journal replayable.
    Restart,
    /// A *local* send to a site link failed (TCP broken pipe, severed
    /// channel) while processing the record before this one. `seq` is the
    /// reactor's send ordinal — every outbound site frame increments it,
    /// and it resets to 0 at each `Restart` — so replay, whose puppet
    /// driver's sends otherwise always succeed, re-fails exactly this
    /// send and takes the link down at the identical point of the
    /// history. Written write-ahead of the takedown it triggers.
    SendFail { seq: u64, site: usize, err: String },
    /// Annotation: a submit was admitted to the queue as `run`.
    Admitted { run: u32, client: u64 },
    /// Annotation: a submit was refused.
    Rejected { client: u64 },
    /// Annotation: the queue popped `run` and the run started.
    Started { run: u32 },
    /// Annotation: `run` delivered labels and a JOBDONE.
    Completed { run: u32 },
    /// Annotation: `run` failed.
    Failed { run: u32 },
}

impl JournalEvent {
    /// Annotations are skipped when rebuilding reactor state.
    pub fn is_annotation(&self) -> bool {
        matches!(
            self,
            JournalEvent::Admitted { .. }
                | JournalEvent::Rejected { .. }
                | JournalEvent::Started { .. }
                | JournalEvent::Completed { .. }
                | JournalEvent::Failed { .. }
        )
    }
}

/// One decoded journal record: when (nanoseconds since the journal epoch,
/// on the reactor's clock) and what.
#[derive(Clone, Debug, PartialEq)]
pub struct Record {
    pub t_ns: u64,
    pub event: JournalEvent,
}

// ─── CRC-32 (IEEE) ─────────────────────────────────────────────────────────

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc32_table();

/// CRC-32 (IEEE 802.3, the zlib/PNG polynomial), hand-rolled — the crate
/// has no compression dependency to borrow one from.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

// ─── record codec ──────────────────────────────────────────────────────────

fn encode_payload(t_ns: u64, ev: &JournalEvent) -> Vec<u8> {
    let mut w = Writer::new();
    w.u64(t_ns);
    match ev {
        JournalEvent::ClientSubmit { client, spec, modern } => {
            w.u8(K_CLIENT_SUBMIT);
            w.u64(*client);
            w.u8(*modern as u8);
            // Embed the spec as its own wire frame: one codec, one set of
            // hostile-input bounds. Legacy SUBMIT cannot carry a priority,
            // so anything non-default rides the modern frame.
            let frame = if *modern || spec.priority != JobSpec::DEFAULT_PRIORITY {
                wire::encode(&Message::SubmitPri(spec.clone()))
            } else {
                wire::encode(&Message::Submit(spec.clone()))
            };
            w.u32(frame.len() as u32);
            w.buf.extend_from_slice(&frame);
        }
        JournalEvent::ClientPull { client, run } => {
            w.u8(K_CLIENT_PULL);
            w.u64(*client);
            w.u32(*run);
        }
        JournalEvent::ClientDown { client } => {
            w.u8(K_CLIENT_DOWN);
            w.u64(*client);
        }
        JournalEvent::SiteFrame { site, gen, frame } => {
            w.u8(K_SITE_FRAME);
            w.u32(*site as u32);
            w.u64(*gen);
            w.u32(frame.len() as u32);
            w.buf.extend_from_slice(frame);
        }
        JournalEvent::SiteDown { site, gen, err } => {
            w.u8(K_SITE_DOWN);
            w.u32(*site as u32);
            w.u64(*gen);
            let bytes = err.as_bytes();
            w.u32(bytes.len() as u32);
            w.buf.extend_from_slice(bytes);
        }
        JournalEvent::CentralDone { run, result, elapsed_ns } => {
            w.u8(K_CENTRAL_DONE);
            w.u32(*run);
            w.u64(*elapsed_ns);
            match result {
                Ok((labels, sigma)) => {
                    w.u8(1);
                    w.f64(*sigma);
                    w.u32(labels.len() as u32);
                    for l in labels {
                        w.u16(*l);
                    }
                }
                Err(e) => {
                    w.u8(0);
                    let bytes = e.as_bytes();
                    w.u32(bytes.len() as u32);
                    w.buf.extend_from_slice(bytes);
                }
            }
        }
        JournalEvent::Tick => w.u8(K_TICK),
        JournalEvent::Restart => w.u8(K_RESTART),
        JournalEvent::SendFail { seq, site, err } => {
            w.u8(K_SEND_FAIL);
            w.u64(*seq);
            w.u32(*site as u32);
            let bytes = err.as_bytes();
            w.u32(bytes.len() as u32);
            w.buf.extend_from_slice(bytes);
        }
        JournalEvent::Admitted { run, client } => {
            w.u8(K_ADMITTED);
            w.u32(*run);
            w.u64(*client);
        }
        JournalEvent::Rejected { client } => {
            w.u8(K_REJECTED);
            w.u64(*client);
        }
        JournalEvent::Started { run } => {
            w.u8(K_STARTED);
            w.u32(*run);
        }
        JournalEvent::Completed { run } => {
            w.u8(K_COMPLETED);
            w.u32(*run);
        }
        JournalEvent::Failed { run } => {
            w.u8(K_FAILED);
            w.u32(*run);
        }
    }
    w.buf
}

/// Build one complete on-disk frame — `len:u32 crc:u32 payload` — for a
/// record. This is the *only* serialization of a journal record in the
/// codebase: [`Journal::append`] writes exactly these bytes, and the
/// `JREPL` replication path (`net/wire.rs` tag 24) ships them to a warm
/// standby verbatim, so primary and standby journals are byte-identical
/// by construction.
pub(crate) fn frame_record(t_ns: u64, ev: &JournalEvent) -> Vec<u8> {
    let payload = encode_payload(t_ns, ev);
    let crc = crc32(&payload);
    let mut frame = Vec::with_capacity(8 + payload.len());
    frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    frame.extend_from_slice(&crc.to_le_bytes());
    frame.extend_from_slice(&payload);
    frame
}

/// Decode one complete frame as produced by [`frame_record`] (and as laid
/// out on disk): length header, CRC check, payload decode, no trailing
/// bytes. The standby validates every replicated record through this
/// before appending it to its own journal.
pub(crate) fn decode_framed(framed: &[u8]) -> Result<Record> {
    if framed.len() < 8 {
        bail!("framed journal record of {} bytes is shorter than its header", framed.len());
    }
    let len = u32::from_le_bytes(framed[..4].try_into().unwrap());
    let crc = u32::from_le_bytes(framed[4..8].try_into().unwrap());
    if len < MIN_RECORD || len > MAX_RECORD {
        bail!("framed journal record claims {len} payload bytes");
    }
    if framed.len() != 8 + len as usize {
        bail!(
            "framed journal record claims {len} payload bytes but carries {}",
            framed.len() - 8
        );
    }
    let payload = &framed[8..];
    if crc32(payload) != crc {
        bail!("framed journal record CRC mismatch");
    }
    decode_payload(payload)
}

/// Read back the valid prefix of a journal file as raw frames — each
/// element is one record's `len crc payload` bytes exactly as on disk —
/// plus the prefix length in bytes. Same recovery rules as [`recover`]
/// (torn tail tolerated, interior corruption and poisoning loud); the
/// primary uses this to stream catch-up history to a connecting standby
/// without re-encoding anything.
pub fn framed_records(path: &Path) -> Result<(Vec<Vec<u8>>, u64)> {
    let rec = recover(path)?;
    let buf = fs::read(path).with_context(|| format!("read journal {}", path.display()))?;
    let mut frames = Vec::with_capacity(rec.records.len());
    let mut pos = MAGIC.len();
    for _ in 0..rec.records.len() {
        let len = u32::from_le_bytes(buf[pos..pos + 4].try_into().unwrap()) as usize;
        frames.push(buf[pos..pos + 8 + len].to_vec());
        pos += 8 + len;
    }
    debug_assert_eq!(pos as u64, rec.valid_bytes.max(MAGIC.len() as u64));
    Ok((frames, rec.valid_bytes))
}

/// Refusal/error strings inside records stay short sentences; anything
/// larger is corruption (same posture as the wire codec's reject cap).
const MAX_TEXT: u32 = 64 * 1024;

fn take_text(r: &mut Reader, what: &str) -> Result<String> {
    let len = r.u32()?;
    if len > MAX_TEXT {
        bail!("{what} of {len} bytes");
    }
    match std::str::from_utf8(r.take(len as usize)?) {
        Ok(s) => Ok(s.to_string()),
        Err(_) => bail!("{what} is not UTF-8"),
    }
}

fn decode_payload(payload: &[u8]) -> Result<Record> {
    let mut r = Reader::new(payload);
    let t_ns = r.u64()?;
    let kind = r.u8()?;
    let event = match kind {
        K_CLIENT_SUBMIT => {
            let client = r.u64()?;
            let modern = match r.u8()? {
                0 => false,
                1 => true,
                o => bail!("submit modern flag must be 0 or 1, got {o}"),
            };
            let flen = r.u32()?;
            let frame = r.take(flen as usize)?;
            let spec = match wire::decode(frame)? {
                Message::Submit(spec) | Message::SubmitPri(spec) => spec,
                other => bail!("journaled submit embeds a non-submit frame {other:?}"),
            };
            JournalEvent::ClientSubmit { client, spec, modern }
        }
        K_CLIENT_PULL => {
            let client = r.u64()?;
            let run = r.u32()?;
            JournalEvent::ClientPull { client, run }
        }
        K_CLIENT_DOWN => JournalEvent::ClientDown { client: r.u64()? },
        K_SITE_FRAME => {
            let site = r.u32()? as usize;
            let gen = r.u64()?;
            let flen = r.u32()?;
            let frame = r.take(flen as usize)?.to_vec();
            JournalEvent::SiteFrame { site, gen, frame }
        }
        K_SITE_DOWN => {
            let site = r.u32()? as usize;
            let gen = r.u64()?;
            let err = take_text(&mut r, "site-down error")?;
            JournalEvent::SiteDown { site, gen, err }
        }
        K_CENTRAL_DONE => {
            let run = r.u32()?;
            let elapsed_ns = r.u64()?;
            let result = match r.u8()? {
                1 => {
                    let sigma = r.f64()?;
                    let n = r.u32()?;
                    // Allocation bounded by the bytes actually present,
                    // mirroring the wire codec's hostile-count posture.
                    let mut labels =
                        Vec::with_capacity((n as usize).min(r.remaining() / 2));
                    for _ in 0..n {
                        labels.push(r.u16()?);
                    }
                    Ok((labels, sigma))
                }
                0 => Err(take_text(&mut r, "central error")?),
                o => bail!("central result flag must be 0 or 1, got {o}"),
            };
            JournalEvent::CentralDone { run, result, elapsed_ns }
        }
        K_TICK => JournalEvent::Tick,
        K_RESTART => JournalEvent::Restart,
        K_SEND_FAIL => {
            let seq = r.u64()?;
            let site = r.u32()? as usize;
            let err = take_text(&mut r, "send-failure error")?;
            JournalEvent::SendFail { seq, site, err }
        }
        K_ADMITTED => {
            let run = r.u32()?;
            let client = r.u64()?;
            JournalEvent::Admitted { run, client }
        }
        K_REJECTED => JournalEvent::Rejected { client: r.u64()? },
        K_STARTED => JournalEvent::Started { run: r.u32()? },
        K_COMPLETED => JournalEvent::Completed { run: r.u32()? },
        K_FAILED => JournalEvent::Failed { run: r.u32()? },
        other => bail!("unknown journal record kind {other}"),
    };
    if !r.done() {
        bail!("trailing bytes in journal record");
    }
    Ok(Record { t_ns, event })
}

// ─── recovery ──────────────────────────────────────────────────────────────

/// What [`recover`] found in a journal file.
#[derive(Debug)]
pub struct Recovered {
    /// Every complete, CRC-valid record, in append order.
    pub records: Vec<Record>,
    /// Length of the valid prefix (magic + complete records) —
    /// [`Journal::open`] truncates the file here before appending.
    pub valid_bytes: u64,
    /// Whether a torn final record was discarded.
    pub torn: bool,
}

/// Parse a journal file. A torn *final* record (the write in flight when
/// the process died) is discarded cleanly; bad magic, a CRC mismatch, or
/// an undecodable record anywhere before the tail fails loudly, naming the
/// byte offset — see the module docs for why the two get opposite
/// treatment.
pub fn recover(path: &Path) -> Result<Recovered> {
    let buf = fs::read(path).with_context(|| format!("read journal {}", path.display()))?;
    if buf.is_empty() {
        return Ok(Recovered { records: Vec::new(), valid_bytes: 0, torn: false });
    }
    if buf.len() < MAGIC.len() {
        // A torn header write: shorter than the magic but a prefix of it
        // is clean (nothing was ever durably journaled); anything else is
        // a foreign file.
        if MAGIC.starts_with(&buf[..]) {
            return Ok(Recovered { records: Vec::new(), valid_bytes: 0, torn: true });
        }
        bail!("{}: bad journal magic at byte offset 0", path.display());
    }
    if buf[..MAGIC.len()] != MAGIC {
        bail!("{}: bad journal magic at byte offset 0", path.display());
    }
    let mut records = Vec::new();
    let mut pos = MAGIC.len();
    let mut torn = false;
    while pos < buf.len() {
        let remaining = buf.len() - pos;
        if remaining < 8 {
            torn = true; // record header cut short by the crash
            break;
        }
        let len = u32::from_le_bytes(buf[pos..pos + 4].try_into().unwrap());
        let crc = u32::from_le_bytes(buf[pos + 4..pos + 8].try_into().unwrap());
        if len == POISON_LEN && crc == POISON_CRC {
            bail!(
                "{}: journal was poisoned after an append/sync failure (marker at byte \
                 offset {pos}, after {} record(s)) — its history is incomplete; inspect \
                 {}.poisoned and remove both files to start fresh",
                path.display(),
                records.len(),
                path.display()
            );
        }
        if len < MIN_RECORD || len > MAX_RECORD {
            bail!(
                "{}: corrupt journal: record {} at byte offset {pos} claims {len} payload \
                 bytes",
                path.display(),
                records.len()
            );
        }
        if (remaining - 8) < len as usize {
            torn = true; // payload cut short by the crash
            break;
        }
        let payload = &buf[pos + 8..pos + 8 + len as usize];
        if crc32(payload) != crc {
            bail!(
                "{}: journal CRC mismatch in record {} at byte offset {pos}",
                path.display(),
                records.len()
            );
        }
        let record = decode_payload(payload).with_context(|| {
            format!(
                "{}: undecodable journal record {} at byte offset {pos}",
                path.display(),
                records.len()
            )
        })?;
        records.push(record);
        pos += 8 + len as usize;
    }
    Ok(Recovered { records, valid_bytes: pos as u64, torn })
}

// ─── the append handle ─────────────────────────────────────────────────────

/// An open journal positioned for appending. Writes are buffered;
/// [`Journal::sync`] is the durability point (frontends call it once per
/// mailbox drain — group commit).
pub struct Journal {
    w: BufWriter<File>,
    path: PathBuf,
    fsync: bool,
    records: u64,
    dirty: bool,
}

impl Journal {
    /// Open (or create) a journal for appending: recover the valid prefix,
    /// truncate any torn tail, and return the handle plus every recovered
    /// record. Interior corruption propagates [`recover`]'s loud error.
    pub fn open(path: &Path, fsync: bool) -> Result<(Journal, Vec<Record>)> {
        let rec = if path.exists() {
            recover(path)?
        } else {
            Recovered { records: Vec::new(), valid_bytes: 0, torn: false }
        };
        if rec.torn {
            eprintln!(
                "leader: journal {}: discarding a torn final record ({} complete record(s) \
                 kept)",
                path.display(),
                rec.records.len()
            );
        }
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .open(path)
            .with_context(|| format!("open journal {}", path.display()))?;
        if rec.valid_bytes < MAGIC.len() as u64 {
            file.set_len(0).context("truncate journal")?;
            file.seek(SeekFrom::Start(0))?;
            file.write_all(&MAGIC).context("write journal magic")?;
        } else {
            file.set_len(rec.valid_bytes).context("truncate torn journal tail")?;
            file.seek(SeekFrom::End(0))?;
        }
        let journal = Journal {
            w: BufWriter::new(file),
            path: path.to_path_buf(),
            fsync,
            records: rec.records.len() as u64,
            dirty: true, // the magic/truncation above is not yet synced
        };
        Ok((journal, rec.records))
    }

    /// Append one record; returns the record count after the append. The
    /// bytes are buffered — not durable until [`Journal::sync`].
    pub fn append(&mut self, t_ns: u64, event: &JournalEvent) -> Result<u64> {
        let frame = frame_record(t_ns, event);
        self.w.write_all(&frame)?;
        self.records += 1;
        self.dirty = true;
        Ok(self.records)
    }

    /// Append one already-framed record (`len crc payload`) verbatim,
    /// after validating it end to end with [`decode_framed`] — the standby
    /// side of journal replication, which must write the primary's exact
    /// bytes so the two files stay byte-identical. Returns the record
    /// count after the append; buffered like [`Journal::append`].
    pub fn append_framed(&mut self, framed: &[u8]) -> Result<(Record, u64)> {
        let record = decode_framed(framed).context("replicated journal record")?;
        self.w.write_all(framed)?;
        self.records += 1;
        self.dirty = true;
        Ok((record, self.records))
    }

    /// Flush buffered records (and `fsync` when configured). No-op when
    /// nothing was appended since the last sync, so frontends call it
    /// unconditionally before every blocking mailbox wait.
    pub fn sync(&mut self) -> Result<()> {
        if !self.dirty {
            return Ok(());
        }
        self.w.flush().with_context(|| format!("flush journal {}", self.path.display()))?;
        if self.fsync {
            self.w
                .get_ref()
                .sync_data()
                .with_context(|| format!("fsync journal {}", self.path.display()))?;
        }
        self.dirty = false;
        Ok(())
    }

    /// Records in the file (recovered + appended).
    pub fn records(&self) -> u64 {
        self.records
    }

    /// The file this journal appends to.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Render this log unrecoverable-by-accident — called when a frontend
    /// disables journaling after an append/sync failure. Without this the
    /// on-disk file is a valid-looking *prefix* of the history, and a
    /// later restart would replay it as if it were the whole record,
    /// silently resurrecting a stale queue. The file is moved aside to
    /// `<path>.poisoned` (forensics) and the journal path is left holding
    /// a poison marker, so [`recover`] — and with it `Journal::open` on
    /// the next restart — fails loudly naming the cause. Buffered,
    /// unflushed records are discarded (exactly the crash contract: not
    /// synced, not history). Every step is best-effort on an
    /// already-failing disk, and logged rather than fatal.
    pub fn poison(self) {
        let Journal { w, path, .. } = self;
        // Close the fd without flushing: the buffer's tail may be a
        // half-written record from the very failure that got us here.
        let (file, _discarded) = w.into_parts();
        drop(file);
        let mut aside = path.clone().into_os_string();
        aside.push(".poisoned");
        let aside = PathBuf::from(aside);
        match fs::rename(&path, &aside) {
            Ok(()) => {
                eprintln!(
                    "leader: journal moved aside to {} after a write failure",
                    aside.display()
                );
                let marked = File::create(&path).and_then(|mut f| {
                    f.write_all(&MAGIC)?;
                    f.write_all(&POISON_LEN.to_le_bytes())?;
                    f.write_all(&POISON_CRC.to_le_bytes())?;
                    f.sync_data()
                });
                if let Err(e) = marked {
                    eprintln!(
                        "leader: could not leave a poison marker at {} ({e}); a restart \
                         with --journal will start from an empty log",
                        path.display()
                    );
                }
            }
            Err(e) => {
                eprintln!(
                    "leader: could not move the failed journal aside ({e}); poisoning it \
                     in place"
                );
                // Appending the marker after a torn record would hide it
                // behind clean torn-tail truncation: cut the file back to
                // its last whole record first, where recover() will look.
                let marked = recover(&path).and_then(|rec| {
                    let mut f = OpenOptions::new().write(true).open(&path)?;
                    f.set_len(rec.valid_bytes.max(MAGIC.len() as u64))?;
                    f.seek(SeekFrom::End(0))?;
                    f.write_all(&POISON_LEN.to_le_bytes())?;
                    f.write_all(&POISON_CRC.to_le_bytes())?;
                    f.sync_data()?;
                    Ok(())
                });
                if let Err(e) = marked {
                    // recover() erroring means the file already fails
                    // loudly on its own; anything else is logged.
                    eprintln!("leader: could not poison journal {} ({e:#})", path.display());
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dml::DmlKind;
    use crate::spectral::{Algo, Bandwidth, GraphKind};

    fn spec() -> JobSpec {
        JobSpec {
            dml: DmlKind::KMeans,
            total_codes: 60,
            k_clusters: 3,
            kmeans_max_iters: 20,
            kmeans_tol: 1e-4,
            seed: 42,
            algo: Algo::Njw,
            graph: GraphKind::Dense,
            weighted: true,
            bandwidth: Bandwidth::MedianScale(1.0),
            priority: JobSpec::DEFAULT_PRIORITY,
        }
    }

    fn sample_events() -> Vec<JournalEvent> {
        vec![
            JournalEvent::ClientSubmit { client: 1, spec: spec(), modern: false },
            JournalEvent::ClientSubmit {
                client: 2,
                spec: JobSpec { priority: 4, ..spec() },
                modern: true,
            },
            JournalEvent::ClientPull { client: 1, run: 7 },
            JournalEvent::ClientDown { client: 2 },
            JournalEvent::SiteFrame { site: 1, gen: 3, frame: vec![9, 8, 7] },
            JournalEvent::SiteDown { site: 0, gen: 1, err: "io error".into() },
            JournalEvent::CentralDone {
                run: 7,
                result: Ok((vec![0, 1, 2, 1], 0.5)),
                elapsed_ns: 1234,
            },
            JournalEvent::CentralDone {
                run: 8,
                result: Err("central step panicked".into()),
                elapsed_ns: 99,
            },
            JournalEvent::Tick,
            JournalEvent::Restart,
            JournalEvent::SendFail { seq: 17, site: 1, err: "site 1 hung up".into() },
            JournalEvent::Admitted { run: 7, client: 1 },
            JournalEvent::Rejected { client: 3 },
            JournalEvent::Started { run: 7 },
            JournalEvent::Completed { run: 7 },
            JournalEvent::Failed { run: 8 },
        ]
    }

    #[test]
    fn crc32_matches_known_vectors() {
        // IEEE CRC-32 check value for "123456789".
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn every_event_kind_roundtrips() {
        for (i, ev) in sample_events().into_iter().enumerate() {
            let t_ns = 1_000 * i as u64;
            let payload = encode_payload(t_ns, &ev);
            let rec = decode_payload(&payload).unwrap();
            assert_eq!(rec, Record { t_ns, event: ev });
        }
    }

    #[test]
    fn payload_truncation_rejected_at_every_offset() {
        for ev in sample_events() {
            let payload = encode_payload(5, &ev);
            for cut in 0..payload.len() {
                assert!(
                    decode_payload(&payload[..cut]).is_err(),
                    "cut at {cut} of {ev:?} should fail"
                );
            }
        }
    }

    #[test]
    fn frame_record_roundtrips_through_decode_framed() {
        for (i, ev) in sample_events().into_iter().enumerate() {
            let t_ns = 7_000 + i as u64;
            let frame = frame_record(t_ns, &ev);
            // The frame is exactly header + payload, CRC included.
            assert_eq!(frame.len(), 8 + encode_payload(t_ns, &ev).len());
            let rec = decode_framed(&frame).unwrap();
            assert_eq!(rec, Record { t_ns, event: ev.clone() });
            // Truncation at every cut and a flipped payload byte both fail.
            for cut in 0..frame.len() {
                assert!(decode_framed(&frame[..cut]).is_err(), "cut at {cut} of {ev:?}");
            }
            let mut bad = frame.clone();
            bad[8] ^= 0xFF;
            assert!(format!("{:#}", decode_framed(&bad).unwrap_err()).contains("CRC"));
        }
    }

    #[test]
    fn append_framed_reproduces_append_byte_for_byte() {
        let dir = std::env::temp_dir().join(format!("dsc-journal-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let native = dir.join("framed-native.journal");
        let copy = dir.join("framed-copy.journal");
        let _ = fs::remove_file(&native);
        let _ = fs::remove_file(&copy);

        let (mut j, _) = Journal::open(&native, false).unwrap();
        for (i, ev) in sample_events().iter().enumerate() {
            j.append(10 + i as u64, ev).unwrap();
        }
        j.sync().unwrap();
        drop(j);

        // Replicate the file frame by frame through the standby path: the
        // result must be byte-identical, and each frame must decode to the
        // record it carries.
        let (frames, valid_bytes) = framed_records(&native).unwrap();
        assert_eq!(frames.len(), sample_events().len());
        assert_eq!(valid_bytes, fs::metadata(&native).unwrap().len());
        let (mut standby, old) = Journal::open(&copy, false).unwrap();
        assert!(old.is_empty());
        for (i, frame) in frames.iter().enumerate() {
            let (rec, count) = standby.append_framed(frame).unwrap();
            assert_eq!(count, i as u64 + 1);
            assert_eq!(rec.t_ns, 10 + i as u64);
        }
        standby.sync().unwrap();
        drop(standby);
        assert_eq!(fs::read(&native).unwrap(), fs::read(&copy).unwrap());
        let _ = fs::remove_file(&native);
        let _ = fs::remove_file(&copy);
    }

    #[test]
    fn append_recover_roundtrip_and_reopen() {
        let dir = std::env::temp_dir().join(format!("dsc-journal-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.journal");
        let _ = fs::remove_file(&path);

        let (mut j, old) = Journal::open(&path, false).unwrap();
        assert!(old.is_empty());
        for (i, ev) in sample_events().iter().enumerate() {
            assert_eq!(j.append(i as u64, ev).unwrap(), i as u64 + 1);
        }
        j.sync().unwrap();
        drop(j);

        let rec = recover(&path).unwrap();
        assert!(!rec.torn);
        assert_eq!(rec.records.len(), sample_events().len());
        for (i, (r, ev)) in rec.records.iter().zip(sample_events()).enumerate() {
            assert_eq!(*r, Record { t_ns: i as u64, event: ev });
        }

        // Reopen for append: recovered count carries over, new records land
        // after the old ones.
        let (mut j, old) = Journal::open(&path, false).unwrap();
        assert_eq!(old.len(), sample_events().len());
        assert_eq!(j.records(), old.len() as u64);
        j.append(777, &JournalEvent::Tick).unwrap();
        j.sync().unwrap();
        drop(j);
        let rec = recover(&path).unwrap();
        assert_eq!(rec.records.len(), sample_events().len() + 1);
        assert_eq!(rec.records.last().unwrap().t_ns, 777);
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn torn_tail_recovers_cleanly_and_open_truncates_it() {
        let dir = std::env::temp_dir().join(format!("dsc-journal-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("torn.journal");
        let _ = fs::remove_file(&path);
        let (mut j, _) = Journal::open(&path, false).unwrap();
        j.append(1, &JournalEvent::Tick).unwrap();
        j.append(2, &JournalEvent::ClientDown { client: 9 }).unwrap();
        j.sync().unwrap();
        drop(j);
        let full = fs::read(&path).unwrap();
        let one_len = 8 + encode_payload(1, &JournalEvent::Tick).len();
        let second_start = MAGIC.len() + one_len;

        // Truncating at every byte offset inside the *last* record (its
        // header included) must recover exactly the first record.
        for cut in second_start..full.len() {
            fs::write(&path, &full[..cut]).unwrap();
            let rec = recover(&path).unwrap();
            assert_eq!(rec.records.len(), 1, "cut at {cut}");
            assert!(rec.torn, "cut at {cut} is a torn tail");
            assert_eq!(rec.valid_bytes as usize, second_start);
        }

        // open() truncates the torn tail and appends after record 1.
        fs::write(&path, &full[..full.len() - 3]).unwrap();
        let (mut j, old) = Journal::open(&path, false).unwrap();
        assert_eq!(old.len(), 1);
        j.append(3, &JournalEvent::Tick).unwrap();
        j.sync().unwrap();
        drop(j);
        let rec = recover(&path).unwrap();
        assert_eq!(rec.records.len(), 2);
        assert_eq!(rec.records[1].t_ns, 3);
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn interior_corruption_fails_loudly_with_the_offset() {
        let dir = std::env::temp_dir().join(format!("dsc-journal-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("corrupt.journal");
        let _ = fs::remove_file(&path);
        let (mut j, _) = Journal::open(&path, false).unwrap();
        j.append(1, &JournalEvent::ClientDown { client: 1 }).unwrap();
        j.append(2, &JournalEvent::Tick).unwrap();
        j.sync().unwrap();
        drop(j);
        let full = fs::read(&path).unwrap();

        // Flip one payload byte of record 0: CRC mismatch at its offset.
        let mut bad = full.clone();
        bad[MAGIC.len() + 8] ^= 0xFF;
        fs::write(&path, &bad).unwrap();
        let err = format!("{:#}", recover(&path).unwrap_err());
        assert!(err.contains("CRC mismatch"), "{err}");
        assert!(err.contains(&format!("byte offset {}", MAGIC.len())), "{err}");

        // Flip a magic byte: loud, at offset 0.
        let mut bad = full.clone();
        bad[0] ^= 0xFF;
        fs::write(&path, &bad).unwrap();
        let err = format!("{:#}", recover(&path).unwrap_err());
        assert!(err.contains("bad journal magic at byte offset 0"), "{err}");

        // A corrupted CRC field itself is also a loud mismatch.
        let mut bad = full.clone();
        bad[MAGIC.len() + 4] ^= 0x01;
        fs::write(&path, &bad).unwrap();
        assert!(recover(&path).is_err());
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn poisoned_journal_fails_loudly_and_keeps_history_aside() {
        let dir = std::env::temp_dir().join(format!("dsc-journal-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("poison.journal");
        let aside = dir.join("poison.journal.poisoned");
        let _ = fs::remove_file(&path);
        let _ = fs::remove_file(&aside);

        let (mut j, _) = Journal::open(&path, false).unwrap();
        j.append(1, &JournalEvent::Tick).unwrap();
        j.append(2, &JournalEvent::ClientDown { client: 4 }).unwrap();
        j.sync().unwrap();
        j.poison();

        // The journal path now refuses recovery — and so Journal::open —
        // loudly, naming the poisoning; a restart cannot silently replay
        // the truncated history.
        let err = format!("{:#}", recover(&path).unwrap_err());
        assert!(err.contains("poisoned"), "{err}");
        assert!(Journal::open(&path, false).is_err());

        // The history itself survives aside, intact, for forensics.
        let rec = recover(&aside).unwrap();
        assert_eq!(rec.records.len(), 2);
        assert_eq!(rec.records[0].t_ns, 1);
        let _ = fs::remove_file(&path);
        let _ = fs::remove_file(&aside);
    }
}
