//! The distributed coordinator — Algorithm 1 as a leader/worker runtime.
//!
//! The protocol has exactly one implementation, split along the network
//! seam: the leader's per-run behavior is the [`machine::RunMachine`]
//! state machine, and [`crate::site::serve`] / [`crate::site::session`]
//! is everything a site does over a [`crate::net::SiteNet`]. Four
//! drivers wire the leader half to transports:
//!
//! * [`run_pipeline`] — the in-process star: one worker thread per site
//!   over the channel transport, [`leader_protocol`] pumping a single
//!   machine. The default for tests, benches, `dsc run`.
//! * [`run_leader_tcp`] — the leader half alone over real TCP connections
//!   to `dsc site` daemon processes (`dsc leader`; see `docs/DEPLOY.md`).
//! * [`server::serve_jobs`] — the event-driven job server: many machines
//!   at once over persistent site sessions, jobs submitted by TCP clients
//!   (`dsc leader --serve` / `dsc submit`), central steps offloaded to a
//!   worker pool so one run's spectral phase never blocks another's
//!   frames.
//! * [`harness::serve_channel`] — the same reactor stack over in-process
//!   channel sites: injectable fault plan, virtual clock, typed clients —
//!   the socket-free test backend (`docs/TESTING.md`).
//!
//! ```text
//! site s:  ──site info──▶ leader         (shard size/dim registration)
//! site s:  ◀─dml request── leader        (budget ∝ site size, forked seed)
//! site s:  DML(local data) ──codebook──▶ leader
//! leader:  collect S codebooks → spectral clustering on the union
//! leader:  ──codeword labels──▶ site s
//! site s:  populate: point label = label of its codeword
//! ```
//!
//! Timing follows the paper's §5 protocol: sites run in parallel, so the
//! *elapsed* model sums `max_s(DML) + central + max_s(populate)` — the wall
//! clock of the run itself is also reported (they agree up to thread
//! scheduling). Communication is whatever crossed the wire, byte-exact and
//! identical across transports.
//!
//! The evaluation channel (per-point labels returned to the caller) is NOT
//! part of the protocol: in production those labels stay at the sites; the
//! driver only needs them to score accuracy against ground truth, so they
//! travel through the thread join (in-process) or site-side label files
//! (TCP), never the network.

pub mod harness;
pub mod journal;
pub mod loadgen;
pub mod machine;
pub mod server;

use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{Backend, PipelineConfig};
use crate::data::scenario::SitePart;
use crate::net::{self, JobSpec, LeaderNet, Message, NetReport};
use crate::rng::Rng;
use crate::runtime::XlaRuntime;
use crate::spectral::{self, njw, GraphKind, SpectralParams};

use machine::{OutMsg, RunInput, RunMachine};

/// Outcome of one distributed run.
#[derive(Clone, Debug)]
pub struct PipelineReport {
    /// Predicted label for every point of the *full* dataset (global index).
    pub labels: Vec<u16>,
    /// Paper metric (Eq. 5) against the ground-truth labels.
    pub accuracy: f64,
    pub ari: f64,
    pub nmi: f64,
    /// Modeled elapsed time: max site DML + central + max site populate.
    pub elapsed_model: Duration,
    /// Actual wall-clock time of the run.
    pub wall: Duration,
    /// Per-site DML seconds (max of these is the parallel-phase cost).
    pub site_dml: Vec<Duration>,
    /// Central spectral time.
    pub central: Duration,
    /// Max site populate time.
    pub populate: Duration,
    /// Codewords that reached the leader.
    pub n_codes: usize,
    /// Bytes on the wire + modeled transfer time.
    pub net: NetReport,
    /// Bytes a ship-all-the-data baseline would need.
    pub full_data_bytes: u64,
    /// Bandwidth used by the central step.
    pub sigma: f64,
    /// Quantization distortion per site (Theorem 2/3 quantity).
    pub site_distortion: Vec<f64>,
}

/// What [`leader_protocol`] learned and produced, transport-independent.
/// Everything a leader can know without ground truth (accuracy lives with
/// whoever holds the labels — see the module docs on the evaluation
/// channel).
#[derive(Clone, Debug)]
pub struct LeaderOutcome {
    /// Data dimensionality every site agreed on.
    pub dim: usize,
    /// Codewords in the union the central step clustered.
    pub n_codes: usize,
    /// Bandwidth used by the central step.
    pub sigma: f64,
    /// Central spectral time.
    pub central: Duration,
    /// Points each site registered.
    pub site_points: Vec<u64>,
    /// Codewords each site contributed.
    pub site_codes: Vec<usize>,
}

/// Report of a TCP leader run ([`run_leader_tcp`]).
#[derive(Clone, Debug)]
pub struct TcpRunReport {
    pub outcome: LeaderOutcome,
    /// Per-link byte counters — identical to what the channel backend
    /// reports for the same config and data.
    pub net: NetReport,
    /// Wall clock from first connect attempt to labels delivered.
    pub wall: Duration,
}

struct SiteOutcome {
    site_id: usize,
    dml_time: Duration,
    populate_time: Duration,
    distortion: f64,
    /// (global point index, predicted label)
    labels: Vec<(u32, u16)>,
}

fn resolve_xla(cfg: &PipelineConfig) -> Result<Option<std::rc::Rc<XlaRuntime>>> {
    Ok(match cfg.backend {
        Backend::Native => None,
        Backend::Xla | Backend::XlaFull => Some(
            crate::runtime::shared(&cfg.artifact_dir)
                .context("init XLA runtime (run `make artifacts`?)")?,
        ),
    })
}

fn check_graph_backend_kinds(graph: GraphKind, backend: Backend) -> Result<()> {
    if backend != Backend::Native && graph != GraphKind::Dense {
        bail!(
            "spectral.graph = \"knn\" requires backend = \"native\": the AOT XLA \
             artifacts compute the dense affinity embedding"
        );
    }
    Ok(())
}

fn check_graph_backend(cfg: &PipelineConfig) -> Result<()> {
    check_graph_backend_kinds(cfg.graph, cfg.backend)
}

/// The job-level subset of a [`PipelineConfig`] — what one clustering run
/// is, independent of how the serving deployment executes it (backend,
/// link model, addresses and timeouts stay with the leader). This is the
/// payload of a `SUBMIT` frame; [`leader_protocol`] derives one from its
/// own config so both drivers run literally the same spec.
pub fn spec_from_config(cfg: &PipelineConfig) -> JobSpec {
    JobSpec {
        dml: cfg.dml,
        total_codes: cfg.total_codes as u32,
        k_clusters: cfg.k_clusters as u32,
        kmeans_max_iters: cfg.kmeans_max_iters as u32,
        kmeans_tol: cfg.kmeans_tol,
        seed: cfg.seed,
        algo: cfg.algo,
        graph: cfg.graph,
        weighted: cfg.weighted_affinity,
        bandwidth: cfg.bandwidth,
        priority: JobSpec::DEFAULT_PRIORITY,
    }
}

/// Run the full distributed pipeline over pre-split site data, in process
/// (channel transport, one worker thread per site).
///
/// `parts` is the output of [`crate::data::scenario::split`] (or any
/// user-provided partition); ground truth inside `parts` is used only for
/// the report's metrics.
pub fn run_pipeline(parts: &[SitePart], cfg: &PipelineConfig) -> Result<PipelineReport> {
    if parts.is_empty() {
        bail!("no sites");
    }
    let dim = parts[0].data.dim;
    let total_points: usize = parts.iter().map(|p| p.data.len()).sum();
    if total_points == 0 {
        bail!("no data");
    }
    for (pos, p) in parts.iter().enumerate() {
        if p.data.dim != dim {
            bail!("site {} has dim {}, expected {dim}", p.site_id, p.data.dim);
        }
        if p.site_id != pos {
            bail!("parts must be ordered by site_id (found {} at position {pos})", p.site_id);
        }
    }
    check_graph_backend(cfg)?;
    let full_data_bytes: u64 = parts.iter().map(|p| p.data.wire_bytes()).sum();

    let wall_start = Instant::now();
    let (leader, mut site_nets) = net::star(parts.len(), cfg.link);

    // XLA runtime resolved before threads spawn; the thread-local shared
    // cache keeps compiled executables alive across pipeline runs on this
    // (leader) thread.
    let xla = resolve_xla(cfg)?;

    // Runs the whole leader protocol inside the thread scope. On ANY error
    // path (straggler timeout, corrupt frame, central failure) the leader
    // handle is dropped *before* the scope ends, which closes every site's
    // downlink and unblocks workers still waiting for labels — error
    // returns never deadlock the scope join.
    let (leader_out, outcomes, net_report) = std::thread::scope(
        |scope| -> Result<(LeaderOutcome, Vec<SiteOutcome>, NetReport)> {
            // ---- spawn site workers ----
            let mut handles = Vec::with_capacity(parts.len());
            for part in parts {
                let site_net = site_nets.remove(0);
                let fail = cfg.inject_site_failure == Some(part.site_id);
                handles.push(scope.spawn(move || site_worker(part, site_net, fail)));
            }

            let leader_work = || -> Result<(LeaderOutcome, Vec<SiteOutcome>)> {
                let leader_out = leader_protocol(&leader, cfg, xla.as_deref())?;
                let mut outcomes = Vec::with_capacity(parts.len());
                for h in handles {
                    outcomes.push(h.join().map_err(|_| anyhow!("site worker panicked"))??);
                }
                Ok((leader_out, outcomes))
            };

            let result = leader_work();
            let report = leader.report();
            drop(leader); // close downlinks: unblocks workers on the error path
            result.map(|(lo, outcomes)| (lo, outcomes, report))
        },
    )?;

    let wall = wall_start.elapsed();

    // ---- assemble the global label vector + metrics ----
    let mut labels = vec![0u16; total_points];
    for o in &outcomes {
        for &(g, l) in &o.labels {
            labels[g as usize] = l;
        }
    }
    let mut truth = vec![0u16; total_points];
    for p in parts {
        for (local, &g) in p.global_idx.iter().enumerate() {
            truth[g as usize] = p.data.labels[local];
        }
    }

    let mut site_dml = vec![Duration::ZERO; parts.len()];
    let mut site_distortion = vec![0.0f64; parts.len()];
    let mut populate = Duration::ZERO;
    for o in &outcomes {
        site_dml[o.site_id] = o.dml_time;
        site_distortion[o.site_id] = o.distortion;
        populate = populate.max(o.populate_time);
    }
    let max_dml = site_dml.iter().copied().max().unwrap_or_default();

    Ok(PipelineReport {
        accuracy: crate::metrics::clustering_accuracy(&truth, &labels),
        ari: crate::metrics::adjusted_rand_index(&truth, &labels),
        nmi: crate::metrics::normalized_mutual_info(&truth, &labels),
        labels,
        elapsed_model: max_dml + leader_out.central + populate,
        wall,
        site_dml,
        central: leader_out.central,
        populate,
        n_codes: leader_out.n_codes,
        net: net_report,
        full_data_bytes,
        sigma: leader_out.sigma,
        site_distortion,
    })
}

/// The leader half of the protocol over real TCP connections to running
/// `dsc site` daemons (`cfg.net.sites`, index = site id). Labels are
/// delivered to the sites; this side reports what a leader can know —
/// codeword counts, σ, timings, and the per-link byte counters.
pub fn run_leader_tcp(cfg: &PipelineConfig) -> Result<TcpRunReport> {
    if cfg.net.sites.is_empty() {
        bail!("no site addresses configured (set [net] sites or --sites)");
    }
    check_graph_backend(cfg)?;
    let wall_start = Instant::now();
    let transport = net::tcp::connect_sites(&cfg.net.sites, &cfg.net.tcp_timeouts())?;
    let leader = LeaderNet::over(Box::new(transport), cfg.link);
    let xla = resolve_xla(cfg)?;
    let outcome = leader_protocol(&leader, cfg, xla.as_deref())?;
    Ok(TcpRunReport { outcome, net: leader.report(), wall: wall_start.elapsed() })
}

/// Everything the leader does for one run, over any transport: the
/// blocking single-run driver around [`machine::RunMachine`]. Events are
/// pumped straight off the transport mailbox; each collect phase gets a
/// fresh `cfg.collect_timeout` deadline (straggler/crash protection). The
/// job server ([`server`]) drives the same machine event-for-event, so a
/// run behaves identically whether it is the only one or interleaved with
/// others.
pub fn leader_protocol(
    net: &LeaderNet,
    cfg: &PipelineConfig,
    xla: Option<&XlaRuntime>,
) -> Result<LeaderOutcome> {
    let n_sites = net.n_sites();
    if n_sites == 0 {
        bail!("no sites");
    }
    check_graph_backend(cfg)?;
    let mut m = RunMachine::new(n_sites, spec_from_config(cfg), cfg.collect_timeout, Instant::now());

    loop {
        let remaining = m.deadline().saturating_duration_since(Instant::now());
        let input = match net.recv_timeout(remaining) {
            Ok((sid, msg)) => classic_input(sid, msg, n_sites)?,
            // Timeout or dead link while collecting: the machine knows
            // which phase stalled and who never reported.
            Err(e) => return Err(m.waiting_error(&format!("{e:#}"))),
        };
        let adv = m.advance(Instant::now(), input)?;
        for (sid, out) in adv.send {
            net.send(sid, &classic_out(sid, out))?;
        }
        if adv.central {
            // ---- central spectral clustering on the codeword union ----
            // Wall time, not thread CPU: this phase runs alone on the host
            // (after the site barrier) and may fan out over the `par`
            // pool, so its wall clock is exactly the elapsed contribution.
            // Sites use thread CPU instead because *their* contention is a
            // simulation artifact when they are threads (see crate::site).
            let t0 = Instant::now();
            let (code_labels, sigma) = {
                let (cw, dim, w) = m.central_input();
                central_cluster(cw, dim, w, m.spec(), cfg.backend, xla)?
            };
            let adv = m.central_done(code_labels, sigma, t0.elapsed())?;
            for (sid, out) in adv.send {
                net.send(sid, &classic_out(sid, out))?;
            }
            debug_assert!(adv.done);
            return Ok(m.outcome());
        }
    }
}

/// Map a classic (unscoped) frame to a machine event, validating the
/// embedded site id against the link it arrived on — the machine itself
/// only ever sees trusted link indices.
fn classic_input(sid: usize, msg: Message, n_sites: usize) -> Result<RunInput> {
    if sid >= n_sites {
        bail!("message from out-of-range site {sid}");
    }
    match msg {
        Message::SiteInfo { site, n_points, dim } => {
            if site as usize != sid {
                bail!("site id mismatch on site info frame");
            }
            Ok(RunInput::SiteInfo { site: sid, n_points, dim })
        }
        Message::Codebook { site, dim, codewords, weights } => {
            if site as usize != sid {
                bail!("site id mismatch on codebook frame");
            }
            Ok(RunInput::Codebook { site: sid, dim, codewords, weights })
        }
        other => bail!("unexpected message from site {sid}: {other:?}"),
    }
}

/// Wrap a machine output in the classic one-shot dialect (the job server
/// wraps the same outputs run-scoped instead).
fn classic_out(sid: usize, out: OutMsg) -> Message {
    match out {
        OutMsg::Dml(o) => Message::DmlRequest {
            site: sid as u32,
            dml: o.dml,
            target_codes: o.target_codes,
            max_iters: o.max_iters,
            tol: o.tol,
            seed: o.seed,
        },
        OutMsg::Labels(labels) => Message::Labels { site: sid as u32, labels },
    }
}

/// What one in-process site worker does: bridge a [`SitePart`] onto the
/// transport-agnostic [`crate::site::serve`] and map the populated labels
/// back to global point indices.
fn site_worker(
    part: &SitePart,
    net: net::SiteNet,
    inject_failure: bool,
) -> Result<SiteOutcome> {
    if inject_failure {
        // Chaos hook (PipelineConfig::inject_site_failure): simulate a site
        // crashing before it reports — the leader must time out cleanly.
        bail!("injected failure at site {}", part.site_id);
    }
    if net.site_id() != part.site_id {
        bail!("site handle {} wired to part {}", net.site_id(), part.site_id);
    }
    let out = crate::site::serve(&net, &part.data)?;
    let labels: Vec<(u32, u16)> =
        part.global_idx.iter().zip(&out.labels).map(|(&g, &l)| (g, l)).collect();
    Ok(SiteOutcome {
        site_id: part.site_id,
        dml_time: out.dml_time,
        populate_time: out.populate_time,
        distortion: out.distortion,
        labels,
    })
}

/// Central spectral step with backend dispatch, parameterized by the job
/// spec (so the blocking driver and the job server run byte-identical
/// specs). Returns codeword labels and the bandwidth used.
fn central_cluster(
    cw: &[f32],
    dim: usize,
    weights: &[f32],
    spec: &JobSpec,
    backend: Backend,
    xla: Option<&XlaRuntime>,
) -> Result<(Vec<u16>, f64)> {
    check_graph_backend_kinds(spec.graph, backend)?;
    let n = weights.len();
    let params = SpectralParams {
        k: spec.k_clusters as usize,
        bandwidth: spec.bandwidth,
        algo: spec.algo,
        graph: spec.graph,
        weighted: spec.weighted,
        seed: spec.seed ^ 0xC0FFEE,
    };

    match backend {
        Backend::Native => {
            let (labels, info) =
                spectral::cluster_codewords(cw, dim, Some(weights), &params);
            Ok((labels, info.sigma))
        }
        Backend::Xla | Backend::XlaFull => {
            let rt = xla.expect("runtime present for XLA backends");
            let mut rng = Rng::new(params.seed);
            let sigma = spectral::resolve_sigma(
                cw,
                dim,
                Some(weights),
                params.bandwidth,
                params.k,
                GraphKind::Dense, // knn + XLA rejected above
                &mut rng,
            );
            // weights double as the pad mask; the unweighted variant sends 1s
            let w_eff: Vec<f32> =
                if params.weighted { weights.to_vec() } else { vec![1.0; n] };
            let out = rt.embed(cw, dim, &w_eff, sigma as f32)?;
            let k_cols = out.k_cols;

            let labels = if backend == Backend::Xla {
                // native K-means finish on the embedding
                let emb: Vec<f64> = out.evecs.iter().map(|&v| v as f64).collect();
                njw::labels_from_embedding(&emb, n, k_cols, params.k, &mut rng)
            } else {
                // XLA Lloyd steps on the row-normalized embedding
                xla_kmeans_labels(rt, &out.evecs, n, k_cols, params.k, &mut rng)?
            };
            Ok((labels, sigma))
        }
    }
}

/// Backend::XlaFull finish: row-normalize, run the kstep artifact to a
/// fixed point, return labels.
fn xla_kmeans_labels(
    rt: &XlaRuntime,
    evecs: &[f32],
    n: usize,
    k_cols: usize,
    k_clusters: usize,
    rng: &mut Rng,
) -> Result<Vec<u16>> {
    let use_cols = k_clusters.clamp(2, k_cols);
    let mut rows = vec![0.0f32; n * k_cols]; // kstep artifact expects d = k_cols
    for i in 0..n {
        let src = &evecs[i * k_cols..i * k_cols + use_cols];
        let norm = src.iter().map(|v| v * v).sum::<f32>().sqrt().max(1e-12);
        for (j, &s) in src.iter().enumerate() {
            rows[i * k_cols + j] = s / norm;
        }
    }
    // Several restarts from random distinct rows, keeping the lowest
    // inertia — Lloyd on spectral embeddings is cheap (n ≤ 2048, d = 8)
    // but sensitive to seeding, exactly like the native NJW finisher.
    let k = k_clusters.min(n);
    let mut best: Option<(f32, Vec<i32>)> = None;
    for _restart in 0..6 {
        let picks = rng.sample_indices(n, k);
        let mut c = vec![0.0f32; k * k_cols];
        for (slot, &p) in picks.iter().enumerate() {
            c[slot * k_cols..(slot + 1) * k_cols]
                .copy_from_slice(&rows[p * k_cols..(p + 1) * k_cols]);
        }
        let mut idx = vec![0i32; n];
        let mut inertia = f32::INFINITY;
        for _ in 0..60 {
            let (newc, assign, shift, inert) = rt.kmeans_step(&rows, k_cols, &c, k)?;
            c = newc;
            idx = assign;
            inertia = inert;
            if shift < 1e-10 {
                break;
            }
        }
        if best.as_ref().map_or(true, |(b, _)| inertia < *b) {
            best = Some((inertia, idx));
        }
    }
    let (_, idx) = best.expect("at least one restart");
    Ok(idx.into_iter().map(|v| v as u16).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gmm, scenario, scenario::Scenario};
    use crate::dml::DmlKind;
    use crate::spectral::{Algo, Bandwidth};

    fn blob_mixture(n: usize, seed: u64) -> crate::data::Dataset {
        // 2 tight blobs in 2-D — easy ground truth for pipeline smoke tests
        let comps = vec![
            gmm::Component::isotropic(vec![0.0, 0.0], 0.5, 1.0),
            gmm::Component::isotropic(vec![10.0, 10.0], 0.5, 1.0),
        ];
        gmm::sample("blobs", &comps, n, seed)
    }

    fn base_cfg() -> PipelineConfig {
        PipelineConfig {
            total_codes: 64,
            k_clusters: 2,
            bandwidth: Bandwidth::MedianScale(0.5),
            ..Default::default()
        }
    }

    #[test]
    fn two_site_pipeline_clusters_blobs() {
        let ds = blob_mixture(4_000, 3);
        for sc in [Scenario::D1, Scenario::D2, Scenario::D3] {
            let parts = scenario::split(&ds, sc, 2, 5);
            let report = run_pipeline(&parts, &base_cfg()).unwrap();
            assert!(report.accuracy > 0.99, "{sc}: accuracy {}", report.accuracy);
            assert_eq!(report.labels.len(), 4_000);
            assert!(report.n_codes >= 60 && report.n_codes <= 68, "{}", report.n_codes);
            // codewords are *much* smaller than the data on the wire
            assert!(report.net.total_bytes() < report.full_data_bytes / 10);
        }
    }

    #[test]
    fn rptree_dml_works_too() {
        let ds = blob_mixture(4_000, 7);
        let parts = scenario::split(&ds, Scenario::D3, 2, 9);
        let cfg = PipelineConfig { dml: DmlKind::RpTree, ..base_cfg() };
        let report = run_pipeline(&parts, &cfg).unwrap();
        assert!(report.accuracy > 0.99, "accuracy {}", report.accuracy);
    }

    #[test]
    fn sparse_graph_pipeline_clusters_blobs() {
        let ds = blob_mixture(4_000, 41);
        let parts = scenario::split(&ds, Scenario::D3, 2, 43);
        let cfg = PipelineConfig { graph: GraphKind::Knn { k: 12 }, ..base_cfg() };
        let report = run_pipeline(&parts, &cfg).unwrap();
        assert!(report.accuracy > 0.99, "accuracy {}", report.accuracy);
    }

    #[test]
    fn sparse_graph_rejected_on_xla_backends() {
        let ds = blob_mixture(400, 47);
        let parts = scenario::split(&ds, Scenario::D3, 2, 49);
        for backend in [Backend::Xla, Backend::XlaFull] {
            let cfg =
                PipelineConfig { graph: GraphKind::Knn { k: 8 }, backend, ..base_cfg() };
            let err = run_pipeline(&parts, &cfg).unwrap_err();
            assert!(err.to_string().contains("native"), "unexpected error: {err}");
        }
    }

    #[test]
    fn njw_algo_works() {
        let ds = blob_mixture(2_000, 11);
        let parts = scenario::split(&ds, Scenario::D2, 2, 13);
        let cfg = PipelineConfig { algo: Algo::Njw, ..base_cfg() };
        let report = run_pipeline(&parts, &cfg).unwrap();
        assert!(report.accuracy > 0.99, "accuracy {}", report.accuracy);
    }

    #[test]
    fn four_sites_conserve_everything() {
        let ds = blob_mixture(3_000, 17);
        let parts = scenario::split(&ds, Scenario::D3, 4, 19);
        let report = run_pipeline(&parts, &base_cfg()).unwrap();
        assert!(report.accuracy > 0.99);
        assert_eq!(report.site_dml.len(), 4);
        assert_eq!(report.net.per_site.len(), 4);
        // the protocol is exactly two frames each way per site: site info +
        // codebook up, dml request + labels down
        for l in &report.net.per_site {
            assert_eq!(l.to_leader.frames, 2);
            assert_eq!(l.to_site.frames, 2);
        }
    }

    #[test]
    fn single_site_is_the_nondistributed_baseline() {
        let ds = blob_mixture(2_000, 23);
        let parts = vec![scenario::SitePart {
            site_id: 0,
            data: ds.clone(),
            global_idx: (0..ds.len() as u32).collect(),
        }];
        let report = run_pipeline(&parts, &base_cfg()).unwrap();
        assert!(report.accuracy > 0.99);
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = blob_mixture(1_000, 29);
        let parts = scenario::split(&ds, Scenario::D3, 2, 31);
        let a = run_pipeline(&parts, &base_cfg()).unwrap();
        let b = run_pipeline(&parts, &base_cfg()).unwrap();
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.n_codes, b.n_codes);
    }

    #[test]
    fn empty_parts_rejected() {
        assert!(run_pipeline(&[], &base_cfg()).is_err());
    }

    #[test]
    fn leader_outcome_accounts_sites() {
        let ds = blob_mixture(2_000, 31);
        let parts = scenario::split(&ds, Scenario::D4, 2, 33);
        let report = run_pipeline(&parts, &base_cfg()).unwrap();
        // D4 skews sizes 2:1; the proportional budget must follow
        assert_eq!(report.n_codes, 64);
        assert!(parts[0].data.len() > parts[1].data.len());
    }

    #[test]
    fn tcp_leader_requires_site_addresses() {
        let err = run_leader_tcp(&base_cfg()).unwrap_err();
        assert!(err.to_string().contains("site addresses"), "{err}");
    }
}
