//! Deterministic load generator for the job-serving leader.
//!
//! The channel generator ([`run_channel_load`]) drives the *real* serving
//! stack — reactor, `JobQueue` (FIFO or DRR), `RunMachine`s, central
//! worker pool, real site sessions — through the socket-free harness
//! ([`super::harness`]), with every source of nondeterminism pinned:
//!
//! * every tenant submits its whole budget up front, at virtual t0, in a
//!   fixed round-robin interleaving (each submit waits for its accept, so
//!   arrival order at the reactor *is* submission order);
//! * `max_jobs = 1` and one central worker make queue pops strictly
//!   sequential — the observed central-entry order is exactly the queue
//!   discipline's dequeue order;
//! * a [`CentralHook`] sequencer holds each central at the gate until the
//!   controller advances the [`VirtualClock`](crate::net::channel) by one
//!   `step` and releases it, so the k-th pop completes its central at
//!   virtual `(k+1)·step` — job sojourns are a pure function of dequeue
//!   order, never of scheduler timing.
//!
//! The same mix therefore always produces the same [`LoadReport`]
//! (bit-for-bit, including the f64s): `benches/jobserver_load.rs` records
//! it as `BENCH_jobserver.json`, and `rust/tests/loadgen.rs` pins both
//! the determinism and the FIFO-vs-DRR fairness ordering. The TCP twin
//! ([`run_tcp_load`]) pushes the identical mix through a real loopback
//! job server for wall-clock numbers (real, therefore *not* in the
//! deterministic report). `docs/TESTING.md` has the how-to.
//!
//! Two hostile variants ride the same machinery:
//!
//! * the **chaos mix** ([`run_chaos_mix`] / [`run_chaos_twin`]) layers a
//!   fault plan, a straggler, a mid-backlog site outage and a staged
//!   leader crash-and-recover over a six-job, three-tenant DRR plan —
//!   only the faulted runs may fail, and every survivor must match its
//!   fault-free twin bit for bit;
//! * the **adversarial mix** ([`run_adversarial_mix`]) pits a flooding
//!   tenant against two paying ones with token-bucket admission on: the
//!   flood is clipped at the burst with typed `REJECT2` rate-limit codes,
//!   and the paying tenants' sojourns stay within a small factor of a
//!   flooder-free run.

use std::collections::{HashMap, HashSet, VecDeque};
use std::path::Path;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::config::PipelineConfig;
use crate::data::scenario::{self, Scenario};
use crate::data::{gmm, Dataset};
use crate::net::channel::Fault;
use crate::net::tcp::SiteListener;
use crate::net::{JobSpec, LinkReport, RejectCode, SiteNet};
use crate::site;

use super::harness::{serve_channel, serve_channel_journaled, HarnessOpts};
use super::server::{
    serve_jobs, CentralHook, JobClient, ServerOpts, ServerStats, SubmitOutcome,
};
use super::spec_from_config;

// ─── mixes ─────────────────────────────────────────────────────────────────

/// One tenant in a load mix.
#[derive(Clone, Copy, Debug)]
pub struct ClientLoad {
    /// Jobs this tenant submits (all up front, at virtual t0).
    pub submits: usize,
    /// Priority its specs carry — the DRR weight, `1..=MAX_PRIORITY`.
    pub priority: u32,
}

/// A deterministic load-generator scenario.
#[derive(Clone, Debug)]
pub struct LoadMix {
    /// The tenants; client ids are assigned 1.. in this order.
    pub clients: Vec<ClientLoad>,
    /// Queue discipline under test (`[leader] fair_queue`).
    pub fair_queue: bool,
    /// Virtual duration of one central step — the queue drains one job
    /// per `step`.
    pub step: Duration,
    /// Seed for the tiny site dataset and the job specs.
    pub seed: u64,
}

impl LoadMix {
    /// Total jobs across every tenant.
    pub fn total_jobs(&self) -> usize {
        self.clients.iter().map(|c| c.submits).sum()
    }

    /// The canonical skewed 3-tenant mix the BENCH trajectory records: a
    /// heavy low-priority tenant (12 jobs, weight 1), a medium one
    /// (6 jobs, weight 2), and a light high-priority one (3 jobs,
    /// weight 4). FIFO serves them in arrival order; DRR should serve
    /// them weight-proportionally.
    pub fn skewed_three(fair_queue: bool) -> LoadMix {
        LoadMix {
            clients: vec![
                ClientLoad { submits: 12, priority: 1 },
                ClientLoad { submits: 6, priority: 2 },
                ClientLoad { submits: 3, priority: 4 },
            ],
            fair_queue,
            step: Duration::from_millis(10),
            seed: 21,
        }
    }
}

// ─── reports ───────────────────────────────────────────────────────────────

/// Virtual-time queue-sojourn statistics for one tenant.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClientLatency {
    /// Client id (1-based, mix order).
    pub client: u64,
    /// The priority/weight its jobs carried.
    pub priority: u32,
    /// Jobs it had served.
    pub jobs: usize,
    /// Mean/percentile sojourn — submit (virtual t0) to central
    /// completion — in virtual nanoseconds (nearest-rank percentiles).
    pub mean_ns: u64,
    pub p50_ns: u64,
    pub p95_ns: u64,
    pub p99_ns: u64,
}

/// What one deterministic channel load run measured. `PartialEq` is exact
/// (including the f64s): same mix ⇒ same report, bit for bit.
#[derive(Clone, Debug, PartialEq)]
pub struct LoadReport {
    /// Queue discipline the run used.
    pub fair_queue: bool,
    /// Jobs submitted.
    pub jobs: usize,
    /// Jobs that completed / submissions refused (from [`ServerStats`]).
    pub completed: u64,
    pub rejected: u64,
    /// Virtual time from t0 to the last central completion.
    pub makespan_ns: u64,
    /// Completed jobs per virtual second.
    pub throughput_jobs_per_sec: f64,
    /// Served central time over makespan (1.0 = the single service slot
    /// never idled; a lost job shows up as a dip).
    pub utilization: f64,
    /// Jain fairness index over weight-normalized service counts, taken
    /// at the instant the first tenant drains (every tenant is backlogged
    /// until then). 1.0 = perfectly weight-proportional service.
    pub fairness: f64,
    /// Per-tenant sojourn statistics, mix order.
    pub per_client: Vec<ClientLatency>,
}

impl LoadReport {
    /// Hand-rolled JSON (the repo's runtime JSON module is a parser, not
    /// a serializer): stable key order, shortest-roundtrip f64s — the
    /// bytes are as deterministic as the report.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"fair_queue\": {},\n", self.fair_queue));
        s.push_str(&format!("  \"jobs\": {},\n", self.jobs));
        s.push_str(&format!("  \"completed\": {},\n", self.completed));
        s.push_str(&format!("  \"rejected\": {},\n", self.rejected));
        s.push_str(&format!("  \"makespan_ns\": {},\n", self.makespan_ns));
        s.push_str(&format!(
            "  \"throughput_jobs_per_sec\": {},\n",
            self.throughput_jobs_per_sec
        ));
        s.push_str(&format!("  \"utilization\": {},\n", self.utilization));
        s.push_str(&format!("  \"fairness\": {},\n", self.fairness));
        s.push_str("  \"per_client\": [\n");
        for (i, c) in self.per_client.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"client\": {}, \"priority\": {}, \"jobs\": {}, \
                 \"mean_ns\": {}, \"p50_ns\": {}, \"p95_ns\": {}, \"p99_ns\": {}}}{}\n",
                c.client,
                c.priority,
                c.jobs,
                c.mean_ns,
                c.p50_ns,
                c.p95_ns,
                c.p99_ns,
                if i + 1 < self.per_client.len() { "," } else { "" }
            ));
        }
        s.push_str("  ]\n}");
        s
    }
}

// ─── metric helpers ────────────────────────────────────────────────────────

/// Jain's fairness index J(x) = (Σx)² / (n·Σx²) ∈ (0, 1]; 1.0 when every
/// share is equal. An all-zero vector is vacuously fair.
pub fn jain_index(shares: &[f64]) -> f64 {
    if shares.is_empty() {
        return 1.0;
    }
    let sum: f64 = shares.iter().sum();
    let sq: f64 = shares.iter().map(|x| x * x).sum();
    if sq == 0.0 {
        return 1.0;
    }
    (sum * sum) / (shares.len() as f64 * sq)
}

/// Nearest-rank percentile of an ascending-sorted sample (0 if empty).
fn percentile(sorted: &[u64], pct: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = ((pct / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

// ─── central sequencer ─────────────────────────────────────────────────────

/// Serializes central steps through the [`CentralHook`]: each worker
/// announces its run and blocks until the controller releases it, so the
/// controller observes the exact dequeue order and stamps each pop
/// against the virtual clock.
struct Sequencer {
    state: Mutex<SeqState>,
    entered_cv: Condvar,
    released_cv: Condvar,
}

#[derive(Default)]
struct SeqState {
    entered: VecDeque<u32>,
    released: HashSet<u32>,
}

impl Sequencer {
    fn new() -> Arc<Sequencer> {
        Arc::new(Sequencer {
            state: Mutex::new(SeqState::default()),
            entered_cv: Condvar::new(),
            released_cv: Condvar::new(),
        })
    }

    /// Worker side: announce `run` entered its central, wait for release.
    fn enter_and_wait(&self, run: u32) {
        let mut st = self.state.lock().unwrap();
        st.entered.push_back(run);
        self.entered_cv.notify_all();
        while !st.released.remove(&run) {
            st = self.released_cv.wait(st).unwrap();
        }
    }

    /// Controller side: next run that reached its central step.
    fn wait_entered(&self) -> u32 {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(run) = st.entered.pop_front() {
                return run;
            }
            st = self.entered_cv.wait(st).unwrap();
        }
    }

    /// Controller side: let `run`'s central compute.
    fn release(&self, run: u32) {
        let mut st = self.state.lock().unwrap();
        st.released.insert(run);
        self.released_cv.notify_all();
    }
}

// ─── workload + config ─────────────────────────────────────────────────────

/// The tiny single-site dataset every load job clusters — small enough
/// that a full serve of a 21-job mix stays test-sized.
pub fn load_workload(seed: u64) -> Vec<Dataset> {
    let ds = gmm::paper_mixture_10d(240, 0.1, seed);
    let parts = scenario::split(&ds, Scenario::D3, 1, seed);
    parts.into_iter().map(|p| p.data).collect()
}

fn load_cfg(mix: &LoadMix) -> PipelineConfig {
    let mut cfg = PipelineConfig {
        total_codes: 16,
        k_clusters: 2,
        seed: mix.seed,
        ..Default::default()
    };
    // The controller advances virtual time by total_jobs·step; no armed
    // straggler deadline may ever fall inside that window.
    cfg.collect_timeout = Duration::from_secs(1 << 22);
    cfg.leader.fair_queue = mix.fair_queue;
    cfg
}

fn check_mix(mix: &LoadMix) -> Result<()> {
    if mix.clients.is_empty() {
        bail!("load mix has no clients");
    }
    if mix.step.is_zero() {
        bail!("load mix step must be > 0 (it is the virtual central duration)");
    }
    for (i, c) in mix.clients.iter().enumerate() {
        if c.submits == 0 {
            bail!("load mix client {i} submits no jobs");
        }
        if c.priority < 1 || c.priority > JobSpec::MAX_PRIORITY {
            bail!(
                "load mix client {i} priority {} out of 1..={}",
                c.priority,
                JobSpec::MAX_PRIORITY
            );
        }
    }
    Ok(())
}

// ─── the channel load generator ────────────────────────────────────────────

/// Run `mix` through the channel job server deterministically and report
/// throughput, per-tenant sojourn percentiles, utilization and the
/// fairness index (see the module docs for the scheme).
pub fn run_channel_load(mix: &LoadMix) -> Result<LoadReport> {
    run_channel_load_inner(mix, None)
}

/// [`run_channel_load`] with the reactor event-sourcing every event into
/// a fresh journal at `journal_path` (`fsync` per group commit when
/// asked). The report is built entirely from virtual time, so journaling
/// — which only ever spends *wall* time — must not move a single bit of
/// it: `benches/jobserver_load.rs` holds this run to bit identity with
/// the journal-off run and records only the wall-clock delta.
pub fn run_channel_load_journaled(
    mix: &LoadMix,
    journal_path: &Path,
    fsync: bool,
) -> Result<LoadReport> {
    run_channel_load_inner(mix, Some((journal_path, fsync)))
}

fn run_channel_load_inner(mix: &LoadMix, journal: Option<(&Path, bool)>) -> Result<LoadReport> {
    check_mix(mix)?;
    let total = mix.total_jobs();
    let mut cfg = load_cfg(mix);
    if let Some((_, fsync)) = journal {
        cfg.leader.journal_fsync = fsync;
    }

    let seq = Sequencer::new();
    let hook: CentralHook = {
        let seq = Arc::clone(&seq);
        Arc::new(move |run: u32| seq.enter_and_wait(run))
    };
    let opts = HarnessOpts {
        server: ServerOpts {
            // one service slot, one worker: pops are strictly sequential,
            // so central-entry order *is* the queue discipline's order
            max_jobs: 1,
            queue_depth: total,
            allow_label_pull: false,
            central_workers: 1,
            client_limit: Some(mix.clients.len() as u64),
        },
        faults: Vec::new(),
        central_hook: Some(hook),
        hangups: vec![],
    };
    let mut harness = match journal {
        Some((path, _)) => serve_channel_journaled(load_workload(mix.seed), &cfg, opts, path, None)?,
        None => serve_channel(load_workload(mix.seed), &cfg, opts)?,
    };

    // One connection per tenant, mix order → client ids 1..=n.
    let clients: Vec<_> = mix.clients.iter().map(|_| harness.client()).collect();

    // Submit every budget up front at virtual t0, round-robin across the
    // tenants — the one canonical interleaving both disciplines see.
    let mut run_owner: HashMap<u32, usize> = HashMap::new();
    let mut remaining: Vec<usize> = mix.clients.iter().map(|c| c.submits).collect();
    let mut submitted = 0;
    while submitted < total {
        for (i, client) in clients.iter().enumerate() {
            if remaining[i] == 0 {
                continue;
            }
            remaining[i] -= 1;
            let mut spec = spec_from_config(&cfg);
            spec.priority = mix.clients[i].priority;
            let acc = client
                .submit_tracked(&spec)
                .with_context(|| format!("load submit for client {}", i + 1))?;
            run_owner.insert(acc.run, i);
            submitted += 1;
        }
    }

    // Drain: one central released per virtual step. The k-th pop (0-based)
    // completes its central at virtual (k+1)·step — its sojourn, since
    // every submit happened at t0.
    let step_ns = mix.step.as_nanos() as u64;
    let mut pops: Vec<(u32, u64)> = Vec::with_capacity(total);
    for k in 0..total {
        let run = seq.wait_entered();
        harness.tick(mix.step);
        pops.push((run, (k as u64 + 1) * step_ns));
        seq.release(run);
    }

    // Every central was released, so every run completes.
    for &(run, _) in &pops {
        clients[run_owner[&run]]
            .await_done(run)
            .with_context(|| format!("load run {run} failed"))?;
    }
    drop(clients);
    let (stats, _outcomes) = harness.join()?;

    Ok(report_from_pops(mix, &pops, &run_owner, stats))
}

fn report_from_pops(
    mix: &LoadMix,
    pops: &[(u32, u64)],
    run_owner: &HashMap<u32, usize>,
    stats: ServerStats,
) -> LoadReport {
    let n = mix.clients.len();
    let mut sojourns: Vec<Vec<u64>> = vec![Vec::new(); n];
    for &(run, stamp) in pops {
        sojourns[run_owner[&run]].push(stamp);
    }

    // Fairness window: service counts at the pop where the first tenant
    // drains (all tenants backlogged until then, since every submit is at
    // t0), normalized by weight.
    let mut served = vec![0usize; n];
    let mut window = served.clone();
    for &(run, _) in pops {
        let i = run_owner[&run];
        served[i] += 1;
        if served[i] == mix.clients[i].submits {
            window = served.clone();
            break;
        }
    }
    let shares: Vec<f64> = window
        .iter()
        .zip(&mix.clients)
        .map(|(&s, c)| s as f64 / c.priority as f64)
        .collect();
    let fairness = jain_index(&shares);

    let step_ns = mix.step.as_nanos() as u64;
    let makespan_ns = pops.last().map(|&(_, t)| t).unwrap_or(0);
    let (throughput, utilization) = if makespan_ns == 0 {
        (0.0, 0.0)
    } else {
        (
            stats.completed as f64 / (makespan_ns as f64 / 1e9),
            (stats.completed * step_ns) as f64 / makespan_ns as f64,
        )
    };

    let per_client = sojourns
        .iter()
        .zip(&mix.clients)
        .enumerate()
        .map(|(i, (s, c))| {
            let mut s = s.clone();
            s.sort_unstable();
            let mean = if s.is_empty() {
                0
            } else {
                s.iter().sum::<u64>() / s.len() as u64
            };
            ClientLatency {
                client: i as u64 + 1,
                priority: c.priority,
                jobs: s.len(),
                mean_ns: mean,
                p50_ns: percentile(&s, 50.0),
                p95_ns: percentile(&s, 95.0),
                p99_ns: percentile(&s, 99.0),
            }
        })
        .collect();

    LoadReport {
        fair_queue: mix.fair_queue,
        jobs: mix.total_jobs(),
        completed: stats.completed,
        rejected: stats.rejected,
        makespan_ns,
        throughput_jobs_per_sec: throughput,
        utilization,
        fairness,
        per_client,
    }
}

// ─── the chaos mix ─────────────────────────────────────────────────────────

/// How one chaos-mix run ended. `Done` keeps only the deterministic
/// fields of a [`JobReport`](crate::net::JobReport) — `central_ns` and
/// `wall_ns` are real time — so a survivor compares bit for bit against
/// its fault-free twin.
#[derive(Clone, Debug, PartialEq)]
pub enum ChaosRun {
    Done { n_codes: u32, sigma: f64, per_site: Vec<LinkReport> },
    Failed { err: String },
}

/// The scripted six-job, three-tenant chaos plan, `(tenant, seed,
/// priority)` per submission. Tenant 2 submits seed 55 twice so the
/// surviving runs also exercise the sites' DML result cache under fire.
const CHAOS_PLAN: [(usize, u64, u32); 6] =
    [(0, 21, 1), (1, 33, 2), (2, 55, 4), (1, 34, 2), (2, 55, 4), (0, 22, 1)];

/// What one chaos (or fault-free twin) pass observed.
#[derive(Clone, Debug)]
pub struct ChaosReport {
    /// Run ids, submission order (the leader assigns 1..=6).
    pub runs: Vec<u32>,
    /// How each run ended, submission order.
    pub results: Vec<ChaosRun>,
    /// Central-entry order the sequencer observed: 6 in the twin; 4 under
    /// faults (the straggler never registers, the severed run never
    /// reaches its central).
    pub pop_order: Vec<u32>,
    pub completed: u64,
    pub failed: u64,
    pub rejected: u64,
    /// Per-site `(runs_served, aborted_runs, dml_passes, cache_hits)`.
    pub sessions: Vec<(usize, usize, usize, usize)>,
    /// Records the run journal held after the mix (0 for the twin, which
    /// does not journal).
    pub journal_records: u64,
}

fn chaos_cfg(seed: u64) -> PipelineConfig {
    let mut cfg = PipelineConfig {
        total_codes: 32,
        k_clusters: 4,
        seed,
        ..Default::default()
    };
    // Armed straggler deadlines fire only when the script advances the
    // virtual clock past them — 5 s is the window the chaos tick jumps.
    cfg.collect_timeout = Duration::from_secs(5);
    cfg.leader.fair_queue = true;
    cfg
}

/// Run [`CHAOS_PLAN`] through a journaling channel leader under fire:
/// both sites silently stall run 1 (the straggler deadline, not a
/// site-down, must catch it), the leader is crashed and recovered the
/// moment all six admissions are on record, and site 1's uplink is
/// severed at the last pop of the recovered DRR backlog. Exactly the two
/// faulted runs fail; the four survivors must match [`run_chaos_twin`]
/// bit for bit.
pub fn run_chaos_mix(journal_path: &Path) -> Result<ChaosReport> {
    run_chaos_inner(Some(journal_path))
}

/// The fault-free twin of [`run_chaos_mix`]: same plan, same harness, no
/// faults, no journal, no crash — the reference the survivors are held
/// to, and the proof the plan itself is clean (six completions, one DML
/// cache hit per site for the repeated seed-55 spec).
pub fn run_chaos_twin() -> Result<ChaosReport> {
    run_chaos_inner(None)
}

fn run_chaos_inner(journal: Option<&Path>) -> Result<ChaosReport> {
    let cfg = chaos_cfg(CHAOS_PLAN[0].1);
    let ds = gmm::paper_mixture_10d(600, 0.1, 21);
    let datasets: Vec<Dataset> =
        scenario::split(&ds, Scenario::D3, 2, 21).into_iter().map(|p| p.data).collect();

    let seq = Sequencer::new();
    let hook: CentralHook = {
        let seq = Arc::clone(&seq);
        Arc::new(move |run: u32| seq.enter_and_wait(run))
    };
    let chaos = journal.is_some();
    let faults = if chaos {
        vec![
            // Run 1 stalls silently at both sites: the 6 s tick must fire
            // its straggler deadline while five jobs sit in the backlog.
            Fault::DropRunFrames { site: 0, run: 1 },
            Fault::DropRunFrames { site: 1, run: 1 },
            // Sever site 1 at its 10th uplink frame: the swallowed run-1
            // registration (1) plus four fully served pops (2 frames
            // each) put frame 10 at the *last* pop's registration. The
            // outage must strike the final pop — a severed channel link
            // never redials, so any job still queued behind it would wait
            // forever.
            Fault::DropSiteAfter { site: 1, frames: 10 },
        ]
    } else {
        Vec::new()
    };
    let opts = HarnessOpts {
        server: ServerOpts {
            max_jobs: 1,
            queue_depth: 8,
            allow_label_pull: false,
            central_workers: 1,
            client_limit: Some(3),
        },
        faults,
        central_hook: Some(hook),
        hangups: vec![],
    };
    let mut harness = match journal {
        Some(path) => {
            let _ = std::fs::remove_file(path);
            // Crash as soon as the journal holds all six admissions —
            // ClientSubmit+Admitted per job plus run 1's Started = 13
            // records — so recovery must rebuild one active run (already
            // expired on the journal's timeline) and a five-deep DRR
            // backlog.
            serve_channel_journaled(datasets, &cfg, opts, path, Some(13))?
        }
        None => serve_channel(datasets, &cfg, opts)?,
    };

    // Three tenants, mix order → client ids 1..=3.
    let clients: Vec<_> = (0..3).map(|_| harness.client()).collect();
    let ticker = harness.ticker();
    let script = {
        let seq = Arc::clone(&seq);
        std::thread::spawn(move || -> Result<(Vec<u32>, Vec<u32>, Vec<ChaosRun>)> {
            let mut runs = Vec::new();
            for &(owner, seed, pri) in &CHAOS_PLAN {
                let mut spec = spec_from_config(&chaos_cfg(seed));
                spec.priority = pri;
                let acc = clients[owner]
                    .submit_tracked(&spec)
                    .with_context(|| format!("chaos submit seed {seed}"))?;
                runs.push(acc.run);
            }
            // Under faults run 1 is stalled at both sites, so jumping past
            // the 5 s collect window fails it and frees the slot for the
            // backlog. The twin must NOT tick: its run 1 is computing real
            // DML and would race this same deadline until its codebooks
            // arrive.
            if chaos {
                ticker.tick(Duration::from_secs(6));
            }
            let centrals = if chaos { 4 } else { CHAOS_PLAN.len() };
            let mut pop_order = Vec::new();
            for _ in 0..centrals {
                let run = seq.wait_entered();
                pop_order.push(run);
                seq.release(run);
            }
            let mut results = Vec::new();
            for (i, &run) in runs.iter().enumerate() {
                let owner = CHAOS_PLAN[i].0;
                results.push(match clients[owner].await_done(run) {
                    Ok(r) => ChaosRun::Done {
                        n_codes: r.n_codes,
                        sigma: r.sigma,
                        per_site: r.per_site,
                    },
                    Err(e) => ChaosRun::Failed { err: format!("{e:#}") },
                });
            }
            drop(clients);
            Ok((runs, pop_order, results))
        })
    };

    if chaos {
        harness.crash_and_restart()?;
    }
    let (runs, pop_order, results) =
        script.join().map_err(|_| anyhow::anyhow!("chaos script thread panicked"))??;
    let (stats, outcomes) = harness.join()?;

    let journal_records = match journal {
        Some(path) => super::journal::recover(path)?.records.len() as u64,
        None => 0,
    };
    Ok(ChaosReport {
        runs,
        results,
        pop_order,
        completed: stats.completed,
        failed: stats.failed,
        rejected: stats.rejected,
        sessions: outcomes
            .iter()
            .map(|o| (o.runs_served, o.aborted_runs, o.dml_passes, o.cache_hits))
            .collect(),
        journal_records,
    })
}

// ─── the adversarial-tenant mix ────────────────────────────────────────────

/// A flooding tenant against two paying ones, with per-client
/// token-bucket admission (`[leader] admit_rate` / `admit_burst`) in
/// front of the DRR queue.
#[derive(Clone, Copy, Debug)]
pub struct AdversarialMix {
    /// Jobs each paying tenant submits (priority 4).
    pub paying_jobs: usize,
    /// Submits the flooder attempts (priority 1); 0 = the flooder-free
    /// twin.
    pub flood_submits: usize,
    /// `[leader] admit_rate`, tokens per second per client.
    pub admit_rate: f64,
    /// `[leader] admit_burst` — the flood is clipped to exactly this many
    /// admissions, since the virtual clock is frozen while submitting.
    pub admit_burst: usize,
    /// Virtual duration of one central step.
    pub step: Duration,
    /// Seed for the site dataset and the job specs.
    pub seed: u64,
}

impl AdversarialMix {
    /// The recorded scenario: 6 jobs per paying tenant, a 20-submit flood
    /// clipped at a burst of 8, one token per second.
    pub fn canonical(flood: bool) -> AdversarialMix {
        AdversarialMix {
            paying_jobs: 6,
            flood_submits: if flood { 20 } else { 0 },
            admit_rate: 1.0,
            admit_burst: 8,
            step: Duration::from_millis(10),
            seed: 21,
        }
    }
}

/// What one adversarial pass measured. Deterministic like [`LoadReport`]:
/// `PartialEq` is exact, including the fairness f64.
#[derive(Clone, Debug, PartialEq)]
pub struct AdversarialReport {
    /// Flood submits the token bucket admitted (= `min(flood_submits,
    /// admit_burst)` at a frozen clock).
    pub flooder_accepted: usize,
    /// One `(code, detail)` per refused flood submit, refusal order —
    /// every one must be `RateLimited` with a positive nanosecond wait.
    pub flooder_rejects: Vec<(RejectCode, u64)>,
    /// Paying tenants' sojourn statistics (clients 1 and 2, priority 4).
    pub paying: Vec<ClientLatency>,
    /// The flooder's own statistics (client 3, priority 1; zeros in the
    /// flooder-free twin).
    pub flooder: ClientLatency,
    pub completed: u64,
    pub rejected: u64,
    pub makespan_ns: u64,
    /// Jain index over weight-normalized service at the first tenant
    /// drain, flood-less tenants excluded.
    pub fairness: f64,
}

impl AdversarialReport {
    /// Stable hand-rolled JSON, same contract as [`LoadReport::to_json`].
    pub fn to_json(&self) -> String {
        let lat = |c: &ClientLatency| {
            format!(
                "{{\"client\": {}, \"priority\": {}, \"jobs\": {}, \
                 \"mean_ns\": {}, \"p50_ns\": {}, \"p95_ns\": {}, \"p99_ns\": {}}}",
                c.client, c.priority, c.jobs, c.mean_ns, c.p50_ns, c.p95_ns, c.p99_ns
            )
        };
        let rejects: Vec<String> = self
            .flooder_rejects
            .iter()
            .map(|(code, detail)| format!("{{\"code\": \"{code:?}\", \"detail_ns\": {detail}}}"))
            .collect();
        let paying: Vec<String> = self.paying.iter().map(|c| format!("    {}", lat(c))).collect();
        format!(
            "{{\n  \"flooder_accepted\": {},\n  \"flooder_rejects\": [{}],\n  \
             \"paying\": [\n{}\n  ],\n  \"flooder\": {},\n  \"completed\": {},\n  \
             \"rejected\": {},\n  \"makespan_ns\": {},\n  \"fairness\": {}\n}}",
            self.flooder_accepted,
            rejects.join(", "),
            paying.join(",\n"),
            lat(&self.flooder),
            self.completed,
            self.rejected,
            self.makespan_ns,
            self.fairness
        )
    }
}

const PAYING_PRIORITY: u32 = 4;
const FLOODER_PRIORITY: u32 = 1;

/// Drive `mix` through the channel leader with admission on: the flooder
/// fires its whole volley first (worst case for the paying tenants —
/// every admitted flood job is already queued when they arrive), then the
/// paying tenants submit round-robin, and the drain stamps sojourns in
/// virtual time exactly like [`run_channel_load`].
pub fn run_adversarial_mix(mix: &AdversarialMix) -> Result<AdversarialReport> {
    if mix.paying_jobs == 0 {
        bail!("adversarial mix needs paying jobs — they are the measurement");
    }
    if mix.step.is_zero() {
        bail!("adversarial mix step must be > 0");
    }
    if !mix.admit_rate.is_finite() || mix.admit_rate <= 0.0 {
        bail!("adversarial mix admit_rate must be > 0 — admission off defeats the drill");
    }
    if mix.admit_burst < 1 {
        bail!("adversarial mix admit_burst must be ≥ 1");
    }
    if mix.paying_jobs > mix.admit_burst {
        bail!(
            "paying tenants must fit the admission burst ({} jobs > burst {})",
            mix.paying_jobs,
            mix.admit_burst
        );
    }

    let mut cfg = PipelineConfig {
        total_codes: 16,
        k_clusters: 2,
        seed: mix.seed,
        ..Default::default()
    };
    cfg.collect_timeout = Duration::from_secs(1 << 22);
    cfg.leader.fair_queue = true;
    cfg.leader.admit_rate = mix.admit_rate;
    cfg.leader.admit_burst = mix.admit_burst;

    let seq = Sequencer::new();
    let hook: CentralHook = {
        let seq = Arc::clone(&seq);
        Arc::new(move |run: u32| seq.enter_and_wait(run))
    };
    let opts = HarnessOpts {
        server: ServerOpts {
            max_jobs: 1,
            queue_depth: 2 * mix.paying_jobs + mix.flood_submits,
            allow_label_pull: false,
            central_workers: 1,
            client_limit: Some(3),
        },
        faults: Vec::new(),
        central_hook: Some(hook),
        hangups: vec![],
    };
    let mut harness = serve_channel(load_workload(mix.seed), &cfg, opts)?;

    // Client ids 1 and 2 pay; 3 floods.
    let clients: Vec<_> = (0..3).map(|_| harness.client()).collect();
    let spec_for = |priority: u32| {
        let mut spec = spec_from_config(&cfg);
        spec.priority = priority;
        spec
    };

    // The flood: all attempts up front. The clock is frozen, so the
    // bucket never refills mid-volley — exactly `admit_burst` admissions,
    // then typed rate-limit refusals.
    let mut run_owner: HashMap<u32, usize> = HashMap::new();
    let mut flooder_rejects = Vec::new();
    for _ in 0..mix.flood_submits {
        match clients[2].try_submit_tracked(&spec_for(FLOODER_PRIORITY))? {
            SubmitOutcome::Accepted(acc) => {
                run_owner.insert(acc.run, 2);
            }
            SubmitOutcome::Rejected { code, detail, .. } => {
                flooder_rejects.push((code, detail));
            }
        }
    }
    let flooder_accepted = run_owner.len();

    // The paying tenants, round-robin; their budgets fit their buckets,
    // so every submit must be admitted (submit_tracked errors otherwise).
    for k in 0..2 * mix.paying_jobs {
        let owner = k % 2;
        let acc = clients[owner]
            .submit_tracked(&spec_for(PAYING_PRIORITY))
            .with_context(|| format!("paying tenant {} submit", owner + 1))?;
        run_owner.insert(acc.run, owner);
    }

    // Drain every admitted job, one central per virtual step.
    let step_ns = mix.step.as_nanos() as u64;
    let total = run_owner.len();
    let mut pops: Vec<(u32, u64)> = Vec::with_capacity(total);
    for k in 0..total {
        let run = seq.wait_entered();
        harness.tick(mix.step);
        pops.push((run, (k as u64 + 1) * step_ns));
        seq.release(run);
    }
    for &(run, _) in &pops {
        clients[run_owner[&run]]
            .await_done(run)
            .with_context(|| format!("adversarial run {run} failed"))?;
    }
    drop(clients);
    let (stats, _outcomes) = harness.join()?;

    let mut sojourns: Vec<Vec<u64>> = vec![Vec::new(); 3];
    for &(run, stamp) in &pops {
        sojourns[run_owner[&run]].push(stamp);
    }
    let budgets = [mix.paying_jobs, mix.paying_jobs, flooder_accepted];
    let weights = [PAYING_PRIORITY, PAYING_PRIORITY, FLOODER_PRIORITY];

    // Fairness window at the first tenant drain, as in the plain load
    // report — but only over tenants that actually submitted.
    let mut served = [0usize; 3];
    let mut window = served;
    for &(run, _) in &pops {
        let i = run_owner[&run];
        served[i] += 1;
        if served[i] == budgets[i] {
            window = served;
            break;
        }
    }
    let shares: Vec<f64> = (0..3)
        .filter(|&i| budgets[i] > 0)
        .map(|i| window[i] as f64 / weights[i] as f64)
        .collect();

    let latency = |i: usize| {
        let mut s = sojourns[i].clone();
        s.sort_unstable();
        let mean = if s.is_empty() { 0 } else { s.iter().sum::<u64>() / s.len() as u64 };
        ClientLatency {
            client: i as u64 + 1,
            priority: weights[i],
            jobs: s.len(),
            mean_ns: mean,
            p50_ns: percentile(&s, 50.0),
            p95_ns: percentile(&s, 95.0),
            p99_ns: percentile(&s, 99.0),
        }
    };

    Ok(AdversarialReport {
        flooder_accepted,
        flooder_rejects,
        paying: vec![latency(0), latency(1)],
        flooder: latency(2),
        completed: stats.completed,
        rejected: stats.rejected,
        makespan_ns: pops.last().map(|&(_, t)| t).unwrap_or(0),
        fairness: jain_index(&shares),
    })
}

// ─── the TCP twin ──────────────────────────────────────────────────────────

/// What the TCP twin measures: wall-clock numbers over real loopback
/// sockets — real, therefore not part of the deterministic BENCH record.
#[derive(Clone, Copy, Debug)]
pub struct TcpLoadReport {
    pub jobs: usize,
    pub completed: u64,
    /// Submit of the first job to the last `JOBDONE`.
    pub wall: Duration,
    pub throughput_jobs_per_sec: f64,
}

/// Push the identical mix through a real TCP job server: persistent site
/// sessions, a `serve_jobs` leader on a loopback listener, one
/// `JobClient` connection per tenant. Same round-robin submission, same
/// specs, real centrals (no sequencer) and real time.
pub fn run_tcp_load(mix: &LoadMix) -> Result<TcpLoadReport> {
    check_mix(mix)?;
    let total = mix.total_jobs();
    let mut cfg = load_cfg(mix);
    let timeouts = cfg.net.tcp_timeouts();

    let mut addrs = Vec::new();
    let mut site_threads = Vec::new();
    for data in load_workload(mix.seed) {
        let listener = SiteListener::bind("127.0.0.1:0").context("bind site listener")?;
        addrs.push(listener.local_addr()?.to_string());
        let limits = cfg.site;
        let t = timeouts;
        site_threads.push(std::thread::spawn(move || {
            let conn = listener.accept(&t)?;
            let net = SiteNet::over(Box::new(conn));
            site::session(&net, &data, None, limits, |_| {})
        }));
    }
    cfg.net.sites = addrs;

    let opts = ServerOpts {
        max_jobs: 1,
        queue_depth: total,
        allow_label_pull: false,
        central_workers: 1,
        client_limit: Some(mix.clients.len() as u64),
    };
    let listener = std::net::TcpListener::bind("127.0.0.1:0").context("bind job listener")?;
    let leader_addr = listener.local_addr()?.to_string();
    let server = std::thread::spawn({
        let cfg = cfg.clone();
        let opts = opts.clone();
        move || serve_jobs(&cfg, &opts, listener)
    });

    let clients: Vec<JobClient> = mix
        .clients
        .iter()
        .map(|_| JobClient::connect(&leader_addr, &timeouts))
        .collect::<Result<_>>()?;

    let t0 = Instant::now();
    let mut runs: Vec<(usize, u32)> = Vec::with_capacity(total);
    let mut remaining: Vec<usize> = mix.clients.iter().map(|c| c.submits).collect();
    let mut submitted = 0;
    while submitted < total {
        for (i, client) in clients.iter().enumerate() {
            if remaining[i] == 0 {
                continue;
            }
            remaining[i] -= 1;
            let mut spec = spec_from_config(&cfg);
            spec.priority = mix.clients[i].priority;
            let acc = client.submit_tracked(&spec)?;
            runs.push((i, acc.run));
            submitted += 1;
        }
    }
    for &(owner, run) in &runs {
        clients[owner].await_done(run)?;
    }
    let wall = t0.elapsed();
    drop(clients);

    let stats = server.join().map_err(|_| anyhow::anyhow!("server thread panicked"))??;
    for t in site_threads {
        t.join().map_err(|_| anyhow::anyhow!("site thread panicked"))??;
    }

    Ok(TcpLoadReport {
        jobs: total,
        completed: stats.completed,
        wall,
        throughput_jobs_per_sec: stats.completed as f64 / wall.as_secs_f64().max(1e-9),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jain_index_equal_shares_is_one() {
        assert_eq!(jain_index(&[2.0, 2.0, 2.0]), 1.0);
        assert_eq!(jain_index(&[]), 1.0);
        assert_eq!(jain_index(&[0.0, 0.0]), 1.0);
    }

    #[test]
    fn jain_index_penalizes_skew() {
        let j = jain_index(&[3.0, 1.5, 0.75]);
        assert!(j < 0.85, "skewed shares should score well below 1: {j}");
        assert!(j > 0.0);
        // one tenant taking everything → 1/n
        let j = jain_index(&[5.0, 0.0, 0.0]);
        assert!((j - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_is_nearest_rank() {
        let s = [10, 20, 30, 40];
        assert_eq!(percentile(&s, 50.0), 20);
        assert_eq!(percentile(&s, 95.0), 40);
        assert_eq!(percentile(&s, 99.0), 40);
        assert_eq!(percentile(&s, 1.0), 10);
        assert_eq!(percentile(&[], 50.0), 0);
        assert_eq!(percentile(&[7], 99.0), 7);
    }

    #[test]
    fn skewed_three_mix_shape() {
        let mix = LoadMix::skewed_three(true);
        assert_eq!(mix.total_jobs(), 21);
        assert!(check_mix(&mix).is_ok());
        let bad = LoadMix {
            clients: vec![ClientLoad { submits: 1, priority: 0 }],
            ..LoadMix::skewed_three(false)
        };
        assert!(check_mix(&bad).is_err());
    }

    #[test]
    fn chaos_plan_shape() {
        assert_eq!(CHAOS_PLAN.len(), 6);
        for &(owner, _, pri) in &CHAOS_PLAN {
            assert!(owner < 3);
            assert!((1..=JobSpec::MAX_PRIORITY).contains(&pri));
        }
        // the repeated spec that exercises the sites' DML cache under fire
        assert_eq!(CHAOS_PLAN.iter().filter(|&&(_, s, _)| s == 55).count(), 2);
        // every tenant owns at least one surviving candidate
        for owner in 0..3 {
            assert!(CHAOS_PLAN.iter().any(|&(o, _, _)| o == owner));
        }
    }

    #[test]
    fn adversarial_mix_is_validated() {
        let ok = AdversarialMix::canonical(true);
        assert_eq!(ok.flood_submits, 20);
        assert_eq!(AdversarialMix::canonical(false).flood_submits, 0);
        let cases = [
            AdversarialMix { paying_jobs: 0, ..ok },
            AdversarialMix { step: Duration::ZERO, ..ok },
            AdversarialMix { admit_rate: 0.0, ..ok },
            AdversarialMix { admit_rate: f64::NAN, ..ok },
            AdversarialMix { admit_burst: 0, ..ok },
            // paying budgets must clear admission untouched
            AdversarialMix { paying_jobs: 9, ..ok },
        ];
        for bad in cases {
            assert!(run_adversarial_mix(&bad).is_err(), "{bad:?} should be refused");
        }
    }

    #[test]
    fn adversarial_json_is_stable() {
        let lat = ClientLatency {
            client: 1,
            priority: 4,
            jobs: 6,
            mean_ns: 5,
            p50_ns: 4,
            p95_ns: 9,
            p99_ns: 9,
        };
        let report = AdversarialReport {
            flooder_accepted: 8,
            flooder_rejects: vec![(RejectCode::RateLimited, 1_000_000_000)],
            paying: vec![lat, ClientLatency { client: 2, ..lat }],
            flooder: ClientLatency { client: 3, priority: 1, ..lat },
            completed: 20,
            rejected: 12,
            makespan_ns: 200,
            fairness: 0.5,
        };
        let a = report.to_json();
        assert_eq!(a, report.clone().to_json());
        assert!(a.contains("\"code\": \"RateLimited\""), "{a}");
        assert!(a.contains("\"detail_ns\": 1000000000"), "{a}");
        assert!(a.contains("\"fairness\": 0.5"), "{a}");
    }

    #[test]
    fn report_json_is_stable() {
        let report = LoadReport {
            fair_queue: true,
            jobs: 2,
            completed: 2,
            rejected: 0,
            makespan_ns: 20,
            throughput_jobs_per_sec: 1e8,
            utilization: 1.0,
            fairness: 0.5,
            per_client: vec![ClientLatency {
                client: 1,
                priority: 1,
                jobs: 2,
                mean_ns: 15,
                p50_ns: 10,
                p95_ns: 20,
                p99_ns: 20,
            }],
        };
        let a = report.to_json();
        assert_eq!(a, report.clone().to_json());
        assert!(a.contains("\"fairness\": 0.5"), "{a}");
        assert!(a.contains("\"p95_ns\": 20"), "{a}");
    }
}
