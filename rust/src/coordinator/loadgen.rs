//! Deterministic load generator for the job-serving leader.
//!
//! The channel generator ([`run_channel_load`]) drives the *real* serving
//! stack — reactor, `JobQueue` (FIFO or DRR), `RunMachine`s, central
//! worker pool, real site sessions — through the socket-free harness
//! ([`super::harness`]), with every source of nondeterminism pinned:
//!
//! * every tenant submits its whole budget up front, at virtual t0, in a
//!   fixed round-robin interleaving (each submit waits for its accept, so
//!   arrival order at the reactor *is* submission order);
//! * `max_jobs = 1` and one central worker make queue pops strictly
//!   sequential — the observed central-entry order is exactly the queue
//!   discipline's dequeue order;
//! * a [`CentralHook`] sequencer holds each central at the gate until the
//!   controller advances the [`VirtualClock`](crate::net::channel) by one
//!   `step` and releases it, so the k-th pop completes its central at
//!   virtual `(k+1)·step` — job sojourns are a pure function of dequeue
//!   order, never of scheduler timing.
//!
//! The same mix therefore always produces the same [`LoadReport`]
//! (bit-for-bit, including the f64s): `benches/jobserver_load.rs` records
//! it as `BENCH_jobserver.json`, and `rust/tests/loadgen.rs` pins both
//! the determinism and the FIFO-vs-DRR fairness ordering. The TCP twin
//! ([`run_tcp_load`]) pushes the identical mix through a real loopback
//! job server for wall-clock numbers (real, therefore *not* in the
//! deterministic report). `docs/TESTING.md` has the how-to.

use std::collections::{HashMap, HashSet, VecDeque};
use std::path::Path;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::config::PipelineConfig;
use crate::data::scenario::{self, Scenario};
use crate::data::{gmm, Dataset};
use crate::net::tcp::SiteListener;
use crate::net::{JobSpec, SiteNet};
use crate::site;

use super::harness::{serve_channel, serve_channel_journaled, HarnessOpts};
use super::server::{serve_jobs, CentralHook, JobClient, ServerOpts, ServerStats};
use super::spec_from_config;

// ─── mixes ─────────────────────────────────────────────────────────────────

/// One tenant in a load mix.
#[derive(Clone, Copy, Debug)]
pub struct ClientLoad {
    /// Jobs this tenant submits (all up front, at virtual t0).
    pub submits: usize,
    /// Priority its specs carry — the DRR weight, `1..=MAX_PRIORITY`.
    pub priority: u32,
}

/// A deterministic load-generator scenario.
#[derive(Clone, Debug)]
pub struct LoadMix {
    /// The tenants; client ids are assigned 1.. in this order.
    pub clients: Vec<ClientLoad>,
    /// Queue discipline under test (`[leader] fair_queue`).
    pub fair_queue: bool,
    /// Virtual duration of one central step — the queue drains one job
    /// per `step`.
    pub step: Duration,
    /// Seed for the tiny site dataset and the job specs.
    pub seed: u64,
}

impl LoadMix {
    /// Total jobs across every tenant.
    pub fn total_jobs(&self) -> usize {
        self.clients.iter().map(|c| c.submits).sum()
    }

    /// The canonical skewed 3-tenant mix the BENCH trajectory records: a
    /// heavy low-priority tenant (12 jobs, weight 1), a medium one
    /// (6 jobs, weight 2), and a light high-priority one (3 jobs,
    /// weight 4). FIFO serves them in arrival order; DRR should serve
    /// them weight-proportionally.
    pub fn skewed_three(fair_queue: bool) -> LoadMix {
        LoadMix {
            clients: vec![
                ClientLoad { submits: 12, priority: 1 },
                ClientLoad { submits: 6, priority: 2 },
                ClientLoad { submits: 3, priority: 4 },
            ],
            fair_queue,
            step: Duration::from_millis(10),
            seed: 21,
        }
    }
}

// ─── reports ───────────────────────────────────────────────────────────────

/// Virtual-time queue-sojourn statistics for one tenant.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClientLatency {
    /// Client id (1-based, mix order).
    pub client: u64,
    /// The priority/weight its jobs carried.
    pub priority: u32,
    /// Jobs it had served.
    pub jobs: usize,
    /// Mean/percentile sojourn — submit (virtual t0) to central
    /// completion — in virtual nanoseconds (nearest-rank percentiles).
    pub mean_ns: u64,
    pub p50_ns: u64,
    pub p95_ns: u64,
    pub p99_ns: u64,
}

/// What one deterministic channel load run measured. `PartialEq` is exact
/// (including the f64s): same mix ⇒ same report, bit for bit.
#[derive(Clone, Debug, PartialEq)]
pub struct LoadReport {
    /// Queue discipline the run used.
    pub fair_queue: bool,
    /// Jobs submitted.
    pub jobs: usize,
    /// Jobs that completed / submissions refused (from [`ServerStats`]).
    pub completed: u64,
    pub rejected: u64,
    /// Virtual time from t0 to the last central completion.
    pub makespan_ns: u64,
    /// Completed jobs per virtual second.
    pub throughput_jobs_per_sec: f64,
    /// Served central time over makespan (1.0 = the single service slot
    /// never idled; a lost job shows up as a dip).
    pub utilization: f64,
    /// Jain fairness index over weight-normalized service counts, taken
    /// at the instant the first tenant drains (every tenant is backlogged
    /// until then). 1.0 = perfectly weight-proportional service.
    pub fairness: f64,
    /// Per-tenant sojourn statistics, mix order.
    pub per_client: Vec<ClientLatency>,
}

impl LoadReport {
    /// Hand-rolled JSON (the repo's runtime JSON module is a parser, not
    /// a serializer): stable key order, shortest-roundtrip f64s — the
    /// bytes are as deterministic as the report.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"fair_queue\": {},\n", self.fair_queue));
        s.push_str(&format!("  \"jobs\": {},\n", self.jobs));
        s.push_str(&format!("  \"completed\": {},\n", self.completed));
        s.push_str(&format!("  \"rejected\": {},\n", self.rejected));
        s.push_str(&format!("  \"makespan_ns\": {},\n", self.makespan_ns));
        s.push_str(&format!(
            "  \"throughput_jobs_per_sec\": {},\n",
            self.throughput_jobs_per_sec
        ));
        s.push_str(&format!("  \"utilization\": {},\n", self.utilization));
        s.push_str(&format!("  \"fairness\": {},\n", self.fairness));
        s.push_str("  \"per_client\": [\n");
        for (i, c) in self.per_client.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"client\": {}, \"priority\": {}, \"jobs\": {}, \
                 \"mean_ns\": {}, \"p50_ns\": {}, \"p95_ns\": {}, \"p99_ns\": {}}}{}\n",
                c.client,
                c.priority,
                c.jobs,
                c.mean_ns,
                c.p50_ns,
                c.p95_ns,
                c.p99_ns,
                if i + 1 < self.per_client.len() { "," } else { "" }
            ));
        }
        s.push_str("  ]\n}");
        s
    }
}

// ─── metric helpers ────────────────────────────────────────────────────────

/// Jain's fairness index J(x) = (Σx)² / (n·Σx²) ∈ (0, 1]; 1.0 when every
/// share is equal. An all-zero vector is vacuously fair.
pub fn jain_index(shares: &[f64]) -> f64 {
    if shares.is_empty() {
        return 1.0;
    }
    let sum: f64 = shares.iter().sum();
    let sq: f64 = shares.iter().map(|x| x * x).sum();
    if sq == 0.0 {
        return 1.0;
    }
    (sum * sum) / (shares.len() as f64 * sq)
}

/// Nearest-rank percentile of an ascending-sorted sample (0 if empty).
fn percentile(sorted: &[u64], pct: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = ((pct / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

// ─── central sequencer ─────────────────────────────────────────────────────

/// Serializes central steps through the [`CentralHook`]: each worker
/// announces its run and blocks until the controller releases it, so the
/// controller observes the exact dequeue order and stamps each pop
/// against the virtual clock.
struct Sequencer {
    state: Mutex<SeqState>,
    entered_cv: Condvar,
    released_cv: Condvar,
}

#[derive(Default)]
struct SeqState {
    entered: VecDeque<u32>,
    released: HashSet<u32>,
}

impl Sequencer {
    fn new() -> Arc<Sequencer> {
        Arc::new(Sequencer {
            state: Mutex::new(SeqState::default()),
            entered_cv: Condvar::new(),
            released_cv: Condvar::new(),
        })
    }

    /// Worker side: announce `run` entered its central, wait for release.
    fn enter_and_wait(&self, run: u32) {
        let mut st = self.state.lock().unwrap();
        st.entered.push_back(run);
        self.entered_cv.notify_all();
        while !st.released.remove(&run) {
            st = self.released_cv.wait(st).unwrap();
        }
    }

    /// Controller side: next run that reached its central step.
    fn wait_entered(&self) -> u32 {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(run) = st.entered.pop_front() {
                return run;
            }
            st = self.entered_cv.wait(st).unwrap();
        }
    }

    /// Controller side: let `run`'s central compute.
    fn release(&self, run: u32) {
        let mut st = self.state.lock().unwrap();
        st.released.insert(run);
        self.released_cv.notify_all();
    }
}

// ─── workload + config ─────────────────────────────────────────────────────

/// The tiny single-site dataset every load job clusters — small enough
/// that a full serve of a 21-job mix stays test-sized.
pub fn load_workload(seed: u64) -> Vec<Dataset> {
    let ds = gmm::paper_mixture_10d(240, 0.1, seed);
    let parts = scenario::split(&ds, Scenario::D3, 1, seed);
    parts.into_iter().map(|p| p.data).collect()
}

fn load_cfg(mix: &LoadMix) -> PipelineConfig {
    let mut cfg = PipelineConfig {
        total_codes: 16,
        k_clusters: 2,
        seed: mix.seed,
        ..Default::default()
    };
    // The controller advances virtual time by total_jobs·step; no armed
    // straggler deadline may ever fall inside that window.
    cfg.collect_timeout = Duration::from_secs(1 << 22);
    cfg.leader.fair_queue = mix.fair_queue;
    cfg
}

fn check_mix(mix: &LoadMix) -> Result<()> {
    if mix.clients.is_empty() {
        bail!("load mix has no clients");
    }
    if mix.step.is_zero() {
        bail!("load mix step must be > 0 (it is the virtual central duration)");
    }
    for (i, c) in mix.clients.iter().enumerate() {
        if c.submits == 0 {
            bail!("load mix client {i} submits no jobs");
        }
        if c.priority < 1 || c.priority > JobSpec::MAX_PRIORITY {
            bail!(
                "load mix client {i} priority {} out of 1..={}",
                c.priority,
                JobSpec::MAX_PRIORITY
            );
        }
    }
    Ok(())
}

// ─── the channel load generator ────────────────────────────────────────────

/// Run `mix` through the channel job server deterministically and report
/// throughput, per-tenant sojourn percentiles, utilization and the
/// fairness index (see the module docs for the scheme).
pub fn run_channel_load(mix: &LoadMix) -> Result<LoadReport> {
    run_channel_load_inner(mix, None)
}

/// [`run_channel_load`] with the reactor event-sourcing every event into
/// a fresh journal at `journal_path` (`fsync` per group commit when
/// asked). The report is built entirely from virtual time, so journaling
/// — which only ever spends *wall* time — must not move a single bit of
/// it: `benches/jobserver_load.rs` holds this run to bit identity with
/// the journal-off run and records only the wall-clock delta.
pub fn run_channel_load_journaled(
    mix: &LoadMix,
    journal_path: &Path,
    fsync: bool,
) -> Result<LoadReport> {
    run_channel_load_inner(mix, Some((journal_path, fsync)))
}

fn run_channel_load_inner(mix: &LoadMix, journal: Option<(&Path, bool)>) -> Result<LoadReport> {
    check_mix(mix)?;
    let total = mix.total_jobs();
    let mut cfg = load_cfg(mix);
    if let Some((_, fsync)) = journal {
        cfg.leader.journal_fsync = fsync;
    }

    let seq = Sequencer::new();
    let hook: CentralHook = {
        let seq = Arc::clone(&seq);
        Arc::new(move |run: u32| seq.enter_and_wait(run))
    };
    let opts = HarnessOpts {
        server: ServerOpts {
            // one service slot, one worker: pops are strictly sequential,
            // so central-entry order *is* the queue discipline's order
            max_jobs: 1,
            queue_depth: total,
            allow_label_pull: false,
            central_workers: 1,
            client_limit: Some(mix.clients.len() as u64),
        },
        faults: Vec::new(),
        central_hook: Some(hook),
        hangups: vec![],
    };
    let mut harness = match journal {
        Some((path, _)) => serve_channel_journaled(load_workload(mix.seed), &cfg, opts, path, None)?,
        None => serve_channel(load_workload(mix.seed), &cfg, opts)?,
    };

    // One connection per tenant, mix order → client ids 1..=n.
    let clients: Vec<_> = mix.clients.iter().map(|_| harness.client()).collect();

    // Submit every budget up front at virtual t0, round-robin across the
    // tenants — the one canonical interleaving both disciplines see.
    let mut run_owner: HashMap<u32, usize> = HashMap::new();
    let mut remaining: Vec<usize> = mix.clients.iter().map(|c| c.submits).collect();
    let mut submitted = 0;
    while submitted < total {
        for (i, client) in clients.iter().enumerate() {
            if remaining[i] == 0 {
                continue;
            }
            remaining[i] -= 1;
            let mut spec = spec_from_config(&cfg);
            spec.priority = mix.clients[i].priority;
            let acc = client
                .submit_tracked(&spec)
                .with_context(|| format!("load submit for client {}", i + 1))?;
            run_owner.insert(acc.run, i);
            submitted += 1;
        }
    }

    // Drain: one central released per virtual step. The k-th pop (0-based)
    // completes its central at virtual (k+1)·step — its sojourn, since
    // every submit happened at t0.
    let step_ns = mix.step.as_nanos() as u64;
    let mut pops: Vec<(u32, u64)> = Vec::with_capacity(total);
    for k in 0..total {
        let run = seq.wait_entered();
        harness.tick(mix.step);
        pops.push((run, (k as u64 + 1) * step_ns));
        seq.release(run);
    }

    // Every central was released, so every run completes.
    for &(run, _) in &pops {
        clients[run_owner[&run]]
            .await_done(run)
            .with_context(|| format!("load run {run} failed"))?;
    }
    drop(clients);
    let (stats, _outcomes) = harness.join()?;

    Ok(report_from_pops(mix, &pops, &run_owner, stats))
}

fn report_from_pops(
    mix: &LoadMix,
    pops: &[(u32, u64)],
    run_owner: &HashMap<u32, usize>,
    stats: ServerStats,
) -> LoadReport {
    let n = mix.clients.len();
    let mut sojourns: Vec<Vec<u64>> = vec![Vec::new(); n];
    for &(run, stamp) in pops {
        sojourns[run_owner[&run]].push(stamp);
    }

    // Fairness window: service counts at the pop where the first tenant
    // drains (all tenants backlogged until then, since every submit is at
    // t0), normalized by weight.
    let mut served = vec![0usize; n];
    let mut window = served.clone();
    for &(run, _) in pops {
        let i = run_owner[&run];
        served[i] += 1;
        if served[i] == mix.clients[i].submits {
            window = served.clone();
            break;
        }
    }
    let shares: Vec<f64> = window
        .iter()
        .zip(&mix.clients)
        .map(|(&s, c)| s as f64 / c.priority as f64)
        .collect();
    let fairness = jain_index(&shares);

    let step_ns = mix.step.as_nanos() as u64;
    let makespan_ns = pops.last().map(|&(_, t)| t).unwrap_or(0);
    let (throughput, utilization) = if makespan_ns == 0 {
        (0.0, 0.0)
    } else {
        (
            stats.completed as f64 / (makespan_ns as f64 / 1e9),
            (stats.completed * step_ns) as f64 / makespan_ns as f64,
        )
    };

    let per_client = sojourns
        .iter()
        .zip(&mix.clients)
        .enumerate()
        .map(|(i, (s, c))| {
            let mut s = s.clone();
            s.sort_unstable();
            let mean = if s.is_empty() {
                0
            } else {
                s.iter().sum::<u64>() / s.len() as u64
            };
            ClientLatency {
                client: i as u64 + 1,
                priority: c.priority,
                jobs: s.len(),
                mean_ns: mean,
                p50_ns: percentile(&s, 50.0),
                p95_ns: percentile(&s, 95.0),
                p99_ns: percentile(&s, 99.0),
            }
        })
        .collect();

    LoadReport {
        fair_queue: mix.fair_queue,
        jobs: mix.total_jobs(),
        completed: stats.completed,
        rejected: stats.rejected,
        makespan_ns,
        throughput_jobs_per_sec: throughput,
        utilization,
        fairness,
        per_client,
    }
}

// ─── the TCP twin ──────────────────────────────────────────────────────────

/// What the TCP twin measures: wall-clock numbers over real loopback
/// sockets — real, therefore not part of the deterministic BENCH record.
#[derive(Clone, Copy, Debug)]
pub struct TcpLoadReport {
    pub jobs: usize,
    pub completed: u64,
    /// Submit of the first job to the last `JOBDONE`.
    pub wall: Duration,
    pub throughput_jobs_per_sec: f64,
}

/// Push the identical mix through a real TCP job server: persistent site
/// sessions, a `serve_jobs` leader on a loopback listener, one
/// `JobClient` connection per tenant. Same round-robin submission, same
/// specs, real centrals (no sequencer) and real time.
pub fn run_tcp_load(mix: &LoadMix) -> Result<TcpLoadReport> {
    check_mix(mix)?;
    let total = mix.total_jobs();
    let mut cfg = load_cfg(mix);
    let timeouts = cfg.net.tcp_timeouts();

    let mut addrs = Vec::new();
    let mut site_threads = Vec::new();
    for data in load_workload(mix.seed) {
        let listener = SiteListener::bind("127.0.0.1:0").context("bind site listener")?;
        addrs.push(listener.local_addr()?.to_string());
        let limits = cfg.site;
        let t = timeouts;
        site_threads.push(std::thread::spawn(move || {
            let conn = listener.accept(&t)?;
            let net = SiteNet::over(Box::new(conn));
            site::session(&net, &data, None, limits, |_| {})
        }));
    }
    cfg.net.sites = addrs;

    let opts = ServerOpts {
        max_jobs: 1,
        queue_depth: total,
        allow_label_pull: false,
        central_workers: 1,
        client_limit: Some(mix.clients.len() as u64),
    };
    let listener = std::net::TcpListener::bind("127.0.0.1:0").context("bind job listener")?;
    let leader_addr = listener.local_addr()?.to_string();
    let server = std::thread::spawn({
        let cfg = cfg.clone();
        let opts = opts.clone();
        move || serve_jobs(&cfg, &opts, listener)
    });

    let clients: Vec<JobClient> = mix
        .clients
        .iter()
        .map(|_| JobClient::connect(&leader_addr, &timeouts))
        .collect::<Result<_>>()?;

    let t0 = Instant::now();
    let mut runs: Vec<(usize, u32)> = Vec::with_capacity(total);
    let mut remaining: Vec<usize> = mix.clients.iter().map(|c| c.submits).collect();
    let mut submitted = 0;
    while submitted < total {
        for (i, client) in clients.iter().enumerate() {
            if remaining[i] == 0 {
                continue;
            }
            remaining[i] -= 1;
            let mut spec = spec_from_config(&cfg);
            spec.priority = mix.clients[i].priority;
            let acc = client.submit_tracked(&spec)?;
            runs.push((i, acc.run));
            submitted += 1;
        }
    }
    for &(owner, run) in &runs {
        clients[owner].await_done(run)?;
    }
    let wall = t0.elapsed();
    drop(clients);

    let stats = server.join().map_err(|_| anyhow::anyhow!("server thread panicked"))??;
    for t in site_threads {
        t.join().map_err(|_| anyhow::anyhow!("site thread panicked"))??;
    }

    Ok(TcpLoadReport {
        jobs: total,
        completed: stats.completed,
        wall,
        throughput_jobs_per_sec: stats.completed as f64 / wall.as_secs_f64().max(1e-9),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jain_index_equal_shares_is_one() {
        assert_eq!(jain_index(&[2.0, 2.0, 2.0]), 1.0);
        assert_eq!(jain_index(&[]), 1.0);
        assert_eq!(jain_index(&[0.0, 0.0]), 1.0);
    }

    #[test]
    fn jain_index_penalizes_skew() {
        let j = jain_index(&[3.0, 1.5, 0.75]);
        assert!(j < 0.85, "skewed shares should score well below 1: {j}");
        assert!(j > 0.0);
        // one tenant taking everything → 1/n
        let j = jain_index(&[5.0, 0.0, 0.0]);
        assert!((j - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_is_nearest_rank() {
        let s = [10, 20, 30, 40];
        assert_eq!(percentile(&s, 50.0), 20);
        assert_eq!(percentile(&s, 95.0), 40);
        assert_eq!(percentile(&s, 99.0), 40);
        assert_eq!(percentile(&s, 1.0), 10);
        assert_eq!(percentile(&[], 50.0), 0);
        assert_eq!(percentile(&[7], 99.0), 7);
    }

    #[test]
    fn skewed_three_mix_shape() {
        let mix = LoadMix::skewed_three(true);
        assert_eq!(mix.total_jobs(), 21);
        assert!(check_mix(&mix).is_ok());
        let bad = LoadMix {
            clients: vec![ClientLoad { submits: 1, priority: 0 }],
            ..LoadMix::skewed_three(false)
        };
        assert!(check_mix(&bad).is_err());
    }

    #[test]
    fn report_json_is_stable() {
        let report = LoadReport {
            fair_queue: true,
            jobs: 2,
            completed: 2,
            rejected: 0,
            makespan_ns: 20,
            throughput_jobs_per_sec: 1e8,
            utilization: 1.0,
            fairness: 0.5,
            per_client: vec![ClientLatency {
                client: 1,
                priority: 1,
                jobs: 2,
                mean_ns: 15,
                p50_ns: 10,
                p95_ns: 20,
                p99_ns: 20,
            }],
        };
        let a = report.to_json();
        assert_eq!(a, report.clone().to_json());
        assert!(a.contains("\"fairness\": 0.5"), "{a}");
        assert!(a.contains("\"p95_ns\": 20"), "{a}");
    }
}
