//! Channel-backed job-server harness: the full reactor stack, socket-free.
//!
//! This hosts the *identical* serving pipeline as `dsc leader --serve` —
//! the same reactor, `JobQueue` semantics,
//! [`super::machine::RunMachine`]s, central worker pool and per-run byte
//! accounting — but wired to in-process channel sites instead of TCP:
//!
//! * sites are threads running the real [`crate::site::session`] loop over
//!   the channel transport (one protocol implementation, as always);
//! * the uplink passes through an injectable
//!   [`FaultPlan`](crate::net::channel::FaultPlan) — drop site N after
//!   frame K, delay or duplicate a specific frame, swallow one run's
//!   frames — so concurrency and failure interleavings are reproducible
//!   functions of frame order, not of scheduler timing;
//! * the reactor's clock is a [`VirtualClock`]: straggler deadlines fire
//!   when a test advances time and injects a `Tick`, never because a real
//!   timer ran out — no sleeps, no flakes;
//! * clients are in-process [`JobClient`]s over a channel link, speaking
//!   the same typed submit/await/pull protocol as `dsc submit` (frames are
//!   mapped through the same decoder the TCP reader threads use).
//!
//! Because byte accounting happens in the reactor on encoded frames, the
//! per-run counters this harness reports are byte-identical to the TCP
//! job server's for the same jobs — `rust/tests/job_server.rs` pins that
//! parity; `rust/tests/channel_harness.rs` uses the harness for the core
//! concurrency, pipelining, deadline and fault cases. `docs/TESTING.md`
//! places it in the test pyramid and shows how to write a fault plan.
//!
//! Shutdown contract: the harness stops when
//! [`ServerOpts::client_limit`] clients have come and gone (a
//! [`JobClient`] counts when dropped), mirroring `--serve-limit`. The
//! limit is required here — without it nothing would ever stop the
//! reactor, since the in-process mailbox can outlive every test handle.

use std::collections::HashMap;
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::config::{Backend, PipelineConfig};
use crate::data::Dataset;
use crate::net::channel::{self, Deliver, Fault, FaultPlan, VirtualClock};
use crate::net::SiteNet;
use crate::site::{self, SessionOutcome};

use super::server::{
    client_frame_to_event, CentralHook, CentralPool, ClientLink, Event, JobClient, Reactor,
    ServerDriver, ServerOpts, ServerStats,
};

/// Everything a harness run is parameterized by, beyond the pipeline
/// config: the serving options (shared with the TCP server), the fault
/// plan, and the central-step instrumentation hook.
#[derive(Default)]
pub struct HarnessOpts {
    /// Serving knobs. `client_limit` must be set — it is the harness's
    /// only shutdown signal (see the module docs).
    pub server: ServerOpts,
    /// Deterministic uplink faults, applied in frame-arrival order.
    pub faults: Vec<Fault>,
    /// Called by a central worker with the run id before computing — block
    /// here to make one run's central arbitrarily slow, deterministically.
    pub central_hook: Option<CentralHook>,
}

/// In-process client link: frames out are decoded into reactor events by
/// the same mapping the TCP client-reader threads use; frames in arrive
/// encoded from the reactor, exactly as they would over a socket.
pub struct ChannelLink {
    client: u64,
    events: Sender<Event>,
    rx: Receiver<Vec<u8>>,
}

impl ClientLink for ChannelLink {
    fn send(&self, frame: &[u8]) -> Result<()> {
        let event = client_frame_to_event(self.client, frame)?;
        self.events.send(event).map_err(|_| anyhow!("job server is gone"))
    }

    fn recv(&self) -> Result<Option<Vec<u8>>> {
        // Disconnect = the reactor shut down and closed its clients: the
        // channel twin of the leader closing a TCP connection.
        Ok(self.rx.recv().ok())
    }
}

impl Drop for ChannelLink {
    fn drop(&mut self) {
        // The client "connection" ends: counts toward client_limit, same
        // as a TCP client hanging up.
        let _ = self.events.send(Event::ClientDown { client: self.client });
    }
}

/// The channel [`ServerDriver`]: downlink senders instead of sockets, a
/// virtual clock instead of real time. A severed link cannot be re-dialed
/// — `ensure_links` errors forever, so queued jobs behind a dead channel
/// site wait out the (virtual) backoff rather than restart it.
struct ChannelDriver {
    clock: VirtualClock,
    to_sites: Vec<Option<Sender<Vec<u8>>>>,
    gens: Vec<u64>,
    clients: Arc<Mutex<HashMap<u64, Sender<Vec<u8>>>>>,
}

impl ServerDriver for ChannelDriver {
    fn n_sites(&self) -> usize {
        self.to_sites.len()
    }

    fn link_gen(&self, site: usize) -> u64 {
        self.gens[site]
    }

    fn send_site(&mut self, site: usize, frame: &[u8]) -> Result<()> {
        let tx = self.to_sites[site]
            .as_ref()
            .ok_or_else(|| anyhow!("site {site} link is down"))?;
        tx.send(frame.to_vec()).map_err(|_| anyhow!("site {site} hung up"))
    }

    fn take_down(&mut self, site: usize) -> bool {
        match self.to_sites[site].take() {
            // dropping the sender ends the site's session loop cleanly
            Some(_tx) => {
                self.gens[site] += 1;
                true
            }
            None => false,
        }
    }

    fn ensure_links(&mut self) -> Result<()> {
        if let Some(site) = self.to_sites.iter().position(|s| s.is_none()) {
            bail!("site {site} is a channel link — severed links cannot be re-dialed");
        }
        Ok(())
    }

    fn send_client(&mut self, client: u64, frame: &[u8]) -> Result<()> {
        let clients = self.clients.lock().unwrap();
        let Some(tx) = clients.get(&client) else {
            return Ok(()); // client gone; its results are dropped
        };
        tx.send(frame.to_vec()).map_err(|_| anyhow!("client {client} hung up"))
    }

    fn drop_client(&mut self, client: u64) {
        self.clients.lock().unwrap().remove(&client);
    }

    fn close_clients(&mut self) {
        self.clients.lock().unwrap().clear();
    }

    fn now(&self) -> Instant {
        self.clock.now()
    }
}

/// A running channel job server: mint clients, drive the virtual clock,
/// and join for the stats once every client is done.
pub struct ChannelHarness {
    events: Sender<Event>,
    clock: VirtualClock,
    clients: Arc<Mutex<HashMap<u64, Sender<Vec<u8>>>>>,
    next_client: u64,
    reactor: JoinHandle<Result<ServerStats>>,
    sites: Vec<JoinHandle<Result<SessionOutcome>>>,
}

/// Stand up the channel job server: one [`crate::site::session`] thread
/// per dataset (site id = index, shard "loaded" once like a daemon), the
/// fault-plan forwarder, the central worker pool, and the reactor on its
/// own thread. Returns immediately; submit through
/// [`ChannelHarness::client`].
pub fn serve_channel(
    datasets: Vec<Dataset>,
    cfg: &PipelineConfig,
    opts: HarnessOpts,
) -> Result<ChannelHarness> {
    if datasets.is_empty() {
        bail!("no site datasets");
    }
    if opts.server.client_limit.is_none() {
        bail!(
            "the channel harness shuts down when client_limit clients have come and gone — \
             set ServerOpts::client_limit"
        );
    }
    let n_sites = datasets.len();
    let (up_rx, down_txs, site_ends) = channel::star_endpoints(n_sites);

    // Real site sessions, one thread each — the same loop `dsc site` runs
    // for a job-serving leader, limits from `[site]` as in the daemon.
    let limits = cfg.site;
    let mut sites = Vec::with_capacity(n_sites);
    for (end, data) in site_ends.into_iter().zip(datasets) {
        sites.push(thread::spawn(move || {
            let net = SiteNet::over(Box::new(end));
            site::session(&net, &data, None, limits, |_| {})
        }));
    }

    let (ev_tx, ev_rx) = mpsc::channel::<Event>();

    // Forwarder: the uplink drains through the fault plan into the
    // mailbox. Exits when every site thread (and so every uplink sender)
    // is gone.
    {
        let ev_tx = ev_tx.clone();
        let mut plan = FaultPlan::new(opts.faults);
        thread::spawn(move || {
            while let Ok((site, frame)) = up_rx.recv() {
                for d in plan.on_frame(site, frame) {
                    let event = match d {
                        Deliver::Frame { site, frame } => {
                            Event::SiteFrame { site, gen: 0, frame }
                        }
                        Deliver::SiteDown { site } => Event::SiteDown {
                            site,
                            gen: 0,
                            err: "fault plan severed the link".into(),
                        },
                    };
                    if ev_tx.send(event).is_err() {
                        return; // reactor gone
                    }
                }
            }
        });
    }

    let clock = VirtualClock::new();
    let clients: Arc<Mutex<HashMap<u64, Sender<Vec<u8>>>>> =
        Arc::new(Mutex::new(HashMap::new()));
    let driver = ChannelDriver {
        clock: clock.clone(),
        to_sites: down_txs.into_iter().map(Some).collect(),
        gens: vec![0; n_sites],
        clients: Arc::clone(&clients),
    };
    // Same offload gate as the TCP server: pool on the native backend only.
    let workers =
        if cfg.backend == Backend::Native { opts.server.central_workers } else { 0 };
    let pool = CentralPool::start(workers, ev_tx.clone(), opts.central_hook);

    let reactor = thread::spawn({
        let cfg = cfg.clone();
        let server_opts = opts.server;
        move || -> Result<ServerStats> {
            // Built on this thread: the reactor may hold a thread-local
            // XLA runtime handle (inline-central path) and must not move.
            let mut reactor = Reactor::new(cfg, server_opts, driver, pool)?;
            loop {
                if reactor.done() {
                    return Ok(reactor.finish());
                }
                // No recv timeout: time is virtual, so deadline wakeups
                // arrive as explicit Tick events from the test.
                let Ok(event) = ev_rx.recv() else {
                    return Ok(reactor.finish()); // every event source gone
                };
                reactor.step(event);
            }
        }
    });

    Ok(ChannelHarness { events: ev_tx, clock, clients, next_client: 1, reactor, sites })
}

impl ChannelHarness {
    /// Open one in-process client connection. Dropping the returned
    /// [`JobClient`] ends it (counts toward `client_limit`).
    pub fn client(&mut self) -> JobClient<ChannelLink> {
        let client = self.next_client;
        self.next_client += 1;
        let (tx, rx) = mpsc::channel();
        self.clients.lock().unwrap().insert(client, tx);
        JobClient::over(ChannelLink { client, events: self.events.clone(), rx })
    }

    /// Advance the virtual clock by `d` and deliver a `Tick`, so the
    /// reactor enforces straggler deadlines against the new now — the
    /// socket-free twin of a recv timeout firing.
    pub fn tick(&self, d: Duration) {
        self.clock.advance(d);
        let _ = self.events.send(Event::Tick);
    }

    /// A handle on the harness clock (clones share time).
    pub fn clock(&self) -> VirtualClock {
        self.clock.clone()
    }

    /// Wait for the server to finish (every `client_limit` client done),
    /// then for every site session; returns the serving stats and the
    /// per-site session outcomes. Call after dropping all clients.
    pub fn join(self) -> Result<(ServerStats, Vec<SessionOutcome>)> {
        let ChannelHarness { events, clock: _, clients, next_client: _, reactor, sites } = self;
        drop(events);
        drop(clients);
        let stats =
            reactor.join().map_err(|_| anyhow!("reactor thread panicked"))??;
        // The reactor dropping its driver closed every site downlink, so
        // the sessions end cleanly (Ok) just like a leader disconnecting.
        let mut outcomes = Vec::with_capacity(sites.len());
        for s in sites {
            outcomes.push(s.join().map_err(|_| anyhow!("site thread panicked"))??);
        }
        Ok((stats, outcomes))
    }
}
