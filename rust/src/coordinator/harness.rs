//! Channel-backed job-server harness: the full reactor stack, socket-free.
//!
//! This hosts the *identical* serving pipeline as `dsc leader --serve` —
//! the same reactor, `JobQueue` semantics,
//! [`super::machine::RunMachine`]s, central worker pool and per-run byte
//! accounting — but wired to in-process channel sites instead of TCP:
//!
//! * sites are threads running the real [`crate::site::session`] loop over
//!   the channel transport (one protocol implementation, as always);
//! * the uplink passes through an injectable
//!   [`FaultPlan`](crate::net::channel::FaultPlan) — drop site N after
//!   frame K, delay or duplicate a specific frame, swallow one run's
//!   frames — so concurrency and failure interleavings are reproducible
//!   functions of frame order, not of scheduler timing;
//! * the reactor's clock is a [`VirtualClock`]: straggler deadlines fire
//!   when a test advances time and injects a `Tick`, never because a real
//!   timer ran out — no sleeps, no flakes;
//! * clients are in-process [`JobClient`]s over a channel link, speaking
//!   the same typed submit/await/pull protocol as `dsc submit` (frames are
//!   mapped through the same decoder the TCP reader threads use).
//!
//! Because byte accounting happens in the reactor on encoded frames, the
//! per-run counters this harness reports are byte-identical to the TCP
//! job server's for the same jobs — `rust/tests/job_server.rs` pins that
//! parity; `rust/tests/channel_harness.rs` uses the harness for the core
//! concurrency, pipelining, deadline and fault cases. `docs/TESTING.md`
//! places it in the test pyramid and shows how to write a fault plan.
//!
//! Shutdown contract: the harness stops when
//! [`ServerOpts::client_limit`] clients have come and gone (a
//! [`JobClient`] counts when dropped), mirroring `--serve-limit`. The
//! limit is required here — without it nothing would ever stop the
//! reactor, since the in-process mailbox can outlive every test handle.
//!
//! **Crash recovery** ([`serve_channel_journaled`]): the same harness with
//! the reactor journaling every event to an on-disk log
//! ([`super::journal`]) and, optionally, a staged crash after the log's
//! `crash_after`-th record. A crash drops the reactor's entire in-memory
//! state; the *world* — site threads, the event mailbox, clients, the
//! virtual clock — survives, exactly as sites and the disk outlive a dead
//! leader process. [`ChannelHarness::crash_and_restart`] then recovers the
//! way `dsc leader --serve --journal` does on reboot: re-open the journal,
//! replay it against a puppet driver, and resume serving the surviving
//! mailbox. `rust/tests/journal_replay.rs` sweeps the crash point over
//! every record index and pins replayed == uninterrupted, bit for bit.
//!
//! **Failover** ([`ChannelHarness::crash_and_failover`]): the warm-standby
//! twin of the above — the crashed primary's journal is replicated through
//! the real `JREPLRECORD` codec into a second journal file, checked
//! byte-identical, and the reactor is promoted *from the standby's copy*,
//! exactly as `dsc leader --standby` takes over a dead primary.
//! `rust/tests/failover.rs` sweeps the kill point over every record index.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::config::{Backend, PipelineConfig};
use crate::data::Dataset;
use crate::net::channel::{self, Deliver, Fault, FaultPlan, HangupSite, VirtualClock};
use crate::net::{wire, Message, SiteNet, SiteTransport};
use crate::site::{self, SessionOutcome};

use super::journal::{self, Journal};
use super::server::{
    client_frame_to_event, CentralHook, CentralPool, ClientLink, Event, JobClient, Reactor,
    ReplayDriver, ServerDriver, ServerOpts, ServerStats,
};

/// Everything a harness run is parameterized by, beyond the pipeline
/// config: the serving options (shared with the TCP server), the fault
/// plan, and the central-step instrumentation hook.
#[derive(Default)]
pub struct HarnessOpts {
    /// Serving knobs. `client_limit` must be set — it is the harness's
    /// only shutdown signal (see the module docs).
    pub server: ServerOpts,
    /// Deterministic uplink faults, applied in frame-arrival order.
    pub faults: Vec<Fault>,
    /// Called by a central worker with the run id before computing — block
    /// here to make one run's central arbitrarily slow, deterministically.
    pub central_hook: Option<CentralHook>,
    /// Scripted site hangups, `(site, hang_before)`: site's transport is
    /// wrapped in a [`HangupSite`] that drops its downlink just before its
    /// `hang_before`-th uplink send. Unlike a fault-plan `Drop` (a severed
    /// *uplink*, journaled as a `SiteDown` event), this makes the reactor
    /// itself hit a failed downlink send mid-step — the lever for testing
    /// journaled `SendFail` records.
    pub hangups: Vec<(usize, u64)>,
}

/// In-process client link: frames out are decoded into reactor events by
/// the same mapping the TCP client-reader threads use; frames in arrive
/// encoded from the reactor, exactly as they would over a socket.
pub struct ChannelLink {
    client: u64,
    events: Sender<Event>,
    rx: Receiver<Vec<u8>>,
}

impl ClientLink for ChannelLink {
    fn send(&self, frame: &[u8]) -> Result<()> {
        let event = client_frame_to_event(self.client, frame)?;
        self.events.send(event).map_err(|_| anyhow!("job server is gone"))
    }

    fn recv(&self) -> Result<Option<Vec<u8>>> {
        // Disconnect = the reactor shut down and closed its clients: the
        // channel twin of the leader closing a TCP connection.
        Ok(self.rx.recv().ok())
    }
}

impl Drop for ChannelLink {
    fn drop(&mut self) {
        // The client "connection" ends: counts toward client_limit, same
        // as a TCP client hanging up.
        let _ = self.events.send(Event::ClientDown { client: self.client });
    }
}

/// The channel [`ServerDriver`]: downlink senders instead of sockets, a
/// virtual clock instead of real time. A severed link cannot be re-dialed
/// — `ensure_links` errors forever, so queued jobs behind a dead channel
/// site wait out the (virtual) backoff rather than restart it.
struct ChannelDriver {
    clock: VirtualClock,
    to_sites: Vec<Option<Sender<Vec<u8>>>>,
    gens: Vec<u64>,
    clients: Arc<Mutex<HashMap<u64, Sender<Vec<u8>>>>>,
    /// Re-dial attempts on a degraded star (shared with
    /// [`ChannelHarness::redial_attempts`]) — the channel world can never
    /// actually revive a link, so the *attempt* is the observable that
    /// pins the reactor's re-dial schedule.
    redials: Arc<AtomicU64>,
}

impl ServerDriver for ChannelDriver {
    fn n_sites(&self) -> usize {
        self.to_sites.len()
    }

    fn link_gen(&self, site: usize) -> u64 {
        self.gens[site]
    }

    fn send_site(&mut self, site: usize, frame: &[u8]) -> Result<()> {
        let tx = self.to_sites[site]
            .as_ref()
            .ok_or_else(|| anyhow!("site {site} link is down"))?;
        tx.send(frame.to_vec()).map_err(|_| anyhow!("site {site} hung up"))
    }

    fn take_down(&mut self, site: usize) -> bool {
        match self.to_sites[site].take() {
            // dropping the sender ends the site's session loop cleanly
            Some(_tx) => {
                self.gens[site] += 1;
                true
            }
            None => false,
        }
    }

    fn ensure_links(&mut self) -> Result<()> {
        if let Some(site) = self.to_sites.iter().position(|s| s.is_none()) {
            self.redials.fetch_add(1, Ordering::Relaxed);
            bail!("site {site} is a channel link — severed links cannot be re-dialed");
        }
        Ok(())
    }

    fn send_client(&mut self, client: u64, frame: &[u8]) -> Result<()> {
        let clients = self.clients.lock().unwrap();
        let Some(tx) = clients.get(&client) else {
            return Ok(()); // client gone; its results are dropped
        };
        tx.send(frame.to_vec()).map_err(|_| anyhow!("client {client} hung up"))
    }

    fn drop_client(&mut self, client: u64) {
        self.clients.lock().unwrap().remove(&client);
    }

    fn close_clients(&mut self) {
        self.clients.lock().unwrap().clear();
    }

    fn now(&self) -> Instant {
        self.clock.now()
    }
}

/// How a reactor thread ended: cleanly, or at a staged crash point with
/// the surviving world (driver, pool, mailbox) handed back for recovery.
enum ReactorOutcome {
    Finished(ServerStats),
    Crashed { driver: ChannelDriver, pool: CentralPool, ev_rx: Receiver<Event> },
}

/// Everything [`ChannelHarness::crash_and_restart`] needs to "reboot" the
/// reactor against the same journal: the original serving parameters plus
/// the journal's pinned epoch (`t_ns = 0` of the log's timeline).
#[derive(Clone)]
struct RestartState {
    cfg: PipelineConfig,
    opts: ServerOpts,
    path: PathBuf,
    fsync: bool,
    epoch: Instant,
    /// Whether the surviving pool offloads centrals (`jobs.is_some()`) —
    /// the replay stub must agree so replay takes the same drive() branch.
    pool_active: bool,
}

/// A cloneable stand-in for [`ChannelHarness::tick`] (see
/// [`ChannelHarness::ticker`]): advances the shared virtual clock and
/// injects the `Tick`, without borrowing the harness.
#[derive(Clone)]
pub struct HarnessTicker {
    events: Sender<Event>,
    clock: VirtualClock,
}

impl HarnessTicker {
    /// Advance the virtual clock by `d` and deliver a `Tick` — identical
    /// to [`ChannelHarness::tick`].
    pub fn tick(&self, d: Duration) {
        self.clock.advance(d);
        let _ = self.events.send(Event::Tick);
    }
}

/// A running channel job server: mint clients, drive the virtual clock,
/// and join for the stats once every client is done.
pub struct ChannelHarness {
    events: Sender<Event>,
    clock: VirtualClock,
    clients: Arc<Mutex<HashMap<u64, Sender<Vec<u8>>>>>,
    next_client: u64,
    reactor: Option<JoinHandle<Result<ReactorOutcome>>>,
    sites: Vec<JoinHandle<Result<SessionOutcome>>>,
    restart: Option<RestartState>,
    redials: Arc<AtomicU64>,
}

/// Stand up the channel job server: one [`crate::site::session`] thread
/// per dataset (site id = index, shard "loaded" once like a daemon), the
/// fault-plan forwarder, the central worker pool, and the reactor on its
/// own thread. Returns immediately; submit through
/// [`ChannelHarness::client`].
pub fn serve_channel(
    datasets: Vec<Dataset>,
    cfg: &PipelineConfig,
    opts: HarnessOpts,
) -> Result<ChannelHarness> {
    serve_channel_inner(datasets, cfg, opts, None)
}

/// [`serve_channel`] with the reactor event-sourcing into `journal_path`
/// (fsync per [`crate::config::LeaderConfig::journal_fsync`]) and, when
/// `crash_after` is `Some(k)`, a staged crash as soon as the journal holds
/// `k` records: the reactor's state is dropped on the spot — sites,
/// mailbox, clients and clock survive — and the harness waits in the
/// crashed state until [`ChannelHarness::crash_and_restart`]. The journal
/// file must be fresh (empty or absent): recovery of an existing log is
/// `crash_and_restart`'s job, not serve's.
pub fn serve_channel_journaled(
    datasets: Vec<Dataset>,
    cfg: &PipelineConfig,
    opts: HarnessOpts,
    journal_path: &Path,
    crash_after: Option<u64>,
) -> Result<ChannelHarness> {
    let plan = JournalPlan {
        path: journal_path.to_path_buf(),
        fsync: cfg.leader.journal_fsync,
        crash_after,
    };
    serve_channel_inner(datasets, cfg, opts, Some(plan))
}

/// Journal wiring for [`serve_channel_journaled`].
struct JournalPlan {
    path: PathBuf,
    fsync: bool,
    crash_after: Option<u64>,
}

fn serve_channel_inner(
    datasets: Vec<Dataset>,
    cfg: &PipelineConfig,
    opts: HarnessOpts,
    journal: Option<JournalPlan>,
) -> Result<ChannelHarness> {
    if datasets.is_empty() {
        bail!("no site datasets");
    }
    if opts.server.client_limit.is_none() {
        bail!(
            "the channel harness shuts down when client_limit clients have come and gone — \
             set ServerOpts::client_limit"
        );
    }
    let n_sites = datasets.len();
    let (up_rx, down_txs, site_ends) = channel::star_endpoints(n_sites);

    // Real site sessions, one thread each — the same loop `dsc site` runs
    // for a job-serving leader, limits from `[site]` as in the daemon.
    let limits = cfg.site;
    let mut sites = Vec::with_capacity(n_sites);
    for (site_id, (end, data)) in site_ends.into_iter().zip(datasets).enumerate() {
        let hang = opts
            .hangups
            .iter()
            .find(|&&(s, _)| s == site_id)
            .map(|&(_, hang_before)| hang_before);
        sites.push(thread::spawn(move || {
            let transport: Box<dyn SiteTransport> = match hang {
                Some(hang_before) => Box::new(HangupSite::over(end, hang_before)),
                None => Box::new(end),
            };
            let net = SiteNet::over(transport);
            site::session(&net, &data, None, limits, |_| {})
        }));
    }

    let (ev_tx, ev_rx) = mpsc::channel::<Event>();

    // Forwarder: the uplink drains through the fault plan into the
    // mailbox. Exits when every site thread (and so every uplink sender)
    // is gone.
    {
        let ev_tx = ev_tx.clone();
        let mut plan = FaultPlan::new(opts.faults);
        thread::spawn(move || {
            while let Ok((site, frame)) = up_rx.recv() {
                for d in plan.on_frame(site, frame) {
                    let event = match d {
                        Deliver::Frame { site, frame } => {
                            Event::SiteFrame { site, gen: 0, frame }
                        }
                        Deliver::SiteDown { site } => Event::SiteDown {
                            site,
                            gen: 0,
                            err: "fault plan severed the link".into(),
                        },
                    };
                    if ev_tx.send(event).is_err() {
                        return; // reactor gone
                    }
                }
            }
        });
    }

    let clock = VirtualClock::new();
    let clients: Arc<Mutex<HashMap<u64, Sender<Vec<u8>>>>> =
        Arc::new(Mutex::new(HashMap::new()));
    let redials = Arc::new(AtomicU64::new(0));
    let driver = ChannelDriver {
        clock: clock.clone(),
        to_sites: down_txs.into_iter().map(Some).collect(),
        gens: vec![0; n_sites],
        clients: Arc::clone(&clients),
        redials: Arc::clone(&redials),
    };
    // Same offload gate as the TCP server: pool on the native backend only.
    let workers =
        if cfg.backend == Backend::Native { opts.server.central_workers } else { 0 };
    let pool = CentralPool::start(workers, ev_tx.clone(), opts.central_hook);

    // The journal epoch is pinned *before* the reactor thread exists, so a
    // test advancing the clock can never race the thread start into a
    // skewed timeline; crash_and_restart reuses the same instant.
    let epoch = clock.now();
    let restart = match &journal {
        None => None,
        Some(plan) => {
            let (log, records) = Journal::open(&plan.path, plan.fsync)?;
            if !records.is_empty() {
                bail!(
                    "{}: the journaled channel harness needs a fresh journal \
                     ({} records found) — recovery goes through crash_and_restart",
                    plan.path.display(),
                    records.len()
                );
            }
            Some((
                log,
                plan.crash_after,
                RestartState {
                    cfg: cfg.clone(),
                    opts: opts.server.clone(),
                    path: plan.path.clone(),
                    fsync: plan.fsync,
                    epoch,
                    pool_active: workers > 0,
                },
            ))
        }
    };
    let (journal, crash_after, restart) = match restart {
        Some((log, crash_after, rs)) => (Some(log), crash_after, Some(rs)),
        None => (None, None, None),
    };

    let reactor = thread::spawn({
        let cfg = cfg.clone();
        let server_opts = opts.server;
        move || -> Result<ReactorOutcome> {
            // Built on this thread: the reactor may hold a thread-local
            // XLA runtime handle (inline-central path) and must not move.
            let mut reactor = Reactor::new(cfg, server_opts, driver, pool)?;
            if let Some(log) = journal {
                reactor.attach_journal_at(log, epoch);
            }
            loop {
                if let Some(k) = crash_after {
                    // A vanished journal means journaling self-disabled on
                    // a write failure: the crash point can never be
                    // reached, so fail loudly instead of serving forever.
                    let Some(records) = reactor.journal_records() else {
                        bail!(
                            "the journal disabled itself before the staged crash \
                             point ({k} records) was reached"
                        );
                    };
                    if records >= k {
                        // Staged crash. The crash model is "every appended
                        // record survives", so force the tail durable
                        // (loudly — a sync failure must not masquerade as
                        // data loss), then drop the reactor state; the
                        // driver, pool and mailbox outlive it the way
                        // sites and the disk outlive a dead process.
                        if let Some(mut log) = reactor.take_journal() {
                            log.sync()?;
                        }
                        let (_lost_state, driver, pool) = reactor.into_parts();
                        return Ok(ReactorOutcome::Crashed { driver, pool, ev_rx });
                    }
                }
                if reactor.done() {
                    return Ok(ReactorOutcome::Finished(reactor.finish()));
                }
                // Group commit: everything journaled this drain becomes
                // durable before the reactor blocks (no-op with no journal).
                reactor.sync_journal();
                // No recv timeout: time is virtual, so deadline wakeups
                // arrive as explicit Tick events from the test.
                let Ok(event) = ev_rx.recv() else {
                    // every event source gone
                    return Ok(ReactorOutcome::Finished(reactor.finish()));
                };
                reactor.step(event);
            }
        }
    });

    Ok(ChannelHarness {
        events: ev_tx,
        clock,
        clients,
        next_client: 1,
        reactor: Some(reactor),
        sites,
        restart,
        redials,
    })
}

impl ChannelHarness {
    /// Open one in-process client connection. Dropping the returned
    /// [`JobClient`] ends it (counts toward `client_limit`).
    pub fn client(&mut self) -> JobClient<ChannelLink> {
        let client = self.next_client;
        self.next_client += 1;
        let (tx, rx) = mpsc::channel();
        self.clients.lock().unwrap().insert(client, tx);
        JobClient::over(ChannelLink { client, events: self.events.clone(), rx })
    }

    /// Advance the virtual clock by `d` and deliver a `Tick`, so the
    /// reactor enforces straggler deadlines against the new now — the
    /// socket-free twin of a recv timeout firing.
    pub fn tick(&self, d: Duration) {
        self.clock.advance(d);
        let _ = self.events.send(Event::Tick);
    }

    /// A handle on the harness clock (clones share time).
    pub fn clock(&self) -> VirtualClock {
        self.clock.clone()
    }

    /// How many times the reactor has tried to re-dial a severed site
    /// link. Channel links can never actually be revived, so the attempt
    /// count is what pins the re-dial *schedule*: it must keep growing on
    /// ticks even when the server is otherwise idle (see
    /// `severed_site_is_redialed_on_schedule_while_idle`).
    pub fn redial_attempts(&self) -> u64 {
        self.redials.load(Ordering::Relaxed)
    }

    /// A detached [`ChannelHarness::tick`] handle: a crash-recovery test
    /// drives its client script (and the clock) from a second thread while
    /// the main thread sits in [`ChannelHarness::crash_and_restart`], so
    /// the script needs tick access that does not borrow the harness.
    pub fn ticker(&self) -> HarnessTicker {
        HarnessTicker { events: self.events.clone(), clock: self.clock.clone() }
    }

    /// Recover from a staged crash the way `dsc leader --serve --journal`
    /// recovers from a real one: join the crashed reactor thread, take the
    /// surviving world (driver, pool, mailbox) off its hands, re-open the
    /// journal, replay it against a [`ReplayDriver`] sharing the log's
    /// epoch, and spawn a fresh reactor around the replayed state. The
    /// resumed reactor keeps journaling into the same log on the same
    /// absolute timeline and serves the mailbox's still-unprocessed events
    /// — post-crash traffic picks up exactly where the journal ends.
    ///
    /// Errors if the harness was not started by [`serve_channel_journaled`]
    /// with a crash point, or if the reactor finished before reaching it.
    pub fn crash_and_restart(&mut self) -> Result<()> {
        let rs = self
            .restart
            .as_ref()
            .ok_or_else(|| anyhow!("crash_and_restart needs a serve_channel_journaled harness"))?
            .clone();
        let (driver, pool, ev_rx) = self.join_crashed()?;
        let path = rs.path.clone();
        self.resume_reactor(rs, path, driver, pool, ev_rx);
        Ok(())
    }

    /// Crash the primary at its staged crash point and promote a warm
    /// standby in its place. The crashed reactor's journal is replicated
    /// record by record into `standby_path` through the real JREPL wire
    /// codec — each framed record rides `JREPLRECORD` encode → decode →
    /// [`Journal::append_framed`], the exact apply path a live standby
    /// runs — and the two files are checked byte-identical before the
    /// promoted reactor recovers from the *standby's* copy: replay,
    /// resume, and journal onward into the standby journal. The surviving
    /// channel world (sites, mailbox, clients, clock) carries over, so
    /// post-promotion traffic continues where the journal ends — the
    /// socket-free twin of `dsc leader --standby` taking over a SIGKILLed
    /// primary. `standby_path` must start empty (a warm standby whose
    /// catch-up streamed the whole history).
    pub fn crash_and_failover(&mut self, standby_path: &Path) -> Result<()> {
        let rs = self
            .restart
            .as_ref()
            .ok_or_else(|| anyhow!("crash_and_failover needs a serve_channel_journaled harness"))?
            .clone();
        let (driver, pool, ev_rx) = self.join_crashed()?;
        let (frames, _) = journal::framed_records(&rs.path)?;
        let (mut standby, existing) = Journal::open(standby_path, rs.fsync)?;
        if !existing.is_empty() {
            bail!(
                "{}: the standby journal must start empty ({} records found)",
                standby_path.display(),
                existing.len()
            );
        }
        for framed in frames {
            let frame = wire::encode(&Message::JreplRecord { framed });
            let Message::JreplRecord { framed } = wire::decode(&frame)? else {
                unreachable!("JREPLRECORD decodes to itself");
            };
            standby.append_framed(&framed)?;
        }
        standby.sync()?;
        drop(standby);
        if std::fs::read(&rs.path)? != std::fs::read(standby_path)? {
            bail!(
                "replicated standby journal {} is not byte-identical to the \
                 primary's {}",
                standby_path.display(),
                rs.path.display()
            );
        }
        let path = standby_path.to_path_buf();
        self.resume_reactor(rs, path, driver, pool, ev_rx);
        Ok(())
    }

    /// Join the reactor thread at its staged crash point and take the
    /// surviving world (driver, pool, mailbox) off its hands.
    fn join_crashed(&mut self) -> Result<(ChannelDriver, CentralPool, Receiver<Event>)> {
        let handle = self
            .reactor
            .take()
            .ok_or_else(|| anyhow!("the reactor handle is already gone"))?;
        let outcome = handle.join().map_err(|_| anyhow!("reactor thread panicked"))??;
        match outcome {
            ReactorOutcome::Crashed { driver, pool, ev_rx } => Ok((driver, pool, ev_rx)),
            ReactorOutcome::Finished(_) => bail!(
                "the reactor finished instead of crashing — crash_after was never reached"
            ),
        }
    }

    /// Second half of crash recovery and of failover promotion: recover
    /// the journal at `path`, replay it on the log's timeline, and spawn
    /// a fresh reactor around the replayed state serving the surviving
    /// mailbox (journaling onward into the same file).
    fn resume_reactor(
        &mut self,
        rs: RestartState,
        path: PathBuf,
        driver: ChannelDriver,
        pool: CentralPool,
        ev_rx: Receiver<Event>,
    ) {
        let clock = self.clock.clone();
        let handle = thread::spawn(move || -> Result<ReactorOutcome> {
            // Read back what survived "on disk"…
            let (journal, records) = Journal::open(&path, rs.fsync)?;
            let last_t_ns = records.last().map(|r| r.t_ns).unwrap_or(0);
            // …make sure the surviving clock is not behind the journal
            // (it cannot be — every record was stamped from it — but the
            // invariant is cheap to enforce)…
            clock.advance_to(rs.epoch + Duration::from_nanos(last_t_ns));
            // …and replay against a puppet driver on the log's timeline.
            // revive = false: the channel world survived, so replay must
            // end with links in exactly the live driver's state.
            let n_sites = driver.n_sites();
            let mut replayer = Reactor::new(
                rs.cfg,
                rs.opts,
                ReplayDriver::new(n_sites, rs.epoch, false),
                CentralPool::replay_stub(rs.pool_active),
            )?;
            replayer.set_replaying(true);
            replayer.replay(&records);
            for (site, gen) in replayer.replay_gens().iter().enumerate() {
                let live = driver.link_gen(site);
                if *gen != live {
                    bail!(
                        "replay says site {site} is at link gen {gen}, the surviving \
                         driver says {live} — journal and world diverged"
                    );
                }
            }
            let (parts, _puppet, _stub) = replayer.into_parts();
            let mut reactor = Reactor::from_parts(parts, driver, pool)?;
            reactor.attach_journal_at(journal, rs.epoch);
            loop {
                if reactor.done() {
                    return Ok(ReactorOutcome::Finished(reactor.finish()));
                }
                reactor.sync_journal();
                let Ok(event) = ev_rx.recv() else {
                    return Ok(ReactorOutcome::Finished(reactor.finish()));
                };
                reactor.step(event);
            }
        });
        self.reactor = Some(handle);
    }

    /// Wait for the server to finish (every `client_limit` client done),
    /// then for every site session; returns the serving stats and the
    /// per-site session outcomes. Call after dropping all clients.
    pub fn join(self) -> Result<(ServerStats, Vec<SessionOutcome>)> {
        let ChannelHarness {
            events, clock: _, clients, next_client: _, reactor, sites, restart: _,
        } = self;
        drop(events);
        drop(clients);
        let handle = reactor.ok_or_else(|| anyhow!("the reactor handle is already gone"))?;
        let stats = match handle.join().map_err(|_| anyhow!("reactor thread panicked"))?? {
            ReactorOutcome::Finished(stats) => stats,
            ReactorOutcome::Crashed { .. } => bail!(
                "the reactor sits at its staged crash point — call crash_and_restart \
                 before join"
            ),
        };
        // The reactor dropping its driver closed every site downlink, so
        // the sessions end cleanly (Ok) just like a leader disconnecting.
        let mut outcomes = Vec::with_capacity(sites.len());
        for s in sites {
            outcomes.push(s.join().map_err(|_| anyhow!("site thread panicked"))??);
        }
        Ok((stats, outcomes))
    }
}
