//! The job-serving leader: one reactor loop, many interleaved runs.
//!
//! `dsc leader --serve` turns the leader from a one-run driver into a
//! long-lived service. The shape:
//!
//! ```text
//!  clients ──SUBMIT──▶ ┌──────────────┐ ◀──run-scoped frames── sites
//!  (dsc submit)        │   mailbox    │   (persistent sessions,
//!                      │ SiteFrame    │    dialed concurrently,
//!   accept thread ───▶ │ SiteDown     │    one reader thread per
//!   per-conn reader ─▶ │ ClientSubmit │    link feeding the mailbox)
//!   threads            │ ClientPull   │
//!   central workers ─▶ │ CentralDone  │
//!                      │ Tick         │
//!                      └──────┬───────┘
//!                             ▼
//!                      one reactor loop: a JobQueue, at most
//!                      [`ServerOpts::max_jobs`] active [`RunMachine`]s,
//!                      per-run byte accounting, straggler deadlines
//! ```
//!
//! Every blocking wait lives in a helper thread; the reactor itself only
//! ever blocks on its mailbox (with a timeout at the nearest run
//! deadline, delivered as `Tick`). Runs interleave over the same site
//! links because every frame carries its run id; per-run [`LinkStats`]
//! are kept by the reactor as it encodes/decodes, so two jobs running
//! concurrently report byte counters identical to the same jobs run
//! back-to-back (pinned by `rust/tests/channel_harness.rs` and
//! `rust/tests/job_server.rs`).
//!
//! **Central offload.** A run's central spectral step does not run on the
//! reactor thread: when the last codebook lands, the codeword union is
//! handed to a small worker pool ([`ServerOpts::central_workers`], config
//! `[leader] central_workers`) and the result comes back through the
//! mailbox as a `CentralDone` event. Site frames, submits, and straggler
//! ticks for *other* runs keep flowing while a central is in flight — the
//! serving pipeline the paper's speedup argument wants. With
//! `central_workers = 0` (or an XLA backend, whose runtime is
//! thread-local) centrals run inline, the pre-offload behavior. The
//! blocking one-shot driver ([`super::leader_protocol`]) always runs its
//! single central inline.
//!
//! **The driver seam.** The reactor core (`Reactor`) owns no transport:
//! everything socket-shaped — per-link reader threads, the client
//! acceptor, re-dialing a dead site — sits behind the `ServerDriver`
//! trait. [`serve_jobs`] wires it to TCP (`TcpDriver`);
//! [`super::harness`] wires the *identical* reactor to in-process channel
//! sites with an injectable fault plan and a virtual clock, which is what
//! makes the multi-run protocol testable without sockets or sleeps
//! (`docs/TESTING.md`).
//!
//! Failure policy: a dead site link fails every *active* run (the star
//! spans all sites) but not the queue — before starting a queued run the
//! server re-dials any dead link, so the queue keeps draining after a
//! site daemon restarts. A run failure is reported to its client as a
//! `REJECT` frame; the server itself only stops on fatal local errors
//! (e.g. the client listener dying).

use std::collections::{HashMap, HashSet, VecDeque};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{Backend, PipelineConfig};
use crate::net::tcp::{self, Backoff, TcpClient, TcpTimeouts};
use crate::net::{wire, JobReport, JobSpec, LinkStats, Message, RejectCode};

use super::journal::{self, Journal, JournalEvent, Record};
use super::machine::{Advance, OutMsg, RunInput, RunMachine};
use super::{central_cluster, check_graph_backend_kinds, resolve_xla};

/// Serving knobs (config `[leader]`, flags override).
#[derive(Clone, Debug)]
pub struct ServerOpts {
    /// Runs allowed in flight at once; further jobs wait in the queue.
    pub max_jobs: usize,
    /// Pending-job cap; submissions beyond it are rejected immediately.
    pub queue_depth: usize,
    /// Whether clients may pull populated labels through the leader
    /// (`LABELSPULL`). Off by default: the paper's privacy posture keeps
    /// per-point labels at the sites.
    pub allow_label_pull: bool,
    /// Central-step worker threads (`[leader] central_workers`). `0` runs
    /// centrals inline on the reactor thread; XLA backends are always
    /// inline regardless (their runtime is thread-local).
    pub central_workers: usize,
    /// Exit after this many client connections have come *and gone* —
    /// drills, tests and the CI smoke use it to get a clean shutdown once
    /// every client got everything it asked for (results, label pulls);
    /// `None` serves forever.
    pub client_limit: Option<u64>,
}

impl Default for ServerOpts {
    fn default() -> Self {
        let cfg = crate::config::LeaderConfig::default();
        ServerOpts {
            max_jobs: cfg.max_jobs,
            queue_depth: cfg.queue_depth,
            allow_label_pull: cfg.allow_label_pull,
            central_workers: cfg.central_workers,
            client_limit: None,
        }
    }
}

impl ServerOpts {
    /// Lift the `[leader]` config table into serving options.
    pub fn from_config(cfg: &PipelineConfig) -> ServerOpts {
        ServerOpts {
            max_jobs: cfg.leader.max_jobs,
            queue_depth: cfg.leader.queue_depth,
            allow_label_pull: cfg.leader.allow_label_pull,
            central_workers: cfg.leader.central_workers,
            client_limit: None,
        }
    }
}

/// What a serving session did (returned when `client_limit` is reached).
#[derive(Clone, Copy, Debug, Default)]
pub struct ServerStats {
    /// Runs that delivered labels and a `JOBDONE`.
    pub completed: u64,
    /// Runs that started (or were queued) and then failed.
    pub failed: u64,
    /// Submissions refused outright (bad spec, queue full, rate limited).
    pub rejected: u64,
    /// `SITEINFO2` shard-digest reports received (sites volunteering
    /// `[site] report_digest`). Observability only — never accounted to a
    /// run, so byte counters are identical whether sites report or not.
    pub digests_seen: u64,
}

/// The reactor mailbox. Site/client reader threads, the acceptor, and the
/// central worker pool all funnel here; `Tick` is synthesized by the loop
/// itself when the nearest run deadline expires with nothing delivered
/// (or injected explicitly by the channel harness's virtual clock).
pub(crate) enum Event {
    /// One frame from a site link. `gen` stamps which incarnation of the
    /// link the reader belongs to — events from a replaced connection are
    /// stale and dropped.
    SiteFrame { site: usize, gen: u64, frame: Vec<u8> },
    /// A site link died (clean close, decode failure, or io error).
    SiteDown { site: usize, gen: u64, err: String },
    /// A client submitted a job. `modern` says which dialect the submit
    /// frame spoke: SUBMITPRI(18) opts the client into JOBACCEPT2/REJECT2
    /// replies, legacy SUBMIT(14) keeps the frozen JOBACCEPT/REJECT frames.
    ClientSubmit { client: u64, spec: Box<JobSpec>, modern: bool },
    /// A client asked for a completed run's populated labels.
    ClientPull { client: u64, run: u32 },
    /// A client connection ended (its runs keep going; reports are
    /// dropped).
    ClientDown { client: u64 },
    /// A central worker finished a run's spectral step: codeword labels
    /// and σ on success, the error text otherwise, plus the compute wall
    /// time.
    CentralDone { run: u32, result: Result<(Vec<u16>, f64), String>, elapsed: Duration },
    /// Deadline check.
    Tick,
}

/// Transport-facing edge of the job server: everything the reactor needs
/// a backend to do, and nothing it does itself. The TCP implementation
/// ([`TcpDriver`]) owns sockets, reader threads and re-dialing; the
/// channel implementation ([`super::harness`]) owns in-process links and
/// a virtual clock. The reactor encodes/decodes and accounts every frame
/// *above* this seam, so per-run byte counters are identical across
/// backends by construction.
pub(crate) trait ServerDriver {
    /// Number of site links in the star.
    fn n_sites(&self) -> usize;
    /// Current incarnation of a site link (for stale-event filtering).
    fn link_gen(&self, site: usize) -> u64;
    /// Deliver one encoded frame to a site. `Err` means the link just
    /// failed — the reactor will take it down.
    fn send_site(&mut self, site: usize, frame: &[u8]) -> Result<()>;
    /// Tear a site link down (bump its generation, wake its reader).
    /// Returns whether the link was up — `false` means it was already
    /// down and nothing changed.
    fn take_down(&mut self, site: usize) -> bool;
    /// Bring every dead site link back up (TCP re-dials and arms a fresh
    /// reader). `Err` leaves the links as they were; channel links cannot
    /// be revived, so a severed one errors here forever.
    fn ensure_links(&mut self) -> Result<()>;
    /// Deliver one encoded frame to a client. `Err` means the client is
    /// gone — the reactor will drop it.
    fn send_client(&mut self, client: u64, frame: &[u8]) -> Result<()>;
    /// Forget a client (its write half is closed/dropped).
    fn drop_client(&mut self, client: u64);
    /// Close every client link (server shutdown).
    fn close_clients(&mut self);
    /// The reactor's clock. Real time for TCP; a
    /// [`crate::net::channel::VirtualClock`] in the harness, so deadline
    /// tests advance time explicitly instead of sleeping through it.
    fn now(&self) -> Instant;
}

// ─── central worker pool ───────────────────────────────────────────────────

/// Test instrumentation: called by a central worker with the run id just
/// before it computes. The channel harness uses it to make one run's
/// central deterministically slow (block on a channel) and prove the
/// reactor keeps serving everyone else meanwhile.
pub type CentralHook = Arc<dyn Fn(u32) + Send + Sync>;

/// One offloaded central step: the codeword union, cloned out of the
/// machine so the reactor keeps owning its state while a worker computes.
struct CentralJob {
    run: u32,
    cw: Vec<f32>,
    dim: usize,
    w: Vec<f32>,
    spec: JobSpec,
}

/// Handle to the central worker pool. `jobs = None` means "no pool": the
/// reactor runs centrals inline (configured off, or an XLA backend whose
/// runtime cannot leave the reactor thread).
pub(crate) struct CentralPool {
    jobs: Option<Sender<CentralJob>>,
}

impl CentralPool {
    /// Spawn `workers` central threads feeding `events`. The workers share
    /// one job queue (a `Mutex<Receiver>` — centrals are seconds-long, so
    /// lock traffic is nil) and exit when the pool handle drops.
    pub(crate) fn start(
        workers: usize,
        events: Sender<Event>,
        hook: Option<CentralHook>,
    ) -> CentralPool {
        if workers == 0 {
            return CentralPool { jobs: None };
        }
        let (tx, rx) = mpsc::channel::<CentralJob>();
        let rx = Arc::new(Mutex::new(rx));
        for _ in 0..workers {
            let rx = Arc::clone(&rx);
            let events = events.clone();
            let hook = hook.clone();
            thread::spawn(move || loop {
                // Hold the lock only for the dequeue, never the compute.
                let job = match rx.lock().unwrap().recv() {
                    Ok(job) => job,
                    Err(_) => return, // pool handle dropped: server is done
                };
                if let Some(h) = &hook {
                    h(job.run);
                }
                let t0 = Instant::now();
                // Offload is gated to Backend::Native (see `drive`), so no
                // runtime handle needs to cross into this thread. A panic
                // must surface as a failed run, not silently wedge it in
                // `Central` forever (mid-central runs have no straggler
                // deadline, so nothing else would ever fail it — and the
                // client would block in await_done with a leaked job slot).
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    central_cluster(&job.cw, job.dim, &job.w, &job.spec, Backend::Native, None)
                }))
                .unwrap_or_else(|_| Err(anyhow!("central step panicked")))
                .map_err(|e| format!("{e:#}"));
                if events
                    .send(Event::CentralDone { run: job.run, result, elapsed: t0.elapsed() })
                    .is_err()
                {
                    return; // reactor gone
                }
            });
        }
        CentralPool { jobs: Some(tx) }
    }

    /// A workerless stand-in whose `jobs.is_some()` matches a real pool's,
    /// so journal replay takes the same offload-vs-inline branch the
    /// original reactor took. Replay never sends into it (`drive` returns
    /// before the send while replaying) — the journaled `CentralDone`
    /// advances the machine instead.
    pub(crate) fn replay_stub(active: bool) -> CentralPool {
        if !active {
            return CentralPool { jobs: None };
        }
        let (tx, _rx) = mpsc::channel::<CentralJob>();
        CentralPool { jobs: Some(tx) }
    }
}

// ─── scheduling primitives ─────────────────────────────────────────────────

/// Deficit round-robin over per-client FIFO lanes — the `[leader]
/// fair_queue = true` scheduler. When a lane reaches the head of the ring
/// with an empty deficit it is granted one round's quantum: the priority
/// (weight) of its head job. Serving one job costs one unit, so a client
/// whose jobs carry weight *w* gets *w* consecutive jobs per round while
/// backlogged — long-run service shares converge to the weight ratio no
/// matter how lopsided the submit mix is (pinned by
/// `prop_drr_backlogged_service_tracks_weights` in
/// `rust/tests/properties.rs`). Per-client order is always FIFO.
///
/// Generic over the queued item so the policy is unit-testable — and
/// replayable by the load generator's schedule predictor — without a
/// reactor around it.
#[derive(Debug)]
pub struct DrrQueue<T> {
    ring: VecDeque<Lane<T>>,
    len: usize,
}

#[derive(Debug)]
struct Lane<T> {
    client: u64,
    /// `(weight, item)` in arrival order.
    jobs: VecDeque<(u32, T)>,
    deficit: u32,
}

impl<T> Default for DrrQueue<T> {
    fn default() -> Self {
        DrrQueue::new()
    }
}

impl<T> DrrQueue<T> {
    pub fn new() -> DrrQueue<T> {
        DrrQueue { ring: VecDeque::new(), len: 0 }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Append an item to `client`'s lane with scheduling weight `weight`
    /// (clamped to ≥ 1). A client seen for the first time joins the ring
    /// at the tail.
    pub fn push(&mut self, client: u64, weight: u32, item: T) {
        let weight = weight.max(1);
        self.len += 1;
        if let Some(lane) = self.ring.iter_mut().find(|l| l.client == client) {
            lane.jobs.push_back((weight, item));
            return;
        }
        let mut jobs = VecDeque::new();
        jobs.push_back((weight, item));
        self.ring.push_back(Lane { client, jobs, deficit: 0 });
    }

    /// Dequeue the next item under deficit round-robin.
    pub fn pop(&mut self) -> Option<T> {
        loop {
            let lane = self.ring.front_mut()?;
            let Some(&(weight, _)) = lane.jobs.front() else {
                // defensive: emptied lanes leave the ring below
                self.ring.pop_front();
                continue;
            };
            if lane.deficit == 0 {
                // fresh visit at the ring head: grant one round's quantum
                lane.deficit = weight;
            }
            lane.deficit -= 1;
            let (_, item) = lane.jobs.pop_front().expect("checked non-empty");
            self.len -= 1;
            if lane.deficit == 0 || lane.jobs.is_empty() {
                // visit over: rotate. An emptied lane leaves the ring and
                // forfeits unused deficit (classic DRR empty-queue reset),
                // so an idle client cannot bank service credit.
                let mut lane = self.ring.pop_front().expect("front exists");
                lane.deficit = 0;
                if !lane.jobs.is_empty() {
                    self.ring.push_back(lane);
                }
            }
            return Some(item);
        }
    }

    /// How many queued jobs DRR would serve before a job `client` pushes
    /// *now* with scheduling weight `weight` — the honest JOBACCEPT2 queue
    /// position under `fair_queue`, where the global backlog count lies
    /// (a light client's first job overtakes a flooder's lane). Read-only:
    /// replays [`DrrQueue::pop`]'s exact schedule on a weight-only copy of
    /// the ring (current deficits included) with the probe job appended.
    pub fn position_of_next(&self, client: u64, weight: u32) -> usize {
        let weight = weight.max(1);
        struct SimLane {
            client: u64,
            /// `(weight, is_probe)` in arrival order.
            jobs: VecDeque<(u32, bool)>,
            deficit: u32,
        }
        let mut ring: VecDeque<SimLane> = self
            .ring
            .iter()
            .map(|l| SimLane {
                client: l.client,
                jobs: l.jobs.iter().map(|&(w, _)| (w, false)).collect(),
                deficit: l.deficit,
            })
            .collect();
        if let Some(lane) = ring.iter_mut().find(|l| l.client == client) {
            lane.jobs.push_back((weight, true));
        } else {
            let mut jobs = VecDeque::new();
            jobs.push_back((weight, true));
            ring.push_back(SimLane { client, jobs, deficit: 0 });
        }
        let mut served = 0usize;
        loop {
            let Some(lane) = ring.front_mut() else {
                unreachable!("the probe job is always in the ring until served");
            };
            let Some(&(w, probe)) = lane.jobs.front() else {
                ring.pop_front();
                continue;
            };
            if lane.deficit == 0 {
                lane.deficit = w;
            }
            lane.deficit -= 1;
            lane.jobs.pop_front().expect("checked non-empty");
            if probe {
                return served;
            }
            served += 1;
            if lane.deficit == 0 || lane.jobs.is_empty() {
                let mut lane = ring.pop_front().expect("front exists");
                lane.deficit = 0;
                if !lane.jobs.is_empty() {
                    ring.push_back(lane);
                }
            }
        }
    }
}

/// Per-client token-bucket admission meter (`[leader] admit_rate` /
/// `admit_burst`): `rate` tokens per second refill up to `burst`, one
/// submit costs one token. Clocked by caller-supplied `Instant`s — the
/// reactor passes `driver.now()`, so the channel harness exercises refill
/// on a virtual clock with no sleeps.
#[derive(Clone, Debug)]
pub struct TokenBucket {
    rate: f64,
    burst: f64,
    tokens: f64,
    last: Instant,
}

impl TokenBucket {
    /// A full bucket as of `now`. `rate` is submits/second (> 0); `burst`
    /// is clamped to ≥ 1 token.
    pub fn new(rate: f64, burst: f64, now: Instant) -> TokenBucket {
        let burst = burst.max(1.0);
        TokenBucket { rate, burst, tokens: burst, last: now }
    }

    /// Take one token, refilling from the time elapsed since the last
    /// call first. `Err` carries the wait until the next token exists.
    pub fn try_take(&mut self, now: Instant) -> std::result::Result<(), Duration> {
        let dt = now.saturating_duration_since(self.last).as_secs_f64();
        self.last = now;
        self.tokens = (self.tokens + dt * self.rate).min(self.burst);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            Ok(())
        } else {
            Err(Duration::from_secs_f64((1.0 - self.tokens) / self.rate))
        }
    }

    /// Return one token (capped at `burst`): a submit that was charged and
    /// then refused for a reason the client did not spend server work on
    /// (bad spec, full queue) must not also burn admission allowance —
    /// during overload that would rate-starve a well-behaved client on
    /// rejections it never caused.
    pub fn refund(&mut self) {
        self.tokens = (self.tokens + 1.0).min(self.burst);
    }
}

/// The reactor's pending-job queue: global FIFO (the legacy default,
/// byte-for-byte the pre-`fair_queue` server) or per-client DRR.
enum JobQueue {
    Fifo(VecDeque<Job>),
    Fair(DrrQueue<Job>),
}

impl JobQueue {
    fn new(fair: bool) -> JobQueue {
        if fair {
            JobQueue::Fair(DrrQueue::new())
        } else {
            JobQueue::Fifo(VecDeque::new())
        }
    }

    fn len(&self) -> usize {
        match self {
            JobQueue::Fifo(q) => q.len(),
            JobQueue::Fair(q) => q.len(),
        }
    }

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn push(&mut self, job: Job) {
        match self {
            JobQueue::Fifo(q) => q.push_back(job),
            JobQueue::Fair(q) => {
                let (client, weight) = (job.client, job.spec.priority);
                q.push(client, weight, job)
            }
        }
    }

    fn pop(&mut self) -> Option<Job> {
        match self {
            JobQueue::Fifo(q) => q.pop_front(),
            JobQueue::Fair(q) => q.pop(),
        }
    }

    /// Queued jobs the scheduler will serve before a job `client` pushes
    /// next with weight `weight`: the whole backlog under FIFO, the lane
    /// schedule's answer under DRR.
    fn position_for(&self, client: u64, weight: u32) -> usize {
        match self {
            JobQueue::Fifo(q) => q.len(),
            JobQueue::Fair(q) => q.position_of_next(client, weight),
        }
    }
}

// ─── reactor core ──────────────────────────────────────────────────────────

struct Job {
    run: u32,
    client: u64,
    spec: JobSpec,
}

struct RunEntry {
    machine: RunMachine,
    client: u64,
    /// Per-run, per-link counters — only this run's frames.
    stats: Vec<LinkStats>,
    started: Instant,
}

/// A label pull in flight: `outstanding` site frames still to forward.
struct Pull {
    run: u32,
    client: u64,
    outstanding: usize,
}

/// Completed runs the leader remembers for label pulls.
const COMPLETED_CAP: usize = 64;

/// One journaled [`JournalEvent::SendFail`], queued for replay-time
/// re-injection at its send ordinal.
struct ReplayFail {
    seq: u64,
    site: usize,
    err: String,
}

/// The transport-agnostic job-server core: run lifecycle, the job queue,
/// per-run byte accounting, straggler deadlines, the pull plane — driven
/// by [`Event`]s a frontend feeds it off its mailbox. See the module docs
/// for the two frontends.
pub(crate) struct Reactor<D: ServerDriver> {
    cfg: PipelineConfig,
    opts: ServerOpts,
    xla: Option<std::rc::Rc<crate::runtime::XlaRuntime>>,
    driver: D,
    pool: CentralPool,
    queue: JobQueue,
    active: HashMap<u32, RunEntry>,
    /// Recently completed runs (run id → site count), FIFO-capped, for
    /// label pulls.
    completed: VecDeque<(u32, usize)>,
    pulls: Vec<Pull>,
    next_run: u32,
    /// Client connections that have ended (for `client_limit`).
    clients_done: u64,
    /// Re-dial pacing for dead site links: queued jobs *wait* through a
    /// site outage (capped, jittered schedule) instead of being drained
    /// with rejects by back-to-back failed dials.
    redial_backoff: Backoff,
    /// No re-dial (and so no queued-run start) before this instant.
    redial_after: Option<Instant>,
    stats: ServerStats,
    /// Clients that submitted via SUBMITPRI(18) at least once: they get
    /// modern-dialect replies (JOBACCEPT2/REJECT2) from then on.
    modern: HashSet<u64>,
    /// Per-client admission meters (`[leader] admit_rate` > 0 only).
    buckets: HashMap<u64, TokenBucket>,
    /// Latest volunteered shard-digest root per site (`SITEINFO2`).
    /// Observability state: re-learned when a site reconnects, never
    /// journaled, never consulted by run machines — the DML result cache
    /// it describes lives entirely on the site.
    site_digests: HashMap<usize, u64>,
    /// Running mean of completed central durations — the ETA basis of
    /// JOBACCEPT2 (`eta_ns ≈ position × mean central`). 0 until the first
    /// run completes.
    central_mean_ns: f64,
    /// Completed centrals behind `central_mean_ns`.
    centrals_done: u64,
    /// Crash-recovery log (`[leader] journal_path`); `None` = off, the
    /// default, which keeps the event path byte-identical to a leader
    /// built without journaling.
    journal: Option<Journal>,
    /// The journal's epoch on this reactor's clock — record `t_ns` values
    /// are offsets from it (plus `jbase_ns`), so replay can rebuild every
    /// `Instant` (run deadlines, token-bucket levels, backoff windows) in
    /// the original timeline.
    jepoch: Instant,
    /// Added to every appended timestamp. 0 for a fresh log; the last
    /// recovered `t_ns` when resuming one, so the log's timeline continues
    /// monotonically. Kept as an offset rather than backdating `jepoch`:
    /// `Instant` subtraction panics on underflow, and after a reboot the
    /// monotonic clock restarts — a journal spanning longer than current
    /// uptime would make recovery itself panic.
    jbase_ns: u64,
    /// Ordinal of the next outbound site frame (see
    /// [`JournalEvent::SendFail`]); resets to 0 at a process restart.
    send_seq: u64,
    /// While replaying: journaled send failures of the current process
    /// incarnation, in order — [`Reactor::send_site_frame`] re-fails the
    /// send whose ordinal matches the front entry.
    replay_fail: VecDeque<ReplayFail>,
    /// Replaying a recovered journal: suppress re-journaling (the records
    /// being applied are already on disk), let the [`ReplayDriver`]
    /// swallow re-sends, and skip re-offloading centrals — their
    /// journaled `CentralDone` advances the machine instead.
    replaying: bool,
    /// Journal replication to a warm standby: the sender thread's inbox
    /// ([`spawn_replicator`]). `None` — no journal, the channel harness,
    /// or a replication-free build — keeps the event path byte-identical
    /// to the pre-failover server.
    repl: Option<Sender<ReplEvent>>,
    /// Framed records appended since the last group commit, with their
    /// record indices. Handed to the sender thread only *after* the sync
    /// that made them durable, so the standby can never hold a record the
    /// primary's own disk does not.
    repl_pending: Vec<(u64, Vec<u8>)>,
}

impl<D: ServerDriver> Reactor<D> {
    pub(crate) fn new(
        cfg: PipelineConfig,
        opts: ServerOpts,
        driver: D,
        pool: CentralPool,
    ) -> Result<Reactor<D>> {
        if opts.max_jobs == 0 || opts.queue_depth == 0 {
            bail!("[leader] max_jobs and queue_depth must be ≥ 1");
        }
        if !cfg.leader.admit_rate.is_finite() || cfg.leader.admit_rate < 0.0 {
            bail!("[leader] admit_rate must be finite and ≥ 0 (0 disables admission)");
        }
        let xla = resolve_xla(&cfg)?;
        let seed = cfg.seed;
        let queue = JobQueue::new(cfg.leader.fair_queue);
        let jepoch = driver.now();
        Ok(Reactor {
            cfg,
            opts,
            xla,
            driver,
            pool,
            queue,
            active: HashMap::new(),
            completed: VecDeque::new(),
            pulls: Vec::new(),
            next_run: 1,
            clients_done: 0,
            redial_backoff: Backoff::new(seed ^ 0xD1A1),
            redial_after: None,
            stats: ServerStats::default(),
            modern: HashSet::new(),
            buckets: HashMap::new(),
            site_digests: HashMap::new(),
            central_mean_ns: 0.0,
            centrals_done: 0,
            journal: None,
            jepoch,
            jbase_ns: 0,
            send_seq: 0,
            replay_fail: VecDeque::new(),
            replaying: false,
            repl: None,
            repl_pending: Vec::new(),
        })
    }

    // ─── journaling & replay ───────────────────────────────────────────

    /// Start journaling into `journal`, with its epoch at the clock's
    /// current reading (a fresh log: the next record is `t_ns = 0`).
    pub(crate) fn attach_journal(&mut self, journal: Journal) {
        self.jepoch = self.driver.now();
        self.jbase_ns = 0;
        self.journal = Some(journal);
    }

    /// Resume journaling into a recovered log whose last record carried
    /// `last_t_ns`: appended records continue the recovered timeline
    /// monotonically from there. The continuation is an additive offset on
    /// a fresh epoch, *not* a backdated `Instant` — backdating would panic
    /// on underflow whenever the journal spans longer than the monotonic
    /// clock has been running (e.g. any recovery after a reboot).
    pub(crate) fn attach_journal_resumed(&mut self, journal: Journal, last_t_ns: u64) {
        self.jepoch = self.driver.now();
        self.jbase_ns = last_t_ns;
        self.journal = Some(journal);
    }

    /// Attach with a caller-pinned epoch. The channel harness fixes the
    /// epoch *before* spawning the reactor thread (and reuses the same
    /// instant across a staged crash), so virtual-clock advances that race
    /// the thread start cannot skew journaled timestamps, and the whole
    /// log shares one absolute timeline.
    pub(crate) fn attach_journal_at(&mut self, journal: Journal, epoch: Instant) {
        self.jepoch = epoch;
        self.jbase_ns = 0;
        self.journal = Some(journal);
    }

    pub(crate) fn set_replaying(&mut self, on: bool) {
        self.replaying = on;
    }

    /// Durably mark a process restart — appended right after a recovery
    /// replay and before any restarted run's traffic, so a later replay
    /// re-enacts the restart at the same point in the history.
    pub(crate) fn journal_restart(&mut self) {
        if self.replaying || self.journal.is_none() {
            return;
        }
        self.append_journal(&JournalEvent::Restart);
        // The send ordinal restarts with the process; replay mirrors this
        // reset when it consumes the Restart record.
        self.send_seq = 0;
    }

    /// Records in the attached journal, `None` when journaling is off.
    pub(crate) fn journal_records(&self) -> Option<u64> {
        self.journal.as_ref().map(|j| j.records())
    }

    /// Detach the journal (the channel harness extracts it at a staged
    /// crash so it can force the tail durable before "rebooting").
    pub(crate) fn take_journal(&mut self) -> Option<Journal> {
        self.journal.take()
    }

    /// Arm journal replication: every framed append is handed to `tx`
    /// (the [`spawn_replicator`] sender thread) right after the group
    /// commit that made it durable here.
    pub(crate) fn attach_repl(&mut self, tx: Sender<ReplEvent>) {
        self.repl = Some(tx);
    }

    /// Group commit: flush (and fsync when configured) everything
    /// appended since the last sync. Frontends call this once per mailbox
    /// drain — right before blocking — so durability is batched off the
    /// hot path. A sync failure disables journaling loudly rather than
    /// taking the server down; the on-disk log is poisoned on the way out
    /// so a later recovery cannot mistake the truncated history for a
    /// complete one (see [`Journal::poison`]). Replication ships strictly
    /// behind this commit: staged frames go to the standby only once the
    /// sync succeeds, and a disabled journal disables the stream with it.
    pub(crate) fn sync_journal(&mut self) {
        let Some(j) = self.journal.as_mut() else { return };
        if let Err(e) = j.sync() {
            eprintln!("leader: journal sync failed ({e:#}); journaling disabled");
            if let Some(j) = self.journal.take() {
                j.poison();
            }
            self.repl = None;
            self.repl_pending.clear();
            return;
        }
        if self.repl.is_some() && !self.repl_pending.is_empty() {
            let tx = self.repl.as_ref().expect("checked above");
            let mut sender_gone = false;
            for (index, framed) in self.repl_pending.drain(..) {
                if tx.send(ReplEvent::Record(index, framed)).is_err() {
                    sender_gone = true;
                    break;
                }
            }
            if sender_gone {
                self.repl = None;
                self.repl_pending.clear();
            }
        }
    }

    /// Write-ahead: journal one mailbox event before it is applied.
    fn journal_event(&mut self, event: &Event) {
        if self.replaying || self.journal.is_none() {
            return;
        }
        let ev = match event {
            Event::SiteFrame { site, gen, frame } => {
                JournalEvent::SiteFrame { site: *site, gen: *gen, frame: frame.clone() }
            }
            Event::SiteDown { site, gen, err } => {
                JournalEvent::SiteDown { site: *site, gen: *gen, err: err.clone() }
            }
            Event::ClientSubmit { client, spec, modern } => JournalEvent::ClientSubmit {
                client: *client,
                spec: (**spec).clone(),
                modern: *modern,
            },
            Event::ClientPull { client, run } => {
                JournalEvent::ClientPull { client: *client, run: *run }
            }
            Event::ClientDown { client } => JournalEvent::ClientDown { client: *client },
            Event::CentralDone { run, result, elapsed } => JournalEvent::CentralDone {
                run: *run,
                result: result.clone(),
                elapsed_ns: elapsed.as_nanos() as u64,
            },
            Event::Tick => JournalEvent::Tick,
        };
        self.append_journal(&ev);
    }

    /// Journal an annotation — a scheduling decision (admission, queue
    /// pop, completion) replay re-derives for itself but tests and
    /// operators read back as the durable record of what the leader did.
    fn annotate(&mut self, ev: JournalEvent) {
        if self.replaying || self.journal.is_none() {
            return;
        }
        self.append_journal(&ev);
    }

    fn append_journal(&mut self, ev: &JournalEvent) {
        let t_ns = self.jbase_ns
            + self.driver.now().saturating_duration_since(self.jepoch).as_nanos() as u64;
        let Some(j) = self.journal.as_mut() else { return };
        match j.append(t_ns, ev) {
            Ok(index) => {
                if self.repl.is_some() {
                    // Stage the identical framed bytes for the standby;
                    // they leave for the sender thread only after the
                    // group commit that makes them durable (`sync_journal`).
                    self.repl_pending.push((index, journal::frame_record(t_ns, ev)));
                }
            }
            Err(e) => {
                eprintln!("leader: journal write failed ({e:#}); journaling disabled");
                if let Some(j) = self.journal.take() {
                    j.poison();
                }
                self.repl = None;
                self.repl_pending.clear();
            }
        }
    }

    /// Dismantle the reactor into its transferable state, its driver and
    /// its worker-pool handle. The journal is *not* part of the state (it
    /// is re-opened by the recovering frontend) and neither is the XLA
    /// runtime handle ([`Reactor::from_parts`] re-resolves it — it is
    /// thread-local and must not ride a state transfer across threads).
    pub(crate) fn into_parts(mut self) -> (ReactorParts, D, CentralPool) {
        self.journal = None;
        let Reactor {
            cfg,
            opts,
            driver,
            pool,
            queue,
            active,
            completed,
            pulls,
            next_run,
            clients_done,
            redial_backoff,
            redial_after,
            stats,
            modern,
            buckets,
            central_mean_ns,
            centrals_done,
            send_seq,
            ..
        } = self;
        let parts = ReactorParts {
            cfg,
            opts,
            queue,
            active,
            completed,
            pulls,
            next_run,
            clients_done,
            redial_backoff,
            redial_after,
            stats,
            modern,
            buckets,
            central_mean_ns,
            centrals_done,
            send_seq,
        };
        (parts, driver, pool)
    }

    /// Rebuild a reactor around replayed state with a live driver and
    /// pool — the second half of crash recovery (the first half is
    /// [`Reactor::replay`] against a [`ReplayDriver`]).
    pub(crate) fn from_parts(
        parts: ReactorParts,
        driver: D,
        pool: CentralPool,
    ) -> Result<Reactor<D>> {
        let xla = resolve_xla(&parts.cfg)?;
        let jepoch = driver.now();
        Ok(Reactor {
            cfg: parts.cfg,
            opts: parts.opts,
            xla,
            driver,
            pool,
            queue: parts.queue,
            active: parts.active,
            completed: parts.completed,
            pulls: parts.pulls,
            next_run: parts.next_run,
            clients_done: parts.clients_done,
            redial_backoff: parts.redial_backoff,
            redial_after: parts.redial_after,
            stats: parts.stats,
            modern: parts.modern,
            buckets: parts.buckets,
            // Sites re-volunteer their digest on every (re)connection, so
            // recovery starts blank rather than trusting pre-crash reports.
            site_digests: HashMap::new(),
            central_mean_ns: parts.central_mean_ns,
            centrals_done: parts.centrals_done,
            journal: None,
            jepoch,
            jbase_ns: 0,
            // The channel harness resumes the surviving incarnation's send
            // stream mid-flight; the TCP path resets this via
            // `journal_restart` right after re-arming.
            send_seq: parts.send_seq,
            replay_fail: VecDeque::new(),
            replaying: false,
            repl: None,
            repl_pending: Vec::new(),
        })
    }

    /// Process-restart recovery (the TCP frontend): the original sites,
    /// clients and worker pool died with the process, so every replayed
    /// *incomplete* run restarts from scratch on the fresh links — same
    /// spec, new machine, zeroed byte counters, a fresh `RUNSTART` on
    /// every site — in ascending run order. Completed runs keep their
    /// label-pull entries; stale client plumbing (pulls, dialect and
    /// admission state keyed by dead connection ids) is dropped, and the
    /// re-dial backoff forgets the dead session's schedule.
    pub(crate) fn restart_active_runs(&mut self) {
        self.pulls.clear();
        self.modern.clear();
        self.buckets.clear();
        self.site_digests.clear();
        self.redial_after = None;
        self.redial_backoff.reset();
        let mut runs: Vec<u32> = self.active.keys().copied().collect();
        runs.sort_unstable();
        let n_sites = self.driver.n_sites();
        let now = self.driver.now();
        for run in runs {
            // A failed send below takes a site link down, which fails every
            // still-active run — later iterations find theirs gone.
            let Some(entry) = self.active.get_mut(&run) else { continue };
            let spec = entry.machine.spec().clone();
            entry.machine = RunMachine::new(n_sites, spec, self.cfg.collect_timeout, now);
            entry.stats = vec![LinkStats::default(); n_sites];
            entry.started = now;
            eprintln!("leader: restarting run {run} recovered from the journal");
            for site in 0..n_sites {
                if let Err(e) =
                    self.send_run_frame(run, site, &Message::RunStart { run })
                {
                    self.site_down(site, &format!("{e:#}"));
                    break; // this run just failed; later runs still restart
                }
            }
        }
    }

    /// Whether `client_limit` clients have come and gone — the frontend's
    /// clean-shutdown condition.
    pub(crate) fn done(&self) -> bool {
        self.opts.client_limit.is_some_and(|limit| self.clients_done >= limit)
    }

    /// Tear down client links and surrender the stats (server shutdown).
    pub(crate) fn finish(mut self) -> ServerStats {
        self.sync_journal();
        self.driver.close_clients();
        self.stats
    }

    /// Apply one mailbox event, then the per-iteration housekeeping every
    /// frontend owes the reactor: deadlines are enforced every iteration,
    /// not only when the mailbox happens to be empty at the timeout
    /// (`Tick`) — under sustained traffic the mailbox keeps delivering
    /// events and a stalled run's collect_timeout must still fire on
    /// schedule — and queued jobs start whenever a slot is free.
    pub(crate) fn step(&mut self, event: Event) {
        self.journal_event(&event);
        match event {
            Event::SiteFrame { site, gen, frame } => {
                if gen == self.driver.link_gen(site) {
                    self.on_site_frame(site, frame);
                } // else: stale reader from a replaced connection
            }
            Event::SiteDown { site, gen, err } => {
                if gen == self.driver.link_gen(site) {
                    self.site_down(site, &err);
                }
            }
            Event::ClientSubmit { client, spec, modern } => {
                self.on_submit(client, *spec, modern)
            }
            Event::ClientPull { client, run } => self.on_pull(client, run),
            Event::ClientDown { client } => {
                self.driver.drop_client(client);
                self.pulls.retain(|p| p.client != client);
                self.modern.remove(&client);
                self.buckets.remove(&client);
                self.clients_done += 1;
            }
            Event::CentralDone { run, result, elapsed } => {
                self.on_central_done(run, result, elapsed)
            }
            Event::Tick => {}
        }
        self.expire_overdue();
        self.try_start_jobs();
    }

    /// Nearest wakeup the reactor must honor even with an empty mailbox:
    /// the earliest straggler deadline over the active runs still in a
    /// collect phase (a run whose central is in flight has no deadline —
    /// [`RunMachine::collect_deadline`] hides the stale one, else it
    /// would spin this wait at zero for the whole central), or the
    /// re-dial retry time while dead site links wait out a backoff. The
    /// re-dial deadline holds even with an empty queue: a pull, a label
    /// cache, and the next submit all want the star healthy, and an idle
    /// server has no other event to wake it (pinned by
    /// `severed_site_is_redialed_on_schedule_while_idle` in
    /// `rust/tests/channel_harness.rs`).
    pub(crate) fn next_deadline(&self) -> Option<Instant> {
        let runs = self.active.values().filter_map(|e| e.machine.collect_deadline()).min();
        let redial = self.redial_after;
        match (runs, redial) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }

    // ─── site plane ────────────────────────────────────────────────────

    fn on_site_frame(&mut self, site: usize, frame: Vec<u8>) {
        let len = frame.len();
        let msg = match wire::decode(&frame) {
            Ok(msg) => msg,
            Err(e) => {
                self.site_down(site, &format!("sent an undecodable frame: {e:#}"));
                return;
            }
        };
        match msg {
            Message::RunSiteInfo { run, site: s, n_points, dim } => {
                if s as usize != site {
                    self.site_down(site, "site id mismatch on site info frame");
                    return;
                }
                self.run_event(run, site, len, RunInput::SiteInfo { site, n_points, dim });
            }
            Message::RunCodebook { run, site: s, dim, codewords, weights } => {
                if s as usize != site {
                    self.site_down(site, "site id mismatch on codebook frame");
                    return;
                }
                self.run_event(
                    run,
                    site,
                    len,
                    RunInput::Codebook { site, dim, codewords, weights },
                );
            }
            // Digest plane: a streaming site volunteering its shard
            // version at connection start (`[site] report_digest`).
            // Recorded for observability and deliberately *not* accounted
            // to any run — no run exists yet, and byte counters must be
            // identical whether sites report or not.
            Message::SiteInfo2 { site: s, n_points, dim, digest, chunks } => {
                if s as usize != site {
                    self.site_down(site, "site id mismatch on digest report frame");
                    return;
                }
                eprintln!(
                    "leader: site {site} shard digest {digest:016x} \
                     ({n_points} points × {dim}d, {chunks} chunks)"
                );
                self.site_digests.insert(site, digest);
                self.stats.digests_seen += 1;
            }
            // Pull plane: forwarded to the pulling client verbatim, and
            // deliberately *not* accounted to any run — the run's NetReport
            // was already fixed when JOBDONE went out.
            Message::SiteLabels { run, .. } => self.forward_pull(run, &frame),
            Message::Reject { run, msg } => self.refuse_pull(run, &msg),
            other => {
                eprintln!("leader: ignoring unexpected frame from site {site}: {other:?}");
            }
        }
    }

    /// Route a frame to its run's machine, accounting it to that run.
    fn run_event(&mut self, run: u32, site: usize, frame_len: usize, input: RunInput) {
        let now = self.driver.now();
        let Some(entry) = self.active.get_mut(&run) else {
            // e.g. a codebook for a run that already failed on a timeout
            eprintln!("leader: dropping frame from site {site} for inactive run {run}");
            return;
        };
        entry.stats[site].account(true, frame_len, &self.cfg.link);
        let adv = entry.machine.advance(now, input);
        self.drive(run, adv);
    }

    /// Apply one machine step: send what it asked, hand a ready central to
    /// the worker pool (or run it inline), finish or fail the run.
    fn drive(&mut self, run: u32, adv: Result<Advance>) {
        let adv = match adv {
            Ok(adv) => adv,
            Err(e) => {
                self.fail_run(run, &format!("{e:#}"));
                return;
            }
        };
        for (site, out) in adv.send {
            let msg = scoped_out(run, site, out);
            if let Err(e) = self.send_run_frame(run, site, &msg) {
                // marks the link down, which fails this run (and any other
                // active one — they all span the dead link)
                self.site_down(site, &format!("{e:#}"));
                return;
            }
        }
        if adv.central {
            // Offload to the pool when it exists and the backend is the
            // pure-Rust path (the XLA runtime is thread-local, so those
            // backends compute inline like the blocking driver does).
            if self.pool.jobs.is_some() && self.cfg.backend == Backend::Native {
                if self.replaying {
                    // The original reactor already offloaded this central:
                    // either its CentralDone is a later journal record, or
                    // it is still in flight on a surviving worker (resume)
                    // or the run will be restarted wholesale (process
                    // restart). Re-offloading would double-compute it.
                    return;
                }
                let entry = self.active.get(&run).expect("central for a live run");
                let (cw, dim, w) = entry.machine.central_input();
                let job = CentralJob {
                    run,
                    cw: cw.to_vec(),
                    dim,
                    w: w.to_vec(),
                    spec: entry.machine.spec().clone(),
                };
                if self.pool.jobs.as_ref().expect("checked above").send(job).is_err() {
                    // every worker died (panicked): fail this run rather
                    // than leave it stuck in Central forever
                    self.fail_run(run, "central worker pool is gone");
                }
                return; // CentralDone continues this run via the mailbox
            }
            let result = {
                let entry = self.active.get(&run).expect("central for a live run");
                let (cw, dim, w) = entry.machine.central_input();
                let t0 = Instant::now();
                central_cluster(
                    cw,
                    dim,
                    w,
                    entry.machine.spec(),
                    self.cfg.backend,
                    self.xla.as_deref(),
                )
                .map(|out| (out, t0.elapsed()))
            };
            match result {
                Ok(((labels, sigma), central)) => {
                    let adv = self
                        .active
                        .get_mut(&run)
                        .expect("still live")
                        .machine
                        .central_done(labels, sigma, central);
                    self.drive(run, adv);
                }
                Err(e) => self.fail_run(run, &format!("central step failed: {e:#}")),
            }
            return; // done-handling happened in the recursive drive
        }
        if adv.done {
            self.complete_run(run);
        }
    }

    /// A worker delivered a run's central result through the mailbox.
    fn on_central_done(
        &mut self,
        run: u32,
        result: Result<(Vec<u16>, f64), String>,
        elapsed: Duration,
    ) {
        if !self.active.contains_key(&run) {
            // the run failed (site death) while its central was in flight;
            // the worker's effort is discarded with the run
            eprintln!("leader: dropping central result for inactive run {run}");
            return;
        }
        match result {
            Ok((labels, sigma)) => {
                let adv = self
                    .active
                    .get_mut(&run)
                    .expect("checked above")
                    .machine
                    .central_done(labels, sigma, elapsed);
                self.drive(run, adv);
            }
            Err(e) => self.fail_run(run, &format!("central step failed: {e}")),
        }
    }

    /// Encode, account to the run, and write one frame to a site link.
    fn send_run_frame(&mut self, run: u32, site: usize, msg: &Message) -> Result<()> {
        let frame = wire::encode(msg);
        if let Some(entry) = self.active.get_mut(&run) {
            entry.stats[site].account(false, frame.len(), &self.cfg.link);
        }
        self.send_site_frame(site, &frame)
    }

    /// The single choke point for outbound site frames: every send gets
    /// the next ordinal, and a *failed* live send is journaled as
    /// [`JournalEvent::SendFail`] before the caller reacts (takes the link
    /// down, fails runs) — so replay, whose puppet driver's sends always
    /// succeed while the link is up, re-fails the send with the matching
    /// ordinal and diverges nowhere. Replay consumes the queued failures
    /// front-to-front; ordinals never repeat within an incarnation, so a
    /// front mismatch just means this send succeeded live.
    fn send_site_frame(&mut self, site: usize, frame: &[u8]) -> Result<()> {
        let seq = self.send_seq;
        self.send_seq += 1;
        if self.replaying {
            if self.replay_fail.front().is_some_and(|f| f.seq == seq) {
                let f = self.replay_fail.pop_front().expect("checked non-empty");
                debug_assert_eq!(
                    f.site, site,
                    "journaled send failure ordinal {seq} names site {} but replay sent to site {site}",
                    f.site
                );
                return Err(anyhow!("{}", f.err));
            }
            return self.driver.send_site(site, frame);
        }
        match self.driver.send_site(site, frame) {
            Ok(()) => Ok(()),
            Err(e) => {
                if self.journal.is_some() {
                    self.append_journal(&JournalEvent::SendFail {
                        seq,
                        site,
                        err: format!("{e:#}"),
                    });
                }
                Err(e)
            }
        }
    }

    /// A site link died: every active run spans it, so they all fail; the
    /// queue survives (links are re-dialed before the next run starts).
    fn site_down(&mut self, site: usize, err: &str) {
        if self.driver.take_down(site) {
            eprintln!("leader: site {site} link down: {err}");
        }
        // Schedule the re-dial *now*, not at the next submit: an idle
        // server has no other reason to call `redial_links`, and the next
        // client should find the star already healed rather than pay the
        // dial latency (see `next_deadline`, which turns this into a
        // wakeup).
        if self.redial_after.is_none() {
            let delay = self.redial_backoff.next_delay();
            self.redial_after = Some(self.driver.now() + delay);
        }
        let mut runs: Vec<u32> = self.active.keys().copied().collect();
        runs.sort_unstable();
        for run in runs {
            self.fail_run(run, &format!("site {site} link failed: {err}"));
        }
        // In-flight label pulls can no longer complete (their SITELABELS
        // frames died with the link): tell the waiting clients, who would
        // otherwise block forever — idle waits never time out by design.
        let pulls = std::mem::take(&mut self.pulls);
        for p in pulls {
            self.reject_pull(
                p.client,
                p.run,
                format!("site {site} link failed during the label pull"),
            );
        }
    }

    // ─── run lifecycle ─────────────────────────────────────────────────

    fn on_submit(&mut self, client: u64, spec: JobSpec, modern: bool) {
        if modern {
            self.modern.insert(client);
        }
        // Admission first: a flooding client is turned away before the
        // leader spends validation or queue space on it.
        let rate = self.cfg.leader.admit_rate;
        if rate > 0.0 {
            let now = self.driver.now();
            let burst = self.cfg.leader.admit_burst.max(1) as f64;
            let bucket = self
                .buckets
                .entry(client)
                .or_insert_with(|| TokenBucket::new(rate, burst, now));
            if let Err(wait) = bucket.try_take(now) {
                self.reject_submit(
                    client,
                    RejectCode::RateLimited,
                    wait.as_nanos() as u64,
                    "rate limited".into(),
                );
                return;
            }
        }
        // Client input is untrusted: refuse specs the pipeline would panic
        // or misbehave on *now*, not after every site has done DML work —
        // and never let one bad job take the reactor (and every other
        // client's runs) down. These rejections refund the admission token
        // charged above: the client spent no server work, and during
        // overload a burned token per refusal would rate-starve a
        // well-behaved tenant on rejections it never caused (only
        // `RateLimited` itself keeps the charge — that *is* the meter).
        if let Err(e) = validate_spec(&spec, self.cfg.backend) {
            if let Some(bucket) = self.buckets.get_mut(&client) {
                bucket.refund();
            }
            self.reject_submit(client, RejectCode::BadSpec, 0, format!("bad job spec: {e:#}"));
            return;
        }
        if self.queue.len() >= self.opts.queue_depth {
            if let Some(bucket) = self.buckets.get_mut(&client) {
                bucket.refund();
            }
            self.reject_submit(
                client,
                RejectCode::QueueFull,
                self.queue.len() as u64,
                format!("queue full ({} jobs pending)", self.queue.len()),
            );
            return;
        }
        let run = self.next_run;
        self.next_run = self.next_run.wrapping_add(1).max(1); // run 0 = "no run"
        if self.modern.contains(&client) {
            // Jobs ahead of this one: everything running, plus the queued
            // jobs the scheduler will serve first — the whole backlog under
            // FIFO, this client's lane-schedule position under DRR. The ETA
            // is honest about having no data: until a first central
            // completes there is no mean to extrapolate, and 0 would read
            // as "runs immediately" at any position.
            let position =
                (self.active.len() + self.queue.position_for(client, spec.priority)) as u32;
            let eta_ns = if self.centrals_done == 0 {
                ETA_UNKNOWN_NS
            } else {
                (self.central_mean_ns * position as f64) as u64
            };
            self.send_client(client, &Message::JobAcceptExt { run, position, eta_ns });
        } else {
            self.send_client(client, &Message::JobAccept { run });
        }
        self.queue.push(Job { run, client, spec });
        self.annotate(JournalEvent::Admitted { run, client });
    }

    /// Refuse a submission in the client's dialect and count it. The
    /// legacy REJECT(17) text is byte-frozen (pre-`fair_queue` parity);
    /// modern clients additionally get the machine-readable reason code
    /// and detail, so nothing needs to parse the sentence.
    fn reject_submit(&mut self, client: u64, code: RejectCode, detail: u64, msg: String) {
        let msg = reject_text(&msg);
        let frame = if self.modern.contains(&client) {
            Message::RejectCoded { run: 0, code, detail, msg }
        } else {
            Message::Reject { run: 0, msg }
        };
        self.send_client(client, &frame);
        self.stats.rejected += 1;
        self.annotate(JournalEvent::Rejected { client });
    }

    /// Revive dead site links now, scheduling the next attempt (capped,
    /// jittered backoff) on failure. Returns whether the star is healthy.
    fn redial_links(&mut self) -> bool {
        if let Err(e) = self.driver.ensure_links() {
            let delay = self.redial_backoff.next_delay();
            eprintln!(
                "leader: sites unreachable ({e:#}); {} queued job(s) wait, retrying \
                 in {delay:?}",
                self.queue.len()
            );
            self.redial_after = Some(self.driver.now() + delay);
            false
        } else {
            self.redial_after = None;
            self.redial_backoff.reset();
            true
        }
    }

    /// Start queued jobs while slots are free. Called after every event.
    /// A failed re-dial does *not* reject the queue: the jobs stay queued
    /// and the next attempt waits out a capped, jittered backoff (the
    /// reactor wakes itself via [`Reactor::next_deadline`]) — one transient
    /// site outage must not destroy every pending job, and back-to-back
    /// dial timeouts must not wedge the reactor.
    fn try_start_jobs(&mut self) {
        // A pending re-dial fires on schedule even when no start is
        // possible (empty queue, full slots): nothing else would wake the
        // star back up on an idle server, and `next_deadline` arms the
        // Tick for exactly this moment.
        if self.redial_after.is_some_and(|t| self.driver.now() >= t)
            && (self.queue.is_empty() || self.active.len() >= self.opts.max_jobs)
        {
            self.redial_links();
        }
        while self.active.len() < self.opts.max_jobs && !self.queue.is_empty() {
            if let Some(not_before) = self.redial_after {
                if self.driver.now() < not_before {
                    return; // still backing off; jobs wait in the queue
                }
            }
            if !self.redial_links() {
                return;
            }
            let job = self.queue.pop().expect("checked non-empty");
            self.annotate(JournalEvent::Started { run: job.run });
            let n_sites = self.driver.n_sites();
            let now = self.driver.now();
            self.active.insert(
                job.run,
                RunEntry {
                    machine: RunMachine::new(n_sites, job.spec, self.cfg.collect_timeout, now),
                    client: job.client,
                    stats: vec![LinkStats::default(); n_sites],
                    started: now,
                },
            );
            // Announce the run on every site link; sites answer with
            // run-scoped registrations and the machine takes it from there.
            for site in 0..n_sites {
                if let Err(e) =
                    self.send_run_frame(job.run, site, &Message::RunStart { run: job.run })
                {
                    self.site_down(site, &format!("{e:#}"));
                    break; // this run just failed; the while loop continues
                }
            }
        }
    }

    fn complete_run(&mut self, run: u32) {
        let Some(entry) = self.active.remove(&run) else { return };
        let outcome = entry.machine.outcome();
        let report = JobReport {
            n_codes: outcome.n_codes as u32,
            sigma: outcome.sigma,
            central_ns: outcome.central.as_nanos() as u64,
            wall_ns: self.driver.now().saturating_duration_since(entry.started).as_nanos()
                as u64,
            per_site: entry.stats.iter().map(|s| s.to_wire()).collect(),
        };
        self.completed.push_back((run, entry.stats.len()));
        while self.completed.len() > COMPLETED_CAP {
            self.completed.pop_front();
        }
        self.stats.completed += 1;
        // Fold this central into the running mean behind JOBACCEPT2's ETA.
        self.centrals_done += 1;
        let central_ns = outcome.central.as_nanos() as f64;
        self.central_mean_ns += (central_ns - self.central_mean_ns) / self.centrals_done as f64;
        self.send_client(entry.client, &Message::JobDone { run, report });
        self.annotate(JournalEvent::Completed { run });
    }

    fn fail_run(&mut self, run: u32, why: &str) {
        let Some(entry) = self.active.remove(&run) else { return };
        eprintln!("leader: run {run} failed: {why}");
        self.stats.failed += 1;
        let msg = reject_text(why);
        let frame = if self.modern.contains(&entry.client) {
            Message::RejectCoded { run, code: RejectCode::RunFailed, detail: 0, msg }
        } else {
            Message::Reject { run, msg }
        };
        self.send_client(entry.client, &frame);
        self.annotate(JournalEvent::Failed { run });
    }

    /// Fail every run whose straggler deadline has passed (the machine
    /// composes the canonical "sites […] never reported" error on an
    /// expired `Tick`). Runs mid-central have no deadline — their sites
    /// owe them nothing until the labels go out.
    fn expire_overdue(&mut self) {
        let now = self.driver.now();
        let mut overdue: Vec<u32> = self
            .active
            .iter()
            .filter(|(_, e)| e.machine.collect_deadline().is_some_and(|d| d <= now))
            .map(|(run, _)| *run)
            .collect();
        overdue.sort_unstable();
        for run in overdue {
            let Some(entry) = self.active.get_mut(&run) else { continue };
            let adv = entry.machine.advance(now, RunInput::Tick);
            self.drive(run, adv);
        }
    }

    // ─── client plane ──────────────────────────────────────────────────

    fn send_client(&mut self, client: u64, msg: &Message) {
        self.send_client_raw(client, &wire::encode(msg));
    }

    fn send_client_raw(&mut self, client: u64, frame: &[u8]) {
        if let Err(e) = self.driver.send_client(client, frame) {
            eprintln!("leader: dropping client {client}: {e:#}");
            self.driver.drop_client(client);
            self.pulls.retain(|p| p.client != client);
        }
    }

    /// Refuse a label pull in the client's dialect (code `PullRefused`).
    fn reject_pull(&mut self, client: u64, run: u32, msg: String) {
        let msg = reject_text(&msg);
        let frame = if self.modern.contains(&client) {
            Message::RejectCoded { run, code: RejectCode::PullRefused, detail: 0, msg }
        } else {
            Message::Reject { run, msg }
        };
        self.send_client(client, &frame);
    }

    fn on_pull(&mut self, client: u64, run: u32) {
        if !self.opts.allow_label_pull {
            self.reject_pull(
                client,
                run,
                "label pull is disabled on this leader \
                 ([leader] allow_label_pull = false)"
                    .into(),
            );
            return;
        }
        let Some(&(_, n_sites)) = self.completed.iter().find(|&&(r, _)| r == run) else {
            self.reject_pull(
                client,
                run,
                format!("run {run} is not a completed run on this leader"),
            );
            return;
        };
        if let Err(e) = self.driver.ensure_links() {
            self.reject_pull(client, run, format!("cannot reach sites for the pull: {e:#}"));
            return;
        }
        let frame = wire::encode(&Message::LabelsPull { run });
        for site in 0..n_sites {
            if let Err(e) = self.send_site_frame(site, &frame) {
                self.site_down(site, &format!("{e:#}"));
                self.reject_pull(client, run, format!("site {site} died during the pull: {e:#}"));
                return;
            }
        }
        self.pulls.push(Pull { run, client, outstanding: n_sites });
    }

    /// A `SITELABELS` frame came back: forward it to the oldest pull of
    /// that run (each pull triggered exactly one frame per site, so
    /// counting completes the bookkeeping).
    fn forward_pull(&mut self, run: u32, frame: &[u8]) {
        let Some(pos) = self.pulls.iter().position(|p| p.run == run) else { return };
        let client = self.pulls[pos].client;
        self.send_client_raw(client, frame);
        // send_client_raw may have retired the client (and its pulls)
        if let Some(pos) = self.pulls.iter().position(|p| p.run == run && p.client == client) {
            self.pulls[pos].outstanding -= 1;
            if self.pulls[pos].outstanding == 0 {
                self.pulls.remove(pos);
            }
        }
    }

    /// A site refused a pull (label cache evicted): the client gets the
    /// refusal and the pull dies.
    fn refuse_pull(&mut self, run: u32, why: &str) {
        let Some(pos) = self.pulls.iter().position(|p| p.run == run) else { return };
        let pull = self.pulls.remove(pos);
        self.reject_pull(pull.client, run, format!("site refused the pull: {why}"));
    }
}

// ─── crash recovery ────────────────────────────────────────────────────────

/// The reactor's transferable state, extracted by [`Reactor::into_parts`]
/// after a journal replay and re-armed with a live driver and worker pool
/// by [`Reactor::from_parts`]. Deliberately excludes the XLA runtime
/// handle (thread-local; re-resolved) and the journal (re-opened by the
/// recovering frontend).
pub(crate) struct ReactorParts {
    cfg: PipelineConfig,
    opts: ServerOpts,
    queue: JobQueue,
    active: HashMap<u32, RunEntry>,
    completed: VecDeque<(u32, usize)>,
    pulls: Vec<Pull>,
    next_run: u32,
    clients_done: u64,
    redial_backoff: Backoff,
    redial_after: Option<Instant>,
    stats: ServerStats,
    modern: HashSet<u64>,
    buckets: HashMap<u64, TokenBucket>,
    central_mean_ns: f64,
    centrals_done: u64,
    send_seq: u64,
}

impl ReactorParts {
    /// Client ids the replayed history has seen — a recovering TCP
    /// frontend numbers fresh connections above every journaled id so a
    /// new client can never collide with a ghost.
    pub(crate) fn max_seen_client(records: &[Record]) -> u64 {
        records
            .iter()
            .map(|r| match &r.event {
                JournalEvent::ClientSubmit { client, .. }
                | JournalEvent::ClientPull { client, .. }
                | JournalEvent::ClientDown { client }
                | JournalEvent::Admitted { client, .. }
                | JournalEvent::Rejected { client } => *client,
                _ => 0,
            })
            .max()
            .unwrap_or(0)
    }
}

/// The replay-time [`ServerDriver`]: a stand-in star whose link
/// generations evolve exactly like the original driver's (up on a fresh
/// dial, +1 per take-down, +1 per revival) while every outbound frame is
/// swallowed — the original already delivered those bytes. Byte
/// accounting still happens above the seam, so replayed `LinkStats`
/// match the live run bit for bit. The clock is puppeteered record by
/// record ([`ReplayDriver::set_now`]), which rebuilds deadlines, bucket
/// levels and backoff windows in the journaled timeline.
pub(crate) struct ReplayDriver {
    gens: Vec<u64>,
    up: Vec<bool>,
    base: Instant,
    now: Instant,
    /// `true` mirrors TCP (`ensure_links` revives dead links, bumping
    /// their generation); `false` mirrors the channel harness (a severed
    /// link errors forever).
    revive: bool,
}

impl ReplayDriver {
    pub(crate) fn new(n_sites: usize, base: Instant, revive: bool) -> ReplayDriver {
        ReplayDriver {
            gens: vec![0; n_sites],
            up: vec![true; n_sites],
            base,
            now: base,
            revive,
        }
    }

    /// Move the replay clock to `t_ns` past the journal epoch.
    fn set_now(&mut self, t_ns: u64) {
        self.now = self.base + Duration::from_nanos(t_ns);
    }

    /// Act out a journaled process restart: every link re-dialed fresh,
    /// one incarnation past whatever the dead session left behind —
    /// mirroring what `serve_jobs` does when it recovers.
    fn restart_links(&mut self) {
        for site in 0..self.gens.len() {
            self.gens[site] += 1;
            self.up[site] = true;
        }
    }
}

impl ServerDriver for ReplayDriver {
    fn n_sites(&self) -> usize {
        self.gens.len()
    }

    fn link_gen(&self, site: usize) -> u64 {
        self.gens[site]
    }

    fn send_site(&mut self, site: usize, _frame: &[u8]) -> Result<()> {
        if self.up[site] {
            Ok(())
        } else {
            Err(anyhow!("site {site} link is down"))
        }
    }

    fn take_down(&mut self, site: usize) -> bool {
        if self.up[site] {
            self.up[site] = false;
            self.gens[site] += 1;
            true
        } else {
            false
        }
    }

    fn ensure_links(&mut self) -> Result<()> {
        for site in 0..self.up.len() {
            if self.up[site] {
                continue;
            }
            if !self.revive {
                bail!("site {site} channel link was severed");
            }
            self.up[site] = true;
            self.gens[site] += 1;
        }
        Ok(())
    }

    fn send_client(&mut self, _client: u64, _frame: &[u8]) -> Result<()> {
        Ok(()) // the original reactor already delivered this frame
    }

    fn drop_client(&mut self, _client: u64) {}

    fn close_clients(&mut self) {}

    fn now(&self) -> Instant {
        self.now
    }
}

impl Reactor<ReplayDriver> {
    /// Rebuild reactor state by re-applying a recovered journal: each
    /// record moves the replay clock to its timestamp, annotations are
    /// skipped (replay re-derives every scheduling decision), and the
    /// rest step the reactor exactly as the original events did. Call
    /// with [`Reactor::set_replaying`] on.
    ///
    /// [`JournalEvent::SendFail`] records are consumed out of band: they
    /// describe sends that failed *while* an earlier record was being
    /// processed, so they are pre-scanned into per-incarnation queues
    /// (ordinals reset at each `Restart`) and re-injected by
    /// [`Reactor::send_site_frame`] when replay reaches the matching
    /// ordinal — the link goes down at the identical point of the history.
    pub(crate) fn replay(&mut self, records: &[Record]) {
        let mut segments: VecDeque<VecDeque<ReplayFail>> = VecDeque::new();
        segments.push_back(VecDeque::new());
        for rec in records {
            match &rec.event {
                JournalEvent::Restart => segments.push_back(VecDeque::new()),
                JournalEvent::SendFail { seq, site, err } => segments
                    .back_mut()
                    .expect("segments starts non-empty")
                    .push_back(ReplayFail { seq: *seq, site: *site, err: err.clone() }),
                _ => {}
            }
        }
        self.send_seq = 0;
        self.replay_fail = segments.pop_front().expect("segments starts non-empty");
        for rec in records {
            self.driver.set_now(rec.t_ns);
            if rec.event.is_annotation() {
                continue;
            }
            if let JournalEvent::SendFail { .. } = rec.event {
                continue; // consumed by ordinal, pre-scanned above
            }
            if let JournalEvent::Restart = rec.event {
                // The leader process died and came back at this point in
                // the history: re-enact the recovery itself so the records
                // that follow land on the same link generations and fresh
                // machines the restarted leader had. The next incarnation's
                // failure queue must be armed *before* the restart resends
                // anything, and its ordinals start over.
                debug_assert!(
                    self.replay_fail.is_empty(),
                    "journaled send failures left unconsumed at a restart boundary"
                );
                self.send_seq = 0;
                self.replay_fail =
                    segments.pop_front().expect("one segment per Restart record");
                self.driver.restart_links();
                self.restart_active_runs();
                continue;
            }
            let event = match rec.event.clone() {
                JournalEvent::ClientSubmit { client, spec, modern } => {
                    Event::ClientSubmit { client, spec: Box::new(spec), modern }
                }
                JournalEvent::ClientPull { client, run } => Event::ClientPull { client, run },
                JournalEvent::ClientDown { client } => Event::ClientDown { client },
                JournalEvent::SiteFrame { site, gen, frame } => {
                    Event::SiteFrame { site, gen, frame }
                }
                JournalEvent::SiteDown { site, gen, err } => {
                    Event::SiteDown { site, gen, err }
                }
                JournalEvent::CentralDone { run, result, elapsed_ns } => Event::CentralDone {
                    run,
                    result,
                    elapsed: Duration::from_nanos(elapsed_ns),
                },
                JournalEvent::Tick => Event::Tick,
                other => unreachable!("handled above: {other:?}"),
            };
            self.step(event);
        }
        debug_assert!(
            self.replay_fail.is_empty() && segments.is_empty(),
            "journaled send failures left unconsumed at the end of replay"
        );
    }

    /// The replayed link generations, for the harness's resume-time
    /// consistency check against the surviving channel driver.
    pub(crate) fn replay_gens(&self) -> Vec<u64> {
        self.driver.gens.clone()
    }
}

// ─── shared helpers ────────────────────────────────────────────────────────

/// Wrap a machine output run-scoped (the classic driver wraps the same
/// outputs unscoped — see `coordinator::classic_out`).
fn scoped_out(run: u32, site: usize, out: OutMsg) -> Message {
    match out {
        OutMsg::Dml(o) => Message::RunDmlRequest {
            run,
            site: site as u32,
            dml: o.dml,
            target_codes: o.target_codes,
            max_iters: o.max_iters,
            tol: o.tol,
            seed: o.seed,
        },
        OutMsg::Labels(labels) => Message::RunLabels { run, site: site as u32, labels },
    }
}

/// Submit-time spec validation: everything a hostile or buggy client could
/// set that the pipeline would only reject (or panic on) deep inside a
/// run. The central step's spectral code asserts `k ≥ 1`, and the graph /
/// backend combination is a property of this serving deployment.
fn validate_spec(spec: &JobSpec, backend: crate::config::Backend) -> Result<()> {
    if spec.k_clusters == 0 {
        bail!("k_clusters must be ≥ 1");
    }
    if spec.total_codes == 0 {
        bail!("total_codes must be ≥ 1");
    }
    if let crate::spectral::GraphKind::Knn { k } = spec.graph {
        if k == 0 {
            bail!("knn_k must be ≥ 1");
        }
    }
    // The wire decoder bounds SUBMITPRI priorities already; this guards
    // specs that reach the reactor through an in-process path.
    if spec.priority < 1 || spec.priority > JobSpec::MAX_PRIORITY {
        bail!("priority must be in 1..={}", JobSpec::MAX_PRIORITY);
    }
    check_graph_backend_kinds(spec.graph, backend)
}

/// Keep reject messages a short sentence (the wire caps them anyway).
fn reject_text(s: &str) -> String {
    if s.len() <= 1000 {
        s.to_string()
    } else {
        s.chars().take(1000).collect()
    }
}

/// Map one decoded client frame to its mailbox event — the single
/// client-dialect definition both frontends share (the TCP reader thread
/// and the channel harness's in-process client link). `Err` means the
/// client broke protocol and must be dropped.
pub(crate) fn client_frame_to_event(client: u64, frame: &[u8]) -> Result<Event> {
    match wire::decode(frame)? {
        Message::Submit(spec) => {
            Ok(Event::ClientSubmit { client, spec: Box::new(spec), modern: false })
        }
        Message::SubmitPri(spec) => {
            Ok(Event::ClientSubmit { client, spec: Box::new(spec), modern: true })
        }
        Message::LabelsPull { run } => Ok(Event::ClientPull { client, run }),
        other => bail!("client sent unexpected {other:?}"),
    }
}

// ─── journal replication (warm standby) ────────────────────────────────────

/// What feeds the replication sender thread ([`spawn_replicator`]): the
/// reactor after each group commit, and the acceptor when a role-4 peer
/// handshakes on the job socket.
pub(crate) enum ReplEvent {
    /// One journal record became durable: `(record index, framed bytes)`.
    /// Indices at or below what catch-up already streamed are skipped.
    Record(u64, Vec<u8>),
    /// A standby completed the role-4 handshake and wants the journal.
    Standby(TcpStream),
}

/// The primary's replication sender: owns the (single, fenced) standby
/// link off the reactor thread, so a slow or dead standby can never stall
/// serving. Durable records arrive via `rx` and are streamed as
/// `JREPLRECORD`; an idle link gets a `JREPLHEARTBEAT` every `heartbeat`
/// (a quarter of `[leader] standby_timeout`, so the standby's idle
/// deadline only fires when the primary is truly gone); a send failure
/// drops the standby link and nothing else. A newly connected standby is
/// caught up from the journal file and *replaces* any previous one —
/// newest wins, the fenced single-standby design (`docs/DEPLOY.md`).
fn spawn_replicator(path: PathBuf, heartbeat: Duration, rx: Receiver<ReplEvent>) {
    thread::spawn(move || {
        // the live standby link and the highest record index shipped on it
        let mut standby: Option<(TcpStream, u64)> = None;
        loop {
            match rx.recv_timeout(heartbeat) {
                Ok(ReplEvent::Record(index, framed)) => {
                    let Some((stream, shipped)) = standby.as_mut() else { continue };
                    if index <= *shipped {
                        continue; // catch-up already streamed it from the file
                    }
                    let frame = wire::encode(&Message::JreplRecord { framed });
                    if let Err(e) = tcp::send_frame(stream, &frame) {
                        eprintln!("leader: standby link lost ({e:#}); replication paused");
                        standby = None;
                    } else {
                        *shipped = index;
                    }
                }
                Ok(ReplEvent::Standby(stream)) => match catch_up_standby(&path, stream) {
                    Ok(caught_up) => {
                        if standby.is_some() {
                            eprintln!(
                                "leader: a new standby connected; fencing out the old \
                                 one (single-standby replication, newest wins)"
                            );
                        }
                        eprintln!(
                            "leader: standby attached, {} journal record(s) replicated",
                            caught_up.1
                        );
                        standby = Some(caught_up);
                    }
                    Err(e) => eprintln!("leader: standby catch-up failed: {e:#}"),
                },
                Err(RecvTimeoutError::Timeout) => {
                    let Some((stream, _)) = standby.as_mut() else { continue };
                    if let Err(e) =
                        tcp::send_frame(stream, &wire::encode(&Message::JreplHeartbeat))
                    {
                        eprintln!("leader: standby link lost ({e:#}); replication paused");
                        standby = None;
                    }
                }
                Err(RecvTimeoutError::Disconnected) => return, // server is done
            }
        }
    });
}

/// Anti-entropy on standby connect: read its `JREPLHELLO` claim
/// `(records, valid_bytes)`, and if that claim is a byte prefix of this
/// journal — the record count fits and the framed sizes up to it sum to
/// exactly its valid length — resume streaming at the suffix
/// (`JREPLSTART{records}`); otherwise restart it from record 0 and stream
/// everything. A standby journal is only ever a verbatim prefix of its
/// primary's lineage by construction (it is written solely by this
/// stream), so the size check is the cheap honest test; re-pointing a
/// standby at an unrelated cluster calls for clearing its journal first
/// (`docs/DEPLOY.md`). Returns the stream and the records it now holds.
fn catch_up_standby(path: &Path, stream: TcpStream) -> Result<(TcpStream, u64)> {
    let hello = match tcp::recv_frame(&stream)? {
        Some(frame) => wire::decode(&frame)?,
        None => bail!("standby closed before its JREPLHELLO"),
    };
    let Message::JreplHello { records, valid_bytes } = hello else {
        bail!("standby opened the replication link with {hello:?} (expected JREPLHELLO)");
    };
    let (frames, _) = journal::framed_records(path)
        .with_context(|| format!("read journal {} for standby catch-up", path.display()))?;
    let prefix_ok = records <= frames.len() as u64 && {
        let bytes: u64 = journal::MAGIC.len() as u64
            + frames[..records as usize].iter().map(|f| f.len() as u64).sum::<u64>();
        bytes == valid_bytes
    };
    let start = if prefix_ok { records } else { 0 };
    tcp::send_frame(&stream, &wire::encode(&Message::JreplStart { from_record: start }))?;
    for framed in &frames[start as usize..] {
        let frame = wire::encode(&Message::JreplRecord { framed: framed.clone() });
        tcp::send_frame(&stream, &frame)?;
    }
    Ok((stream, frames.len() as u64))
}

/// `dsc leader --standby`: follow the primary's journal over JREPL
/// replication until the primary dies, then return — the caller promotes
/// by serving from the replicated journal, which is exactly the
/// crash-restart recovery [`serve_jobs`] already performs. Blocks for the
/// whole standby lifetime; a primary that cannot be reached (yet) is
/// re-dialed forever on a capped backoff. Returns the number of records
/// the local journal holds at promotion.
pub fn replicate_standby(cfg: &PipelineConfig) -> Result<u64> {
    let primary = cfg.leader.standby_of.clone().ok_or_else(|| {
        anyhow!("standby mode needs [leader] standby_of (the primary's job address)")
    })?;
    let path = cfg.leader.journal_path.clone().ok_or_else(|| {
        anyhow!("standby mode needs [leader] journal_path (the journal being replicated)")
    })?;
    let timeouts = cfg.net.tcp_timeouts();
    let idle = cfg.leader.standby_timeout;
    let mut backoff = Backoff::new(cfg.seed ^ 0x57B7);
    loop {
        match follow_primary_once(&primary, &path, cfg.leader.journal_fsync, &timeouts, idle)
        {
            Ok(records) => {
                eprintln!(
                    "standby: primary {primary} is gone; promoting with {records} \
                     journaled record(s)"
                );
                return Ok(records);
            }
            Err(e) => {
                let delay = backoff.next_delay();
                eprintln!("standby: {e:#}; retrying in {delay:?}");
                thread::sleep(delay);
            }
        }
    }
}

/// One replication session against the primary. `Ok(records)` means the
/// session *established* (JREPLSTART received) and the link then died —
/// idle past `[leader] standby_timeout` with the primary heartbeating at
/// a quarter of it, an EOF, or a read error all mean the primary is gone
/// and the standby's job is to promote, not to re-dial a ghost. `Err`
/// means the session never established (connect refused, handshake
/// failure): keep dialing.
fn follow_primary_once(
    primary: &str,
    path: &Path,
    fsync: bool,
    timeouts: &TcpTimeouts,
    idle: Duration,
) -> Result<u64> {
    // Local tail first: `open` truncates any torn tail, so after a sync
    // the (records, valid_bytes) claim is exactly what is on disk.
    let (mut journal, records) = Journal::open(path, fsync)?;
    journal.sync().with_context(|| format!("sync journal {}", path.display()))?;
    let valid_bytes = std::fs::metadata(path)
        .with_context(|| format!("stat journal {}", path.display()))?
        .len();
    let stream = tcp::connect_standby(primary, timeouts, Some(idle))?;
    let hello = Message::JreplHello { records: records.len() as u64, valid_bytes };
    tcp::send_frame(&stream, &wire::encode(&hello)).context("send JREPLHELLO")?;
    let start = match tcp::recv_frame(&stream).context("await JREPLSTART")? {
        Some(frame) => match wire::decode(&frame)? {
            Message::JreplStart { from_record } => from_record,
            other => bail!("primary answered JREPLHELLO with {other:?}"),
        },
        None => bail!(
            "primary closed the link before JREPLSTART — is replication enabled \
             there ([leader] journal_path)?"
        ),
    };
    let mut held = records.len() as u64;
    if start != held {
        if start != 0 {
            bail!(
                "primary wants to resume replication at record {start}, but this \
                 standby holds {held}"
            );
        }
        // Anti-entropy said this file is not a prefix of the primary's
        // history: reset it and take the full stream.
        eprintln!(
            "standby: journal {} diverged from the primary ({held} local record(s)); \
             resetting and taking the full stream",
            path.display()
        );
        drop(journal);
        std::fs::remove_file(path)
            .with_context(|| format!("reset journal {}", path.display()))?;
        let (fresh, recovered) = Journal::open(path, fsync)?;
        debug_assert!(recovered.is_empty());
        journal = fresh;
        held = 0;
    }
    eprintln!("standby: following {primary} from record {held}");
    loop {
        let frame = match tcp::recv_frame(&stream) {
            Ok(Some(frame)) => frame,
            Ok(None) => {
                eprintln!("standby: primary closed the replication link");
                break;
            }
            Err(e) => {
                eprintln!("standby: replication link died: {e:#}");
                break;
            }
        };
        match wire::decode(&frame).context("decode replication frame")? {
            Message::JreplRecord { framed } => {
                let (_, count) = journal
                    .append_framed(&framed)
                    .with_context(|| format!("apply replicated record {}", held + 1))?;
                // Per-record durability: the whole point of standing by is
                // surviving the primary's death at any instant.
                journal.sync()?;
                held = count;
            }
            Message::JreplHeartbeat => {} // the read itself reset the idle clock
            other => bail!("primary sent {other:?} on the replication link"),
        }
    }
    journal.sync()?;
    Ok(held)
}

// ─── TCP frontend ──────────────────────────────────────────────────────────

struct SiteLink {
    addr: String,
    /// Driver-owned write half; `None` while the link is down.
    stream: Option<TcpStream>,
    /// Incarnation counter for stale-event filtering.
    gen: u64,
}

/// The socket-backed [`ServerDriver`]: one persistent session per site
/// (reader threads feeding the mailbox), the client map shared with the
/// acceptor thread, re-dial on demand, real time.
struct TcpDriver {
    timeouts: TcpTimeouts,
    /// Kept so the mailbox can never disconnect and to arm new readers.
    tx: Sender<Event>,
    links: Vec<SiteLink>,
    /// Client write halves, by client id — shared with the acceptor
    /// thread, which registers each handshaken connection before spawning
    /// its reader. `Arc` so a send can clone the handle out and release
    /// the lock *before* the (possibly blocking) socket write — a slow
    /// client must not stall the acceptor on this mutex.
    clients: Arc<Mutex<HashMap<u64, Arc<TcpStream>>>>,
}

impl ServerDriver for TcpDriver {
    fn n_sites(&self) -> usize {
        self.links.len()
    }

    fn link_gen(&self, site: usize) -> u64 {
        self.links[site].gen
    }

    fn send_site(&mut self, site: usize, frame: &[u8]) -> Result<()> {
        let stream = self.links[site]
            .stream
            .as_ref()
            .ok_or_else(|| anyhow!("site {site} link is down"))?;
        tcp::send_frame(stream, frame).with_context(|| format!("send to site {site}"))
    }

    fn take_down(&mut self, site: usize) -> bool {
        match self.links[site].stream.take() {
            Some(stream) => {
                let _ = stream.shutdown(Shutdown::Both); // wake its reader thread
                self.links[site].gen += 1;
                true
            }
            None => false,
        }
    }

    fn ensure_links(&mut self) -> Result<()> {
        for site in 0..self.links.len() {
            if self.links[site].stream.is_some() {
                continue;
            }
            let stream =
                tcp::connect_site(&self.links[site].addr, site as u32, &self.timeouts, true)
                    .with_context(|| format!("re-dial site {site}"))?;
            let rd = stream.try_clone().context("clone site socket for reading")?;
            self.links[site].gen += 1;
            self.links[site].stream = Some(stream);
            spawn_site_reader(rd, site, self.links[site].gen, self.tx.clone());
        }
        Ok(())
    }

    fn send_client(&mut self, client: u64, frame: &[u8]) -> Result<()> {
        // Lock only for the lookup; the write happens on a cloned handle.
        let stream = {
            let clients = self.clients.lock().unwrap();
            match clients.get(&client) {
                Some(stream) => Arc::clone(stream),
                None => return Ok(()), // client hung up; results dropped
            }
        };
        tcp::send_frame(&stream, frame)
    }

    fn drop_client(&mut self, client: u64) {
        self.clients.lock().unwrap().remove(&client);
    }

    fn close_clients(&mut self) {
        self.clients.lock().unwrap().clear();
    }

    fn now(&self) -> Instant {
        Instant::now()
    }
}

/// Serve jobs until `opts.client_limit` client connections have come and
/// gone (forever when `None`). `client_listener` is the already-bound job
/// socket — the caller binds it so it can print the chosen address before
/// the server blocks (`dsc leader --serve host:0`). Site links are dialed
/// from `cfg.net.sites` as persistent multi-run sessions before any job
/// is accepted.
pub fn serve_jobs(
    cfg: &PipelineConfig,
    opts: &ServerOpts,
    client_listener: TcpListener,
) -> Result<ServerStats> {
    if cfg.net.sites.is_empty() {
        bail!("no site addresses configured (set [net] sites or --sites)");
    }
    let timeouts = cfg.net.tcp_timeouts();
    let (tx, rx) = mpsc::channel::<Event>();

    // Crash recovery happens *before* anything is dialed: open the journal
    // (`[leader] journal_path` / `--journal`), and if it holds history,
    // replay it against a pure stand-in driver to rebuild the queue, the
    // incomplete runs and every counter. Interior corruption fails here,
    // loudly — the operator decides, the server never guesses.
    let mut journal = None;
    let mut recovered: Option<(ReactorParts, u64)> = None;
    let mut first_client = 1u64;
    let mut link_gens = vec![0u64; cfg.net.sites.len()];
    if let Some(path) = &cfg.leader.journal_path {
        let (j, records) = Journal::open(path, cfg.leader.journal_fsync)?;
        if !records.is_empty() {
            eprintln!(
                "leader: replaying {} journaled record(s) from {}",
                records.len(),
                path.display()
            );
            let pool_active = cfg.backend == Backend::Native && opts.central_workers > 0;
            let mut replayer = Reactor::new(
                cfg.clone(),
                opts.clone(),
                ReplayDriver::new(cfg.net.sites.len(), Instant::now(), true),
                CentralPool::replay_stub(pool_active),
            )?;
            replayer.set_replaying(true);
            replayer.replay(&records);
            // Fresh connections must never collide with journaled ids: new
            // clients number above history, new link incarnations sit one
            // generation past the replayed ones (the Restart record makes
            // a future replay bump the same way).
            first_client = ReactorParts::max_seen_client(&records) + 1;
            link_gens = replayer.replay_gens().iter().map(|g| g + 1).collect();
            let last_t_ns = records.last().map(|r| r.t_ns).unwrap_or(0);
            let (parts, _replay_driver, _stub) = replayer.into_parts();
            recovered = Some((parts, last_t_ns));
        }
        journal = Some(j);
    }

    // Replication plane: with a journal configured, a sender thread owns
    // the (single, fenced) standby link — the acceptor hands role-4
    // connections over, the reactor hands framed records over after each
    // group commit, and the thread heartbeats the link when idle so the
    // standby's promotion deadline only fires on a truly dead primary.
    let repl_tx = cfg.leader.journal_path.as_ref().map(|path| {
        let (rtx, rrx) = mpsc::channel::<ReplEvent>();
        spawn_replicator(path.clone(), cfg.leader.standby_timeout / 4, rrx);
        rtx
    });

    // Dial every site concurrently in the session dialect, then hand each
    // connection's read half to a reader thread.
    let conns = tcp::dial_sites(&cfg.net.sites, &timeouts, true)?;
    let mut links = Vec::with_capacity(conns.len());
    for (site, stream) in conns.into_iter().enumerate() {
        let rd = stream.try_clone().context("clone site socket for reading")?;
        spawn_site_reader(rd, site, link_gens[site], tx.clone());
        links.push(SiteLink {
            addr: cfg.net.sites[site].clone(),
            stream: Some(stream),
            gen: link_gens[site],
        });
    }

    let clients = Arc::new(Mutex::new(HashMap::new()));
    spawn_acceptor(
        client_listener,
        timeouts,
        cfg.seed,
        first_client,
        tx.clone(),
        Arc::clone(&clients),
        repl_tx.clone(),
    );

    let driver = TcpDriver { timeouts, tx: tx.clone(), links, clients };
    // Centrals go to the pool only on the native backend — the XLA runtime
    // is thread-local, so those deployments keep the inline path.
    let workers =
        if cfg.backend == Backend::Native { opts.central_workers } else { 0 };
    let pool = CentralPool::start(workers, tx.clone(), None);
    let mut reactor = match recovered {
        Some((parts, last_t_ns)) => {
            let mut reactor = Reactor::from_parts(parts, driver, pool)?;
            if let Some(j) = journal.take() {
                reactor.attach_journal_resumed(j, last_t_ns);
            }
            // Replication must be armed before the first append below: a
            // record appended unarmed is never staged for the standby, and
            // one caught up from the file just beforehand would be left
            // with a permanent gap.
            if let Some(rtx) = repl_tx {
                reactor.attach_repl(rtx);
            }
            // Mark the restart durably, then act it out: the old process's
            // in-flight runs restart from scratch on the fresh links (their
            // old sites, workers and clients died with it); completed runs
            // keep serving label pulls.
            reactor.journal_restart();
            reactor.restart_active_runs();
            reactor
        }
        None => {
            let mut reactor = Reactor::new(cfg.clone(), opts.clone(), driver, pool)?;
            if let Some(j) = journal.take() {
                reactor.attach_journal(j);
            }
            if let Some(rtx) = repl_tx {
                reactor.attach_repl(rtx);
            }
            reactor
        }
    };

    loop {
        if reactor.done() {
            return Ok(reactor.finish());
        }
        // Group commit: everything journaled since the last wait becomes
        // durable in one flush, right before the reactor blocks.
        reactor.sync_journal();
        let event = match reactor.next_deadline() {
            None => rx.recv().map_err(|_| anyhow!("reactor mailbox closed"))?,
            Some(deadline) => {
                let wait = deadline.saturating_duration_since(Instant::now());
                match rx.recv_timeout(wait) {
                    Ok(ev) => ev,
                    Err(RecvTimeoutError::Timeout) => Event::Tick,
                    Err(RecvTimeoutError::Disconnected) => {
                        bail!("reactor mailbox closed")
                    }
                }
            }
        };
        reactor.step(event);
    }
}

/// Reader thread for one site-link incarnation: frames (and death) become
/// mailbox events tagged with the link generation.
fn spawn_site_reader(stream: TcpStream, site: usize, gen: u64, tx: Sender<Event>) {
    thread::spawn(move || loop {
        match tcp::recv_frame(&stream) {
            Ok(Some(frame)) => {
                if tx.send(Event::SiteFrame { site, gen, frame }).is_err() {
                    return; // server gone
                }
            }
            Ok(None) => {
                let _ = tx.send(Event::SiteDown {
                    site,
                    gen,
                    err: "site closed the connection".into(),
                });
                return;
            }
            Err(e) => {
                let _ = tx.send(Event::SiteDown { site, gen, err: format!("{e:#}") });
                return;
            }
        }
    });
}

/// Accept thread for the job socket: handshakes, registers a client's
/// write half with the driver's client map and spawns its reader — or
/// hands a role-4 standby to the replication sender. Handshake failures
/// (port scans, version skew) are logged and never take the server down;
/// persistent accept errors back off like the site daemon.
fn spawn_acceptor(
    listener: TcpListener,
    timeouts: TcpTimeouts,
    seed: u64,
    first_client: u64,
    tx: Sender<Event>,
    clients: Arc<Mutex<HashMap<u64, Arc<TcpStream>>>>,
    repl: Option<Sender<ReplEvent>>,
) {
    thread::spawn(move || {
        let mut next_client = first_client;
        let mut backoff = Backoff::new(seed ^ 0x5EE1);
        loop {
            match tcp::accept_job_peer(&listener, &timeouts) {
                Ok(tcp::JobPeer::Client(stream)) => {
                    backoff.reset();
                    let client = next_client;
                    next_client += 1;
                    let rd = match stream.try_clone() {
                        Ok(s) => s,
                        Err(e) => {
                            eprintln!("leader: clone client socket: {e}");
                            continue;
                        }
                    };
                    clients.lock().unwrap().insert(client, Arc::new(stream));
                    spawn_client_reader(rd, client, tx.clone());
                }
                Ok(tcp::JobPeer::Standby(stream)) => {
                    backoff.reset();
                    match &repl {
                        Some(rtx) => {
                            if rtx.send(ReplEvent::Standby(stream)).is_err() {
                                eprintln!(
                                    "leader: replication sender is gone; dropping standby"
                                );
                            }
                        }
                        // dropping the stream EOFs the standby, which keeps
                        // re-dialing and logging — the misconfiguration is
                        // visible on both ends
                        None => eprintln!(
                            "leader: refusing a standby — no journal configured, \
                             nothing to replicate (set [leader] journal_path)"
                        ),
                    }
                }
                Err(e) => {
                    eprintln!("leader: client accept failed: {e:#}");
                    thread::sleep(backoff.next_delay());
                }
            }
        }
    });
}

/// Reader thread for one client connection: decodes frames into typed
/// events; anything unexpected (or the connection ending) retires the
/// client.
fn spawn_client_reader(stream: TcpStream, client: u64, tx: Sender<Event>) {
    thread::spawn(move || {
        loop {
            let frame = match tcp::recv_frame(&stream) {
                Ok(Some(frame)) => frame,
                Ok(None) | Err(_) => break,
            };
            let event = match client_frame_to_event(client, &frame) {
                Ok(event) => event,
                Err(e) => {
                    eprintln!("leader: dropping client {client}: {e:#}");
                    break;
                }
            };
            if tx.send(event).is_err() {
                return; // server gone: no one left to tell
            }
        }
        let _ = tx.send(Event::ClientDown { client });
    });
}

// ─── client side ───────────────────────────────────────────────────────────

/// A frame link from a job client to a serving leader: the transport
/// under [`JobClient`]. TCP ([`TcpClient`]) for `dsc submit`; the channel
/// harness provides an in-process implementation, so the same typed
/// client drives both backends.
pub trait ClientLink {
    /// Deliver one encoded frame to the leader.
    fn send(&self, frame: &[u8]) -> Result<()>;
    /// Next frame from the leader; `Ok(None)` means the leader closed.
    /// Idle waiting is legal for however long a job takes.
    fn recv(&self) -> Result<Option<Vec<u8>>>;
}

impl ClientLink for TcpClient {
    fn send(&self, frame: &[u8]) -> Result<()> {
        TcpClient::send(self, frame)
    }
    fn recv(&self) -> Result<Option<Vec<u8>>> {
        TcpClient::recv(self)
    }
}

/// JOBACCEPT2's `eta_ns` before the leader has completed a single central:
/// there is no duration mean to extrapolate yet, and `0` would be
/// indistinguishable from "starts immediately". Clients print "unknown"
/// (or similar) for this value instead of a time.
pub const ETA_UNKNOWN_NS: u64 = u64::MAX;

/// What a modern-dialect accept (JOBACCEPT2) carries — returned by
/// [`JobClient::submit_tracked`].
#[derive(Clone, Copy, Debug)]
pub struct Accepted {
    /// Assigned run id.
    pub run: u32,
    /// Jobs ahead of this one when the leader accepted it: everything
    /// running plus the queued jobs the scheduler will serve first (the
    /// whole backlog under FIFO; this client's DRR lane-schedule position
    /// under `[leader] fair_queue`).
    pub position: u32,
    /// Estimated nanoseconds until this job starts, from the leader's
    /// running mean of central durations; [`ETA_UNKNOWN_NS`] (`u64::MAX`)
    /// until a first run completes — an honest "no data yet", not a
    /// promise of immediacy.
    pub eta_ns: u64,
}

/// How the leader answered one tracked submit — see
/// [`JobClient::try_submit_tracked`].
#[derive(Clone, Debug)]
pub enum SubmitOutcome {
    /// The job is queued (or started); the accept carries position + ETA.
    Accepted(Accepted),
    /// Refused, with the typed REJECT2 code: `BadSpec`, `QueueFull`, or
    /// `RateLimited` (where `detail` is nanoseconds until the next token).
    Rejected { code: RejectCode, detail: u64, msg: String },
}

/// A client of a job-serving leader (`dsc submit`, tests, drills): typed
/// submit / await / pull over one [`ClientLink`]. Out-of-order frames (a
/// `JOBDONE` for an earlier job arriving while waiting for a `JOBACCEPT`)
/// are buffered, so one connection can carry several jobs.
pub struct JobClient<L: ClientLink = TcpClient> {
    conn: L,
    pending: std::cell::RefCell<VecDeque<Message>>,
}

impl JobClient<TcpClient> {
    /// Dial a leader's `--serve` address.
    pub fn connect(addr: &str, timeouts: &TcpTimeouts) -> Result<JobClient> {
        Ok(JobClient::over(tcp::connect_client(addr, timeouts)?))
    }
}

impl<L: ClientLink> JobClient<L> {
    /// Wrap an established link (the channel harness calls this; TCP goes
    /// through [`JobClient::connect`]).
    pub fn over(conn: L) -> JobClient<L> {
        JobClient { conn, pending: std::cell::RefCell::new(VecDeque::new()) }
    }

    /// Submit a job; returns the assigned run id. Specs with the default
    /// priority go out as legacy SUBMIT(14) — byte-identical to the
    /// pre-`fair_queue` client — and any other priority upgrades the frame
    /// to SUBMITPRI(18) (use [`JobClient::submit_tracked`] to see the
    /// queue position and ETA that come back in the modern dialect).
    pub fn submit(&self, spec: &JobSpec) -> Result<u32> {
        let msg = if spec.priority == JobSpec::DEFAULT_PRIORITY {
            Message::Submit(spec.clone())
        } else {
            Message::SubmitPri(spec.clone())
        };
        self.conn.send(&wire::encode(&msg))?;
        match self.next_accept()? {
            Message::JobAccept { run } | Message::JobAcceptExt { run, .. } => Ok(run),
            Message::Reject { msg, .. } | Message::RejectCoded { msg, .. } => {
                bail!("leader rejected the job: {msg}")
            }
            _ => unreachable!("filtered above"),
        }
    }

    /// Submit in the modern dialect (SUBMITPRI) regardless of priority and
    /// return the full accept: run id, queue position, and the leader's
    /// ETA estimate.
    pub fn submit_tracked(&self, spec: &JobSpec) -> Result<Accepted> {
        self.conn.send(&wire::encode(&Message::SubmitPri(spec.clone())))?;
        match self.next_accept()? {
            Message::JobAcceptExt { run, position, eta_ns } => {
                Ok(Accepted { run, position, eta_ns })
            }
            Message::JobAccept { run } => Ok(Accepted { run, position: 0, eta_ns: 0 }),
            Message::Reject { msg, .. } | Message::RejectCoded { msg, .. } => {
                bail!("leader rejected the job: {msg}")
            }
            _ => unreachable!("filtered above"),
        }
    }

    /// Like [`JobClient::submit_tracked`], but a refused submit is data,
    /// not an error: the typed REJECT2 code and detail come back in
    /// [`SubmitOutcome::Rejected`] (e.g. `RateLimited` with `detail` =
    /// nanoseconds until the client's next admission token). Transport
    /// failures are still `Err`. Load generators and admission drills use
    /// this to keep flooding past refusals without tearing the link down.
    pub fn try_submit_tracked(&self, spec: &JobSpec) -> Result<SubmitOutcome> {
        self.conn.send(&wire::encode(&Message::SubmitPri(spec.clone())))?;
        match self.next_accept()? {
            Message::JobAcceptExt { run, position, eta_ns } => {
                Ok(SubmitOutcome::Accepted(Accepted { run, position, eta_ns }))
            }
            Message::JobAccept { run } => {
                Ok(SubmitOutcome::Accepted(Accepted { run, position: 0, eta_ns: 0 }))
            }
            Message::RejectCoded { code, detail, msg, .. } => {
                Ok(SubmitOutcome::Rejected { code, detail, msg })
            }
            Message::Reject { msg, .. } => {
                // A modern submit always gets a coded reply; a legacy
                // REJECT here means the peer predates REJECT2.
                Ok(SubmitOutcome::Rejected { code: RejectCode::BadSpec, detail: 0, msg })
            }
            _ => unreachable!("filtered above"),
        }
    }

    /// Next accept-or-refusal frame for a just-sent submit, either dialect.
    fn next_accept(&self) -> Result<Message> {
        self.next_where(|m| {
            matches!(
                m,
                Message::JobAccept { .. }
                    | Message::JobAcceptExt { .. }
                    | Message::Reject { run: 0, .. }
                    | Message::RejectCoded { run: 0, .. }
            )
        })
    }

    /// Block until the run finishes; a failed run is an `Err` carrying the
    /// leader's reason. Idle waiting is legal for however long the job
    /// takes — the transport never times out between frames.
    pub fn await_done(&self, run: u32) -> Result<JobReport> {
        match self.next_where(|m| {
            matches!(
                m,
                Message::JobDone { run: r, .. }
                    | Message::Reject { run: r, .. }
                    | Message::RejectCoded { run: r, .. } if *r == run
            )
        })? {
            Message::JobDone { report, .. } => Ok(report),
            Message::Reject { msg, .. } | Message::RejectCoded { msg, .. } => {
                bail!("run {run} failed: {msg}")
            }
            _ => unreachable!("filtered above"),
        }
    }

    /// Pull the populated labels of a completed run through the leader:
    /// one `(site, labels)` per site, site order. `n_sites` comes from the
    /// run's [`JobReport::per_site`] length.
    pub fn pull_labels(&self, run: u32, n_sites: usize) -> Result<Vec<(usize, Vec<u16>)>> {
        self.conn.send(&wire::encode(&Message::LabelsPull { run }))?;
        let mut out: Vec<(usize, Vec<u16>)> = Vec::with_capacity(n_sites);
        while out.len() < n_sites {
            match self.next_where(|m| {
                matches!(
                    m,
                    Message::SiteLabels { run: r, .. }
                        | Message::Reject { run: r, .. }
                        | Message::RejectCoded { run: r, .. } if *r == run
                )
            })? {
                Message::SiteLabels { site, labels, .. } => out.push((site as usize, labels)),
                Message::Reject { msg, .. } | Message::RejectCoded { msg, .. } => {
                    bail!("label pull for run {run} refused: {msg}")
                }
                _ => unreachable!("filtered above"),
            }
        }
        out.sort_by_key(|&(site, _)| site);
        Ok(out)
    }

    /// Next frame matching `want`, buffering everything else.
    fn next_where(&self, want: impl Fn(&Message) -> bool) -> Result<Message> {
        let mut pending = self.pending.borrow_mut();
        if let Some(pos) = pending.iter().position(|m| want(m)) {
            return Ok(pending.remove(pos).expect("position exists"));
        }
        loop {
            let msg = match self.conn.recv()? {
                Some(frame) => wire::decode(&frame)?,
                None => bail!("leader closed the connection"),
            };
            if want(&msg) {
                return Ok(msg);
            }
            pending.push_back(msg);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drr_single_client_is_fifo() {
        let mut q = DrrQueue::new();
        for i in 0..5 {
            q.push(1, 3, i);
        }
        assert_eq!(q.len(), 5);
        for i in 0..5 {
            assert_eq!(q.pop(), Some(i));
        }
        assert_eq!(q.pop(), None);
        assert!(q.is_empty());
    }

    #[test]
    fn drr_equal_weights_round_robin() {
        let mut q = DrrQueue::new();
        // client 1 floods before client 2 shows up at all
        for i in 0..4 {
            q.push(1, 1, (1u64, i));
        }
        for i in 0..4 {
            q.push(2, 1, (2u64, i));
        }
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|(c, _)| c).collect();
        assert_eq!(order, vec![1, 2, 1, 2, 1, 2, 1, 2]);
    }

    #[test]
    fn drr_weighted_client_gets_weight_proportional_service() {
        let mut q = DrrQueue::new();
        for i in 0..6 {
            q.push(1, 3, (1u64, i)); // weight 3
        }
        for i in 0..6 {
            q.push(2, 1, (2u64, i)); // weight 1
        }
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|(c, _)| c).collect();
        // each round: 3 jobs of client 1, then 1 of client 2
        assert_eq!(order, vec![1, 1, 1, 2, 1, 1, 1, 2, 2, 2, 2, 2]);
    }

    #[test]
    fn drr_preserves_per_client_order_and_conserves_items() {
        let mut q = DrrQueue::new();
        for i in 0..5 {
            q.push(7, 2, (7u64, i));
            q.push(9, 4, (9u64, i));
        }
        let mut last: HashMap<u64, i32> = HashMap::new();
        let mut n = 0;
        while let Some((c, i)) = q.pop() {
            let prev = last.insert(c, i);
            assert!(prev.map_or(true, |p| p < i), "client {c} served out of order");
            n += 1;
        }
        assert_eq!(n, 10);
    }

    #[test]
    fn drr_idle_client_banks_no_credit() {
        let mut q = DrrQueue::new();
        q.push(1, 5, 0);
        assert_eq!(q.pop(), Some(0));
        // lane emptied after one job of a weight-5 visit: the unused
        // deficit is forfeited, so a later burst starts a fresh round
        for i in 10..13 {
            q.push(1, 1, i);
        }
        q.push(2, 1, 99);
        assert_eq!(q.pop(), Some(10));
        assert_eq!(q.pop(), Some(99)); // client 2 is not starved
    }

    #[test]
    fn token_bucket_burst_then_refill() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(2.0, 3.0, t0); // 2/s, burst 3
        assert!(b.try_take(t0).is_ok());
        assert!(b.try_take(t0).is_ok());
        assert!(b.try_take(t0).is_ok());
        let wait = b.try_take(t0).unwrap_err();
        // empty bucket at 2 tokens/s: next token in 0.5 s
        assert!(wait > Duration::from_millis(400) && wait <= Duration::from_millis(500));
        // one second later two tokens have refilled
        let t1 = t0 + Duration::from_secs(1);
        assert!(b.try_take(t1).is_ok());
        assert!(b.try_take(t1).is_ok());
        assert!(b.try_take(t1).is_err());
    }

    #[test]
    fn token_bucket_never_exceeds_burst() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(100.0, 2.0, t0);
        // a long idle period must not bank more than `burst` tokens
        let t1 = t0 + Duration::from_secs(3600);
        assert!(b.try_take(t1).is_ok());
        assert!(b.try_take(t1).is_ok());
        assert!(b.try_take(t1).is_err());
    }

    #[test]
    fn token_bucket_refund_restores_a_charge() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(1.0, 2.0, t0);
        assert!(b.try_take(t0).is_ok());
        assert!(b.try_take(t0).is_ok());
        assert!(b.try_take(t0).is_err(), "burst of 2 is spent");
        // a charge-then-refund round trip is a no-op on the balance:
        // refunding twice restores both burst tokens with no time passing
        b.refund();
        b.refund();
        assert!(b.try_take(t0).is_ok());
        assert!(b.try_take(t0).is_ok());
        assert!(b.try_take(t0).is_err());
    }

    #[test]
    fn token_bucket_refund_never_exceeds_burst() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(1.0, 2.0, t0);
        // refunding a full bucket must not bank a third token
        b.refund();
        b.refund();
        assert!(b.try_take(t0).is_ok());
        assert!(b.try_take(t0).is_ok());
        assert!(b.try_take(t0).is_err());
    }

    #[test]
    fn drr_position_of_next_matches_actual_pop_order() {
        // Every (client, weight) probe against a mixed backlog: the
        // prediction must equal the pop count observed when the probe job
        // is actually pushed and the queue drained for real.
        let backlogs: &[&[(u64, u32)]] = &[
            &[],
            &[(1, 1)],
            &[(1, 3), (1, 3), (2, 1)],
            &[(1, 1), (2, 2), (1, 1), (3, 4), (2, 2)],
            &[(5, 16), (5, 16), (6, 1), (7, 2), (6, 1)],
        ];
        for (case, backlog) in backlogs.iter().enumerate() {
            for &(probe_client, probe_weight) in
                &[(1u64, 1u32), (1, 5), (2, 1), (9, 1), (9, 16)]
            {
                let mut q = DrrQueue::new();
                for (i, &(c, w)) in backlog.iter().enumerate() {
                    q.push(c, w, (c, i as u32));
                }
                let predicted = q.position_of_next(probe_client, probe_weight);
                q.push(probe_client, probe_weight, (probe_client, u32::MAX));
                let mut served = 0usize;
                while let Some((c, i)) = q.pop() {
                    if (c, i) == (probe_client, u32::MAX) {
                        break;
                    }
                    served += 1;
                }
                assert_eq!(
                    predicted, served,
                    "case {case}: probe ({probe_client}, w{probe_weight})"
                );
            }
        }
    }

    #[test]
    fn drr_position_of_next_is_read_only_and_respects_mid_visit_deficit() {
        let mut q = DrrQueue::new();
        for i in 0..4 {
            q.push(1, 3, (1u64, i)); // weight-3 lane
        }
        q.push(2, 1, (2u64, 0));
        // serve one job: lane 1 is mid-visit with deficit 2 remaining
        assert_eq!(q.pop(), Some((1, 0)));
        // a new client-2 job waits for the rest of lane 1's visit (2 jobs),
        // the client-2 job already queued ahead in its own lane, and lane
        // 1's next one-job visit before client 2's lane comes around again
        assert_eq!(q.position_of_next(2, 1), 4);
        // the probe must not have mutated the schedule
        let order: Vec<(u64, i32)> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(order, vec![(1, 1), (1, 2), (2, 0), (1, 3)]);
    }
}
