//! The per-run leader state machine.
//!
//! One clustering run, as seen by the leader, is a small protocol:
//! register every site's shard, size codeword budgets, collect codebooks,
//! cluster centrally, send codeword labels back. [`RunMachine`] is that
//! protocol as an explicit event-driven state machine
//!
//! ```text
//! Registering ──all sites registered──▶ BudgetsSent ──first codebook──▶
//! Collecting ──all codebooks in──▶ Central ──labels computed──▶ LabelsSent
//! ```
//!
//! advanced by [`RunInput`] events and emitting [`Advance`] actions. It
//! owns no transport and no clock: *who* feeds it events decides the
//! concurrency model. Two drivers exist:
//!
//! * [`super::leader_protocol`] — the blocking single-run driver: one
//!   machine, events pumped straight off a [`crate::net::LeaderNet`]
//!   (channel or TCP; classic unscoped frames). `dsc run`, `dsc leader`.
//! * [`super::server`] — the job-serving reactor: many machines at once,
//!   events demultiplexed by run id off a single mailbox (run-scoped
//!   frames), so several runs interleave over the same persistent site
//!   links. `dsc leader --serve`.
//!
//! Budgets and per-site seeds are derived only from `(JobSpec, site
//! sizes)` — never from the run id or event arrival order — which is what
//! makes a job's result identical across drivers, transports, and
//! interleavings (the parity guarantees in `rust/tests/job_server.rs` and
//! `examples/tcp_cluster.rs`).

use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Error, Result};

use crate::dml::DmlKind;
use crate::net::JobSpec;
use crate::rng::Rng;

use super::LeaderOutcome;

/// Where a run stands. `BudgetsSent` and `Collecting` differ only in
/// whether any codebook has arrived yet; both accept codebooks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Waiting for every site's `SiteInfo` registration.
    Registering,
    /// DML work orders are out; no codebook back yet.
    BudgetsSent,
    /// At least one codebook in, more outstanding.
    Collecting,
    /// All codebooks in; the driver owes the machine a central-step result
    /// ([`RunMachine::central_done`]).
    Central,
    /// Codeword labels delivered — the run is complete.
    LabelsSent,
}

impl Phase {
    /// The name used in straggler-deadline errors ("registration collect
    /// failed …"), matching the pre-machine error text.
    fn collect_name(self) -> &'static str {
        match self {
            Phase::Registering => "registration",
            _ => "codebook",
        }
    }
}

/// One event for the machine. Embedded site ids have already been checked
/// against the link the frame arrived on (the driver's job, since only it
/// sees links); `site` here is the trusted link index.
#[derive(Debug)]
pub enum RunInput {
    /// A site registered its shard shape.
    SiteInfo { site: usize, n_points: u64, dim: u32 },
    /// A site delivered its codebook.
    Codebook { site: usize, dim: u32, codewords: Vec<f32>, weights: Vec<u32> },
    /// A site's link died. Any run still needing that site fails.
    SiteDown { site: usize, err: String },
    /// Time passed with nothing to deliver; the machine checks its
    /// straggler deadline.
    Tick,
}

/// A work order for one site, emitted when budgets are assigned. The
/// driver wraps it into a classic `DMLREQ` or a run-scoped `RDMLREQ`
/// frame — the machine is dialect-agnostic.
#[derive(Clone, Debug, PartialEq)]
pub struct DmlOrder {
    pub dml: DmlKind,
    pub target_codes: u32,
    pub max_iters: u32,
    pub tol: f64,
    pub seed: u64,
}

/// One outbound payload: `(site, what)`.
#[derive(Clone, Debug, PartialEq)]
pub enum OutMsg {
    Dml(DmlOrder),
    Labels(Vec<u16>),
}

/// What one [`RunMachine::advance`] produced.
#[derive(Debug, Default)]
pub struct Advance {
    /// Frames to send now, in emission order (site order within a batch,
    /// for the deterministic send sequence the parity tests pin).
    pub send: Vec<(usize, OutMsg)>,
    /// The machine entered [`Phase::Central`]: the driver must run the
    /// central step on [`RunMachine::central_input`] and call
    /// [`RunMachine::central_done`].
    pub central: bool,
    /// The machine entered [`Phase::LabelsSent`] — after the driver sends
    /// the accompanying label frames, the run is complete.
    pub done: bool,
}

/// Site-reported point counts are untrusted input: bound them per site and
/// sum checked, so one hostile SiteInfo cannot panic the leader (debug
/// overflow) or wrap the proportional-budget arithmetic (release).
const MAX_SITE_POINTS: u64 = 1 << 48;

/// The per-run leader state machine. See the module docs.
pub struct RunMachine {
    spec: JobSpec,
    phase: Phase,
    collect_timeout: Duration,
    deadline: Instant,
    /// Registration slots: `(n_points, dim)` per site.
    infos: Vec<Option<(u64, u32)>>,
    /// Codebook slots, buffered per site then concatenated in site order
    /// (determinism: the codeword union must not depend on arrival order).
    books: Vec<Option<(Vec<f32>, Vec<u32>)>>,
    dim: u32,
    site_points: Vec<u64>,
    /// Codeword union, assembled when the last codebook lands.
    cw_all: Vec<f32>,
    w_all: Vec<f32>,
    /// Per-site `(offset, count)` spans into the union.
    spans: Vec<(usize, usize)>,
    sigma: f64,
    central: Duration,
}

impl RunMachine {
    /// A fresh machine in [`Phase::Registering`], with its first straggler
    /// deadline at `now + collect_timeout`.
    pub fn new(n_sites: usize, spec: JobSpec, collect_timeout: Duration, now: Instant) -> RunMachine {
        RunMachine {
            spec,
            phase: Phase::Registering,
            collect_timeout,
            deadline: now + collect_timeout,
            infos: vec![None; n_sites],
            books: vec![None; n_sites],
            dim: 0,
            site_points: Vec::new(),
            cw_all: Vec::new(),
            w_all: Vec::new(),
            spans: vec![(0, 0); n_sites],
            sigma: 0.0,
            central: Duration::ZERO,
        }
    }

    pub fn phase(&self) -> Phase {
        self.phase
    }

    pub fn n_sites(&self) -> usize {
        self.infos.len()
    }

    pub fn spec(&self) -> &JobSpec {
        &self.spec
    }

    /// The current straggler deadline. Only meaningful while collecting
    /// (`Registering`/`BudgetsSent`/`Collecting`); drivers use it to size
    /// their receive timeout.
    pub fn deadline(&self) -> Instant {
        self.deadline
    }

    /// The straggler deadline, but only while the machine is actually in a
    /// collect phase — `None` during `Central`/`LabelsSent`. Reactors that
    /// let a central step span mailbox waits (the job server's worker-pool
    /// offload) must use this for their wakeup computation: the raw
    /// [`RunMachine::deadline`] goes stale the moment the last codebook
    /// lands, and a stale, already-passed instant would spin the event loop
    /// with zero-length timeouts for the whole central phase.
    pub fn collect_deadline(&self) -> Option<Instant> {
        matches!(self.phase, Phase::Registering | Phase::BudgetsSent | Phase::Collecting)
            .then_some(self.deadline)
    }

    /// Feed one event. `now` is the driver's clock reading for this event
    /// (deadline resets are measured from it). An `Err` is fatal to the
    /// run — the driver reports it and discards the machine.
    pub fn advance(&mut self, now: Instant, input: RunInput) -> Result<Advance> {
        match input {
            RunInput::SiteInfo { site, n_points, dim } => {
                self.on_site_info(now, site, n_points, dim)
            }
            RunInput::Codebook { site, dim, codewords, weights } => {
                self.on_codebook(site, dim, codewords, weights)
            }
            RunInput::SiteDown { site, err } => {
                if self.phase == Phase::LabelsSent {
                    return Ok(Advance::default()); // run already complete
                }
                bail!("site {site} link failed mid-run: {err}")
            }
            RunInput::Tick => {
                if now >= self.deadline
                    && matches!(
                        self.phase,
                        Phase::Registering | Phase::BudgetsSent | Phase::Collecting
                    )
                {
                    return Err(self.waiting_error("deadline expired"));
                }
                Ok(Advance::default())
            }
        }
    }

    fn on_site_info(
        &mut self,
        now: Instant,
        site: usize,
        n_points: u64,
        dim: u32,
    ) -> Result<Advance> {
        if self.phase != Phase::Registering {
            bail!("unexpected site info from site {site} during {:?}", self.phase);
        }
        if site >= self.infos.len() {
            bail!("site info from out-of-range site {site}");
        }
        if n_points > MAX_SITE_POINTS {
            bail!("site {site} reports an implausible {n_points} points");
        }
        if self.infos[site].replace((n_points, dim)).is_some() {
            bail!("site {site} registered twice");
        }
        if self.infos.iter().any(|s| s.is_none()) {
            return Ok(Advance::default()); // still collecting registrations
        }

        // ---- everyone registered: validate, size budgets, emit orders ----
        let infos: Vec<(u64, u32)> = self.infos.iter().map(|s| s.unwrap()).collect();
        let dim0 = infos[0].1;
        for (sid, &(_, d)) in infos.iter().enumerate() {
            if d != dim0 {
                bail!("site {sid} has dim {d}, expected {dim0}");
            }
        }
        if dim0 == 0 {
            bail!("sites report zero-dimensional data");
        }
        self.dim = dim0;
        self.site_points = infos.iter().map(|&(np, _)| np).collect();
        let mut total_points: u64 = 0;
        for &np in &self.site_points {
            total_points = total_points
                .checked_add(np)
                .ok_or_else(|| anyhow!("total point count overflows u64"))?;
        }
        if total_points == 0 {
            bail!("no data at any site");
        }

        // Per-site codeword budgets ∝ site size (paper: fixed compression
        // ratio); per-site seeds fork from the job seed, so results are a
        // function of (data, spec) alone — not of transport, driver, or
        // which runs happen to share the links.
        let spec = &self.spec;
        let root_rng = Rng::new(spec.seed);
        let send = self
            .site_points
            .iter()
            .enumerate()
            .map(|(sid, &np)| {
                let budget = ((spec.total_codes as f64 * np as f64 / total_points as f64)
                    .round() as usize)
                    .max(1)
                    .min((np as usize).max(1));
                let mut fork = root_rng.fork(sid as u64 + 1);
                (
                    sid,
                    OutMsg::Dml(DmlOrder {
                        dml: spec.dml,
                        target_codes: budget as u32,
                        max_iters: spec.kmeans_max_iters,
                        tol: spec.kmeans_tol,
                        seed: fork.next_u64(),
                    }),
                )
            })
            .collect();
        self.phase = Phase::BudgetsSent;
        self.deadline = now + self.collect_timeout; // fresh codebook deadline
        Ok(Advance { send, central: false, done: false })
    }

    fn on_codebook(
        &mut self,
        site: usize,
        dim: u32,
        codewords: Vec<f32>,
        weights: Vec<u32>,
    ) -> Result<Advance> {
        if !matches!(self.phase, Phase::BudgetsSent | Phase::Collecting) {
            bail!("unexpected codebook from site {site} during {:?}", self.phase);
        }
        if site >= self.books.len() {
            bail!("codebook from out-of-range site {site}");
        }
        if dim != self.dim {
            bail!("site {site} sent dim {dim}, expected {}", self.dim);
        }
        if codewords.len() != (dim as usize) * weights.len() {
            bail!("site {site} sent a malformed codebook");
        }
        if self.books[site].replace((codewords, weights)).is_some() {
            bail!("site {site} sent two codebooks");
        }
        self.phase = Phase::Collecting;
        if self.books.iter().any(|s| s.is_none()) {
            return Ok(Advance::default());
        }

        // ---- all codebooks in: concatenate in site order, go central ----
        for (sid, slot) in self.books.iter_mut().enumerate() {
            let (codewords, weights) = slot.take().expect("all collected");
            self.spans[sid] = (self.w_all.len(), weights.len());
            self.cw_all.extend_from_slice(&codewords);
            self.w_all.extend(weights.iter().map(|&w| w as f32));
        }
        self.phase = Phase::Central;
        Ok(Advance { send: Vec::new(), central: true, done: false })
    }

    /// The codeword union for the central step: `(codewords, dim,
    /// weights)`. Valid in [`Phase::Central`].
    pub fn central_input(&self) -> (&[f32], usize, &[f32]) {
        debug_assert_eq!(self.phase, Phase::Central);
        (&self.cw_all, self.dim as usize, &self.w_all)
    }

    /// The driver ran the central step; hand the machine one label per
    /// codeword of the union. Emits the per-site label frames (site order)
    /// and completes the run.
    pub fn central_done(
        &mut self,
        code_labels: Vec<u16>,
        sigma: f64,
        central: Duration,
    ) -> Result<Advance> {
        if self.phase != Phase::Central {
            bail!("central result delivered during {:?}", self.phase);
        }
        if code_labels.len() != self.w_all.len() {
            bail!(
                "central step produced {} labels for {} codewords",
                code_labels.len(),
                self.w_all.len()
            );
        }
        self.sigma = sigma;
        self.central = central;
        let send = self
            .spans
            .iter()
            .enumerate()
            .map(|(sid, &(off, len))| (sid, OutMsg::Labels(code_labels[off..off + len].to_vec())))
            .collect();
        self.phase = Phase::LabelsSent;
        Ok(Advance { send, central: false, done: true })
    }

    /// The canonical straggler error: which collect phase stalled, for how
    /// long, and which sites never reported. Drivers also call this when
    /// their own receive fails mid-collect (`cause` = the transport error).
    pub fn waiting_error(&self, cause: &str) -> Error {
        let slots: Vec<bool> = match self.phase {
            Phase::Registering => self.infos.iter().map(|s| s.is_some()).collect(),
            _ => self.books.iter().map(|s| s.is_some()).collect(),
        };
        let missing: Vec<usize> =
            slots.iter().enumerate().filter(|(_, &ok)| !ok).map(|(i, _)| i).collect();
        anyhow!(
            "{} collect failed after {:?} — sites {missing:?} never reported ({cause})",
            self.phase.collect_name(),
            self.collect_timeout
        )
    }

    /// The transport-independent outcome. Valid once [`Phase::LabelsSent`].
    pub fn outcome(&self) -> LeaderOutcome {
        debug_assert_eq!(self.phase, Phase::LabelsSent);
        LeaderOutcome {
            dim: self.dim as usize,
            n_codes: self.w_all.len(),
            sigma: self.sigma,
            central: self.central,
            site_points: self.site_points.clone(),
            site_codes: self.spans.iter().map(|&(_, len)| len).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spectral::{Algo, Bandwidth, GraphKind};

    fn spec(total_codes: u32, seed: u64) -> JobSpec {
        JobSpec {
            dml: DmlKind::KMeans,
            total_codes,
            k_clusters: 2,
            kmeans_max_iters: 30,
            kmeans_tol: 1e-6,
            seed,
            algo: Algo::RecursiveNcut,
            graph: GraphKind::Dense,
            weighted: false,
            bandwidth: Bandwidth::MedianScale(0.5),
        }
    }

    fn machine(n_sites: usize) -> RunMachine {
        RunMachine::new(n_sites, spec(64, 7), Duration::from_secs(300), Instant::now())
    }

    #[test]
    fn full_run_walkthrough() {
        let now = Instant::now();
        let mut m = machine(2);
        assert_eq!(m.phase(), Phase::Registering);

        // second site registers first — order must not matter
        let adv =
            m.advance(now, RunInput::SiteInfo { site: 1, n_points: 1_000, dim: 2 }).unwrap();
        assert!(adv.send.is_empty() && !adv.central && !adv.done);
        let adv =
            m.advance(now, RunInput::SiteInfo { site: 0, n_points: 3_000, dim: 2 }).unwrap();
        assert_eq!(m.phase(), Phase::BudgetsSent);
        assert_eq!(adv.send.len(), 2);
        // budgets ∝ site size: 3000/4000·64 = 48, 1000/4000·64 = 16
        let budgets: Vec<u32> = adv
            .send
            .iter()
            .map(|(_, out)| match out {
                OutMsg::Dml(o) => o.target_codes,
                other => panic!("expected dml orders, got {other:?}"),
            })
            .collect();
        assert_eq!(budgets, vec![48, 16]);
        // seeds fork from the job seed per site — deterministic and distinct
        let seeds: Vec<u64> = adv
            .send
            .iter()
            .map(|(_, out)| match out {
                OutMsg::Dml(o) => o.seed,
                _ => unreachable!(),
            })
            .collect();
        assert_ne!(seeds[0], seeds[1]);
        let root = Rng::new(7);
        assert_eq!(seeds[0], root.fork(1).next_u64());
        assert_eq!(seeds[1], root.fork(2).next_u64());

        let adv = m
            .advance(
                now,
                RunInput::Codebook {
                    site: 1,
                    dim: 2,
                    codewords: vec![5.0, 6.0],
                    weights: vec![1_000],
                },
            )
            .unwrap();
        assert_eq!(m.phase(), Phase::Collecting);
        assert!(!adv.central);
        let adv = m
            .advance(
                now,
                RunInput::Codebook {
                    site: 0,
                    dim: 2,
                    codewords: vec![1.0, 2.0, 3.0, 4.0],
                    weights: vec![2_000, 1_000],
                },
            )
            .unwrap();
        assert_eq!(m.phase(), Phase::Central);
        assert!(adv.central && !adv.done);

        // union is in site order regardless of arrival order
        let (cw, dim, w) = m.central_input();
        assert_eq!(dim, 2);
        assert_eq!(cw.to_vec(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(w.to_vec(), vec![2_000.0, 1_000.0, 1_000.0]);

        let adv = m.central_done(vec![0, 1, 1], 1.5, Duration::from_millis(3)).unwrap();
        assert_eq!(m.phase(), Phase::LabelsSent);
        assert!(adv.done);
        assert_eq!(adv.send.len(), 2);
        assert_eq!(adv.send[0], (0, OutMsg::Labels(vec![0, 1])));
        assert_eq!(adv.send[1], (1, OutMsg::Labels(vec![1])));

        let out = m.outcome();
        assert_eq!(out.dim, 2);
        assert_eq!(out.n_codes, 3);
        assert_eq!(out.sigma, 1.5);
        assert_eq!(out.site_points, vec![3_000, 1_000]);
        assert_eq!(out.site_codes, vec![2, 1]);
    }

    #[test]
    fn protocol_violations_fail_the_run() {
        let now = Instant::now();

        // double registration
        let mut m = machine(2);
        m.advance(now, RunInput::SiteInfo { site: 0, n_points: 10, dim: 2 }).unwrap();
        let err = m
            .advance(now, RunInput::SiteInfo { site: 0, n_points: 10, dim: 2 })
            .unwrap_err();
        assert!(err.to_string().contains("registered twice"), "{err}");

        // dim disagreement surfaces when the last site registers
        let mut m = machine(2);
        m.advance(now, RunInput::SiteInfo { site: 0, n_points: 10, dim: 2 }).unwrap();
        let err = m
            .advance(now, RunInput::SiteInfo { site: 1, n_points: 10, dim: 3 })
            .unwrap_err();
        assert!(err.to_string().contains("dim"), "{err}");

        // codebook before registration completes
        let mut m = machine(2);
        let err = m
            .advance(
                now,
                RunInput::Codebook { site: 0, dim: 2, codewords: vec![], weights: vec![] },
            )
            .unwrap_err();
        assert!(err.to_string().contains("unexpected codebook"), "{err}");

        // hostile point count
        let mut m = machine(1);
        let err = m
            .advance(now, RunInput::SiteInfo { site: 0, n_points: u64::MAX - 1, dim: 2 })
            .unwrap_err();
        assert!(err.to_string().contains("implausible"), "{err}");

        // malformed codebook
        let mut m = machine(1);
        m.advance(now, RunInput::SiteInfo { site: 0, n_points: 10, dim: 2 }).unwrap();
        let err = m
            .advance(
                now,
                RunInput::Codebook {
                    site: 0,
                    dim: 2,
                    codewords: vec![1.0; 3], // not 2·n
                    weights: vec![5],
                },
            )
            .unwrap_err();
        assert!(err.to_string().contains("malformed"), "{err}");
    }

    #[test]
    fn deadline_expiry_names_missing_sites() {
        let t0 = Instant::now();
        let mut m = RunMachine::new(3, spec(64, 7), Duration::from_millis(100), t0);
        m.advance(t0, RunInput::SiteInfo { site: 0, n_points: 10, dim: 2 }).unwrap();
        m.advance(t0, RunInput::SiteInfo { site: 2, n_points: 10, dim: 2 }).unwrap();
        // before the deadline, ticks are harmless
        assert!(m.advance(t0, RunInput::Tick).unwrap().send.is_empty());
        let err = m
            .advance(t0 + Duration::from_millis(150), RunInput::Tick)
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("registration collect failed"), "{msg}");
        assert!(msg.contains("[1]"), "must name the missing site: {msg}");
    }

    #[test]
    fn deadline_resets_between_phases() {
        let t0 = Instant::now();
        let mut m = RunMachine::new(1, spec(16, 7), Duration::from_millis(100), t0);
        // register at t0+80ms: the codebook deadline restarts from there
        let t1 = t0 + Duration::from_millis(80);
        m.advance(t1, RunInput::SiteInfo { site: 0, n_points: 100, dim: 2 }).unwrap();
        assert_eq!(m.phase(), Phase::BudgetsSent);
        assert!(m.advance(t0 + Duration::from_millis(150), RunInput::Tick).is_ok());
        let err =
            m.advance(t1 + Duration::from_millis(150), RunInput::Tick).unwrap_err();
        assert!(err.to_string().contains("codebook collect failed"), "{err}");
        assert!(err.to_string().contains("[0]"), "{err}");
    }

    #[test]
    fn collect_deadline_vanishes_once_central_starts() {
        let t0 = Instant::now();
        let mut m = RunMachine::new(1, spec(16, 7), Duration::from_millis(100), t0);
        assert!(m.collect_deadline().is_some(), "registering is a collect phase");
        m.advance(t0, RunInput::SiteInfo { site: 0, n_points: 100, dim: 1 }).unwrap();
        assert!(m.collect_deadline().is_some(), "budgets-sent is a collect phase");
        m.advance(
            t0,
            RunInput::Codebook { site: 0, dim: 1, codewords: vec![0.5], weights: vec![100] },
        )
        .unwrap();
        assert_eq!(m.phase(), Phase::Central);
        assert!(m.collect_deadline().is_none(), "no straggler deadline mid-central");
        // the raw deadline may already be in the past here — that staleness
        // is exactly what collect_deadline hides from reactors
        m.central_done(vec![0], 1.0, Duration::ZERO).unwrap();
        assert!(m.collect_deadline().is_none(), "no deadline after completion");
    }

    #[test]
    fn site_down_fails_active_run_but_not_finished_one() {
        let now = Instant::now();
        let mut m = machine(1);
        m.advance(now, RunInput::SiteInfo { site: 0, n_points: 100, dim: 1 }).unwrap();
        m.advance(
            now,
            RunInput::Codebook { site: 0, dim: 1, codewords: vec![0.5], weights: vec![100] },
        )
        .unwrap();
        m.central_done(vec![0], 1.0, Duration::ZERO).unwrap();
        // complete run: a late SiteDown is a no-op
        assert!(m
            .advance(now, RunInput::SiteDown { site: 0, err: "gone".into() })
            .is_ok());

        let mut m = machine(1);
        let err = m
            .advance(now, RunInput::SiteDown { site: 0, err: "gone".into() })
            .unwrap_err();
        assert!(err.to_string().contains("link failed"), "{err}");
    }
}
