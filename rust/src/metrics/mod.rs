//! Evaluation metrics.
//!
//! * [`clustering_accuracy`] — the paper's Eq. (5): the best label-
//!   permutation agreement between predicted clusters and ground truth.
//!   Computed exactly via the Hungarian algorithm on the confusion matrix
//!   (equivalent to the max over permutations, but O(K³) instead of K! —
//!   the paper dropped Cover Type classes to keep 7! feasible; we don't
//!   have to).
//! * [`adjusted_rand_index`] / [`normalized_mutual_info`] — standard
//!   secondary metrics, reported in EXPERIMENTS.md alongside accuracy.
//! * [`Stopwatch`] — elapsed-time bookkeeping matching the paper's protocol
//!   (§5: per-site times are maxed, not summed, plus the central stage).

pub mod hungarian;

pub use hungarian::hungarian_max;

/// Confusion matrix `counts[t][p]` = #points with true label `t` and
/// predicted label `p`.
pub fn confusion(truth: &[u16], pred: &[u16], k_true: usize, k_pred: usize) -> Vec<Vec<u64>> {
    assert_eq!(truth.len(), pred.len(), "label vectors differ in length");
    let mut m = vec![vec![0u64; k_pred]; k_true];
    for (&t, &p) in truth.iter().zip(pred) {
        m[t as usize][p as usize] += 1;
    }
    m
}

/// The paper's clustering accuracy (Eq. 5): maximal fraction of agreeing
/// labels over all assignments of predicted clusters to true classes.
pub fn clustering_accuracy(truth: &[u16], pred: &[u16]) -> f64 {
    if truth.is_empty() {
        return 1.0;
    }
    let k_true = truth.iter().map(|&l| l as usize + 1).max().unwrap_or(1);
    let k_pred = pred.iter().map(|&l| l as usize + 1).max().unwrap_or(1);
    let k = k_true.max(k_pred);
    let m = confusion(truth, pred, k, k);
    let profit: Vec<Vec<f64>> = m
        .iter()
        .map(|row| row.iter().map(|&c| c as f64).collect())
        .collect();
    let (matched, _cols) = hungarian_max(&profit);
    matched / truth.len() as f64
}

/// Adjusted Rand index (Hubert–Arabie).
pub fn adjusted_rand_index(truth: &[u16], pred: &[u16]) -> f64 {
    assert_eq!(truth.len(), pred.len());
    let n = truth.len();
    if n < 2 {
        return 1.0;
    }
    let k_true = truth.iter().map(|&l| l as usize + 1).max().unwrap();
    let k_pred = pred.iter().map(|&l| l as usize + 1).max().unwrap();
    let m = confusion(truth, pred, k_true, k_pred);

    fn c2(x: u64) -> f64 {
        (x as f64) * (x as f64 - 1.0) / 2.0
    }

    let mut sum_ij = 0.0;
    let mut row_sums = vec![0u64; k_true];
    let mut col_sums = vec![0u64; k_pred];
    for (t, row) in m.iter().enumerate() {
        for (p, &c) in row.iter().enumerate() {
            sum_ij += c2(c);
            row_sums[t] += c;
            col_sums[p] += c;
        }
    }
    let sum_a: f64 = row_sums.iter().map(|&x| c2(x)).sum();
    let sum_b: f64 = col_sums.iter().map(|&x| c2(x)).sum();
    let total = c2(n as u64);
    let expected = sum_a * sum_b / total;
    let max_index = 0.5 * (sum_a + sum_b);
    if (max_index - expected).abs() < 1e-12 {
        return 1.0; // degenerate: single cluster on both sides
    }
    (sum_ij - expected) / (max_index - expected)
}

/// Normalized mutual information with arithmetic-mean normalization.
pub fn normalized_mutual_info(truth: &[u16], pred: &[u16]) -> f64 {
    assert_eq!(truth.len(), pred.len());
    let n = truth.len();
    if n == 0 {
        return 1.0;
    }
    let k_true = truth.iter().map(|&l| l as usize + 1).max().unwrap();
    let k_pred = pred.iter().map(|&l| l as usize + 1).max().unwrap();
    let m = confusion(truth, pred, k_true, k_pred);
    let nf = n as f64;

    let mut row = vec![0u64; k_true];
    let mut col = vec![0u64; k_pred];
    for (t, r) in m.iter().enumerate() {
        for (p, &c) in r.iter().enumerate() {
            row[t] += c;
            col[p] += c;
        }
    }
    let mut mi = 0.0;
    for (t, r) in m.iter().enumerate() {
        for (p, &c) in r.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let pij = c as f64 / nf;
            let pi = row[t] as f64 / nf;
            let pj = col[p] as f64 / nf;
            mi += pij * (pij / (pi * pj)).ln();
        }
    }
    let ent = |counts: &[u64]| -> f64 {
        counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / nf;
                -p * p.ln()
            })
            .sum()
    };
    let h_t = ent(&row);
    let h_p = ent(&col);
    let denom = 0.5 * (h_t + h_p);
    if denom < 1e-12 {
        return 1.0;
    }
    (mi / denom).clamp(0.0, 1.0)
}

/// CPU time consumed by the *calling thread* so far.
///
/// The paper's elapsed-time protocol assumes distributed sites run
/// independently and reports the max over sites. When this crate simulates
/// sites as threads on a shared (possibly single-core) host, wall clocks
/// include scheduler contention between sites — time that would not exist
/// on real distributed hardware. Thread CPU time is contention-free, so
/// per-site phase costs are measured with it (see `coordinator`).
pub fn thread_cpu_time() -> std::time::Duration {
    // 64-bit only: on those targets `c_long` matches the C library's
    // `time_t`/`long`, so the hand-declared struct below is ABI-exact.
    // (32-bit Linux with 64-bit time_t would need a different layout —
    // there we degrade to the zero fallback rather than risk UB.)
    #[cfg(all(
        target_pointer_width = "64",
        any(target_os = "linux", target_os = "android", target_os = "macos")
    ))]
    {
        use std::os::raw::{c_int, c_long};

        #[repr(C)]
        struct Timespec {
            tv_sec: c_long,
            tv_nsec: c_long,
        }
        // Declared directly (no libc crate offline); the symbol lives in
        // the platform C library every Rust binary already links.
        extern "C" {
            fn clock_gettime(clock_id: c_int, tp: *mut Timespec) -> c_int;
        }
        #[cfg(any(target_os = "linux", target_os = "android"))]
        const CLOCK_THREAD_CPUTIME_ID: c_int = 3;
        #[cfg(target_os = "macos")]
        const CLOCK_THREAD_CPUTIME_ID: c_int = 16;

        let mut ts = Timespec { tv_sec: 0, tv_nsec: 0 };
        // SAFETY: ts is a valid out-pointer with the target's exact
        // timespec layout; CLOCK_THREAD_CPUTIME_ID is supported on the
        // targets selected above.
        let rc = unsafe { clock_gettime(CLOCK_THREAD_CPUTIME_ID, &mut ts) };
        if rc != 0 {
            return std::time::Duration::ZERO;
        }
        std::time::Duration::new(ts.tv_sec as u64, ts.tv_nsec as u32)
    }
    #[cfg(not(all(
        target_pointer_width = "64",
        any(target_os = "linux", target_os = "android", target_os = "macos")
    )))]
    {
        std::time::Duration::ZERO // other platforms: degrade gracefully
    }
}

/// Simple elapsed-time stopwatch with named laps.
#[derive(Debug)]
pub struct Stopwatch {
    start: std::time::Instant,
    laps: Vec<(String, std::time::Duration)>,
    last: std::time::Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        let now = std::time::Instant::now();
        Stopwatch { start: now, laps: Vec::new(), last: now }
    }

    /// Record the time since the previous lap under `name`.
    pub fn lap(&mut self, name: &str) -> std::time::Duration {
        let now = std::time::Instant::now();
        let d = now - self.last;
        self.laps.push((name.to_string(), d));
        self.last = now;
        d
    }

    pub fn total(&self) -> std::time::Duration {
        std::time::Instant::now() - self.start
    }

    pub fn laps(&self) -> &[(String, std::time::Duration)] {
        &self.laps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_perfect_up_to_permutation() {
        let truth = vec![0u16, 0, 1, 1, 2, 2];
        let pred = vec![2u16, 2, 0, 0, 1, 1]; // relabelled
        assert_eq!(clustering_accuracy(&truth, &pred), 1.0);
    }

    #[test]
    fn accuracy_counts_errors() {
        let truth = vec![0u16, 0, 0, 0, 1, 1, 1, 1];
        let pred = vec![0u16, 0, 0, 1, 1, 1, 1, 1]; // one point misplaced
        assert!((clustering_accuracy(&truth, &pred) - 7.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn accuracy_handles_different_cluster_counts() {
        // prediction split one true class in two: best map still ≥ 1/2
        let truth = vec![0u16, 0, 0, 0, 1, 1, 1, 1];
        let pred = vec![0u16, 0, 2, 2, 1, 1, 1, 1];
        let acc = clustering_accuracy(&truth, &pred);
        assert!((acc - 6.0 / 8.0).abs() < 1e-12, "{acc}");
    }

    #[test]
    fn accuracy_single_cluster_prediction() {
        let truth = vec![0u16, 0, 0, 1, 1, 1];
        let pred = vec![0u16; 6];
        assert!((clustering_accuracy(&truth, &pred) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn ari_extremes() {
        let truth = vec![0u16, 0, 1, 1, 2, 2];
        assert!((adjusted_rand_index(&truth, &truth) - 1.0).abs() < 1e-12);
        let relabel = vec![1u16, 1, 2, 2, 0, 0];
        assert!((adjusted_rand_index(&truth, &relabel) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ari_random_is_near_zero() {
        let mut rng = crate::rng::Rng::new(3);
        let n = 10_000;
        let truth: Vec<u16> = (0..n).map(|_| rng.index(3) as u16).collect();
        let pred: Vec<u16> = (0..n).map(|_| rng.index(3) as u16).collect();
        let ari = adjusted_rand_index(&truth, &pred);
        assert!(ari.abs() < 0.02, "{ari}");
    }

    #[test]
    fn nmi_extremes_and_permutation_invariance() {
        let truth = vec![0u16, 0, 1, 1, 2, 2];
        assert!((normalized_mutual_info(&truth, &truth) - 1.0).abs() < 1e-12);
        let relabel = vec![2u16, 2, 0, 0, 1, 1];
        assert!((normalized_mutual_info(&truth, &relabel) - 1.0).abs() < 1e-12);
        let uninformative = vec![0u16; 6];
        let nmi = normalized_mutual_info(&truth, &uninformative);
        assert!(nmi < 1e-9, "{nmi}");
    }

    #[test]
    fn confusion_shape_and_counts() {
        let m = confusion(&[0, 1, 1], &[1, 1, 0], 2, 2);
        assert_eq!(m, vec![vec![0, 1], vec![1, 1]]);
    }

    #[test]
    fn stopwatch_laps_accumulate() {
        let mut sw = Stopwatch::new();
        std::thread::sleep(std::time::Duration::from_millis(5));
        let lap = sw.lap("phase1");
        assert!(lap.as_millis() >= 4);
        assert_eq!(sw.laps().len(), 1);
        assert!(sw.total() >= lap);
    }
}
