//! Hungarian (Kuhn–Munkres) assignment via the potentials formulation.
//!
//! [`hungarian_max`] maximizes total profit over one-to-one assignments of
//! rows to columns — used to evaluate the paper's Eq. (5) exactly in O(K³)
//! instead of enumerating K! permutations.
//!
//! Implementation: the classic shortest-augmenting-path algorithm with row
//! and column potentials (the "e-maxx" formulation) on the *cost* matrix
//! `cost = max_profit − profit`, padded to square.

/// Maximize `Σ profit[r][assignment[r]]` over injective row→column
/// assignments. Returns `(total_profit, cols)` where `cols[r]` is the
/// column assigned to row `r` (`usize::MAX` for rows left unmatched when
/// there are more rows than columns — padding handles the reverse case).
pub fn hungarian_max(profit: &[Vec<f64>]) -> (f64, Vec<usize>) {
    let rows = profit.len();
    if rows == 0 {
        return (0.0, vec![]);
    }
    let cols = profit[0].len();
    for row in profit {
        assert_eq!(row.len(), cols, "profit matrix must be rectangular");
    }
    if cols == 0 {
        return (0.0, vec![usize::MAX; rows]);
    }

    let n = rows.max(cols); // pad to square with zero-profit cells
    let maxp = profit
        .iter()
        .flat_map(|r| r.iter().copied())
        .fold(0.0f64, f64::max)
        .max(0.0);
    let cost = |r: usize, c: usize| -> f64 {
        if r < rows && c < cols {
            maxp - profit[r][c]
        } else {
            maxp // zero profit for padding cells
        }
    };

    // potentials u (rows), v (cols); way[c] = previous column on aug path;
    // match_col[c] = row matched to column c. 1-indexed internally.
    let mut u = vec![0.0f64; n + 1];
    let mut v = vec![0.0f64; n + 1];
    let mut match_col = vec![0usize; n + 1]; // 0 = free
    let mut way = vec![0usize; n + 1];

    for r in 1..=n {
        match_col[0] = r;
        let mut j0 = 0usize;
        let mut minv = vec![f64::INFINITY; n + 1];
        let mut used = vec![false; n + 1];
        loop {
            used[j0] = true;
            let i0 = match_col[j0];
            let mut delta = f64::INFINITY;
            let mut j1 = 0usize;
            for j in 1..=n {
                if used[j] {
                    continue;
                }
                let cur = cost(i0 - 1, j - 1) - u[i0] - v[j];
                if cur < minv[j] {
                    minv[j] = cur;
                    way[j] = j0;
                }
                if minv[j] < delta {
                    delta = minv[j];
                    j1 = j;
                }
            }
            for j in 0..=n {
                if used[j] {
                    u[match_col[j]] += delta;
                    v[j] -= delta;
                } else {
                    minv[j] -= delta;
                }
            }
            j0 = j1;
            if match_col[j0] == 0 {
                break;
            }
        }
        // augment along the path
        loop {
            let j1 = way[j0];
            match_col[j0] = match_col[j1];
            j0 = j1;
            if j0 == 0 {
                break;
            }
        }
    }

    let mut assignment = vec![usize::MAX; rows];
    let mut total = 0.0;
    for c in 1..=n {
        let r = match_col[c];
        if r >= 1 && r <= rows && c <= cols {
            assignment[r - 1] = c - 1;
            total += profit[r - 1][c - 1];
        }
    }
    (total, assignment)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn brute_force_max(profit: &[Vec<f64>]) -> f64 {
        // permutations over the padded square, rows ≤ 8
        let rows = profit.len();
        let cols = profit[0].len();
        let n = rows.max(cols);
        let mut cols_perm: Vec<usize> = (0..n).collect();
        let mut best = f64::NEG_INFINITY;
        permute(&mut cols_perm, 0, &mut |perm| {
            let mut s = 0.0;
            for (r, item) in perm.iter().enumerate().take(rows) {
                if *item < cols {
                    s += profit[r][*item];
                }
            }
            best = best.max(s);
        });
        best
    }

    fn permute(v: &mut Vec<usize>, k: usize, f: &mut impl FnMut(&[usize])) {
        if k == v.len() {
            f(v);
            return;
        }
        for i in k..v.len() {
            v.swap(k, i);
            permute(v, k + 1, f);
            v.swap(k, i);
        }
    }

    #[test]
    fn identity_matrix_prefers_diagonal() {
        let p = vec![
            vec![10.0, 0.0, 0.0],
            vec![0.0, 10.0, 0.0],
            vec![0.0, 0.0, 10.0],
        ];
        let (total, cols) = hungarian_max(&p);
        assert_eq!(total, 30.0);
        assert_eq!(cols, vec![0, 1, 2]);
    }

    #[test]
    fn known_tricky_case() {
        // greedy (row-wise argmax) fails here
        let p = vec![vec![9.0, 8.0], vec![8.0, 1.0]];
        let (total, cols) = hungarian_max(&p);
        assert_eq!(total, 16.0);
        assert_eq!(cols, vec![1, 0]);
    }

    #[test]
    fn rectangular_wide() {
        let p = vec![vec![1.0, 5.0, 3.0]];
        let (total, cols) = hungarian_max(&p);
        assert_eq!(total, 5.0);
        assert_eq!(cols, vec![1]);
    }

    #[test]
    fn rectangular_tall() {
        let p = vec![vec![1.0], vec![5.0], vec![3.0]];
        let (total, cols) = hungarian_max(&p);
        assert_eq!(total, 5.0);
        let matched: Vec<usize> = cols.iter().filter(|&&c| c != usize::MAX).copied().collect();
        assert_eq!(matched, vec![0]);
        assert_eq!(cols[1], 0);
    }

    #[test]
    fn matches_brute_force_on_random_instances() {
        let mut rng = Rng::new(19);
        for trial in 0..50 {
            let rows = 1 + rng.index(6);
            let cols = 1 + rng.index(6);
            let p: Vec<Vec<f64>> = (0..rows)
                .map(|_| (0..cols).map(|_| (rng.f64() * 20.0).round()).collect())
                .collect();
            let (got, assign) = hungarian_max(&p);
            let want = brute_force_max(&p);
            assert!((got - want).abs() < 1e-9, "trial {trial}: {got} vs {want} on {p:?}");
            // assignment must be injective over matched columns
            let mut seen = std::collections::HashSet::new();
            for &c in assign.iter().filter(|&&c| c != usize::MAX) {
                assert!(seen.insert(c), "column {c} used twice");
            }
        }
    }

    #[test]
    fn empty_input() {
        let (t, a) = hungarian_max(&[]);
        assert_eq!(t, 0.0);
        assert!(a.is_empty());
    }
}
