//! Dense linear-algebra substrate.
//!
//! The spectral-clustering core needs exactly three things, all implemented
//! here from scratch (no LAPACK/BLAS available offline):
//!
//! * a dense row-major matrix type [`Mat`] with the handful of ops the
//!   pipeline uses (matvec, gemm, transpose, norms);
//! * a full symmetric eigensolver [`eigen::sym_eig`] (Householder
//!   tridiagonalization + implicit-QL with shifts — the classic
//!   tred2/tql2 pair), used for small/medium problems and as the oracle in
//!   tests;
//! * a Krylov solver [`eigen::lanczos_topk`] for the extremal eigenpairs of
//!   large symmetric operators given only a mat-vec closure — the native
//!   fast path of normalized cuts over codewords.
//!
//! Everything is `f64`: eigensolver stability matters more than memory here
//! (codebooks are ≤ a few thousand rows; the raw data never enters linalg).

pub mod eigen;
pub mod kernels;

/// A symmetric linear operator exposed only through its action `y = A x`.
///
/// Krylov methods need nothing else, which is what lets dense and sparse
/// affinity graphs share one eigensolver: both the dense `n × n` normalized
/// affinity and the CSR k-NN graph implement this trait via their
/// `normalized_matvec` (see [`crate::spectral::NormalizedOp`]), and
/// [`eigen::lanczos_topk_op`] iterates either one identically.
///
/// Symmetry is the implementor's contract — Lanczos silently produces
/// garbage on non-symmetric operators.
pub trait SymOp {
    /// Operator dimension (the length of `x` and `y`).
    fn dim(&self) -> usize;
    /// Compute `y = A x`.
    fn apply(&self, x: &[f64], y: &mut [f64]);
}

/// Dense row-major `f64` matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_fn(rows: usize, cols: usize, f: impl Fn(usize, usize) -> f64) -> Self {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    /// Build from a row-major slice.
    pub fn from_rows(rows: usize, cols: usize, data: &[f64]) -> Self {
        assert_eq!(data.len(), rows * cols, "from_rows: size mismatch");
        Mat { rows, cols, data: data.to_vec() }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// y = A x
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols, "matvec: dim mismatch");
        let mut y = vec![0.0; self.rows];
        for i in 0..self.rows {
            let row = self.row(i);
            let mut acc = 0.0;
            for j in 0..self.cols {
                acc += row[j] * x[j];
            }
            y[i] = acc;
        }
        y
    }

    /// C = A B (naive ikj loop — cache-friendly enough for the ≤2k sizes here).
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "matmul: dim mismatch");
        let mut c = Mat::zeros(self.rows, b.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let aik = self[(i, k)];
                if aik == 0.0 {
                    continue;
                }
                let brow = b.row(k);
                let crow = c.row_mut(i);
                for j in 0..b.cols {
                    crow[j] += aik * brow[j];
                }
            }
        }
        c
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Largest absolute entry difference (test helper).
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    pub fn frobenius_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                if (self[(i, j)] - self[(j, i)]).abs() > tol {
                    return false;
                }
            }
        }
        true
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

/// Cholesky factorization `A = L Lᵀ` of a symmetric positive-definite
/// matrix; returns the lower-triangular factor. Panics on non-SPD input.
pub fn cholesky(a: &Mat) -> Mat {
    assert_eq!(a.rows, a.cols, "cholesky: matrix must be square");
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[(i, j)];
            for k in 0..j {
                sum -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                assert!(sum > 0.0, "cholesky: matrix not positive definite (pivot {i})");
                l[(i, j)] = sum.sqrt();
            } else {
                l[(i, j)] = sum / l[(j, j)];
            }
        }
    }
    l
}

/// Euclidean norm of a vector.
pub fn norm2(x: &[f64]) -> f64 {
    x.iter().map(|v| v * v).sum::<f64>().sqrt()
}

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// x ← x / ‖x‖; returns the norm. Zero vectors are left untouched.
pub fn normalize(x: &mut [f64]) -> f64 {
    let n = norm2(x);
    if n > 0.0 {
        for v in x.iter_mut() {
            *v /= n;
        }
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_known() {
        let a = Mat::from_rows(2, 3, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(a.matvec(&[1.0, 0.0, -1.0]), vec![-2.0, -2.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = Mat::from_fn(5, 5, |i, j| (i * 5 + j) as f64);
        let i5 = Mat::identity(5);
        assert_eq!(a.matmul(&i5), a);
        assert_eq!(i5.matmul(&a), a);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_rows(2, 2, &[5.0, 6.0, 7.0, 8.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Mat::from_fn(3, 7, |i, j| (i * 31 + j * 7) as f64);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn symmetry_check() {
        let s = Mat::from_fn(4, 4, |i, j| (i + j) as f64);
        assert!(s.is_symmetric(1e-12));
        let ns = Mat::from_fn(4, 4, |i, j| (i * 2 + j) as f64);
        assert!(!ns.is_symmetric(1e-12));
    }

    #[test]
    fn cholesky_roundtrip() {
        // SPD matrix: B Bᵀ + n I
        let n = 6;
        let b = Mat::from_fn(n, n, |i, j| ((i * 7 + j * 3) % 5) as f64 - 2.0);
        let mut a = b.matmul(&b.transpose());
        for i in 0..n {
            a[(i, i)] += n as f64;
        }
        let l = cholesky(&a);
        let rec = l.matmul(&l.transpose());
        assert!(rec.max_abs_diff(&a) < 1e-10);
        // strictly lower-triangular above diagonal
        for i in 0..n {
            for j in (i + 1)..n {
                assert_eq!(l[(i, j)], 0.0);
            }
        }
    }

    #[test]
    #[should_panic(expected = "not positive definite")]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_rows(2, 2, &[1.0, 2.0, 2.0, 1.0]);
        cholesky(&a);
    }

    #[test]
    fn normalize_unit() {
        let mut v = vec![3.0, 4.0];
        let n = normalize(&mut v);
        assert!((n - 5.0).abs() < 1e-12);
        assert!((norm2(&v) - 1.0).abs() < 1e-12);
    }
}
