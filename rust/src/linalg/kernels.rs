//! Explicit-width SIMD kernels for the central hot path, with runtime
//! dispatch — ROADMAP item 4(a).
//!
//! Five inner loops account for essentially all compute in the pipeline:
//!
//! * [`dot_f32`] — the f32 dot inside the O(n²d) affinity row build and
//!   the k-NN candidate distance scans (`spectral::{affinity, sparse}`);
//! * [`dot_f32_f64`] — the widened f32×f64 dot that *is* the dense
//!   `normalized_matvec`, Lanczos' entire inner loop;
//! * [`spmv_row_f64`] — the gathered CSR twin of the above
//!   (`SparseAffinity::normalized_matvec`);
//! * [`axpy_f32`] — the rank-1 score update of the K-means / landmark
//!   assignment sweep (`dml::{kmeans, sample}`);
//! * [`sqdist_f32`] — widened squared Euclidean distance (k-means++
//!   seeding, `dml::nearest_code`, streaming fold-in).
//!
//! Each kernel has two arms selected at runtime: an AVX2 `core::arch`
//! path (no FMA — see below) and a scalar fallback. The two arms are
//! **bit-identical by construction**, which is what lets the repo's
//! bit-parity discipline (`sparse_parity`, the crash/chaos twins, the
//! streaming result cache) survive vectorization:
//!
//! * the scalar arm uses the *same* 4-lane (f64) / 8-lane (f32)
//!   accumulator tree as the vector arm — lane `l` accumulates elements
//!   `l mod LANES`, exactly like a SIMD register does;
//! * the horizontal reduction mirrors the AVX2 shuffle sequence exactly
//!   (`(a₀+a₄)+(a₂+a₆)` then `(a₁+a₅)+(a₃+a₇)` for 8 lanes,
//!   `(a₀+a₂)+(a₁+a₃)` for 4) — *not* a left-to-right fold;
//! * every multiply is followed by a separate IEEE-754 add — **FMA is
//!   deliberately excluded**, because a fused multiply-add rounds once
//!   where `mul`+`add` rounds twice, and that single rounding difference
//!   would break scalar/SIMD bit parity;
//! * tails (length `mod` lane count) run serially after the reduced
//!   vector sum, in both arms, in the same order.
//!
//! `is_x86_feature_detected!` never selects an arm the CPU lacks; on
//! non-x86_64 targets the scalar arm is the only arm. `DSC_SIMD`
//! (`off`/`scalar` force the scalar arm, `auto`/`on` or unset detect)
//! pins dispatch process-wide for tests and benches, mirroring
//! `DSC_THREADS`; [`set_mode`] overrides it at runtime so the `hotpath`
//! bench can time both arms in one process.

use std::sync::atomic::{AtomicU8, Ordering};

/// Kernel dispatch policy (`DSC_SIMD`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdMode {
    /// Always run the scalar arm (the `DSC_SIMD=off|scalar` override).
    Scalar,
    /// Use the widest arm the CPU supports (AVX2 today), scalar otherwise.
    Auto,
}

/// 0 = unset (read `DSC_SIMD` lazily), 1 = scalar, 2 = auto.
static MODE: AtomicU8 = AtomicU8::new(0);

/// Parse a `DSC_SIMD` value. `None` for unrecognized strings (the
/// initializer falls back to [`SimdMode::Auto`], like `par::threads()`
/// ignores an unparseable `DSC_THREADS`).
pub fn parse_mode(s: &str) -> Option<SimdMode> {
    match s.to_ascii_lowercase().as_str() {
        "off" | "scalar" => Some(SimdMode::Scalar),
        "auto" | "on" => Some(SimdMode::Auto),
        _ => None,
    }
}

/// The dispatch mode in effect (env-initialized, [`set_mode`]-overridable).
pub fn mode() -> SimdMode {
    match MODE.load(Ordering::Relaxed) {
        1 => SimdMode::Scalar,
        2 => SimdMode::Auto,
        _ => {
            let m = std::env::var("DSC_SIMD")
                .ok()
                .and_then(|v| parse_mode(&v))
                .unwrap_or(SimdMode::Auto);
            set_mode(m);
            m
        }
    }
}

/// Override the dispatch mode process-wide. The `hotpath` bench uses this
/// to time the scalar and dispatched arms in one process; the parity
/// suite uses it to pin an end-to-end run to each arm.
pub fn set_mode(m: SimdMode) {
    MODE.store(
        match m {
            SimdMode::Scalar => 1,
            SimdMode::Auto => 2,
        },
        Ordering::Relaxed,
    );
}

/// Whether the AVX2 arm is selected right now.
#[inline]
fn use_avx2() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        mode() == SimdMode::Auto && is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Name of the arm dispatch resolves to right now (`"avx2"`/`"scalar"`).
pub fn active_arm() -> &'static str {
    if use_avx2() {
        "avx2"
    } else {
        "scalar"
    }
}

/// Comma-separated SIMD feature sets the CPU reports, independent of the
/// dispatch mode — recorded in `BENCH_hotpath.json` so a trajectory
/// snapshot names the hardware it was measured on. FMA is listed when
/// present even though the kernels never use it (bit-parity policy).
pub fn detected_features() -> String {
    #[cfg(target_arch = "x86_64")]
    {
        let mut feats = Vec::new();
        if is_x86_feature_detected!("sse2") {
            feats.push("sse2");
        }
        if is_x86_feature_detected!("avx") {
            feats.push("avx");
        }
        if is_x86_feature_detected!("avx2") {
            feats.push("avx2");
        }
        if is_x86_feature_detected!("fma") {
            feats.push("fma");
        }
        if is_x86_feature_detected!("avx512f") {
            feats.push("avx512f");
        }
        if feats.is_empty() {
            "none".into()
        } else {
            feats.join(",")
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        format!("non-x86_64 ({})", std::env::consts::ARCH)
    }
}

// ---------------------------------------------------------------------------
// Dispatched entry points
// ---------------------------------------------------------------------------

/// `Σ a[j]·b[j]` in f32 — the affinity-build / k-NN-scan dot.
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    if use_avx2() {
        // SAFETY: AVX2 support verified by `use_avx2`.
        return unsafe { avx2::dot_f32(a, b) };
    }
    scalar::dot_f32(a, b)
}

/// `Σ (a[j] as f64)·z[j]` — the dense normalized-matvec row dot.
#[inline]
pub fn dot_f32_f64(a: &[f32], z: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), z.len());
    #[cfg(target_arch = "x86_64")]
    if use_avx2() {
        // SAFETY: AVX2 support verified by `use_avx2`.
        return unsafe { avx2::dot_f32_f64(a, z) };
    }
    scalar::dot_f32_f64(a, z)
}

/// `Σ (vals[t] as f64)·z[cols[t]]` — one CSR row of the sparse
/// normalized matvec. Every `cols[t]` must index into `z`.
#[inline]
pub fn spmv_row_f64(vals: &[f32], cols: &[u32], z: &[f64]) -> f64 {
    debug_assert_eq!(vals.len(), cols.len());
    #[cfg(target_arch = "x86_64")]
    // The AVX2 gather sign-extends i32 indices, so it only covers vectors
    // the i32 index space can address — far beyond any codebook here, but
    // the scalar arm is the correct fallback rather than a debug assert.
    if use_avx2() && z.len() <= i32::MAX as usize {
        // SAFETY: AVX2 support verified by `use_avx2`; column bounds are
        // the caller's CSR invariant (checked below in debug builds).
        debug_assert!(cols.iter().all(|&c| (c as usize) < z.len()));
        return unsafe { avx2::spmv_row_f64(vals, cols, z) };
    }
    scalar::spmv_row_f64(vals, cols, z)
}

/// `out[c] += coef · row[c]` — the assignment sweep's rank-1 update.
/// Element-wise (no reduction), so any lane width is bit-identical; the
/// AVX2 arm exists purely for speed.
#[inline]
pub fn axpy_f32(out: &mut [f32], coef: f32, row: &[f32]) {
    debug_assert_eq!(out.len(), row.len());
    #[cfg(target_arch = "x86_64")]
    if use_avx2() {
        // SAFETY: AVX2 support verified by `use_avx2`.
        unsafe { avx2::axpy_f32(out, coef, row) };
        return;
    }
    scalar::axpy_f32(out, coef, row);
}

/// `Σ ((a[j] − b[j]) as f64)²` — squared Euclidean distance with the
/// subtraction in f32 and the squaring/accumulation widened to f64,
/// exactly the arithmetic the dml callers have always used (the f64
/// square of an f32 value is exact — ≤ 48 mantissa bits — so only the
/// accumulation order distinguishes implementations).
#[inline]
pub fn sqdist_f32(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    if use_avx2() {
        // SAFETY: AVX2 support verified by `use_avx2`.
        return unsafe { avx2::sqdist_f32(a, b) };
    }
    scalar::sqdist_f32(a, b)
}

// ---------------------------------------------------------------------------
// Scalar arm — the lane-structured reference every vector arm must equal
// bit for bit. The 4/8-lane accumulator arrays and the shuffle-mirroring
// reductions below are the contract; do not "simplify" them into serial
// folds.
// ---------------------------------------------------------------------------

pub mod scalar {
    /// Reduce an 8-lane f32 accumulator exactly like the AVX2 sequence
    /// `add(lo128, hi128)` → `add(q, movehl(q))` → `add_ss(d, shuffle(d, 1))`.
    #[inline]
    fn reduce8(acc: [f32; 8]) -> f32 {
        let q = [acc[0] + acc[4], acc[1] + acc[5], acc[2] + acc[6], acc[3] + acc[7]];
        let d = [q[0] + q[2], q[1] + q[3]];
        d[0] + d[1]
    }

    /// Reduce a 4-lane f64 accumulator exactly like the AVX2 sequence
    /// `add(lo128, hi128)` → `add_sd(q, unpackhi(q))`.
    #[inline]
    fn reduce4(acc: [f64; 4]) -> f64 {
        (acc[0] + acc[2]) + (acc[1] + acc[3])
    }

    /// See [`super::dot_f32`].
    pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let chunks = n / 8;
        let mut acc = [0.0f32; 8];
        for c in 0..chunks {
            let ra = &a[c * 8..c * 8 + 8];
            let rb = &b[c * 8..c * 8 + 8];
            for l in 0..8 {
                acc[l] += ra[l] * rb[l];
            }
        }
        let mut sum = reduce8(acc);
        for j in chunks * 8..n {
            sum += a[j] * b[j];
        }
        sum
    }

    /// See [`super::dot_f32_f64`].
    pub fn dot_f32_f64(a: &[f32], z: &[f64]) -> f64 {
        let n = a.len();
        let chunks = n / 4;
        let mut acc = [0.0f64; 4];
        for c in 0..chunks {
            let ra = &a[c * 4..c * 4 + 4];
            let rz = &z[c * 4..c * 4 + 4];
            for l in 0..4 {
                acc[l] += ra[l] as f64 * rz[l];
            }
        }
        let mut sum = reduce4(acc);
        for j in chunks * 4..n {
            sum += a[j] as f64 * z[j];
        }
        sum
    }

    /// See [`super::spmv_row_f64`].
    pub fn spmv_row_f64(vals: &[f32], cols: &[u32], z: &[f64]) -> f64 {
        let n = vals.len();
        let chunks = n / 4;
        let mut acc = [0.0f64; 4];
        for c in 0..chunks {
            for l in 0..4 {
                let t = c * 4 + l;
                acc[l] += vals[t] as f64 * z[cols[t] as usize];
            }
        }
        let mut sum = reduce4(acc);
        for t in chunks * 4..n {
            sum += vals[t] as f64 * z[cols[t] as usize];
        }
        sum
    }

    /// See [`super::axpy_f32`].
    pub fn axpy_f32(out: &mut [f32], coef: f32, row: &[f32]) {
        for (o, &r) in out.iter_mut().zip(row) {
            *o += coef * r;
        }
    }

    /// See [`super::sqdist_f32`].
    pub fn sqdist_f32(a: &[f32], b: &[f32]) -> f64 {
        let n = a.len();
        let chunks = n / 4;
        let mut acc = [0.0f64; 4];
        for c in 0..chunks {
            let ra = &a[c * 4..c * 4 + 4];
            let rb = &b[c * 4..c * 4 + 4];
            for l in 0..4 {
                let d = (ra[l] - rb[l]) as f64; // f32 sub, like the callers always did
                acc[l] += d * d;
            }
        }
        let mut sum = reduce4(acc);
        for j in chunks * 4..n {
            let d = (a[j] - b[j]) as f64;
            sum += d * d;
        }
        sum
    }
}

// ---------------------------------------------------------------------------
// AVX2 arm. Unaligned loads throughout (`loadu`); the f32→f64 widening
// (`cvtps_pd`) is exact, so the only rounding ops are the same mul/add
// pairs the scalar arm performs, lane for lane.
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    /// Horizontal sum of 8 f32 lanes; the scalar `reduce8` mirrors this
    /// exact shuffle sequence.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn reduce8(acc: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(acc);
        let hi = _mm256_extractf128_ps::<1>(acc);
        let q = _mm_add_ps(lo, hi); // [a0+a4, a1+a5, a2+a6, a3+a7]
        let d = _mm_add_ps(q, _mm_movehl_ps(q, q)); // [q0+q2, q1+q3, ..]
        let r = _mm_add_ss(d, _mm_shuffle_ps::<0b01>(d, d)); // d0+d1
        _mm_cvtss_f32(r)
    }

    /// Horizontal sum of 4 f64 lanes; the scalar `reduce4` mirrors this.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn reduce4(acc: __m256d) -> f64 {
        let lo = _mm256_castpd256_pd128(acc);
        let hi = _mm256_extractf128_pd::<1>(acc);
        let q = _mm_add_pd(lo, hi); // [a0+a2, a1+a3]
        let r = _mm_add_sd(q, _mm_unpackhi_pd(q, q)); // q0+q1
        _mm_cvtsd_f64(r)
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let chunks = n / 8;
        let mut acc = _mm256_setzero_ps();
        for c in 0..chunks {
            let va = _mm256_loadu_ps(a.as_ptr().add(c * 8));
            let vb = _mm256_loadu_ps(b.as_ptr().add(c * 8));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vb)); // no FMA
        }
        let mut sum = reduce8(acc);
        for j in chunks * 8..n {
            sum += a[j] * b[j];
        }
        sum
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_f32_f64(a: &[f32], z: &[f64]) -> f64 {
        let n = a.len();
        let chunks = n / 4;
        let mut acc = _mm256_setzero_pd();
        for c in 0..chunks {
            let va = _mm256_cvtps_pd(_mm_loadu_ps(a.as_ptr().add(c * 4)));
            let vz = _mm256_loadu_pd(z.as_ptr().add(c * 4));
            acc = _mm256_add_pd(acc, _mm256_mul_pd(va, vz)); // no FMA
        }
        let mut sum = reduce4(acc);
        for j in chunks * 4..n {
            sum += a[j] as f64 * z[j];
        }
        sum
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn spmv_row_f64(vals: &[f32], cols: &[u32], z: &[f64]) -> f64 {
        let n = vals.len();
        let chunks = n / 4;
        let mut acc = _mm256_setzero_pd();
        for c in 0..chunks {
            let vv = _mm256_cvtps_pd(_mm_loadu_ps(vals.as_ptr().add(c * 4)));
            let vidx = _mm_loadu_si128(cols.as_ptr().add(c * 4) as *const __m128i);
            let vz = _mm256_i32gather_pd::<8>(z.as_ptr(), vidx);
            acc = _mm256_add_pd(acc, _mm256_mul_pd(vv, vz)); // no FMA
        }
        let mut sum = reduce4(acc);
        for t in chunks * 4..n {
            sum += vals[t] as f64 * z[cols[t] as usize];
        }
        sum
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_f32(out: &mut [f32], coef: f32, row: &[f32]) {
        let n = out.len();
        let chunks = n / 8;
        let vc = _mm256_set1_ps(coef);
        for c in 0..chunks {
            let vo = _mm256_loadu_ps(out.as_ptr().add(c * 8));
            let vr = _mm256_loadu_ps(row.as_ptr().add(c * 8));
            let upd = _mm256_add_ps(vo, _mm256_mul_ps(vc, vr)); // no FMA
            _mm256_storeu_ps(out.as_mut_ptr().add(c * 8), upd);
        }
        for j in chunks * 8..n {
            out[j] += coef * row[j];
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn sqdist_f32(a: &[f32], b: &[f32]) -> f64 {
        let n = a.len();
        let chunks = n / 4;
        let mut acc = _mm256_setzero_pd();
        for c in 0..chunks {
            let va = _mm_loadu_ps(a.as_ptr().add(c * 4));
            let vb = _mm_loadu_ps(b.as_ptr().add(c * 4));
            // subtract in f32 first (caller semantics), then widen exactly
            let d = _mm256_cvtps_pd(_mm_sub_ps(va, vb));
            acc = _mm256_add_pd(acc, _mm256_mul_pd(d, d)); // no FMA
        }
        let mut sum = reduce4(acc);
        for j in chunks * 4..n {
            let d = (a[j] - b[j]) as f64;
            sum += d * d;
        }
        sum
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic, sign-varied, non-trivially-rounding test vector.
    fn pat(len: usize, salt: u32) -> Vec<f32> {
        (0..len)
            .map(|i| {
                let h = (i as u32).wrapping_mul(2_654_435_761).wrapping_add(salt);
                ((h % 2000) as f32 - 1000.0) / 97.0
            })
            .collect()
    }

    #[test]
    fn parse_mode_values() {
        assert_eq!(parse_mode("off"), Some(SimdMode::Scalar));
        assert_eq!(parse_mode("scalar"), Some(SimdMode::Scalar));
        assert_eq!(parse_mode("SCALAR"), Some(SimdMode::Scalar));
        assert_eq!(parse_mode("auto"), Some(SimdMode::Auto));
        assert_eq!(parse_mode("on"), Some(SimdMode::Auto));
        assert_eq!(parse_mode("avx999"), None);
    }

    #[test]
    fn active_arm_is_consistent_with_mode() {
        // only observe; other tests in this binary may run concurrently,
        // so don't flip the global mode here (the hotpath bench and the
        // simd_kernels integration suite own that).
        let arm = active_arm();
        assert!(arm == "avx2" || arm == "scalar", "{arm}");
        if mode() == SimdMode::Scalar {
            assert_eq!(arm, "scalar");
        }
    }

    #[test]
    fn detected_features_nonempty() {
        let f = detected_features();
        assert!(!f.is_empty());
    }

    #[test]
    fn scalar_dot_matches_serial_reference() {
        for len in [0usize, 1, 3, 7, 8, 9, 31, 64, 67] {
            let a = pat(len, 1);
            let b = pat(len, 2);
            let serial: f64 = a.iter().zip(&b).map(|(x, y)| (*x as f64) * (*y as f64)).sum();
            let got = scalar::dot_f32(&a, &b) as f64;
            let tol = 1e-4 * serial.abs().max(1.0);
            assert!((got - serial).abs() < tol, "len {len}: {got} vs {serial}");
        }
    }

    #[test]
    fn scalar_reduction_tree_is_pinned() {
        // 8 lanes of exactly one element each: the reduce must be
        // ((a0+a4)+(a2+a6)) + ((a1+a5)+(a3+a7)) — the AVX2 shuffle order —
        // pinned here so a "cleanup" to a serial fold fails loudly.
        let a: Vec<f32> = (0..8).map(|i| (10f32).powi(i - 4)).collect();
        let b = vec![1.0f32; 8];
        let lanes: Vec<f32> = a.clone();
        let want = ((lanes[0] + lanes[4]) + (lanes[2] + lanes[6]))
            + ((lanes[1] + lanes[5]) + (lanes[3] + lanes[7]));
        assert_eq!(scalar::dot_f32(&a, &b).to_bits(), want.to_bits());

        // 4-lane f64 twin: (a0+a2) + (a1+a3)
        let z = vec![1.0f64; 4];
        let a4: Vec<f32> = (0..4).map(|i| (10f32).powi(i * 3 - 5)).collect();
        let want4 = ((a4[0] as f64 + a4[2] as f64)) + ((a4[1] as f64 + a4[3] as f64));
        assert_eq!(scalar::dot_f32_f64(&a4, &z).to_bits(), want4.to_bits());
    }

    #[test]
    fn dispatched_equals_scalar_bitwise() {
        // Whatever arm dispatch resolves to (AVX2 on a capable CPU in auto
        // mode, scalar otherwise), it must equal the scalar arm bit for
        // bit. The full 0..=67 sweep lives in rust/tests/simd_kernels.rs.
        for len in [0usize, 5, 8, 16, 33, 67] {
            let a = pat(len, 3);
            let b = pat(len, 4);
            let z: Vec<f64> = pat(len, 5).iter().map(|&v| v as f64).collect();
            assert_eq!(dot_f32(&a, &b).to_bits(), scalar::dot_f32(&a, &b).to_bits());
            assert_eq!(dot_f32_f64(&a, &z).to_bits(), scalar::dot_f32_f64(&a, &z).to_bits());
            assert_eq!(sqdist_f32(&a, &b).to_bits(), scalar::sqdist_f32(&a, &b).to_bits());
            let cols: Vec<u32> =
                (0..len).map(|i| ((i * 13 + 5) % len.max(1)) as u32).collect();
            let zbig: Vec<f64> = pat(len.max(1), 6).iter().map(|&v| v as f64).collect();
            assert_eq!(
                spmv_row_f64(&a, &cols, &zbig).to_bits(),
                scalar::spmv_row_f64(&a, &cols, &zbig).to_bits()
            );
            let mut o1 = pat(len, 7);
            let mut o2 = o1.clone();
            axpy_f32(&mut o1, -1.75, &b);
            scalar::axpy_f32(&mut o2, -1.75, &b);
            assert_eq!(
                o1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                o2.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn axpy_matches_by_element() {
        let row = pat(19, 8);
        let mut out = pat(19, 9);
        let before = out.clone();
        scalar::axpy_f32(&mut out, 0.5, &row);
        for i in 0..19 {
            assert_eq!(out[i].to_bits(), (before[i] + 0.5 * row[i]).to_bits());
        }
    }

    #[test]
    fn sqdist_is_zero_on_identical_inputs() {
        let a = pat(41, 10);
        assert_eq!(sqdist_f32(&a, &a), 0.0);
        assert_eq!(scalar::sqdist_f32(&a, &a), 0.0);
    }
}
