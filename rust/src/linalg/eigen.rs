//! Symmetric eigensolvers: dense (tred2 + tql2) and Krylov (Lanczos).
//!
//! `sym_eig` is the classic EISPACK pair — Householder reduction to
//! tridiagonal form followed by the implicit-QL algorithm with Wilkinson
//! shifts — ported to safe Rust. It is O(n³) and rock-solid; the pipeline
//! uses it for Ritz problems and as the reference in tests.
//!
//! `lanczos_topk` computes the largest eigenpairs of a symmetric operator
//! given only a mat-vec closure, with *full* reorthogonalization (the
//! codebook problems are ≤ a few thousand dims, so the O(m²n) reorth cost
//! is irrelevant next to the matvec and buys unconditional numerical
//! stability — no ghost eigenvalues).

use super::{dot, norm2, normalize, Mat, SymOp};
use crate::rng::Rng;

/// Full eigendecomposition of a symmetric matrix.
///
/// Returns `(evals, evecs)` with eigenvalues **ascending** and `evecs`
/// column `k` (i.e. `evecs[(i, k)]`) the unit eigenvector for `evals[k]`.
///
/// Panics if `a` is not square; symmetry is the caller's contract (only the
/// lower triangle is referenced during reduction).
pub fn sym_eig(a: &Mat) -> (Vec<f64>, Mat) {
    assert_eq!(a.rows, a.cols, "sym_eig: matrix must be square");
    let n = a.rows;
    if n == 0 {
        return (vec![], Mat::zeros(0, 0));
    }
    let mut z = a.clone();
    let mut d = vec![0.0; n];
    let mut e = vec![0.0; n];
    tred2(&mut z, &mut d, &mut e);
    tql2(&mut d, &mut e, &mut z);
    // sort ascending, permuting eigenvector columns with the values
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| d[i].partial_cmp(&d[j]).unwrap());
    let evals: Vec<f64> = order.iter().map(|&i| d[i]).collect();
    let mut v = Mat::zeros(n, n);
    for (newc, &oldc) in order.iter().enumerate() {
        for r in 0..n {
            v[(r, newc)] = z[(r, oldc)];
        }
    }
    (evals, v)
}

/// Householder reduction of a real symmetric matrix to tridiagonal form
/// (Numerical Recipes tred2, with eigenvector accumulation).
/// On exit: `d` holds the diagonal, `e[1..]` the subdiagonal, and `z` the
/// accumulated orthogonal transform Q with A = Q T Qᵀ.
fn tred2(z: &mut Mat, d: &mut [f64], e: &mut [f64]) {
    let n = z.rows;
    for i in (1..n).rev() {
        let l = i - 1;
        let mut h = 0.0;
        if l > 0 {
            let mut scale = 0.0;
            for k in 0..=l {
                scale += z[(i, k)].abs();
            }
            if scale == 0.0 {
                e[i] = z[(i, l)];
            } else {
                for k in 0..=l {
                    z[(i, k)] /= scale;
                    h += z[(i, k)] * z[(i, k)];
                }
                let mut f = z[(i, l)];
                let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                e[i] = scale * g;
                h -= f * g;
                z[(i, l)] = f - g;
                f = 0.0;
                for j in 0..=l {
                    z[(j, i)] = z[(i, j)] / h;
                    let mut g = 0.0;
                    for k in 0..=j {
                        g += z[(j, k)] * z[(i, k)];
                    }
                    for k in (j + 1)..=l {
                        g += z[(k, j)] * z[(i, k)];
                    }
                    e[j] = g / h;
                    f += e[j] * z[(i, j)];
                }
                let hh = f / (h + h);
                for j in 0..=l {
                    let f = z[(i, j)];
                    let g = e[j] - hh * f;
                    e[j] = g;
                    for k in 0..=j {
                        let upd = f * e[k] + g * z[(i, k)];
                        z[(j, k)] -= upd;
                    }
                }
            }
        } else {
            e[i] = z[(i, l)];
        }
        d[i] = h;
    }
    d[0] = 0.0;
    e[0] = 0.0;
    for i in 0..n {
        if d[i] != 0.0 {
            for j in 0..i {
                let mut g = 0.0;
                for k in 0..i {
                    g += z[(i, k)] * z[(k, j)];
                }
                for k in 0..i {
                    let upd = g * z[(k, i)];
                    z[(k, j)] -= upd;
                }
            }
        }
        d[i] = z[(i, i)];
        z[(i, i)] = 1.0;
        for j in 0..i {
            z[(j, i)] = 0.0;
            z[(i, j)] = 0.0;
        }
    }
}

/// Implicit-QL with shifts on a symmetric tridiagonal matrix, accumulating
/// the transform into `z` (Numerical Recipes tql2).
fn tql2(d: &mut [f64], e: &mut [f64], z: &mut Mat) {
    let n = d.len();
    if n <= 1 {
        return;
    }
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;

    for l in 0..n {
        let mut iter = 0;
        loop {
            // find small subdiagonal element
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            assert!(iter <= 50, "tql2: too many iterations (pathological input?)");
            // Wilkinson shift
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = g.hypot(1.0);
            let sign_r = if g >= 0.0 { r.abs() } else { -r.abs() };
            g = d[m] - d[l] + e[l] / (g + sign_r);
            let (mut s, mut c) = (1.0, 1.0);
            let mut p = 0.0;
            for i in (l..m).rev() {
                let mut f = s * e[i];
                let b = c * e[i];
                r = f.hypot(g);
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                // accumulate transform
                for k in 0..n {
                    f = z[(k, i + 1)];
                    z[(k, i + 1)] = s * z[(k, i)] + c * f;
                    z[(k, i)] = c * z[(k, i)] - s * f;
                }
            }
            if r == 0.0 && m > l {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }
}

/// Eigendecomposition of a symmetric tridiagonal matrix given its diagonal
/// and subdiagonal (`off.len() == diag.len() - 1`). Ascending eigenvalues.
pub fn tridiag_eig(diag: &[f64], off: &[f64]) -> (Vec<f64>, Mat) {
    let n = diag.len();
    assert!(off.len() + 1 == n || (n == 0 && off.is_empty()));
    let mut d = diag.to_vec();
    let mut e = vec![0.0; n];
    e[1..].copy_from_slice(off);
    // tql2 expects e[i] as subdiag below d[i-1]... it shifts internally.
    let mut z = Mat::identity(n);
    tql2(&mut d, &mut e, &mut z);
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| d[i].partial_cmp(&d[j]).unwrap());
    let evals: Vec<f64> = order.iter().map(|&i| d[i]).collect();
    let mut v = Mat::zeros(n, n);
    for (newc, &oldc) in order.iter().enumerate() {
        for r in 0..n {
            v[(r, newc)] = z[(r, oldc)];
        }
    }
    (evals, v)
}

/// Largest `k` eigenpairs of a symmetric operator via Lanczos with full
/// reorthogonalization.
///
/// * `n` — operator dimension;
/// * `matvec(x, y)` — writes `A x` into `y`;
/// * `k` — number of pairs wanted;
/// * `max_iters` — Krylov dimension cap (clamped to `n`);
/// * `tol` — residual tolerance on the Ritz pairs for early exit.
///
/// Returns `(evals, vecs)` with eigenvalues **descending**; `vecs[j]` is the
/// unit Ritz vector for `evals[j]`.
pub fn lanczos_topk(
    n: usize,
    mut matvec: impl FnMut(&[f64], &mut [f64]),
    k: usize,
    max_iters: usize,
    tol: f64,
    rng: &mut Rng,
) -> (Vec<f64>, Vec<Vec<f64>>) {
    assert!(k >= 1 && n >= 1);
    let k = k.min(n);
    let m_cap = max_iters.max(k + 2).min(n);

    // Krylov basis (full reorthogonalization keeps it orthonormal).
    let mut basis: Vec<Vec<f64>> = Vec::with_capacity(m_cap);
    let mut alphas: Vec<f64> = Vec::with_capacity(m_cap);
    let mut betas: Vec<f64> = Vec::with_capacity(m_cap);

    let mut q = vec![0.0; n];
    for v in q.iter_mut() {
        *v = rng.normal();
    }
    normalize(&mut q);
    basis.push(q);

    let mut w = vec![0.0; n];
    let mut m = 0usize;
    while m < m_cap {
        let qm = basis[m].clone();
        matvec(&qm, &mut w);
        let alpha = dot(&qm, &w);
        alphas.push(alpha);
        // w ← w − α qm − β q_{m−1}, then full reorth (twice is enough)
        for _pass in 0..2 {
            for qb in &basis {
                let c = dot(qb, &w);
                for i in 0..n {
                    w[i] -= c * qb[i];
                }
            }
        }
        let beta = norm2(&w);
        m += 1;
        if m >= m_cap {
            break;
        }
        if beta < 1e-12 {
            // invariant subspace found — restart with a fresh random vector
            let mut fresh = vec![0.0; n];
            for v in fresh.iter_mut() {
                *v = rng.normal();
            }
            for _pass in 0..2 {
                for qb in &basis {
                    let c = dot(qb, &fresh);
                    for i in 0..n {
                        fresh[i] -= c * qb[i];
                    }
                }
            }
            if normalize(&mut fresh) < 1e-12 {
                break; // space exhausted
            }
            betas.push(0.0);
            basis.push(fresh);
            continue;
        }
        betas.push(beta);
        let mut next = w.clone();
        for v in next.iter_mut() {
            *v /= beta;
        }
        basis.push(next);

        // convergence check every few steps once we have k Ritz pairs
        if m >= k + 2 && m % 4 == 0 {
            let (tev, _tv) = tridiag_eig(&alphas, &betas[..m - 1]);
            let beta_last = *betas.last().unwrap();
            // crude residual bound: β_m · |last component of Ritz vector|
            // cheap proxy: if β is already tiny relative to the spectrum span
            let span = tev.last().unwrap() - tev.first().unwrap();
            if beta_last <= tol * span.max(1e-30) {
                break;
            }
        }
    }

    let m = alphas.len();
    let (tev, tv) = tridiag_eig(&alphas, &betas[..m.saturating_sub(1)]);
    // top-k Ritz pairs (tridiag_eig returns ascending)
    let mut evals = Vec::with_capacity(k);
    let mut vecs = Vec::with_capacity(k);
    for j in 0..k.min(m) {
        let col = m - 1 - j; // descending
        evals.push(tev[col]);
        let mut v = vec![0.0; n];
        for (r, qb) in basis.iter().take(m).enumerate() {
            let c = tv[(r, col)];
            if c != 0.0 {
                for i in 0..n {
                    v[i] += c * qb[i];
                }
            }
        }
        normalize(&mut v);
        vecs.push(v);
    }
    (evals, vecs)
}

/// [`lanczos_topk`] over a [`SymOp`] — the entry point the spectral layer
/// uses so the dense and sparse graph operators run through one solver.
pub fn lanczos_topk_op<A: SymOp + ?Sized>(
    op: &A,
    k: usize,
    max_iters: usize,
    tol: f64,
    rng: &mut Rng,
) -> (Vec<f64>, Vec<Vec<f64>>) {
    lanczos_topk(op.dim(), |x, y| op.apply(x, y), k, max_iters, tol, rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn random_sym(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let v = rng.normal();
                a[(i, j)] = v;
                a[(j, i)] = v;
            }
        }
        a
    }

    fn check_decomposition(a: &Mat, evals: &[f64], v: &Mat, tol: f64) {
        let n = a.rows;
        // A V = V Λ
        for k in 0..n {
            let col: Vec<f64> = (0..n).map(|i| v[(i, k)]).collect();
            let av = a.matvec(&col);
            for i in 0..n {
                assert!(
                    (av[i] - evals[k] * col[i]).abs() < tol,
                    "residual too big at ({i},{k}): {} vs {}",
                    av[i],
                    evals[k] * col[i]
                );
            }
        }
        // V orthonormal
        let vtv = v.transpose().matmul(v);
        assert!(vtv.max_abs_diff(&Mat::identity(n)) < tol, "V not orthonormal");
    }

    #[test]
    fn sym_eig_2x2_known() {
        let a = Mat::from_rows(2, 2, &[2.0, 1.0, 1.0, 2.0]);
        let (evals, v) = sym_eig(&a);
        assert!((evals[0] - 1.0).abs() < 1e-12);
        assert!((evals[1] - 3.0).abs() < 1e-12);
        check_decomposition(&a, &evals, &v, 1e-10);
    }

    #[test]
    fn sym_eig_diagonal() {
        let a = Mat::from_fn(5, 5, |i, j| if i == j { (i as f64) - 2.0 } else { 0.0 });
        let (evals, v) = sym_eig(&a);
        let want = [-2.0, -1.0, 0.0, 1.0, 2.0];
        for (g, w) in evals.iter().zip(want) {
            assert!((g - w).abs() < 1e-12);
        }
        check_decomposition(&a, &evals, &v, 1e-10);
    }

    #[test]
    fn sym_eig_random_sizes() {
        for (n, seed) in [(3, 1u64), (8, 2), (17, 3), (40, 4), (83, 5)] {
            let a = random_sym(n, seed);
            let (evals, v) = sym_eig(&a);
            // ascending
            for w in evals.windows(2) {
                assert!(w[0] <= w[1] + 1e-12);
            }
            check_decomposition(&a, &evals, &v, 1e-8 * (n as f64));
        }
    }

    #[test]
    fn sym_eig_trace_preserved() {
        let a = random_sym(30, 9);
        let (evals, _) = sym_eig(&a);
        let trace: f64 = (0..30).map(|i| a[(i, i)]).sum();
        let sum: f64 = evals.iter().sum();
        assert!((trace - sum).abs() < 1e-8);
    }

    #[test]
    fn tridiag_eig_matches_dense() {
        let n = 12;
        let mut rng = Rng::new(21);
        let diag: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let off: Vec<f64> = (0..n - 1).map(|_| rng.normal()).collect();
        let a = Mat::from_fn(n, n, |i, j| {
            if i == j {
                diag[i]
            } else if i + 1 == j || j + 1 == i {
                off[i.min(j)]
            } else {
                0.0
            }
        });
        let (tev, tv) = tridiag_eig(&diag, &off);
        let (dev, _) = sym_eig(&a);
        for (t, d) in tev.iter().zip(&dev) {
            assert!((t - d).abs() < 1e-9, "{t} vs {d}");
        }
        check_decomposition(&a, &tev, &tv, 1e-8);
    }

    #[test]
    fn lanczos_matches_dense_topk() {
        let n = 60;
        let a = {
            // positive-definite-ish with a clear top cluster
            let r = random_sym(n, 31);
            let mut m = Mat::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    m[(i, j)] = r[(i, j)] / (n as f64);
                }
                m[(i, i)] += 1.0 + (i as f64) / (n as f64);
            }
            m
        };
        let (dense_ev, _) = sym_eig(&a);
        let mut rng = Rng::new(77);
        let (lev, lv) = lanczos_topk(n, |x, y| y.copy_from_slice(&a.matvec(x)), 4, 60, 1e-12, &mut rng);
        for j in 0..4 {
            let want = dense_ev[n - 1 - j];
            assert!((lev[j] - want).abs() < 1e-7, "eval {j}: {} vs {want}", lev[j]);
            let av = a.matvec(&lv[j]);
            for i in 0..n {
                assert!((av[i] - lev[j] * lv[j][i]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn lanczos_op_entry_point_matches_closure_form() {
        struct MatOp(Mat);
        impl SymOp for MatOp {
            fn dim(&self) -> usize {
                self.0.rows
            }
            fn apply(&self, x: &[f64], y: &mut [f64]) {
                y.copy_from_slice(&self.0.matvec(x));
            }
        }
        let a = random_sym(24, 41);
        let op = MatOp(a.clone());
        let mut r1 = Rng::new(43);
        let mut r2 = Rng::new(43);
        let (ev_op, _) = lanczos_topk_op(&op, 3, 24, 1e-12, &mut r1);
        let (ev_cl, _) =
            lanczos_topk(24, |x, y| y.copy_from_slice(&a.matvec(x)), 3, 24, 1e-12, &mut r2);
        for (a, b) in ev_op.iter().zip(&ev_cl) {
            assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        }
    }

    #[test]
    fn lanczos_handles_degenerate_operator() {
        // rank-1 operator: only one nonzero eigenvalue
        let n = 20;
        let u: Vec<f64> = (0..n).map(|i| ((i + 1) as f64).sqrt()).collect();
        let nn = dot(&u, &u);
        let mut rng = Rng::new(5);
        let (ev, _vecs) = lanczos_topk(
            n,
            |x, y| {
                let c = dot(&u, x);
                for i in 0..n {
                    y[i] = c * u[i];
                }
            },
            3,
            20,
            1e-12,
            &mut rng,
        );
        assert!((ev[0] - nn).abs() < 1e-7);
        assert!(ev[1].abs() < 1e-7);
    }
}
