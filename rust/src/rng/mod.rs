//! Deterministic PRNG substrate (the offline stand-in for the `rand` crate).
//!
//! [`Rng`] is xoshiro256** seeded through SplitMix64 — fast, well-tested
//! statistically, and trivially reproducible across the whole pipeline:
//! every experiment in `EXPERIMENTS.md` is keyed by a single `u64` seed.
//!
//! Provided distributions / utilities: uniform `f64`/`f32`/ranges, standard
//! normal (Box–Muller with spare caching), integer ranges without modulo
//! bias (Lemire), Fisher–Yates shuffle, Floyd's sampling without
//! replacement, and stream splitting ([`Rng::fork`]) so parallel sites get
//! decorrelated but reproducible streams.

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second output of the last Box–Muller draw
    spare_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator; any `u64` (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream for subtask `idx` (e.g. one per site).
    /// Deterministic in `(self state, idx)` without advancing `self`.
    pub fn fork(&self, idx: u64) -> Rng {
        let mix = self.s[0]
            ^ self.s[1].rotate_left(17)
            ^ self.s[2].rotate_left(29)
            ^ self.s[3].rotate_left(43);
        Rng::new(mix ^ (idx.wrapping_mul(0xA24BAED4963EE407)).wrapping_add(0x9FB21C651E98DF25))
    }

    /// Next raw 64 bits (xoshiro256**).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as `f32`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize index in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Standard normal via Box–Muller (caches the second draw).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // u1 in (0,1] to keep ln finite
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.spare_normal = Some(r * s);
        r * c
    }

    /// Normal with mean/σ as `f32` (the pipeline's storage type).
    #[inline]
    pub fn normal_f32(&mut self, mean: f32, sd: f32) -> f32 {
        mean + sd * self.normal() as f32
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// `k` distinct indices from `[0, n)` (Floyd's algorithm), ascending
    /// order not guaranteed.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.index(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }

    /// Draw from a discrete distribution given cumulative weights.
    /// `cum` must be non-decreasing with a positive final value.
    pub fn discrete_cum(&mut self, cum: &[f64]) -> usize {
        let total = *cum.last().expect("empty cum weights");
        let u = self.f64() * total;
        match cum.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => (i + 1).min(cum.len() - 1),
            Err(i) => i.min(cum.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn fork_streams_decorrelated_and_stable() {
        let root = Rng::new(7);
        let mut f0 = root.fork(0);
        let mut f1 = root.fork(1);
        let mut f0b = root.fork(0);
        assert_eq!(f0.next_u64(), f0b.next_u64());
        assert_ne!(f0.next_u64(), f1.next_u64());
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for c in counts {
            assert!((c as i64 - 10_000).abs() < 800, "count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..1000).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..1000).collect::<Vec<_>>());
        assert_ne!(v, (0..1000).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct_in_range() {
        let mut r = Rng::new(13);
        for _ in 0..50 {
            let ks = r.sample_indices(100, 10);
            assert_eq!(ks.len(), 10);
            let set: std::collections::HashSet<_> = ks.iter().collect();
            assert_eq!(set.len(), 10);
            assert!(ks.iter().all(|&i| i < 100));
        }
    }

    #[test]
    fn discrete_cum_respects_weights() {
        let mut r = Rng::new(17);
        let cum = [1.0, 1.0, 4.0]; // weights 1, 0, 3
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.discrete_cum(&cum)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!((counts[0] as f64 / 40_000.0 - 0.25).abs() < 0.02);
        assert!((counts[2] as f64 / 40_000.0 - 0.75).abs() < 0.02);
    }
}
