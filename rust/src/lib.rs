//! # dsc — Distributed Spectral Clustering
//!
//! A production-oriented reproduction of *"Fast Communication-efficient
//! Spectral Clustering Over Distributed Data"* (Yan, Wang, Wang, Wu, Wang —
//! IEEE Transactions on Big Data, 2019).
//!
//! The paper's framework clusters data that lives on `S` distributed sites
//! without moving the raw data:
//!
//! 1. every site compresses its local data into *codewords* with a
//!    distortion-minimizing local (DML) transform — K-means or rpTrees
//!    ([`dml`]);
//! 2. a leader collects the codewords (the only communication, accounted by
//!    [`net`]) and runs normalized-cuts spectral clustering on their union
//!    ([`spectral`] — over the paper's dense affinity or, for large
//!    codebooks, the sparse k-NN graph in [`spectral::sparse`]; optionally
//!    executing the eigensolver as an AOT-compiled XLA program through
//!    [`runtime`]);
//! 3. codeword labels are populated back so each site recovers the label of
//!    every original point ([`coordinator`] drives the leader half, [`site`]
//!    the worker half — over in-process channels by default, or over real
//!    TCP between `dsc leader` / `dsc site` daemon processes; a long-lived
//!    `dsc leader --serve` job server pipelines many client-submitted runs
//!    over persistent site sessions, the "heavy traffic" serving mode
//!    ([`coordinator::server`])).
//!
//! The crate is the Layer-3 coordinator of a three-layer Rust + JAX + Pallas
//! stack: the Gaussian-affinity and k-means-assignment hot spots are Pallas
//! kernels (Layer 1), the spectral-embedding / Lloyd-step compute graphs are
//! JAX programs (Layer 2), AOT-lowered to HLO text in `artifacts/` and
//! executed from Rust via PJRT. Python never runs on the request path.
//!
//! ## Quick start
//!
//! ```no_run
//! use dsc::prelude::*;
//!
//! // 40k points from a 4-component Gaussian mixture, split across 2 sites.
//! let ds = dsc::data::gmm::paper_mixture_10d(40_000, 0.3, 7);
//! let parts = dsc::data::scenario::split(&ds, Scenario::D3, 2, 7);
//! let cfg = PipelineConfig::default();
//! let report = run_pipeline(&parts, &cfg).unwrap();
//! println!("accuracy = {:.4}", report.accuracy);
//! ```
//!
//! ## Features and offline builds
//!
//! The default build is pure Rust: the central eigensolver is the in-crate
//! Lanczos path (`linalg::eigen`) and the only dependency is the vendored
//! `anyhow` shim, so `cargo build --release && cargo test -q` works from a
//! clean checkout with no network access. The PJRT/XLA execution path
//! ([`runtime`]) is gated behind the `xla` cargo feature; without it,
//! `Backend::Xla` / `Backend::XlaFull` fail fast at runtime with a clear
//! error (see README.md, "The `xla` feature").
//!
//! Because the build must stand alone, the usual ecosystem pieces are
//! implemented as first-class substrates here: [`par`] (thread pool),
//! [`rng`] (PRNG), [`config`] (TOML subset), [`bench`] (micro-benchmark
//! harness), [`prop`] (property-testing harness), [`cli`] (argument
//! parsing).

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dml;
pub mod linalg;
pub mod metrics;
pub mod net;
pub mod par;
pub mod prop;
pub mod rng;
pub mod runtime;
pub mod site;
pub mod spectral;

/// Convenience re-exports for the common pipeline surface.
pub mod prelude {
    pub use crate::config::{Backend, PipelineConfig};
    pub use crate::coordinator::{run_pipeline, PipelineReport};
    pub use crate::data::scenario::{self, Scenario, SitePart};
    pub use crate::data::Dataset;
    pub use crate::dml::DmlKind;
    pub use crate::metrics::clustering_accuracy;
    pub use crate::spectral::{Algo, Bandwidth, GraphKind};
}
