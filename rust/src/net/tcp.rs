//! TCP backend: the star network over real sockets (`std::net`, no deps).
//!
//! This is the transport behind the `dsc leader` / `dsc site` daemon modes.
//! Layout on the wire (little-endian, see `docs/PROTOCOL.md` for the full
//! byte-level specification):
//!
//! ```text
//! connection := leader_hello site_hello frame*
//! hello      := magic:[u8;4]="DSCP" version:u16 role:u8 site_id:u32
//! frame      := len:u32 payload:[u8; len]        (payload = one wire frame)
//! ```
//!
//! The leader dials every site, sends its `Hello` (assigning the site its
//! id — position in the `--sites` list), and the site echoes one back; both
//! ends then verify magic, role, protocol version, and the echoed id before
//! any protocol frame flows. Read/write timeouts bound mid-frame stalls and
//! writes, but *idle* links never time out at this layer — a site
//! legitimately sits silent through the leader's central phase (and the
//! leader through the sites' DML phase); deadline policy belongs to the
//! coordinator (`collect_timeout`), not the transport.
//!
//! Byte accounting happens above the transport seam, on the encoded wire
//! frames only: the 4-byte length prefix and the 11-byte handshake are
//! transport framing, excluded so [`super::NetReport`] counters are
//! identical across the channel and TCP backends.

use std::io::{self, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::transport::{LeaderTransport, SiteTransport};
use crate::rng::Rng;

/// Version of the wire protocol this build speaks. Bumped on any breaking
/// change to the handshake, framing, or message layouts (`docs/PROTOCOL.md`
/// has the forward-compatibility rules).
pub const PROTOCOL_VERSION: u16 = 1;

/// Hard cap on a single frame; protects the receiver from hostile length
/// prefixes (the largest legitimate frame — a capped label or codebook
/// message — stays under this).
pub const MAX_FRAME_BYTES: u32 = 1 << 30;

const MAGIC: [u8; 4] = *b"DSCP";
const ROLE_LEADER: u8 = 0;
const ROLE_SITE: u8 = 1;
/// A client submitting jobs to a leader's `--serve` socket.
const ROLE_CLIENT: u8 = 2;
/// A job-serving leader opening a persistent multi-run site session
/// (run-scoped frames, tags 7+). Distinct from [`ROLE_LEADER`] so the site
/// knows *at handshake time* whether to speak the one-shot or the session
/// dialect — and so a pre-session build fails loudly on the role check.
const ROLE_JOB_LEADER: u8 = 3;
/// A warm standby (`dsc leader --standby`) dialing a serving primary's job
/// socket to receive journal replication (JREPL frames, wire tags 22–25).
/// A new role rather than a flag on [`ROLE_CLIENT`], so a pre-failover
/// primary refuses the connection loudly at handshake time instead of
/// misreading replication hellos as job submissions.
const ROLE_STANDBY: u8 = 4;
const HELLO_LEN: usize = 11;

/// Socket deadlines for the TCP backend (config `[net]`).
#[derive(Clone, Copy, Debug)]
pub struct TcpTimeouts {
    /// Dial + handshake deadline per site.
    pub connect: Duration,
    /// Mid-frame read stall / write stall deadline. Zero disables.
    pub io: Duration,
    /// Site-side dead-leader deadline: how long an *accepted* connection
    /// may sit with no frame at all before the site presumes the leader
    /// silently died (power loss, partition) and drops the link to
    /// re-listen. Zero disables — idle is then legal forever, the
    /// pre-`max_idle_secs` behavior. Size it above the longest legitimate
    /// central phase (see `docs/DEPLOY.md`).
    pub max_idle: Duration,
}

impl Default for TcpTimeouts {
    fn default() -> Self {
        TcpTimeouts {
            connect: Duration::from_secs(10),
            io: Duration::from_secs(30),
            max_idle: Duration::ZERO,
        }
    }
}

/// `set_read_timeout`/`set_write_timeout` reject `Some(0)`; zero means "no
/// timeout" throughout the config surface.
fn opt_timeout(d: Duration) -> Option<Duration> {
    (!d.is_zero()).then_some(d)
}

fn is_wait(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}

// ─── handshake ─────────────────────────────────────────────────────────────

struct Hello {
    version: u16,
    role: u8,
    site_id: u32,
}

fn encode_hello(role: u8, site_id: u32) -> [u8; HELLO_LEN] {
    let mut b = [0u8; HELLO_LEN];
    b[..4].copy_from_slice(&MAGIC);
    b[4..6].copy_from_slice(&PROTOCOL_VERSION.to_le_bytes());
    b[6] = role;
    b[7..11].copy_from_slice(&site_id.to_le_bytes());
    b
}

fn read_hello<R: Read>(r: &mut R) -> Result<Hello> {
    let mut b = [0u8; HELLO_LEN];
    r.read_exact(&mut b).context("read handshake")?;
    if b[..4] != MAGIC {
        bail!("peer is not a dsc endpoint (bad handshake magic)");
    }
    Ok(Hello {
        version: u16::from_le_bytes([b[4], b[5]]),
        role: b[6],
        site_id: u32::from_le_bytes(b[7..11].try_into().unwrap()),
    })
}

fn check_version(peer: u16) -> Result<()> {
    if peer != PROTOCOL_VERSION {
        bail!(
            "protocol version mismatch: peer speaks v{peer}, this build speaks \
             v{PROTOCOL_VERSION}"
        );
    }
    Ok(())
}

// ─── framing ───────────────────────────────────────────────────────────────

fn write_frame<W: Write>(w: &mut W, frame: &[u8]) -> Result<()> {
    let len = u32::try_from(frame.len())
        .ok()
        .filter(|&l| l <= MAX_FRAME_BYTES)
        .ok_or_else(|| {
            anyhow!("frame of {} bytes exceeds the {MAX_FRAME_BYTES}-byte cap", frame.len())
        })?;
    w.write_all(&len.to_le_bytes()).context("write frame length")?;
    w.write_all(frame).context("write frame body")?;
    Ok(())
}

/// Read one length-prefixed frame. `Ok(None)` means the peer closed the
/// connection cleanly at a frame boundary. Read timeouts while *waiting*
/// for a frame to start are swallowed (idle links are legal — see the
/// module docs) unless `idle_limit` is set and exceeded, in which case the
/// silent peer is presumed dead; a timeout or EOF *inside* a frame is
/// always an error. The idle clock needs the socket read timeout to fire
/// periodically — callers that pass a limit must arrange one no larger
/// than the limit (see [`SiteListener::accept`]).
fn read_frame<R: Read>(r: &mut R, idle_limit: Option<Duration>) -> Result<Option<Vec<u8>>> {
    let waiting_since = Instant::now();
    let mut len_buf = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        match r.read(&mut len_buf[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => bail!("connection closed mid-frame (torn length prefix)"),
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) if is_wait(&e) && got == 0 => {
                // idle between frames: legal, unless an idle deadline says
                // the silent peer must be dead by now
                if let Some(limit) = idle_limit {
                    if waiting_since.elapsed() >= limit {
                        bail!(
                            "link idle for {:.0?} (max_idle exceeded — presuming the \
                             peer silently died)",
                            waiting_since.elapsed()
                        );
                    }
                }
            }
            Err(e) if is_wait(&e) => bail!("peer stalled mid-frame: {e}"),
            Err(e) => return Err(e).context("read frame length"),
        }
    }
    let len = u32::from_le_bytes(len_buf);
    if len > MAX_FRAME_BYTES {
        bail!("frame length {len} exceeds the {MAX_FRAME_BYTES}-byte cap");
    }
    let len = len as usize;
    // Grow as bytes actually arrive instead of trusting the declared length
    // with an upfront reservation (mirror of wire::decode's allocation
    // bound): a hostile prefix costs at most one socket buffer of memory.
    let mut buf = Vec::with_capacity(len.min(64 * 1024));
    let mut limited = Read::take(&mut *r, len as u64);
    match limited.read_to_end(&mut buf) {
        Ok(_) => {}
        Err(e) if is_wait(&e) => {
            bail!("peer stalled mid-frame after {} of {len} bytes: {e}", buf.len())
        }
        Err(e) => return Err(e).context("read frame body"),
    }
    if buf.len() != len {
        bail!("connection closed mid-frame: got {} of {len} bytes", buf.len());
    }
    Ok(Some(buf))
}

// ─── leader side ───────────────────────────────────────────────────────────

/// Leader transport: one socket per site plus a reader thread per socket
/// funnelling frames into a single mailbox (so `recv` is "next frame from
/// any site", exactly like the channel backend).
pub struct TcpLeader {
    conns: Vec<TcpStream>,
    rx: Receiver<(usize, Result<Vec<u8>, String>)>,
    readers: Vec<thread::JoinHandle<()>>,
}

/// Dial every site in `addrs` (index = site id), run the handshakes, and
/// assemble the leader transport. Dials run **concurrently** (one thread
/// per site), so the worst-case connect phase is one `connect` timeout,
/// not `S` of them; any unreachable or incompatible site fails the whole
/// call, naming every site that failed.
pub fn connect_sites(addrs: &[String], timeouts: &TcpTimeouts) -> Result<TcpLeader> {
    let conns = dial_sites(addrs, timeouts, false)?;
    let (tx, rx) = mpsc::channel();
    let mut readers = Vec::with_capacity(conns.len());
    for (site_id, stream) in conns.iter().enumerate() {
        let mut rd = stream.try_clone().context("clone site socket for reading")?;
        let tx = tx.clone();
        readers.push(thread::spawn(move || loop {
            match read_frame(&mut rd, None) {
                Ok(Some(frame)) => {
                    if tx.send((site_id, Ok(frame))).is_err() {
                        return; // leader gone; stop reading
                    }
                }
                Ok(None) => {
                    let _ = tx.send((site_id, Err("site closed the connection".into())));
                    return;
                }
                Err(e) => {
                    let _ = tx.send((site_id, Err(format!("{e:#}"))));
                    return;
                }
            }
        }));
    }
    Ok(TcpLeader { conns, rx, readers })
}

/// Dial + handshake every site concurrently. `session = true` opens
/// persistent multi-run sessions (the job-leader role-3 hello, run-scoped
/// frames); `false` opens classic one-shot connections. The job server
/// uses this directly so it can own per-connection reader threads feeding
/// its reactor mailbox.
pub fn dial_sites(
    addrs: &[String],
    timeouts: &TcpTimeouts,
    session: bool,
) -> Result<Vec<TcpStream>> {
    if addrs.is_empty() {
        bail!("no site addresses to connect to");
    }
    let results: Vec<Result<TcpStream>> = thread::scope(|scope| {
        let handles: Vec<_> = addrs
            .iter()
            .enumerate()
            .map(|(site_id, addr)| {
                scope.spawn(move || connect_site(addr, site_id as u32, timeouts, session))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|_| Err(anyhow!("dial thread panicked"))))
            .collect()
    });
    let mut conns = Vec::with_capacity(addrs.len());
    let mut failures = Vec::new();
    for (site_id, res) in results.into_iter().enumerate() {
        match res {
            Ok(s) => conns.push(s),
            Err(e) => failures.push(format!("site {site_id}: {e:#}")),
        }
    }
    if !failures.is_empty() {
        bail!("{}", failures.join("; "));
    }
    Ok(conns)
}

/// Dial one site and run the leader-side handshake (the single-link piece
/// of [`dial_sites`]; the job server also calls it to re-dial a site whose
/// link died between runs).
pub fn connect_site(
    addr: &str,
    site_id: u32,
    timeouts: &TcpTimeouts,
    session: bool,
) -> Result<TcpStream> {
    let stream = connect_one(addr, timeouts)
        .with_context(|| format!("connect to site {site_id} at {addr}"))?;
    leader_handshake(stream, site_id, timeouts, session)
        .with_context(|| format!("handshake with site {site_id} at {addr}"))
}

/// Write one length-prefixed frame to a raw stream (the job server's
/// `TcpDriver` send path; `TcpStream` writes are not buffered, so
/// interleaved writers per stream must be externally serialized — the
/// reactor is single-threaded).
pub fn send_frame(stream: &TcpStream, frame: &[u8]) -> Result<()> {
    let mut w = stream;
    write_frame(&mut w, frame)
}

/// Read one length-prefixed frame from a raw stream; `Ok(None)` is a clean
/// close at a frame boundary (job-server reader-thread path).
pub fn recv_frame(stream: &TcpStream) -> Result<Option<Vec<u8>>> {
    let mut r = stream;
    read_frame(&mut r, None)
}

fn connect_one(addr: &str, t: &TcpTimeouts) -> Result<TcpStream> {
    let sa: SocketAddr = addr
        .to_socket_addrs()
        .with_context(|| format!("resolve {addr:?}"))?
        .next()
        .ok_or_else(|| anyhow!("address {addr:?} resolved to nothing"))?;
    let stream = match opt_timeout(t.connect) {
        Some(d) => TcpStream::connect_timeout(&sa, d),
        None => TcpStream::connect(sa),
    }
    .context("tcp connect")?;
    stream.set_nodelay(true).ok(); // small control frames must not batch
    Ok(stream)
}

fn leader_handshake(
    mut stream: TcpStream,
    site_id: u32,
    t: &TcpTimeouts,
    session: bool,
) -> Result<TcpStream> {
    let role = if session { ROLE_JOB_LEADER } else { ROLE_LEADER };
    stream.set_read_timeout(opt_timeout(t.connect)).context("set handshake timeout")?;
    stream.set_write_timeout(opt_timeout(t.connect)).context("set handshake timeout")?;
    stream.write_all(&encode_hello(role, site_id)).context("send hello")?;
    let hello = read_hello(&mut stream)?;
    check_version(hello.version)?;
    if hello.role != ROLE_SITE {
        bail!("peer answered with role {} (expected a site)", hello.role);
    }
    if hello.site_id != site_id {
        bail!("site echoed id {} (expected {site_id})", hello.site_id);
    }
    stream.set_read_timeout(opt_timeout(t.io)).context("set io timeout")?;
    stream.set_write_timeout(opt_timeout(t.io)).context("set io timeout")?;
    Ok(stream)
}

impl LeaderTransport for TcpLeader {
    fn n_sites(&self) -> usize {
        self.conns.len()
    }

    fn send(&self, site: usize, frame: Vec<u8>) -> Result<()> {
        let mut w = &self.conns[site];
        write_frame(&mut w, &frame).with_context(|| format!("send to site {site}"))
    }

    fn recv(&self, timeout: Option<Duration>) -> Result<(usize, Vec<u8>)> {
        let (site, res) = match timeout {
            None => {
                self.rx.recv().map_err(|_| anyhow!("all site connections closed"))?
            }
            Some(t) => self.rx.recv_timeout(t).map_err(|e| match e {
                RecvTimeoutError::Timeout => anyhow!("timed out waiting for sites"),
                RecvTimeoutError::Disconnected => anyhow!("all site connections closed"),
            })?,
        };
        match res {
            Ok(frame) => Ok((site, frame)),
            Err(msg) => bail!("site {site} link failed: {msg}"),
        }
    }
}

impl Drop for TcpLeader {
    fn drop(&mut self) {
        // Shut the sockets down first so reader threads blocked in `read`
        // wake with EOF, then reap them.
        for c in &self.conns {
            let _ = c.shutdown(Shutdown::Both);
        }
        for h in self.readers.drain(..) {
            let _ = h.join();
        }
    }
}

// ─── site side ─────────────────────────────────────────────────────────────

/// A site's listening socket (`dsc site --listen`). Each [`accept`] yields
/// one handshaken leader connection; a daemon loops accepting, one pipeline
/// run per connection.
///
/// [`accept`]: SiteListener::accept
pub struct SiteListener {
    listener: TcpListener,
}

impl SiteListener {
    /// Bind the listening socket. Port 0 picks a free port — read it back
    /// with [`SiteListener::local_addr`].
    pub fn bind(addr: &str) -> Result<SiteListener> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        Ok(SiteListener { listener })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().context("listener local addr")
    }

    /// Block for the next leader connection and complete the handshake.
    /// The returned transport carries the site id the leader assigned and
    /// which dialect the leader opened ([`TcpSite::session_mode`]): a
    /// classic one-shot run, or a persistent multi-run session.
    pub fn accept(&self, timeouts: &TcpTimeouts) -> Result<TcpSite> {
        let (mut stream, peer) = self.listener.accept().context("accept")?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(opt_timeout(timeouts.connect)).context("set handshake timeout")?;
        stream.set_write_timeout(opt_timeout(timeouts.connect)).context("set handshake timeout")?;
        let hello =
            read_hello(&mut stream).with_context(|| format!("handshake with {peer}"))?;
        // Reply before validating the peer's version so a mismatched leader
        // still learns which version this site speaks.
        stream.write_all(&encode_hello(ROLE_SITE, hello.site_id)).context("send hello")?;
        check_version(hello.version)?;
        let session = match hello.role {
            ROLE_LEADER => false,
            ROLE_JOB_LEADER => true,
            ROLE_CLIENT => bail!(
                "peer {peer} is a dsc client — jobs are submitted to a leader's \
                 --serve address, not to a site"
            ),
            other => bail!("peer {peer} presented role {other} (expected a leader)"),
        };
        // The idle clock (dead-leader detection) only advances when the
        // blocking read wakes up, so the socket read timeout must be no
        // larger than the idle limit; mid-frame stalls are then bounded by
        // min(io, max_idle) instead of io alone — documented in DEPLOY.md.
        let idle_limit = opt_timeout(timeouts.max_idle);
        let read_timeout = match (opt_timeout(timeouts.io), idle_limit) {
            (io, None) => io,
            (None, Some(idle)) => Some(idle),
            (Some(io), Some(idle)) => Some(io.min(idle)),
        };
        stream.set_read_timeout(read_timeout).context("set io timeout")?;
        stream.set_write_timeout(opt_timeout(timeouts.io)).context("set io timeout")?;
        Ok(TcpSite { stream, site_id: hello.site_id as usize, session, idle_limit })
    }
}

/// Site transport: one handshaken connection to the leader.
pub struct TcpSite {
    stream: TcpStream,
    site_id: usize,
    session: bool,
    idle_limit: Option<Duration>,
}

impl TcpSite {
    /// True when the leader opened a persistent multi-run session
    /// (`ROLE_JOB_LEADER` hello) rather than a classic one-shot run — the
    /// daemon picks [`crate::site::session`] vs [`crate::site::serve`]
    /// accordingly.
    pub fn session_mode(&self) -> bool {
        self.session
    }
}

impl SiteTransport for TcpSite {
    fn site_id(&self) -> usize {
        self.site_id
    }

    fn send(&self, frame: Vec<u8>) -> Result<()> {
        let mut w = &self.stream;
        write_frame(&mut w, &frame).context("send to leader")
    }

    fn recv_opt(&self) -> Result<Option<Vec<u8>>> {
        let mut r = &self.stream;
        read_frame(&mut r, self.idle_limit)
    }
}

// ─── client side (job submission plane) ────────────────────────────────────

/// A client's handshaken connection to a job-serving leader
/// (`dsc submit` → `dsc leader --serve`). Moves raw frames; the typed
/// submit/await protocol lives in [`crate::coordinator::server::JobClient`].
pub struct TcpClient {
    stream: TcpStream,
}

/// Dial a leader's `--serve` address and run the client handshake
/// (role 2). The `site_id` hello field is unused on this plane and sent as
/// zero; the leader echoes it.
pub fn connect_client(addr: &str, t: &TcpTimeouts) -> Result<TcpClient> {
    let mut stream =
        connect_one(addr, t).with_context(|| format!("connect to leader at {addr}"))?;
    stream.set_read_timeout(opt_timeout(t.connect)).context("set handshake timeout")?;
    stream.set_write_timeout(opt_timeout(t.connect)).context("set handshake timeout")?;
    stream.write_all(&encode_hello(ROLE_CLIENT, 0)).context("send hello")?;
    let hello = read_hello(&mut stream)?;
    check_version(hello.version)?;
    if hello.role != ROLE_LEADER {
        bail!("peer at {addr} answered with role {} (expected a leader)", hello.role);
    }
    stream.set_read_timeout(opt_timeout(t.io)).context("set io timeout")?;
    stream.set_write_timeout(opt_timeout(t.io)).context("set io timeout")?;
    Ok(TcpClient { stream })
}

impl TcpClient {
    pub fn send(&self, frame: &[u8]) -> Result<()> {
        let mut w = &self.stream;
        write_frame(&mut w, frame).context("send to leader")
    }

    /// Next frame from the leader; `Ok(None)` = leader closed. Waiting out
    /// a long-running job is idle time, which never errors here.
    pub fn recv(&self) -> Result<Option<Vec<u8>>> {
        let mut r = &self.stream;
        read_frame(&mut r, None)
    }
}

/// What a completed handshake on the leader's job socket turned out to be:
/// a submitting client (role 2) or a warm standby asking for journal
/// replication (role 4).
pub enum JobPeer {
    Client(TcpStream),
    Standby(TcpStream),
}

/// Leader side: accept + handshake one connection on the job socket.
/// Returns the raw stream tagged with what the peer is (the job server
/// splits a client into a reader thread and a reactor-owned writer, and
/// hands a standby to the replication sender).
pub fn accept_job_peer(listener: &TcpListener, t: &TcpTimeouts) -> Result<JobPeer> {
    let (mut stream, peer) = listener.accept().context("accept client")?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(opt_timeout(t.connect)).context("set handshake timeout")?;
    stream.set_write_timeout(opt_timeout(t.connect)).context("set handshake timeout")?;
    let hello = read_hello(&mut stream).with_context(|| format!("handshake with {peer}"))?;
    // Same reply-before-validate convention as the site listener.
    stream.write_all(&encode_hello(ROLE_LEADER, hello.site_id)).context("send hello")?;
    check_version(hello.version)?;
    let standby = match hello.role {
        ROLE_CLIENT => false,
        ROLE_STANDBY => true,
        ROLE_SITE => bail!(
            "peer {peer} is a dsc site — the leader dials sites from its --sites \
             list; sites do not dial the job socket"
        ),
        other => bail!("peer {peer} presented role {other} (expected a client)"),
    };
    stream.set_read_timeout(opt_timeout(t.io)).context("set io timeout")?;
    stream.set_write_timeout(opt_timeout(t.io)).context("set io timeout")?;
    Ok(if standby { JobPeer::Standby(stream) } else { JobPeer::Client(stream) })
}

/// Leader side: accept + handshake one *client* connection on the job
/// socket — [`accept_job_peer`] for callers with no replication plane,
/// which refuse a standby loudly.
pub fn accept_client(listener: &TcpListener, t: &TcpTimeouts) -> Result<TcpStream> {
    match accept_job_peer(listener, t)? {
        JobPeer::Client(stream) => Ok(stream),
        JobPeer::Standby(_) => {
            bail!("peer is a dsc standby, but this leader has no replication plane")
        }
    }
}

/// Standby side: dial a serving primary's job socket and run the role-4
/// handshake. `idle_limit` is the standby's promotion deadline — reads on
/// the returned stream must wake at least that often for the idle clock to
/// fire (same rule as [`SiteListener::accept`]), so the socket read
/// timeout is clamped to it.
pub fn connect_standby(
    addr: &str,
    t: &TcpTimeouts,
    idle_limit: Option<Duration>,
) -> Result<TcpStream> {
    let mut stream =
        connect_one(addr, t).with_context(|| format!("connect to primary at {addr}"))?;
    stream.set_read_timeout(opt_timeout(t.connect)).context("set handshake timeout")?;
    stream.set_write_timeout(opt_timeout(t.connect)).context("set handshake timeout")?;
    stream.write_all(&encode_hello(ROLE_STANDBY, 0)).context("send hello")?;
    let hello = read_hello(&mut stream)?;
    check_version(hello.version)?;
    if hello.role != ROLE_LEADER {
        bail!("peer at {addr} answered with role {} (expected a leader)", hello.role);
    }
    let read_timeout = match (opt_timeout(t.io), idle_limit) {
        (io, None) => io,
        (None, Some(idle)) => Some(idle),
        (Some(io), Some(idle)) => Some(io.min(idle)),
    };
    stream.set_read_timeout(read_timeout).context("set io timeout")?;
    stream.set_write_timeout(opt_timeout(t.io)).context("set io timeout")?;
    Ok(stream)
}

// ─── backoff ───────────────────────────────────────────────────────────────

/// Capped exponential backoff with deterministic jitter for daemon retry
/// loops (`dsc site`'s accept loop): doubling keeps a persistently failing
/// accept from hot-spinning, the cap bounds recovery latency once the
/// fault clears, and the seeded jitter keeps a *fleet* of sites that
/// restarted together from retrying in lockstep and sync-storming the
/// leader — callers salt the seed with something site-local (the listen
/// address) so streams decorrelate while staying reproducible.
#[derive(Debug)]
pub struct Backoff {
    rng: Rng,
    attempt: u32,
    base: Duration,
    cap: Duration,
}

impl Backoff {
    /// Daemon defaults: 100 ms doubling to a 10 s cap.
    pub fn new(seed: u64) -> Backoff {
        Backoff::with_limits(seed, Duration::from_millis(100), Duration::from_secs(10))
    }

    pub fn with_limits(seed: u64, base: Duration, cap: Duration) -> Backoff {
        Backoff { rng: Rng::new(seed), attempt: 0, base, cap }
    }

    /// Delay before the next retry: `min(cap, base·2^attempt)`, jittered
    /// to 75–125% (so the cap is approximate by design — identical caps
    /// must not re-synchronize a fleet).
    pub fn next_delay(&mut self) -> Duration {
        let exp = self.attempt.min(30); // 2^30 · base saturates far past any cap
        self.attempt = self.attempt.saturating_add(1);
        let raw = self.base.saturating_mul(1u32 << exp).min(self.cap);
        let jitter = 0.75 + 0.5 * self.rng.f64();
        Duration::from_secs_f64(raw.as_secs_f64() * jitter)
    }

    /// A successful cycle resets the schedule to the base delay.
    pub fn reset(&mut self) {
        self.attempt = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip_in_memory() {
        let mut wire = Vec::new();
        write_frame(&mut wire, b"hello frames").unwrap();
        write_frame(&mut wire, b"").unwrap();
        let mut r = &wire[..];
        assert_eq!(read_frame(&mut r, None).unwrap().unwrap(), b"hello frames".to_vec());
        assert_eq!(read_frame(&mut r, None).unwrap().unwrap(), Vec::<u8>::new());
        // clean EOF at a frame boundary
        assert!(read_frame(&mut r, None).unwrap().is_none());
    }

    #[test]
    fn torn_frames_error() {
        let mut wire = Vec::new();
        write_frame(&mut wire, b"full frame").unwrap();
        // torn inside the payload and inside the length prefix
        for cut in [2usize, 4, 7] {
            let mut r = &wire[..cut];
            assert!(read_frame(&mut r, None).is_err(), "cut at {cut} must error");
        }
    }

    #[test]
    fn hostile_length_prefix_rejected() {
        let wire = u32::MAX.to_le_bytes();
        let mut r = &wire[..];
        let err = read_frame(&mut r, None).unwrap_err();
        assert!(err.to_string().contains("cap"), "{err}");
    }

    #[test]
    fn declared_length_longer_than_stream_errors() {
        let mut wire = 1000u32.to_le_bytes().to_vec();
        wire.extend_from_slice(&[7u8; 10]); // only 10 of 1000 bytes present
        let mut r = &wire[..];
        let err = read_frame(&mut r, None).unwrap_err();
        assert!(err.to_string().contains("mid-frame"), "{err}");
    }

    #[test]
    fn backoff_is_deterministic_and_capped() {
        let schedule = |seed: u64| -> Vec<Duration> {
            let mut b =
                Backoff::with_limits(seed, Duration::from_millis(100), Duration::from_secs(2));
            (0..10).map(|_| b.next_delay()).collect()
        };
        // same seed ⇒ identical schedule; different seed ⇒ different jitter
        assert_eq!(schedule(7), schedule(7));
        assert_ne!(schedule(7), schedule(8));

        let delays = schedule(7);
        // every delay is within the 75–125% jitter band of min(cap, 100ms·2^i)
        for (i, d) in delays.iter().enumerate() {
            let raw = Duration::from_millis(100 * (1u64 << i.min(6)))
                .min(Duration::from_secs(2))
                .as_secs_f64();
            let f = d.as_secs_f64();
            assert!(f >= raw * 0.75 - 1e-9 && f <= raw * 1.25 + 1e-9, "delay {i} = {f}s");
        }
        // the tail has hit the cap: everything in the cap's jitter band
        let cap = 2.0;
        for d in &delays[6..] {
            assert!(d.as_secs_f64() >= cap * 0.75 && d.as_secs_f64() <= cap * 1.25);
        }
    }

    #[test]
    fn backoff_reset_restarts_the_schedule() {
        let mut b = Backoff::new(5);
        let first = b.next_delay();
        let second = b.next_delay();
        assert!(second > first / 2, "doubling should dominate jitter here");
        b.reset();
        let after_reset = b.next_delay();
        // back to the base band: ≤ 125 ms, far under the second step's ≥150 ms
        assert!(after_reset <= Duration::from_millis(125), "{after_reset:?}");
        assert!(second >= Duration::from_millis(150), "{second:?}");
    }

    #[test]
    fn hello_roundtrip_and_validation() {
        let bytes = encode_hello(ROLE_SITE, 42);
        let h = read_hello(&mut &bytes[..]).unwrap();
        assert_eq!((h.version, h.role, h.site_id), (PROTOCOL_VERSION, ROLE_SITE, 42));

        let mut bad_magic = bytes;
        bad_magic[0] = b'X';
        assert!(read_hello(&mut &bad_magic[..]).is_err());

        assert!(check_version(PROTOCOL_VERSION).is_ok());
        let err = check_version(PROTOCOL_VERSION + 1).unwrap_err();
        assert!(err.to_string().contains("version mismatch"), "{err}");
    }

    #[test]
    fn zero_io_timeout_means_disabled() {
        assert_eq!(opt_timeout(Duration::ZERO), None);
        assert_eq!(opt_timeout(Duration::from_secs(3)), Some(Duration::from_secs(3)));
    }

    #[test]
    fn job_socket_dispatches_clients_and_standbys_by_role() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let t = TcpTimeouts::default();

        // A role-4 hello lands as a standby peer…
        let dial_addr = addr.clone();
        let dialer = thread::spawn(move || {
            connect_standby(&dial_addr, &TcpTimeouts::default(), None).map(|_| ())
        });
        assert!(matches!(accept_job_peer(&listener, &t).unwrap(), JobPeer::Standby(_)));
        dialer.join().unwrap().unwrap();

        // …a role-2 hello as a client…
        let dial_addr = addr.clone();
        let dialer =
            thread::spawn(move || connect_client(&dial_addr, &TcpTimeouts::default()).map(|_| ()));
        assert!(matches!(accept_job_peer(&listener, &t).unwrap(), JobPeer::Client(_)));
        dialer.join().unwrap().unwrap();

        // …and a replication-less accept refuses the standby loudly, after
        // the reply-before-validate hello (so the dialer handshake itself
        // succeeds and the refusal is the leader's, not a protocol error).
        let dial_addr = addr;
        let dialer = thread::spawn(move || {
            connect_standby(&dial_addr, &TcpTimeouts::default(), None).map(|_| ())
        });
        let err = accept_client(&listener, &t).unwrap_err();
        assert!(err.to_string().contains("no replication plane"), "{err}");
        dialer.join().unwrap().unwrap();
    }
}
