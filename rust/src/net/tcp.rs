//! TCP backend: the star network over real sockets (`std::net`, no deps).
//!
//! This is the transport behind the `dsc leader` / `dsc site` daemon modes.
//! Layout on the wire (little-endian, see `docs/PROTOCOL.md` for the full
//! byte-level specification):
//!
//! ```text
//! connection := leader_hello site_hello frame*
//! hello      := magic:[u8;4]="DSCP" version:u16 role:u8 site_id:u32
//! frame      := len:u32 payload:[u8; len]        (payload = one wire frame)
//! ```
//!
//! The leader dials every site, sends its `Hello` (assigning the site its
//! id — position in the `--sites` list), and the site echoes one back; both
//! ends then verify magic, role, protocol version, and the echoed id before
//! any protocol frame flows. Read/write timeouts bound mid-frame stalls and
//! writes, but *idle* links never time out at this layer — a site
//! legitimately sits silent through the leader's central phase (and the
//! leader through the sites' DML phase); deadline policy belongs to the
//! coordinator (`collect_timeout`), not the transport.
//!
//! Byte accounting happens above the transport seam, on the encoded wire
//! frames only: the 4-byte length prefix and the 11-byte handshake are
//! transport framing, excluded so [`super::NetReport`] counters are
//! identical across the channel and TCP backends.

use std::io::{self, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError};
use std::thread;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use super::transport::{LeaderTransport, SiteTransport};

/// Version of the wire protocol this build speaks. Bumped on any breaking
/// change to the handshake, framing, or message layouts (`docs/PROTOCOL.md`
/// has the forward-compatibility rules).
pub const PROTOCOL_VERSION: u16 = 1;

/// Hard cap on a single frame; protects the receiver from hostile length
/// prefixes (the largest legitimate frame — a capped label or codebook
/// message — stays under this).
pub const MAX_FRAME_BYTES: u32 = 1 << 30;

const MAGIC: [u8; 4] = *b"DSCP";
const ROLE_LEADER: u8 = 0;
const ROLE_SITE: u8 = 1;
const HELLO_LEN: usize = 11;

/// Socket deadlines for the TCP backend (config `[net]`).
#[derive(Clone, Copy, Debug)]
pub struct TcpTimeouts {
    /// Dial + handshake deadline per site.
    pub connect: Duration,
    /// Mid-frame read stall / write stall deadline. Zero disables.
    pub io: Duration,
}

impl Default for TcpTimeouts {
    fn default() -> Self {
        TcpTimeouts { connect: Duration::from_secs(10), io: Duration::from_secs(30) }
    }
}

/// `set_read_timeout`/`set_write_timeout` reject `Some(0)`; zero means "no
/// timeout" throughout the config surface.
fn opt_timeout(d: Duration) -> Option<Duration> {
    (!d.is_zero()).then_some(d)
}

fn is_wait(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}

// ─── handshake ─────────────────────────────────────────────────────────────

struct Hello {
    version: u16,
    role: u8,
    site_id: u32,
}

fn encode_hello(role: u8, site_id: u32) -> [u8; HELLO_LEN] {
    let mut b = [0u8; HELLO_LEN];
    b[..4].copy_from_slice(&MAGIC);
    b[4..6].copy_from_slice(&PROTOCOL_VERSION.to_le_bytes());
    b[6] = role;
    b[7..11].copy_from_slice(&site_id.to_le_bytes());
    b
}

fn read_hello<R: Read>(r: &mut R) -> Result<Hello> {
    let mut b = [0u8; HELLO_LEN];
    r.read_exact(&mut b).context("read handshake")?;
    if b[..4] != MAGIC {
        bail!("peer is not a dsc endpoint (bad handshake magic)");
    }
    Ok(Hello {
        version: u16::from_le_bytes([b[4], b[5]]),
        role: b[6],
        site_id: u32::from_le_bytes(b[7..11].try_into().unwrap()),
    })
}

fn check_version(peer: u16) -> Result<()> {
    if peer != PROTOCOL_VERSION {
        bail!(
            "protocol version mismatch: peer speaks v{peer}, this build speaks \
             v{PROTOCOL_VERSION}"
        );
    }
    Ok(())
}

// ─── framing ───────────────────────────────────────────────────────────────

fn write_frame<W: Write>(w: &mut W, frame: &[u8]) -> Result<()> {
    let len = u32::try_from(frame.len())
        .ok()
        .filter(|&l| l <= MAX_FRAME_BYTES)
        .ok_or_else(|| {
            anyhow!("frame of {} bytes exceeds the {MAX_FRAME_BYTES}-byte cap", frame.len())
        })?;
    w.write_all(&len.to_le_bytes()).context("write frame length")?;
    w.write_all(frame).context("write frame body")?;
    Ok(())
}

/// Read one length-prefixed frame. `Ok(None)` means the peer closed the
/// connection cleanly at a frame boundary. Read timeouts while *waiting*
/// for a frame to start are swallowed (idle links are legal — see the
/// module docs); a timeout or EOF *inside* a frame is an error.
fn read_frame<R: Read>(r: &mut R) -> Result<Option<Vec<u8>>> {
    let mut len_buf = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        match r.read(&mut len_buf[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => bail!("connection closed mid-frame (torn length prefix)"),
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) if is_wait(&e) && got == 0 => {} // idle between frames
            Err(e) if is_wait(&e) => bail!("peer stalled mid-frame: {e}"),
            Err(e) => return Err(e).context("read frame length"),
        }
    }
    let len = u32::from_le_bytes(len_buf);
    if len > MAX_FRAME_BYTES {
        bail!("frame length {len} exceeds the {MAX_FRAME_BYTES}-byte cap");
    }
    let len = len as usize;
    // Grow as bytes actually arrive instead of trusting the declared length
    // with an upfront reservation (mirror of wire::decode's allocation
    // bound): a hostile prefix costs at most one socket buffer of memory.
    let mut buf = Vec::with_capacity(len.min(64 * 1024));
    let mut limited = Read::take(&mut *r, len as u64);
    match limited.read_to_end(&mut buf) {
        Ok(_) => {}
        Err(e) if is_wait(&e) => {
            bail!("peer stalled mid-frame after {} of {len} bytes: {e}", buf.len())
        }
        Err(e) => return Err(e).context("read frame body"),
    }
    if buf.len() != len {
        bail!("connection closed mid-frame: got {} of {len} bytes", buf.len());
    }
    Ok(Some(buf))
}

// ─── leader side ───────────────────────────────────────────────────────────

/// Leader transport: one socket per site plus a reader thread per socket
/// funnelling frames into a single mailbox (so `recv` is "next frame from
/// any site", exactly like the channel backend).
pub struct TcpLeader {
    conns: Vec<TcpStream>,
    rx: Receiver<(usize, Result<Vec<u8>, String>)>,
    readers: Vec<thread::JoinHandle<()>>,
}

/// Dial every site in `addrs` (index = site id), run the handshake, and
/// assemble the leader transport. Fails fast on the first unreachable or
/// incompatible site.
pub fn connect_sites(addrs: &[String], timeouts: &TcpTimeouts) -> Result<TcpLeader> {
    if addrs.is_empty() {
        bail!("no site addresses to connect to");
    }
    let mut conns = Vec::with_capacity(addrs.len());
    for (site_id, addr) in addrs.iter().enumerate() {
        let stream = connect_one(addr, timeouts)
            .with_context(|| format!("connect to site {site_id} at {addr}"))?;
        let stream = leader_handshake(stream, site_id as u32, timeouts)
            .with_context(|| format!("handshake with site {site_id} at {addr}"))?;
        conns.push(stream);
    }
    let (tx, rx) = mpsc::channel();
    let mut readers = Vec::with_capacity(conns.len());
    for (site_id, stream) in conns.iter().enumerate() {
        let mut rd = stream.try_clone().context("clone site socket for reading")?;
        let tx = tx.clone();
        readers.push(thread::spawn(move || loop {
            match read_frame(&mut rd) {
                Ok(Some(frame)) => {
                    if tx.send((site_id, Ok(frame))).is_err() {
                        return; // leader gone; stop reading
                    }
                }
                Ok(None) => {
                    let _ = tx.send((site_id, Err("site closed the connection".into())));
                    return;
                }
                Err(e) => {
                    let _ = tx.send((site_id, Err(format!("{e:#}"))));
                    return;
                }
            }
        }));
    }
    Ok(TcpLeader { conns, rx, readers })
}

fn connect_one(addr: &str, t: &TcpTimeouts) -> Result<TcpStream> {
    let sa: SocketAddr = addr
        .to_socket_addrs()
        .with_context(|| format!("resolve {addr:?}"))?
        .next()
        .ok_or_else(|| anyhow!("address {addr:?} resolved to nothing"))?;
    let stream = match opt_timeout(t.connect) {
        Some(d) => TcpStream::connect_timeout(&sa, d),
        None => TcpStream::connect(sa),
    }
    .context("tcp connect")?;
    stream.set_nodelay(true).ok(); // small control frames must not batch
    Ok(stream)
}

fn leader_handshake(mut stream: TcpStream, site_id: u32, t: &TcpTimeouts) -> Result<TcpStream> {
    stream.set_read_timeout(opt_timeout(t.connect)).context("set handshake timeout")?;
    stream.set_write_timeout(opt_timeout(t.connect)).context("set handshake timeout")?;
    stream.write_all(&encode_hello(ROLE_LEADER, site_id)).context("send hello")?;
    let hello = read_hello(&mut stream)?;
    check_version(hello.version)?;
    if hello.role != ROLE_SITE {
        bail!("peer answered with role {} (expected a site)", hello.role);
    }
    if hello.site_id != site_id {
        bail!("site echoed id {} (expected {site_id})", hello.site_id);
    }
    stream.set_read_timeout(opt_timeout(t.io)).context("set io timeout")?;
    stream.set_write_timeout(opt_timeout(t.io)).context("set io timeout")?;
    Ok(stream)
}

impl LeaderTransport for TcpLeader {
    fn n_sites(&self) -> usize {
        self.conns.len()
    }

    fn send(&self, site: usize, frame: Vec<u8>) -> Result<()> {
        let mut w = &self.conns[site];
        write_frame(&mut w, &frame).with_context(|| format!("send to site {site}"))
    }

    fn recv(&self, timeout: Option<Duration>) -> Result<(usize, Vec<u8>)> {
        let (site, res) = match timeout {
            None => {
                self.rx.recv().map_err(|_| anyhow!("all site connections closed"))?
            }
            Some(t) => self.rx.recv_timeout(t).map_err(|e| match e {
                RecvTimeoutError::Timeout => anyhow!("timed out waiting for sites"),
                RecvTimeoutError::Disconnected => anyhow!("all site connections closed"),
            })?,
        };
        match res {
            Ok(frame) => Ok((site, frame)),
            Err(msg) => bail!("site {site} link failed: {msg}"),
        }
    }
}

impl Drop for TcpLeader {
    fn drop(&mut self) {
        // Shut the sockets down first so reader threads blocked in `read`
        // wake with EOF, then reap them.
        for c in &self.conns {
            let _ = c.shutdown(Shutdown::Both);
        }
        for h in self.readers.drain(..) {
            let _ = h.join();
        }
    }
}

// ─── site side ─────────────────────────────────────────────────────────────

/// A site's listening socket (`dsc site --listen`). Each [`accept`] yields
/// one handshaken leader connection; a daemon loops accepting, one pipeline
/// run per connection.
///
/// [`accept`]: SiteListener::accept
pub struct SiteListener {
    listener: TcpListener,
}

impl SiteListener {
    /// Bind the listening socket. Port 0 picks a free port — read it back
    /// with [`SiteListener::local_addr`].
    pub fn bind(addr: &str) -> Result<SiteListener> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        Ok(SiteListener { listener })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().context("listener local addr")
    }

    /// Block for the next leader connection and complete the handshake.
    /// The returned transport carries the site id the leader assigned.
    pub fn accept(&self, timeouts: &TcpTimeouts) -> Result<TcpSite> {
        let (mut stream, peer) = self.listener.accept().context("accept")?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(opt_timeout(timeouts.connect)).context("set handshake timeout")?;
        stream.set_write_timeout(opt_timeout(timeouts.connect)).context("set handshake timeout")?;
        let hello =
            read_hello(&mut stream).with_context(|| format!("handshake with {peer}"))?;
        // Reply before validating the peer's version so a mismatched leader
        // still learns which version this site speaks.
        stream.write_all(&encode_hello(ROLE_SITE, hello.site_id)).context("send hello")?;
        check_version(hello.version)?;
        if hello.role != ROLE_LEADER {
            bail!("peer {peer} presented role {} (expected the leader)", hello.role);
        }
        stream.set_read_timeout(opt_timeout(timeouts.io)).context("set io timeout")?;
        stream.set_write_timeout(opt_timeout(timeouts.io)).context("set io timeout")?;
        Ok(TcpSite { stream, site_id: hello.site_id as usize })
    }
}

/// Site transport: one handshaken connection to the leader.
pub struct TcpSite {
    stream: TcpStream,
    site_id: usize,
}

impl SiteTransport for TcpSite {
    fn site_id(&self) -> usize {
        self.site_id
    }

    fn send(&self, frame: Vec<u8>) -> Result<()> {
        let mut w = &self.stream;
        write_frame(&mut w, &frame).context("send to leader")
    }

    fn recv(&self) -> Result<Vec<u8>> {
        let mut r = &self.stream;
        match read_frame(&mut r)? {
            Some(frame) => Ok(frame),
            None => bail!("leader closed the connection"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip_in_memory() {
        let mut wire = Vec::new();
        write_frame(&mut wire, b"hello frames").unwrap();
        write_frame(&mut wire, b"").unwrap();
        let mut r = &wire[..];
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b"hello frames".to_vec());
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), Vec::<u8>::new());
        // clean EOF at a frame boundary
        assert!(read_frame(&mut r).unwrap().is_none());
    }

    #[test]
    fn torn_frames_error() {
        let mut wire = Vec::new();
        write_frame(&mut wire, b"full frame").unwrap();
        // torn inside the payload and inside the length prefix
        for cut in [2usize, 4, 7] {
            let mut r = &wire[..cut];
            assert!(read_frame(&mut r).is_err(), "cut at {cut} must error");
        }
    }

    #[test]
    fn hostile_length_prefix_rejected() {
        let wire = u32::MAX.to_le_bytes();
        let mut r = &wire[..];
        let err = read_frame(&mut r).unwrap_err();
        assert!(err.to_string().contains("cap"), "{err}");
    }

    #[test]
    fn declared_length_longer_than_stream_errors() {
        let mut wire = 1000u32.to_le_bytes().to_vec();
        wire.extend_from_slice(&[7u8; 10]); // only 10 of 1000 bytes present
        let mut r = &wire[..];
        let err = read_frame(&mut r).unwrap_err();
        assert!(err.to_string().contains("mid-frame"), "{err}");
    }

    #[test]
    fn hello_roundtrip_and_validation() {
        let bytes = encode_hello(ROLE_SITE, 42);
        let h = read_hello(&mut &bytes[..]).unwrap();
        assert_eq!((h.version, h.role, h.site_id), (PROTOCOL_VERSION, ROLE_SITE, 42));

        let mut bad_magic = bytes;
        bad_magic[0] = b'X';
        assert!(read_hello(&mut &bad_magic[..]).is_err());

        assert!(check_version(PROTOCOL_VERSION).is_ok());
        let err = check_version(PROTOCOL_VERSION + 1).unwrap_err();
        assert!(err.to_string().contains("version mismatch"), "{err}");
    }

    #[test]
    fn zero_io_timeout_means_disabled() {
        assert_eq!(opt_timeout(Duration::ZERO), None);
        assert_eq!(opt_timeout(Duration::from_secs(3)), Some(Duration::from_secs(3)));
    }
}
