//! Simulated site↔leader network with exact byte accounting.
//!
//! The paper runs all "sites" on one laptop and reasons about communication
//! qualitatively ("only those codewords need to be transmitted"). This
//! module makes that quantitative: every protocol message is serialized
//! through [`wire`], counted per link and direction, and assigned a
//! simulated transfer time `latency + bytes / bandwidth` under a
//! configurable [`LinkSpec`]. Benchmarks report both the byte totals and
//! the modeled transfer times (DESIGN.md ablation A3).
//!
//! Transport is in-process (`mpsc` channels between the leader and each
//! site thread); the wire format is the real ABI, so swapping in TCP later
//! only replaces this file.

pub mod wire;

use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

pub use wire::Message;

/// Bandwidth/latency model of one site↔leader link.
#[derive(Clone, Copy, Debug)]
pub struct LinkSpec {
    /// Sustained bandwidth, bytes/second.
    pub bandwidth_bps: f64,
    /// One-way latency.
    pub latency: Duration,
}

impl Default for LinkSpec {
    /// A WAN-ish default: 100 Mbit/s, 20 ms one way — the regime the paper
    /// targets (geo-distributed business sites).
    fn default() -> Self {
        LinkSpec { bandwidth_bps: 12.5e6, latency: Duration::from_millis(20) }
    }
}

impl LinkSpec {
    /// Modeled one-way transfer time for a frame of `bytes`.
    pub fn transfer_time(&self, bytes: u64) -> Duration {
        self.latency + Duration::from_secs_f64(bytes as f64 / self.bandwidth_bps)
    }
}

/// Byte/time counters for one direction of one link.
#[derive(Clone, Copy, Debug, Default)]
pub struct DirStats {
    pub frames: u64,
    pub bytes: u64,
    /// Accumulated modeled transfer time (not wall clock).
    pub sim_time: Duration,
}

/// Counters for one site's link.
#[derive(Clone, Copy, Debug, Default)]
pub struct LinkStats {
    pub to_leader: DirStats,
    pub to_site: DirStats,
}

/// Aggregated communication report for a pipeline run.
#[derive(Clone, Debug, Default)]
pub struct NetReport {
    pub per_site: Vec<LinkStats>,
}

impl NetReport {
    pub fn total_bytes(&self) -> u64 {
        self.per_site.iter().map(|l| l.to_leader.bytes + l.to_site.bytes).sum()
    }

    /// Max over sites of the modeled transfer time (links operate in
    /// parallel, mirroring the paper's max-over-sites timing).
    pub fn max_link_time(&self) -> Duration {
        self.per_site
            .iter()
            .map(|l| l.to_leader.sim_time + l.to_site.sim_time)
            .max()
            .unwrap_or_default()
    }
}

struct Shared {
    stats: Mutex<Vec<LinkStats>>,
    spec: LinkSpec,
}

/// Leader-side handle to the star network.
pub struct LeaderNet {
    shared: Arc<Shared>,
    from_sites: Receiver<(usize, Vec<u8>)>,
    to_sites: Vec<Sender<Vec<u8>>>,
}

/// Site-side handle (moved into the site's thread).
pub struct SiteNet {
    shared: Arc<Shared>,
    site_id: usize,
    to_leader: Sender<(usize, Vec<u8>)>,
    from_leader: Receiver<Vec<u8>>,
}

/// Build a star topology: one leader, `n_sites` sites, all links sharing
/// `spec`. Returns the leader handle plus one handle per site.
pub fn star(n_sites: usize, spec: LinkSpec) -> (LeaderNet, Vec<SiteNet>) {
    let shared = Arc::new(Shared { stats: Mutex::new(vec![LinkStats::default(); n_sites]), spec });
    let (up_tx, up_rx) = std::sync::mpsc::channel::<(usize, Vec<u8>)>();
    let mut to_sites = Vec::with_capacity(n_sites);
    let mut site_handles = Vec::with_capacity(n_sites);
    for site_id in 0..n_sites {
        let (down_tx, down_rx) = std::sync::mpsc::channel::<Vec<u8>>();
        to_sites.push(down_tx);
        site_handles.push(SiteNet {
            shared: shared.clone(),
            site_id,
            to_leader: up_tx.clone(),
            from_leader: down_rx,
        });
    }
    (LeaderNet { shared, from_sites: up_rx, to_sites }, site_handles)
}

impl LeaderNet {
    /// Send `msg` to `site`.
    pub fn send(&self, site: usize, msg: &Message) -> Result<()> {
        let frame = wire::encode(msg);
        {
            let mut stats = self.shared.stats.lock().unwrap();
            let dir = &mut stats[site].to_site;
            dir.frames += 1;
            dir.bytes += frame.len() as u64;
            dir.sim_time += self.shared.spec.transfer_time(frame.len() as u64);
        }
        self.to_sites[site].send(frame).context("site channel closed")?;
        Ok(())
    }

    /// Blocking receive of the next message from any site.
    pub fn recv(&self) -> Result<(usize, Message)> {
        let (site, frame) = self.from_sites.recv().context("all site channels closed")?;
        let msg = wire::decode(&frame)?;
        Ok((site, msg))
    }

    /// Receive with a timeout (failure-injection tests use this).
    pub fn recv_timeout(&self, timeout: Duration) -> Result<(usize, Message)> {
        let (site, frame) =
            self.from_sites.recv_timeout(timeout).context("timed out waiting for sites")?;
        let msg = wire::decode(&frame)?;
        Ok((site, msg))
    }

    /// Snapshot of the per-link counters.
    pub fn report(&self) -> NetReport {
        NetReport { per_site: self.shared.stats.lock().unwrap().clone() }
    }

    pub fn n_sites(&self) -> usize {
        self.to_sites.len()
    }
}

impl SiteNet {
    pub fn site_id(&self) -> usize {
        self.site_id
    }

    /// Send `msg` up to the leader.
    pub fn send(&self, msg: &Message) -> Result<()> {
        let frame = wire::encode(msg);
        {
            let mut stats = self.shared.stats.lock().unwrap();
            let dir = &mut stats[self.site_id].to_leader;
            dir.frames += 1;
            dir.bytes += frame.len() as u64;
            dir.sim_time += self.shared.spec.transfer_time(frame.len() as u64);
        }
        self.to_leader.send((self.site_id, frame)).context("leader channel closed")?;
        Ok(())
    }

    /// Blocking receive of the next leader message.
    pub fn recv(&self) -> Result<Message> {
        let frame = self.from_leader.recv().context("leader channel closed")?;
        wire::decode(&frame)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_and_accounting() {
        let (leader, sites) = star(2, LinkSpec::default());
        let s0 = &sites[0];
        s0.send(&Message::Sigma(1.0)).unwrap();
        let (id, msg) = leader.recv().unwrap();
        assert_eq!(id, 0);
        assert_eq!(msg, Message::Sigma(1.0));

        leader.send(0, &Message::Ack).unwrap();
        assert_eq!(s0.recv().unwrap(), Message::Ack);

        let rep = leader.report();
        assert_eq!(rep.per_site[0].to_leader.frames, 1);
        assert_eq!(rep.per_site[0].to_leader.bytes, 5); // tag + f32
        assert_eq!(rep.per_site[0].to_site.frames, 1);
        assert_eq!(rep.per_site[0].to_site.bytes, 1);
        assert_eq!(rep.per_site[1].to_leader.frames, 0);
        assert_eq!(rep.total_bytes(), 6);
    }

    #[test]
    fn transfer_time_model() {
        let spec = LinkSpec { bandwidth_bps: 1000.0, latency: Duration::from_millis(10) };
        let t = spec.transfer_time(500);
        assert_eq!(t, Duration::from_millis(510));
    }

    #[test]
    fn concurrent_sites_to_leader() {
        let (leader, sites) = star(4, LinkSpec::default());
        std::thread::scope(|s| {
            for site in sites {
                s.spawn(move || {
                    site.send(&Message::Labels {
                        site: site.site_id() as u32,
                        labels: vec![site.site_id() as u16; 3],
                    })
                    .unwrap();
                });
            }
            let mut seen = std::collections::HashSet::new();
            for _ in 0..4 {
                let (id, msg) = leader.recv().unwrap();
                match msg {
                    Message::Labels { site, labels } => {
                        assert_eq!(site as usize, id);
                        assert_eq!(labels, vec![id as u16; 3]);
                    }
                    other => panic!("unexpected {other:?}"),
                }
                seen.insert(id);
            }
            assert_eq!(seen.len(), 4);
        });
        let rep = leader.report();
        assert!(rep.max_link_time() > Duration::ZERO);
    }

    #[test]
    fn recv_timeout_fires() {
        let (leader, _sites) = star(1, LinkSpec::default());
        let err = leader.recv_timeout(Duration::from_millis(20));
        assert!(err.is_err());
    }
}
