//! Site↔leader star network with exact byte accounting, over pluggable
//! transports.
//!
//! The paper runs all "sites" on one laptop and reasons about communication
//! qualitatively ("only those codewords need to be transmitted"). This
//! module makes that quantitative — and, since the TCP backend, literal:
//! every protocol message is serialized through [`wire`], counted per link
//! and direction, and assigned a simulated transfer time
//! `latency + bytes / bandwidth` under a configurable [`LinkSpec`].
//! Benchmarks report both the byte totals and the modeled transfer times
//! (DESIGN.md ablation A3).
//!
//! Delivery is a [`transport`] backend:
//!
//! * [`channel`] — in-process `mpsc` star (default; `dsc run`, tests,
//!   benches). Sites are threads of the coordinator process.
//! * [`tcp`] — real sockets for separate leader/site processes
//!   (`dsc leader` / `dsc site`), with length-prefixed frames, a versioned
//!   handshake, and read/write timeouts.
//!
//! Accounting lives *above* the seam, on the leader's side of every link:
//! [`LeaderNet`] counts each encoded frame as it sends (`to_site`) or
//! receives (`to_leader`) it. Both backends therefore report identical
//! [`NetReport`] counters for the same protocol run — transport framing
//! (TCP length prefixes, the handshake) is deliberately excluded.
//! `docs/PROTOCOL.md` specifies the wire format; `docs/DEPLOY.md` covers
//! running the star across real machines.

pub mod channel;
pub mod tcp;
pub mod transport;
pub mod wire;

use std::sync::Mutex;
use std::time::Duration;

use anyhow::Result;

pub use transport::{LeaderTransport, SiteTransport};
pub use wire::{JobReport, JobSpec, LinkReport, Message, RejectCode};

/// Bandwidth/latency model of one site↔leader link.
#[derive(Clone, Copy, Debug)]
pub struct LinkSpec {
    /// Sustained bandwidth, bytes/second.
    pub bandwidth_bps: f64,
    /// One-way latency.
    pub latency: Duration,
}

impl Default for LinkSpec {
    /// A WAN-ish default: 100 Mbit/s, 20 ms one way — the regime the paper
    /// targets (geo-distributed business sites).
    fn default() -> Self {
        LinkSpec { bandwidth_bps: 12.5e6, latency: Duration::from_millis(20) }
    }
}

impl LinkSpec {
    /// Modeled one-way transfer time for a frame of `bytes`.
    pub fn transfer_time(&self, bytes: u64) -> Duration {
        self.latency + Duration::from_secs_f64(bytes as f64 / self.bandwidth_bps)
    }
}

/// Byte/time counters for one direction of one link.
#[derive(Clone, Copy, Debug, Default)]
pub struct DirStats {
    pub frames: u64,
    pub bytes: u64,
    /// Accumulated modeled transfer time (not wall clock).
    pub sim_time: Duration,
}

/// Counters for one site's link.
#[derive(Clone, Copy, Debug, Default)]
pub struct LinkStats {
    pub to_leader: DirStats,
    pub to_site: DirStats,
}

impl LinkStats {
    /// Count one frame of `bytes` in the given direction under `spec`'s
    /// transfer-time model (the job server accounts per *run* with this;
    /// [`LeaderNet`] keeps per-*connection* counters the same way).
    pub fn account(&mut self, to_leader: bool, bytes: usize, spec: &LinkSpec) {
        let dir = if to_leader { &mut self.to_leader } else { &mut self.to_site };
        dir.frames += 1;
        dir.bytes += bytes as u64;
        dir.sim_time += spec.transfer_time(bytes as u64);
    }

    /// The wire form used inside [`wire::JobReport`] (nanosecond
    /// truncation to u64 is safe for ~585 years of simulated transfer).
    pub fn to_wire(&self) -> LinkReport {
        LinkReport {
            up_frames: self.to_leader.frames,
            up_bytes: self.to_leader.bytes,
            up_sim_ns: self.to_leader.sim_time.as_nanos() as u64,
            down_frames: self.to_site.frames,
            down_bytes: self.to_site.bytes,
            down_sim_ns: self.to_site.sim_time.as_nanos() as u64,
        }
    }
}

/// Aggregated communication report for a pipeline run.
#[derive(Clone, Debug, Default)]
pub struct NetReport {
    pub per_site: Vec<LinkStats>,
}

impl NetReport {
    pub fn total_bytes(&self) -> u64 {
        self.per_site.iter().map(|l| l.to_leader.bytes + l.to_site.bytes).sum()
    }

    /// Max over sites of the modeled transfer time (links operate in
    /// parallel, mirroring the paper's max-over-sites timing).
    pub fn max_link_time(&self) -> Duration {
        self.per_site
            .iter()
            .map(|l| l.to_leader.sim_time + l.to_site.sim_time)
            .max()
            .unwrap_or_default()
    }
}

/// Leader-side handle to the star network: encodes/decodes protocol
/// messages and keeps the per-link byte counters, independent of which
/// transport moves the frames.
pub struct LeaderNet {
    transport: Box<dyn LeaderTransport>,
    spec: LinkSpec,
    stats: Mutex<Vec<LinkStats>>,
}

/// Site-side handle (moved into the site's thread, or owned by the site
/// daemon process).
pub struct SiteNet {
    transport: Box<dyn SiteTransport>,
}

/// Build the default in-process star: one leader, `n_sites` site threads,
/// all links sharing `spec`. Swap the transport with [`LeaderNet::over`] /
/// [`SiteNet::over`] for TCP.
pub fn star(n_sites: usize, spec: LinkSpec) -> (LeaderNet, Vec<SiteNet>) {
    let (leader, sites) = channel::star(n_sites);
    (
        LeaderNet::over(Box::new(leader), spec),
        sites.into_iter().map(|s| SiteNet::over(Box::new(s))).collect(),
    )
}

impl LeaderNet {
    /// Wrap a leader transport with accounting under `spec`.
    pub fn over(transport: Box<dyn LeaderTransport>, spec: LinkSpec) -> LeaderNet {
        let n = transport.n_sites();
        LeaderNet { transport, spec, stats: Mutex::new(vec![LinkStats::default(); n]) }
    }

    fn account(&self, site: usize, to_leader: bool, bytes: usize) {
        self.stats.lock().unwrap()[site].account(to_leader, bytes, &self.spec);
    }

    /// Send `msg` to `site`.
    pub fn send(&self, site: usize, msg: &Message) -> Result<()> {
        let frame = wire::encode(msg);
        self.account(site, false, frame.len());
        self.transport.send(site, frame)
    }

    /// Blocking receive of the next message from any site.
    pub fn recv(&self) -> Result<(usize, Message)> {
        self.recv_inner(None)
    }

    /// Receive with a timeout (straggler deadlines and failure-injection
    /// tests use this).
    pub fn recv_timeout(&self, timeout: Duration) -> Result<(usize, Message)> {
        self.recv_inner(Some(timeout))
    }

    fn recv_inner(&self, timeout: Option<Duration>) -> Result<(usize, Message)> {
        let (site, frame) = self.transport.recv(timeout)?;
        self.account(site, true, frame.len());
        let msg = wire::decode(&frame)?;
        Ok((site, msg))
    }

    /// Snapshot of the per-link counters.
    pub fn report(&self) -> NetReport {
        NetReport { per_site: self.stats.lock().unwrap().clone() }
    }

    pub fn n_sites(&self) -> usize {
        self.transport.n_sites()
    }
}

impl SiteNet {
    /// Wrap a site transport. No counters on this side: the leader accounts
    /// both directions of its links, so counts cannot drift between
    /// backends (a site daemon has no way to see the whole star anyway).
    pub fn over(transport: Box<dyn SiteTransport>) -> SiteNet {
        SiteNet { transport }
    }

    pub fn site_id(&self) -> usize {
        self.transport.site_id()
    }

    /// Send `msg` up to the leader.
    pub fn send(&self, msg: &Message) -> Result<()> {
        self.transport.send(wire::encode(msg))
    }

    /// Blocking receive of the next leader message.
    pub fn recv(&self) -> Result<Message> {
        wire::decode(&self.transport.recv()?)
    }

    /// Receive where a clean close is `Ok(None)` — the multi-run session
    /// loop ([`crate::site::session`]) ends this way when the leader shuts
    /// down between runs.
    pub fn recv_opt(&self) -> Result<Option<Message>> {
        match self.transport.recv_opt()? {
            Some(frame) => Ok(Some(wire::decode(&frame)?)),
            None => Ok(None),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_and_accounting() {
        let (leader, sites) = star(2, LinkSpec::default());
        let s0 = &sites[0];
        s0.send(&Message::Sigma(1.0)).unwrap();
        let (id, msg) = leader.recv().unwrap();
        assert_eq!(id, 0);
        assert_eq!(msg, Message::Sigma(1.0));

        leader.send(0, &Message::Ack).unwrap();
        assert_eq!(s0.recv().unwrap(), Message::Ack);

        let rep = leader.report();
        assert_eq!(rep.per_site[0].to_leader.frames, 1);
        assert_eq!(rep.per_site[0].to_leader.bytes, 5); // tag + f32
        assert_eq!(rep.per_site[0].to_site.frames, 1);
        assert_eq!(rep.per_site[0].to_site.bytes, 1);
        assert_eq!(rep.per_site[1].to_leader.frames, 0);
        assert_eq!(rep.total_bytes(), 6);
    }

    #[test]
    fn transfer_time_model() {
        let spec = LinkSpec { bandwidth_bps: 1000.0, latency: Duration::from_millis(10) };
        let t = spec.transfer_time(500);
        assert_eq!(t, Duration::from_millis(510));
    }

    #[test]
    fn concurrent_sites_to_leader() {
        let (leader, sites) = star(4, LinkSpec::default());
        std::thread::scope(|s| {
            for site in sites {
                s.spawn(move || {
                    site.send(&Message::Labels {
                        site: site.site_id() as u32,
                        labels: vec![site.site_id() as u16; 3],
                    })
                    .unwrap();
                });
            }
            let mut seen = std::collections::HashSet::new();
            for _ in 0..4 {
                let (id, msg) = leader.recv().unwrap();
                match msg {
                    Message::Labels { site, labels } => {
                        assert_eq!(site as usize, id);
                        assert_eq!(labels, vec![id as u16; 3]);
                    }
                    other => panic!("unexpected {other:?}"),
                }
                seen.insert(id);
            }
            assert_eq!(seen.len(), 4);
        });
        let rep = leader.report();
        assert!(rep.max_link_time() > Duration::ZERO);
    }

    #[test]
    fn recv_timeout_fires() {
        let (leader, _sites) = star(1, LinkSpec::default());
        let err = leader.recv_timeout(Duration::from_millis(20));
        assert!(err.is_err());
    }
}
