//! The transport seam: how raw protocol frames move between the leader and
//! the sites.
//!
//! Everything above this layer — the [`super::wire`] codec, the byte
//! accounting in [`super::LeaderNet`]/[`super::SiteNet`], and the whole
//! coordinator protocol — is transport-agnostic. A backend only has to move
//! opaque `Vec<u8>` frames reliably and in order per link:
//!
//! * [`super::channel`] — in-process `mpsc` star (the default for tests,
//!   benches and `dsc run`): zero-cost links, every "site" is a thread.
//!   Also carries the fault plan and virtual clock behind the channel
//!   job-server harness (`crate::coordinator::harness`).
//! * [`super::tcp`] — real sockets for the leader/site daemon modes
//!   (`dsc leader` / `dsc site`): length-prefixed frames, a versioned
//!   handshake, read/write timeouts.
//!
//! The multi-run job server sits one level up: its reactor moves raw
//! frames through a `ServerDriver` (the acceptor / per-link reader /
//! re-dial edge), with a TCP and a channel implementation over the same
//! primitives these backends expose.
//!
//! Because byte accounting happens *above* this seam (the leader counts
//! each encoded frame as it sends/receives it), the per-link counters in
//! [`super::NetReport`] are identical across backends by construction —
//! `examples/tcp_cluster.rs` and `rust/tests/tcp_transport.rs` pin that.

use std::time::Duration;

use anyhow::{bail, Result};

/// Leader-side frame mover for a star of `n_sites` links.
///
/// Implementations must deliver frames reliably and in order per link;
/// `recv` is a single mailbox over all sites (frames from different sites
/// may interleave arbitrarily). Not required to support concurrent calls.
pub trait LeaderTransport: Send {
    /// Number of site links in the star.
    fn n_sites(&self) -> usize;

    /// Deliver one frame to `site`. Ownership passes so the channel
    /// backend can move the encoded buffer straight into its queue without
    /// a copy (TCP serializes from the same buffer).
    fn send(&self, site: usize, frame: Vec<u8>) -> Result<()>;

    /// Next frame from any site; blocks up to `timeout` (`None` = forever).
    /// An error means a link failed or the wait timed out — the frame, if
    /// any was in flight, is lost with the connection.
    fn recv(&self, timeout: Option<Duration>) -> Result<(usize, Vec<u8>)>;
}

/// Site-side frame mover for one leader link.
pub trait SiteTransport: Send {
    /// This site's id in the star (assigned by the leader).
    fn site_id(&self) -> usize;

    /// Deliver one frame to the leader (ownership passes; see
    /// [`LeaderTransport::send`]).
    fn send(&self, frame: Vec<u8>) -> Result<()>;

    /// Next frame from the leader; `Ok(None)` means the leader closed the
    /// link *cleanly at a frame boundary* (a multi-run session ending).
    /// Blocks until a frame arrives, the link dies, or — where the backend
    /// supports an idle deadline — the link has been silent too long. Sites
    /// wait out the leader's long central phase here, so ordinary idle time
    /// must not error.
    fn recv_opt(&self) -> Result<Option<Vec<u8>>>;

    /// Next frame from the leader, where a clean close is also an error —
    /// the single-run protocol ([`crate::site::serve`]) is mid-run for its
    /// whole lifetime, so *any* close is premature.
    fn recv(&self) -> Result<Vec<u8>> {
        match self.recv_opt()? {
            Some(frame) => Ok(frame),
            None => bail!("leader closed the connection"),
        }
    }
}
