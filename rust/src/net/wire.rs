//! Binary wire format for coordinator ⇄ site traffic.
//!
//! Everything that crosses a (simulated) link is serialized through this
//! codec, so the byte counts the benchmarks report are the real size of
//! the protocol messages, not estimates. Little-endian, length-prefixed:
//!
//! ```text
//! frame   := tag:u8 payload
//! CODEBOOK(1) := site:u32 dim:u32 n:u32 codewords:[f32; n*dim] weights:[u32; n]
//! LABELS(2)   := site:u32 n:u32 labels:[u16; n]
//! SIGMA(3)    := sigma:f32            (leader → sites broadcast, D3 tuning)
//! ACK(4)      :=
//! ```
//!
//! Codebook frames are exactly what the paper transmits (codewords + group
//! sizes); label frames are the populated memberships coming back.

use anyhow::{bail, Result};

/// A protocol message.
#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    /// Site → leader: the DML output (Algorithm 1, line 8 input).
    Codebook { site: u32, dim: u32, codewords: Vec<f32>, weights: Vec<u32> },
    /// Leader → site: cluster label per codeword (Algorithm 1, line 10).
    Labels { site: u32, labels: Vec<u16> },
    /// Leader → sites: broadcast of the affinity bandwidth (when sites
    /// pre-scale data) — small control traffic, counted like the rest.
    Sigma(f32),
    Ack,
}

const TAG_CODEBOOK: u8 = 1;
const TAG_LABELS: u8 = 2;
const TAG_SIGMA: u8 = 3;
const TAG_ACK: u8 = 4;

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn new() -> Self {
        Writer { buf: Vec::new() }
    }
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("truncated frame: need {n} bytes at offset {}", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn done(&self) -> bool {
        self.pos == self.buf.len()
    }
}

/// Serialize a message to a frame.
pub fn encode(msg: &Message) -> Vec<u8> {
    let mut w = Writer::new();
    match msg {
        Message::Codebook { site, dim, codewords, weights } => {
            assert_eq!(codewords.len(), (*dim as usize) * weights.len());
            w.u8(TAG_CODEBOOK);
            w.u32(*site);
            w.u32(*dim);
            w.u32(weights.len() as u32);
            for v in codewords {
                w.f32(*v);
            }
            for v in weights {
                w.u32(*v);
            }
        }
        Message::Labels { site, labels } => {
            w.u8(TAG_LABELS);
            w.u32(*site);
            w.u32(labels.len() as u32);
            for v in labels {
                w.u16(*v);
            }
        }
        Message::Sigma(s) => {
            w.u8(TAG_SIGMA);
            w.f32(*s);
        }
        Message::Ack => w.u8(TAG_ACK),
    }
    w.buf
}

/// Deserialize a frame. Errors on truncation, trailing garbage, overflow or
/// unknown tags (a hostile/corrupt frame must not panic the coordinator).
pub fn decode(frame: &[u8]) -> Result<Message> {
    let mut r = Reader::new(frame);
    let tag = r.u8()?;
    let msg = match tag {
        TAG_CODEBOOK => {
            let site = r.u32()?;
            let dim = r.u32()?;
            let n = r.u32()?;
            let total = (dim as u64) * (n as u64);
            if total > 100_000_000 {
                bail!("codebook too large: {n} codes × {dim} dims");
            }
            let mut codewords = Vec::with_capacity(total as usize);
            for _ in 0..total {
                codewords.push(r.f32()?);
            }
            let mut weights = Vec::with_capacity(n as usize);
            for _ in 0..n {
                weights.push(r.u32()?);
            }
            Message::Codebook { site, dim, codewords, weights }
        }
        TAG_LABELS => {
            let site = r.u32()?;
            let n = r.u32()?;
            if n > 500_000_000 {
                bail!("label frame too large: {n}");
            }
            let mut labels = Vec::with_capacity(n as usize);
            for _ in 0..n {
                labels.push(r.u16()?);
            }
            Message::Labels { site, labels }
        }
        TAG_SIGMA => Message::Sigma(r.f32()?),
        TAG_ACK => Message::Ack,
        t => bail!("unknown message tag {t}"),
    };
    if !r.done() {
        bail!("trailing bytes after frame");
    }
    Ok(msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codebook_roundtrip() {
        let msg = Message::Codebook {
            site: 3,
            dim: 2,
            codewords: vec![1.5, -2.0, 0.0, 7.25],
            weights: vec![10, 20],
        };
        let frame = encode(&msg);
        assert_eq!(decode(&frame).unwrap(), msg);
        // frame size = 1 + 4 + 4 + 4 + 4*4 + 2*4 = 37
        assert_eq!(frame.len(), 37);
    }

    #[test]
    fn labels_roundtrip() {
        let msg = Message::Labels { site: 0, labels: vec![0, 1, 2, 65535] };
        assert_eq!(decode(&encode(&msg)).unwrap(), msg);
    }

    #[test]
    fn sigma_and_ack_roundtrip() {
        assert_eq!(decode(&encode(&Message::Sigma(0.75))).unwrap(), Message::Sigma(0.75));
        assert_eq!(decode(&encode(&Message::Ack)).unwrap(), Message::Ack);
    }

    #[test]
    fn truncated_frame_errors() {
        let frame = encode(&Message::Labels { site: 0, labels: vec![1, 2, 3] });
        for cut in 0..frame.len() {
            assert!(decode(&frame[..cut]).is_err(), "cut at {cut} should fail");
        }
    }

    #[test]
    fn trailing_bytes_error() {
        let mut frame = encode(&Message::Ack);
        frame.push(0);
        assert!(decode(&frame).is_err());
    }

    #[test]
    fn unknown_tag_errors() {
        assert!(decode(&[99]).is_err());
    }

    #[test]
    fn hostile_length_does_not_allocate() {
        // tag CODEBOOK with dim and n at u32::MAX must error, not OOM
        let mut frame = vec![1u8];
        frame.extend_from_slice(&0u32.to_le_bytes());
        frame.extend_from_slice(&u32::MAX.to_le_bytes());
        frame.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode(&frame).is_err());
    }
}
