//! Binary wire format for coordinator ⇄ site traffic.
//!
//! Everything that crosses a link — simulated channel or real TCP — is
//! serialized through this codec, so the byte counts the benchmarks report
//! are the real size of the protocol messages, not estimates. Little-endian
//! throughout:
//!
//! ```text
//! frame   := tag:u8 payload
//! CODEBOOK(1) := site:u32 dim:u32 n:u32 codewords:[f32; n*dim] weights:[u32; n]
//! LABELS(2)   := site:u32 n:u32 labels:[u16; n]
//! SIGMA(3)    := sigma:f32            (leader → sites broadcast, D3 tuning)
//! ACK(4)      :=
//! SITEINFO(5) := site:u32 n_points:u64 dim:u32     (site → leader, registration)
//! DMLREQ(6)   := site:u32 dml:u8 target_codes:u32
//!                max_iters:u32 tol:f64 seed:u64    (leader → site, work order)
//! ```
//!
//! Tags 7–11 are the **run-scoped** family used by the multi-run job
//! server (`dsc leader --serve`): the same payloads as tags 1/2/5/6 with a
//! leading `run:u32`, so frames of interleaved runs can share one site
//! link. Tags 12–20 are the client/job-control plane (`dsc submit`):
//!
//! ```text
//! RUNSTART(7)    := run:u32                        (leader → site, open a run)
//! RSITEINFO(8)   := run:u32 SITEINFO payload       (site → leader)
//! RDMLREQ(9)     := run:u32 DMLREQ payload         (leader → site)
//! RCODEBOOK(10)  := run:u32 CODEBOOK payload       (site → leader)
//! RLABELS(11)    := run:u32 LABELS payload         (leader → site)
//! LABELSPULL(12) := run:u32                        (client → leader → site)
//! SITELABELS(13) := run:u32 site:u32 n:u32 labels:[u16; n]
//!                                                  (site → leader → client)
//! SUBMIT(14)     := job spec                       (client → leader)
//! JOBACCEPT(15)  := run:u32                        (leader → client)
//! JOBDONE(16)    := run:u32 job report             (leader → client)
//! REJECT(17)     := run:u32 len:u32 msg:[u8; len]  (leader → client / site → leader)
//! SUBMITPRI(18)  := job spec priority:u32          (client → leader)
//! JOBACCEPT2(19) := run:u32 position:u32 eta_ns:u64
//!                                                  (leader → client)
//! REJECT2(20)    := run:u32 code:u8 detail:u64 len:u32 msg:[u8; len]
//!                                                  (leader → client)
//! ```
//!
//! Tags 18–20 are the **modern client dialect**: a client that submits with
//! SUBMITPRI(18) carries an explicit scheduling priority and is answered
//! with JOBACCEPT2(19) (queue position + ETA) and structured REJECT2(20)
//! frames (machine-readable reason code + detail). Clients speaking the
//! legacy SUBMIT(14) keep getting byte-identical JOBACCEPT(15)/REJECT(17),
//! so pre-existing deployments see no change on the wire.
//!
//! Tags 22–25 are the **journal replication** (`JREPL`) family spoken on
//! the link between a serving primary and a `dsc leader --standby`:
//!
//! ```text
//! JREPLHELLO(22)  := records:u64 valid_bytes:u64   (standby → primary)
//! JREPLSTART(23)  := from_record:u64               (primary → standby)
//! JREPLRECORD(24) := len:u32 framed:[u8; len]      (primary → standby)
//! JREPLBEAT(25)   :=                               (primary → standby)
//! ```
//!
//! JREPLRECORD carries one of the run journal's own CRC-framed records
//! (`coordinator/journal.rs`: `len crc payload`) **verbatim** — there is
//! no second serialization of journal history, so a standby's journal file
//! is byte-identical to the primary's by construction. JREPLHELLO opens
//! the anti-entropy exchange (what the standby already holds), JREPLSTART
//! names the record index streaming resumes from (0 orders a full resync),
//! and JREPLBEAT keeps the link's idle deadline — the standby's promotion
//! trigger — honest while the primary has nothing to commit.
//!
//! Codebook frames are exactly what the paper transmits (codewords + group
//! sizes); label frames are the populated memberships coming back. SiteInfo
//! and DmlRequest are the small control handshake that lets the leader size
//! each site's codeword budget without seeing the data. The byte-level
//! layout, framing on TCP, and forward-compatibility rules are documented
//! in `docs/PROTOCOL.md`.

use anyhow::{bail, Result};

use crate::dml::DmlKind;
use crate::spectral::{Algo, Bandwidth, GraphKind};

/// A protocol message.
#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    /// Site → leader: the DML output (Algorithm 1, line 8 input).
    Codebook { site: u32, dim: u32, codewords: Vec<f32>, weights: Vec<u32> },
    /// Leader → site: cluster label per codeword (Algorithm 1, line 10).
    Labels { site: u32, labels: Vec<u16> },
    /// Leader → sites: broadcast of the affinity bandwidth (when sites
    /// pre-scale data) — small control traffic, counted like the rest.
    Sigma(f32),
    Ack,
    /// Site → leader: local shard shape, sent at the start of a run so the
    /// leader can size codeword budgets proportionally to site sizes.
    SiteInfo { site: u32, n_points: u64, dim: u32 },
    /// Leader → site: the DML work order (transform, budget, Lloyd knobs,
    /// the site's forked seed).
    DmlRequest { site: u32, dml: DmlKind, target_codes: u32, max_iters: u32, tol: f64, seed: u64 },
    /// Leader → site (multi-run session): open run `run` on this link. The
    /// site answers with a [`Message::RunSiteInfo`] for that run.
    RunStart { run: u32 },
    /// Run-scoped [`Message::SiteInfo`].
    RunSiteInfo { run: u32, site: u32, n_points: u64, dim: u32 },
    /// Run-scoped [`Message::DmlRequest`].
    RunDmlRequest {
        run: u32,
        site: u32,
        dml: DmlKind,
        target_codes: u32,
        max_iters: u32,
        tol: f64,
        seed: u64,
    },
    /// Run-scoped [`Message::Codebook`].
    RunCodebook { run: u32, site: u32, dim: u32, codewords: Vec<f32>, weights: Vec<u32> },
    /// Run-scoped [`Message::Labels`].
    RunLabels { run: u32, site: u32, labels: Vec<u16> },
    /// Client → leader (and leader → site): request the populated per-point
    /// labels of a completed run (`[leader] allow_label_pull` gates it).
    LabelsPull { run: u32 },
    /// Site → leader (and leader → client): one site's populated per-point
    /// labels for a completed run, in local shard row order.
    SiteLabels { run: u32, site: u32, labels: Vec<u16> },
    /// Client → leader: enqueue a clustering job.
    Submit(JobSpec),
    /// Leader → client: the job was queued under this run id.
    JobAccept { run: u32 },
    /// Leader → client: the run finished; summary + per-link counters.
    JobDone { run: u32, report: JobReport },
    /// Leader → client or site → leader: a request was refused or a run
    /// failed; `msg` says why. `run = 0` when no run was assigned.
    Reject { run: u32, msg: String },
    /// Client → leader: enqueue a clustering job carrying an explicit
    /// scheduling priority — the modern-dialect twin of
    /// [`Message::Submit`]. Submitting with this tag opts the client into
    /// [`Message::JobAcceptExt`] / [`Message::RejectCoded`] replies.
    SubmitPri(JobSpec),
    /// Leader → client (modern dialect): the job was queued under this run
    /// id; `position` counts the jobs ahead of it at accept time (under
    /// `[leader] fair_queue` it follows the client's own DRR lane
    /// schedule, not the global arrival order) and `eta_ns` is a
    /// start-time estimate from the leader's running mean of central-step
    /// durations. Until the first central completes the leader has no
    /// sample to extrapolate from and sends the documented *unknown*
    /// sentinel `u64::MAX` — `0` means "starts now", never "no estimate".
    JobAcceptExt { run: u32, position: u32, eta_ns: u64 },
    /// Leader → client (modern dialect): structured refusal. `code` says
    /// *why* without string matching, `detail` is a per-code
    /// machine-readable quantity (see [`RejectCode`]), and `msg` stays a
    /// short human-readable sentence.
    RejectCoded { run: u32, code: RejectCode, detail: u64, msg: String },
    /// Site → leader: shard shape *plus* the shard's merkle-style version
    /// digest (`docs/PROTOCOL.md` §"The shard digest"). A streaming site
    /// volunteers it once per session when `[site] report_digest` is on;
    /// `digest` is the chunked-hash root and `chunks` the leaf count.
    /// Legacy [`Message::SiteInfo`] stays byte-frozen — this is a new tag,
    /// and leaders that predate it simply never see the frame.
    SiteInfo2 { site: u32, n_points: u64, dim: u32, digest: u64, chunks: u32 },
    /// Standby → primary: opens journal replication by stating what the
    /// standby already holds — its journal's record count and valid byte
    /// length — so the primary can stream only the missing suffix
    /// (anti-entropy), or order a full resync if the two histories
    /// diverged.
    JreplHello { records: u64, valid_bytes: u64 },
    /// Primary → standby: streaming starts at this record index. When it
    /// is lower than what the standby announced (normally `0`), the
    /// standby's journal does not prefix-match the primary's and must be
    /// truncated before the stream is applied.
    JreplStart { from_record: u64 },
    /// Primary → standby: one run-journal record, as the journal's own
    /// CRC-framed bytes (`len crc payload`) **verbatim**. The standby
    /// validates the frame end to end and appends the identical bytes to
    /// its journal, keeping the two files byte-identical by construction.
    JreplRecord { framed: Vec<u8> },
    /// Primary → standby: an "I am alive" beat sent while there is nothing
    /// to commit, so the standby's idle deadline — its promotion trigger —
    /// only fires when the primary is actually gone.
    JreplHeartbeat,
}

/// Machine-readable refusal reason inside a [`Message::RejectCoded`].
///
/// The `detail` field of the frame qualifies the code: `QueueFull` carries
/// the number of jobs pending, `RateLimited` carries the nanoseconds until
/// the client's token bucket refills; the other codes carry 0.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejectCode {
    /// The submitted spec failed validation.
    BadSpec,
    /// The job queue is at `[leader] queue_depth`.
    QueueFull,
    /// The client exceeded `[leader] admit_rate` (token bucket empty).
    RateLimited,
    /// An accepted run failed (site fault, central error, timeout).
    RunFailed,
    /// A label pull was refused (disabled, unknown run, evicted).
    PullRefused,
}

/// Wire encoding of a [`RejectCode`] (REJECT2 `code` field).
fn reject_code(c: RejectCode) -> u8 {
    match c {
        RejectCode::BadSpec => 1,
        RejectCode::QueueFull => 2,
        RejectCode::RateLimited => 3,
        RejectCode::RunFailed => 4,
        RejectCode::PullRefused => 5,
    }
}

fn reject_from_code(code: u8) -> Result<RejectCode> {
    Ok(match code {
        1 => RejectCode::BadSpec,
        2 => RejectCode::QueueFull,
        3 => RejectCode::RateLimited,
        4 => RejectCode::RunFailed,
        5 => RejectCode::PullRefused,
        other => bail!("unknown reject code {other}"),
    })
}

/// Everything a client must specify for the leader to run one clustering
/// job: the central-step knobs of `PipelineConfig` that are a property of
/// the *job* rather than of the serving deployment (backend, link model and
/// timeouts stay leader-side).
#[derive(Clone, Debug, PartialEq)]
pub struct JobSpec {
    /// DML transform every site runs.
    pub dml: DmlKind,
    /// Total codeword budget, split ∝ site size.
    pub total_codes: u32,
    /// Output clusters.
    pub k_clusters: u32,
    /// Lloyd sweep cap for K-means DML.
    pub kmeans_max_iters: u32,
    /// Relative centroid-shift tolerance for K-means DML.
    pub kmeans_tol: f64,
    /// Master seed; per-site seeds fork from it (run-id independent, so a
    /// job's result is a function of (data, spec) alone).
    pub seed: u64,
    /// Central spectral algorithm.
    pub algo: Algo,
    /// Affinity-graph storage for the central step.
    pub graph: GraphKind,
    /// Weight affinity by codeword group sizes.
    pub weighted: bool,
    /// Affinity bandwidth policy.
    pub bandwidth: Bandwidth,
    /// Scheduling weight under `[leader] fair_queue` (deficit round-robin
    /// serves a client `priority` jobs per round). `1..=MAX_PRIORITY`;
    /// ignored by the FIFO scheduler. Travels only in SUBMITPRI(18) —
    /// legacy SUBMIT(14) frames decode to [`JobSpec::DEFAULT_PRIORITY`].
    pub priority: u32,
}

impl JobSpec {
    /// Priority carried by legacy SUBMIT(14) frames and used when a client
    /// does not care about scheduling weight.
    pub const DEFAULT_PRIORITY: u32 = 1;
    /// Inclusive priority ceiling: bounds the deficit round-robin burst one
    /// client can claim per round, so a hostile priority cannot starve the
    /// ring.
    pub const MAX_PRIORITY: u32 = 16;
}

/// Per-link counters inside a [`JobReport`] (the wire form of one
/// [`super::LinkStats`], directions from the leader's viewpoint).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct LinkReport {
    pub up_frames: u64,
    pub up_bytes: u64,
    pub up_sim_ns: u64,
    pub down_frames: u64,
    pub down_bytes: u64,
    pub down_sim_ns: u64,
}

/// What the leader tells the submitting client when a run completes:
/// everything a leader can know (accuracy lives with whoever holds ground
/// truth) plus the per-link byte counters for exactly this run's frames.
#[derive(Clone, Debug, PartialEq)]
pub struct JobReport {
    /// Codewords the central step clustered.
    pub n_codes: u32,
    /// Bandwidth used by the central step.
    pub sigma: f64,
    /// Central spectral time, nanoseconds.
    pub central_ns: u64,
    /// Run-started → labels-delivered wall time, nanoseconds (queue wait
    /// excluded).
    pub wall_ns: u64,
    /// Per-site link counters, site-id order.
    pub per_site: Vec<LinkReport>,
}

const TAG_CODEBOOK: u8 = 1;
const TAG_LABELS: u8 = 2;
const TAG_SIGMA: u8 = 3;
const TAG_ACK: u8 = 4;
const TAG_SITEINFO: u8 = 5;
const TAG_DMLREQ: u8 = 6;
const TAG_RUNSTART: u8 = 7;
const TAG_RUN_SITEINFO: u8 = 8;
const TAG_RUN_DMLREQ: u8 = 9;
const TAG_RUN_CODEBOOK: u8 = 10;
const TAG_RUN_LABELS: u8 = 11;
const TAG_LABELS_PULL: u8 = 12;
const TAG_SITE_LABELS: u8 = 13;
const TAG_SUBMIT: u8 = 14;
const TAG_JOB_ACCEPT: u8 = 15;
const TAG_JOB_DONE: u8 = 16;
const TAG_REJECT: u8 = 17;
const TAG_SUBMIT_PRI: u8 = 18;
const TAG_JOB_ACCEPT2: u8 = 19;
const TAG_REJECT2: u8 = 20;
const TAG_SITEINFO2: u8 = 21;
const TAG_JREPL_HELLO: u8 = 22;
const TAG_JREPL_START: u8 = 23;
const TAG_JREPL_RECORD: u8 = 24;
const TAG_JREPL_BEAT: u8 = 25;

/// Refusal messages are short human-readable sentences; anything larger is
/// hostile.
const MAX_REJECT_MSG: u32 = 64 * 1024;
/// A replicated journal record may not claim more than the journal's own
/// record ceiling (`coordinator/journal.rs` `MAX_RECORD` plus its 8-byte
/// frame header); a larger length is hostile, not data.
const MAX_JREPL_RECORD: u32 = (1 << 30) + 8;
/// More sites than this in one report is hostile (the star tops out far
/// lower).
const MAX_REPORT_SITES: u32 = 100_000;

/// Little-endian byte builder behind [`encode`]. `pub(crate)` so the run
/// journal (`coordinator/journal.rs`) frames its records with the exact
/// same primitives and discipline as the wire codec.
pub(crate) struct Writer {
    pub(crate) buf: Vec<u8>,
}

impl Writer {
    pub(crate) fn new() -> Self {
        Writer { buf: Vec::new() }
    }
    pub(crate) fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    pub(crate) fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub(crate) fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub(crate) fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub(crate) fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub(crate) fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
}

/// Bounds-checked little-endian cursor behind [`decode`] — shared with the
/// run journal for the same reason as [`Writer`]: one parsing discipline,
/// one set of truncation errors, everywhere bytes come off a disk or a
/// link.
pub(crate) struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }
    pub(crate) fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("truncated frame: need {n} bytes at offset {}", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    pub(crate) fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    pub(crate) fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    pub(crate) fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    pub(crate) fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    pub(crate) fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    pub(crate) fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    /// Bytes left in the frame — the hard ceiling on how many array
    /// elements can still be decoded, used to bound pre-allocation.
    pub(crate) fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
    pub(crate) fn done(&self) -> bool {
        self.pos == self.buf.len()
    }
}

/// Wire encoding of a [`DmlKind`] (DMLREQ `dml` field).
fn dml_code(kind: DmlKind) -> u8 {
    match kind {
        DmlKind::KMeans => 0,
        DmlKind::RpTree => 1,
        DmlKind::RandomSample => 2,
    }
}

fn dml_from_code(code: u8) -> Result<DmlKind> {
    Ok(match code {
        0 => DmlKind::KMeans,
        1 => DmlKind::RpTree,
        2 => DmlKind::RandomSample,
        other => bail!("unknown dml code {other}"),
    })
}

/// Wire encoding of an [`Algo`] (SUBMIT `algo` field).
fn algo_code(a: Algo) -> u8 {
    match a {
        Algo::RecursiveNcut => 0,
        Algo::Njw => 1,
    }
}

fn algo_from_code(code: u8) -> Result<Algo> {
    Ok(match code {
        0 => Algo::RecursiveNcut,
        1 => Algo::Njw,
        other => bail!("unknown algo code {other}"),
    })
}

/// Wire encoding of a [`GraphKind`] as `(graph:u8, knn_k:u32)` — dense
/// carries `knn_k = 0`.
fn graph_code(g: GraphKind) -> (u8, u32) {
    match g {
        GraphKind::Dense => (0, 0),
        GraphKind::Knn { k } => (1, k as u32),
    }
}

fn graph_from_code(code: u8, knn_k: u32) -> Result<GraphKind> {
    Ok(match (code, knn_k) {
        (0, 0) => GraphKind::Dense,
        (0, k) => bail!("dense graph with knn_k = {k}"),
        (1, 0) => bail!("knn graph needs knn_k ≥ 1"),
        (1, k) => GraphKind::Knn { k: k as usize },
        (other, _) => bail!("unknown graph code {other}"),
    })
}

/// Wire encoding of a [`Bandwidth`] policy as `(policy:u8, value:f64)`.
fn bandwidth_code(b: Bandwidth) -> (u8, f64) {
    match b {
        Bandwidth::Fixed(s) => (0, s),
        Bandwidth::MedianScale(s) => (1, s),
        Bandwidth::EigengapSearch { k } => (2, k as f64),
    }
}

fn bandwidth_from_code(code: u8, value: f64) -> Result<Bandwidth> {
    Ok(match code {
        0 => Bandwidth::Fixed(value),
        1 => Bandwidth::MedianScale(value),
        2 => {
            if !(value >= 0.0 && value <= u32::MAX as f64 && value.fract() == 0.0) {
                bail!("eigengap bandwidth k must be a small non-negative integer, got {value}");
            }
            Bandwidth::EigengapSearch { k: value as usize }
        }
        other => bail!("unknown bandwidth policy code {other}"),
    })
}

fn bool_from_code(code: u8, what: &str) -> Result<bool> {
    match code {
        0 => Ok(false),
        1 => Ok(true),
        other => bail!("{what} flag must be 0 or 1, got {other}"),
    }
}

/// Serialize a message to a frame.
pub fn encode(msg: &Message) -> Vec<u8> {
    let mut w = Writer::new();
    match msg {
        Message::Codebook { site, dim, codewords, weights } => {
            assert_eq!(codewords.len(), (*dim as usize) * weights.len());
            w.u8(TAG_CODEBOOK);
            w.u32(*site);
            w.u32(*dim);
            w.u32(weights.len() as u32);
            for v in codewords {
                w.f32(*v);
            }
            for v in weights {
                w.u32(*v);
            }
        }
        Message::Labels { site, labels } => {
            w.u8(TAG_LABELS);
            w.u32(*site);
            w.u32(labels.len() as u32);
            for v in labels {
                w.u16(*v);
            }
        }
        Message::Sigma(s) => {
            w.u8(TAG_SIGMA);
            w.f32(*s);
        }
        Message::Ack => w.u8(TAG_ACK),
        Message::SiteInfo { site, n_points, dim } => {
            w.u8(TAG_SITEINFO);
            w.u32(*site);
            w.u64(*n_points);
            w.u32(*dim);
        }
        Message::DmlRequest { site, dml, target_codes, max_iters, tol, seed } => {
            w.u8(TAG_DMLREQ);
            w.u32(*site);
            w.u8(dml_code(*dml));
            w.u32(*target_codes);
            w.u32(*max_iters);
            w.f64(*tol);
            w.u64(*seed);
        }
        Message::RunStart { run } => {
            w.u8(TAG_RUNSTART);
            w.u32(*run);
        }
        Message::RunSiteInfo { run, site, n_points, dim } => {
            w.u8(TAG_RUN_SITEINFO);
            w.u32(*run);
            w.u32(*site);
            w.u64(*n_points);
            w.u32(*dim);
        }
        Message::RunDmlRequest { run, site, dml, target_codes, max_iters, tol, seed } => {
            w.u8(TAG_RUN_DMLREQ);
            w.u32(*run);
            w.u32(*site);
            w.u8(dml_code(*dml));
            w.u32(*target_codes);
            w.u32(*max_iters);
            w.f64(*tol);
            w.u64(*seed);
        }
        Message::RunCodebook { run, site, dim, codewords, weights } => {
            assert_eq!(codewords.len(), (*dim as usize) * weights.len());
            w.u8(TAG_RUN_CODEBOOK);
            w.u32(*run);
            w.u32(*site);
            w.u32(*dim);
            w.u32(weights.len() as u32);
            for v in codewords {
                w.f32(*v);
            }
            for v in weights {
                w.u32(*v);
            }
        }
        Message::RunLabels { run, site, labels } => {
            w.u8(TAG_RUN_LABELS);
            w.u32(*run);
            w.u32(*site);
            w.u32(labels.len() as u32);
            for v in labels {
                w.u16(*v);
            }
        }
        Message::LabelsPull { run } => {
            w.u8(TAG_LABELS_PULL);
            w.u32(*run);
        }
        Message::SiteLabels { run, site, labels } => {
            w.u8(TAG_SITE_LABELS);
            w.u32(*run);
            w.u32(*site);
            w.u32(labels.len() as u32);
            for v in labels {
                w.u16(*v);
            }
        }
        Message::Submit(spec) => {
            // The legacy frame has no priority slot; encoding a non-default
            // priority here would silently drop it.
            assert_eq!(spec.priority, JobSpec::DEFAULT_PRIORITY);
            w.u8(TAG_SUBMIT);
            encode_spec_body(&mut w, spec);
        }
        Message::SubmitPri(spec) => {
            w.u8(TAG_SUBMIT_PRI);
            encode_spec_body(&mut w, spec);
            w.u32(spec.priority);
        }
        Message::JobAccept { run } => {
            w.u8(TAG_JOB_ACCEPT);
            w.u32(*run);
        }
        Message::JobDone { run, report } => {
            w.u8(TAG_JOB_DONE);
            w.u32(*run);
            w.u32(report.n_codes);
            w.f64(report.sigma);
            w.u64(report.central_ns);
            w.u64(report.wall_ns);
            w.u32(report.per_site.len() as u32);
            for l in &report.per_site {
                w.u64(l.up_frames);
                w.u64(l.up_bytes);
                w.u64(l.up_sim_ns);
                w.u64(l.down_frames);
                w.u64(l.down_bytes);
                w.u64(l.down_sim_ns);
            }
        }
        Message::Reject { run, msg } => {
            let bytes = msg.as_bytes();
            assert!(bytes.len() as u64 <= MAX_REJECT_MSG as u64);
            w.u8(TAG_REJECT);
            w.u32(*run);
            w.u32(bytes.len() as u32);
            w.buf.extend_from_slice(bytes);
        }
        Message::JobAcceptExt { run, position, eta_ns } => {
            w.u8(TAG_JOB_ACCEPT2);
            w.u32(*run);
            w.u32(*position);
            w.u64(*eta_ns);
        }
        Message::RejectCoded { run, code, detail, msg } => {
            let bytes = msg.as_bytes();
            assert!(bytes.len() as u64 <= MAX_REJECT_MSG as u64);
            w.u8(TAG_REJECT2);
            w.u32(*run);
            w.u8(reject_code(*code));
            w.u64(*detail);
            w.u32(bytes.len() as u32);
            w.buf.extend_from_slice(bytes);
        }
        Message::SiteInfo2 { site, n_points, dim, digest, chunks } => {
            w.u8(TAG_SITEINFO2);
            w.u32(*site);
            w.u64(*n_points);
            w.u32(*dim);
            w.u64(*digest);
            w.u32(*chunks);
        }
        Message::JreplHello { records, valid_bytes } => {
            w.u8(TAG_JREPL_HELLO);
            w.u64(*records);
            w.u64(*valid_bytes);
        }
        Message::JreplStart { from_record } => {
            w.u8(TAG_JREPL_START);
            w.u64(*from_record);
        }
        Message::JreplRecord { framed } => {
            assert!(framed.len() as u64 <= MAX_JREPL_RECORD as u64);
            w.u8(TAG_JREPL_RECORD);
            w.u32(framed.len() as u32);
            w.buf.extend_from_slice(framed);
        }
        Message::JreplHeartbeat => w.u8(TAG_JREPL_BEAT),
    }
    w.buf
}

/// Shared body of SUBMIT(14) and SUBMITPRI(18): the ten legacy spec fields
/// in frozen order (the priority suffix of tag 18 is written by the
/// caller).
fn encode_spec_body(w: &mut Writer, spec: &JobSpec) {
    w.u8(dml_code(spec.dml));
    w.u32(spec.total_codes);
    w.u32(spec.k_clusters);
    w.u32(spec.kmeans_max_iters);
    w.f64(spec.kmeans_tol);
    w.u64(spec.seed);
    w.u8(algo_code(spec.algo));
    let (g, knn_k) = graph_code(spec.graph);
    w.u8(g);
    w.u32(knn_k);
    w.u8(spec.weighted as u8);
    let (bw, value) = bandwidth_code(spec.bandwidth);
    w.u8(bw);
    w.f64(value);
}

/// Deserialize a frame. Errors on truncation, trailing garbage, overflow or
/// unknown tags (a hostile/corrupt frame must not panic the coordinator).
///
/// Array pre-allocation is bounded by the bytes actually present in the
/// frame, not by the declared element count: a 13-byte hostile frame whose
/// header claims millions of elements fails on truncation having reserved
/// nothing, instead of reserving hundreds of megabytes first.
pub fn decode(frame: &[u8]) -> Result<Message> {
    let mut r = Reader::new(frame);
    let tag = r.u8()?;
    let msg = match tag {
        TAG_CODEBOOK => {
            let (site, dim, codewords, weights) = decode_codebook_body(&mut r)?;
            Message::Codebook { site, dim, codewords, weights }
        }
        TAG_LABELS => {
            let (site, labels) = decode_labels_body(&mut r)?;
            Message::Labels { site, labels }
        }
        TAG_SIGMA => Message::Sigma(r.f32()?),
        TAG_ACK => Message::Ack,
        TAG_SITEINFO => {
            let site = r.u32()?;
            let n_points = r.u64()?;
            let dim = r.u32()?;
            Message::SiteInfo { site, n_points, dim }
        }
        TAG_DMLREQ => {
            let site = r.u32()?;
            let dml = dml_from_code(r.u8()?)?;
            let target_codes = r.u32()?;
            let max_iters = r.u32()?;
            let tol = r.f64()?;
            let seed = r.u64()?;
            Message::DmlRequest { site, dml, target_codes, max_iters, tol, seed }
        }
        TAG_RUNSTART => Message::RunStart { run: r.u32()? },
        TAG_RUN_SITEINFO => {
            let run = r.u32()?;
            let site = r.u32()?;
            let n_points = r.u64()?;
            let dim = r.u32()?;
            Message::RunSiteInfo { run, site, n_points, dim }
        }
        TAG_RUN_DMLREQ => {
            let run = r.u32()?;
            let site = r.u32()?;
            let dml = dml_from_code(r.u8()?)?;
            let target_codes = r.u32()?;
            let max_iters = r.u32()?;
            let tol = r.f64()?;
            let seed = r.u64()?;
            Message::RunDmlRequest { run, site, dml, target_codes, max_iters, tol, seed }
        }
        TAG_RUN_CODEBOOK => {
            let run = r.u32()?;
            let (site, dim, codewords, weights) = decode_codebook_body(&mut r)?;
            Message::RunCodebook { run, site, dim, codewords, weights }
        }
        TAG_RUN_LABELS => {
            let run = r.u32()?;
            let (site, labels) = decode_labels_body(&mut r)?;
            Message::RunLabels { run, site, labels }
        }
        TAG_LABELS_PULL => Message::LabelsPull { run: r.u32()? },
        TAG_SITE_LABELS => {
            let run = r.u32()?;
            let (site, labels) = decode_labels_body(&mut r)?;
            Message::SiteLabels { run, site, labels }
        }
        TAG_SUBMIT => Message::Submit(decode_spec_body(&mut r)?),
        TAG_SUBMIT_PRI => {
            let mut spec = decode_spec_body(&mut r)?;
            spec.priority = r.u32()?;
            if spec.priority < 1 || spec.priority > JobSpec::MAX_PRIORITY {
                bail!(
                    "job priority must be in 1..={}, got {}",
                    JobSpec::MAX_PRIORITY,
                    spec.priority
                );
            }
            Message::SubmitPri(spec)
        }
        TAG_JOB_ACCEPT => Message::JobAccept { run: r.u32()? },
        TAG_JOB_DONE => {
            let run = r.u32()?;
            let n_codes = r.u32()?;
            let sigma = r.f64()?;
            let central_ns = r.u64()?;
            let wall_ns = r.u64()?;
            let n_sites = r.u32()?;
            if n_sites > MAX_REPORT_SITES {
                bail!("job report claims {n_sites} sites");
            }
            // 48 bytes per link entry; capacity bounded by what is present
            let mut per_site =
                Vec::with_capacity((n_sites as usize).min(r.remaining() / 48));
            for _ in 0..n_sites {
                per_site.push(LinkReport {
                    up_frames: r.u64()?,
                    up_bytes: r.u64()?,
                    up_sim_ns: r.u64()?,
                    down_frames: r.u64()?,
                    down_bytes: r.u64()?,
                    down_sim_ns: r.u64()?,
                });
            }
            Message::JobDone {
                run,
                report: JobReport { n_codes, sigma, central_ns, wall_ns, per_site },
            }
        }
        TAG_REJECT => {
            let run = r.u32()?;
            let len = r.u32()?;
            if len > MAX_REJECT_MSG {
                bail!("reject message of {len} bytes");
            }
            let bytes = r.take(len as usize)?;
            let msg = match std::str::from_utf8(bytes) {
                Ok(s) => s.to_string(),
                Err(_) => bail!("reject message is not UTF-8"),
            };
            Message::Reject { run, msg }
        }
        TAG_JOB_ACCEPT2 => {
            let run = r.u32()?;
            let position = r.u32()?;
            let eta_ns = r.u64()?;
            Message::JobAcceptExt { run, position, eta_ns }
        }
        TAG_REJECT2 => {
            let run = r.u32()?;
            let code = reject_from_code(r.u8()?)?;
            let detail = r.u64()?;
            let len = r.u32()?;
            if len > MAX_REJECT_MSG {
                bail!("reject message of {len} bytes");
            }
            let bytes = r.take(len as usize)?;
            let msg = match std::str::from_utf8(bytes) {
                Ok(s) => s.to_string(),
                Err(_) => bail!("reject message is not UTF-8"),
            };
            Message::RejectCoded { run, code, detail, msg }
        }
        TAG_SITEINFO2 => {
            let site = r.u32()?;
            let n_points = r.u64()?;
            let dim = r.u32()?;
            let digest = r.u64()?;
            let chunks = r.u32()?;
            Message::SiteInfo2 { site, n_points, dim, digest, chunks }
        }
        TAG_JREPL_HELLO => {
            let records = r.u64()?;
            let valid_bytes = r.u64()?;
            Message::JreplHello { records, valid_bytes }
        }
        TAG_JREPL_START => Message::JreplStart { from_record: r.u64()? },
        TAG_JREPL_RECORD => {
            let len = r.u32()?;
            if len > MAX_JREPL_RECORD {
                bail!("replicated journal record of {len} bytes");
            }
            let framed = r.take(len as usize)?.to_vec();
            Message::JreplRecord { framed }
        }
        TAG_JREPL_BEAT => Message::JreplHeartbeat,
        t => bail!("unknown message tag {t}"),
    };
    if !r.done() {
        bail!("trailing bytes after frame");
    }
    Ok(msg)
}

/// Shared body of SUBMIT(14) and SUBMITPRI(18). Leaves `priority` at the
/// legacy default; the tag-18 decoder overwrites it from the suffix.
fn decode_spec_body(r: &mut Reader) -> Result<JobSpec> {
    let dml = dml_from_code(r.u8()?)?;
    let total_codes = r.u32()?;
    let k_clusters = r.u32()?;
    let kmeans_max_iters = r.u32()?;
    let kmeans_tol = r.f64()?;
    let seed = r.u64()?;
    let algo = algo_from_code(r.u8()?)?;
    let gcode = r.u8()?;
    let knn_k = r.u32()?;
    let graph = graph_from_code(gcode, knn_k)?;
    let weighted = bool_from_code(r.u8()?, "weighted")?;
    let bw = r.u8()?;
    let value = r.f64()?;
    let bandwidth = bandwidth_from_code(bw, value)?;
    Ok(JobSpec {
        dml,
        total_codes,
        k_clusters,
        kmeans_max_iters,
        kmeans_tol,
        seed,
        algo,
        graph,
        weighted,
        bandwidth,
        priority: JobSpec::DEFAULT_PRIORITY,
    })
}

/// Shared body of CODEBOOK(1) and RCODEBOOK(10): `site dim n codewords
/// weights`, with the element cap and remaining-bytes-bounded allocation
/// every decoder must apply.
fn decode_codebook_body(r: &mut Reader) -> Result<(u32, u32, Vec<f32>, Vec<u32>)> {
    let site = r.u32()?;
    let dim = r.u32()?;
    let n = r.u32()?;
    let total = (dim as u64) * (n as u64);
    if total > 100_000_000 {
        bail!("codebook too large: {n} codes × {dim} dims");
    }
    let mut codewords = Vec::with_capacity((total as usize).min(r.remaining() / 4));
    for _ in 0..total {
        codewords.push(r.f32()?);
    }
    let mut weights = Vec::with_capacity((n as usize).min(r.remaining() / 4));
    for _ in 0..n {
        weights.push(r.u32()?);
    }
    Ok((site, dim, codewords, weights))
}

/// Shared body of LABELS(2), RLABELS(11) and SITELABELS(13): `site n
/// labels`, same caps and allocation bounds.
fn decode_labels_body(r: &mut Reader) -> Result<(u32, Vec<u16>)> {
    let site = r.u32()?;
    let n = r.u32()?;
    if n > 500_000_000 {
        bail!("label frame too large: {n}");
    }
    let mut labels = Vec::with_capacity((n as usize).min(r.remaining() / 2));
    for _ in 0..n {
        labels.push(r.u16()?);
    }
    Ok((site, labels))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codebook_roundtrip() {
        let msg = Message::Codebook {
            site: 3,
            dim: 2,
            codewords: vec![1.5, -2.0, 0.0, 7.25],
            weights: vec![10, 20],
        };
        let frame = encode(&msg);
        assert_eq!(decode(&frame).unwrap(), msg);
        // frame size = 1 + 4 + 4 + 4 + 4*4 + 2*4 = 37
        assert_eq!(frame.len(), 37);
    }

    #[test]
    fn labels_roundtrip() {
        let msg = Message::Labels { site: 0, labels: vec![0, 1, 2, 65535] };
        assert_eq!(decode(&encode(&msg)).unwrap(), msg);
    }

    #[test]
    fn sigma_and_ack_roundtrip() {
        assert_eq!(decode(&encode(&Message::Sigma(0.75))).unwrap(), Message::Sigma(0.75));
        assert_eq!(decode(&encode(&Message::Ack)).unwrap(), Message::Ack);
    }

    #[test]
    fn siteinfo_roundtrip() {
        let msg = Message::SiteInfo { site: 7, n_points: u64::MAX - 3, dim: 128 };
        let frame = encode(&msg);
        assert_eq!(decode(&frame).unwrap(), msg);
        // 1 + 4 + 8 + 4
        assert_eq!(frame.len(), 17);
    }

    #[test]
    fn dml_request_roundtrip() {
        for dml in [DmlKind::KMeans, DmlKind::RpTree, DmlKind::RandomSample] {
            let msg = Message::DmlRequest {
                site: 2,
                dml,
                target_codes: 500,
                max_iters: 30,
                tol: 1e-6,
                seed: 0xDEAD_BEEF_CAFE_F00D,
            };
            let frame = encode(&msg);
            assert_eq!(decode(&frame).unwrap(), msg);
            // 1 + 4 + 1 + 4 + 4 + 8 + 8
            assert_eq!(frame.len(), 30);
        }
    }

    #[test]
    fn dml_request_bad_code_errors() {
        let mut frame = encode(&Message::DmlRequest {
            site: 0,
            dml: DmlKind::KMeans,
            target_codes: 1,
            max_iters: 1,
            tol: 0.0,
            seed: 0,
        });
        frame[5] = 99; // the dml code byte
        assert!(decode(&frame).is_err());
    }

    #[test]
    fn truncated_frame_errors() {
        let frames = [
            encode(&Message::Labels { site: 0, labels: vec![1, 2, 3] }),
            encode(&Message::SiteInfo { site: 1, n_points: 10, dim: 4 }),
            encode(&Message::DmlRequest {
                site: 0,
                dml: DmlKind::RpTree,
                target_codes: 8,
                max_iters: 5,
                tol: 1e-3,
                seed: 11,
            }),
        ];
        for frame in frames {
            for cut in 0..frame.len() {
                assert!(decode(&frame[..cut]).is_err(), "cut at {cut} should fail");
            }
        }
    }

    #[test]
    fn trailing_bytes_error() {
        let mut frame = encode(&Message::Ack);
        frame.push(0);
        assert!(decode(&frame).is_err());
    }

    #[test]
    fn unknown_tag_errors() {
        assert!(decode(&[99]).is_err());
    }

    #[test]
    fn hostile_length_does_not_allocate() {
        // tag CODEBOOK with dim and n at u32::MAX must error, not OOM
        let mut frame = vec![1u8];
        frame.extend_from_slice(&0u32.to_le_bytes());
        frame.extend_from_slice(&u32::MAX.to_le_bytes());
        frame.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode(&frame).is_err());
    }

    fn sample_spec() -> JobSpec {
        JobSpec {
            dml: DmlKind::RpTree,
            total_codes: 300,
            k_clusters: 4,
            kmeans_max_iters: 30,
            kmeans_tol: 1e-6,
            seed: 0xFEED_F00D,
            algo: Algo::Njw,
            graph: GraphKind::Knn { k: 12 },
            weighted: true,
            bandwidth: Bandwidth::MedianScale(0.5),
            priority: JobSpec::DEFAULT_PRIORITY,
        }
    }

    #[test]
    fn run_scoped_frames_roundtrip() {
        let msgs = vec![
            Message::RunStart { run: 9 },
            Message::RunSiteInfo { run: 9, site: 1, n_points: 40_000, dim: 10 },
            Message::RunDmlRequest {
                run: 9,
                site: 1,
                dml: DmlKind::KMeans,
                target_codes: 150,
                max_iters: 30,
                tol: 1e-6,
                seed: 77,
            },
            Message::RunCodebook {
                run: 9,
                site: 1,
                dim: 2,
                codewords: vec![0.5, -1.5, 2.0, 3.25],
                weights: vec![3, 4],
            },
            Message::RunLabels { run: 9, site: 1, labels: vec![0, 2, 1] },
            Message::LabelsPull { run: 9 },
            Message::SiteLabels { run: 9, site: 1, labels: vec![1, 1, 0, 3] },
        ];
        for msg in msgs {
            assert_eq!(decode(&encode(&msg)).unwrap(), msg, "{msg:?}");
        }
        // a run-scoped frame is its classic twin plus the 4-byte run id
        let classic = encode(&Message::SiteInfo { site: 1, n_points: 40_000, dim: 10 });
        let scoped =
            encode(&Message::RunSiteInfo { run: 9, site: 1, n_points: 40_000, dim: 10 });
        assert_eq!(scoped.len(), classic.len() + 4);
    }

    #[test]
    fn submit_roundtrip_all_enums() {
        for dml in [DmlKind::KMeans, DmlKind::RpTree, DmlKind::RandomSample] {
            for algo in [Algo::RecursiveNcut, Algo::Njw] {
                for graph in [GraphKind::Dense, GraphKind::Knn { k: 32 }] {
                    for bandwidth in [
                        Bandwidth::Fixed(2.5),
                        Bandwidth::MedianScale(0.5),
                        Bandwidth::EigengapSearch { k: 4 },
                    ] {
                        let spec =
                            JobSpec { dml, algo, graph, bandwidth, ..sample_spec() };
                        let msg = Message::Submit(spec);
                        assert_eq!(decode(&encode(&msg)).unwrap(), msg);
                    }
                }
            }
        }
    }

    #[test]
    fn submit_rejects_bad_codes() {
        let frame = encode(&Message::Submit(sample_spec()));
        // algo code lives right after dml(1)+codes(4)+k(4)+iters(4)+tol(8)+seed(8)
        let algo_off = 1 + 1 + 4 + 4 + 4 + 8 + 8;
        for (off, bad) in [
            (1usize, 99u8),            // dml
            (algo_off, 7),             // algo
            (algo_off + 1, 9),         // graph kind
            (algo_off + 6, 2),         // weighted flag
            (algo_off + 7, 5),         // bandwidth policy
        ] {
            let mut f = frame.clone();
            f[off] = bad;
            assert!(decode(&f).is_err(), "byte {off} = {bad} must fail");
        }
        // dense graph with a nonzero knn_k is contradictory
        let mut f = frame.clone();
        f[algo_off + 1] = 0; // dense, but knn_k stays 12
        assert!(decode(&f).is_err());
    }

    #[test]
    fn job_control_roundtrip() {
        assert_eq!(
            decode(&encode(&Message::JobAccept { run: 3 })).unwrap(),
            Message::JobAccept { run: 3 }
        );
        let done = Message::JobDone {
            run: 3,
            report: JobReport {
                n_codes: 300,
                sigma: 1.25,
                central_ns: 1_000_000,
                wall_ns: 2_000_000,
                per_site: vec![
                    LinkReport {
                        up_frames: 2,
                        up_bytes: 1234,
                        up_sim_ns: 99,
                        down_frames: 3,
                        down_bytes: 567,
                        down_sim_ns: 11,
                    },
                    LinkReport::default(),
                ],
            },
        };
        assert_eq!(decode(&encode(&done)).unwrap(), done);
        let rej = Message::Reject { run: 0, msg: "queue full (depth 32)".into() };
        assert_eq!(decode(&encode(&rej)).unwrap(), rej);
    }

    #[test]
    fn new_frames_reject_truncation() {
        let frames = [
            encode(&Message::RunStart { run: 1 }),
            encode(&Message::RunSiteInfo { run: 1, site: 0, n_points: 5, dim: 2 }),
            encode(&Message::RunLabels { run: 1, site: 0, labels: vec![1, 2] }),
            encode(&Message::SiteLabels { run: 1, site: 0, labels: vec![1] }),
            encode(&Message::Submit(sample_spec())),
            encode(&Message::JobDone {
                run: 1,
                report: JobReport {
                    n_codes: 4,
                    sigma: 1.0,
                    central_ns: 5,
                    wall_ns: 6,
                    per_site: vec![LinkReport::default()],
                },
            }),
            encode(&Message::Reject { run: 1, msg: "x".into() }),
            encode(&Message::SubmitPri(JobSpec { priority: 3, ..sample_spec() })),
            encode(&Message::JobAcceptExt { run: 1, position: 2, eta_ns: 9 }),
            encode(&Message::RejectCoded {
                run: 1,
                code: RejectCode::QueueFull,
                detail: 8,
                msg: "x".into(),
            }),
            encode(&Message::SiteInfo2 {
                site: 0,
                n_points: 5,
                dim: 2,
                digest: 0xDEAD_BEEF,
                chunks: 1,
            }),
        ];
        for frame in frames {
            for cut in 0..frame.len() {
                assert!(decode(&frame[..cut]).is_err(), "cut at {cut} should fail");
            }
        }
    }

    #[test]
    fn siteinfo2_roundtrip_and_legacy_frozen() {
        let msg = Message::SiteInfo2 {
            site: 3,
            n_points: 1 << 40,
            dim: 10,
            digest: 0x0123_4567_89AB_CDEF,
            chunks: 1_025,
        };
        let frame = encode(&msg);
        assert_eq!(decode(&frame).unwrap(), msg);
        // 1 + 4 + 8 + 4 + 8 + 4
        assert_eq!(frame.len(), 29);
        assert_eq!(frame[0], TAG_SITEINFO2);
        // forward-compat rule: the digest report is a *new* tag; the legacy
        // SITEINFO frame stays byte-identical (old leaders keep working)
        let legacy = encode(&Message::SiteInfo { site: 3, n_points: 1 << 40, dim: 10 });
        assert_eq!(legacy.len(), 17);
        assert_eq!(&frame[1..17], &legacy[1..]);
    }

    #[test]
    fn submit_pri_roundtrip() {
        for priority in [1, 2, JobSpec::MAX_PRIORITY] {
            let msg = Message::SubmitPri(JobSpec { priority, ..sample_spec() });
            let frame = encode(&msg);
            assert_eq!(decode(&frame).unwrap(), msg);
            // the modern submit is its legacy twin plus the 4-byte priority
            let legacy = encode(&Message::Submit(sample_spec()));
            assert_eq!(frame.len(), legacy.len() + 4);
            assert_eq!(frame[0], TAG_SUBMIT_PRI);
            assert_eq!(&frame[1..legacy.len()], &legacy[1..]);
        }
    }

    #[test]
    fn submit_pri_rejects_out_of_range_priority() {
        let frame = encode(&Message::SubmitPri(JobSpec { priority: 2, ..sample_spec() }));
        let n = frame.len();
        // priority is the trailing u32
        let mut f = frame.clone();
        f[n - 4..].copy_from_slice(&0u32.to_le_bytes());
        assert!(decode(&f).is_err(), "priority 0 must fail");
        let mut f = frame.clone();
        f[n - 4..].copy_from_slice(&(JobSpec::MAX_PRIORITY + 1).to_le_bytes());
        assert!(decode(&f).is_err(), "priority above the cap must fail");
    }

    #[test]
    fn job_accept_ext_roundtrip() {
        let msg = Message::JobAcceptExt { run: 5, position: 3, eta_ns: 42_000_000 };
        let frame = encode(&msg);
        assert_eq!(decode(&frame).unwrap(), msg);
        // 1 + 4 + 4 + 8
        assert_eq!(frame.len(), 17);
    }

    #[test]
    fn reject_coded_roundtrip_all_codes() {
        for code in [
            RejectCode::BadSpec,
            RejectCode::QueueFull,
            RejectCode::RateLimited,
            RejectCode::RunFailed,
            RejectCode::PullRefused,
        ] {
            let msg =
                Message::RejectCoded { run: 2, code, detail: 17, msg: "why".into() };
            assert_eq!(decode(&encode(&msg)).unwrap(), msg);
        }
    }

    #[test]
    fn reject_coded_bad_code_and_hostile_len_error() {
        let frame = encode(&Message::RejectCoded {
            run: 0,
            code: RejectCode::BadSpec,
            detail: 0,
            msg: String::new(),
        });
        let mut f = frame.clone();
        f[5] = 99; // the reason-code byte, right after tag + run
        assert!(decode(&f).is_err());

        // hostile message length fails before allocating
        let mut f = vec![TAG_REJECT2];
        f.extend_from_slice(&0u32.to_le_bytes()); // run
        f.push(1); // code
        f.extend_from_slice(&0u64.to_le_bytes()); // detail
        f.extend_from_slice(&u32::MAX.to_le_bytes()); // len
        assert!(decode(&f).is_err());
    }

    #[test]
    fn hostile_new_frames_do_not_overallocate() {
        // RCODEBOOK with a huge declared count fails on truncation cheaply
        let mut frame = vec![10u8]; // TAG_RUN_CODEBOOK
        frame.extend_from_slice(&1u32.to_le_bytes()); // run
        frame.extend_from_slice(&0u32.to_le_bytes()); // site
        frame.extend_from_slice(&1u32.to_le_bytes()); // dim
        frame.extend_from_slice(&99_000_000u32.to_le_bytes()); // n
        assert!(decode(&frame).is_err());

        // SITELABELS with a hostile count, same shape
        let mut frame = vec![13u8]; // TAG_SITE_LABELS
        frame.extend_from_slice(&1u32.to_le_bytes());
        frame.extend_from_slice(&0u32.to_le_bytes());
        frame.extend_from_slice(&400_000_000u32.to_le_bytes());
        assert!(decode(&frame).is_err());

        // JOBDONE claiming an absurd site count is rejected outright
        let mut frame = vec![16u8]; // TAG_JOB_DONE
        frame.extend_from_slice(&1u32.to_le_bytes()); // run
        frame.extend_from_slice(&4u32.to_le_bytes()); // n_codes
        frame.extend_from_slice(&1.0f64.to_le_bytes()); // sigma
        frame.extend_from_slice(&0u64.to_le_bytes()); // central_ns
        frame.extend_from_slice(&0u64.to_le_bytes()); // wall_ns
        frame.extend_from_slice(&u32::MAX.to_le_bytes()); // n_sites
        assert!(decode(&frame).is_err());

        // REJECT with a hostile message length
        let mut frame = vec![17u8];
        frame.extend_from_slice(&0u32.to_le_bytes());
        frame.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode(&frame).is_err());
    }

    #[test]
    fn jrepl_frames_roundtrip_with_exact_sizes() {
        let hello = Message::JreplHello { records: 17, valid_bytes: 1 << 20 };
        let frame = encode(&hello);
        assert_eq!(decode(&frame).unwrap(), hello);
        // 1 + 8 + 8
        assert_eq!(frame.len(), 17);
        assert_eq!(frame[0], TAG_JREPL_HELLO);

        let start = Message::JreplStart { from_record: 9 };
        let frame = encode(&start);
        assert_eq!(decode(&frame).unwrap(), start);
        // 1 + 8
        assert_eq!(frame.len(), 9);

        // A replicated record crosses the wire verbatim: the payload bytes
        // come back untouched, wrapped only by tag + length.
        let framed = vec![0xAAu8, 0xBB, 0xCC, 0x01, 0x02, 0x03, 0x04, 0x05, 0x06];
        let rec = Message::JreplRecord { framed: framed.clone() };
        let frame = encode(&rec);
        assert_eq!(decode(&frame).unwrap(), rec);
        assert_eq!(frame.len(), 1 + 4 + framed.len());
        assert_eq!(&frame[5..], &framed[..]);

        let beat = Message::JreplHeartbeat;
        let frame = encode(&beat);
        assert_eq!(decode(&frame).unwrap(), beat);
        assert_eq!(frame, vec![TAG_JREPL_BEAT]);
    }

    #[test]
    fn jrepl_frames_reject_truncation_and_hostile_length() {
        let frames = [
            encode(&Message::JreplHello { records: 3, valid_bytes: 99 }),
            encode(&Message::JreplStart { from_record: 1 }),
            encode(&Message::JreplRecord { framed: vec![1, 2, 3] }),
        ];
        for frame in frames {
            for cut in 0..frame.len() {
                assert!(decode(&frame[..cut]).is_err(), "cut at {cut} should fail");
            }
        }
        // a hostile record length fails outright, allocating nothing
        let mut f = vec![TAG_JREPL_RECORD];
        f.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode(&f).is_err());
        // and a plausible length with missing bytes fails on truncation
        let mut f = vec![TAG_JREPL_RECORD];
        f.extend_from_slice(&1_000u32.to_le_bytes());
        f.push(7);
        assert!(decode(&f).is_err());
    }

    #[test]
    fn hostile_count_under_element_cap_does_not_overallocate() {
        // A 13-byte frame can pass the 100M-element cap with a count that
        // would still mean a ~400 MB reservation if capacity followed the
        // declared length. Capacity is bounded by the frame's remaining
        // bytes instead, so this must fail fast on truncation.
        let mut frame = vec![1u8]; // CODEBOOK
        frame.extend_from_slice(&0u32.to_le_bytes()); // site
        frame.extend_from_slice(&1u32.to_le_bytes()); // dim = 1
        frame.extend_from_slice(&99_000_000u32.to_le_bytes()); // n under the cap
        assert!(decode(&frame).is_err());

        // same shape for LABELS
        let mut frame = vec![2u8];
        frame.extend_from_slice(&0u32.to_le_bytes());
        frame.extend_from_slice(&400_000_000u32.to_le_bytes());
        assert!(decode(&frame).is_err());
    }
}
