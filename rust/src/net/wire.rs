//! Binary wire format for coordinator ⇄ site traffic.
//!
//! Everything that crosses a link — simulated channel or real TCP — is
//! serialized through this codec, so the byte counts the benchmarks report
//! are the real size of the protocol messages, not estimates. Little-endian
//! throughout:
//!
//! ```text
//! frame   := tag:u8 payload
//! CODEBOOK(1) := site:u32 dim:u32 n:u32 codewords:[f32; n*dim] weights:[u32; n]
//! LABELS(2)   := site:u32 n:u32 labels:[u16; n]
//! SIGMA(3)    := sigma:f32            (leader → sites broadcast, D3 tuning)
//! ACK(4)      :=
//! SITEINFO(5) := site:u32 n_points:u64 dim:u32     (site → leader, registration)
//! DMLREQ(6)   := site:u32 dml:u8 target_codes:u32
//!                max_iters:u32 tol:f64 seed:u64    (leader → site, work order)
//! ```
//!
//! Codebook frames are exactly what the paper transmits (codewords + group
//! sizes); label frames are the populated memberships coming back. SiteInfo
//! and DmlRequest are the small control handshake that lets the leader size
//! each site's codeword budget without seeing the data. The byte-level
//! layout, framing on TCP, and forward-compatibility rules are documented
//! in `docs/PROTOCOL.md`.

use anyhow::{bail, Result};

use crate::dml::DmlKind;

/// A protocol message.
#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    /// Site → leader: the DML output (Algorithm 1, line 8 input).
    Codebook { site: u32, dim: u32, codewords: Vec<f32>, weights: Vec<u32> },
    /// Leader → site: cluster label per codeword (Algorithm 1, line 10).
    Labels { site: u32, labels: Vec<u16> },
    /// Leader → sites: broadcast of the affinity bandwidth (when sites
    /// pre-scale data) — small control traffic, counted like the rest.
    Sigma(f32),
    Ack,
    /// Site → leader: local shard shape, sent at the start of a run so the
    /// leader can size codeword budgets proportionally to site sizes.
    SiteInfo { site: u32, n_points: u64, dim: u32 },
    /// Leader → site: the DML work order (transform, budget, Lloyd knobs,
    /// the site's forked seed).
    DmlRequest { site: u32, dml: DmlKind, target_codes: u32, max_iters: u32, tol: f64, seed: u64 },
}

const TAG_CODEBOOK: u8 = 1;
const TAG_LABELS: u8 = 2;
const TAG_SIGMA: u8 = 3;
const TAG_ACK: u8 = 4;
const TAG_SITEINFO: u8 = 5;
const TAG_DMLREQ: u8 = 6;

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn new() -> Self {
        Writer { buf: Vec::new() }
    }
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("truncated frame: need {n} bytes at offset {}", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    /// Bytes left in the frame — the hard ceiling on how many array
    /// elements can still be decoded, used to bound pre-allocation.
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
    fn done(&self) -> bool {
        self.pos == self.buf.len()
    }
}

/// Wire encoding of a [`DmlKind`] (DMLREQ `dml` field).
fn dml_code(kind: DmlKind) -> u8 {
    match kind {
        DmlKind::KMeans => 0,
        DmlKind::RpTree => 1,
        DmlKind::RandomSample => 2,
    }
}

fn dml_from_code(code: u8) -> Result<DmlKind> {
    Ok(match code {
        0 => DmlKind::KMeans,
        1 => DmlKind::RpTree,
        2 => DmlKind::RandomSample,
        other => bail!("unknown dml code {other}"),
    })
}

/// Serialize a message to a frame.
pub fn encode(msg: &Message) -> Vec<u8> {
    let mut w = Writer::new();
    match msg {
        Message::Codebook { site, dim, codewords, weights } => {
            assert_eq!(codewords.len(), (*dim as usize) * weights.len());
            w.u8(TAG_CODEBOOK);
            w.u32(*site);
            w.u32(*dim);
            w.u32(weights.len() as u32);
            for v in codewords {
                w.f32(*v);
            }
            for v in weights {
                w.u32(*v);
            }
        }
        Message::Labels { site, labels } => {
            w.u8(TAG_LABELS);
            w.u32(*site);
            w.u32(labels.len() as u32);
            for v in labels {
                w.u16(*v);
            }
        }
        Message::Sigma(s) => {
            w.u8(TAG_SIGMA);
            w.f32(*s);
        }
        Message::Ack => w.u8(TAG_ACK),
        Message::SiteInfo { site, n_points, dim } => {
            w.u8(TAG_SITEINFO);
            w.u32(*site);
            w.u64(*n_points);
            w.u32(*dim);
        }
        Message::DmlRequest { site, dml, target_codes, max_iters, tol, seed } => {
            w.u8(TAG_DMLREQ);
            w.u32(*site);
            w.u8(dml_code(*dml));
            w.u32(*target_codes);
            w.u32(*max_iters);
            w.f64(*tol);
            w.u64(*seed);
        }
    }
    w.buf
}

/// Deserialize a frame. Errors on truncation, trailing garbage, overflow or
/// unknown tags (a hostile/corrupt frame must not panic the coordinator).
///
/// Array pre-allocation is bounded by the bytes actually present in the
/// frame, not by the declared element count: a 13-byte hostile frame whose
/// header claims millions of elements fails on truncation having reserved
/// nothing, instead of reserving hundreds of megabytes first.
pub fn decode(frame: &[u8]) -> Result<Message> {
    let mut r = Reader::new(frame);
    let tag = r.u8()?;
    let msg = match tag {
        TAG_CODEBOOK => {
            let site = r.u32()?;
            let dim = r.u32()?;
            let n = r.u32()?;
            let total = (dim as u64) * (n as u64);
            if total > 100_000_000 {
                bail!("codebook too large: {n} codes × {dim} dims");
            }
            let mut codewords = Vec::with_capacity((total as usize).min(r.remaining() / 4));
            for _ in 0..total {
                codewords.push(r.f32()?);
            }
            let mut weights = Vec::with_capacity((n as usize).min(r.remaining() / 4));
            for _ in 0..n {
                weights.push(r.u32()?);
            }
            Message::Codebook { site, dim, codewords, weights }
        }
        TAG_LABELS => {
            let site = r.u32()?;
            let n = r.u32()?;
            if n > 500_000_000 {
                bail!("label frame too large: {n}");
            }
            let mut labels = Vec::with_capacity((n as usize).min(r.remaining() / 2));
            for _ in 0..n {
                labels.push(r.u16()?);
            }
            Message::Labels { site, labels }
        }
        TAG_SIGMA => Message::Sigma(r.f32()?),
        TAG_ACK => Message::Ack,
        TAG_SITEINFO => {
            let site = r.u32()?;
            let n_points = r.u64()?;
            let dim = r.u32()?;
            Message::SiteInfo { site, n_points, dim }
        }
        TAG_DMLREQ => {
            let site = r.u32()?;
            let dml = dml_from_code(r.u8()?)?;
            let target_codes = r.u32()?;
            let max_iters = r.u32()?;
            let tol = r.f64()?;
            let seed = r.u64()?;
            Message::DmlRequest { site, dml, target_codes, max_iters, tol, seed }
        }
        t => bail!("unknown message tag {t}"),
    };
    if !r.done() {
        bail!("trailing bytes after frame");
    }
    Ok(msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codebook_roundtrip() {
        let msg = Message::Codebook {
            site: 3,
            dim: 2,
            codewords: vec![1.5, -2.0, 0.0, 7.25],
            weights: vec![10, 20],
        };
        let frame = encode(&msg);
        assert_eq!(decode(&frame).unwrap(), msg);
        // frame size = 1 + 4 + 4 + 4 + 4*4 + 2*4 = 37
        assert_eq!(frame.len(), 37);
    }

    #[test]
    fn labels_roundtrip() {
        let msg = Message::Labels { site: 0, labels: vec![0, 1, 2, 65535] };
        assert_eq!(decode(&encode(&msg)).unwrap(), msg);
    }

    #[test]
    fn sigma_and_ack_roundtrip() {
        assert_eq!(decode(&encode(&Message::Sigma(0.75))).unwrap(), Message::Sigma(0.75));
        assert_eq!(decode(&encode(&Message::Ack)).unwrap(), Message::Ack);
    }

    #[test]
    fn siteinfo_roundtrip() {
        let msg = Message::SiteInfo { site: 7, n_points: u64::MAX - 3, dim: 128 };
        let frame = encode(&msg);
        assert_eq!(decode(&frame).unwrap(), msg);
        // 1 + 4 + 8 + 4
        assert_eq!(frame.len(), 17);
    }

    #[test]
    fn dml_request_roundtrip() {
        for dml in [DmlKind::KMeans, DmlKind::RpTree, DmlKind::RandomSample] {
            let msg = Message::DmlRequest {
                site: 2,
                dml,
                target_codes: 500,
                max_iters: 30,
                tol: 1e-6,
                seed: 0xDEAD_BEEF_CAFE_F00D,
            };
            let frame = encode(&msg);
            assert_eq!(decode(&frame).unwrap(), msg);
            // 1 + 4 + 1 + 4 + 4 + 8 + 8
            assert_eq!(frame.len(), 30);
        }
    }

    #[test]
    fn dml_request_bad_code_errors() {
        let mut frame = encode(&Message::DmlRequest {
            site: 0,
            dml: DmlKind::KMeans,
            target_codes: 1,
            max_iters: 1,
            tol: 0.0,
            seed: 0,
        });
        frame[5] = 99; // the dml code byte
        assert!(decode(&frame).is_err());
    }

    #[test]
    fn truncated_frame_errors() {
        let frames = [
            encode(&Message::Labels { site: 0, labels: vec![1, 2, 3] }),
            encode(&Message::SiteInfo { site: 1, n_points: 10, dim: 4 }),
            encode(&Message::DmlRequest {
                site: 0,
                dml: DmlKind::RpTree,
                target_codes: 8,
                max_iters: 5,
                tol: 1e-3,
                seed: 11,
            }),
        ];
        for frame in frames {
            for cut in 0..frame.len() {
                assert!(decode(&frame[..cut]).is_err(), "cut at {cut} should fail");
            }
        }
    }

    #[test]
    fn trailing_bytes_error() {
        let mut frame = encode(&Message::Ack);
        frame.push(0);
        assert!(decode(&frame).is_err());
    }

    #[test]
    fn unknown_tag_errors() {
        assert!(decode(&[99]).is_err());
    }

    #[test]
    fn hostile_length_does_not_allocate() {
        // tag CODEBOOK with dim and n at u32::MAX must error, not OOM
        let mut frame = vec![1u8];
        frame.extend_from_slice(&0u32.to_le_bytes());
        frame.extend_from_slice(&u32::MAX.to_le_bytes());
        frame.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode(&frame).is_err());
    }

    #[test]
    fn hostile_count_under_element_cap_does_not_overallocate() {
        // A 13-byte frame can pass the 100M-element cap with a count that
        // would still mean a ~400 MB reservation if capacity followed the
        // declared length. Capacity is bounded by the frame's remaining
        // bytes instead, so this must fail fast on truncation.
        let mut frame = vec![1u8]; // CODEBOOK
        frame.extend_from_slice(&0u32.to_le_bytes()); // site
        frame.extend_from_slice(&1u32.to_le_bytes()); // dim = 1
        frame.extend_from_slice(&99_000_000u32.to_le_bytes()); // n under the cap
        assert!(decode(&frame).is_err());

        // same shape for LABELS
        let mut frame = vec![2u8];
        frame.extend_from_slice(&0u32.to_le_bytes());
        frame.extend_from_slice(&400_000_000u32.to_le_bytes());
        assert!(decode(&frame).is_err());
    }
}
