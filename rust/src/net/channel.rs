//! In-process channel backend: the star network as `mpsc` channels.
//!
//! The default transport for tests, benches and `dsc run` — every site is a
//! thread in the coordinator's process and a "link" is a pair of unbounded
//! channels. Frames are the same encoded bytes the TCP backend ships, so
//! the byte accounting (done above the transport seam) is identical; only
//! the delivery mechanism differs.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use super::transport::{LeaderTransport, SiteTransport};

/// Leader half of the channel star.
pub struct ChannelLeader {
    from_sites: Receiver<(usize, Vec<u8>)>,
    to_sites: Vec<Sender<Vec<u8>>>,
}

/// One site's half of the channel star (moved into the site's thread).
pub struct ChannelSite {
    site_id: usize,
    to_leader: Sender<(usize, Vec<u8>)>,
    from_leader: Receiver<Vec<u8>>,
}

/// Build the channel star: one leader transport, `n_sites` site transports.
pub fn star(n_sites: usize) -> (ChannelLeader, Vec<ChannelSite>) {
    let (up_tx, up_rx) = channel::<(usize, Vec<u8>)>();
    let mut to_sites = Vec::with_capacity(n_sites);
    let mut sites = Vec::with_capacity(n_sites);
    for site_id in 0..n_sites {
        let (down_tx, down_rx) = channel::<Vec<u8>>();
        to_sites.push(down_tx);
        sites.push(ChannelSite { site_id, to_leader: up_tx.clone(), from_leader: down_rx });
    }
    (ChannelLeader { from_sites: up_rx, to_sites }, sites)
}

impl LeaderTransport for ChannelLeader {
    fn n_sites(&self) -> usize {
        self.to_sites.len()
    }

    fn send(&self, site: usize, frame: Vec<u8>) -> Result<()> {
        self.to_sites[site].send(frame).context("site channel closed")
    }

    fn recv(&self, timeout: Option<Duration>) -> Result<(usize, Vec<u8>)> {
        match timeout {
            None => self.from_sites.recv().context("all site channels closed"),
            Some(t) => self.from_sites.recv_timeout(t).map_err(|e| match e {
                RecvTimeoutError::Timeout => anyhow!("timed out waiting for sites"),
                RecvTimeoutError::Disconnected => anyhow!("all site channels closed"),
            }),
        }
    }
}

impl SiteTransport for ChannelSite {
    fn site_id(&self) -> usize {
        self.site_id
    }

    fn send(&self, frame: Vec<u8>) -> Result<()> {
        self.to_leader.send((self.site_id, frame)).context("leader channel closed")
    }

    fn recv_opt(&self) -> Result<Option<Vec<u8>>> {
        // A dropped leader handle is the channel star's clean close: there
        // is no mid-frame state to tear (frames move whole), so hangup is
        // always at a frame boundary.
        Ok(self.from_leader.recv().ok())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_flow_both_ways() {
        let (leader, sites) = star(2);
        sites[1].send(b"up".to_vec()).unwrap();
        let (id, frame) = leader.recv(None).unwrap();
        assert_eq!((id, frame.as_slice()), (1, b"up".as_slice()));

        leader.send(0, b"down".to_vec()).unwrap();
        assert_eq!(sites[0].recv().unwrap(), b"down".to_vec());
    }

    #[test]
    fn recv_timeout_expires() {
        let (leader, _sites) = star(1);
        assert!(leader.recv(Some(Duration::from_millis(10))).is_err());
    }

    #[test]
    fn dropped_leader_unblocks_site() {
        let (leader, sites) = star(1);
        drop(leader);
        assert!(sites[0].recv().is_err());
        assert!(sites[0].send(b"x".to_vec()).is_err());
    }
}
