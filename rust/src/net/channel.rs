//! In-process channel backend: the star network as `mpsc` channels.
//!
//! The default transport for tests, benches and `dsc run` — every site is a
//! thread in the coordinator's process and a "link" is a pair of unbounded
//! channels. Frames are the same encoded bytes the TCP backend ships, so
//! the byte accounting (done above the transport seam) is identical; only
//! the delivery mechanism differs.
//!
//! Beyond the plain [`star`], this module carries the building blocks of
//! the **channel-backed job-server harness**
//! ([`crate::coordinator::harness`]): [`star_endpoints`] exposes the
//! leader-side raw channel ends so a reactor can own them directly, a
//! [`FaultPlan`] injects deterministic link faults (drop a site after
//! frame K, delay or duplicate a specific frame, swallow one run's
//! frames) into the uplink without sockets or sleeps, and a
//! [`VirtualClock`] lets tests drive straggler deadlines by advancing
//! time explicitly instead of waiting it out. `docs/TESTING.md` shows how
//! the pieces compose.

use std::cell::{Cell, RefCell};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use super::transport::{LeaderTransport, SiteTransport};

/// Leader half of the channel star.
pub struct ChannelLeader {
    from_sites: Receiver<(usize, Vec<u8>)>,
    to_sites: Vec<Sender<Vec<u8>>>,
}

/// One site's half of the channel star (moved into the site's thread).
pub struct ChannelSite {
    site_id: usize,
    to_leader: Sender<(usize, Vec<u8>)>,
    from_leader: Receiver<Vec<u8>>,
}

/// Build the channel star: one leader transport, `n_sites` site transports.
pub fn star(n_sites: usize) -> (ChannelLeader, Vec<ChannelSite>) {
    let (up_rx, to_sites, sites) = star_endpoints(n_sites);
    (ChannelLeader { from_sites: up_rx, to_sites }, sites)
}

/// Build the channel star exposing the leader side as raw channel ends —
/// the shared uplink mailbox and one downlink sender per site — instead of
/// a [`ChannelLeader`]. The job-server harness uses this: its reactor owns
/// the downlinks (so a fault plan can sever one) and a forwarder drains
/// the uplink through the [`FaultPlan`] before frames become reactor
/// events. The site halves are identical to [`star`]'s.
pub fn star_endpoints(
    n_sites: usize,
) -> (Receiver<(usize, Vec<u8>)>, Vec<Sender<Vec<u8>>>, Vec<ChannelSite>) {
    let (up_tx, up_rx) = channel::<(usize, Vec<u8>)>();
    let mut to_sites = Vec::with_capacity(n_sites);
    let mut sites = Vec::with_capacity(n_sites);
    for site_id in 0..n_sites {
        let (down_tx, down_rx) = channel::<Vec<u8>>();
        to_sites.push(down_tx);
        sites.push(ChannelSite { site_id, to_leader: up_tx.clone(), from_leader: down_rx });
    }
    (up_rx, to_sites, sites)
}

impl LeaderTransport for ChannelLeader {
    fn n_sites(&self) -> usize {
        self.to_sites.len()
    }

    fn send(&self, site: usize, frame: Vec<u8>) -> Result<()> {
        self.to_sites[site].send(frame).context("site channel closed")
    }

    fn recv(&self, timeout: Option<Duration>) -> Result<(usize, Vec<u8>)> {
        match timeout {
            None => self.from_sites.recv().context("all site channels closed"),
            Some(t) => self.from_sites.recv_timeout(t).map_err(|e| match e {
                RecvTimeoutError::Timeout => anyhow!("timed out waiting for sites"),
                RecvTimeoutError::Disconnected => anyhow!("all site channels closed"),
            }),
        }
    }
}

impl SiteTransport for ChannelSite {
    fn site_id(&self) -> usize {
        self.site_id
    }

    fn send(&self, frame: Vec<u8>) -> Result<()> {
        self.to_leader.send((self.site_id, frame)).context("leader channel closed")
    }

    fn recv_opt(&self) -> Result<Option<Vec<u8>>> {
        // A dropped leader handle is the channel star's clean close: there
        // is no mid-frame state to tear (frames move whole), so hangup is
        // always at a frame boundary.
        Ok(self.from_leader.recv().ok())
    }
}

/// A [`ChannelSite`] that hangs up on the leader at a scripted point: just
/// before it sends its `hang_before`-th uplink frame (1-based), it drops
/// its own downlink receiver. The uplink frame still goes out, so the
/// leader processes it — and the leader's *reply* is the first send that
/// fails, deterministically, with "site N hung up". This is the send-
/// failure lever the crash sweep uses to exercise journaled
/// `SendFail` records: unlike a fault-plan `Drop` (which severs via a
/// mailbox event the reactor journals as `SiteDown`), a hangup makes the
/// reactor *itself* hit a failed send mid-step.
///
/// Severing sender-side (here) instead of having the fault plan hang up
/// the receiver matters for determinism: an mpsc send into a receiver
/// that is dropped *concurrently* can either succeed (frame silently
/// lost) or fail depending on thread timing, but a receiver dropped
/// before the triggering uplink frame is even enqueued guarantees the
/// leader's reply fails every execution at the same point.
pub struct HangupSite {
    site_id: usize,
    to_leader: Sender<(usize, Vec<u8>)>,
    from_leader: RefCell<Option<Receiver<Vec<u8>>>>,
    hang_before: u64,
    sent: Cell<u64>,
}

impl HangupSite {
    /// Wrap `inner`, hanging up just before its `hang_before`-th uplink
    /// send (1-based; 0 never hangs up).
    pub fn over(inner: ChannelSite, hang_before: u64) -> HangupSite {
        HangupSite {
            site_id: inner.site_id,
            to_leader: inner.to_leader,
            from_leader: RefCell::new(Some(inner.from_leader)),
            hang_before,
            sent: Cell::new(0),
        }
    }
}

impl SiteTransport for HangupSite {
    fn site_id(&self) -> usize {
        self.site_id
    }

    fn send(&self, frame: Vec<u8>) -> Result<()> {
        let n = self.sent.get() + 1;
        self.sent.set(n);
        if n == self.hang_before {
            // Hang up *first*: the downlink is gone before the leader can
            // even see this frame, so its reply fails deterministically.
            self.from_leader.borrow_mut().take();
        }
        self.to_leader.send((self.site_id, frame)).context("leader channel closed")
    }

    fn recv_opt(&self) -> Result<Option<Vec<u8>>> {
        match self.from_leader.borrow().as_ref() {
            Some(rx) => Ok(rx.recv().ok()),
            None => Ok(None), // we hung up on ourselves: a clean close
        }
    }
}

// ─── virtual clock ─────────────────────────────────────────────────────────

/// A controllable clock for socket-free reactor tests: `now()` is a real
/// [`Instant`] (so it flows straight into `RunMachine` deadlines), but it
/// only moves when a test calls [`VirtualClock::advance`] — straggler
/// deadlines become deterministic events instead of sleeps. Clones share
/// the same time.
#[derive(Clone, Debug)]
pub struct VirtualClock {
    base: Instant,
    offset: Arc<Mutex<Duration>>,
}

impl Default for VirtualClock {
    fn default() -> Self {
        VirtualClock::new()
    }
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        VirtualClock { base: Instant::now(), offset: Arc::new(Mutex::new(Duration::ZERO)) }
    }

    /// The current virtual instant: construction time plus every
    /// [`advance`](VirtualClock::advance) so far.
    pub fn now(&self) -> Instant {
        self.base + *self.offset.lock().unwrap()
    }

    /// Move time forward by `d` (for every clone of this clock).
    pub fn advance(&self, d: Duration) {
        *self.offset.lock().unwrap() += d;
    }

    /// Move time forward *to* `t` if it lies in the future; a `t` at or
    /// before [`now`](VirtualClock::now) is a no-op (the clock never runs
    /// backwards). The crash-recovery harness uses this to re-seed a
    /// surviving clock from the journal's last timestamp, so replayed
    /// deadlines and the resumed live timeline agree.
    pub fn advance_to(&self, t: Instant) {
        let mut off = self.offset.lock().unwrap();
        if t > self.base + *off {
            *off = t - self.base;
        }
    }
}

// ─── fault plan ────────────────────────────────────────────────────────────

/// One deterministic uplink fault, keyed by per-site frame counts (frame 1
/// is a site's first frame, in arrival order at the harness). Faults act on
/// the *uplink* (site → leader) because that is where the interesting
/// protocol state lives: registrations, codebooks, pulled labels.
#[derive(Clone, Debug)]
pub enum Fault {
    /// Sever the site's link after its `frames`-th frame has been
    /// delivered: a synthesized site-down follows it and every later frame
    /// from that site is swallowed. `frames = 0` kills the link before it
    /// delivers anything.
    DropSiteAfter { site: usize, frames: u64 },
    /// Hold the site's `frame`-th frame back until `release_after` further
    /// frames (from any site) have been delivered, then deliver it — a
    /// deterministic reordering, e.g. forcing one run's codebook to arrive
    /// after another run's whole exchange.
    DelayFrame { site: usize, frame: u64, release_after: u64 },
    /// Deliver the site's `frame`-th frame twice, back to back — a
    /// duplicated run-scoped frame must fail exactly that run ("site sent
    /// two codebooks"), nothing else.
    DuplicateFrame { site: usize, frame: u64 },
    /// Silently swallow every frame of `site` that belongs to run `run`
    /// (run-scoped uplink traffic only). The site stays healthy — so the
    /// *straggler deadline*, not a site-down, must catch the stall.
    DropRunFrames { site: usize, run: u32 },
}

/// What a [`FaultPlan`] tells the harness to do with the reactor mailbox.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Deliver {
    /// Hand this frame to the reactor as a site frame.
    Frame { site: usize, frame: Vec<u8> },
    /// Tell the reactor the site's link died.
    SiteDown { site: usize },
}

/// A stateful filter over the uplink: feed every `(site, frame)` through
/// [`FaultPlan::on_frame`] and deliver what comes back, in order. With no
/// faults it is the identity. All state is frame-count based, so a plan's
/// behavior is a pure function of the frame arrival order — no clocks, no
/// races.
#[derive(Debug, Default)]
pub struct FaultPlan {
    faults: Vec<Fault>,
    /// Frames *arrived* per site (1-based after increment).
    seen: Vec<u64>,
    /// Sites already severed.
    dead: Vec<bool>,
    /// Delayed frames: `(site, frame, deliveries still to wait out)`.
    held: Vec<(usize, Vec<u8>, u64)>,
}

impl FaultPlan {
    pub fn new(faults: Vec<Fault>) -> FaultPlan {
        FaultPlan { faults, ..FaultPlan::default() }
    }

    /// Run id of a run-scoped site→leader frame, if it is one (decoding
    /// errors and unscoped frames are `None` — the plan passes them on).
    fn run_of(frame: &[u8]) -> Option<u32> {
        match super::wire::decode(frame) {
            Ok(super::wire::Message::RunSiteInfo { run, .. })
            | Ok(super::wire::Message::RunCodebook { run, .. })
            | Ok(super::wire::Message::SiteLabels { run, .. })
            | Ok(super::wire::Message::Reject { run, .. }) => Some(run),
            _ => None,
        }
    }

    /// Feed one arriving uplink frame; returns the deliveries it causes,
    /// in order (possibly none, possibly several once held frames release).
    pub fn on_frame(&mut self, site: usize, frame: Vec<u8>) -> Vec<Deliver> {
        if self.seen.len() <= site {
            self.seen.resize(site + 1, 0);
            self.dead.resize(site + 1, false);
        }
        self.seen[site] += 1;
        let idx = self.seen[site];
        let mut out = Vec::new();
        if self.dead[site] {
            return out; // severed link: everything later is swallowed
        }

        let mut swallow = false;
        let mut duplicate = false;
        let mut delay: Option<u64> = None;
        let mut kill_after = false;
        for f in &self.faults {
            match *f {
                Fault::DropSiteAfter { site: s, frames } if s == site && idx > frames => {
                    // past the kill point without a delivery having
                    // triggered it (frames = 0): sever now, swallow this
                    self.dead[site] = true;
                    out.push(Deliver::SiteDown { site });
                    return out;
                }
                Fault::DropSiteAfter { site: s, frames } if s == site && idx == frames => {
                    kill_after = true;
                }
                Fault::DelayFrame { site: s, frame: f_idx, release_after }
                    if s == site && f_idx == idx =>
                {
                    delay = Some(release_after);
                }
                Fault::DuplicateFrame { site: s, frame: f_idx } if s == site && f_idx == idx => {
                    duplicate = true;
                }
                Fault::DropRunFrames { site: s, run } if s == site => {
                    if Self::run_of(&frame) == Some(run) {
                        swallow = true;
                    }
                }
                _ => {}
            }
        }

        if swallow {
            return out;
        }
        if let Some(release_after) = delay {
            if release_after == 0 {
                self.deliver(site, frame, &mut out);
            } else {
                self.held.push((site, frame, release_after));
            }
        } else if duplicate {
            let copy = frame.clone();
            self.deliver(site, frame, &mut out);
            self.deliver(site, copy, &mut out);
        } else {
            self.deliver(site, frame, &mut out);
        }
        if kill_after {
            self.dead[site] = true;
            out.push(Deliver::SiteDown { site });
        }
        out
    }

    /// Deliver one frame and tick every held frame's release countdown,
    /// emitting the ones that reach zero (their own deliveries tick the
    /// countdowns of frames still held).
    fn deliver(&mut self, site: usize, frame: Vec<u8>, out: &mut Vec<Deliver>) {
        out.push(Deliver::Frame { site, frame });
        let mut released = Vec::new();
        for h in &mut self.held {
            h.2 -= 1;
            if h.2 == 0 {
                released.push((h.0, std::mem::take(&mut h.1)));
            }
        }
        self.held.retain(|h| h.2 > 0);
        for (s, f) in released {
            if !self.dead[s] {
                self.deliver(s, f, out);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_flow_both_ways() {
        let (leader, sites) = star(2);
        sites[1].send(b"up".to_vec()).unwrap();
        let (id, frame) = leader.recv(None).unwrap();
        assert_eq!((id, frame.as_slice()), (1, b"up".as_slice()));

        leader.send(0, b"down".to_vec()).unwrap();
        assert_eq!(sites[0].recv().unwrap(), b"down".to_vec());
    }

    #[test]
    fn recv_timeout_expires() {
        let (leader, _sites) = star(1);
        assert!(leader.recv(Some(Duration::from_millis(10))).is_err());
    }

    #[test]
    fn dropped_leader_unblocks_site() {
        let (leader, sites) = star(1);
        drop(leader);
        assert!(sites[0].recv().is_err());
        assert!(sites[0].send(b"x".to_vec()).is_err());
    }

    #[test]
    fn virtual_clock_only_moves_on_advance() {
        let clock = VirtualClock::new();
        let t0 = clock.now();
        assert_eq!(clock.now(), t0, "time stands still without advance");
        let twin = clock.clone();
        twin.advance(Duration::from_secs(5));
        assert_eq!(clock.now(), t0 + Duration::from_secs(5), "clones share time");
    }

    #[test]
    fn virtual_clock_advance_to_is_monotone() {
        let clock = VirtualClock::new();
        let t0 = clock.now();
        clock.advance_to(t0 + Duration::from_secs(3));
        assert_eq!(clock.now(), t0 + Duration::from_secs(3));
        // advancing to the past (or present) never rewinds the clock
        clock.advance_to(t0 + Duration::from_secs(1));
        assert_eq!(clock.now(), t0 + Duration::from_secs(3));
        clock.advance_to(t0 + Duration::from_secs(3));
        assert_eq!(clock.now(), t0 + Duration::from_secs(3));
    }

    fn frames_of(deliveries: &[Deliver]) -> Vec<(usize, Vec<u8>)> {
        deliveries
            .iter()
            .filter_map(|d| match d {
                Deliver::Frame { site, frame } => Some((*site, frame.clone())),
                Deliver::SiteDown { .. } => None,
            })
            .collect()
    }

    #[test]
    fn empty_fault_plan_is_the_identity() {
        let mut plan = FaultPlan::new(Vec::new());
        for i in 0..4u8 {
            let out = plan.on_frame(i as usize % 2, vec![i]);
            assert_eq!(out, vec![Deliver::Frame { site: i as usize % 2, frame: vec![i] }]);
        }
    }

    #[test]
    fn drop_site_after_severs_and_swallows() {
        let mut plan = FaultPlan::new(vec![Fault::DropSiteAfter { site: 0, frames: 2 }]);
        assert_eq!(plan.on_frame(0, vec![1]).len(), 1);
        let out = plan.on_frame(0, vec![2]);
        assert_eq!(
            out,
            vec![
                Deliver::Frame { site: 0, frame: vec![2] },
                Deliver::SiteDown { site: 0 }
            ]
        );
        assert!(plan.on_frame(0, vec![3]).is_empty(), "severed link swallows");
        // the other site is untouched
        assert_eq!(plan.on_frame(1, vec![9]).len(), 1);
    }

    #[test]
    fn drop_site_after_zero_kills_before_first_frame() {
        let mut plan = FaultPlan::new(vec![Fault::DropSiteAfter { site: 1, frames: 0 }]);
        assert_eq!(plan.on_frame(1, vec![7]), vec![Deliver::SiteDown { site: 1 }]);
        assert!(plan.on_frame(1, vec![8]).is_empty());
    }

    #[test]
    fn delay_frame_reorders_deterministically() {
        // hold site 0's 1st frame until 2 more deliveries have happened
        let mut plan = FaultPlan::new(vec![Fault::DelayFrame {
            site: 0,
            frame: 1,
            release_after: 2,
        }]);
        assert!(plan.on_frame(0, vec![10]).is_empty(), "held, not delivered");
        assert_eq!(frames_of(&plan.on_frame(1, vec![20])), vec![(1, vec![20])]);
        // the second delivery releases the held frame right after itself
        let out = plan.on_frame(1, vec![21]);
        assert_eq!(frames_of(&out), vec![(1, vec![21]), (0, vec![10])]);
    }

    #[test]
    fn duplicate_frame_delivers_twice() {
        let mut plan = FaultPlan::new(vec![Fault::DuplicateFrame { site: 0, frame: 2 }]);
        assert_eq!(plan.on_frame(0, vec![1]).len(), 1);
        let out = plan.on_frame(0, vec![2]);
        assert_eq!(frames_of(&out), vec![(0, vec![2]), (0, vec![2])]);
    }

    #[test]
    fn drop_run_frames_swallows_only_that_run() {
        use super::super::wire::{encode, Message};
        let mut plan = FaultPlan::new(vec![Fault::DropRunFrames { site: 0, run: 2 }]);
        let run1 = encode(&Message::RunSiteInfo { run: 1, site: 0, n_points: 5, dim: 2 });
        let run2 = encode(&Message::RunSiteInfo { run: 2, site: 0, n_points: 5, dim: 2 });
        assert_eq!(plan.on_frame(0, run1.clone()).len(), 1, "run 1 passes");
        assert!(plan.on_frame(0, run2.clone()).is_empty(), "run 2 swallowed");
        // the same run from another site passes (the fault names site 0)
        let run2_s1 = encode(&Message::RunSiteInfo { run: 2, site: 1, n_points: 5, dim: 2 });
        assert_eq!(plan.on_frame(1, run2_s1).len(), 1);
    }
}
