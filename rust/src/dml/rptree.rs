//! Random-projection trees — the paper's Algorithm 3 (§2.2.2, from [59]).
//!
//! A node is split by projecting its points onto a random unit direction
//! `r` and cutting at `c ~ U[min, max]` of the projections; recursion stops
//! when a node holds fewer than `n_T` points. Leaf centroids become the
//! codewords. rpTrees adapt to intrinsic dimension (Dasgupta–Freund) and
//! cost O(n log(n/leaf)) — the cheap-but-slightly-coarser DML of Table 4.
//!
//! Robustness beyond the paper's pseudocode: a uniform cut can land so that
//! one side is empty (duplicate-heavy projections); we retry a few fresh
//! directions and fall back to a median split, and declare a leaf if the
//! node is constant. This keeps the tree finite on degenerate data without
//! changing behaviour on continuous data (empty sides have probability 0).

use crate::data::Dataset;
use crate::rng::Rng;

use super::Codebook;

/// Retries of (direction, cut) before falling back to the median split.
const SPLIT_RETRIES: usize = 4;

/// Build an rpTree codebook with leaves of at most `max_leaf` points.
pub fn build(data: &Dataset, max_leaf: usize, rng: &mut Rng) -> Codebook {
    let dim = data.dim;
    if data.is_empty() {
        return Codebook { dim, codewords: vec![], weights: vec![], assign: vec![] };
    }
    let mut assign = vec![0u32; data.len()];
    let mut codewords: Vec<f32> = Vec::new();
    let mut weights: Vec<u32> = Vec::new();
    for node in leaf_groups(&data.points, dim, max_leaf, rng) {
        emit_leaf(data, &node, &mut assign, &mut codewords, &mut weights);
    }
    Codebook { dim, codewords, weights, assign }
}

/// Partition `n = points.len()/dim` raw points into rp-tree leaves of at
/// most `max_leaf` members and return the leaf membership lists.
///
/// This exposes the tree *structure* (rather than the leaf centroids) so
/// other consumers can use it — the sparse k-NN affinity builder
/// ([`crate::spectral::sparse`]) treats points sharing a leaf as
/// approximate-neighbor candidates, one tree per voting round. [`build`]
/// layers codebook emission on top of the same partition.
///
/// Every point lands in exactly one leaf; leaves exceed `max_leaf` only for
/// constant (unsplittable) nodes. Deterministic in the `rng` seed.
pub fn leaf_groups(points: &[f32], dim: usize, max_leaf: usize, rng: &mut Rng) -> Vec<Vec<u32>> {
    assert!(dim > 0);
    let n = points.len() / dim;
    assert_eq!(points.len(), n * dim, "points buffer not a multiple of dim");
    let mut groups: Vec<Vec<u32>> = Vec::new();
    if n == 0 {
        return groups;
    }
    let max_leaf = max_leaf.max(1);
    let point = |i: usize| &points[i * dim..(i + 1) * dim];

    // worklist of (point-index buffers); explicit stack instead of recursion
    let mut stack: Vec<Vec<u32>> = vec![(0..n as u32).collect()];
    let mut proj: Vec<f32> = Vec::new();
    let mut dir: Vec<f32> = vec![0.0; dim];

    while let Some(node) = stack.pop() {
        if node.len() <= max_leaf {
            groups.push(node);
            continue;
        }

        let mut split: Option<(Vec<u32>, Vec<u32>)> = None;
        for _try in 0..SPLIT_RETRIES {
            // random unit direction
            let mut norm = 0.0f64;
            for v in dir.iter_mut() {
                let z = rng.normal();
                *v = z as f32;
                norm += z * z;
            }
            let norm = norm.sqrt().max(1e-12) as f32;
            for v in dir.iter_mut() {
                *v /= norm;
            }

            // project node points
            proj.clear();
            proj.reserve(node.len());
            let mut lo = f32::INFINITY;
            let mut hi = f32::NEG_INFINITY;
            for &i in &node {
                let p = point(i as usize);
                let mut s = 0.0f32;
                for j in 0..dim {
                    s += p[j] * dir[j];
                }
                proj.push(s);
                lo = lo.min(s);
                hi = hi.max(s);
            }
            if hi - lo <= 1e-12 {
                continue; // degenerate direction; try another
            }

            let c = lo + (hi - lo) * rng.f32();
            let mut left = Vec::new();
            let mut right = Vec::new();
            for (k, &i) in node.iter().enumerate() {
                if proj[k] < c {
                    left.push(i);
                } else {
                    right.push(i);
                }
            }
            if !left.is_empty() && !right.is_empty() {
                split = Some((left, right));
                break;
            }
        }

        let (left, right) = match split {
            Some(s) => s,
            None => {
                // All retries failed: either the node is constant (leaf) or
                // we median-split the last projection.
                let distinct =
                    node.iter().any(|&i| point(i as usize) != point(node[0] as usize));
                if !distinct {
                    groups.push(node);
                    continue;
                }
                // median split on the last computed projection
                let mut order: Vec<usize> = (0..node.len()).collect();
                order.sort_by(|&a, &b| proj[a].partial_cmp(&proj[b]).unwrap());
                let mid = node.len() / 2;
                let left: Vec<u32> = order[..mid].iter().map(|&k| node[k]).collect();
                let right: Vec<u32> = order[mid..].iter().map(|&k| node[k]).collect();
                if left.is_empty() || right.is_empty() {
                    groups.push(node);
                    continue;
                }
                (left, right)
            }
        };
        stack.push(left);
        stack.push(right);
    }

    groups
}

fn emit_leaf(
    data: &Dataset,
    node: &[u32],
    assign: &mut [u32],
    codewords: &mut Vec<f32>,
    weights: &mut Vec<u32>,
) {
    let dim = data.dim;
    let code_id = weights.len() as u32;
    let mut mean = vec![0.0f64; dim];
    for &i in node {
        let p = data.point(i as usize);
        for j in 0..dim {
            mean[j] += p[j] as f64;
        }
        assign[i as usize] = code_id;
    }
    let inv = 1.0 / node.len() as f64;
    codewords.extend(mean.iter().map(|&s| (s * inv) as f32));
    weights.push(node.len() as u32);
}

/// Online fold of points `new_from..` into an rpTree codebook: each new
/// point joins its nearest leaf (whose centroid tracks the running mean),
/// then any leaf that overflowed `max_leaf` is re-split *in place* by
/// running [`leaf_groups`] over just that leaf's members — the rest of
/// the tree is untouched, so ingest costs O(new · codes · d) plus the
/// split work of the overflowing leaves only.
pub fn fold_in(
    cb: &mut Codebook,
    data: &Dataset,
    new_from: usize,
    max_leaf: usize,
    rng: &mut Rng,
) {
    let dim = cb.dim;
    debug_assert_eq!(cb.assign.len(), new_from);
    debug_assert!(cb.n_codes() > 0, "fold_in needs a non-empty codebook");
    let max_leaf = max_leaf.max(1);

    let mut touched: Vec<u32> = Vec::new();
    for i in new_from..data.len() {
        let best = super::nearest_code(cb, data.point(i));
        let b = best as usize;
        cb.weights[b] += 1;
        let w = cb.weights[b] as f32;
        let p = data.point(i);
        let row = &mut cb.codewords[b * dim..(b + 1) * dim];
        for (c, &x) in row.iter_mut().zip(p) {
            *c += (x - *c) / w;
        }
        cb.assign.push(best);
        if cb.weights[b] as usize > max_leaf && !touched.contains(&best) {
            touched.push(best);
        }
    }
    touched.sort_unstable(); // split order is deterministic, not arrival order

    for leaf in touched {
        split_leaf(cb, data, leaf, max_leaf, rng);
    }
}

/// Re-split one overflowing leaf: gather its members, partition them with
/// [`leaf_groups`], keep the first group under the old code id and append
/// the rest as fresh codewords (leaf means recomputed exactly, like
/// [`emit_leaf`]). A constant (unsplittable) leaf stays oversized, the
/// same concession [`build`] makes.
fn split_leaf(cb: &mut Codebook, data: &Dataset, leaf: u32, max_leaf: usize, rng: &mut Rng) {
    let dim = cb.dim;
    let members: Vec<u32> =
        (0..cb.assign.len() as u32).filter(|&i| cb.assign[i as usize] == leaf).collect();
    let mut buf: Vec<f32> = Vec::with_capacity(members.len() * dim);
    for &m in &members {
        buf.extend_from_slice(data.point(m as usize));
    }
    let groups = leaf_groups(&buf, dim, max_leaf, rng);
    if groups.len() <= 1 {
        return; // constant node: cannot split, stays an oversized leaf
    }
    for (g_idx, group) in groups.iter().enumerate() {
        let code = if g_idx == 0 { leaf } else { cb.weights.len() as u32 };
        let mut mean = vec![0.0f64; dim];
        for &local in group {
            let i = members[local as usize] as usize;
            for j in 0..dim {
                mean[j] += data.point(i)[j] as f64;
            }
            cb.assign[i] = code;
        }
        let inv = 1.0 / group.len() as f64;
        let row: Vec<f32> = mean.iter().map(|&s| (s * inv) as f32).collect();
        if g_idx == 0 {
            cb.codewords[leaf as usize * dim..(leaf as usize + 1) * dim]
                .copy_from_slice(&row);
            cb.weights[leaf as usize] = group.len() as u32;
        } else {
            cb.codewords.extend_from_slice(&row);
            cb.weights.push(group.len() as u32);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gmm;
    use crate::data::Dataset;

    #[test]
    fn leaf_size_respected() {
        let ds = gmm::paper_mixture_2d(5_000, 3);
        let mut rng = Rng::new(7);
        let cb = build(&ds, 40, &mut rng);
        cb.validate(ds.len()).unwrap();
        assert!(cb.weights.iter().all(|&w| w <= 40), "oversized leaf");
        // compression roughly n / max_leaf .. a few ×
        assert!(cb.n_codes() >= 125);
        assert!(cb.n_codes() <= 2_000);
    }

    #[test]
    fn codewords_are_leaf_means() {
        let ds = gmm::paper_mixture_2d(1_000, 9);
        let mut rng = Rng::new(2);
        let cb = build(&ds, 25, &mut rng);
        let mut sums = vec![0.0f64; cb.n_codes() * 2];
        let mut counts = vec![0u64; cb.n_codes()];
        for i in 0..ds.len() {
            let a = cb.assign[i] as usize;
            counts[a] += 1;
            sums[a * 2] += ds.point(i)[0] as f64;
            sums[a * 2 + 1] += ds.point(i)[1] as f64;
        }
        for c in 0..cb.n_codes() {
            let cw = cb.codeword(c);
            assert!((cw[0] as f64 - sums[c * 2] / counts[c] as f64).abs() < 1e-4);
            assert!((cw[1] as f64 - sums[c * 2 + 1] / counts[c] as f64).abs() < 1e-4);
        }
    }

    #[test]
    fn constant_data_single_leaf_per_bucket() {
        let mut ds = Dataset::new("const", 3, 1);
        for _ in 0..500 {
            ds.push(&[1.0, 2.0, 3.0], 0);
        }
        let mut rng = Rng::new(5);
        let cb = build(&ds, 40, &mut rng);
        cb.validate(500).unwrap();
        // cannot split constant data: one leaf, even though it exceeds max_leaf
        assert_eq!(cb.n_codes(), 1);
        assert_eq!(cb.codeword(0), &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn distortion_shrinks_with_smaller_leaves() {
        let ds = gmm::paper_mixture_2d(4_000, 11);
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(1);
        let coarse = build(&ds, 400, &mut r1);
        let fine = build(&ds, 20, &mut r2);
        assert!(fine.distortion(&ds) < coarse.distortion(&ds));
    }

    #[test]
    fn deterministic_in_seed() {
        let ds = gmm::paper_mixture_2d(1_000, 13);
        let mut r1 = Rng::new(21);
        let mut r2 = Rng::new(21);
        let a = build(&ds, 50, &mut r1);
        let b = build(&ds, 50, &mut r2);
        assert_eq!(a.assign, b.assign);
        assert_eq!(a.codewords, b.codewords);
    }

    #[test]
    fn fold_in_splits_overflowing_leaves_only() {
        let full = gmm::paper_mixture_2d(1_500, 27);
        let cut = 1_200;
        let mut base = Dataset::new("b", full.dim, full.n_classes);
        for i in 0..cut {
            base.push(full.point(i), full.labels[i]);
        }
        let mut rng = Rng::new(5);
        let mut cb = build(&base, 30, &mut rng);
        let before_codes = cb.n_codes();

        let mut grown = base.clone();
        for i in cut..full.len() {
            grown.push(full.point(i), full.labels[i]);
        }
        let mut fold_rng = Rng::new(99);
        fold_in(&mut cb, &grown, cut, 30, &mut fold_rng);
        cb.validate(grown.len()).unwrap();
        // continuous data: every leaf respects the cap after the fold
        assert!(cb.weights.iter().all(|&w| w <= 30), "oversized leaf after fold");
        // overflows were split, so the tree grew where the points landed
        assert!(cb.n_codes() >= before_codes);
    }

    #[test]
    fn fold_in_constant_leaf_stays_oversized() {
        let mut ds = Dataset::new("c", 2, 1);
        for _ in 0..10 {
            ds.push(&[1.0, 1.0], 0);
        }
        let mut rng = Rng::new(3);
        let mut cb = build(&ds, 10, &mut rng);
        assert_eq!(cb.n_codes(), 1);
        for _ in 0..5 {
            ds.push(&[1.0, 1.0], 0);
        }
        let mut fold_rng = Rng::new(4);
        fold_in(&mut cb, &ds, 10, 10, &mut fold_rng);
        cb.validate(15).unwrap();
        assert_eq!(cb.n_codes(), 1); // unsplittable: one oversized leaf
        assert_eq!(cb.weights, vec![15]);
    }

    #[test]
    fn empty_input() {
        let ds = Dataset::new("e", 2, 1);
        let mut rng = Rng::new(0);
        let cb = build(&ds, 10, &mut rng);
        assert_eq!(cb.n_codes(), 0);
        assert!(cb.assign.is_empty());
    }

    #[test]
    fn leaf_groups_partition_every_point_once() {
        let ds = gmm::paper_mixture_2d(2_000, 15);
        let mut rng = Rng::new(17);
        let groups = leaf_groups(&ds.points, 2, 30, &mut rng);
        let mut seen = vec![false; ds.len()];
        for g in &groups {
            assert!(!g.is_empty());
            assert!(g.len() <= 30, "leaf of {} exceeds cap", g.len());
            for &i in g {
                assert!(!seen[i as usize], "point {i} in two leaves");
                seen[i as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "some point missing from the partition");
    }

    #[test]
    fn leaf_groups_whole_set_when_cap_covers_n() {
        let ds = gmm::paper_mixture_2d(100, 19);
        let mut rng = Rng::new(21);
        let groups = leaf_groups(&ds.points, 2, 100, &mut rng);
        assert_eq!(groups.len(), 1);
        assert_eq!(groups[0].len(), 100);
    }

    #[test]
    fn leaf_groups_empty_points() {
        let mut rng = Rng::new(23);
        assert!(leaf_groups(&[], 3, 10, &mut rng).is_empty());
    }
}
