//! Random-sampling "DML" — the naive baseline the paper's framework is
//! implicitly compared against.
//!
//! Landmark/subsampling methods (paper §3: Chen–Cai landmark spectral
//! clustering, Nyström selection, …) reduce data by keeping a *random
//! subset of original points* as representatives. Implementing it as a
//! third [`super::DmlKind`] lets the ablation bench answer: at the same
//! communication budget, how much accuracy do distortion-minimizing
//! codewords buy over plain random landmarks?
//!
//! (Spoiler, DESIGN.md A6: on well-separated data both work; as overlap
//! grows, K-means codewords — which sit at local centers of mass — give a
//! cleaner codeword graph than raw samples, and they also don't leak
//! original points.)
//!
//! Construction: choose `k` distinct points uniformly, assign every point
//! to its nearest landmark (parallel chunks), weights = Voronoi cell
//! sizes. O(n·k·d) — same assignment cost as one Lloyd sweep.

use crate::data::Dataset;
use crate::linalg::kernels;
use crate::par;
use crate::rng::Rng;

use super::Codebook;

/// Build a random-landmark codebook of `k` codewords.
pub fn build(data: &Dataset, k: usize, rng: &mut Rng) -> Codebook {
    let n = data.len();
    let dim = data.dim;
    if n == 0 {
        return Codebook { dim, codewords: vec![], weights: vec![], assign: vec![] };
    }
    let k = k.min(n).max(1);

    let picks = rng.sample_indices(n, k);
    let mut codewords = Vec::with_capacity(k * dim);
    for &p in &picks {
        codewords.extend_from_slice(data.point(p));
    }

    // nearest-landmark assignment, transposed-axpy form (same scheme as
    // the Lloyd hot loop)
    let mut landmarks_t = vec![0.0f32; k * dim];
    for c in 0..k {
        for j in 0..dim {
            landmarks_t[j * k + c] = codewords[c * dim + j];
        }
    }
    let c_norm: Vec<f32> = (0..k)
        .map(|c| codewords[c * dim..(c + 1) * dim].iter().map(|v| v * v).sum())
        .collect();

    let mut assign = vec![0u32; n];
    let points = &data.points;
    let lt = &landmarks_t;
    let cn = &c_norm;
    par::par_chunks_mut(&mut assign, 1024, |start, chunk| {
        let mut scores = vec![0.0f32; k];
        for (off, slot) in chunk.iter_mut().enumerate() {
            let i = start + off;
            let p = &points[i * dim..(i + 1) * dim];
            scores.copy_from_slice(cn);
            for (j, &pj) in p.iter().enumerate() {
                let coef = -2.0 * pj;
                let row = &lt[j * k..(j + 1) * k];
                kernels::axpy_f32(&mut scores, coef, row);
            }
            let mut best = 0u32;
            let mut best_score = f32::INFINITY;
            for (c, &s) in scores.iter().enumerate() {
                if s < best_score {
                    best_score = s;
                    best = c as u32;
                }
            }
            *slot = best;
        }
    });

    let mut weights = vec![0u32; k];
    for &a in &assign {
        weights[a as usize] += 1;
    }

    // Landmarks with empty Voronoi cells can occur (a landmark strictly
    // closer to another landmark than any point is to it — rare but real);
    // compact them out like the Lloyd path does.
    if weights.iter().any(|&w| w == 0) {
        let mut remap = vec![u32::MAX; k];
        let mut cw = Vec::new();
        let mut wts = Vec::new();
        let mut next = 0u32;
        for c in 0..k {
            if weights[c] > 0 {
                remap[c] = next;
                next += 1;
                cw.extend_from_slice(&codewords[c * dim..(c + 1) * dim]);
                wts.push(weights[c]);
            }
        }
        for a in assign.iter_mut() {
            *a = remap[*a as usize];
        }
        return Codebook { dim, codewords: cw, weights: wts, assign };
    }

    Codebook { dim, codewords, weights, assign }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gmm;

    #[test]
    fn codebook_is_consistent() {
        let ds = gmm::paper_mixture_2d(2_000, 3);
        let mut rng = Rng::new(5);
        let cb = build(&ds, 64, &mut rng);
        cb.validate(ds.len()).unwrap();
        assert!(cb.n_codes() <= 64);
    }

    #[test]
    fn codewords_are_original_points() {
        // the defining property (and the privacy weakness) of the baseline
        let ds = gmm::paper_mixture_2d(500, 7);
        let mut rng = Rng::new(9);
        let cb = build(&ds, 20, &mut rng);
        for c in 0..cb.n_codes() {
            let cw = cb.codeword(c);
            let hit = (0..ds.len()).any(|i| ds.point(i) == cw);
            assert!(hit, "codeword {c} is not an original point");
        }
    }

    #[test]
    fn assignment_is_nearest_landmark() {
        let ds = gmm::paper_mixture_2d(300, 11);
        let mut rng = Rng::new(13);
        let cb = build(&ds, 10, &mut rng);
        for i in 0..ds.len() {
            let p = ds.point(i);
            let mut best = 0usize;
            let mut best_d = f64::INFINITY;
            for c in 0..cb.n_codes() {
                let cw = cb.codeword(c);
                let d: f64 =
                    p.iter().zip(cw).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
                if d < best_d {
                    best_d = d;
                    best = c;
                }
            }
            // allow exact ties to go either way
            let chosen = cb.assign[i] as usize;
            if chosen != best {
                let cw = cb.codeword(chosen);
                let d: f64 =
                    p.iter().zip(cw).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
                assert!((d - best_d).abs() < 1e-3, "point {i} misassigned");
            }
        }
    }

    #[test]
    fn higher_distortion_than_kmeans() {
        // the quantity Theorem 2 says K-means optimizes and sampling doesn't
        let ds = gmm::paper_mixture_2d(4_000, 17);
        let mut r1 = Rng::new(1);
        let sample_cb = build(&ds, 100, &mut r1);
        let mut r2 = Rng::new(1);
        let kmeans_cb = super::super::kmeans::lloyd(&ds, 100, 30, 1e-6, &mut r2);
        assert!(
            sample_cb.distortion(&ds) > kmeans_cb.distortion(&ds),
            "random landmarks should quantize worse than Lloyd centroids"
        );
    }

    #[test]
    fn empty_input() {
        let ds = crate::data::Dataset::new("e", 2, 1);
        let mut rng = Rng::new(0);
        assert_eq!(build(&ds, 5, &mut rng).n_codes(), 0);
    }
}
