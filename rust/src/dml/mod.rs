//! Distortion-minimizing local (DML) transformations — the paper's §2.2.
//!
//! A DML compresses a site's local data into a [`Codebook`]: a small set of
//! representative points (codewords), the size of each group, and the
//! point→codeword correspondence the site keeps for label population. Two
//! implementations, as in the paper:
//!
//! * [`kmeans`] — Lloyd's algorithm (with incremental k-means++ seeding on
//!   a subsample); codewords are cluster centroids. O(n·k·d) per sweep,
//!   parallelized over points.
//! * [`rptree`] — random-projection trees (the paper's Algorithm 3);
//!   codewords are leaf centroids. O(n log(n/leaf)) — much cheaper than
//!   K-means at equal compression, at slightly higher distortion, exactly
//!   the trade the paper reports (Tables 3 vs 4).
//!
//! The *local* property that makes the framework work: building a codebook
//! touches only the site's own data — no cross-site information.

pub mod kmeans;
pub mod rptree;
pub mod sample;

use crate::data::Dataset;
use crate::rng::Rng;

/// Which DML transform to run at the sites.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DmlKind {
    KMeans,
    RpTree,
    /// Random-landmark baseline (not a DML — kept for the A6 ablation).
    RandomSample,
}

impl DmlKind {
    pub fn parse(s: &str) -> Option<DmlKind> {
        match s.to_ascii_lowercase().as_str() {
            "kmeans" | "k-means" => Some(DmlKind::KMeans),
            "rptree" | "rptrees" | "rp-tree" => Some(DmlKind::RpTree),
            "sample" | "random-sample" | "landmarks" => Some(DmlKind::RandomSample),
            _ => None,
        }
    }
}

impl std::fmt::Display for DmlKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DmlKind::KMeans => write!(f, "kmeans"),
            DmlKind::RpTree => write!(f, "rptrees"),
            DmlKind::RandomSample => write!(f, "sample"),
        }
    }
}

/// The product of a DML transform at one site.
#[derive(Clone, Debug)]
pub struct Codebook {
    pub dim: usize,
    /// `n_codes × dim` row-major codewords (group centroids).
    pub codewords: Vec<f32>,
    /// Group size per codeword (`W_i` in Algorithm 1).
    pub weights: Vec<u32>,
    /// For every local point, the index of its codeword. This is the
    /// correspondence table kept *at the site* — it is never transmitted.
    pub assign: Vec<u32>,
}

impl Codebook {
    pub fn n_codes(&self) -> usize {
        self.weights.len()
    }

    #[inline]
    pub fn codeword(&self, i: usize) -> &[f32] {
        &self.codewords[i * self.dim..(i + 1) * self.dim]
    }

    /// Bytes this codebook costs on the wire (codewords + weights). The
    /// assignment table stays local, so it does not count.
    pub fn wire_bytes(&self) -> u64 {
        (self.codewords.len() * 4 + self.weights.len() * 4) as u64
    }

    /// Mean squared quantization distortion E‖X − q(X)‖² over `data` —
    /// the quantity Theorem 2/3 bound.
    pub fn distortion(&self, data: &Dataset) -> f64 {
        assert_eq!(data.len(), self.assign.len());
        if data.is_empty() {
            return 0.0;
        }
        let mut total = 0.0f64;
        for i in 0..data.len() {
            let cw = self.codeword(self.assign[i] as usize);
            let p = data.point(i);
            let mut d2 = 0.0f64;
            for j in 0..self.dim {
                let d = (p[j] - cw[j]) as f64;
                d2 += d * d;
            }
            total += d2;
        }
        total / data.len() as f64
    }

    /// Internal consistency check (used by tests and debug assertions):
    /// weights sum to the site size and match the assignment histogram.
    pub fn validate(&self, n_points: usize) -> Result<(), String> {
        if self.codewords.len() != self.n_codes() * self.dim {
            return Err("codeword buffer size mismatch".into());
        }
        if self.assign.len() != n_points {
            return Err(format!(
                "assignment table covers {} points, site has {n_points}",
                self.assign.len()
            ));
        }
        let mut hist = vec![0u32; self.n_codes()];
        for &a in &self.assign {
            let a = a as usize;
            if a >= self.n_codes() {
                return Err(format!("assignment {a} out of range"));
            }
            hist[a] += 1;
        }
        if hist != self.weights {
            return Err("weights disagree with assignment histogram".into());
        }
        if self.weights.iter().map(|&w| w as usize).sum::<usize>() != n_points {
            return Err("weights do not sum to site size".into());
        }
        Ok(())
    }
}

/// Parameters shared by both DML implementations. `PartialEq` is exact —
/// the streaming site keys its DML result cache on `(params, shard
/// version)`, so two work orders compare equal iff a cached codebook can
/// stand in for a recompute.
#[derive(Clone, Debug, PartialEq)]
pub struct DmlParams {
    pub kind: DmlKind,
    /// Codeword budget. For K-means this is the exact number of clusters;
    /// for rpTrees it sets the max leaf size to `ceil(n / target_codes)`
    /// (matching how the paper equalizes compression across the two DMLs).
    pub target_codes: usize,
    /// Lloyd sweep cap (K-means only).
    pub max_iters: usize,
    /// Relative centroid-shift tolerance for early exit (K-means only).
    pub tol: f64,
    pub seed: u64,
}

impl Default for DmlParams {
    fn default() -> Self {
        DmlParams { kind: DmlKind::KMeans, target_codes: 256, max_iters: 30, tol: 1e-6, seed: 0 }
    }
}

/// Run the configured DML on one site's data.
pub fn apply(data: &Dataset, params: &DmlParams) -> Codebook {
    let mut rng = Rng::new(params.seed);
    match params.kind {
        DmlKind::KMeans => kmeans::lloyd(
            data,
            params.target_codes.min(data.len().max(1)),
            params.max_iters,
            params.tol,
            &mut rng,
        ),
        DmlKind::RpTree => {
            let max_leaf = data.len().div_ceil(params.target_codes.max(1)).max(1);
            rptree::build(data, max_leaf, &mut rng)
        }
        DmlKind::RandomSample => {
            sample::build(data, params.target_codes.min(data.len().max(1)), &mut rng)
        }
    }
}

/// Fold points `new_from..data.len()` into an existing codebook
/// incrementally — the streaming-site ingest path. No full rescan:
///
/// * K-means — each new point joins its nearest codeword, which tracks
///   the running mean of its group (mini-batch refinement);
/// * rpTrees — each new point joins its nearest leaf; a leaf that
///   overflows the (recomputed) `ceil(n / target_codes)` cap is split
///   in place via [`rptree::leaf_groups`] over its members only;
/// * random sample — landmarks are real points and stay fixed; new
///   points only join their nearest landmark's group.
///
/// An empty codebook (or `new_from == 0`) falls back to a fresh
/// [`apply`] — there is nothing to fold into. The result always passes
/// [`Codebook::validate`] for the extended shard; it is an *approximate*
/// refresh, deliberately not bit-equal to a from-scratch rebuild (the
/// site's result cache recomputes exactly when a job needs that).
pub fn fold_in(cb: &mut Codebook, data: &Dataset, new_from: usize, params: &DmlParams) {
    debug_assert_eq!(cb.assign.len(), new_from);
    if cb.n_codes() == 0 || new_from == 0 {
        *cb = apply(data, params);
        return;
    }
    match params.kind {
        DmlKind::KMeans => kmeans::fold_in(cb, data, new_from),
        DmlKind::RpTree => {
            let max_leaf = data.len().div_ceil(params.target_codes.max(1)).max(1);
            // A distinct deterministic stream from the build's: fold-time
            // splits must not replay the tree-construction randomness.
            let mut rng = Rng::new(params.seed ^ 0x666f_6c64_2d69_6e21);
            rptree::fold_in(cb, data, new_from, max_leaf, &mut rng);
        }
        DmlKind::RandomSample => {
            for i in new_from..data.len() {
                let best = nearest_code(cb, data.point(i));
                cb.weights[best as usize] += 1;
                cb.assign.push(best);
            }
        }
    }
    debug_assert!(cb.validate(data.len()).is_ok());
}

/// Index of the codeword nearest to `p` (squared Euclidean).
pub(crate) fn nearest_code(cb: &Codebook, p: &[f32]) -> u32 {
    let mut best = 0u32;
    let mut best_d = f64::INFINITY;
    for c in 0..cb.n_codes() {
        let d2 = crate::linalg::kernels::sqdist_f32(p, cb.codeword(c));
        if d2 < best_d {
            best_d = d2;
            best = c as u32;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gmm;

    #[test]
    fn apply_kmeans_validates() {
        let ds = gmm::paper_mixture_2d(2_000, 3);
        let cb = apply(&ds, &DmlParams { target_codes: 50, ..Default::default() });
        assert_eq!(cb.n_codes(), 50);
        cb.validate(ds.len()).unwrap();
    }

    #[test]
    fn apply_rptree_validates_and_respects_budget() {
        let ds = gmm::paper_mixture_2d(2_000, 4);
        let cb = apply(
            &ds,
            &DmlParams { kind: DmlKind::RpTree, target_codes: 50, ..Default::default() },
        );
        cb.validate(ds.len()).unwrap();
        // leaf size cap = ceil(2000/50) = 40 ⇒ at least 50 leaves
        assert!(cb.n_codes() >= 50, "{} codes", cb.n_codes());
        assert!(cb.weights.iter().all(|&w| w <= 40));
    }

    #[test]
    fn distortion_decreases_with_budget() {
        let ds = gmm::paper_mixture_2d(4_000, 5);
        let lo = apply(&ds, &DmlParams { target_codes: 10, ..Default::default() });
        let hi = apply(&ds, &DmlParams { target_codes: 200, ..Default::default() });
        assert!(
            hi.distortion(&ds) < lo.distortion(&ds),
            "more codewords must mean less distortion"
        );
    }

    #[test]
    fn wire_bytes_excludes_assignment() {
        let ds = gmm::paper_mixture_2d(1_000, 6);
        let cb = apply(&ds, &DmlParams { target_codes: 32, ..Default::default() });
        assert_eq!(cb.wire_bytes(), (32 * 2 * 4 + 32 * 4) as u64);
        assert!(cb.wire_bytes() < ds.wire_bytes() / 10);
    }

    #[test]
    fn parse_kind() {
        assert_eq!(DmlKind::parse("kmeans"), Some(DmlKind::KMeans));
        assert_eq!(DmlKind::parse("rpTrees"), Some(DmlKind::RpTree));
        assert_eq!(DmlKind::parse("dbscan"), None);
    }

    /// The ingest fold keeps every codebook invariant and stays close to
    /// a from-scratch rebuild in distortion, for each DML kind.
    #[test]
    fn fold_in_extends_every_kind_consistently() {
        let full = gmm::paper_mixture_2d(1_200, 31);
        let cut = 1_000;
        let mut base = Dataset::new("base", full.dim, full.n_classes);
        for i in 0..cut {
            base.push(full.point(i), full.labels[i]);
        }
        for kind in [DmlKind::KMeans, DmlKind::RpTree, DmlKind::RandomSample] {
            let params = DmlParams { kind, target_codes: 24, seed: 7, ..Default::default() };
            let mut cb = apply(&base, &params);
            let mut grown = base.clone();
            for i in cut..full.len() {
                grown.push(full.point(i), full.labels[i]);
            }
            fold_in(&mut cb, &grown, cut, &params);
            cb.validate(grown.len()).unwrap();
            assert_eq!(
                cb.weights.iter().map(|&w| w as usize).sum::<usize>(),
                grown.len(),
                "{kind}: weights must cover the extended shard"
            );
            let folded = cb.distortion(&grown);
            let scratch = apply(&grown, &params).distortion(&grown);
            assert!(folded.is_finite() && folded >= 0.0);
            assert!(
                folded <= scratch * 5.0 + 1e-9,
                "{kind}: folded distortion {folded} vs from-scratch {scratch}"
            );
        }
    }

    /// Folding into an empty codebook (empty original shard) rebuilds.
    #[test]
    fn fold_in_from_empty_rebuilds() {
        let ds = gmm::paper_mixture_2d(200, 33);
        let params = DmlParams { target_codes: 8, ..Default::default() };
        let mut cb = apply(&Dataset::new("e", ds.dim, ds.n_classes), &params);
        assert_eq!(cb.n_codes(), 0);
        fold_in(&mut cb, &ds, 0, &params);
        cb.validate(ds.len()).unwrap();
        assert_eq!(cb.n_codes(), 8);
    }
}
