//! Lloyd's K-means — the paper's primary DML (§2.2.1, Algorithm 2).
//!
//! The assignment step is the hot loop of every distributed site, so it is
//! written for throughput:
//!
//! * distances use the expanded form `‖x‖² − 2·x·c + ‖c‖²`; `‖c‖²` is
//!   precomputed per sweep and `‖x‖²` is constant in the argmin, so the
//!   inner loop is a pure dot product over the centroid matrix;
//! * points are processed in parallel chunks ([`crate::par::par_chunks_mut`]); each
//!   chunk accumulates its own partial centroid sums, merged once per
//!   sweep (no atomic traffic in the inner loop);
//! * seeding is incremental k-means++ on a bounded subsample — O(k·m·d)
//!   with m ≤ `SEED_SAMPLE_CAP`, independent of the site size.
//!
//! Convergence: stops when no assignment changes, when the relative
//! centroid shift falls under `tol`, or after `max_iters` sweeps —
//! whichever comes first (the paper's R `kmeans()` behaves the same).

use std::sync::Mutex;

use crate::data::Dataset;
use crate::linalg::kernels;
use crate::par;
use crate::rng::Rng;

use super::Codebook;

/// Seeding subsample cap: k-means++ quality saturates well below this for
/// the codebook sizes the paper uses (≤ 2000).
const SEED_SAMPLE_CAP: usize = 8_192;

/// Incremental k-means++ seeding over a subsample. Returns `k` row-major
/// centroids.
fn seed_centroids(data: &Dataset, k: usize, rng: &mut Rng) -> Vec<f32> {
    let n = data.len();
    let dim = data.dim;
    let m = n.min(SEED_SAMPLE_CAP);
    let sample: Vec<usize> = if m == n {
        (0..n).collect()
    } else {
        rng.sample_indices(n, m)
    };

    let mut centroids = Vec::with_capacity(k * dim);
    // first seed uniform
    let first = sample[rng.index(m)];
    centroids.extend_from_slice(data.point(first));

    // d²(x, nearest seed so far), updated incrementally per new seed
    let mut best_d2: Vec<f64> = sample
        .iter()
        .map(|&i| sqdist(data.point(i), &centroids[0..dim]))
        .collect();

    while centroids.len() < k * dim {
        let total: f64 = best_d2.iter().sum();
        let next = if total <= 1e-30 {
            // all residual mass zero (duplicate-heavy data): uniform pick
            sample[rng.index(m)]
        } else {
            let mut u = rng.f64() * total;
            let mut pick = sample[m - 1];
            for (j, &d2) in best_d2.iter().enumerate() {
                u -= d2;
                if u <= 0.0 {
                    pick = sample[j];
                    break;
                }
            }
            pick
        };
        let start = centroids.len();
        centroids.extend_from_slice(data.point(next));
        let new_c = &centroids[start..start + dim];
        for (j, &i) in sample.iter().enumerate() {
            let d2 = sqdist(data.point(i), new_c);
            if d2 < best_d2[j] {
                best_d2[j] = d2;
            }
        }
    }
    centroids
}

#[inline]
fn sqdist(a: &[f32], b: &[f32]) -> f64 {
    kernels::sqdist_f32(a, b)
}

/// Per-chunk partial statistics for the update step.
struct Partial {
    /// Chunk start index — partials are merged in this order so centroid
    /// sums are bit-deterministic regardless of thread completion order.
    start: usize,
    sums: Vec<f64>,
    counts: Vec<u32>,
    changed: usize,
    inertia: f64,
}

/// Run Lloyd's algorithm; returns the site's [`Codebook`].
pub fn lloyd(data: &Dataset, k: usize, max_iters: usize, tol: f64, rng: &mut Rng) -> Codebook {
    let n = data.len();
    let dim = data.dim;
    assert!(k >= 1, "k must be >= 1");
    if n == 0 {
        return Codebook { dim, codewords: vec![], weights: vec![], assign: vec![] };
    }
    let k = k.min(n);

    let mut centroids = seed_centroids(data, k, rng);
    let mut assign = vec![u32::MAX; n];
    let mut c_norm = vec![0.0f32; k];

    for _iter in 0..max_iters {
        // ‖c‖² table for the expanded distance form
        for c in 0..k {
            let row = &centroids[c * dim..(c + 1) * dim];
            c_norm[c] = row.iter().map(|v| v * v).sum();
        }

        // Transposed centroid matrix (dim × k): the per-point score vector
        // is then built by `dim` rank-1 axpy updates over a *contiguous*
        // k-length row — SIMD across centroids, the profitable axis when
        // k ≫ SIMD width (see EXPERIMENTS.md §Perf, change 2).
        let mut centroids_t = vec![0.0f32; k * dim];
        for c in 0..k {
            for j in 0..dim {
                centroids_t[j * k + c] = centroids[c * dim + j];
            }
        }

        let partials: Mutex<Vec<Partial>> = Mutex::new(Vec::new());
        let centroids_t_ref = &centroids_t;
        let c_norm_ref = &c_norm;
        let points = &data.points;

        par::par_chunks_mut(&mut assign, 1024, |start, chunk| {
            let mut part = Partial {
                start,
                sums: vec![0.0f64; k * dim],
                counts: vec![0u32; k],
                changed: 0,
                inertia: 0.0,
            };
            // reusable score buffer: score[c] = ‖c‖² − 2 p·c
            let mut scores = vec![0.0f32; k];
            for (off, slot) in chunk.iter_mut().enumerate() {
                let i = start + off;
                let p = &points[i * dim..(i + 1) * dim];
                scores.copy_from_slice(c_norm_ref);
                for (j, &pj) in p.iter().enumerate() {
                    let coef = -2.0 * pj;
                    let row = &centroids_t_ref[j * k..(j + 1) * k];
                    kernels::axpy_f32(&mut scores, coef, row);
                }
                let mut best = 0u32;
                let mut best_score = f32::INFINITY;
                for (c, &s) in scores.iter().enumerate() {
                    if s < best_score {
                        best_score = s;
                        best = c as u32;
                    }
                }
                if *slot != best {
                    part.changed += 1;
                    *slot = best;
                }
                let b = best as usize;
                part.counts[b] += 1;
                for j in 0..dim {
                    part.sums[b * dim + j] += p[j] as f64;
                }
                let p_norm: f32 = p.iter().map(|v| v * v).sum();
                part.inertia += (p_norm + best_score).max(0.0) as f64;
            }
            partials.lock().unwrap().push(part);
        });

        // merge partials → new centroids (sorted: deterministic summation)
        let mut parts = partials.into_inner().unwrap();
        parts.sort_by_key(|p| p.start);
        let mut sums = vec![0.0f64; k * dim];
        let mut counts = vec![0u32; k];
        let mut changed = 0usize;
        for p in parts {
            for (a, b) in sums.iter_mut().zip(&p.sums) {
                *a += b;
            }
            for (a, b) in counts.iter_mut().zip(&p.counts) {
                *a += b;
            }
            changed += p.changed;
        }

        let mut shift = 0.0f64;
        let mut scale = 0.0f64;
        for c in 0..k {
            if counts[c] == 0 {
                continue; // empty cluster keeps its centroid (R kmeans errs;
                          // keeping is the standard robust choice)
            }
            let inv = 1.0 / counts[c] as f64;
            for j in 0..dim {
                let newv = (sums[c * dim + j] * inv) as f32;
                let old = centroids[c * dim + j];
                shift += ((newv - old) as f64).powi(2);
                scale += (old as f64).powi(2);
                centroids[c * dim + j] = newv;
            }
        }

        if changed == 0 || shift <= tol * tol * scale.max(1e-30) {
            break;
        }
    }

    // final weights from the last assignment
    let mut weights = vec![0u32; k];
    for &a in &assign {
        weights[a as usize] += 1;
    }

    // Drop empty codewords (possible when k-means++ picked duplicate points
    // on duplicate-heavy data): remap indices compactly.
    if weights.contains(&0) {
        let mut remap = vec![u32::MAX; k];
        let mut cw = Vec::with_capacity(centroids.len());
        let mut wts = Vec::new();
        let mut next = 0u32;
        for c in 0..k {
            if weights[c] > 0 {
                remap[c] = next;
                next += 1;
                cw.extend_from_slice(&centroids[c * dim..(c + 1) * dim]);
                wts.push(weights[c]);
            }
        }
        for a in assign.iter_mut() {
            *a = remap[*a as usize];
        }
        return Codebook { dim, codewords: cw, weights: wts, assign };
    }

    Codebook { dim, codewords: centroids, weights, assign }
}

/// Mini-batch fold of points `new_from..` into an existing codebook: each
/// new point joins its nearest codeword, whose centroid tracks the
/// running mean of its (grown) group — `c += (x − c) / w`, the classic
/// online update. One pass over the new points only; existing
/// assignments are never revisited, so the fold is O(new · k · d).
pub fn fold_in(cb: &mut Codebook, data: &Dataset, new_from: usize) {
    let dim = cb.dim;
    debug_assert_eq!(cb.assign.len(), new_from);
    debug_assert!(cb.n_codes() > 0, "fold_in needs a non-empty codebook");
    for i in new_from..data.len() {
        let best = super::nearest_code(cb, data.point(i)) as usize;
        cb.weights[best] += 1;
        let w = cb.weights[best] as f32;
        let p = data.point(i);
        let row = &mut cb.codewords[best * dim..(best + 1) * dim];
        for (c, &x) in row.iter_mut().zip(p) {
            *c += (x - *c) / w;
        }
        cb.assign.push(best as u32);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gmm;
    use crate::data::Dataset;

    #[test]
    fn recovers_separated_clusters() {
        // 4 tight, far-apart blobs; k=4 must land one centroid per blob.
        let mut comps = Vec::new();
        for (x, y) in [(0.0, 0.0), (100.0, 0.0), (0.0, 100.0), (100.0, 100.0)] {
            comps.push(gmm::Component::isotropic(vec![x, y], 0.5, 1.0));
        }
        let ds = gmm::sample("blobs", &comps, 4_000, 5);
        let mut rng = Rng::new(9);
        let cb = lloyd(&ds, 4, 50, 1e-9, &mut rng);
        cb.validate(ds.len()).unwrap();
        // every centroid is close to one of the true means
        for c in 0..4 {
            let cw = cb.codeword(c);
            let best = [(0.0, 0.0), (100.0, 0.0), (0.0, 100.0), (100.0, 100.0)]
                .iter()
                .map(|&(x, y)| {
                    ((cw[0] - x as f32).powi(2) + (cw[1] - y as f32).powi(2)).sqrt()
                })
                .fold(f32::INFINITY, f32::min);
            assert!(best < 1.0, "centroid {c} off by {best}");
        }
        // distortion ~ within-blob variance (2 dims × 0.25)
        let d = cb.distortion(&ds);
        assert!(d < 1.0, "distortion {d}");
    }

    #[test]
    fn centroid_is_group_mean() {
        let ds = gmm::paper_mixture_2d(1_000, 2);
        let mut rng = Rng::new(1);
        let cb = lloyd(&ds, 16, 100, 1e-12, &mut rng);
        // after convergence each codeword equals the mean of its group
        let mut sums = vec![0.0f64; 16 * 2];
        let mut counts = [0u64; 16];
        for i in 0..ds.len() {
            let a = cb.assign[i] as usize;
            counts[a] += 1;
            sums[a * 2] += ds.point(i)[0] as f64;
            sums[a * 2 + 1] += ds.point(i)[1] as f64;
        }
        for c in 0..cb.n_codes() {
            if counts[c] == 0 {
                continue;
            }
            let mx = (sums[c * 2] / counts[c] as f64) as f32;
            let my = (sums[c * 2 + 1] / counts[c] as f64) as f32;
            let cw = cb.codeword(c);
            assert!((cw[0] - mx).abs() < 1e-3, "{} vs {}", cw[0], mx);
            assert!((cw[1] - my).abs() < 1e-3);
        }
    }

    #[test]
    fn k_clamped_to_n() {
        let mut ds = Dataset::new("tiny", 1, 1);
        for i in 0..5 {
            ds.push(&[i as f32], 0);
        }
        let mut rng = Rng::new(3);
        let cb = lloyd(&ds, 50, 10, 1e-6, &mut rng);
        assert!(cb.n_codes() <= 5);
        cb.validate(5).unwrap();
    }

    #[test]
    fn duplicate_heavy_data_has_no_empty_codes() {
        let mut ds = Dataset::new("dup", 1, 1);
        for _ in 0..100 {
            ds.push(&[1.0], 0);
        }
        for _ in 0..100 {
            ds.push(&[2.0], 0);
        }
        let mut rng = Rng::new(4);
        let cb = lloyd(&ds, 8, 20, 1e-9, &mut rng);
        cb.validate(200).unwrap();
        assert!(cb.weights.iter().all(|&w| w > 0));
        assert!(cb.n_codes() <= 8);
    }

    #[test]
    fn deterministic_under_fixed_threads() {
        // chunk merge order can vary; centroid update is order-insensitive
        // in exact arithmetic but f64 merge keeps it stable in practice for
        // identical chunking — we assert assignment equality which is robust.
        let ds = gmm::paper_mixture_2d(2_000, 8);
        let mut r1 = Rng::new(11);
        let mut r2 = Rng::new(11);
        let a = lloyd(&ds, 20, 15, 1e-9, &mut r1);
        let b = lloyd(&ds, 20, 15, 1e-9, &mut r2);
        assert_eq!(a.assign, b.assign);
        assert_eq!(a.weights, b.weights);
    }

    #[test]
    fn fold_in_tracks_the_running_mean() {
        let mut ds = Dataset::new("m", 1, 1);
        for v in [0.0f32, 2.0] {
            ds.push(&[v], 0);
        }
        let mut rng = Rng::new(1);
        let mut cb = lloyd(&ds, 1, 10, 1e-9, &mut rng);
        assert_eq!(cb.codeword(0), &[1.0]);
        ds.push(&[4.0], 0);
        fold_in(&mut cb, &ds, 2);
        cb.validate(3).unwrap();
        assert_eq!(cb.weights, vec![3]);
        // running mean of {0, 2, 4}
        assert!((cb.codeword(0)[0] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn empty_dataset_is_empty_codebook() {
        let ds = Dataset::new("e", 3, 1);
        let mut rng = Rng::new(0);
        let cb = lloyd(&ds, 4, 10, 1e-6, &mut rng);
        assert_eq!(cb.n_codes(), 0);
    }
}
