//! Micro-benchmark harness (offline stand-in for `criterion`).
//!
//! [`time_it`] measures a closure with warmup and repeated samples and
//! returns robust statistics; [`Table`] renders the paper-style result
//! tables every `benches/*.rs` binary prints (and optionally dumps CSV
//! next to them for plotting).
//!
//! These are *macro* benches by design: the quantities the paper reports
//! (elapsed seconds per pipeline stage) are tenths-of-seconds to minutes,
//! so wall-clock sampling with a handful of repetitions is the right tool —
//! no need for criterion's nanosecond machinery.

use std::time::{Duration, Instant};

/// Summary statistics of one measurement.
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
    /// Sample standard deviation.
    pub sd: Duration,
    pub samples: usize,
}

impl Stats {
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }
}

impl std::fmt::Display for Stats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:.3}s ±{:.3} (min {:.3}, n={})",
            self.mean.as_secs_f64(),
            self.sd.as_secs_f64(),
            self.min.as_secs_f64(),
            self.samples
        )
    }
}

/// Measure `f` with `warmup` unrecorded runs then `samples` recorded ones.
pub fn time_it(warmup: usize, samples: usize, mut f: impl FnMut()) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let samples = samples.max(1);
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    stats_of(&times)
}

/// Compute [`Stats`] from raw durations.
pub fn stats_of(times: &[Duration]) -> Stats {
    assert!(!times.is_empty());
    let n = times.len();
    let sum: Duration = times.iter().sum();
    let mean = sum / n as u32;
    let min = *times.iter().min().unwrap();
    let max = *times.iter().max().unwrap();
    let mean_s = mean.as_secs_f64();
    let var = times
        .iter()
        .map(|t| (t.as_secs_f64() - mean_s).powi(2))
        .sum::<f64>()
        / (n.max(2) - 1) as f64;
    Stats { mean, min, max, sd: Duration::from_secs_f64(var.sqrt()), samples: n }
}

/// A paper-style results table with markdown rendering and CSV dumping.
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, columns: &[&str]) -> Table {
        Table {
            title: title.into(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render as a markdown table (what the bench binaries print).
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = format!("\n### {}\n\n", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths.iter().copied()) {
                line.push_str(&format!(" {c:<w$} |"));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.columns, &widths));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}-|", "-".repeat(w + 1)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    /// Dump as CSV under `bench_out/` for plotting.
    pub fn save_csv(&self, name: &str) -> std::io::Result<std::path::PathBuf> {
        let dir = std::path::Path::new("bench_out");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.csv"));
        let mut body = format!("# {}\n{}\n", self.title, self.columns.join(","));
        for row in &self.rows {
            body.push_str(&row.join(","));
            body.push('\n');
        }
        std::fs::write(&path, body)?;
        Ok(path)
    }
}

/// Format a duration as seconds with milli precision (table cells).
pub fn secs(d: Duration) -> String {
    format!("{:.3}", d.as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_measures() {
        let s = time_it(1, 3, || std::thread::sleep(Duration::from_millis(2)));
        assert!(s.mean >= Duration::from_millis(1));
        assert_eq!(s.samples, 3);
        assert!(s.min <= s.mean && s.mean <= s.max);
    }

    #[test]
    fn stats_of_constant_has_zero_sd() {
        let s = stats_of(&[Duration::from_millis(5); 4]);
        assert_eq!(s.sd, Duration::ZERO);
        assert_eq!(s.mean, Duration::from_millis(5));
    }

    #[test]
    fn table_renders_and_saves() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.row(&["1".into(), "long cell".into()]);
        let md = t.render();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| 1 | long cell |"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_rows() {
        let mut t = Table::new("x", &["a"]);
        t.row(&["1".into(), "2".into()]);
    }
}
