//! Mini property-testing harness (offline stand-in for `proptest`).
//!
//! [`forall`] runs a property over `cases` generated inputs; every case is
//! seeded from `(suite seed, case index)`, so a failure report's case index
//! reproduces exactly. There is no shrinking — generators are kept small
//! and structured instead (generate *parameters*, not giant blobs), which
//! in practice localizes failures well enough for this crate.
//!
//! ```no_run
//! # // no_run: illustrative only — the real properties live in rust/tests
//! use dsc::prop::{forall, Gen};
//! forall("sorting is idempotent", 100, 42, |g: &mut Gen| {
//!     let n = g.usize_in(0, 50);
//!     let mut v = g.vec_f32(n, -10.0, 10.0);
//!     v.sort_by(|a, b| a.partial_cmp(b).unwrap());
//!     let mut w = v.clone();
//!     w.sort_by(|a, b| a.partial_cmp(b).unwrap());
//!     if v == w { Ok(()) } else { Err("not idempotent".into()) }
//! });
//! ```

use crate::rng::Rng;

/// Case-local generator handed to properties.
pub struct Gen {
    rng: Rng,
    /// Which case this is (for error messages / conditioning).
    pub case: usize,
}

impl Gen {
    /// Uniform integer in `[lo, hi]` (inclusive).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.index(hi - lo + 1)
    }

    /// Uniform float in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    /// Uniform f32 vector.
    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| lo + (hi - lo) * self.rng.f32()).collect()
    }

    /// Vector of labels in `[0, k)`.
    pub fn labels(&mut self, len: usize, k: usize) -> Vec<u16> {
        (0..len).map(|_| self.rng.index(k) as u16).collect()
    }

    /// Random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.rng.shuffle(&mut v);
        v
    }

    /// Coin flip.
    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.bool(p)
    }

    /// Access the underlying PRNG for bespoke generation.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `property` over `cases` generated inputs; panics (test failure) on
/// the first counter-example with enough context to reproduce it.
pub fn forall(
    name: &str,
    cases: usize,
    seed: u64,
    property: impl Fn(&mut Gen) -> Result<(), String>,
) {
    let root = Rng::new(seed);
    for case in 0..cases {
        let mut g = Gen { rng: root.fork(case as u64), case };
        if let Err(msg) = property(&mut g) {
            panic!(
                "property {name:?} failed on case {case}/{cases} (suite seed {seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall("x + 0 == x", 50, 1, |g| {
            let x = g.f64_in(-10.0, 10.0);
            if x + 0.0 == x {
                Ok(())
            } else {
                Err(format!("{x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "failed on case")]
    fn reports_counterexample() {
        forall("all ints are even", 50, 2, |g| {
            let x = g.usize_in(0, 100);
            if x % 2 == 0 {
                Ok(())
            } else {
                Err(format!("{x} is odd"))
            }
        });
    }

    #[test]
    fn cases_are_reproducible() {
        let mut firsts = Vec::new();
        for _ in 0..2 {
            let root = Rng::new(9);
            let mut g = Gen { rng: root.fork(3), case: 3 };
            firsts.push(g.usize_in(0, 1_000_000));
        }
        assert_eq!(firsts[0], firsts[1]);
    }

    #[test]
    fn permutation_is_valid() {
        forall("permutation covers 0..n", 30, 4, |g| {
            let n = g.usize_in(0, 64);
            let p = g.permutation(n);
            let mut seen = vec![false; n];
            for &i in &p {
                if seen[i] {
                    return Err(format!("dup {i}"));
                }
                seen[i] = true;
            }
            if seen.iter().all(|&b| b) {
                Ok(())
            } else {
                Err("missing index".into())
            }
        });
    }
}
