//! Sparse k-NN Gaussian affinity in CSR form — the large-codebook path.
//!
//! The dense affinity ([`super::affinity`]) costs O(m²) memory and mat-vec
//! time, which caps the total code budget at a few thousand. This module
//! keeps only a symmetric k-nearest-neighbor graph:
//!
//! * neighbor *candidates* come from rp-tree leaves
//!   ([`crate::dml::rptree::leaf_groups`]) over several independent trees —
//!   points sharing a leaf in any tree are candidates, the classic
//!   forest-of-rp-trees approximate-NN scheme, O(n · k · trees · dim)
//!   instead of O(n² · dim);
//! * kept edges get the same `w_i w_j exp(−‖x_i−x_j‖²/2σ²)` Gaussian
//!   weight as the dense path — computed with the *identical* expanded-form
//!   f32 arithmetic, so at `k = m − 1` the two graphs match bit for bit;
//! * the edge set is union-symmetrized (`i→j` or `j→i` keeps both
//!   directions) and stored CSR with cached degrees, so memory and
//!   [`SparseAffinity::normalized_matvec`] are O(m·k̄).
//!
//! [`SparseAffinity`] implements [`super::Graph`], so recursive ncut, the
//! NJW embedding and the Lanczos eigensolver run on it unchanged.

use crate::dml::rptree;
use crate::linalg::kernels;
use crate::par;
use crate::rng::Rng;

use super::Graph;

/// How many independent rp-trees vote on neighbor candidates. More trees
/// raise recall (and build cost) linearly; four is plenty for the smooth
/// codeword clouds this pipeline produces.
const N_TREES: usize = 4;

/// Symmetric k-NN Gaussian affinity with CSR storage and cached degrees.
#[derive(Clone, Debug)]
pub struct SparseAffinity {
    pub n: usize,
    /// CSR row offsets (`n + 1` entries, monotone, `row_ptr[n] == nnz`).
    pub row_ptr: Vec<usize>,
    /// Column indices, ascending within each row, never the diagonal.
    pub col_idx: Vec<u32>,
    /// Edge weights, aligned with `col_idx`.
    pub vals: Vec<f32>,
    /// Degree `d_i = Σ_j A[i,j]` (f64 accumulation).
    pub deg: Vec<f64>,
    /// Cached `1/√d_i` (0 for isolated vertices): the normalized mat-vec is
    /// Lanczos' inner loop, so this is precomputed once at construction
    /// rather than per call.
    pub inv_sqrt_deg: Vec<f64>,
}

impl SparseAffinity {
    /// Finish construction from assembled CSR arrays: compute degrees and
    /// the cached `1/√d` table.
    fn from_csr(n: usize, row_ptr: Vec<usize>, col_idx: Vec<u32>, vals: Vec<f32>) -> Self {
        debug_assert_eq!(row_ptr.len(), n + 1);
        debug_assert_eq!(col_idx.len(), vals.len());
        let mut deg = vec![0.0f64; n];
        for i in 0..n {
            deg[i] = vals[row_ptr[i]..row_ptr[i + 1]].iter().map(|&v| v as f64).sum();
        }
        let inv_sqrt_deg: Vec<f64> =
            deg.iter().map(|&d| if d > 1e-300 { 1.0 / d.sqrt() } else { 0.0 }).collect();
        SparseAffinity { n, row_ptr, col_idx, vals, deg, inv_sqrt_deg }
    }
    /// Stored (directed) entries; each undirected edge counts twice.
    pub fn nnz(&self) -> usize {
        self.col_idx.len()
    }

    /// Bytes of CSR storage — the footprint the `hotpath` bench reports
    /// against the dense path's `4m²`.
    pub fn storage_bytes(&self) -> usize {
        self.row_ptr.len() * std::mem::size_of::<usize>()
            + self.col_idx.len() * 4
            + self.vals.len() * 4
            + self.deg.len() * 8
    }

    /// The `(columns, weights)` pair of row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let (s, e) = (self.row_ptr[i], self.row_ptr[i + 1]);
        (&self.col_idx[s..e], &self.vals[s..e])
    }

    /// y = M x where `M = D^{-1/2} A D^{-1/2}` — Lanczos' entire inner
    /// loop, parallel over row chunks like the dense twin. Each row is a
    /// [`kernels::spmv_row_f64`] gather; the `D^{-1/2} x` pre-scale reuses
    /// a thread-local scratch buffer instead of allocating per call.
    pub fn normalized_matvec(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.n);
        assert_eq!(y.len(), self.n);
        super::with_scaled_scratch(x, &self.inv_sqrt_deg, |z| {
            par::par_chunks_mut(y, 512, |start, chunk| {
                for (off, out) in chunk.iter_mut().enumerate() {
                    let i = start + off;
                    let (cols, vals) = self.row(i);
                    *out = kernels::spmv_row_f64(vals, cols, z) * self.inv_sqrt_deg[i];
                }
            });
        });
    }

    /// Restrict to an index subset: kept edges are those with both
    /// endpoints in `idx`, degrees recomputed within the subset. Column
    /// order within a row follows `idx` order (ascending `idx` keeps rows
    /// sorted, which is how recursive ncut calls it).
    pub fn subgraph(&self, idx: &[usize]) -> SparseAffinity {
        let m = idx.len();
        let mut local = vec![u32::MAX; self.n];
        for (r, &g) in idx.iter().enumerate() {
            local[g] = r as u32;
        }
        let mut row_ptr = Vec::with_capacity(m + 1);
        row_ptr.push(0usize);
        let mut col_idx = Vec::new();
        let mut vals = Vec::new();
        for &g in idx {
            let (cols, ws) = self.row(g);
            for (c, v) in cols.iter().zip(ws) {
                let lc = local[*c as usize];
                if lc != u32::MAX {
                    col_idx.push(lc);
                    vals.push(*v);
                }
            }
            row_ptr.push(col_idx.len());
        }
        SparseAffinity::from_csr(m, row_ptr, col_idx, vals)
    }
}

impl Graph for SparseAffinity {
    fn len(&self) -> usize {
        self.n
    }
    fn degrees(&self) -> &[f64] {
        &self.deg
    }
    fn normalized_matvec(&self, x: &[f64], y: &mut [f64]) {
        SparseAffinity::normalized_matvec(self, x, y)
    }
    fn for_each_edge<F: FnMut(usize, f64)>(&self, i: usize, mut f: F) {
        let (cols, vals) = self.row(i);
        for (c, v) in cols.iter().zip(vals) {
            f(*c as usize, *v as f64);
        }
    }
    fn subgraph(&self, idx: &[usize]) -> SparseAffinity {
        SparseAffinity::subgraph(self, idx)
    }
}

/// The σ-independent half of a k-NN affinity: the symmetrized neighbor
/// topology with squared distances per edge, in CSR shape.
///
/// The expensive part of a build — rp-tree construction and the candidate
/// distance search — does not depend on the bandwidth, so the eigengap
/// σ-search computes one topology and reweights it per candidate σ
/// ([`weight_topology`]); this also means every σ is scored on the *same*
/// random graph instead of conflating the eigengap signal with
/// graph-sampling noise.
#[derive(Clone, Debug)]
pub struct KnnTopology {
    pub n: usize,
    /// CSR row offsets (`n + 1` entries).
    pub row_ptr: Vec<usize>,
    /// Column indices, ascending within each row, never the diagonal.
    pub col_idx: Vec<u32>,
    /// `‖x_i − x_j‖²` per edge (expanded-form f32, matching the dense
    /// builder's arithmetic bit for bit).
    pub d2: Vec<f32>,
}

/// Build the symmetric k-NN Gaussian affinity for `points` (`n × dim`,
/// row-major) with per-point weights `w` (all-ones for the unweighted
/// variant) and bandwidth `sigma`.
///
/// `k` is clamped to `n − 1`. Candidates come from rp-tree leaf partitions
/// with a leaf cap of `max(4k, 64)`; once the cap reaches `n` the partition
/// is a single leaf and the search is exact — in particular `k = n − 1`
/// reproduces the dense affinity entry for entry. Ties at the k-th distance
/// break deterministically toward the smaller index.
///
/// Equivalent to [`knn_topology`] followed by [`weight_topology`]; callers
/// that sweep σ (the eigengap search) should use the two-step form so the
/// neighbor search runs once.
pub fn build_knn(
    points: &[f32],
    dim: usize,
    w: &[f32],
    sigma: f64,
    k: usize,
    rng: &mut Rng,
) -> SparseAffinity {
    weight_topology(&knn_topology(points, dim, k, rng), w, sigma)
}

/// Symmetrized approximate k-NN topology of `points` (see [`build_knn`]
/// for the search scheme). σ-independent; pair with [`weight_topology`].
pub fn knn_topology(points: &[f32], dim: usize, k: usize, rng: &mut Rng) -> KnnTopology {
    assert!(dim > 0);
    let n = points.len() / dim;
    assert_eq!(points.len(), n * dim);
    if n == 0 {
        return KnnTopology { n: 0, row_ptr: vec![0], col_idx: vec![], d2: vec![] };
    }
    if n == 1 {
        return KnnTopology { n: 1, row_ptr: vec![0, 0], col_idx: vec![], d2: vec![] };
    }
    let k = k.clamp(1, n - 1);

    // ‖x‖² table — shared with the weight pass so the f32 arithmetic is
    // bit-identical to the dense builder's expanded form.
    let sq: Vec<f32> = (0..n)
        .map(|i| points[i * dim..(i + 1) * dim].iter().map(|v| v * v).sum())
        .collect();

    // Leaf partitions from independent rp-trees. A cap ≥ n collapses each
    // tree to one leaf, so one tree suffices and the search is exact.
    let leaf_cap = (4 * k).max(64).min(n);
    let n_trees = if leaf_cap >= n { 1 } else { N_TREES };
    let mut leaves: Vec<Vec<Vec<u32>>> = Vec::with_capacity(n_trees);
    let mut leaf_of: Vec<Vec<u32>> = Vec::with_capacity(n_trees);
    for _ in 0..n_trees {
        let groups = rptree::leaf_groups(points, dim, leaf_cap, rng);
        let mut assign = vec![0u32; n];
        for (lid, g) in groups.iter().enumerate() {
            for &i in g {
                assign[i as usize] = lid as u32;
            }
        }
        leaves.push(groups);
        leaf_of.push(assign);
    }

    // Per-point k nearest among leaf-mates (parallel over points).
    let mut nbrs: Vec<Vec<(u32, f32)>> = vec![Vec::new(); n]; // (j, d²)
    par::par_chunks_mut(&mut nbrs, 64, |start, chunk| {
        let mut cand: Vec<u32> = Vec::new();
        let mut scored: Vec<(f32, u32)> = Vec::new();
        for (off, out) in chunk.iter_mut().enumerate() {
            let i = start + off;
            cand.clear();
            for t in 0..n_trees {
                cand.extend_from_slice(&leaves[t][leaf_of[t][i] as usize]);
            }
            cand.sort_unstable();
            cand.dedup();
            scored.clear();
            let pi = &points[i * dim..(i + 1) * dim];
            for &ju in &cand {
                let j = ju as usize;
                if j == i {
                    continue;
                }
                let pj = &points[j * dim..(j + 1) * dim];
                // same kernel as the dense builder's row dot — the bit-parity
                // tests compare the two entry for entry at full k
                let dot = kernels::dot_f32(pi, pj);
                let d2 = (sq[i] + sq[j] - 2.0 * dot).max(0.0);
                scored.push((d2, ju));
            }
            if scored.len() > k {
                // tuple order breaks distance ties by index: deterministic
                scored.select_nth_unstable_by(k - 1, |a, b| a.partial_cmp(b).unwrap());
                scored.truncate(k);
            }
            out.extend(scored.iter().map(|&(d2, j)| (j, d2)));
        }
    });

    // Union-symmetrize into adjacency lists carrying d². The two directions
    // of a mutual edge computed the same f32 distance (commutative ops on
    // identical inputs), so the dedup after sorting is exact.
    let mut adj: Vec<Vec<(u32, f32)>> = vec![Vec::new(); n];
    for i in 0..n {
        for &(ju, d2) in &nbrs[i] {
            adj[i].push((ju, d2));
            adj[ju as usize].push((i as u32, d2));
        }
    }

    // CSR assembly: sort each row by column, drop the duplicate direction
    // of mutual edges.
    let mut row_ptr = Vec::with_capacity(n + 1);
    row_ptr.push(0usize);
    let mut col_idx = Vec::new();
    let mut d2s = Vec::new();
    for row in adj.iter_mut() {
        row.sort_unstable_by_key(|&(j, _)| j);
        row.dedup_by_key(|e| e.0);
        for &(j, d2) in row.iter() {
            col_idx.push(j);
            d2s.push(d2);
        }
        row_ptr.push(col_idx.len());
    }
    KnnTopology { n, row_ptr, col_idx, d2: d2s }
}

/// Apply Gaussian weights `w_i w_j exp(−d²/2σ²)` for one σ to a prebuilt
/// [`KnnTopology`]. O(nnz) — cheap enough to call once per candidate σ in
/// the eigengap search.
pub fn weight_topology(topo: &KnnTopology, w: &[f32], sigma: f64) -> SparseAffinity {
    assert_eq!(w.len(), topo.n);
    assert!(sigma > 0.0, "sigma must be positive");
    let inv_two_sigma2 = (1.0 / (2.0 * sigma * sigma)) as f32;
    let mut vals = Vec::with_capacity(topo.col_idx.len());
    for i in 0..topo.n {
        let (s, e) = (topo.row_ptr[i], topo.row_ptr[i + 1]);
        for (c, d2) in topo.col_idx[s..e].iter().zip(&topo.d2[s..e]) {
            vals.push(w[i] * w[*c as usize] * (-d2 * inv_two_sigma2).exp());
        }
    }
    SparseAffinity::from_csr(topo.n, topo.row_ptr.clone(), topo.col_idx.clone(), vals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spectral::affinity;

    fn blob_points(centers: &[(f32, f32)], m: usize, spread: f32, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut pts = Vec::with_capacity(centers.len() * m * 2);
        for &(cx, cy) in centers {
            for _ in 0..m {
                pts.push(cx + rng.normal_f32(0.0, spread));
                pts.push(cy + rng.normal_f32(0.0, spread));
            }
        }
        pts
    }

    /// Structural invariants every build must satisfy.
    fn check_csr(a: &SparseAffinity) {
        assert_eq!(a.row_ptr.len(), a.n + 1);
        assert_eq!(a.row_ptr[0], 0);
        assert_eq!(*a.row_ptr.last().unwrap(), a.nnz());
        assert_eq!(a.col_idx.len(), a.vals.len());
        assert!(a.row_ptr.windows(2).all(|w| w[0] <= w[1]), "row_ptr not monotone");
        for i in 0..a.n {
            let (cols, vals) = a.row(i);
            assert!(cols.windows(2).all(|w| w[0] < w[1]), "row {i} not sorted/unique");
            assert!(cols.iter().all(|&c| c as usize != i), "self-loop in row {i}");
            let sum: f64 = vals.iter().map(|&v| v as f64).sum();
            assert!((sum - a.deg[i]).abs() < 1e-9, "deg[{i}] off: {sum} vs {}", a.deg[i]);
            // symmetry: every (i, j, v) has a matching (j, i, v)
            for (c, v) in cols.iter().zip(vals) {
                let (jc, jv) = a.row(*c as usize);
                let pos = jc.binary_search(&(i as u32));
                assert!(pos.is_ok(), "edge ({i},{c}) has no mirror");
                assert_eq!(jv[pos.unwrap()], *v, "asymmetric weight on ({i},{c})");
            }
        }
    }

    #[test]
    fn csr_is_symmetric_with_consistent_degrees() {
        let pts = blob_points(&[(0.0, 0.0), (8.0, 0.0), (0.0, 8.0)], 40, 0.5, 3);
        let w = vec![1.0f32; 120];
        let mut rng = Rng::new(5);
        let a = build_knn(&pts, 2, &w, 1.0, 8, &mut rng);
        check_csr(&a);
        // each vertex contributes ≤ k outgoing picks, so symmetrization
        // bounds nnz by 2nk; the graph must also be connected enough that
        // no vertex is isolated
        assert!(a.nnz() <= 2 * 120 * 8, "nnz {}", a.nnz());
        for i in 0..a.n {
            let (cols, _) = a.row(i);
            assert!(!cols.is_empty(), "vertex {i} isolated");
        }
    }

    #[test]
    fn full_k_matches_dense_bitwise() {
        let pts = blob_points(&[(0.0, 0.0), (6.0, 0.0)], 20, 0.6, 7);
        let n = 40;
        let w: Vec<f32> = (0..n).map(|i| 1.0 + (i % 3) as f32).collect();
        let dense = affinity::build(&pts, 2, &w, 1.3);
        let mut rng = Rng::new(9);
        let sp = build_knn(&pts, 2, &w, 1.3, n - 1, &mut rng);
        check_csr(&sp);
        assert_eq!(sp.nnz(), n * (n - 1));
        for i in 0..n {
            let (cols, vals) = sp.row(i);
            for (c, v) in cols.iter().zip(vals) {
                assert_eq!(
                    v.to_bits(),
                    dense.row(i)[*c as usize].to_bits(),
                    "entry ({i},{c}) differs from dense"
                );
            }
            assert_eq!(sp.deg[i].to_bits(), dense.deg[i].to_bits(), "deg[{i}] differs");
        }
    }

    #[test]
    fn matvec_matches_dense_at_full_k() {
        let pts = blob_points(&[(0.0, 0.0), (5.0, 5.0)], 25, 0.5, 11);
        let n = 50;
        let w = vec![1.0f32; n];
        let dense = affinity::build(&pts, 2, &w, 1.0);
        let mut rng = Rng::new(13);
        let sp = build_knn(&pts, 2, &w, 1.0, n - 1, &mut rng);
        let x: Vec<f64> = (0..n).map(|i| ((i * 37) % 11) as f64 - 5.0).collect();
        let mut yd = vec![0.0f64; n];
        let mut ys = vec![0.0f64; n];
        dense.normalized_matvec(&x, &mut yd);
        sp.normalized_matvec(&x, &mut ys);
        for i in 0..n {
            assert!((yd[i] - ys[i]).abs() < 1e-12, "y[{i}]: {} vs {}", yd[i], ys[i]);
        }
    }

    #[test]
    fn normalized_matvec_top_eigvec_is_sqrt_deg() {
        // M (D^{1/2} 1) = D^{-1/2} A 1 = D^{1/2} 1 — exact, like the dense twin
        let pts = blob_points(&[(0.0, 0.0), (4.0, 0.0)], 30, 0.5, 15);
        let w = vec![1.0f32; 60];
        let mut rng = Rng::new(17);
        let a = build_knn(&pts, 2, &w, 2.0, 10, &mut rng);
        let x: Vec<f64> = a.deg.iter().map(|d| d.sqrt()).collect();
        let mut y = vec![0.0; 60];
        a.normalized_matvec(&x, &mut y);
        for i in 0..60 {
            assert!((y[i] - x[i]).abs() < 1e-9, "{} vs {}", y[i], x[i]);
        }
    }

    #[test]
    fn subgraph_keeps_internal_edges_only() {
        let pts = blob_points(&[(0.0, 0.0), (9.0, 0.0)], 15, 0.4, 19);
        let w = vec![1.0f32; 30];
        let mut rng = Rng::new(21);
        let a = build_knn(&pts, 2, &w, 1.5, 29, &mut rng); // full graph
        let idx: Vec<usize> = (0..10).collect();
        let sub = a.subgraph(&idx);
        check_csr(&sub);
        assert_eq!(sub.n, 10);
        // full graph restricted to 10 vertices = complete graph on 10
        assert_eq!(sub.nnz(), 10 * 9);
        let (cols, vals) = sub.row(0);
        let (acols, avals) = a.row(0);
        // row 0's first 9 global columns are exactly 1..=9 here
        for (c, v) in cols.iter().zip(vals) {
            let gpos = acols.iter().position(|&g| g == *c).unwrap();
            assert_eq!(avals[gpos], *v);
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let pts = blob_points(&[(0.0, 0.0), (7.0, 0.0)], 50, 0.5, 23);
        let w = vec![1.0f32; 100];
        let mut r1 = Rng::new(31);
        let mut r2 = Rng::new(31);
        let a = build_knn(&pts, 2, &w, 1.0, 6, &mut r1);
        let b = build_knn(&pts, 2, &w, 1.0, 6, &mut r2);
        assert_eq!(a.col_idx, b.col_idx);
        assert_eq!(a.vals, b.vals);
        assert_eq!(a.row_ptr, b.row_ptr);
    }

    #[test]
    fn topology_reuse_matches_fresh_builds() {
        // reweighting one topology across σ equals building from scratch at
        // each σ with the same tree seed — what the eigengap search relies on
        let pts = blob_points(&[(0.0, 0.0), (6.0, 0.0)], 40, 0.5, 37);
        let w = vec![1.0f32; 80];
        let mut rt = Rng::new(41);
        let topo = knn_topology(&pts, 2, 8, &mut rt);
        for sigma in [0.5, 1.0, 2.5] {
            let reweighted = weight_topology(&topo, &w, sigma);
            let mut rf = Rng::new(41);
            let fresh = build_knn(&pts, 2, &w, sigma, 8, &mut rf);
            assert_eq!(reweighted.col_idx, fresh.col_idx);
            assert_eq!(reweighted.row_ptr, fresh.row_ptr);
            assert_eq!(reweighted.vals, fresh.vals);
            assert_eq!(reweighted.deg, fresh.deg);
        }
    }

    #[test]
    fn edge_cases_empty_and_singleton() {
        let mut rng = Rng::new(1);
        let e = build_knn(&[], 2, &[], 1.0, 4, &mut rng);
        assert_eq!(e.n, 0);
        assert_eq!(e.nnz(), 0);
        let s = build_knn(&[1.0, 2.0], 2, &[1.0], 1.0, 4, &mut rng);
        assert_eq!(s.n, 1);
        assert_eq!(s.nnz(), 0);
        assert_eq!(s.deg, vec![0.0]);
    }

    #[test]
    fn storage_is_linear_in_k_not_quadratic() {
        let pts = blob_points(&[(0.0, 0.0), (10.0, 0.0), (0.0, 10.0), (10.0, 10.0)], 100, 0.5, 33);
        let n = 400;
        let w = vec![1.0f32; n];
        let mut rng = Rng::new(35);
        let a = build_knn(&pts, 2, &w, 1.0, 8, &mut rng);
        // union symmetrization at most doubles the k picks per vertex
        assert!(a.nnz() <= n * 16, "nnz {} too dense", a.nnz());
        assert!(a.storage_bytes() < n * n, "CSR not smaller than dense");
    }
}
