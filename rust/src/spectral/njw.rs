//! NJW (Ng–Jordan–Weiss) spectral clustering: top-K eigenvectors of the
//! normalized affinity, row-normalized, then K-means in embedding space.
//!
//! This is the algorithmic shape of the AOT path: the XLA artifact computes
//! the same embedding (Layer 2's `spectral_embedding`), and
//! [`labels_from_embedding`] finishes the job identically for both
//! backends, so native-vs-XLA parity tests compare end labels directly.

use crate::linalg::eigen::lanczos_topk_op;
use crate::rng::Rng;

use super::{Graph, NormalizedOp};

/// Compute the `k`-column spectral embedding of `aff` natively (Lanczos).
/// Works on any [`Graph`] storage (dense or sparse k-NN). Rows are the
/// codeword coordinates in spectral space, **not yet** row-normalized.
/// Column order: decreasing eigenvalue.
pub fn embed<G: Graph>(aff: &G, k: usize, rng: &mut Rng) -> Vec<f64> {
    let n = aff.len();
    let iters = (4 * ((n as f64).ln().ceil() as usize) + 60).min(n.max(k + 2));
    let (_evals, vecs) = lanczos_topk_op(&NormalizedOp(aff), k, iters, 1e-10, rng);
    let mut embedding = vec![0.0f64; n * k];
    for (j, v) in vecs.iter().enumerate() {
        for i in 0..n {
            embedding[i * k + j] = v[i];
        }
    }
    embedding
}

/// Top-(k+1) eigenvalues of the normalized affinity (for eigengap-based
/// bandwidth search).
pub fn top_eigenvalues<G: Graph>(aff: &G, k: usize, rng: &mut Rng) -> Vec<f64> {
    let n = aff.len();
    let want = (k + 1).min(n);
    let iters = (4 * ((n as f64).ln().ceil() as usize) + 60).min(n.max(want + 2));
    let (evals, _) = lanczos_topk_op(&NormalizedOp(aff), want, iters, 1e-10, rng);
    evals
}

/// NJW step 4–5: row-normalize the embedding and K-means it into
/// `k_clusters` groups (multiple restarts, best inertia wins).
///
/// `embedding` is `n × k_cols` row-major; callers may pass more columns
/// than clusters (the AOT artifact always returns 8) — only the first
/// `k_clusters.max(2)` columns are used, mirroring NJW's prescription.
pub fn labels_from_embedding(
    embedding: &[f64],
    n: usize,
    k_cols: usize,
    k_clusters: usize,
    rng: &mut Rng,
) -> Vec<u16> {
    assert_eq!(embedding.len(), n * k_cols);
    if n == 0 {
        return vec![];
    }
    let use_cols = k_clusters.clamp(2, k_cols);

    // row-normalize the first `use_cols` columns
    let mut rows = vec![0.0f64; n * use_cols];
    for i in 0..n {
        let src = &embedding[i * k_cols..i * k_cols + use_cols];
        let norm = src.iter().map(|v| v * v).sum::<f64>().sqrt();
        let dst = &mut rows[i * use_cols..(i + 1) * use_cols];
        if norm > 1e-300 {
            for (d, s) in dst.iter_mut().zip(src) {
                *d = s / norm;
            }
        }
    }

    kmeans_rows(&rows, n, use_cols, k_clusters, 8, 60, rng)
}

/// Small dense K-means on f64 rows (Lloyd, k-means++ seeding, restarts).
/// Embedding problems are tiny (n ≤ a few thousand, d ≤ 8), so this stays
/// single-threaded and simple.
pub fn kmeans_rows(
    rows: &[f64],
    n: usize,
    d: usize,
    k: usize,
    restarts: usize,
    iters: usize,
    rng: &mut Rng,
) -> Vec<u16> {
    assert_eq!(rows.len(), n * d);
    let k = k.min(n).max(1);
    let mut best_labels = vec![0u16; n];
    let mut best_inertia = f64::INFINITY;

    for _restart in 0..restarts.max(1) {
        // k-means++ seeding
        let mut centroids = Vec::with_capacity(k * d);
        let first = rng.index(n);
        centroids.extend_from_slice(&rows[first * d..(first + 1) * d]);
        let mut best_d2: Vec<f64> = (0..n).map(|i| sq(&rows[i * d..(i + 1) * d], &centroids[..d])).collect();
        while centroids.len() < k * d {
            let total: f64 = best_d2.iter().sum();
            let pick = if total <= 1e-30 {
                rng.index(n)
            } else {
                let mut u = rng.f64() * total;
                let mut pick = n - 1;
                for (i, &v) in best_d2.iter().enumerate() {
                    u -= v;
                    if u <= 0.0 {
                        pick = i;
                        break;
                    }
                }
                pick
            };
            let s = centroids.len();
            centroids.extend_from_slice(&rows[pick * d..(pick + 1) * d]);
            let c_new = centroids[s..s + d].to_vec();
            for i in 0..n {
                let v = sq(&rows[i * d..(i + 1) * d], &c_new);
                if v < best_d2[i] {
                    best_d2[i] = v;
                }
            }
        }

        let mut labels = vec![0u16; n];
        let mut inertia = f64::INFINITY;
        for _it in 0..iters {
            // assign
            let mut new_inertia = 0.0;
            for i in 0..n {
                let p = &rows[i * d..(i + 1) * d];
                let mut bl = 0u16;
                let mut bd = f64::INFINITY;
                for c in 0..k {
                    let v = sq(p, &centroids[c * d..(c + 1) * d]);
                    if v < bd {
                        bd = v;
                        bl = c as u16;
                    }
                }
                labels[i] = bl;
                new_inertia += bd;
            }
            // update
            let mut sums = vec![0.0f64; k * d];
            let mut counts = vec![0usize; k];
            for i in 0..n {
                let c = labels[i] as usize;
                counts[c] += 1;
                for j in 0..d {
                    sums[c * d + j] += rows[i * d + j];
                }
            }
            for c in 0..k {
                if counts[c] == 0 {
                    continue;
                }
                for j in 0..d {
                    centroids[c * d + j] = sums[c * d + j] / counts[c] as f64;
                }
            }
            if (inertia - new_inertia).abs() <= 1e-12 * inertia.max(1e-300) {
                inertia = new_inertia;
                break;
            }
            inertia = new_inertia;
        }
        if inertia < best_inertia {
            best_inertia = inertia;
            best_labels = labels;
        }
    }
    best_labels
}

#[inline]
fn sq(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spectral::affinity;

    fn blob_points(centers: &[(f32, f32)], m: usize, spread: f32, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut pts = Vec::with_capacity(centers.len() * m * 2);
        for &(cx, cy) in centers {
            for _ in 0..m {
                pts.push(cx + rng.normal_f32(0.0, spread));
                pts.push(cy + rng.normal_f32(0.0, spread));
            }
        }
        pts
    }

    fn purity(labels: &[u16], m: usize, k: usize) -> f64 {
        let truth: Vec<u16> =
            (0..k).flat_map(|c| std::iter::repeat(c as u16).take(m)).collect();
        crate::metrics::clustering_accuracy(&truth, labels)
    }

    #[test]
    fn njw_separates_four_blobs() {
        let pts =
            blob_points(&[(0.0, 0.0), (12.0, 0.0), (0.0, 12.0), (12.0, 12.0)], 50, 0.5, 21);
        let aff = affinity::build(&pts, 2, &vec![1.0; 200], 1.5);
        let mut rng = Rng::new(22);
        let emb = embed(&aff, 4, &mut rng);
        let labels = labels_from_embedding(&emb, 200, 4, 4, &mut rng);
        let acc = purity(&labels, 50, 4);
        assert!(acc > 0.99, "accuracy {acc}");
    }

    #[test]
    fn embedding_columns_orthonormal() {
        let pts = blob_points(&[(0.0, 0.0), (8.0, 0.0)], 40, 0.5, 23);
        let aff = affinity::build(&pts, 2, &vec![1.0; 80], 1.5);
        let mut rng = Rng::new(24);
        let emb = embed(&aff, 3, &mut rng);
        for a in 0..3 {
            for b in 0..3 {
                let dot: f64 = (0..80).map(|i| emb[i * 3 + a] * emb[i * 3 + b]).sum();
                let want = if a == b { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-6, "col {a}·{b} = {dot}");
            }
        }
    }

    #[test]
    fn top_eigenvalue_is_one() {
        let pts = blob_points(&[(0.0, 0.0), (9.0, 0.0)], 30, 0.4, 25);
        let aff = affinity::build(&pts, 2, &vec![1.0; 60], 1.0);
        let mut rng = Rng::new(26);
        let evals = top_eigenvalues(&aff, 2, &mut rng);
        assert!((evals[0] - 1.0).abs() < 1e-8, "λ1 = {}", evals[0]);
        assert!(evals[1] <= 1.0 + 1e-9);
    }

    #[test]
    fn kmeans_rows_exact_on_trivial() {
        // 3 well-separated 1-D groups
        let rows: Vec<f64> = vec![0.0, 0.1, 0.05, 10.0, 10.1, 9.9, 20.0, 20.1, 19.95];
        let mut rng = Rng::new(27);
        let labels = kmeans_rows(&rows, 9, 1, 3, 4, 50, &mut rng);
        assert_eq!(labels[0], labels[1]);
        assert_eq!(labels[0], labels[2]);
        assert_eq!(labels[3], labels[4]);
        assert_ne!(labels[0], labels[3]);
        assert_ne!(labels[3], labels[6]);
    }

    #[test]
    fn labels_from_embedding_handles_extra_columns() {
        // 8-col embedding (the artifact width) with 2 clusters
        let pts = blob_points(&[(0.0, 0.0), (15.0, 0.0)], 30, 0.4, 28);
        let aff = affinity::build(&pts, 2, &vec![1.0; 60], 1.5);
        let mut rng = Rng::new(29);
        let emb = embed(&aff, 8, &mut rng);
        let labels = labels_from_embedding(&emb, 60, 8, 2, &mut rng);
        assert_eq!(purity(&labels, 30, 2), 1.0);
    }
}
