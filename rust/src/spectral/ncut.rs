//! Recursive normalized cuts (Shi–Malik) — the paper's spectral algorithm.
//!
//! Each bipartition: second-largest eigenvector of `M = D^{-1/2} A D^{-1/2}`
//! (via Lanczos on the mat-vec), mapped back through `D^{-1/2}` to the
//! relaxed indicator, then the discrete split is recovered by a *sweep*:
//! vertices sorted by indicator value, every prefix split scored with the
//! exact ncut objective `cut/assoc(A) + cut/assoc(B)` maintained
//! incrementally. Recursion greedily splits whichever current cluster has
//! the cheapest best split until `k` clusters exist (the paper recurses on
//! each bipartition the same way).
//!
//! Everything is generic over [`Graph`]: with the dense affinity the sweep
//! costs O(n²) total, with the sparse k-NN graph O(nnz) — edge iteration
//! goes through [`Graph::for_each_edge`] so the sparse path never touches
//! absent edges.

use crate::linalg::eigen::lanczos_topk_op;
use crate::rng::Rng;

use super::{Graph, NormalizedOp};

/// Result of scoring one cluster's best bipartition.
struct SplitPlan {
    /// ncut objective of the best split (lower = better).
    score: f64,
    /// Membership (true = side A) in cluster-local indexing.
    side_a: Vec<bool>,
}

/// Best ncut bipartition of `aff` by eigenvector sweep. Returns `None` for
/// clusters too small or too disconnected to split meaningfully.
fn best_bipartition<G: Graph>(aff: &G, rng: &mut Rng) -> Option<SplitPlan> {
    let n = aff.len();
    if n < 2 {
        return None;
    }
    let total_deg: f64 = aff.degrees().iter().sum();
    if total_deg <= 1e-300 {
        // no edges: arbitrary halving (keeps recursion finite)
        let side_a: Vec<bool> = (0..n).map(|i| i < n / 2).collect();
        return Some(SplitPlan { score: 0.0, side_a });
    }

    // v2 of M via Lanczos (top-2; v1 ≈ D^{1/2}·1). The Krylov budget is
    // generous: clusterable graphs have λ2 ≈ 1 nearly degenerate with λ1
    // and close to λ3, which slows Ritz separation — under-iterating mixes
    // v3 into v2 and scrambles the sweep order.
    let iters = (8 * ((n as f64).ln().ceil() as usize) + 80).min(n);
    let (_evals, vecs) = lanczos_topk_op(&NormalizedOp(aff), 2, iters, 1e-10, rng);
    if vecs.len() < 2 {
        return None;
    }
    // relaxed indicator u = D^{-1/2} v2
    let u: Vec<f64> = vecs[1]
        .iter()
        .zip(aff.degrees())
        .map(|(v, d)| if *d > 1e-300 { v / d.sqrt() } else { 0.0 })
        .collect();

    // sweep over prefix splits in u-order
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| u[a].partial_cmp(&u[b]).unwrap());

    let mut in_a = vec![false; n];
    let mut assoc_a = 0.0f64;
    let mut cut = 0.0f64;
    let mut best: Option<(f64, usize)> = None;

    for (prefix, &v) in order.iter().enumerate().take(n - 1) {
        // move v from B to A: cut gains v→B edges, loses v→A edges
        let mut to_a = 0.0f64;
        aff.for_each_edge(v, |j, w| {
            if in_a[j] {
                to_a += w;
            }
        });
        let row_sum = aff.degrees()[v];
        let to_b = row_sum - to_a; // includes nothing for self (A[v,v]=0)
        cut += to_b - to_a;
        in_a[v] = true;
        assoc_a += row_sum;
        let assoc_b = total_deg - assoc_a;
        if assoc_a <= 1e-300 || assoc_b <= 1e-300 {
            continue;
        }
        let score = cut / assoc_a + cut / assoc_b;
        if best.map_or(true, |(s, _)| score < s) {
            best = Some((score, prefix));
        }
    }

    let (score, prefix) = best?;
    let mut side_a = vec![false; n];
    for &v in order.iter().take(prefix + 1) {
        side_a[v] = true;
    }
    Some(SplitPlan { score, side_a })
}

/// Cluster the graph into `k` groups by recursive normalized cuts.
/// Returns one label per vertex (0..k', k' ≤ k — fewer if the graph cannot
/// be split further).
pub fn recursive_ncut<G: Graph>(aff: &G, k: usize, rng: &mut Rng) -> Vec<u16> {
    assert!(k >= 1);
    let n = aff.len();
    let mut labels = vec![0u16; n];
    if k == 1 || n <= 1 {
        return labels;
    }

    // clusters as (global index lists, cached best split)
    struct Cluster {
        members: Vec<usize>,
        plan: Option<SplitPlan>,
    }

    let plan_for = |members: &[usize], rng: &mut Rng| -> Option<SplitPlan> {
        if members.len() < 2 {
            return None;
        }
        let sub = aff.subgraph(members);
        best_bipartition(&sub, rng)
    };

    let all: Vec<usize> = (0..n).collect();
    let first_plan = plan_for(&all, rng);
    let mut clusters = vec![Cluster { members: all, plan: first_plan }];

    while clusters.len() < k {
        // pick the cluster whose best split has the lowest ncut score
        let Some((ci, _)) = clusters
            .iter()
            .enumerate()
            .filter_map(|(i, c)| c.plan.as_ref().map(|p| (i, p.score)))
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        else {
            break; // nothing splittable left
        };
        let cluster = clusters.swap_remove(ci);
        let plan = cluster.plan.unwrap();
        let mut a_members = Vec::new();
        let mut b_members = Vec::new();
        for (local, &g) in cluster.members.iter().enumerate() {
            if plan.side_a[local] {
                a_members.push(g);
            } else {
                b_members.push(g);
            }
        }
        debug_assert!(!a_members.is_empty() && !b_members.is_empty());
        let a_plan = plan_for(&a_members, rng);
        let b_plan = plan_for(&b_members, rng);
        clusters.push(Cluster { members: a_members, plan: a_plan });
        clusters.push(Cluster { members: b_members, plan: b_plan });
    }

    for (label, cluster) in clusters.iter().enumerate() {
        for &g in &cluster.members {
            labels[g] = label as u16;
        }
    }
    labels
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spectral::affinity;

    /// blobs at given centers, m points each, tight spread
    fn blob_points(centers: &[(f32, f32)], m: usize, spread: f32, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut pts = Vec::with_capacity(centers.len() * m * 2);
        for &(cx, cy) in centers {
            for _ in 0..m {
                pts.push(cx + rng.normal_f32(0.0, spread));
                pts.push(cy + rng.normal_f32(0.0, spread));
            }
        }
        pts
    }

    fn purity(labels: &[u16], m: usize, k: usize) -> f64 {
        let truth: Vec<u16> =
            (0..k).flat_map(|c| std::iter::repeat(c as u16).take(m)).collect();
        crate::metrics::clustering_accuracy(&truth, labels)
    }

    #[test]
    fn two_blobs_split_perfectly() {
        let pts = blob_points(&[(0.0, 0.0), (10.0, 0.0)], 60, 0.4, 1);
        let w = vec![1.0f32; 120];
        let aff = affinity::build(&pts, 2, &w, 1.5);
        let mut rng = Rng::new(2);
        let labels = recursive_ncut(&aff, 2, &mut rng);
        assert_eq!(purity(&labels, 60, 2), 1.0);
    }

    #[test]
    fn four_blobs_recursive() {
        let pts =
            blob_points(&[(0.0, 0.0), (12.0, 0.0), (0.0, 12.0), (12.0, 12.0)], 40, 0.5, 3);
        let w = vec![1.0f32; 160];
        let aff = affinity::build(&pts, 2, &w, 1.5);
        let mut rng = Rng::new(4);
        let labels = recursive_ncut(&aff, 4, &mut rng);
        let acc = purity(&labels, 40, 4);
        assert!(acc > 0.99, "accuracy {acc}");
    }

    #[test]
    fn two_blobs_split_perfectly_on_sparse_graph() {
        let pts = blob_points(&[(0.0, 0.0), (10.0, 0.0)], 60, 0.4, 1);
        let w = vec![1.0f32; 120];
        let mut grng = Rng::new(3);
        let aff = crate::spectral::sparse::build_knn(&pts, 2, &w, 1.5, 10, &mut grng);
        let mut rng = Rng::new(2);
        let labels = recursive_ncut(&aff, 2, &mut rng);
        assert_eq!(purity(&labels, 60, 2), 1.0);
    }

    #[test]
    fn k_one_is_trivial() {
        let pts = blob_points(&[(0.0, 0.0)], 10, 0.5, 5);
        let aff = affinity::build(&pts, 2, &[1.0; 10], 1.0);
        let mut rng = Rng::new(6);
        let labels = recursive_ncut(&aff, 1, &mut rng);
        assert!(labels.iter().all(|&l| l == 0));
    }

    #[test]
    fn more_clusters_than_points_saturates() {
        let pts = blob_points(&[(0.0, 0.0), (5.0, 5.0)], 2, 0.1, 7);
        let aff = affinity::build(&pts, 2, &[1.0; 4], 1.0);
        let mut rng = Rng::new(8);
        let labels = recursive_ncut(&aff, 10, &mut rng);
        let distinct: std::collections::HashSet<u16> = labels.iter().copied().collect();
        assert!(distinct.len() <= 4);
    }

    #[test]
    fn nonconvex_rings_beat_naive_distance() {
        // inner tight ring + outer ring: spectral separates by connectivity
        let mut pts = Vec::new();
        let mut rng = Rng::new(9);
        let n_ring = 80;
        for i in 0..n_ring {
            let th = i as f64 / n_ring as f64 * std::f64::consts::TAU;
            pts.push((1.0 * th.cos()) as f32 + rng.normal_f32(0.0, 0.05));
            pts.push((1.0 * th.sin()) as f32 + rng.normal_f32(0.0, 0.05));
        }
        for i in 0..n_ring {
            let th = i as f64 / n_ring as f64 * std::f64::consts::TAU;
            pts.push((5.0 * th.cos()) as f32 + rng.normal_f32(0.0, 0.05));
            pts.push((5.0 * th.sin()) as f32 + rng.normal_f32(0.0, 0.05));
        }
        let aff = affinity::build(&pts, 2, &vec![1.0; 2 * n_ring], 0.5);
        let mut rng2 = Rng::new(10);
        let labels = recursive_ncut(&aff, 2, &mut rng2);
        let acc = purity(&labels, n_ring, 2);
        assert!(acc > 0.95, "ring separation accuracy {acc}");
    }

    #[test]
    fn weighted_codewords_respected() {
        // two heavy codewords near origin vs many light ones far away:
        // weights change degrees but splitting must still follow geometry
        let pts = blob_points(&[(0.0, 0.0), (20.0, 0.0)], 30, 0.3, 11);
        let mut w = vec![1.0f32; 60];
        for slot in w.iter_mut().take(30) {
            *slot = 50.0;
        }
        let aff = affinity::build(&pts, 2, &w, 2.0);
        let mut rng = Rng::new(12);
        let labels = recursive_ncut(&aff, 2, &mut rng);
        assert_eq!(purity(&labels, 30, 2), 1.0);
    }
}
