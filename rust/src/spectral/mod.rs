//! Spectral clustering over codewords — the central step of Algorithm 1.
//!
//! Two algorithms (both generic over the [`Graph`] storage):
//!
//! * [`ncut`] — recursive normalized cuts (Shi–Malik), the paper's choice;
//! * [`njw`] — NJW embedding + K-means, the algorithmic twin of the AOT
//!   XLA artifact so that the native and PJRT backends can be compared
//!   label-for-label (ablation A4/A5).
//!
//! Two graph storages (selected by [`GraphKind`]):
//!
//! * [`affinity::Affinity`] — the paper's dense `m × m` Gaussian affinity;
//!   O(m²) memory and mat-vec, fine up to a few thousand codewords;
//! * [`sparse::SparseAffinity`] — symmetric k-NN Gaussian graph in CSR
//!   form, neighbors found with rp-tree leaf candidates; O(m·k) memory and
//!   mat-vec, the path that unlocks 8k–32k+ codeword budgets.
//!
//! Both implement [`Graph`], and Lanczos consumes either through the
//! [`NormalizedOp`] adapter (a [`crate::linalg::SymOp`]), so the
//! algorithms above are written once.
//!
//! [`cluster_codewords`] is the front door used by the coordinator: it
//! resolves the bandwidth policy, builds the configured graph (optionally
//! weighted), runs the selected algorithm and reports eigen/bandwidth
//! diagnostics.

pub mod affinity;
pub mod ncut;
pub mod njw;
pub mod sparse;

use crate::rng::Rng;

pub use affinity::{Affinity, Bandwidth};
pub use sparse::SparseAffinity;

/// Abstraction over affinity-graph storage (dense matrix or CSR k-NN).
///
/// Everything the spectral algorithms need from a graph: its size and
/// cached degrees, the normalized mat-vec Lanczos iterates (exposed as a
/// [`crate::linalg::SymOp`] via [`NormalizedOp`]), sparse-aware edge
/// iteration for the ncut sweep, and subgraph extraction for the recursive
/// splits.
pub trait Graph: Sized {
    /// Number of vertices.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Cached vertex degrees `d_i = Σ_j A[i,j]` (f64 accumulation).
    fn degrees(&self) -> &[f64];

    /// `y = M x` where `M = D^{-1/2} A D^{-1/2}`. Zero-degree rows act as
    /// isolated vertices.
    fn normalized_matvec(&self, x: &[f64], y: &mut [f64]);

    /// Visit the edges of vertex `i` as `(neighbor, weight)`. Self-loops
    /// are never reported (`A[i,i] = 0` by construction in both storages).
    fn for_each_edge<F: FnMut(usize, f64)>(&self, i: usize, f: F);

    /// Restrict to an index subset; degrees are recomputed within the
    /// subset (recursive normalized cuts re-partitions subgraphs).
    fn subgraph(&self, idx: &[usize]) -> Self;
}

/// Run `f` against `z = scale ⊙ x` built in a thread-local scratch buffer —
/// the `D^{-1/2} x` pre-scaling both graph storages perform at the top of
/// every `normalized_matvec`. One reused buffer per thread keeps Lanczos'
/// per-iteration allocations at zero; `take`/`replace` (rather than holding
/// a `RefCell` borrow across `f`) lets a re-entrant call degrade to a fresh
/// allocation instead of panicking.
pub(crate) fn with_scaled_scratch<R>(
    x: &[f64],
    scale: &[f64],
    f: impl FnOnce(&[f64]) -> R,
) -> R {
    use std::cell::RefCell;
    thread_local! {
        static SCRATCH: RefCell<Vec<f64>> = const { RefCell::new(Vec::new()) };
    }
    SCRATCH.with(|cell| {
        let mut buf = cell.take();
        buf.clear();
        buf.extend(x.iter().zip(scale).map(|(v, s)| v * s));
        let out = f(&buf);
        cell.replace(buf);
        out
    })
}

/// Adapter exposing a [`Graph`]'s normalized affinity `D^{-1/2} A D^{-1/2}`
/// as a [`crate::linalg::SymOp`], so
/// [`crate::linalg::eigen::lanczos_topk_op`] runs identically against dense
/// and sparse storage.
pub struct NormalizedOp<'a, G: Graph>(pub &'a G);

impl<G: Graph> crate::linalg::SymOp for NormalizedOp<'_, G> {
    fn dim(&self) -> usize {
        self.0.len()
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.0.normalized_matvec(x, y)
    }
}

/// Affinity-graph construction policy for the central step.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum GraphKind {
    /// Full `m × m` Gaussian affinity (the paper's construction). O(m²)
    /// memory — fine up to a few thousand codewords.
    #[default]
    Dense,
    /// Symmetric k-nearest-neighbor Gaussian graph in CSR form, built with
    /// rp-tree-accelerated approximate neighbor search. O(m·k) memory —
    /// the large-codebook path (8k codewords and beyond).
    Knn {
        /// Neighbors kept per vertex before symmetrization. At `k = m − 1`
        /// the graph equals the dense affinity exactly (the parity tests
        /// pin that).
        k: usize,
    },
}

impl GraphKind {
    /// Neighbor count used when `knn` is selected without an explicit `k`.
    pub const DEFAULT_KNN_K: usize = 32;

    pub fn parse(s: &str) -> Option<GraphKind> {
        match s.to_ascii_lowercase().as_str() {
            "dense" | "full" => Some(GraphKind::Dense),
            "knn" | "sparse" => Some(GraphKind::Knn { k: Self::DEFAULT_KNN_K }),
            _ => None,
        }
    }
}

/// Which spectral algorithm to run on the codewords.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    /// Recursive normalized cuts (paper's algorithm).
    RecursiveNcut,
    /// NJW embedding + K-means (matches the XLA artifact pipeline).
    Njw,
}

impl Algo {
    pub fn parse(s: &str) -> Option<Algo> {
        match s.to_ascii_lowercase().as_str() {
            "ncut" | "recursive-ncut" => Some(Algo::RecursiveNcut),
            "njw" | "embedding" => Some(Algo::Njw),
            _ => None,
        }
    }
}

/// Parameters for the central spectral step.
#[derive(Clone, Debug)]
pub struct SpectralParams {
    /// Number of clusters to produce.
    pub k: usize,
    pub bandwidth: Bandwidth,
    pub algo: Algo,
    /// Affinity-graph storage: dense (paper) or sparse k-NN.
    pub graph: GraphKind,
    /// Weight affinity entries by codeword group sizes (`w_i w_j` factor).
    /// The paper clusters centroids unweighted; weighting is ablation A2.
    pub weighted: bool,
    pub seed: u64,
}

impl Default for SpectralParams {
    fn default() -> Self {
        SpectralParams {
            k: 2,
            bandwidth: Bandwidth::default(),
            algo: Algo::RecursiveNcut,
            graph: GraphKind::Dense,
            weighted: false,
            seed: 0,
        }
    }
}

/// Diagnostics from a spectral run.
#[derive(Clone, Debug, Default)]
pub struct SpectralInfo {
    /// Bandwidth actually used.
    pub sigma: f64,
    /// Top eigenvalues of the normalized affinity (when computed).
    pub top_evals: Vec<f64>,
}

/// Resolve a [`Bandwidth`] policy to a concrete σ for the given codewords.
/// The eigengap search builds its candidate graphs with the same `graph`
/// policy the clustering will use, so the sparse path stays O(m·k) even
/// while searching.
pub fn resolve_sigma(
    points: &[f32],
    dim: usize,
    weights: Option<&[f32]>,
    bw: Bandwidth,
    k: usize,
    graph: GraphKind,
    rng: &mut Rng,
) -> f64 {
    match bw {
        Bandwidth::Fixed(s) => s,
        Bandwidth::MedianScale(scale) => {
            scale * affinity::median_distance(points, dim, 512, rng)
        }
        Bandwidth::EigengapSearch { k: k_gap } => {
            let k_gap = k_gap.max(k).max(2);
            let med = affinity::median_distance(points, dim, 512, rng);
            let n = points.len() / dim;
            let ones = vec![1.0f32; n];
            let w = weights.unwrap_or(&ones);
            // The k-NN topology is σ-independent: search neighbors once and
            // reweight per candidate σ, so every σ is scored on the same
            // graph and the O(n·k·d) search is not repeated per scale.
            let topo = match graph {
                GraphKind::Dense => None,
                GraphKind::Knn { k: knn } => Some(sparse::knn_topology(points, dim, knn, rng)),
            };
            let mut best = (f64::NEG_INFINITY, med);
            for scale in [0.25, 0.5, 0.75, 1.0, 1.5, 2.0, 3.0] {
                let sigma = scale * med;
                let evals = match &topo {
                    None => {
                        let aff = affinity::build(points, dim, w, sigma);
                        njw::top_eigenvalues(&aff, k_gap, rng)
                    }
                    Some(t) => {
                        let aff = sparse::weight_topology(t, w, sigma);
                        njw::top_eigenvalues(&aff, k_gap, rng)
                    }
                };
                if evals.len() <= k_gap {
                    continue;
                }
                let gap = evals[k_gap - 1] - evals[k_gap];
                if gap > best.0 {
                    best = (gap, sigma);
                }
            }
            best.1
        }
    }
}

/// Spectral clustering of `n = points.len()/dim` codewords into
/// `params.k` groups. `weights` are the codeword group sizes (used for the
/// weighted-affinity variant; pass `None` for the paper's unweighted form).
pub fn cluster_codewords(
    points: &[f32],
    dim: usize,
    weights: Option<&[f32]>,
    params: &SpectralParams,
) -> (Vec<u16>, SpectralInfo) {
    let n = points.len() / dim;
    assert_eq!(points.len(), n * dim, "points buffer not a multiple of dim");
    if n == 0 {
        return (vec![], SpectralInfo::default());
    }
    let mut rng = Rng::new(params.seed);

    let sigma =
        resolve_sigma(points, dim, weights, params.bandwidth, params.k, params.graph, &mut rng);
    let ones;
    let w: &[f32] = if params.weighted {
        weights.expect("weighted=true requires weights")
    } else {
        ones = vec![1.0f32; n];
        &ones
    };

    let (labels, top_evals) = match params.graph {
        GraphKind::Dense => {
            let aff = affinity::build(points, dim, w, sigma);
            cluster_graph(&aff, params, &mut rng)
        }
        GraphKind::Knn { k } => {
            let aff = sparse::build_knn(points, dim, w, sigma, k, &mut rng);
            cluster_graph(&aff, params, &mut rng)
        }
    };
    (labels, SpectralInfo { sigma, top_evals })
}

/// Run the configured algorithm + eigen diagnostics on an already-built
/// graph — the storage-generic half of [`cluster_codewords`].
fn cluster_graph<G: Graph>(aff: &G, params: &SpectralParams, rng: &mut Rng) -> (Vec<u16>, Vec<f64>) {
    let n = aff.len();
    let labels = match params.algo {
        Algo::RecursiveNcut => ncut::recursive_ncut(aff, params.k, rng),
        Algo::Njw => {
            let k_cols = params.k.clamp(2, 8);
            let emb = njw::embed(aff, k_cols, rng);
            njw::labels_from_embedding(&emb, n, k_cols, params.k, rng)
        }
    };
    let top_evals = njw::top_eigenvalues(aff, params.k, rng);
    (labels, top_evals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gmm;
    use crate::metrics::clustering_accuracy;

    #[test]
    fn both_algorithms_cluster_the_paper_2d_mixture() {
        let ds = gmm::paper_mixture_2d(400, 31);
        for algo in [Algo::RecursiveNcut, Algo::Njw] {
            let params = SpectralParams {
                k: 4,
                algo,
                seed: 7,
                bandwidth: Bandwidth::MedianScale(0.3),
                ..Default::default()
            };
            let (labels, info) = cluster_codewords(&ds.points, 2, None, &params);
            let acc = clustering_accuracy(&ds.labels, &labels);
            // the Fig. 5 mixture overlaps heavily (means ±2, per-axis sd
            // √3): Bayes accuracy is ~0.8, k-means-style methods land ~0.75
            assert!(acc > 0.70, "{algo:?}: accuracy {acc}, sigma {}", info.sigma);
            assert!(info.sigma > 0.0);
        }
    }

    #[test]
    fn sparse_graph_clusters_the_paper_2d_mixture() {
        let ds = gmm::paper_mixture_2d(400, 31);
        for algo in [Algo::RecursiveNcut, Algo::Njw] {
            let params = SpectralParams {
                k: 4,
                algo,
                seed: 7,
                bandwidth: Bandwidth::MedianScale(0.3),
                graph: GraphKind::Knn { k: 24 },
                ..Default::default()
            };
            let (labels, info) = cluster_codewords(&ds.points, 2, None, &params);
            let acc = clustering_accuracy(&ds.labels, &labels);
            // the k-NN graph sees only local structure on this heavily
            // overlapping mixture, so allow a slightly wider band than the
            // dense test (random = 0.25, dense lands ~0.75)
            assert!(acc > 0.60, "{algo:?}: accuracy {acc}, sigma {}", info.sigma);
        }
    }

    #[test]
    fn eigengap_search_returns_positive_sigma() {
        let ds = gmm::paper_mixture_2d(200, 33);
        let mut rng = Rng::new(1);
        let sigma = resolve_sigma(
            &ds.points,
            2,
            None,
            Bandwidth::EigengapSearch { k: 4 },
            4,
            GraphKind::Dense,
            &mut rng,
        );
        assert!(sigma > 0.0);
    }

    #[test]
    fn eigengap_search_works_on_the_sparse_graph() {
        let ds = gmm::paper_mixture_2d(200, 33);
        let mut rng = Rng::new(1);
        let sigma = resolve_sigma(
            &ds.points,
            2,
            None,
            Bandwidth::EigengapSearch { k: 4 },
            4,
            GraphKind::Knn { k: 16 },
            &mut rng,
        );
        assert!(sigma > 0.0);
    }

    #[test]
    fn weighted_and_unweighted_agree_on_uniform_weights() {
        let ds = gmm::paper_mixture_2d(200, 35);
        let w = vec![1.0f32; 200];
        let base = SpectralParams {
            k: 4,
            algo: Algo::Njw,
            seed: 11,
            bandwidth: Bandwidth::Fixed(1.5),
            ..Default::default()
        };
        let (a, _) = cluster_codewords(&ds.points, 2, Some(&w), &base);
        let weighted = SpectralParams { weighted: true, ..base };
        let (b, _) = cluster_codewords(&ds.points, 2, Some(&w), &weighted);
        // identical affinity ⇒ identical labels (same seeds)
        assert_eq!(a, b);
    }

    #[test]
    fn graph_kind_parses() {
        assert_eq!(GraphKind::parse("dense"), Some(GraphKind::Dense));
        assert_eq!(
            GraphKind::parse("knn"),
            Some(GraphKind::Knn { k: GraphKind::DEFAULT_KNN_K })
        );
        assert_eq!(
            GraphKind::parse("sparse"),
            Some(GraphKind::Knn { k: GraphKind::DEFAULT_KNN_K })
        );
        assert_eq!(GraphKind::parse("csr"), None);
    }

    #[test]
    fn empty_input() {
        let (labels, _) = cluster_codewords(&[], 3, None, &SpectralParams::default());
        assert!(labels.is_empty());
    }
}
