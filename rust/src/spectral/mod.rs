//! Spectral clustering over codewords — the central step of Algorithm 1.
//!
//! Two algorithms (both operate on the same [`affinity::Affinity`]):
//!
//! * [`ncut`] — recursive normalized cuts (Shi–Malik), the paper's choice;
//! * [`njw`] — NJW embedding + K-means, the algorithmic twin of the AOT
//!   XLA artifact so that the native and PJRT backends can be compared
//!   label-for-label (ablation A4/A5).
//!
//! [`cluster_codewords`] is the front door used by the coordinator: it
//! resolves the bandwidth policy, builds the (optionally weighted)
//! affinity, runs the selected algorithm and reports eigen/bandwidth
//! diagnostics.

pub mod affinity;
pub mod ncut;
pub mod njw;

use crate::rng::Rng;

pub use affinity::{Affinity, Bandwidth};

/// Which spectral algorithm to run on the codewords.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    /// Recursive normalized cuts (paper's algorithm).
    RecursiveNcut,
    /// NJW embedding + K-means (matches the XLA artifact pipeline).
    Njw,
}

impl Algo {
    pub fn parse(s: &str) -> Option<Algo> {
        match s.to_ascii_lowercase().as_str() {
            "ncut" | "recursive-ncut" => Some(Algo::RecursiveNcut),
            "njw" | "embedding" => Some(Algo::Njw),
            _ => None,
        }
    }
}

/// Parameters for the central spectral step.
#[derive(Clone, Debug)]
pub struct SpectralParams {
    /// Number of clusters to produce.
    pub k: usize,
    pub bandwidth: Bandwidth,
    pub algo: Algo,
    /// Weight affinity entries by codeword group sizes (`w_i w_j` factor).
    /// The paper clusters centroids unweighted; weighting is ablation A2.
    pub weighted: bool,
    pub seed: u64,
}

impl Default for SpectralParams {
    fn default() -> Self {
        SpectralParams {
            k: 2,
            bandwidth: Bandwidth::default(),
            algo: Algo::RecursiveNcut,
            weighted: false,
            seed: 0,
        }
    }
}

/// Diagnostics from a spectral run.
#[derive(Clone, Debug, Default)]
pub struct SpectralInfo {
    /// Bandwidth actually used.
    pub sigma: f64,
    /// Top eigenvalues of the normalized affinity (when computed).
    pub top_evals: Vec<f64>,
}

/// Resolve a [`Bandwidth`] policy to a concrete σ for the given codewords.
pub fn resolve_sigma(
    points: &[f32],
    dim: usize,
    weights: Option<&[f32]>,
    bw: Bandwidth,
    k: usize,
    rng: &mut Rng,
) -> f64 {
    match bw {
        Bandwidth::Fixed(s) => s,
        Bandwidth::MedianScale(scale) => {
            scale * affinity::median_distance(points, dim, 512, rng)
        }
        Bandwidth::EigengapSearch { k: k_gap } => {
            let k_gap = k_gap.max(k).max(2);
            let med = affinity::median_distance(points, dim, 512, rng);
            let n = points.len() / dim;
            let ones = vec![1.0f32; n];
            let w = weights.unwrap_or(&ones);
            let mut best = (f64::NEG_INFINITY, med);
            for scale in [0.25, 0.5, 0.75, 1.0, 1.5, 2.0, 3.0] {
                let sigma = scale * med;
                let aff = affinity::build(points, dim, w, sigma);
                let evals = njw::top_eigenvalues(&aff, k_gap, rng);
                if evals.len() <= k_gap {
                    continue;
                }
                let gap = evals[k_gap - 1] - evals[k_gap];
                if gap > best.0 {
                    best = (gap, sigma);
                }
            }
            best.1
        }
    }
}

/// Spectral clustering of `n = points.len()/dim` codewords into
/// `params.k` groups. `weights` are the codeword group sizes (used for the
/// weighted-affinity variant; pass `None` for the paper's unweighted form).
pub fn cluster_codewords(
    points: &[f32],
    dim: usize,
    weights: Option<&[f32]>,
    params: &SpectralParams,
) -> (Vec<u16>, SpectralInfo) {
    let n = points.len() / dim;
    assert_eq!(points.len(), n * dim, "points buffer not a multiple of dim");
    if n == 0 {
        return (vec![], SpectralInfo::default());
    }
    let mut rng = Rng::new(params.seed);

    let sigma = resolve_sigma(points, dim, weights, params.bandwidth, params.k, &mut rng);
    let ones;
    let w: &[f32] = if params.weighted {
        weights.expect("weighted=true requires weights")
    } else {
        ones = vec![1.0f32; n];
        &ones
    };

    let aff = affinity::build(points, dim, w, sigma);
    let labels = match params.algo {
        Algo::RecursiveNcut => ncut::recursive_ncut(&aff, params.k, &mut rng),
        Algo::Njw => {
            let k_cols = params.k.clamp(2, 8);
            let emb = njw::embed(&aff, k_cols, &mut rng);
            njw::labels_from_embedding(&emb, n, k_cols, params.k, &mut rng)
        }
    };
    let top_evals = njw::top_eigenvalues(&aff, params.k, &mut rng);
    (labels, SpectralInfo { sigma, top_evals })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gmm;
    use crate::metrics::clustering_accuracy;

    #[test]
    fn both_algorithms_cluster_the_paper_2d_mixture() {
        let ds = gmm::paper_mixture_2d(400, 31);
        for algo in [Algo::RecursiveNcut, Algo::Njw] {
            let params = SpectralParams {
                k: 4,
                algo,
                seed: 7,
                bandwidth: Bandwidth::MedianScale(0.3),
                ..Default::default()
            };
            let (labels, info) = cluster_codewords(&ds.points, 2, None, &params);
            let acc = clustering_accuracy(&ds.labels, &labels);
            // the Fig. 5 mixture overlaps heavily (means ±2, per-axis sd
            // √3): Bayes accuracy is ~0.8, k-means-style methods land ~0.75
            assert!(acc > 0.70, "{algo:?}: accuracy {acc}, sigma {}", info.sigma);
            assert!(info.sigma > 0.0);
        }
    }

    #[test]
    fn eigengap_search_returns_positive_sigma() {
        let ds = gmm::paper_mixture_2d(200, 33);
        let mut rng = Rng::new(1);
        let sigma = resolve_sigma(
            &ds.points,
            2,
            None,
            Bandwidth::EigengapSearch { k: 4 },
            4,
            &mut rng,
        );
        assert!(sigma > 0.0);
    }

    #[test]
    fn weighted_and_unweighted_agree_on_uniform_weights() {
        let ds = gmm::paper_mixture_2d(200, 35);
        let w = vec![1.0f32; 200];
        let base = SpectralParams {
            k: 4,
            algo: Algo::Njw,
            seed: 11,
            bandwidth: Bandwidth::Fixed(1.5),
            ..Default::default()
        };
        let (a, _) = cluster_codewords(&ds.points, 2, Some(&w), &base);
        let weighted = SpectralParams { weighted: true, ..base };
        let (b, _) = cluster_codewords(&ds.points, 2, Some(&w), &weighted);
        // identical affinity ⇒ identical labels (same seeds)
        assert_eq!(a, b);
    }

    #[test]
    fn empty_input() {
        let (labels, _) = cluster_codewords(&[], 3, None, &SpectralParams::default());
        assert!(labels.is_empty());
    }
}
