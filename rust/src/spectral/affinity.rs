//! Native Gaussian-affinity construction over codewords.
//!
//! Mirrors the semantics of the Layer-1 Pallas kernel exactly (weighted,
//! zero diagonal, pad-free here since the native path needs no padding):
//! `A[i,j] = w_i w_j exp(−‖x_i−x_j‖² / 2σ²)`, `A[i,i] = 0`.
//!
//! Rows are built in parallel chunks with the same `‖x‖²+‖y‖²−2x·y`
//! expansion the kernel uses. Bandwidth selection offers the paper's
//! cross-validatory spirit via an eigengap grid search on top of the
//! median-distance heuristic (the paper greps σ ∈ (0, 200] per dataset;
//! see [`Bandwidth`]).

use crate::linalg::kernels;
use crate::par;
use crate::rng::Rng;

/// Symmetric affinity matrix with cached degrees.
#[derive(Clone, Debug)]
pub struct Affinity {
    pub n: usize,
    /// Row-major `n × n` weights.
    pub data: Vec<f32>,
    /// Degree `d_i = Σ_j A[i,j]` (f64 accumulation).
    pub deg: Vec<f64>,
    /// Cached `1/√d_i` (0 for isolated vertices): the normalized mat-vec is
    /// Lanczos' inner loop, so this is precomputed once at construction
    /// rather than per call — same scheme as `SparseAffinity`.
    pub inv_sqrt_deg: Vec<f64>,
}

impl Affinity {
    /// Finish construction from assembled weights and degrees: compute the
    /// cached `1/√d` table. Every constructor funnels through here so the
    /// field can't be forgotten.
    fn finish(n: usize, data: Vec<f32>, deg: Vec<f64>) -> Affinity {
        debug_assert_eq!(data.len(), n * n);
        debug_assert_eq!(deg.len(), n);
        let inv_sqrt_deg: Vec<f64> =
            deg.iter().map(|&d| if d > 1e-300 { 1.0 / d.sqrt() } else { 0.0 }).collect();
        Affinity { n, data, deg, inv_sqrt_deg }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.n..(i + 1) * self.n]
    }

    /// y = M x where `M = D^{-1/2} A D^{-1/2}` (the normalized affinity
    /// whose top eigenvectors normalized cuts needs). Zero-degree rows act
    /// as isolated vertices.
    ///
    /// The row dot is [`kernels::dot_f32_f64`] — Lanczos' entire inner loop
    /// (EXPERIMENTS.md §Perf, change 5) — and the `D^{-1/2} x` pre-scale
    /// reuses a thread-local scratch buffer instead of allocating per call.
    pub fn normalized_matvec(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.n);
        assert_eq!(y.len(), self.n);
        super::with_scaled_scratch(x, &self.inv_sqrt_deg, |z| {
            par::par_chunks_mut(y, 256, |start, chunk| {
                for (off, out) in chunk.iter_mut().enumerate() {
                    let i = start + off;
                    *out = kernels::dot_f32_f64(self.row(i), z) * self.inv_sqrt_deg[i];
                }
            });
        });
    }

    /// Restrict to an index subset (recursive normalized cuts re-partitions
    /// sub-graphs). Degrees are recomputed within the subset.
    pub fn submatrix(&self, idx: &[usize]) -> Affinity {
        let m = idx.len();
        let mut data = vec![0.0f32; m * m];
        for (r, &i) in idx.iter().enumerate() {
            let src = self.row(i);
            let dst = &mut data[r * m..(r + 1) * m];
            for (c, &j) in idx.iter().enumerate() {
                dst[c] = src[j];
            }
        }
        let mut deg = vec![0.0f64; m];
        for r in 0..m {
            deg[r] = data[r * m..(r + 1) * m].iter().map(|&v| v as f64).sum();
        }
        Affinity::finish(m, data, deg)
    }

    /// Total edge weight between `a`-side and `b`-side of a bipartition
    /// given a membership mask (true = side A). Used by the ncut objective.
    pub fn cut_value(&self, side_a: &[bool]) -> f64 {
        assert_eq!(side_a.len(), self.n);
        let mut cut = 0.0f64;
        for i in 0..self.n {
            if !side_a[i] {
                continue;
            }
            let row = self.row(i);
            for (j, &v) in row.iter().enumerate() {
                if !side_a[j] {
                    cut += v as f64;
                }
            }
        }
        cut
    }
}

/// Build the affinity matrix for `points` (`n × dim`, row-major) with
/// per-point weights `w` (pass all-ones for the unweighted variant).
pub fn build(points: &[f32], dim: usize, w: &[f32], sigma: f64) -> Affinity {
    assert!(dim > 0);
    let n = points.len() / dim;
    assert_eq!(points.len(), n * dim);
    assert_eq!(w.len(), n);
    assert!(sigma > 0.0, "sigma must be positive");

    // ‖x_i‖² table
    let sq: Vec<f32> = (0..n)
        .map(|i| points[i * dim..(i + 1) * dim].iter().map(|v| v * v).sum())
        .collect();
    let inv_two_sigma2 = (1.0 / (2.0 * sigma * sigma)) as f32;

    // Row-parallel build. Each output row i is a contiguous n-length slice
    // filled in three vectorizable passes: squared distances via the
    // expanded form (the dot runs over points' rows), one fused
    // scale+exp+weight pass, then the diagonal zero. (Per-element index
    // arithmetic — the first implementation — cost ~35% of the kernel; see
    // EXPERIMENTS.md §Perf, change 3.)
    let mut data = vec![0.0f32; n * n];
    par::par_rows_mut(&mut data, n, |row0, rows| {
        for (r, row) in rows.chunks_exact_mut(n).enumerate() {
            let i = row0 + r;
            let pi = &points[i * dim..(i + 1) * dim];
            let sqi = sq[i];
            let wi = w[i];
            for (j, slot) in row.iter_mut().enumerate() {
                let pj = &points[j * dim..(j + 1) * dim];
                // kernels::dot_f32 — the same kernel the sparse k-NN scan
                // uses, which is what keeps full-k sparse/dense bit parity
                let dot = kernels::dot_f32(pi, pj);
                let d2 = (sqi + sq[j] - 2.0 * dot).max(0.0);
                *slot = wi * w[j] * (-d2 * inv_two_sigma2).exp();
            }
            row[i] = 0.0;
        }
    });

    let mut deg = vec![0.0f64; n];
    par::par_chunks_mut(&mut deg, 64, |start, chunk| {
        for (off, d) in chunk.iter_mut().enumerate() {
            let i = start + off;
            *d = data[i * n..(i + 1) * n].iter().map(|&v| v as f64).sum();
        }
    });

    Affinity::finish(n, data, deg)
}

impl super::Graph for Affinity {
    fn len(&self) -> usize {
        self.n
    }
    fn degrees(&self) -> &[f64] {
        &self.deg
    }
    fn normalized_matvec(&self, x: &[f64], y: &mut [f64]) {
        Affinity::normalized_matvec(self, x, y)
    }
    fn for_each_edge<F: FnMut(usize, f64)>(&self, i: usize, mut f: F) {
        for (j, &v) in self.row(i).iter().enumerate() {
            if j != i {
                f(j, v as f64);
            }
        }
    }
    fn subgraph(&self, idx: &[usize]) -> Affinity {
        self.submatrix(idx)
    }
}

/// Bandwidth (σ) selection policy.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Bandwidth {
    /// Use σ as given.
    Fixed(f64),
    /// Median pairwise distance of a subsample, times the scale factor.
    MedianScale(f64),
    /// Grid of scale factors over the median heuristic; pick the σ that
    /// maximizes the eigengap λ_K − λ_{K+1} of the normalized affinity —
    /// our deterministic stand-in for the paper's cross-validatory search
    /// over (0, 200].
    EigengapSearch { k: usize },
}

impl Default for Bandwidth {
    fn default() -> Self {
        Bandwidth::MedianScale(1.0)
    }
}

/// Median pairwise distance over a random subsample (≤ `cap` points).
pub fn median_distance(points: &[f32], dim: usize, cap: usize, rng: &mut Rng) -> f64 {
    let n = points.len() / dim;
    assert!(n > 0, "median_distance on empty set");
    if n == 1 {
        return 1.0;
    }
    let m = n.min(cap);
    let idx: Vec<usize> =
        if m == n { (0..n).collect() } else { rng.sample_indices(n, m) };
    let mut dists = Vec::with_capacity(m * (m - 1) / 2);
    for a in 0..m {
        let pa = &points[idx[a] * dim..idx[a] * dim + dim];
        for b in (a + 1)..m {
            let pb = &points[idx[b] * dim..idx[b] * dim + dim];
            let mut d2 = 0.0f64;
            for k in 0..dim {
                let d = (pa[k] - pb[k]) as f64;
                d2 += d * d;
            }
            dists.push(d2.sqrt());
        }
    }
    dists.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let med = dists[dists.len() / 2];
    if med > 1e-12 {
        med
    } else {
        1.0 // degenerate (all points identical): any σ works
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_points() -> (Vec<f32>, usize) {
        // two pairs of close points, far apart
        (vec![0.0, 0.0, 0.1, 0.0, 10.0, 10.0, 10.1, 10.0], 2)
    }

    #[test]
    fn matches_bruteforce() {
        let (pts, dim) = toy_points();
        let w = vec![1.0f32; 4];
        let a = build(&pts, dim, &w, 1.0);
        for i in 0..4 {
            for j in 0..4 {
                let want = if i == j {
                    0.0
                } else {
                    let pi = &pts[i * 2..i * 2 + 2];
                    let pj = &pts[j * 2..j * 2 + 2];
                    let d2 = (pi[0] - pj[0]).powi(2) + (pi[1] - pj[1]).powi(2);
                    (-d2 / 2.0).exp()
                };
                // f32 expanded-form distances near large ||x||^2 lose ~1e-5
                assert!((a.row(i)[j] - want).abs() < 2e-4, "A[{i},{j}]");
            }
        }
        // symmetric, nonnegative, deg consistent
        for i in 0..4 {
            let sum: f64 = a.row(i).iter().map(|&v| v as f64).sum();
            assert!((sum - a.deg[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn weights_scale_entries() {
        let (pts, dim) = toy_points();
        let w1 = vec![1.0f32; 4];
        let w2 = vec![2.0f32, 3.0, 1.0, 1.0];
        let a1 = build(&pts, dim, &w1, 1.0);
        let a2 = build(&pts, dim, &w2, 1.0);
        assert!((a2.row(0)[1] - 6.0 * a1.row(0)[1]).abs() < 1e-6);
    }

    #[test]
    fn normalized_matvec_top_eigvec_is_sqrt_deg() {
        // M (D^{1/2} 1) = D^{-1/2} A 1 = D^{-1/2} d = D^{1/2} 1 — exact
        let (pts, dim) = toy_points();
        let w = vec![1.0f32; 4];
        let a = build(&pts, dim, &w, 2.0);
        let x: Vec<f64> = a.deg.iter().map(|d| d.sqrt()).collect();
        let mut y = vec![0.0; 4];
        a.normalized_matvec(&x, &mut y);
        for i in 0..4 {
            assert!((y[i] - x[i]).abs() < 1e-9, "{} vs {}", y[i], x[i]);
        }
    }

    #[test]
    fn inv_sqrt_deg_cached_at_construction() {
        let (pts, dim) = toy_points();
        let w = vec![1.0f32; 4];
        let a = build(&pts, dim, &w, 1.0);
        for i in 0..4 {
            assert_eq!(a.inv_sqrt_deg[i].to_bits(), (1.0 / a.deg[i].sqrt()).to_bits());
        }
        // every constructor goes through finish(), including submatrix
        let sub = a.submatrix(&[1, 3]);
        assert_eq!(sub.inv_sqrt_deg.len(), 2);
        assert_eq!(sub.inv_sqrt_deg[0].to_bits(), (1.0 / sub.deg[0].sqrt()).to_bits());
    }

    #[test]
    fn submatrix_consistent() {
        let (pts, dim) = toy_points();
        let w = vec![1.0f32; 4];
        let a = build(&pts, dim, &w, 1.0);
        let sub = a.submatrix(&[1, 3]);
        assert_eq!(sub.n, 2);
        assert!((sub.row(0)[1] - a.row(1)[3]).abs() < 1e-9);
        assert_eq!(sub.row(0)[0], 0.0);
    }

    #[test]
    fn cut_value_counts_cross_edges() {
        let (pts, dim) = toy_points();
        let w = vec![1.0f32; 4];
        let a = build(&pts, dim, &w, 5.0);
        let cut = a.cut_value(&[true, true, false, false]);
        let manual = a.row(0)[2] as f64 + a.row(0)[3] as f64 + a.row(1)[2] as f64 + a.row(1)[3] as f64;
        assert!((cut - manual).abs() < 1e-9);
    }

    #[test]
    fn median_distance_sane() {
        let (pts, dim) = toy_points();
        let mut rng = Rng::new(1);
        let med = median_distance(&pts, dim, 100, &mut rng);
        // pairwise distances: {0.1, 0.1, ~14.14 ×4} — median is ~14.1
        assert!(med > 1.0 && med < 20.0, "{med}");
    }

    #[test]
    fn median_distance_degenerate_is_one() {
        let pts = vec![1.0f32; 10];
        let mut rng = Rng::new(2);
        assert_eq!(median_distance(&pts, 1, 100, &mut rng), 1.0);
    }
}
