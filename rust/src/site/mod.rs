//! The site half of the distributed protocol — one worker, any transport.
//!
//! [`serve`] is the *entire* behavior of a site for one classic pipeline
//! run: register the local shard, receive the DML work order, compress,
//! ship the codebook, await codeword labels, populate per-point labels.
//! The same function drives
//!
//! * the in-process site threads that [`crate::coordinator::run_pipeline`]
//!   spawns over the channel transport, and
//! * the `dsc site` daemon process serving a one-shot leader over TCP
//!   ([`crate::net::tcp::SiteListener`]).
//!
//! [`session`] is the multi-run sibling: one persistent connection from a
//! job-serving leader (`dsc leader --serve`), run-scoped frames, many
//! runs — possibly interleaved — served without reloading anything (the
//! shard is loaded once per daemon, each run reuses it). The per-run
//! behavior is identical to [`serve`] step for step; only the framing and
//! the lifetime differ. That symmetry is what makes the drivers
//! result-identical: there is one protocol implementation, not a
//! simulated one and a real one.
//!
//! Per-phase costs are **thread CPU time**: sites are independent machines
//! in the paper's model, so when they are simulated as threads of one
//! (possibly single-core) host, scheduler contention between them must not
//! leak into the max-over-sites elapsed model. See
//! [`crate::metrics::thread_cpu_time`].
//!
//! [`Session`] is the *streaming* owner behind [`session`]: it holds the
//! shard, its merkle-style [`ShardDigest`] version, and a DML result cache
//! keyed by `(work-order params, shard version)`. New points arrive through
//! [`Session::ingest`] (the `dsc site --ingest` seam) — they are folded
//! into the live codebook incrementally ([`dml::fold_in`]) and move the
//! digest, which invalidates every cached result at once. A repeat work
//! order at an unchanged shard replays its cached codebook without a
//! single DML pass; because DML is deterministic, the replay is
//! bit-identical to a recompute, so nothing downstream (leader accounting,
//! labels, byte counters) can tell the difference.

pub mod digest;

use std::collections::HashMap;
use std::path::Path;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::data::Dataset;
use crate::dml::{self, DmlParams};
use crate::net::{Message, SiteNet};

pub use digest::ShardDigest;

/// What one site produced and measured during a pipeline run.
#[derive(Clone, Debug)]
pub struct ServeOutcome {
    /// The id the leader addressed this site by.
    pub site_id: usize,
    /// Points in the local shard.
    pub n_points: usize,
    /// Codewords this site shipped.
    pub n_codes: usize,
    /// Thread CPU time of the DML phase.
    pub dml_time: Duration,
    /// Thread CPU time of the label-population phase.
    pub populate_time: Duration,
    /// Mean squared quantization distortion (Theorem 2/3 quantity).
    pub distortion: f64,
    /// Predicted label per local point, in local point order. Mapping local
    /// to global indices is the caller's business (a real site has no
    /// global view; the in-process coordinator keeps `global_idx`).
    pub labels: Vec<u16>,
}

/// Serve one pipeline run over an established link: the site side of the
/// protocol in `docs/PROTOCOL.md` §"One run".
pub fn serve(net: &SiteNet, data: &Dataset) -> Result<ServeOutcome> {
    let site_id = net.site_id();

    // 1. Register the shard so the leader can size codeword budgets.
    net.send(&Message::SiteInfo {
        site: site_id as u32,
        n_points: data.len() as u64,
        dim: data.dim as u32,
    })
    .context("send site info")?;

    // 2. The DML work order (transform, budget, knobs, forked seed).
    let params = match net.recv().context("await dml request")? {
        Message::DmlRequest { site, dml, target_codes, max_iters, tol, seed } => {
            if site as usize != site_id {
                bail!("dml request addressed to site {site}, this is site {site_id}");
            }
            DmlParams {
                kind: dml,
                target_codes: target_codes as usize,
                max_iters: max_iters as usize,
                tol,
                seed,
            }
        }
        other => bail!("expected a dml request, got {other:?}"),
    };

    // 3. Compress locally; only the codebook leaves the site.
    let (cb, dml_time, distortion) = run_dml(data, &params);

    net.send(&Message::Codebook {
        site: site_id as u32,
        dim: cb.dim as u32,
        codewords: cb.codewords.clone(),
        weights: cb.weights.clone(),
    })
    .context("send codebook")?;

    // 4. Codeword labels come back after the leader's central phase. The
    //    link sits idle for that whole phase — transports must tolerate it.
    let code_labels = match net.recv().context("await codeword labels")? {
        Message::Labels { site, labels } => {
            if site as usize != site_id {
                bail!("label frame addressed to site {site}, this is site {site_id}");
            }
            if labels.len() != cb.n_codes() {
                bail!("leader sent {} labels for {} codewords", labels.len(), cb.n_codes());
            }
            labels
        }
        other => bail!("expected labels, got {other:?}"),
    };

    // 5. Populate: every local point inherits its codeword's label via the
    //    assignment table that never left this site.
    let (labels, populate_time) = populate(&cb, &code_labels);

    Ok(ServeOutcome {
        site_id,
        n_points: data.len(),
        n_codes: cb.n_codes(),
        dml_time,
        populate_time,
        distortion,
        labels,
    })
}

/// The DML phase, timed in thread CPU: compress the shard under `params`.
fn run_dml(data: &Dataset, params: &DmlParams) -> (dml::Codebook, Duration, f64) {
    let t0 = crate::metrics::thread_cpu_time();
    let cb = dml::apply(data, params);
    let dml_time = crate::metrics::thread_cpu_time().saturating_sub(t0);
    debug_assert!(cb.validate(data.len()).is_ok());
    let distortion = cb.distortion(data);
    (cb, dml_time, distortion)
}

/// The populate phase, timed in thread CPU: every local point inherits its
/// codeword's label via the assignment table that never left this site.
fn populate(cb: &dml::Codebook, code_labels: &[u16]) -> (Vec<u16>, Duration) {
    let t1 = crate::metrics::thread_cpu_time();
    let labels: Vec<u16> = cb.assign.iter().map(|&a| code_labels[a as usize]).collect();
    let populate_time = crate::metrics::thread_cpu_time().saturating_sub(t1);
    (labels, populate_time)
}

/// What one completed run of a [`session`] produced (per-run callback
/// payload — the daemon prints a `SERVED` line from it).
#[derive(Clone, Debug)]
pub struct RunServed {
    pub run: u32,
    pub n_points: usize,
    pub n_codes: usize,
    /// Thread CPU time of the DML phase — [`Duration::ZERO`] on a cache
    /// hit, which performed no DML at all.
    pub dml_time: Duration,
    pub distortion: f64,
    /// Whether the work order was answered from the DML result cache.
    pub cache_hit: bool,
}

/// How a [`session`] ended.
#[derive(Clone, Copy, Debug, Default)]
pub struct SessionOutcome {
    /// Runs fully served (labels populated).
    pub runs_served: usize,
    /// Runs still mid-flight when the leader went away (their state is
    /// discarded with the connection).
    pub aborted_runs: usize,
    /// Full DML computations this session performed.
    pub dml_passes: usize,
    /// Work orders answered from the DML result cache (zero DML passes).
    pub cache_hits: usize,
}

/// Limits on one multi-run [`session`] (config `[site]`; the count knobs
/// are validated ≥ 1 at parse time — zero would silently refuse every
/// pull or every run, or hash the shard point by point).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SessionLimits {
    /// Completed runs whose populated labels are kept for `LABELSPULL`.
    /// Oldest evicted first; a pull for an evicted run gets a `REJECT`
    /// (the leader forwards it to the asking client).
    pub label_cache_runs: usize,
    /// Most runs a leader may hold open on one session before the site
    /// calls it hostile — a sanity backstop sized far above any real
    /// `[leader] max_jobs`.
    pub max_open_runs: usize,
    /// Answer repeat work orders from the shard-versioned DML result
    /// cache (`[site] cache_dml`). Deterministic DML makes a cached
    /// codebook bit-identical to a recompute, so this is on by default;
    /// turning it off forces a full DML pass per work order.
    pub cache_dml: bool,
    /// Distinct DML results kept per site, oldest evicted first
    /// (`[site] dml_cache_runs`).
    pub dml_cache_runs: usize,
    /// Points per leaf chunk of the shard digest
    /// (`[site] digest_chunk`) — smaller chunks mean cheaper ingest
    /// rehashing, more leaf hashes.
    pub digest_chunk: usize,
    /// Volunteer a `SITEINFO2` digest report at the start of each session
    /// (`[site] report_digest`, default off: a leader that predates the
    /// tag rejects unknown frames loudly — per the forward-compat rules —
    /// so the site volunteers nothing unless the operator opts in).
    pub report_digest: bool,
}

impl Default for SessionLimits {
    fn default() -> Self {
        SessionLimits {
            label_cache_runs: 8,
            max_open_runs: 64,
            cache_dml: true,
            dml_cache_runs: 8,
            digest_chunk: digest::DEFAULT_DIGEST_CHUNK,
            report_digest: false,
        }
    }
}

/// One cached DML result: the codebook computed for `params` when the
/// shard digest root was `version`. Valid exactly while both match.
struct DmlCacheEntry {
    params: DmlParams,
    version: u64,
    cb: dml::Codebook,
    distortion: f64,
}

/// A streaming site: the shard, its version digest, the DML result cache
/// and the live codebook, owned across connections and ingests.
///
/// The `dsc site` daemon builds one `Session` at startup and drives
/// [`Session::serve`] once per accepted leader connection — the caches
/// and the digest survive reconnects. [`Session::ingest`] is the seam
/// through which data arrives after startup (`dsc site --ingest`, tests,
/// embedders): it appends points, advances the digest incrementally, and
/// folds the new points into the live codebook — never a full rescan.
pub struct Session {
    data: Dataset,
    limits: SessionLimits,
    digest: ShardDigest,
    /// Cached per-work-order DML results, newest last, capped at
    /// `dml_cache_runs`. Keyed by `(params, shard version)` — an ingest
    /// moves the version and thereby invalidates every entry at once
    /// (stale entries age out of the bounded queue).
    dml_cache: Vec<DmlCacheEntry>,
    /// The most recently computed codebook and its work order — the
    /// streaming summary that ingests refine incrementally.
    live: Option<(DmlParams, dml::Codebook)>,
    /// Cumulative counters across every serve on this session.
    total_dml_passes: usize,
    total_cache_hits: usize,
}

impl Session {
    /// Take ownership of the shard and hash it (chunked, per
    /// `limits.digest_chunk`).
    pub fn new(data: Dataset, limits: SessionLimits) -> Session {
        let digest = ShardDigest::over(&data, limits.digest_chunk);
        Session {
            data,
            limits,
            digest,
            dml_cache: Vec::new(),
            live: None,
            total_dml_passes: 0,
            total_cache_hits: 0,
        }
    }

    /// The shard as this site currently holds it.
    pub fn data(&self) -> &Dataset {
        &self.data
    }

    /// The shard's current version — the digest root. Any ingested point
    /// moves it.
    pub fn shard_version(&self) -> u64 {
        self.digest.root()
    }

    /// Leaf-chunk count of the digest (the `chunks` field of `SITEINFO2`).
    pub fn digest_chunks(&self) -> u32 {
        self.digest.chunks()
    }

    /// Cumulative `(dml_passes, cache_hits)` across every serve.
    pub fn dml_stats(&self) -> (usize, usize) {
        (self.total_dml_passes, self.total_cache_hits)
    }

    /// Ingest new points into the shard: append, advance the digest over
    /// just the new tail, and fold the points into the live codebook
    /// incrementally ([`dml::fold_in`] — mini-batch K-means refinement /
    /// online rpTree leaf splits). Returns the number of points added.
    ///
    /// Takes `&mut self` — ingest happens *between* serves (the daemon's
    /// accept loop) or before the first one (`--ingest`), never while a
    /// connection is live on this session.
    pub fn ingest(&mut self, points: &Dataset) -> Result<usize> {
        if points.dim != self.data.dim {
            bail!(
                "ingest of {}-dim points into a {}-dim shard",
                points.dim,
                self.data.dim
            );
        }
        let old_len = self.data.len();
        for i in 0..points.len() {
            self.data.push(points.point(i), points.labels[i]);
        }
        if points.len() == 0 {
            return Ok(0); // digest (and caches) unchanged: nothing arrived
        }
        self.digest.append(&self.data, old_len);
        if let Some((params, cb)) = self.live.as_mut() {
            let params = params.clone();
            dml::fold_in(cb, &self.data, old_len, &params);
        }
        Ok(points.len())
    }

    /// Serve one persistent multi-run connection from a job-serving
    /// leader: the site side of the run-scoped dialect. Each `RUNSTART`
    /// is answered with a registration, each work order compresses the
    /// *same owned shard* (loaded once per daemon — never per run or per
    /// connection) or replays a cached result when the shard version
    /// still matches, and each label frame completes one run, invoking
    /// `on_served`. Frames of different runs may interleave arbitrarily;
    /// per-run state is keyed by run id, bounded by the session's
    /// [`SessionLimits`]. Returns when the leader closes the link
    /// cleanly; errors on protocol violations or a dead link, either of
    /// which sends the daemon back to its accept loop (the session — and
    /// its caches — survive).
    pub fn serve(
        &mut self,
        net: &SiteNet,
        out_path: Option<&Path>,
        mut on_served: impl FnMut(&RunServed),
    ) -> Result<SessionOutcome> {
        struct OpenRun {
            cb: dml::Codebook,
            dml_time: Duration,
            distortion: f64,
            cache_hit: bool,
        }

        let site_id = net.site_id();
        let limits = self.limits;
        // Runs whose labels have not come back yet, by run id: the
        // assignment table must survive until populate time.
        let mut open: HashMap<u32, OpenRun> = HashMap::new();
        // Completed runs' populated labels, newest last, for label pulls.
        let mut cache: Vec<(u32, Vec<u16>)> = Vec::new();
        let mut outcome = SessionOutcome::default();

        if limits.report_digest {
            // Volunteer the shard version once per connection. The frame
            // is observability, not protocol: run budgets and the result
            // cache never depend on the leader having seen it.
            net.send(&Message::SiteInfo2 {
                site: site_id as u32,
                n_points: self.data.len() as u64,
                dim: self.data.dim as u32,
                digest: self.digest.root(),
                chunks: self.digest.chunks(),
            })
            .context("send digest report")?;
        }

        loop {
            let msg = match net.recv_opt().context("await next session frame")? {
                Some(msg) => msg,
                None => {
                    outcome.aborted_runs = open.len();
                    return Ok(outcome); // leader closed cleanly between frames
                }
            };
            match msg {
                Message::RunStart { run } => {
                    // Register this shard for the new run; budgets come back
                    // with the work order.
                    net.send(&Message::RunSiteInfo {
                        run,
                        site: site_id as u32,
                        n_points: self.data.len() as u64,
                        dim: self.data.dim as u32,
                    })
                    .context("send run registration")?;
                }
                Message::RunDmlRequest { run, site, dml, target_codes, max_iters, tol, seed } => {
                    if site as usize != site_id {
                        bail!("dml request for run {run} addressed to site {site}, this is site {site_id}");
                    }
                    if open.contains_key(&run) {
                        bail!("two dml requests for run {run}");
                    }
                    if open.len() >= limits.max_open_runs {
                        bail!(
                            "leader holds {} runs open on one session ([site] max_open_runs)",
                            limits.max_open_runs
                        );
                    }
                    let params = DmlParams {
                        kind: dml,
                        target_codes: target_codes as usize,
                        max_iters: max_iters as usize,
                        tol,
                        seed,
                    };
                    let (cb, dml_time, distortion, cache_hit) = self.dml_for(&params);
                    net.send(&Message::RunCodebook {
                        run,
                        site: site_id as u32,
                        dim: cb.dim as u32,
                        codewords: cb.codewords.clone(),
                        weights: cb.weights.clone(),
                    })
                    .context("send run codebook")?;
                    if cache_hit {
                        outcome.cache_hits += 1;
                    } else {
                        outcome.dml_passes += 1;
                    }
                    // Stash per-run context for the populate phase (and the
                    // DML cost, reported via the completion callback).
                    cache.retain(|(r, _)| *r != run); // a reused id replaces its labels
                    open.insert(run, OpenRun { cb, dml_time, distortion, cache_hit });
                }
                Message::RunLabels { run, site, labels } => {
                    if site as usize != site_id {
                        bail!("label frame for run {run} addressed to site {site}, this is site {site_id}");
                    }
                    let Some(o) = open.remove(&run) else {
                        bail!("labels for run {run}, which is not open on this session");
                    };
                    if labels.len() != o.cb.n_codes() {
                        bail!(
                            "leader sent {} labels for {} codewords (run {run})",
                            labels.len(),
                            o.cb.n_codes()
                        );
                    }
                    let (point_labels, _populate_time) = populate(&o.cb, &labels);
                    if let Some(path) = out_path {
                        write_labels(path, &point_labels)?;
                    }
                    on_served(&RunServed {
                        run,
                        n_points: self.data.len(),
                        n_codes: o.cb.n_codes(),
                        dml_time: o.dml_time,
                        distortion: o.distortion,
                        cache_hit: o.cache_hit,
                    });
                    cache.push((run, point_labels));
                    if cache.len() > limits.label_cache_runs {
                        cache.remove(0);
                    }
                    outcome.runs_served += 1;
                }
                Message::LabelsPull { run } => {
                    match cache.iter().find(|(r, _)| *r == run) {
                        Some((_, labels)) => net
                            .send(&Message::SiteLabels {
                                run,
                                site: site_id as u32,
                                labels: labels.clone(),
                            })
                            .context("send pulled labels")?,
                        None => net
                            .send(&Message::Reject {
                                run,
                                msg: format!(
                                    "run {run} is not in this site's label cache \
                                     (keeps the last {} runs — [site] label_cache_runs)",
                                    limits.label_cache_runs
                                ),
                            })
                            .context("send pull refusal")?,
                    }
                }
                other => bail!("unexpected message in a multi-run session: {other:?}"),
            }
        }
    }

    /// Answer one work order: a cache hit replays the stored codebook
    /// (zero DML passes, `dml_time` zero); a miss recomputes from scratch
    /// — deterministically, so hit and miss are bit-interchangeable — and
    /// caches the result under the current shard version.
    fn dml_for(&mut self, params: &DmlParams) -> (dml::Codebook, Duration, f64, bool) {
        let version = self.digest.root();
        if self.limits.cache_dml {
            if let Some(e) = self
                .dml_cache
                .iter()
                .rev()
                .find(|e| e.version == version && e.params == *params)
            {
                self.total_cache_hits += 1;
                return (e.cb.clone(), Duration::ZERO, e.distortion, true);
            }
        }
        let (cb, dml_time, distortion) = run_dml(&self.data, params);
        self.total_dml_passes += 1;
        self.live = Some((params.clone(), cb.clone()));
        if self.limits.cache_dml {
            self.dml_cache.push(DmlCacheEntry {
                params: params.clone(),
                version,
                cb: cb.clone(),
                distortion,
            });
            if self.dml_cache.len() > self.limits.dml_cache_runs {
                self.dml_cache.remove(0);
            }
        }
        (cb, dml_time, distortion, false)
    }

    /// The live codebook — the most recent DML result, refined in place
    /// by every ingest since — with the work order it answers.
    pub fn live_codebook(&self) -> Option<(&DmlParams, &dml::Codebook)> {
        self.live.as_ref().map(|(p, cb)| (p, cb))
    }
}

/// Serve one persistent multi-run session over a fresh [`Session`] that
/// borrows nothing past the call: the historical entry point, used where
/// the shard serves exactly one connection (the in-process harness's site
/// threads, the TCP load twin). Daemons that outlive connections — and
/// anything that ingests — hold a [`Session`] and call
/// [`Session::serve`] per connection instead, keeping the result cache
/// warm across reconnects.
pub fn session(
    net: &SiteNet,
    data: &Dataset,
    out_path: Option<&Path>,
    limits: SessionLimits,
    on_served: impl FnMut(&RunServed),
) -> Result<SessionOutcome> {
    Session::new(data.clone(), limits).serve(net, out_path, on_served)
}

/// Persist populated labels for the `dsc site --out` daemon flag: one
/// decimal label per line, local point order (the same order as the rows of
/// the site's `--data` CSV).
pub fn write_labels(path: &Path, labels: &[u16]) -> Result<()> {
    use std::io::Write;
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent).ok();
        }
    }
    let file = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = std::io::BufWriter::new(file);
    for l in labels {
        writeln!(w, "{l}")?;
    }
    w.flush()?;
    Ok(())
}

/// Read a label file written by [`write_labels`] (drivers that evaluate a
/// multi-process run, e.g. `examples/tcp_cluster.rs`, use this).
pub fn read_labels(path: &Path) -> Result<Vec<u16>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read {}", path.display()))?;
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| l.trim().parse::<u16>().with_context(|| format!("bad label line {l:?}")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gmm;
    use crate::dml::DmlKind;
    use crate::net::{star, LinkSpec};

    /// Drive one site by hand over the channel transport: the leader side
    /// here is the test itself, which pins the message order.
    #[test]
    fn serve_follows_the_protocol() {
        let ds = gmm::paper_mixture_2d(400, 5);
        let (leader, mut sites) = star(1, LinkSpec::default());
        let site_net = sites.remove(0);

        let worker = std::thread::spawn({
            let ds = ds.clone();
            move || serve(&site_net, &ds)
        });

        let (sid, info) = leader.recv().unwrap();
        assert_eq!(sid, 0);
        match info {
            Message::SiteInfo { site, n_points, dim } => {
                assert_eq!((site, n_points, dim), (0, 400, 2));
            }
            other => panic!("expected site info, got {other:?}"),
        }

        leader
            .send(
                0,
                &Message::DmlRequest {
                    site: 0,
                    dml: DmlKind::KMeans,
                    target_codes: 16,
                    max_iters: 20,
                    tol: 1e-6,
                    seed: 9,
                },
            )
            .unwrap();

        let (_, cb) = leader.recv().unwrap();
        let n_codes = match cb {
            Message::Codebook { site, dim, codewords, weights } => {
                assert_eq!((site, dim), (0, 2));
                assert_eq!(codewords.len(), 2 * weights.len());
                assert_eq!(weights.iter().map(|&w| w as usize).sum::<usize>(), 400);
                weights.len()
            }
            other => panic!("expected codebook, got {other:?}"),
        };
        assert_eq!(n_codes, 16);

        leader
            .send(0, &Message::Labels { site: 0, labels: vec![3u16; n_codes] })
            .unwrap();

        let out = worker.join().unwrap().unwrap();
        assert_eq!(out.site_id, 0);
        assert_eq!(out.n_points, 400);
        assert_eq!(out.n_codes, 16);
        assert_eq!(out.labels, vec![3u16; 400]);
        assert!(out.distortion >= 0.0);
    }

    #[test]
    fn serve_rejects_misaddressed_request() {
        let ds = gmm::paper_mixture_2d(50, 7);
        let (leader, mut sites) = star(1, LinkSpec::default());
        let site_net = sites.remove(0);
        let worker = std::thread::spawn({
            let ds = ds.clone();
            move || serve(&site_net, &ds)
        });
        let _ = leader.recv().unwrap(); // site info
        leader
            .send(
                0,
                &Message::DmlRequest {
                    site: 5, // wrong address
                    dml: DmlKind::KMeans,
                    target_codes: 4,
                    max_iters: 5,
                    tol: 1e-6,
                    seed: 1,
                },
            )
            .unwrap();
        assert!(worker.join().unwrap().is_err());
    }

    /// Drive one site session by hand: two runs opened back to back, work
    /// orders and labels delivered in *swapped* order (run-scoped frames
    /// make the interleaving legal), then label pulls for a cached and an
    /// unknown run.
    #[test]
    fn session_serves_interleaved_runs_and_pulls() {
        let ds = gmm::paper_mixture_2d(300, 9);
        let (leader, mut sites) = star(1, LinkSpec::default());
        let site_net = sites.remove(0);

        let worker = std::thread::spawn({
            let ds = ds.clone();
            move || {
                let mut served = Vec::new();
                let out =
                    session(&site_net, &ds, None, SessionLimits::default(), |r| {
                        served.push(r.run)
                    })
                    .unwrap();
                (out, served)
            }
        });

        leader.send(0, &Message::RunStart { run: 1 }).unwrap();
        leader.send(0, &Message::RunStart { run: 2 }).unwrap();
        for expect in [1u32, 2] {
            match leader.recv().unwrap().1 {
                Message::RunSiteInfo { run, site, n_points, dim } => {
                    assert_eq!((run, site, n_points, dim), (expect, 0, 300, 2));
                }
                other => panic!("expected a registration, got {other:?}"),
            }
        }

        // run 2's work order first: per-run state must be keyed by run id
        for run in [2u32, 1] {
            leader
                .send(
                    0,
                    &Message::RunDmlRequest {
                        run,
                        site: 0,
                        dml: DmlKind::KMeans,
                        target_codes: 8,
                        max_iters: 10,
                        tol: 1e-6,
                        seed: run as u64,
                    },
                )
                .unwrap();
        }
        let mut n_codes = std::collections::HashMap::new();
        for _ in 0..2 {
            match leader.recv().unwrap().1 {
                Message::RunCodebook { run, site, dim, codewords, weights } => {
                    assert_eq!((site, dim), (0, 2));
                    assert_eq!(codewords.len(), 2 * weights.len());
                    n_codes.insert(run, weights.len());
                }
                other => panic!("expected a codebook, got {other:?}"),
            }
        }
        assert_eq!(n_codes.get(&1), Some(&8));
        assert_eq!(n_codes.get(&2), Some(&8));

        leader.send(0, &Message::RunLabels { run: 1, site: 0, labels: vec![7; 8] }).unwrap();
        leader.send(0, &Message::RunLabels { run: 2, site: 0, labels: vec![3; 8] }).unwrap();

        // pull a completed run's populated labels through the link
        leader.send(0, &Message::LabelsPull { run: 1 }).unwrap();
        match leader.recv().unwrap().1 {
            Message::SiteLabels { run, site, labels } => {
                assert_eq!((run, site), (1, 0));
                assert_eq!(labels, vec![7u16; 300]);
            }
            other => panic!("expected pulled labels, got {other:?}"),
        }
        // an unknown run is refused, not fatal
        leader.send(0, &Message::LabelsPull { run: 99 }).unwrap();
        match leader.recv().unwrap().1 {
            Message::Reject { run, msg } => {
                assert_eq!(run, 99);
                assert!(msg.contains("label cache"), "{msg}");
            }
            other => panic!("expected a refusal, got {other:?}"),
        }

        drop(leader); // clean close: the session ends without error
        let (out, served) = worker.join().unwrap();
        assert_eq!(out.runs_served, 2);
        assert_eq!(out.aborted_runs, 0);
        assert_eq!(served, vec![1, 2]);
    }

    #[test]
    fn session_rejects_labels_for_unopened_run() {
        let ds = gmm::paper_mixture_2d(50, 11);
        let (leader, mut sites) = star(1, LinkSpec::default());
        let site_net = sites.remove(0);
        let worker = std::thread::spawn({
            let ds = ds.clone();
            move || session(&site_net, &ds, None, SessionLimits::default(), |_| {})
        });
        leader.send(0, &Message::RunLabels { run: 5, site: 0, labels: vec![1] }).unwrap();
        assert!(worker.join().unwrap().is_err());
    }

    /// `[site] label_cache_runs` really bounds the pull cache: with a
    /// 1-run cache, completing a second run evicts the first.
    #[test]
    fn label_cache_limit_evicts_oldest_run() {
        let ds = gmm::paper_mixture_2d(80, 13);
        let (leader, mut sites) = star(1, LinkSpec::default());
        let site_net = sites.remove(0);
        let limits = SessionLimits { label_cache_runs: 1, ..Default::default() };
        let worker = std::thread::spawn({
            let ds = ds.clone();
            move || session(&site_net, &ds, None, limits, |_| {})
        });

        for run in [1u32, 2] {
            leader.send(0, &Message::RunStart { run }).unwrap();
            let _ = leader.recv().unwrap(); // registration
            leader
                .send(
                    0,
                    &Message::RunDmlRequest {
                        run,
                        site: 0,
                        dml: DmlKind::KMeans,
                        target_codes: 4,
                        max_iters: 5,
                        tol: 1e-6,
                        seed: run as u64,
                    },
                )
                .unwrap();
            let _ = leader.recv().unwrap(); // codebook
            leader
                .send(0, &Message::RunLabels { run, site: 0, labels: vec![run as u16; 4] })
                .unwrap();
        }

        // run 1 was evicted by run 2; the refusal names the config key
        leader.send(0, &Message::LabelsPull { run: 1 }).unwrap();
        match leader.recv().unwrap().1 {
            Message::Reject { run, msg } => {
                assert_eq!(run, 1);
                assert!(msg.contains("last 1 runs"), "{msg}");
                assert!(msg.contains("label_cache_runs"), "{msg}");
            }
            other => panic!("expected a refusal, got {other:?}"),
        }
        leader.send(0, &Message::LabelsPull { run: 2 }).unwrap();
        match leader.recv().unwrap().1 {
            Message::SiteLabels { run, labels, .. } => {
                assert_eq!(run, 2);
                assert_eq!(labels, vec![2u16; 80]);
            }
            other => panic!("expected run 2's labels, got {other:?}"),
        }

        drop(leader);
        worker.join().unwrap().unwrap();
    }

    /// `[site] max_open_runs` is the hostile-leader backstop: one more
    /// work order than the limit kills the session with a loud error.
    #[test]
    fn open_run_backstop_errors_past_the_limit() {
        let ds = gmm::paper_mixture_2d(60, 17);
        let (leader, mut sites) = star(1, LinkSpec::default());
        let site_net = sites.remove(0);
        let limits = SessionLimits { max_open_runs: 2, ..Default::default() };
        let worker = std::thread::spawn({
            let ds = ds.clone();
            move || session(&site_net, &ds, None, limits, |_| {})
        });

        for run in 1u32..=3 {
            leader
                .send(
                    0,
                    &Message::RunDmlRequest {
                        run,
                        site: 0,
                        dml: DmlKind::KMeans,
                        target_codes: 4,
                        max_iters: 5,
                        tol: 1e-6,
                        seed: 1,
                    },
                )
                .unwrap();
        }
        // runs 1 and 2 produce codebooks; run 3 trips the backstop
        let _ = leader.recv().unwrap();
        let _ = leader.recv().unwrap();
        let err = worker.join().unwrap().unwrap_err();
        assert!(err.to_string().contains("2 runs open"), "{err}");
        assert!(err.to_string().contains("max_open_runs"), "{err}");
    }

    #[test]
    fn label_file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("dsc_site_labels_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("labels.txt");
        let labels = vec![0u16, 3, 65535, 2];
        write_labels(&path, &labels).unwrap();
        assert_eq!(read_labels(&path).unwrap(), labels);
        std::fs::remove_dir_all(&dir).ok();
    }
}
