//! The site half of the distributed protocol — one worker, any transport.
//!
//! [`serve`] is the *entire* behavior of a site for one pipeline run:
//! register the local shard, receive the DML work order, compress, ship the
//! codebook, await codeword labels, populate per-point labels. The same
//! function drives
//!
//! * the in-process site threads that [`crate::coordinator::run_pipeline`]
//!   spawns over the channel transport, and
//! * the `dsc site` daemon process serving a real leader over TCP
//!   ([`crate::net::tcp::SiteListener`]).
//!
//! That symmetry is what makes the backends byte-identical: there is one
//! protocol implementation, not a simulated one and a real one.
//!
//! Per-phase costs are **thread CPU time**: sites are independent machines
//! in the paper's model, so when they are simulated as threads of one
//! (possibly single-core) host, scheduler contention between them must not
//! leak into the max-over-sites elapsed model. See
//! [`crate::metrics::thread_cpu_time`].

use std::path::Path;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::data::Dataset;
use crate::dml::{self, DmlParams};
use crate::net::{Message, SiteNet};

/// What one site produced and measured during a pipeline run.
#[derive(Clone, Debug)]
pub struct ServeOutcome {
    /// The id the leader addressed this site by.
    pub site_id: usize,
    /// Points in the local shard.
    pub n_points: usize,
    /// Codewords this site shipped.
    pub n_codes: usize,
    /// Thread CPU time of the DML phase.
    pub dml_time: Duration,
    /// Thread CPU time of the label-population phase.
    pub populate_time: Duration,
    /// Mean squared quantization distortion (Theorem 2/3 quantity).
    pub distortion: f64,
    /// Predicted label per local point, in local point order. Mapping local
    /// to global indices is the caller's business (a real site has no
    /// global view; the in-process coordinator keeps `global_idx`).
    pub labels: Vec<u16>,
}

/// Serve one pipeline run over an established link: the site side of the
/// protocol in `docs/PROTOCOL.md` §"One run".
pub fn serve(net: &SiteNet, data: &Dataset) -> Result<ServeOutcome> {
    let site_id = net.site_id();

    // 1. Register the shard so the leader can size codeword budgets.
    net.send(&Message::SiteInfo {
        site: site_id as u32,
        n_points: data.len() as u64,
        dim: data.dim as u32,
    })
    .context("send site info")?;

    // 2. The DML work order (transform, budget, knobs, forked seed).
    let params = match net.recv().context("await dml request")? {
        Message::DmlRequest { site, dml, target_codes, max_iters, tol, seed } => {
            if site as usize != site_id {
                bail!("dml request addressed to site {site}, this is site {site_id}");
            }
            DmlParams {
                kind: dml,
                target_codes: target_codes as usize,
                max_iters: max_iters as usize,
                tol,
                seed,
            }
        }
        other => bail!("expected a dml request, got {other:?}"),
    };

    // 3. Compress locally; only the codebook leaves the site.
    let t0 = crate::metrics::thread_cpu_time();
    let cb = dml::apply(data, &params);
    let dml_time = crate::metrics::thread_cpu_time().saturating_sub(t0);
    debug_assert!(cb.validate(data.len()).is_ok());
    let distortion = cb.distortion(data);

    net.send(&Message::Codebook {
        site: site_id as u32,
        dim: cb.dim as u32,
        codewords: cb.codewords.clone(),
        weights: cb.weights.clone(),
    })
    .context("send codebook")?;

    // 4. Codeword labels come back after the leader's central phase. The
    //    link sits idle for that whole phase — transports must tolerate it.
    let code_labels = match net.recv().context("await codeword labels")? {
        Message::Labels { site, labels } => {
            if site as usize != site_id {
                bail!("label frame addressed to site {site}, this is site {site_id}");
            }
            if labels.len() != cb.n_codes() {
                bail!("leader sent {} labels for {} codewords", labels.len(), cb.n_codes());
            }
            labels
        }
        other => bail!("expected labels, got {other:?}"),
    };

    // 5. Populate: every local point inherits its codeword's label via the
    //    assignment table that never left this site.
    let t1 = crate::metrics::thread_cpu_time();
    let labels: Vec<u16> =
        cb.assign.iter().map(|&a| code_labels[a as usize]).collect();
    let populate_time = crate::metrics::thread_cpu_time().saturating_sub(t1);

    Ok(ServeOutcome {
        site_id,
        n_points: data.len(),
        n_codes: cb.n_codes(),
        dml_time,
        populate_time,
        distortion,
        labels,
    })
}

/// Persist populated labels for the `dsc site --out` daemon flag: one
/// decimal label per line, local point order (the same order as the rows of
/// the site's `--data` CSV).
pub fn write_labels(path: &Path, labels: &[u16]) -> Result<()> {
    use std::io::Write;
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent).ok();
        }
    }
    let file = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = std::io::BufWriter::new(file);
    for l in labels {
        writeln!(w, "{l}")?;
    }
    w.flush()?;
    Ok(())
}

/// Read a label file written by [`write_labels`] (drivers that evaluate a
/// multi-process run, e.g. `examples/tcp_cluster.rs`, use this).
pub fn read_labels(path: &Path) -> Result<Vec<u16>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read {}", path.display()))?;
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| l.trim().parse::<u16>().with_context(|| format!("bad label line {l:?}")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gmm;
    use crate::dml::DmlKind;
    use crate::net::{star, LinkSpec};

    /// Drive one site by hand over the channel transport: the leader side
    /// here is the test itself, which pins the message order.
    #[test]
    fn serve_follows_the_protocol() {
        let ds = gmm::paper_mixture_2d(400, 5);
        let (leader, mut sites) = star(1, LinkSpec::default());
        let site_net = sites.remove(0);

        let worker = std::thread::spawn({
            let ds = ds.clone();
            move || serve(&site_net, &ds)
        });

        let (sid, info) = leader.recv().unwrap();
        assert_eq!(sid, 0);
        match info {
            Message::SiteInfo { site, n_points, dim } => {
                assert_eq!((site, n_points, dim), (0, 400, 2));
            }
            other => panic!("expected site info, got {other:?}"),
        }

        leader
            .send(
                0,
                &Message::DmlRequest {
                    site: 0,
                    dml: DmlKind::KMeans,
                    target_codes: 16,
                    max_iters: 20,
                    tol: 1e-6,
                    seed: 9,
                },
            )
            .unwrap();

        let (_, cb) = leader.recv().unwrap();
        let n_codes = match cb {
            Message::Codebook { site, dim, codewords, weights } => {
                assert_eq!((site, dim), (0, 2));
                assert_eq!(codewords.len(), 2 * weights.len());
                assert_eq!(weights.iter().map(|&w| w as usize).sum::<usize>(), 400);
                weights.len()
            }
            other => panic!("expected codebook, got {other:?}"),
        };
        assert_eq!(n_codes, 16);

        leader
            .send(0, &Message::Labels { site: 0, labels: vec![3u16; n_codes] })
            .unwrap();

        let out = worker.join().unwrap().unwrap();
        assert_eq!(out.site_id, 0);
        assert_eq!(out.n_points, 400);
        assert_eq!(out.n_codes, 16);
        assert_eq!(out.labels, vec![3u16; 400]);
        assert!(out.distortion >= 0.0);
    }

    #[test]
    fn serve_rejects_misaddressed_request() {
        let ds = gmm::paper_mixture_2d(50, 7);
        let (leader, mut sites) = star(1, LinkSpec::default());
        let site_net = sites.remove(0);
        let worker = std::thread::spawn({
            let ds = ds.clone();
            move || serve(&site_net, &ds)
        });
        let _ = leader.recv().unwrap(); // site info
        leader
            .send(
                0,
                &Message::DmlRequest {
                    site: 5, // wrong address
                    dml: DmlKind::KMeans,
                    target_codes: 4,
                    max_iters: 5,
                    tol: 1e-6,
                    seed: 1,
                },
            )
            .unwrap();
        assert!(worker.join().unwrap().is_err());
    }

    #[test]
    fn label_file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("dsc_site_labels_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("labels.txt");
        let labels = vec![0u16, 3, 65535, 2];
        write_labels(&path, &labels).unwrap();
        assert_eq!(read_labels(&path).unwrap(), labels);
        std::fs::remove_dir_all(&dir).ok();
    }
}
