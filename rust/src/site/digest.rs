//! Merkle-style shard digest — the version tag behind the site's DML
//! result cache and the `SITEINFO2` report.
//!
//! The shard is hashed in fixed-size chunks of points; each chunk yields a
//! 64-bit FNV-1a leaf hash over the raw point bytes (coordinates in
//! little-endian f32 order, then the class label), and the root folds the
//! leaf hashes together with the shard geometry (`n_points`, `dim`). The
//! tree is merkle-*style*, not cryptographic: it exists so that ingesting
//! points is O(tail + new chunks) — only the trailing partial chunk is
//! rehashed and fresh chunks appended — never a full rescan, while any
//! change to any point still flips the root.
//!
//! Determinism matters more than collision resistance here: the root is a
//! cache key and a change detector between two honest ends of one link,
//! and the same bytes must produce the same root on every platform (f32
//! little-endian bytes are, unlike the float values' formatting, exact).

use crate::data::Dataset;

/// Default points per leaf chunk (`[site] digest_chunk`).
pub const DEFAULT_DIGEST_CHUNK: usize = 1024;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

#[inline]
fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Incrementally maintained chunked digest over one site's shard.
#[derive(Clone, Debug)]
pub struct ShardDigest {
    chunk_points: usize,
    /// One FNV-1a hash per chunk of `chunk_points` points (the last leaf
    /// may cover a partial chunk and is rewritten as it fills).
    leaves: Vec<u64>,
    /// Points hashed so far — must track `Dataset::len()` of the shard.
    n_points: usize,
    dim: usize,
}

impl ShardDigest {
    /// Hash the whole shard from scratch.
    pub fn over(data: &Dataset, chunk_points: usize) -> ShardDigest {
        let mut d = ShardDigest {
            chunk_points: chunk_points.max(1),
            leaves: Vec::new(),
            n_points: 0,
            dim: data.dim,
        };
        d.append(data, 0);
        d
    }

    /// Fold points `from..data.len()` into the digest. `from` must equal
    /// the number of points already hashed — appends are strictly
    /// sequential, mirroring `Dataset::push`. Only the trailing partial
    /// leaf is rehashed; full leaves behind it are never touched.
    pub fn append(&mut self, data: &Dataset, from: usize) {
        assert_eq!(
            from, self.n_points,
            "digest append must continue from the last hashed point"
        );
        assert_eq!(data.dim, self.dim, "digest append with a different dim");
        assert!(from <= data.len());
        // Drop the trailing partial leaf (if any): it is rehashed below
        // together with the new points that extend it.
        let first_dirty = from - (from % self.chunk_points);
        self.leaves.truncate(first_dirty / self.chunk_points);

        let mut i = first_dirty;
        while i < data.len() {
            let end = (i + self.chunk_points).min(data.len());
            let mut h = FNV_OFFSET;
            for p in i..end {
                for &v in data.point(p) {
                    h = fnv1a(h, &v.to_le_bytes());
                }
                h = fnv1a(h, &data.labels[p].to_le_bytes());
            }
            self.leaves.push(h);
            i = end;
        }
        self.n_points = data.len();
    }

    /// The root: leaf hashes folded with the shard geometry. Two shards
    /// with the same points in the same order (and the same chunking)
    /// share a root; any ingested point moves it.
    pub fn root(&self) -> u64 {
        let mut h = FNV_OFFSET;
        h = fnv1a(h, &(self.n_points as u64).to_le_bytes());
        h = fnv1a(h, &(self.dim as u64).to_le_bytes());
        for leaf in &self.leaves {
            h = fnv1a(h, &leaf.to_le_bytes());
        }
        h
    }

    /// Leaf count (the `chunks` field of `SITEINFO2`).
    pub fn chunks(&self) -> u32 {
        self.leaves.len() as u32
    }

    /// Points hashed so far.
    pub fn n_points(&self) -> usize {
        self.n_points
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gmm;

    fn shard(n: usize, seed: u64) -> Dataset {
        gmm::paper_mixture_2d(n, seed)
    }

    #[test]
    fn append_equals_from_scratch_at_every_boundary() {
        // The merkle property: growing the digest incrementally — in any
        // number of installments, across chunk boundaries — produces the
        // same root as hashing the final shard in one pass.
        let full = shard(100, 3);
        for chunk in [1usize, 4, 7, 100, 1000] {
            for cut in [1usize, 3, 4, 5, 50, 99] {
                let mut grown = Dataset::new("g", full.dim, full.n_classes);
                for i in 0..cut {
                    grown.push(full.point(i), full.labels[i]);
                }
                let mut d = ShardDigest::over(&grown, chunk);
                let before = d.root();
                for i in cut..full.len() {
                    grown.push(full.point(i), full.labels[i]);
                }
                d.append(&grown, cut);
                let scratch = ShardDigest::over(&full, chunk);
                assert_eq!(d.root(), scratch.root(), "chunk={chunk} cut={cut}");
                assert_eq!(d.chunks(), scratch.chunks());
                assert_ne!(before, d.root(), "ingest must move the root");
            }
        }
    }

    #[test]
    fn every_split_of_three_chunks_appends_to_the_scratch_root() {
        // Exhaustive boundary sweep: for shards of every size up to three
        // full chunks and *every* split point — including cut = 0 (grow
        // from empty), cut = n (a zero-point append), and cuts landing
        // exactly on leaf boundaries — the incremental root equals the
        // from-scratch root bit for bit. The selected-cut test above
        // samples this space; this one closes it for small chunks, where
        // the partial-leaf truncation arithmetic has all its edge cases.
        let source = shard(64, 11);
        for chunk in [1usize, 4, 5] {
            for n in 0..=3 * chunk {
                let mut full = Dataset::new("f", source.dim, source.n_classes);
                for i in 0..n {
                    full.push(source.point(i), source.labels[i]);
                }
                let scratch = ShardDigest::over(&full, chunk);
                for cut in 0..=n {
                    let mut grown = Dataset::new("g", source.dim, source.n_classes);
                    for i in 0..cut {
                        grown.push(source.point(i), source.labels[i]);
                    }
                    let mut d = ShardDigest::over(&grown, chunk);
                    for i in cut..n {
                        grown.push(source.point(i), source.labels[i]);
                    }
                    d.append(&grown, cut);
                    assert_eq!(
                        d.root(),
                        scratch.root(),
                        "chunk={chunk} n={n} cut={cut}"
                    );
                    assert_eq!(d.chunks(), scratch.chunks(), "chunk={chunk} n={n} cut={cut}");
                    assert_eq!(d.n_points(), n);
                    // a second zero-point append is a no-op on the root
                    d.append(&grown, n);
                    assert_eq!(d.root(), scratch.root(), "idempotent tail rehash");
                }
            }
        }
    }

    #[test]
    fn any_point_change_flips_the_root() {
        let a = shard(64, 5);
        let base = ShardDigest::over(&a, 16).root();
        for i in [0usize, 15, 16, 40, 63] {
            let mut b = a.clone();
            b.points[i * b.dim] += 1.0;
            assert_ne!(ShardDigest::over(&b, 16).root(), base, "point {i}");
        }
        // a label change alone flips it too: the digest covers the shard
        let mut c = a.clone();
        c.labels[20] ^= 1;
        assert_ne!(ShardDigest::over(&c, 16).root(), base);
    }

    #[test]
    fn chunk_size_changes_the_root_but_not_consistency() {
        let a = shard(50, 7);
        let d16 = ShardDigest::over(&a, 16);
        let d8 = ShardDigest::over(&a, 8);
        assert_eq!(d16.chunks(), 4); // 16+16+16+2
        assert_eq!(d8.chunks(), 7); // 6×8 + 2
        assert_ne!(d16.root(), d8.root());
        // same data, same chunking → same root (it is a pure function)
        assert_eq!(d16.root(), ShardDigest::over(&a, 16).root());
    }

    #[test]
    fn empty_shard_has_a_stable_root() {
        let e = Dataset::new("e", 3, 1);
        let d = ShardDigest::over(&e, 4);
        assert_eq!(d.chunks(), 0);
        assert_eq!(d.n_points(), 0);
        assert_eq!(d.root(), ShardDigest::over(&e, 4).root());
        // geometry is part of the root: an empty 2-D shard differs
        let e2 = Dataset::new("e2", 2, 1);
        assert_ne!(d.root(), ShardDigest::over(&e2, 4).root());
    }
}
