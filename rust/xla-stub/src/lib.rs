//! Compile-time stub of the PJRT/XLA binding surface `dsc`'s `runtime`
//! module uses (`PjRtClient`, `HloModuleProto`, `XlaComputation`,
//! `PjRtLoadedExecutable`, `Literal`).
//!
//! The stub exists so `cargo build --features xla` type-checks offline with
//! no accelerator toolchain present. Every runtime entry point returns
//! [`Error`] — nothing here executes HLO. A deployment with the real
//! vendored `xla` bindings replaces this crate through a `[patch]` section
//! in the workspace `Cargo.toml` (see the repository README, "The `xla`
//! feature"); the API below mirrors the subset of the real crate that `dsc`
//! calls, so the swap is manifest-only.

use std::fmt;

/// Error returned by every stub entry point.
pub struct Error(String);

impl Error {
    fn stub(what: &str) -> Error {
        Error(format!(
            "xla stub: {what} is unavailable (this build links the compile-time \
             stub; vendor the real xla bindings via [patch] to execute HLO)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla::Error({})", self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result alias matching the real binding's fallible calls.
pub type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle. [`PjRtClient::cpu`] always errors in the stub.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Create a CPU PJRT client. Always errors in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::stub("PjRtClient::cpu"))
    }

    /// Compile a computation into a loaded executable. Unreachable in the
    /// stub (no client can exist), kept for signature parity.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub("PjRtClient::compile"))
    }
}

/// Parsed HLO module handle.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse an HLO **text** file. Always errors in the stub.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::stub("HloModuleProto::from_text_file"))
    }
}

/// A computation wrapping an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A compiled, device-loaded executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with the given arguments, returning per-device, per-output
    /// buffers. Always errors in the stub.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer produced by execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Copy the buffer back to a host literal. Always errors in the stub.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub("PjRtBuffer::to_literal_sync"))
    }
}

/// Host-side literal value (dense array or tuple).
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Build a rank-1 `f32` literal.
    pub fn vec1(_values: &[f32]) -> Literal {
        Literal { _private: () }
    }

    /// Reshape to the given dimensions. Always errors in the stub.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::stub("Literal::reshape"))
    }

    /// Destructure a 3-tuple literal. Always errors in the stub.
    pub fn to_tuple3(self) -> Result<(Literal, Literal, Literal)> {
        Err(Error::stub("Literal::to_tuple3"))
    }

    /// Destructure a 4-tuple literal. Always errors in the stub.
    pub fn to_tuple4(self) -> Result<(Literal, Literal, Literal, Literal)> {
        Err(Error::stub("Literal::to_tuple4"))
    }

    /// Copy out the elements. Always errors in the stub.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::stub("Literal::to_vec"))
    }

    /// Read the first element. Always errors in the stub.
    pub fn get_first_element<T>(&self) -> Result<T> {
        Err(Error::stub("Literal::get_first_element"))
    }
}

impl From<f32> for Literal {
    fn from(_value: f32) -> Literal {
        Literal { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_errors_with_stub_message() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2, 1]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("xla stub"));
    }
}
