//! Vendored minimal `anyhow`: the error-handling subset this workspace uses,
//! implemented with zero dependencies so a clean checkout builds offline.
//!
//! Provided surface (API-compatible with the crates.io `anyhow` for these
//! items):
//!
//! * [`Error`] — a context-chained error value. `Display` prints the
//!   outermost message; the alternate form (`{:#}`) prints the whole chain
//!   joined by `": "`; `Debug` prints the message followed by a
//!   `Caused by:` list.
//! * [`Result`] — `Result<T, Error>` alias with a defaultable error type.
//! * [`anyhow!`] / [`bail!`] / [`ensure!`] — `format!`-style constructors.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` (for
//!   any `std::error::Error` and for [`Error`] itself) and on `Option`.
//! * `?` conversion from any `E: std::error::Error + Send + Sync + 'static`.
//!
//! Unlike the real crate there is no backtrace capture and no downcasting —
//! the source error is flattened into its message chain at conversion time.

use std::fmt;

/// A context-chained error value (outermost context first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Flatten a standard error and its `source()` chain into messages.
    fn from_std<E: std::error::Error>(error: E) -> Error {
        let mut chain = vec![error.to_string()];
        let mut source = error.source();
        while let Some(cause) = source {
            chain.push(cause.to_string());
            source = cause.source();
        }
        Error { chain }
    }

    /// The messages of the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost message of the chain.
    pub fn root_cause(&self) -> &str {
        self.chain.last().expect("error chain is never empty")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::from_std(error)
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Conversion into [`Error`] for the [`Context`] blanket impl: covers every
/// standard error *and* `Error` itself (which deliberately does not
/// implement `std::error::Error`, keeping the two impls coherent — the same
/// trick the real crate uses).
mod ext {
    pub trait IntoError {
        fn into_error(self) -> super::Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> super::Error {
            super::Error::from_std(self)
        }
    }

    impl IntoError for super::Error {
        fn into_error(self) -> super::Error {
            self
        }
    }
}

/// Attach context to fallible values.
pub trait Context<T> {
    /// Wrap the error (or `None`) with `context`.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    /// Like [`Context::context`], but the message is built lazily.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: ext::IntoError> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(ext::IntoError::into_error(e).context(context)),
        }
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(ext::IntoError::into_error(e).context(f())),
        }
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a `format!`-style message (or any
/// displayable expression).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`anyhow!`] error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn display_shows_outermost_alternate_shows_chain() {
        let e: Error = Err::<(), _>(io_err()).context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: missing thing");
    }

    #[test]
    fn debug_lists_causes() {
        let e: Error = Err::<(), _>(io_err())
            .context("mid")
            .context("outer")
            .unwrap_err();
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("outer"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("mid"));
        assert!(dbg.contains("missing thing"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<i32> {
            let n: i32 = "42".parse()?;
            let _bad: Result<i32> = Err("x".parse::<i32>().unwrap_err().into());
            Ok(n)
        }
        assert_eq!(inner().unwrap(), 42);
    }

    #[test]
    fn option_context() {
        let none: Option<u8> = None;
        let e = none.context("empty csv").unwrap_err();
        assert_eq!(e.to_string(), "empty csv");
        let lazy: Option<u8> = None;
        let e = lazy.with_context(|| format!("slot {}", 3)).unwrap_err();
        assert_eq!(e.to_string(), "slot 3");
    }

    #[test]
    fn macros_format_and_bail() {
        fn f(n: usize) -> Result<()> {
            ensure!(n < 10, "n too big: {n}");
            if n == 3 {
                bail!("exact failure at {}", n);
            }
            Err(anyhow!("fell through with n={n}"))
        }
        assert_eq!(f(12).unwrap_err().to_string(), "n too big: 12");
        assert_eq!(f(3).unwrap_err().to_string(), "exact failure at 3");
        assert_eq!(f(1).unwrap_err().to_string(), "fell through with n=1");
    }

    #[test]
    fn context_on_result_of_error() {
        // .context must also apply to Result<_, Error> (re-wrapping)
        let e: Result<()> = Err(anyhow!("inner"));
        let e = e.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner");
        assert_eq!(e.root_cause(), "inner");
        assert_eq!(e.chain().count(), 2);
    }
}
